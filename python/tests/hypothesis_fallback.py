"""Deterministic stand-in for the `hypothesis` API surface the kernel tests
use (`given`, `settings`, `strategies.integers/sampled_from`).

The container image does not ship hypothesis and the test environment is
offline, so rather than skipping the property sweeps entirely we replay
them against seeded pseudo-random draws: every test function gets its own
RNG seeded from its qualified name, so runs are reproducible and
independent of execution order. When real hypothesis is installed the
tests import it instead (see test_kernels.py) and this module is unused.
"""

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 — mirrors `hypothesis.strategies` module name
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        opts = list(elements)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples=100, deadline=None, **_ignored):
    """Records the example budget on the decorated (given-wrapped) test."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    """Runs the test once per drawn example, like hypothesis but with a
    fixed per-test seed instead of shrinking/coverage search."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 100)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {name: s.draw(rng) for name, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # pytest must not mistake the drawn parameters for fixtures: hide
        # the inner signature (functools.wraps copies it via __wrapped__).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
