"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes; fixed seeds keep runs deterministic. Tolerances are
loose-ish (2e-5) because interpret-mode pallas and the dense einsum oracle
accumulate in different orders.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline image has no hypothesis — see fallback
    from hypothesis_fallback import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention
from compile.kernels.moe_ffn import moe_ffn, vmem_bytes
from compile.kernels.router import router_postprocess

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# moe_ffn
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    T=st.integers(1, 12),
    d=st.sampled_from([8, 16, 32]),
    N=st.sampled_from([2, 8, 17]),
    f=st.sampled_from([8, 24]),
    seed=st.integers(0, 2**16),
)
def test_moe_ffn_matches_ref(T, d, N, f, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (T, d))
    gates = jax.random.uniform(ks[1], (T, N))
    w1 = jax.random.normal(ks[2], (N, d, f)) * 0.2
    w2 = jax.random.normal(ks[3], (N, f, d)) * 0.2
    got = moe_ffn(x, gates, w1, w2)
    want = ref.moe_ffn_ref(x, gates, w1, w2)
    np.testing.assert_allclose(got, want, **TOL)


def test_moe_ffn_zero_gates_is_zero():
    x = rand(0, 4, 8)
    w1 = rand(1, 6, 8, 16, scale=0.2)
    w2 = rand(2, 6, 16, 8, scale=0.2)
    out = moe_ffn(x, jnp.zeros((4, 6)), w1, w2)
    np.testing.assert_allclose(out, jnp.zeros((4, 8)), atol=1e-7)


def test_moe_ffn_one_hot_gate_selects_single_expert():
    """A token whose gate row is one-hot on expert j must get exactly
    FFN_j(x) — the masked-expert-skipping equivalence the coordinator
    relies on."""
    T, d, N, f = 3, 8, 5, 16
    x = rand(3, T, d)
    w1 = rand(4, N, d, f, scale=0.2)
    w2 = rand(5, N, f, d, scale=0.2)
    j = 2
    gates = jnp.zeros((T, N)).at[:, j].set(1.0)
    got = moe_ffn(x, gates, w1, w2)
    want = jax.nn.silu(x @ w1[j]) @ w2[j]
    np.testing.assert_allclose(got, want, **TOL)


def test_moe_ffn_linear_in_gates():
    """Modularity at the kernel level: output is linear in the gate matrix
    (mirrors Proposition 3.2's modularity of the proxy)."""
    T, d, N, f = 4, 8, 6, 12
    x = rand(6, T, d)
    w1 = rand(7, N, d, f, scale=0.2)
    w2 = rand(8, N, f, d, scale=0.2)
    g1 = jax.random.uniform(jax.random.PRNGKey(9), (T, N))
    g2 = jax.random.uniform(jax.random.PRNGKey(10), (T, N))
    lhs = moe_ffn(x, g1 + g2, w1, w2)
    rhs = moe_ffn(x, g1, w1, w2) + moe_ffn(x, g2, w1, w2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


def test_moe_ffn_vmem_budget_gptoss():
    """Structural perf check (interpret mode gives no TPU timing): the
    expert-major block for the largest preset must fit VMEM comfortably."""
    assert vmem_bytes(T=32, d=64, f=128) < 16 * 2**20 / 8


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    T=st.integers(1, 16),
    N=st.sampled_from([4, 64, 256]),
    n_pad=st.integers(0, 4),
    seed=st.integers(0, 2**16),
)
def test_router_matches_ref(T, N, n_pad, seed):
    n_pad = min(n_pad, T - 1) if T > 1 else 0
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, N)) * 3
    active = jnp.ones((T,)).at[T - n_pad :].set(0.0) if n_pad else jnp.ones((T,))
    p, c = router_postprocess(logits, active)
    pr, cr = ref.router_ref(logits, active)
    np.testing.assert_allclose(p, pr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c, cr, rtol=1e-6, atol=1e-6)


def test_router_probs_rows_sum_to_one():
    logits = rand(11, 8, 32, scale=4.0)
    p, _ = router_postprocess(logits, jnp.ones((8,)))
    np.testing.assert_allclose(p.sum(-1), jnp.ones(8), rtol=1e-6)


def test_router_colsum_ignores_padded_rows():
    """Padding must never leak into the batch utility — selection would
    otherwise see ghost tokens."""
    logits = rand(12, 6, 16, scale=2.0)
    full = jnp.ones((6,))
    half = jnp.array([1, 1, 1, 0, 0, 0], jnp.float32)
    _, c_half = router_postprocess(logits, half)
    _, c_live = router_postprocess(logits[:3], jnp.ones((3,)))
    np.testing.assert_allclose(c_half, c_live, rtol=1e-6, atol=1e-6)
    _, c_full = router_postprocess(logits, full)
    assert not np.allclose(c_half, c_full)


def test_router_colsum_mass_equals_live_rows():
    """Each live row contributes exactly probability mass 1."""
    logits = rand(13, 10, 64, scale=2.0)
    active = jnp.ones((10,)).at[7:].set(0.0)
    _, c = router_postprocess(logits, active)
    np.testing.assert_allclose(c.sum(), 7.0, rtol=1e-5)


def test_router_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0, 1e4]])
    p, c = router_postprocess(logits, jnp.ones((1,)))
    assert np.isfinite(np.asarray(p)).all()
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 6),
    H=st.sampled_from([1, 2, 4]),
    S=st.sampled_from([4, 16, 33]),
    hd=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(B, H, S, hd, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, H, S, hd))
    vc = jax.random.normal(ks[2], (B, H, S, hd))
    pos = jax.random.randint(ks[3], (B,), 0, S)
    got = decode_attention(q, kc, vc, pos)
    want = ref.decode_attention_ref(q, kc, vc, pos)
    np.testing.assert_allclose(got, want, **TOL)


def test_attention_pos_zero_attends_only_first():
    """pos=0 must return v_cache[:, :, 0] exactly (only one unmasked slot)."""
    B, H, S, hd = 2, 2, 8, 4
    q = rand(20, B, H, hd)
    kc = rand(21, B, H, S, hd)
    vc = rand(22, B, H, S, hd)
    pos = jnp.zeros((B,), jnp.int32)
    got = decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(got, vc[:, :, 0], **TOL)


def test_attention_garbage_beyond_pos_is_ignored():
    """Stale cache slots past pos[b] must not affect the output."""
    B, H, S, hd = 2, 2, 10, 4
    q = rand(23, B, H, hd)
    kc = rand(24, B, H, S, hd)
    vc = rand(25, B, H, S, hd)
    pos = jnp.array([4, 7], jnp.int32)
    base = decode_attention(q, kc, vc, pos)
    kc2 = kc.at[0, :, 5:].set(99.0).at[1, :, 8:].set(-99.0)
    vc2 = vc.at[0, :, 5:].set(99.0).at[1, :, 8:].set(-99.0)
    got = decode_attention(q, kc2, vc2, pos)
    np.testing.assert_allclose(got, base, **TOL)
