"""L2 correctness: decode-step program semantics.

These tests exercise the *traced functions* directly (not the HLO artifacts —
that round-trip is covered by the rust integration suite against the
selftest vectors).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import make_weights, program_signatures, make_selftest_inputs
from compile.configs import TINY, PRESETS
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = TINY


@pytest.fixture(scope="module")
def weights():
    return {k: jnp.asarray(v) for k, v in make_weights(CFG).items()}


def layer_w(weights, l):
    p = f"layer{l}."
    return {k: weights[p + k] for k in
            ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "w1", "w2", "ws1", "ws2")}


def fresh_caches(cfg, B):
    return (
        jnp.zeros((B, cfg.n_heads, cfg.max_seq, cfg.head_dim)),
        jnp.zeros((B, cfg.n_heads, cfg.max_seq, cfg.head_dim)),
    )


def run_attn(weights, hidden, pos, active, kc, vc, l=0):
    w = layer_w(weights, l)
    return M.attn_router(
        hidden, pos, active, kc, vc,
        w["ln1"], w["wq"], w["wk"], w["wv"], w["wo"], w["ln2"], w["wg"],
    )


# ---------------------------------------------------------------------------
# rope / rmsnorm primitives
# ---------------------------------------------------------------------------


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 8))
    pos = jnp.array([0, 5, 11], jnp.int32)
    y = M.rope(x, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )


def test_rope_pos_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 8))
    y = M.rope(x, jnp.zeros((2,), jnp.int32))
    np.testing.assert_allclose(y, x, atol=1e-6)


def test_rope_relative_shift_invariance():
    """<rope(q,p), rope(k,p)> depends only on the content for equal
    positions: dot products are invariant to a common position shift."""
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 8))
    d0 = jnp.sum(M.rope(q, jnp.array([3])) * M.rope(k, jnp.array([3])))
    d1 = jnp.sum(M.rope(q, jnp.array([9])) * M.rope(k, jnp.array([9])))
    np.testing.assert_allclose(d0, d1, rtol=1e-5)


def test_rmsnorm_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 16)) * 3
    s = jax.random.normal(jax.random.PRNGKey(5), (16,))
    np.testing.assert_allclose(M.rmsnorm(x, s), ref.rmsnorm_ref(x, s), rtol=1e-6)


# ---------------------------------------------------------------------------
# attn_router program
# ---------------------------------------------------------------------------


def test_attn_router_shapes(weights):
    B, cfg = CFG.max_batch, CFG
    kc, vc = fresh_caches(cfg, B)
    hidden = jax.random.normal(jax.random.PRNGKey(6), (B, cfg.d_model))
    pos = jnp.zeros((B,), jnp.int32)
    active = jnp.ones((B,))
    h2, logits, probs, colsum, kc2, vc2 = run_attn(weights, hidden, pos, active, kc, vc)
    assert h2.shape == (B, cfg.d_model)
    assert logits.shape == (B, cfg.n_experts)
    assert probs.shape == (B, cfg.n_experts)
    assert colsum.shape == (cfg.n_experts,)
    assert kc2.shape == kc.shape and vc2.shape == vc.shape


def test_attn_router_probs_consistent_with_logits(weights):
    B, cfg = 4, CFG
    kc, vc = fresh_caches(cfg, B)
    hidden = jax.random.normal(jax.random.PRNGKey(7), (B, cfg.d_model))
    _, logits, probs, colsum, _, _ = run_attn(
        weights, hidden, jnp.zeros((B,), jnp.int32), jnp.ones((B,)), kc, vc
    )
    want, want_cs = ref.router_ref(logits, jnp.ones((B,)))
    np.testing.assert_allclose(probs, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(colsum, want_cs, rtol=1e-5, atol=1e-6)


def test_attn_router_cache_write_at_pos(weights):
    """The step's K/V must land at pos[b] and leave other slots untouched."""
    B, cfg = 2, CFG
    kc, vc = fresh_caches(cfg, B)
    kc = kc + 0.123  # sentinel
    hidden = jax.random.normal(jax.random.PRNGKey(8), (B, cfg.d_model))
    pos = jnp.array([0, 3], jnp.int32)
    _, _, _, _, kc2, _ = run_attn(weights, hidden, pos, jnp.ones((B,)), kc, vc)
    changed0 = np.any(np.asarray(kc2[0]) != 0.123, axis=(0, 2))
    changed1 = np.any(np.asarray(kc2[1]) != 0.123, axis=(0, 2))
    assert changed0.tolist() == [i == 0 for i in range(cfg.max_seq)]
    assert changed1.tolist() == [i == 3 for i in range(cfg.max_seq)]


def test_attn_router_step_determinism(weights):
    B, cfg = 3, CFG
    kc, vc = fresh_caches(cfg, B)
    hidden = jax.random.normal(jax.random.PRNGKey(9), (B, cfg.d_model))
    args = (weights, hidden, jnp.zeros((B,), jnp.int32), jnp.ones((B,)), kc, vc)
    a = run_attn(*args)
    b = run_attn(*args)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# prefill_attn_router program (chunked multi-token prefill)
# ---------------------------------------------------------------------------


def run_prefill(weights, hidden, start, valid, row, kc, vc, l=0):
    w = layer_w(weights, l)
    return M.prefill_attn_router(
        hidden, jnp.asarray([start], jnp.int32), jnp.asarray(valid, jnp.float32),
        jnp.asarray([row], jnp.int32), kc, vc,
        w["ln1"], w["wq"], w["wk"], w["wv"], w["wo"], w["ln2"], w["wg"],
    )


def test_prefill_chunk_matches_one_token_walk_bitwise(weights):
    """The load-bearing numerics property of chunked prefill: advancing one
    row by T tokens in a single invocation must reproduce the one-token
    attn_router walk BIT FOR BIT (cache row, hidden2, router scores) — the
    same kernel sees the same per-position inputs. The rust equivalence
    suite builds on this through the whole serving stack."""
    B, cfg = CFG.max_batch, CFG
    row = 1
    rng = np.random.RandomState(3)
    history = rng.randint(0, cfg.vocab, size=2).astype(np.int32)
    chunk = rng.randint(0, cfg.vocab, size=B).astype(np.int32)

    def walk(kc, vc, tokens, start):
        """One-token attn_router steps for `row`, layer 0."""
        h2s, probss = [], []
        for i, tok in enumerate(tokens):
            toks = np.zeros(B, np.int32)
            toks[row] = tok
            (hidden,) = M.embed(jnp.asarray(toks), weights["emb"])
            pos = np.zeros(B, np.int32)
            pos[row] = start + i
            active = np.zeros(B, np.float32)
            active[row] = 1.0
            h2, _, probs, _, kc, vc = run_attn(
                weights, hidden, jnp.asarray(pos), jnp.asarray(active), kc, vc
            )
            h2s.append(np.asarray(h2[row]))
            probss.append(np.asarray(probs[row]))
        return kc, vc, h2s, probss

    # shared history: two one-token steps
    kc, vc = fresh_caches(cfg, B)
    kc, vc, _, _ = walk(kc, vc, history, 0)

    kc_seq, vc_seq, h2_seq, probs_seq = walk(kc, vc, chunk, len(history))

    (hc,) = M.embed(jnp.asarray(chunk), weights["emb"])
    h2c, _, probsc, _, kc_chunk, vc_chunk = run_prefill(
        weights, hc, len(history), np.ones(B, np.float32), row, kc, vc
    )

    np.testing.assert_array_equal(np.asarray(kc_seq[row]), np.asarray(kc_chunk[row]))
    np.testing.assert_array_equal(np.asarray(vc_seq[row]), np.asarray(vc_chunk[row]))
    for i in range(B):
        np.testing.assert_array_equal(h2_seq[i], np.asarray(h2c[i]))
        np.testing.assert_array_equal(probs_seq[i], np.asarray(probsc[i]))


def test_prefill_partial_chunk_preserves_cache_bits(weights):
    """chunk_valid=0 positions must keep the previous cache bytes exactly
    (select, not arithmetic blend) and untouched rows must not change."""
    B, cfg = CFG.max_batch, CFG
    kc, vc = fresh_caches(cfg, B)
    kc = kc + 0.123  # sentinel everywhere
    valid = np.zeros(B, np.float32)
    valid[:2] = 1.0
    rng = np.random.RandomState(4)
    (hc,) = M.embed(jnp.asarray(rng.randint(0, cfg.vocab, B, ), dtype=jnp.int32), weights["emb"])
    _, _, _, _, kc2, _ = run_prefill(weights, hc, 3, valid, 2, kc, vc)
    got = np.asarray(kc2)
    want = np.asarray(kc)
    # rows other than 2 are untouched
    mask_rows = [r for r in range(B) if r != 2]
    np.testing.assert_array_equal(got[mask_rows], want[mask_rows])
    # row 2: only positions 3 and 4 (the valid chunk entries) changed
    changed = np.any(got[2] != 0.123, axis=(0, 2))
    assert changed.tolist() == [i in (3, 4) for i in range(cfg.max_seq)]


def test_prefill_causal_mask_within_chunk(weights):
    """Position i's outputs must not depend on later chunk tokens."""
    B, cfg = CFG.max_batch, CFG
    rng = np.random.RandomState(5)
    toks_a = rng.randint(0, cfg.vocab, size=B).astype(np.int32)
    toks_b = toks_a.copy()
    toks_b[-1] = (toks_b[-1] + 1) % cfg.vocab  # perturb only the last token

    outs = []
    for toks in (toks_a, toks_b):
        kc, vc = fresh_caches(cfg, B)
        (hc,) = M.embed(jnp.asarray(toks), weights["emb"])
        h2, logits, probs, _, _, _ = run_prefill(
            weights, hc, 0, np.ones(B, np.float32), 0, kc, vc
        )
        outs.append((np.asarray(h2), np.asarray(logits), np.asarray(probs)))
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a[: B - 1], b[: B - 1])
        assert np.any(a[B - 1] != b[B - 1])


def test_prefill_colsum_masks_invalid_positions(weights):
    B, cfg = CFG.max_batch, CFG
    kc, vc = fresh_caches(cfg, B)
    rng = np.random.RandomState(6)
    (hc,) = M.embed(jnp.asarray(rng.randint(0, cfg.vocab, B), dtype=jnp.int32), weights["emb"])
    valid = np.zeros(B, np.float32)
    valid[:3] = 1.0
    _, _, probs, colsum, _, _ = run_prefill(weights, hc, 0, valid, 0, kc, vc)
    np.testing.assert_allclose(
        colsum, np.asarray(probs)[:3].sum(axis=0), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# moe_layer program
# ---------------------------------------------------------------------------


def test_moe_layer_matches_manual(weights):
    B, cfg = 4, CFG
    w = layer_w(weights, 0)
    hidden2 = jax.random.normal(jax.random.PRNGKey(10), (B, cfg.d_model))
    gates = jax.random.uniform(jax.random.PRNGKey(11), (B, cfg.n_experts))
    (out,) = M.moe_layer(
        hidden2, gates, w["ln2"], w["w1"], w["w2"], w["ws1"], w["ws2"],
        jnp.asarray([1.0]),
    )
    x2 = ref.rmsnorm_ref(hidden2, w["ln2"])
    want = (
        hidden2
        + ref.moe_ffn_ref(x2, gates, w["w1"], w["w2"])
        + jax.nn.silu(x2 @ w["ws1"]) @ w["ws2"]
    )
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_moe_layer_shared_flag_off(weights):
    """shared_flag=0 must silence the shared expert exactly."""
    B, cfg = 3, CFG
    w = layer_w(weights, 1)
    hidden2 = jax.random.normal(jax.random.PRNGKey(12), (B, cfg.d_model))
    gates = jax.random.uniform(jax.random.PRNGKey(13), (B, cfg.n_experts))
    (off,) = M.moe_layer(
        hidden2, gates, w["ln2"], w["w1"], w["w2"], w["ws1"], w["ws2"],
        jnp.asarray([0.0]),
    )
    x2 = ref.rmsnorm_ref(hidden2, w["ln2"])
    want = hidden2 + ref.moe_ffn_ref(x2, gates, w["w1"], w["w2"])
    np.testing.assert_allclose(off, want, rtol=2e-5, atol=2e-5)


def test_moe_layer_restricted_gates_changes_output_smoothly(weights):
    """Zeroing the lowest-gate expert of each token perturbs the output much
    less than zeroing the highest-gate expert — the monotonicity Assumption
    3.1 (router score reliability) needs from the substrate."""
    B, cfg = 6, CFG
    w = layer_w(weights, 0)
    hidden2 = jax.random.normal(jax.random.PRNGKey(14), (B, cfg.d_model))
    logits = jax.random.normal(jax.random.PRNGKey(15), (B, cfg.n_experts)) * 2
    probs, _ = ref.router_ref(logits, jnp.ones((B,)))
    topk = ref.topk_mask_ref(probs, CFG.top_k)
    gates = jnp.where(topk, probs, 0.0)

    def out(g):
        (o,) = M.moe_layer(
            hidden2, g, w["ln2"], w["w1"], w["w2"], w["ws1"], w["ws2"],
            jnp.asarray([1.0]),
        )
        return o

    base = out(gates)
    # drop per-token weakest selected expert vs strongest
    sel = np.asarray(jnp.where(topk, probs, jnp.inf))
    weakest = sel.argmin(axis=1)
    strongest = np.asarray(jnp.where(topk, probs, -jnp.inf)).argmax(axis=1)
    g_weak = gates.at[jnp.arange(B), weakest].set(0.0)
    g_strong = gates.at[jnp.arange(B), strongest].set(0.0)
    d_weak = float(jnp.linalg.norm(out(g_weak) - base))
    d_strong = float(jnp.linalg.norm(out(g_strong) - base))
    assert d_weak < d_strong


# ---------------------------------------------------------------------------
# lm_head / embed / draft
# ---------------------------------------------------------------------------


def test_embed_lookup(weights):
    toks = jnp.array([0, 1, 5, 5], jnp.int32)
    (h,) = M.embed(toks, weights["emb"])
    np.testing.assert_allclose(h, weights["emb"][toks])
    np.testing.assert_array_equal(np.asarray(h[2]), np.asarray(h[3]))


def test_lm_head_shapes(weights):
    h = jax.random.normal(jax.random.PRNGKey(16), (5, CFG.d_model))
    (logits,) = M.lm_head(h, weights["lnf"], weights["unembed"])
    assert logits.shape == (5, CFG.vocab)


def test_draft_step_runs_and_updates_cache(weights):
    cfg = CFG
    B, Ld = 3, cfg.draft_layers
    Hd, hdd, S = cfg.draft_n_heads, cfg.draft_head_dim, cfg.max_seq
    kc = jnp.zeros((Ld, B, Hd, S, hdd))
    vc = jnp.zeros((Ld, B, Hd, S, hdd))
    toks = jnp.array([1, 2, 3], jnp.int32)
    pos = jnp.array([0, 0, 1], jnp.int32)
    dw = {k.split("draft.")[1]: v for k, v in weights.items() if k.startswith("draft.")}
    logits, kc2, vc2 = M.draft_step(
        toks, pos, kc, vc, dw["emb"], dw["ln1s"], dw["wqs"], dw["wks"], dw["wvs"],
        dw["wos"], dw["ln2s"], dw["wf1s"], dw["wf2s"], dw["lnf"], dw["unembed"],
    )
    assert logits.shape == (B, cfg.vocab)
    assert np.any(np.asarray(kc2) != 0)
    # row 2 wrote at position 1, not 0
    assert np.any(np.asarray(kc2[0, 2, :, 1]) != 0)
    assert not np.any(np.asarray(kc2[0, 2, :, 0]) != 0)


# ---------------------------------------------------------------------------
# signatures / selftest plumbing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preset", list(PRESETS))
def test_signatures_shapes_consistent(preset):
    cfg = PRESETS[preset]
    sigs = program_signatures(cfg)
    for name, sig in sigs.items():
        rng = np.random.RandomState(0)
        vals = make_selftest_inputs(cfg, sig, rng)
        assert len(vals) == len(sig["params"])
        for v, (pname, shape, dt) in zip(vals, sig["params"]):
            assert v.shape == tuple(shape), (name, pname)


def test_selftest_inputs_respect_dtypes():
    sigs = program_signatures(CFG)
    rng = np.random.RandomState(1)
    vals = make_selftest_inputs(CFG, sigs["attn_router"], rng)
    assert vals[1].dtype == np.int32  # pos
    assert vals[0].dtype == np.float32  # hidden
