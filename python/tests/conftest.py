"""Test bootstrap: make `compile.*` importable no matter where pytest is
invoked from (repo root, python/, or python/tests/)."""

import sys
from pathlib import Path

_PKG_ROOT = Path(__file__).resolve().parents[1]
if str(_PKG_ROOT) not in sys.path:
    sys.path.insert(0, str(_PKG_ROOT))
