"""AOT pipeline tests: manifest consistency, weight files, HLO text sanity,
and regeneration determinism — everything the rust runtime assumes."""

import json
import os

import numpy as np
import pytest

from compile.aot import build_preset, make_weights
from compile.configs import TINY, PRESETS


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    manifest = build_preset(TINY, str(root))
    return str(root / TINY.name), manifest


def test_manifest_written_and_loadable(built):
    out_dir, manifest = built
    with open(os.path.join(out_dir, "manifest.json")) as fh:
        on_disk = json.load(fh)
    assert on_disk == manifest
    assert on_disk["format_version"] == 1
    assert on_disk["model"]["name"] == "tiny"


def test_all_program_files_exist_with_entry(built):
    out_dir, manifest = built
    for name, prog in manifest["programs"].items():
        path = os.path.join(out_dir, prog["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text, f"{name}: not HLO text"
        assert "main" in text


def test_weight_files_match_declared_shapes(built):
    out_dir, manifest = built
    assert manifest["weights"], "no weights dumped"
    for wmeta in manifest["weights"]:
        path = os.path.join(out_dir, wmeta["file"])
        n = int(np.prod(wmeta["shape"]))
        arr = np.fromfile(path, "<f4")
        assert arr.size == n, wmeta["name"]


def test_weights_deterministic_per_seed():
    w1 = make_weights(TINY)
    w2 = make_weights(TINY)
    assert set(w1) == set(w2)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])


def test_weights_differ_across_presets():
    names = {TINY.name}
    w_tiny = make_weights(TINY)
    for preset in PRESETS.values():
        if preset.name in names or preset.n_experts != TINY.n_experts:
            continue
        w_other = make_weights(preset)
        assert not np.array_equal(w_tiny["emb"], w_other["emb"])


def test_selftest_vectors_exist_and_sized(built):
    out_dir, manifest = built
    assert manifest["selftests"]
    for name, entry in manifest["selftests"].items():
        prog = manifest["programs"][name]
        assert len(entry["inputs"]) == len(prog["params"])
        assert len(entry["outputs"]) == len(prog["outputs"])
        for fname, out_meta in zip(entry["outputs"], prog["outputs"]):
            arr = np.fromfile(os.path.join(out_dir, fname), "<f4")
            assert arr.size == int(np.prod(out_meta["shape"])), (name, fname)


def test_program_params_cover_model_geometry(built):
    """attn_router must expose exactly the shapes the rust side derives from
    the manifest's model block."""
    _, manifest = built
    m = manifest["model"]
    params = {p["name"]: p["shape"] for p in manifest["programs"]["attn_router"]["params"]}
    B, d, N = m["max_batch"], m["d_model"], m["n_experts"]
    assert params["hidden"] == [B, d]
    assert params["wg"] == [N, d]
    assert params["k_cache"] == [B, m["n_heads"], m["max_seq"], m["head_dim"]]


def test_shared_flag_constant_matches_preset(built):
    out_dir, manifest = built
    entry = manifest["selftests"]["moe_layer"]
    idx = [p["name"] for p in manifest["programs"]["moe_layer"]["params"]].index(
        "shared_flag"
    )
    val = np.fromfile(os.path.join(out_dir, entry["inputs"][idx]), "<f4")
    assert val[0] == (1.0 if manifest["model"]["n_shared"] > 0 else 0.0)
