"""L2: the JAX MoE transformer decode step, built on the L1 Pallas kernels.

The model is split into the four programs the rust coordinator calls per
decode step (see DESIGN.md §2 — the split is what lets XShare's selection
logic sit *between* routing and expert compute, on the rust side):

  embed        tokens[B] i32, emb[V,d]                      -> hidden[B,d]
  attn_router  hidden, attn weights, router weights, caches -> hidden2,
               logits[B,N], probs[B,N], colsum[N], new caches
  moe_ffn      hidden2, refined gates[B,N], expert weights  -> hidden3
  lm_head      hidden[B,d], ln scale, unembed               -> logits[B,V]

plus ``draft_step`` — a complete dense decode step (embed → L_d dense layers
→ logits) for the speculative-decoding draft model.

All weights are runtime parameters (never baked into the HLO) so one compiled
program serves every layer; the rust side keeps them as device-resident
PJRT buffers, uploaded once at startup.

Everything here runs ONLY at build time (`make artifacts`): `aot.py` lowers
each program to HLO text. Python is never on the request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention
from compile.kernels.moe_ffn import moe_ffn
from compile.kernels.router import router_postprocess

_EPS = 1e-6


def rmsnorm(x, scale):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * scale * jax.lax.rsqrt(var + _EPS)


def rope(x, pos, base=10000.0):
    """Rotary position embedding. x: [B, H, hd], pos: [B] i32."""
    B, H, hd = x.shape
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(base) * jnp.arange(half, dtype=jnp.float32) / half
    )  # [half]
    angles = pos.astype(jnp.float32)[:, None] * freqs[None, :]  # [B, half]
    cos = jnp.cos(angles)[:, None, :]  # [B, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _update_cache(cache, new, pos):
    """Write this step's K or V into the padded cache.

    cache: [B, H, S, hd], new: [B, H, hd], pos: [B] i32."""

    def upd(cache_b, new_b, p):
        return jax.lax.dynamic_update_slice(cache_b, new_b[:, None, :], (0, p, 0))

    return jax.vmap(upd)(cache, new, pos)


# ---------------------------------------------------------------------------
# Programs
# ---------------------------------------------------------------------------


def embed(tokens, emb):
    """tokens: [B] i32, emb: [V, d] -> [B, d]."""
    return (jnp.take(emb, tokens, axis=0),)


def attn_router(
    hidden,      # [B, d]  residual stream
    pos,         # [B] i32 current position per row
    active,      # [B] f32 1.0 live / 0.0 padded
    k_cache,     # [B, H, S, hd]
    v_cache,     # [B, H, S, hd]
    ln1,         # [d]
    wq, wk, wv, wo,  # [d, d] each
    ln2,         # [d]
    wg,          # [N, d] router
):
    """Attention half of a layer + router scoring of the post-attn stream.

    Returns (hidden2, logits, probs, colsum, k_cache', v_cache').
    The router sees rmsnorm(hidden2, ln2) — the same normalized input the
    MoE half will use — so gate scores and expert inputs are consistent.
    """
    B, d = hidden.shape
    H = k_cache.shape[1]
    hd = d // H

    x = rmsnorm(hidden, ln1)
    q = (x @ wq).reshape(B, H, hd)
    k = (x @ wk).reshape(B, H, hd)
    v = (x @ wv).reshape(B, H, hd)
    q = rope(q, pos)
    k = rope(k, pos)
    k_cache = _update_cache(k_cache, k, pos)
    v_cache = _update_cache(v_cache, v, pos)
    ctx = decode_attention(q, k_cache, v_cache, pos).reshape(B, d)
    hidden2 = hidden + ctx @ wo

    x2 = rmsnorm(hidden2, ln2)
    logits = x2 @ wg.T                            # [B, N]
    probs, colsum = router_postprocess(logits, active)
    return hidden2, logits, probs, colsum, k_cache, v_cache


def _blend_chunk_cache(cache, new, row, start_pos, chunk_valid):
    """Write a chunk's K or V slab into one row of the padded cache.

    cache: [B, H, S, hd], new: [T, H, hd] (chunk positions as rows),
    row: [1] i32, start_pos: [1] i32, chunk_valid: [T] f32.

    Positions with chunk_valid == 0 keep their previous cache bits exactly
    (a `where`-select, not an arithmetic blend), so a partial final chunk
    cannot disturb cache state beyond the prompt — the byte-identity the
    prefill equivalence suite asserts. The caller guarantees
    start_pos + T <= S (dynamic_slice would clamp, silently shifting the
    window, so the rust runtime refuses chunks near the cache end).
    """
    B, H, S, hd = cache.shape
    T = new.shape[0]
    slab = jax.lax.dynamic_slice(cache, (row[0], 0, 0, 0), (1, H, S, hd))[0]
    old = jax.lax.dynamic_slice(slab, (0, start_pos[0], 0), (H, T, hd))
    mixed = jnp.where(chunk_valid[None, :, None] > 0, jnp.transpose(new, (1, 0, 2)), old)
    slab = jax.lax.dynamic_update_slice(slab, mixed, (0, start_pos[0], 0))
    return jax.lax.dynamic_update_slice(cache, slab[None], (row[0], 0, 0, 0))


def prefill_attn_router(
    hidden,      # [T, d]  chunk token embeddings / residual stream
    start_pos,   # [1] i32 row position before the chunk
    chunk_valid,  # [T] f32 1.0 real chunk token / 0.0 padding
    row,         # [1] i32 batch row the chunk belongs to
    k_cache,     # [B, H, S, hd]
    v_cache,     # [B, H, S, hd]
    ln1,         # [d]
    wq, wk, wv, wo,  # [d, d] each
    ln2,         # [d]
    wg,          # [N, d] router
):
    """Chunked-prefill variant of ``attn_router``: advances ONE batch row by
    up to T prompt tokens in a single invocation instead of T decode-shaped
    steps. T equals ``max_batch`` so the chunk borrows the batch-shaped
    ``embed`` / ``moe_layer`` / ``lm_head`` programs unchanged — only the
    attention/cache half needs its own artifact.

    Chunk position i sits at sequence position start_pos + i and attends
    causally (mask s <= start_pos + i) over the row's updated cache, which
    holds the real prompt history plus this chunk's K/V. The attention is
    the *same* Pallas kernel as decode, fed the row slab broadcast across
    chunk positions, so per-position numerics match the one-token path
    bit for bit. Returns (hidden2, logits, probs, colsum, k_cache',
    v_cache') shaped exactly like ``attn_router`` with T in place of B.
    """
    T, d = hidden.shape
    _, H, S, hd = k_cache.shape
    pos = start_pos[0] + jnp.arange(T, dtype=jnp.int32)  # [T]

    x = rmsnorm(hidden, ln1)
    q = (x @ wq).reshape(T, H, hd)
    k = (x @ wk).reshape(T, H, hd)
    v = (x @ wv).reshape(T, H, hd)
    q = rope(q, pos)
    k = rope(k, pos)
    k_cache = _blend_chunk_cache(k_cache, k, row, start_pos, chunk_valid)
    v_cache = _blend_chunk_cache(v_cache, v, row, start_pos, chunk_valid)

    row_k = jax.lax.dynamic_slice(k_cache, (row[0], 0, 0, 0), (1, H, S, hd))
    row_v = jax.lax.dynamic_slice(v_cache, (row[0], 0, 0, 0), (1, H, S, hd))
    kb = jnp.broadcast_to(row_k, (T, H, S, hd))
    vb = jnp.broadcast_to(row_v, (T, H, S, hd))
    ctx = decode_attention(q, kb, vb, pos).reshape(T, d)
    hidden2 = hidden + ctx @ wo

    x2 = rmsnorm(hidden2, ln2)
    logits = x2 @ wg.T                            # [T, N]
    probs, colsum = router_postprocess(logits, chunk_valid)
    return hidden2, logits, probs, colsum, k_cache, v_cache


def moe_layer(
    hidden2,     # [B, d]  residual stream (post attention)
    gates,       # [B, N]  refined gate weights from the coordinator
    ln2,         # [d]
    w1,          # [N, d, f]
    w2,          # [N, f, d]
    ws1,         # [d, fs]   shared expert up (fs=f; zero-sized presets pass f)
    ws2,         # [fs, d]   shared expert down
    shared_flag,  # [1] f32   1.0 when the preset has a shared expert
):
    """MoE half of a layer: routed experts (Pallas kernel) + optional
    DeepSeek-style shared expert + residual."""
    x2 = rmsnorm(hidden2, ln2)
    y = moe_ffn(x2, gates, w1, w2)
    shared = jax.nn.silu(x2 @ ws1) @ ws2
    y = y + shared_flag * shared
    return (hidden2 + y,)


def lm_head(hidden, lnf, unembed):
    """hidden: [B, d], lnf: [d], unembed: [d, V] -> logits [B, V]."""
    return (rmsnorm(hidden, lnf) @ unembed,)


def draft_step(
    tokens,      # [B] i32
    pos,         # [B] i32
    k_cache,     # [Ld, B, Hd, S, hdd]
    v_cache,     # [Ld, B, Hd, S, hdd]
    emb,         # [V, dd]
    ln1s,        # [Ld, dd]
    wqs, wks, wvs, wos,  # [Ld, dd, dd]
    ln2s,        # [Ld, dd]
    wf1s,        # [Ld, dd, fd]
    wf2s,        # [Ld, fd, dd]
    lnf,         # [dd]
    unembed,     # [dd, V]
):
    """One decode step of the dense draft model (speculative decoding).

    The layer loop is unrolled at trace time (Ld is small); caches are
    stacked per layer so the rust side round-trips two buffers only.
    Returns (logits [B, V], k_cache', v_cache').
    """
    Ld = k_cache.shape[0]
    B = tokens.shape[0]
    Hd = k_cache.shape[2]
    dd = emb.shape[1]
    hdd = dd // Hd

    hidden = jnp.take(emb, tokens, axis=0)
    new_k, new_v = [], []
    for l in range(Ld):
        x = rmsnorm(hidden, ln1s[l])
        q = (x @ wqs[l]).reshape(B, Hd, hdd)
        k = (x @ wks[l]).reshape(B, Hd, hdd)
        v = (x @ wvs[l]).reshape(B, Hd, hdd)
        q = rope(q, pos)
        k = rope(k, pos)
        kc = _update_cache(k_cache[l], k, pos)
        vc = _update_cache(v_cache[l], v, pos)
        ctx = decode_attention(q, kc, vc, pos).reshape(B, dd)
        hidden = hidden + ctx @ wos[l]
        x2 = rmsnorm(hidden, ln2s[l])
        hidden = hidden + jax.nn.silu(x2 @ wf1s[l]) @ wf2s[l]
        new_k.append(kc)
        new_v.append(vc)
    logits = rmsnorm(hidden, lnf) @ unembed
    return logits, jnp.stack(new_k), jnp.stack(new_v)
