"""AOT driver: lower every L2 program to HLO text + dump weights + manifest.

Run once per preset (``make artifacts``); the rust binary is self-contained
afterwards. Interchange is HLO **text**, not ``.serialize()``: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per preset, under ``artifacts/<preset>/``:

  manifest.json        model geometry, program signatures (ordered param
                       names + shapes + dtypes), weight inventory
  <program>.hlo.txt    embed, attn_router, moe_layer, lm_head[, draft_step]
  weights/<name>.bin   raw little-endian f32, row-major

Usage:  python -m compile.aot --preset all --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.configs import PRESETS, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Weight generation (seeded, deterministic per preset)
# ---------------------------------------------------------------------------


def make_weights(cfg: ModelConfig) -> dict:
    """Seeded random weights. Scales chosen so the residual stream stays
    O(1) through n_layers and router logits have std ~2-3 (peaked-but-not-
    degenerate softmax, matching the gating-score profiles of trained MoEs).
    """
    key = jax.random.PRNGKey(cfg.seed)

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    d, f, N, V = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.vocab
    w = {}
    w["emb"] = jax.random.normal(nxt(), (V, d)) * 1.0
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        w[p + "ln1"] = jnp.ones((d,))
        w[p + "ln2"] = jnp.ones((d,))
        for name in ("wq", "wk", "wv"):
            w[p + name] = jax.random.normal(nxt(), (d, d)) * (d ** -0.5)
        w[p + "wo"] = jax.random.normal(nxt(), (d, d)) * 0.5 * (d ** -0.5)
        w[p + "wg"] = jax.random.normal(nxt(), (N, d)) * (2.5 * d ** -0.5)
        w[p + "w1"] = jax.random.normal(nxt(), (N, d, f)) * (d ** -0.5)
        w[p + "w2"] = jax.random.normal(nxt(), (N, f, d)) * 0.5 * (f ** -0.5)
        if cfg.n_shared > 0:
            w[p + "ws1"] = jax.random.normal(nxt(), (d, f)) * (d ** -0.5)
            w[p + "ws2"] = jax.random.normal(nxt(), (f, d)) * 0.5 * (f ** -0.5)
        else:
            w[p + "ws1"] = jnp.zeros((d, f))
            w[p + "ws2"] = jnp.zeros((f, d))
    w["lnf"] = jnp.ones((d,))
    w["unembed"] = jax.random.normal(nxt(), (d, V)) * (d ** -0.5)

    if cfg.draft_layers > 0:
        Ld, dd, fd = cfg.draft_layers, cfg.draft_d_model, cfg.draft_d_ff
        w["draft.emb"] = jax.random.normal(nxt(), (V, dd)) * 1.0
        w["draft.ln1s"] = jnp.ones((Ld, dd))
        w["draft.ln2s"] = jnp.ones((Ld, dd))
        for name in ("wqs", "wks", "wvs"):
            w["draft." + name] = jax.random.normal(nxt(), (Ld, dd, dd)) * (dd ** -0.5)
        w["draft.wos"] = jax.random.normal(nxt(), (Ld, dd, dd)) * 0.5 * (dd ** -0.5)
        w["draft.wf1s"] = jax.random.normal(nxt(), (Ld, dd, fd)) * (dd ** -0.5)
        w["draft.wf2s"] = jax.random.normal(nxt(), (Ld, fd, dd)) * 0.5 * (fd ** -0.5)
        w["draft.lnf"] = jnp.ones((dd,))
        w["draft.unembed"] = jax.random.normal(nxt(), (dd, V)) * (dd ** -0.5)
    return {k: np.asarray(v, np.float32) for k, v in w.items()}


# ---------------------------------------------------------------------------
# Program signatures
# ---------------------------------------------------------------------------


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def program_signatures(cfg: ModelConfig) -> dict:
    """Ordered (name, shape, dtype) per program. The manifest serializes this
    so the rust runtime feeds buffers in exactly this order."""
    B, d, N, f = cfg.max_batch, cfg.d_model, cfg.n_experts, cfg.d_ff
    H, S, hd, V = cfg.n_heads, cfg.max_seq, cfg.head_dim, cfg.vocab
    sigs = {
        "embed": {
            "fn": M.embed,
            "params": [
                ("tokens", (B,), "i32"),
                ("emb", (V, d), "f32"),
            ],
            "outputs": [("hidden", (B, d), "f32")],
        },
        "attn_router": {
            "fn": M.attn_router,
            "params": [
                ("hidden", (B, d), "f32"),
                ("pos", (B,), "i32"),
                ("active", (B,), "f32"),
                ("k_cache", (B, H, S, hd), "f32"),
                ("v_cache", (B, H, S, hd), "f32"),
                ("ln1", (d,), "f32"),
                ("wq", (d, d), "f32"),
                ("wk", (d, d), "f32"),
                ("wv", (d, d), "f32"),
                ("wo", (d, d), "f32"),
                ("ln2", (d,), "f32"),
                ("wg", (N, d), "f32"),
            ],
            "outputs": [
                ("hidden2", (B, d), "f32"),
                ("logits", (B, N), "f32"),
                ("probs", (B, N), "f32"),
                ("colsum", (N,), "f32"),
                ("k_cache", (B, H, S, hd), "f32"),
                ("v_cache", (B, H, S, hd), "f32"),
            ],
        },
        # Chunked prefill: T = max_batch chunk positions of ONE row per
        # invocation, so hidden/logits tensors are batch-shaped and the
        # embed/moe_layer/lm_head programs serve both phases unchanged.
        "prefill_attn_router": {
            "fn": M.prefill_attn_router,
            "params": [
                ("hidden", (B, d), "f32"),
                ("start_pos", (1,), "i32"),
                ("chunk_valid", (B,), "f32"),
                ("row", (1,), "i32"),
                ("k_cache", (B, H, S, hd), "f32"),
                ("v_cache", (B, H, S, hd), "f32"),
                ("ln1", (d,), "f32"),
                ("wq", (d, d), "f32"),
                ("wk", (d, d), "f32"),
                ("wv", (d, d), "f32"),
                ("wo", (d, d), "f32"),
                ("ln2", (d,), "f32"),
                ("wg", (N, d), "f32"),
            ],
            "outputs": [
                ("hidden2", (B, d), "f32"),
                ("logits", (B, N), "f32"),
                ("probs", (B, N), "f32"),
                ("colsum", (N,), "f32"),
                ("k_cache", (B, H, S, hd), "f32"),
                ("v_cache", (B, H, S, hd), "f32"),
            ],
        },
        "moe_layer": {
            "fn": M.moe_layer,
            "params": [
                ("hidden2", (B, d), "f32"),
                ("gates", (B, N), "f32"),
                ("ln2", (d,), "f32"),
                ("w1", (N, d, f), "f32"),
                ("w2", (N, f, d), "f32"),
                ("ws1", (d, f), "f32"),
                ("ws2", (f, d), "f32"),
                ("shared_flag", (1,), "f32"),
            ],
            "outputs": [("hidden3", (B, d), "f32")],
        },
        "lm_head": {
            "fn": M.lm_head,
            "params": [
                ("hidden", (B, d), "f32"),
                ("lnf", (d,), "f32"),
                ("unembed", (d, V), "f32"),
            ],
            "outputs": [("logits", (B, V), "f32")],
        },
    }
    if cfg.draft_layers > 0:
        Ld, dd, fd = cfg.draft_layers, cfg.draft_d_model, cfg.draft_d_ff
        Hd, hdd = cfg.draft_n_heads, cfg.draft_head_dim
        sigs["draft_step"] = {
            "fn": M.draft_step,
            "params": [
                ("tokens", (B,), "i32"),
                ("pos", (B,), "i32"),
                ("k_cache", (Ld, B, Hd, S, hdd), "f32"),
                ("v_cache", (Ld, B, Hd, S, hdd), "f32"),
                ("emb", (V, dd), "f32"),
                ("ln1s", (Ld, dd), "f32"),
                ("wqs", (Ld, dd, dd), "f32"),
                ("wks", (Ld, dd, dd), "f32"),
                ("wvs", (Ld, dd, dd), "f32"),
                ("wos", (Ld, dd, dd), "f32"),
                ("ln2s", (Ld, dd), "f32"),
                ("wf1s", (Ld, dd, fd), "f32"),
                ("wf2s", (Ld, fd, dd), "f32"),
                ("lnf", (dd,), "f32"),
                ("unembed", (dd, V), "f32"),
            ],
            "outputs": [
                ("logits", (B, V), "f32"),
                ("k_cache", (Ld, B, Hd, S, hdd), "f32"),
                ("v_cache", (Ld, B, Hd, S, hdd), "f32"),
            ],
        }
    return sigs


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def lower_program(sig) -> str:
    specs = [_spec(shape, _DTYPES[dt]) for _, shape, dt in sig["params"]]
    lowered = jax.jit(sig["fn"]).lower(*specs)
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def make_selftest_inputs(cfg: ModelConfig, sig, rng: np.random.RandomState):
    """Seeded runtime inputs for a program's selftest vector."""
    vals = []
    for name, shape, dt in sig["params"]:
        if dt == "i32":
            if name == "tokens":
                hi = cfg.vocab
            elif name == "start_pos":
                # the chunk window [start, start + max_batch) must fit the
                # cache (dynamic_slice clamps instead of erroring)
                hi = max(cfg.max_seq - cfg.max_batch + 1, 1)
            elif name == "row":
                hi = cfg.max_batch
            else:
                hi = max(cfg.max_seq - 1, 1)
            vals.append(rng.randint(0, hi, size=shape).astype(np.int32))
        elif name == "shared_flag":
            vals.append(np.asarray([float(cfg.n_shared > 0)], np.float32))
        elif name in ("active", "chunk_valid"):
            v = np.ones(shape, np.float32)
            v[shape[0] // 2 :] = 0.0
            vals.append(v)
        else:
            vals.append(rng.standard_normal(shape).astype(np.float32) * 0.5)
    return vals


def write_selftests(cfg: ModelConfig, sigs, out_dir: str) -> dict:
    """Run every program in python on seeded inputs; dump inputs and outputs
    as raw .bin. The rust integration suite replays these through the PJRT
    runtime and asserts allclose — the cross-language numerics anchor."""
    st_dir = os.path.join(out_dir, "selftest")
    os.makedirs(st_dir, exist_ok=True)
    rng = np.random.RandomState(cfg.seed + 99)
    meta = {}
    for name, sig in sigs.items():
        inputs = make_selftest_inputs(cfg, sig, rng)
        outputs = jax.jit(sig["fn"])(*[jnp.asarray(v) for v in inputs])
        if not isinstance(outputs, (tuple, list)):
            outputs = (outputs,)
        entry = {"inputs": [], "outputs": []}
        for i, v in enumerate(inputs):
            fname = os.path.join("selftest", f"{name}.in{i}.bin")
            np.asarray(v).tofile(os.path.join(out_dir, fname))
            entry["inputs"].append(fname)
        for i, v in enumerate(outputs):
            fname = os.path.join("selftest", f"{name}.out{i}.bin")
            np.asarray(v, np.float32).tofile(os.path.join(out_dir, fname))
            entry["outputs"].append(fname)
        meta[name] = entry
    return meta


def build_preset(cfg: ModelConfig, out_root: str, skip_weights=False) -> dict:
    out_dir = os.path.join(out_root, cfg.name)
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)

    sigs = program_signatures(cfg)
    programs = {}
    for name, sig in sigs.items():
        text = lower_program(sig)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        programs[name] = {
            "file": fname,
            "params": [
                {"name": n, "shape": list(s), "dtype": dt}
                for n, s, dt in sig["params"]
            ],
            "outputs": [
                {"name": n, "shape": list(s), "dtype": dt}
                for n, s, dt in sig["outputs"]
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  [{cfg.name}] {name}: {len(text)} chars")

    weights_meta = []
    if not skip_weights:
        weights = make_weights(cfg)
        for name, arr in sorted(weights.items()):
            fname = os.path.join("weights", name + ".bin")
            arr.astype("<f4").tofile(os.path.join(out_dir, fname))
            weights_meta.append(
                {"name": name, "shape": list(arr.shape), "file": fname, "dtype": "f32"}
            )

    selftests = write_selftests(cfg, sigs, out_dir)

    manifest = {
        "format_version": 1,
        "model": cfg.to_dict(),
        "programs": programs,
        "weights": weights_meta,
        "selftests": selftests,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="all", help="preset name or 'all'")
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    names = list(PRESETS) if args.preset == "all" else [args.preset]
    for name in names:
        print(f"building preset {name}")
        build_preset(PRESETS[name], args.out_dir)
    print("artifacts done")


if __name__ == "__main__":
    main()
