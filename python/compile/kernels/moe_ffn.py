"""L1 Pallas kernel: masked grouped expert FFN — the MoE decode hot spot.

This is the compute the paper optimizes around: for every activated expert j,
stream its weights (w1[j], w2[j]) from HBM once per batch, apply it to every
token that routed to it, and accumulate ``gates[:, j] * FFN_j(x)`` into the
output. XShare's contribution is to make ``|{j : gates[:, j] != 0}|`` small;
the kernel's job is to make each surviving expert's pass efficient.

Hardware adaptation (DESIGN.md §3): the paper's vLLM/H100 implementation
tiles tokens across threadblocks with expert weights in shared memory. On
TPU the analogue is an **expert-major grid**: grid=(N,), each step holds one
expert's (d×f + f×d) weights in VMEM (BlockSpec blocks below) and the whole
token tile. For the mini presets a block is

    gptoss-mini: x[32,64] + w1[64,128] + w2[128,64] + out[32,64] ≈ 82 KiB

far under the ~16 MiB VMEM budget; the schedule streams each expert's
weights HBM→VMEM exactly once per layer call — the same "load each activated
expert once" property the paper's memory model assumes. On a real TPU the
per-expert step would be predicated off for masked experts (scalar-prefetch
of the expert mask); under interpret=True every step executes and masked
experts contribute exactly zero (gates column is zero), so numerics are
identical and the IO saving is accounted by the rust `memsim` layer.

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_ffn_kernel(x_ref, g_ref, w1_ref, w2_ref, o_ref):
    """One grid step = one block of EB experts:
    o += Σ_{e∈block} g[:, e] · silu(x @ w1[e]) @ w2[e].

    Perf note (EXPERIMENTS.md §Perf, L1 iterations 1-5): the first version
    used EB=1 (one expert per step); 128 serial grid steps of tiny matmuls
    left the CPU backend at ~0.7 GFLOP/s (185 ms/call on gptoss-mini).
    Blocking EB experts per step turns the inner work into batched
    [EB×T×f] einsums: 26.8 ms (EB=8) → 15.8 (16) → 9.8 (32) → 7.1 (64) →
    4.0 (128). EB=64 is the shipped default: its 4 MiB weight block still
    double-buffers inside a 16 MiB TPU VMEM (the HBM→VMEM streaming
    schedule the paper's memory model needs), while EB=128 would hold the
    whole expert bank resident and abandon streaming."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]            # [T, d]    (same block every step)
    w1 = w1_ref[...]          # [EB, d, f] (expert-major block)
    w2 = w2_ref[...]          # [EB, f, d]
    g = g_ref[...]            # [T, EB]   this block's gate columns

    h = jax.nn.silu(jnp.einsum("td,edf->etf", x, w1))
    y = jnp.einsum("etf,efd->etd", h, w2)
    o_ref[...] += jnp.einsum("te,etd->td", g, y)


def expert_block(n_experts: int, max_block: int = 64) -> int:
    """Largest divisor of N not exceeding max_block (grid must tile N)."""
    for eb in range(min(max_block, n_experts), 0, -1):
        if n_experts % eb == 0:
            return eb
    return 1


@functools.partial(jax.jit, static_argnames=())
def moe_ffn(x, gates, w1, w2):
    """Pallas grouped expert FFN. Shapes as in ``ref.moe_ffn_ref``."""
    T, d = x.shape
    N = w1.shape[0]
    f = w1.shape[2]
    eb = expert_block(N)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=(N // eb,),
        in_specs=[
            pl.BlockSpec((T, d), lambda j: (0, 0)),        # x: whole tile
            pl.BlockSpec((T, eb), lambda j: (0, j)),       # gate columns
            pl.BlockSpec((eb, d, f), lambda j: (j, 0, 0)),  # w1 block
            pl.BlockSpec((eb, f, d), lambda j: (j, 0, 0)),  # w2 block
        ],
        out_specs=pl.BlockSpec((T, d), lambda j: (0, 0)),  # accumulate in place
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=True,
    )(x, gates, w1, w2)


def vmem_bytes(T, d, f, eb=8, dtype_bytes=4):
    """Static VMEM footprint of one grid step (perf-model helper; see
    DESIGN.md §8 and EXPERIMENTS.md §Perf)."""
    x = T * d
    g = T * eb
    w = eb * (d * f + f * d)
    o = T * d
    return (x + g + w + o) * dtype_bytes
