"""L1 Pallas kernel: router post-processing (softmax + batch utility).

Computes, in one VMEM-resident pass over the router logits:

  * ``probs``  — full-N softmax per token: the gate-score matrix G^{(l)}
    every XShare selection algorithm consumes, and
  * ``colsum`` — the batch utility vector  u_j = Σ_i active_i · probs[i, j],
    which is exactly the modular marginal gain f_l({e_j}) of
    Proposition 3.2. Shipping it pre-reduced from the accelerator lets the
    rust coordinator run Algorithm 1 (sort by u_j) without touching the
    [T×N] matrix in the common no-warm-up path.

``active`` masks padded batch rows (the compiled programs have a fixed B_max;
the coordinator pads short batches) so padding never leaks into selection.

Sized for VMEM: [T, N] at the largest preset is 32×256 f32 = 32 KiB.
interpret=True for the CPU PJRT plugin (see moe_ffn.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(logits_ref, active_ref, probs_ref, colsum_ref):
    logits = logits_ref[...]                       # [T, N]
    active = active_ref[...]                       # [T, 1]
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    probs_ref[...] = probs
    colsum_ref[...] = jnp.sum(probs * active, axis=0, keepdims=True)  # [1, N]


def router_postprocess(logits, active):
    """Pallas router post-processing.

    Args:
      logits: [T, N] raw router logits.
      active: [T]    1.0 live rows / 0.0 padding.
    Returns:
      (probs [T, N], colsum [N]) — see ``ref.router_ref``.
    """
    T, N = logits.shape
    probs, colsum = pl.pallas_call(
        _router_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((T, N), logits.dtype),
            jax.ShapeDtypeStruct((1, N), logits.dtype),
        ),
        interpret=True,
    )(logits, active[:, None])
    return probs, colsum[0]
