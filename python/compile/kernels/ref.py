"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts each kernel matches its
oracle with ``assert_allclose``. The oracles are deliberately written in the
most direct dense form (no tiling, no masking tricks) so a reviewer can check
them against the paper's equations by eye.
"""

import jax
import jax.numpy as jnp


def moe_ffn_ref(x, gates, w1, w2):
    """Dense reference for the grouped expert FFN.

    y_i = sum_j gates[i, j] * FFN_j(x_i),  FFN_j(x) = silu(x @ w1[j]) @ w2[j]

    Args:
      x:     [T, d]     token hidden states (post-norm).
      gates: [T, N]     refined gate weights; zero outside each token's
                        top-k-within-S (the coordinator guarantees this).
      w1:    [N, d, f]  per-expert up-projection.
      w2:    [N, f, d]  per-expert down-projection.
    Returns:
      [T, d] mixture output (no residual).
    """
    # h[n, t, f] = silu(x @ w1[n])
    h = jax.nn.silu(jnp.einsum("td,ndf->ntf", x, w1))
    # y[n, t, d] = h @ w2[n]
    y = jnp.einsum("ntf,nfd->ntd", h, w2)
    # weight by gates and sum over experts
    return jnp.einsum("tn,ntd->td", gates, y)


def router_ref(logits, active):
    """Reference for the router post-processing kernel.

    Args:
      logits: [T, N] raw router logits (h = W_g x).
      active: [T]    1.0 for live batch rows, 0.0 for padding.
    Returns:
      probs:  [T, N] full-N softmax of the logits (the paper's gate-score
                     matrix G used by every selection algorithm).
      colsum: [N]    batch utility sum_i active_i * probs[i, :] — the modular
                     proxy objective f_l({e}) from Proposition 3.2.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    colsum = jnp.sum(probs * active[:, None], axis=0)
    return probs, colsum


def decode_attention_ref(q, k_cache, v_cache, pos):
    """Reference single-token decode attention over a padded KV cache.

    Args:
      q:       [B, H, hd]    this step's query.
      k_cache: [B, H, S, hd] keys, already containing this step at pos[b].
      v_cache: [B, H, S, hd] values, same.
      pos:     [B] i32       index of the current token per row.
    Returns:
      [B, H, hd] context vectors.
    """
    hd = q.shape[-1]
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / jnp.sqrt(
        jnp.asarray(hd, q.dtype)
    )
    s_idx = jnp.arange(k_cache.shape[2])
    mask = s_idx[None, None, :] <= pos[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", attn, v_cache)


def rmsnorm_ref(x, scale, eps=1e-6):
    """y = x * scale / sqrt(mean(x^2) + eps)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * scale * jax.lax.rsqrt(var + eps)


def topk_mask_ref(scores, k):
    """[T, N] -> boolean mask of each row's top-k entries (ties broken by
    lower index first, matching the rust implementation)."""
    order = jnp.argsort(-scores, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    return ranks < k
