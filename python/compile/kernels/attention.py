"""L1 Pallas kernel: single-token decode attention over a padded KV cache.

One grid step per attention head: the head's K/V cache slab ([B, S, hd]) is
VMEM-resident while its B queries attend over it. Padding beyond each row's
current position ``pos[b]`` is masked to -1e30 before the softmax.

VMEM per step at the largest preset (gptoss-mini, B=32, S=160, hd=16):
K+V slabs 2×32×160×16×4B = 640 KiB plus [B, S] scores — comfortably inside
the ~16 MiB budget (see DESIGN.md §8). interpret=True as everywhere.

``pos`` arrives as f32 (compare-only use) because mixed-dtype scalar blocks
complicate BlockSpecs under interpret mode; the model layer casts.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
    q = q_ref[:, 0, :]            # [B, hd]
    k = k_ref[:, 0, :, :]         # [B, S, hd]
    v = v_ref[:, 0, :, :]         # [B, S, hd]
    pos = pos_ref[...]            # [B, 1] f32
    hd = q.shape[-1]
    scale = jax.lax.rsqrt(jnp.asarray(hd, q.dtype))
    scores = jnp.einsum("bd,bsd->bs", q, k) * scale          # [B, S]
    s_idx = jax.lax.broadcasted_iota(jnp.float32, scores.shape, 1)
    scores = jnp.where(s_idx <= pos, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    attn = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[:, 0, :] = jnp.einsum("bs,bsd->bd", attn, v)


def decode_attention(q, k_cache, v_cache, pos):
    """Pallas decode attention. Shapes as in ``ref.decode_attention_ref``
    (``pos`` is i32 [B]; cast internally)."""
    B, H, hd = q.shape
    S = k_cache.shape[2]
    posf = pos.astype(jnp.float32)
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((B, 1, hd), lambda h: (0, h, 0)),
            pl.BlockSpec((B, 1, S, hd), lambda h: (0, h, 0, 0)),
            pl.BlockSpec((B, 1, S, hd), lambda h: (0, h, 0, 0)),
            pl.BlockSpec((B, 1), lambda h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, 1, hd), lambda h: (0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=True,
    )(q, k_cache, v_cache, posf[:, None])
