"""Model presets shared between the AOT pipeline and the rust coordinator.

The rust side never imports this module: `aot.py` serializes everything it
needs into ``artifacts/<preset>/manifest.json``.

Presets mirror the geometry of the paper's evaluation models:

* ``gptoss-mini``  — GPT-OSS-120B geometry (N=128 routed experts, top-4,
  no shared expert) scaled to laptop size.
* ``dsr1-mini``    — DeepSeek-R1 geometry (N=256 routed experts, top-8,
  one shared expert) scaled down; used for the expert-parallel (Table 2)
  experiments.
* ``tiny``         — a minimal preset for fast unit tests of the whole
  AOT → rust round trip.

The selection algorithms only consume gate-score *distributions*, so keeping
(N, k, shared-expert) geometry identical to the paper's models preserves the
batch-activation and selection behaviour (DESIGN.md §4).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    # transformer geometry
    d_model: int
    n_heads: int
    d_ff: int            # per-expert FFN hidden size
    n_layers: int
    vocab: int
    max_seq: int         # KV-cache capacity S
    # MoE geometry
    n_experts: int       # N routed experts
    top_k: int           # k
    n_shared: int        # shared experts (DeepSeek-style), 0 or 1
    # serving geometry
    max_batch: int       # B_max baked into the compiled programs
    # draft model (dense) for speculative decoding; 0 layers = no draft
    draft_layers: int = 0
    draft_d_model: int = 0
    draft_n_heads: int = 0
    draft_d_ff: int = 0
    seed: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def draft_head_dim(self) -> int:
        if self.draft_layers == 0:
            return 0
        assert self.draft_d_model % self.draft_n_heads == 0
        return self.draft_d_model // self.draft_n_heads

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["draft_head_dim"] = self.draft_head_dim
        return d


GPTOSS_MINI = ModelConfig(
    name="gptoss-mini",
    d_model=64,
    n_heads=4,
    d_ff=128,
    n_layers=4,
    vocab=512,
    max_seq=160,
    n_experts=128,
    top_k=4,
    n_shared=0,
    max_batch=16,
    draft_layers=2,
    draft_d_model=32,
    draft_n_heads=2,
    draft_d_ff=64,
    seed=1234,
)

DSR1_MINI = ModelConfig(
    name="dsr1-mini",
    d_model=32,
    n_heads=2,
    d_ff=64,
    n_layers=2,
    vocab=256,
    max_seq=96,
    n_experts=256,
    top_k=8,
    n_shared=1,
    max_batch=16,
    draft_layers=0,
    seed=4321,
)

TINY = ModelConfig(
    name="tiny",
    d_model=16,
    n_heads=2,
    d_ff=32,
    n_layers=2,
    vocab=64,
    max_seq=32,
    n_experts=8,
    top_k=2,
    n_shared=1,
    max_batch=4,
    draft_layers=1,
    draft_d_model=16,
    draft_n_heads=2,
    draft_d_ff=32,
    seed=7,
)

PRESETS = {c.name: c for c in (GPTOSS_MINI, DSR1_MINI, TINY)}
