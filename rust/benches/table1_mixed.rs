//! **Table 1 + Figure 6**: heterogeneous-batch speculative decoding — one
//! request from each of GPQA, AIME2025, MMLU-Pro and AA-LCR in a single
//! BS=4, L_s=3 batch (§6.3).
//!
//! Paper shape targets: hierarchical configs with k0≥1 ((1,0,4), (1,0,5),
//! (2,0,4)) keep double-digit ΔOTPS at ≈baseline fidelity even though the
//! batch is domain-diverse; the warm-up-less (0,4,16)-style config loses
//! badly on at least one dataset.

#[path = "common/mod.rs"]
mod common;

use common::{load_model, mixed_requests, pct, sweep, Table};
use xshare::config::ServeConfig;

fn main() {
    println!("# Table 1 / Figure 6 — mixed-dataset speculative batch (BS=4, L_s=3)");
    let mut model = load_model("gptoss-mini");
    let vocab = model.dims().vocab;
    let cfg = ServeConfig {
        preset: "gptoss-mini".into(),
        batch_size: 4,
        spec_len: 3,
        max_new_tokens: 8,
        ..Default::default()
    };
    let policies = [
        "vanilla",
        "spec:0:4:16",
        "spec:1:0:4",
        "spec:1:0:5",
        "spec:2:0:4",
        "spec:1:24:0",
        "spec:1:32:0",
        "spec:2:10:0",
        "spec:0:0:8",
    ];

    // Several mixed batches for stability (each = 1 request per dataset).
    let mut table = Table::new(&[
        "config (k0,m,mr)",
        "OTPS",
        "ΔOTPS",
        "activated/layer",
        "fidelity",
        "per-domain fidelity (gpqa/aime/mmlu/lcr)",
    ]);
    let batches: Vec<Vec<xshare::coordinator::Request>> =
        (0..3).map(|i| mixed_requests(vocab, 10, 8, 100 + i)).collect();

    // Baseline first, per batch; aggregate across batches per policy.
    let mut base_otps = 0.0;
    for (pi, &policy) in policies.iter().enumerate() {
        let mut otps_sum = 0.0;
        let mut act_sum = 0.0;
        let mut fid_sum = 0.0;
        let mut domain_fid: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
        let mut base_outputs = Vec::new();
        for (bi, reqs) in batches.iter().enumerate() {
            let results = sweep(&mut model, &cfg, &["vanilla", policy], reqs);
            let base = &results[0];
            let cand = if pi == 0 { &results[0] } else { &results[1] };
            if pi == 0 {
                base_outputs.push(base.report.outputs.clone());
            }
            otps_sum += cand.report.metrics.otps();
            act_sum += cand.report.metrics.mean_activated();
            let fid = cand.fidelity.as_ref().map(|f| f.token_match).unwrap_or(1.0);
            fid_sum += fid;
            // per-domain fidelity
            for (id, dom) in &cand.report.domains {
                let b = &base.report.outputs[id];
                let c = &cand.report.outputs[id];
                let len = b.len().max(c.len()).max(1);
                let matches =
                    (0..len).filter(|&i| b.get(i).is_some() && b.get(i) == c.get(i)).count();
                let e = domain_fid.entry(dom.clone()).or_insert((0.0, 0));
                e.0 += matches as f64 / len as f64;
                e.1 += 1;
            }
            let _ = bi;
        }
        let nb = batches.len() as f64;
        if pi == 0 {
            base_otps = otps_sum / nb;
        }
        let dom_str = ["gpqa", "aime2025", "mmlu-pro", "aa-lcr"]
            .iter()
            .map(|d| {
                domain_fid
                    .get(*d)
                    .map(|(s, n)| format!("{:.0}%", 100.0 * s / *n as f64))
                    .unwrap_or_else(|| "-".into())
            })
            .collect::<Vec<_>>()
            .join("/");
        table.row(&[
            policy.to_string(),
            format!("{:.1}", otps_sum / nb),
            format!("{:+.1}%", pct(otps_sum / nb, base_otps)),
            format!("{:.1}", act_sum / nb),
            format!("{:.1}%", 100.0 * fid_sum / nb),
            dom_str,
        ]);
    }
    table.print("mixed batch (mean over 3 batches)");
    common::save_report("table1_mixed.csv", &table.to_csv());
    println!("\npaper shape: k0≥1 hierarchical configs keep ΔOTPS>0 at ≈100% fidelity");
    println!("across all four domains; warm-up-less config drops fidelity hardest.");
}
