//! **Figure 5 + Table 4**: speculative decoding (BS=4, L_s=3) — Algorithm 4
//! configurations (k0, m, m_r) against the vanilla speculative baseline and
//! Algorithm 2 on the same effective batch.
//!
//! Paper shape targets: (1,0,4) and (1,0,5) Pareto-optimal (+13-14% /
//! +8-10% OTPS at ≈baseline accuracy); k0=0 configs (0,16,4) suffer severe
//! accuracy loss; Algorithm 4 beats Algorithm 2 ((1,24,0)-style batch-only
//! budgets) under speculation.

#[path = "common/mod.rs"]
mod common;

use common::{domain_requests, load_model, pct, sweep, Table};
use xshare::config::ServeConfig;

fn main() {
    println!("# Figure 5 / Table 4 — speculative decoding (BS=4, L_s=3)");
    let mut model = load_model("gptoss-mini");
    let vocab = model.dims().vocab;
    let cfg = ServeConfig {
        preset: "gptoss-mini".into(),
        batch_size: 4,
        spec_len: 3,
        max_new_tokens: 8,
        ..Default::default()
    };
    // (k0, m, m_r) grid of the paper; policy syntax spec:<k0>:<m>:<mr>.
    // (k0, m, 0) rows are Algorithm 2 run on the effective batch.
    let policies = [
        "vanilla",
        "spec:0:16:4",
        "spec:1:0:4",
        "spec:1:0:5",
        "spec:2:0:4",
        "spec:1:24:0",
        "spec:1:32:0",
        "spec:2:10:0",
        "spec:0:0:8",
    ];

    for domain in ["aime2025", "gpqa", "aa-lcr"] {
        let reqs = domain_requests(domain, vocab, 4, 10, 8, 33);
        let results = sweep(&mut model, &cfg, &policies, &reqs);
        let base_otps = results[0].report.metrics.otps();
        let mut table = Table::new(&[
            "config (k0,m,mr)",
            "OTPS",
            "ΔOTPS",
            "activated/layer",
            "fidelity",
            "Δfid pts",
        ]);
        for r in &results {
            let m = &r.report.metrics;
            let (fid, drop) = match &r.fidelity {
                None => (1.0, 0.0),
                Some(f) => (f.token_match, f.accuracy_drop_pts()),
            };
            table.row(&[
                r.policy.clone(),
                format!("{:.1}", m.otps()),
                format!("{:+.1}%", pct(m.otps(), base_otps)),
                format!("{:.1}", m.mean_activated()),
                format!("{:.1}%", fid * 100.0),
                format!("{drop:+.1}"),
            ]);
        }
        table.print(&format!("domain {domain}"));
        common::save_report(&format!("fig5_{domain}.csv"), &table.to_csv());
    }
    println!("\npaper shape: (1,0,4)/(1,0,5) Pareto-optimal; k0=0 configs crater");
    println!("fidelity; per-request budgets beat batch-only budgets under spec.");
}
