//! **Table 2**: expert parallelism on DeepSeek-R1 geometry (dsr1-mini:
//! N=256, top-8, 1 shared expert) over G=8 GPU groups — vanilla routing vs
//! Algorithm 6 (k0=1, m_g=5), at batch sizes 8 and 16.
//!
//! Paper shape targets: ≈70% drop in activated experts at BS=16 and ≈3×
//! lower peak per-GPU load (25.6 → 8.6 in the paper), with fidelity close
//! to baseline.

#[path = "common/mod.rs"]
mod common;

use common::{domain_requests, load_model, sweep, Table};
use xshare::config::{EpConfig, ServeConfig};
use xshare::ep::PlacementKind;

fn main() {
    println!("# Table 2 — expert parallelism (dsr1-mini, G=8)");
    let mut model = load_model("dsr1-mini");
    let vocab = model.dims().vocab;

    let mut table = Table::new(&[
        "setting",
        "method",
        "fidelity",
        "# experts (mean/layer)",
        "max/GPU",
        "sim-otps",
    ]);

    // Paper rows: GSM-8K @ BS=8 and IFEval @ BS=16. GSM-8K maps to the
    // math-flavoured aime2025 domain; the BS=16 row mixes domains the way
    // the paper's production batches do (higher token diversity → higher
    // baseline activation, the regime Table 2 reports).
    for (label, domain, bs) in
        [("GSM-8K-like (BS=8)", "aime2025", 8usize), ("IFEval-like (BS=16)", "mixed", 16)]
    {
        let cfg = ServeConfig {
            preset: "dsr1-mini".into(),
            batch_size: bs,
            max_new_tokens: 8,
            ep: Some(EpConfig { n_gpus: 8, placement: PlacementKind::Contiguous }),
            ..Default::default()
        };
        let reqs = if domain == "mixed" {
            use xshare::gen::{TraceDomain, TraceGenerator};
            TraceGenerator::new(vocab, 55)
                .generate(&TraceDomain::standard_suite(), bs)
                .into_iter()
                .map(|t| {
                    let mut prompt = t.prompt;
                    prompt.truncate(8);
                    let mut r = xshare::coordinator::Request::new(t.id, prompt, 8);
                    r.domain = t.domain;
                    r
                })
                .collect()
        } else {
            domain_requests(domain, vocab, bs, 8, 8, 55)
        };
        let results = sweep(&mut model, &cfg, &["vanilla", "gpu:1:5"], &reqs);
        for r in &results {
            let m = &r.report.metrics;
            let fid = r.fidelity.as_ref().map(|f| f.token_match).unwrap_or(1.0);
            table.row(&[
                label.to_string(),
                r.policy.clone(),
                format!("{:.1}%", fid * 100.0),
                format!("{:.1}", m.mean_activated()),
                format!("{:.2}", m.max_gpu_load.mean()),
                format!("{:.1}", m.otps()),
            ]);
        }
        let base = &results[0].report.metrics;
        let ours = &results[1].report.metrics;
        println!(
            "{label}: activated -{:.0}%  max/GPU {:.2} -> {:.2} ({:.1}x)",
            100.0 * (1.0 - ours.mean_activated() / base.mean_activated()),
            base.max_gpu_load.mean(),
            ours.max_gpu_load.mean(),
            base.max_gpu_load.mean() / ours.max_gpu_load.mean().max(1e-9),
        );
    }
    table.print("DS-R1 geometry, accuracy/load (paper Table 2)");
    common::save_report("table2_ep.csv", &table.to_csv());
    println!("\npaper shape: ~73% activated-expert drop at BS=16, ~3x lower max/GPU,");
    println!("fidelity within ~1% of baseline.");
}
