//! **Figure 8**: OTPS vs number of activated experts under speculative
//! decoding (BS=4, L_s=3) — the Figure-5 sweep along the activation axis.
//! Shape target: same memory-bound roofline as Figure 7, shifted by the
//! draft-model overhead; hierarchical (m_r>0) points reach lower activation
//! than batch-budget (m>0) points.

#[path = "common/mod.rs"]
mod common;

use common::{domain_requests, load_model, sweep, Table};
use xshare::config::ServeConfig;

fn main() {
    println!("# Figure 8 — OTPS vs activated experts (BS=4, L_s=3)");
    let mut model = load_model("gptoss-mini");
    let vocab = model.dims().vocab;
    let cfg = ServeConfig {
        preset: "gptoss-mini".into(),
        batch_size: 4,
        spec_len: 3,
        max_new_tokens: 8,
        ..Default::default()
    };
    let policies = [
        "vanilla",
        "spec:0:16:4",
        "spec:1:0:4",
        "spec:1:0:5",
        "spec:2:0:4",
        "spec:1:24:0",
        "spec:1:32:0",
        "spec:2:10:0",
        "spec:0:0:8",
    ];
    let reqs = domain_requests("gpqa", vocab, 4, 10, 8, 88);
    let results = sweep(&mut model, &cfg, &policies, &reqs);

    let mut series: Vec<(f64, f64, String)> = results
        .iter()
        .map(|r| {
            (r.report.metrics.mean_activated(), r.report.metrics.otps(), r.policy.clone())
        })
        .collect();
    series.sort_by(|a, b| a.0.total_cmp(&b.0));

    let mut table = Table::new(&["activated/layer", "OTPS", "config"]);
    for (act, otps, policy) in &series {
        table.row(&[format!("{act:.1}"), format!("{otps:.1}"), policy.clone()]);
    }
    table.print("speculative sweep sorted by activation (gpqa)");
    common::save_report("fig8.csv", &table.to_csv());

    let violations = series.windows(2).filter(|w| w[1].1 > w[0].1 * 1.05).count();
    println!(
        "\nroofline direction under speculation: {violations} violations of {}",
        series.len() - 1
    );
    // hierarchical-vs-batch activation comparison
    let act_of = |name: &str| {
        series.iter().find(|(_, _, p)| p == name).map(|(a, _, _)| *a).unwrap_or(f64::NAN)
    };
    println!(
        "hierarchical spec:1:0:4 activation {:.1} vs batch-budget spec:1:24:0 {:.1}",
        act_of("spec:1:0:4"),
        act_of("spec:1:24:0")
    );
}
