//! **Continuous-batching serving bench**: throughput and latency under
//! Poisson arrivals with staggered request lengths — stepped continuous
//! admission (the live TCP worker's path) vs the old gather-window
//! batch-at-a-time worker — across vanilla routing and XShare Algorithm 2.
//!
//! Both modes are driven on the *simulated* clock (memsim H100 cost model),
//! so results are deterministic and hardware-honest: the batch-at-a-time
//! worker idles freed slots on straggler requests and makes late arrivals
//! wait for the whole batch to drain; the stepped core admits them at the
//! next decode step. Same requests, same arrival process, same policies.
//!
//!   make artifacts && cargo bench --bench serve_continuous

#[path = "common/mod.rs"]
mod common;

use std::collections::{BTreeMap, VecDeque};

use common::{fmt, load_model, pct, save_report, Table};
use xshare::config::{EpConfig, ServeConfig, SpecDraft};
use xshare::coordinator::admission::{
    AdmissionContext, AdmissionKind, AdmissionQueue, FootprintTracker,
};
use xshare::coordinator::{Request, Scheduler, ServeLoop};
use xshare::ep::PlacementKind;
use xshare::gen::{Domain, GatingParams, RequestGating, TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::selection::{softmax_in_place, topk_indices, ExpertSet, PolicyKind};

const PRESET: &str = "gptoss-mini";
const N_REQUESTS: usize = 32;
const BATCH_SIZE: usize = 8;
const SEED: u64 = 17;
/// Arrivals are rescaled so the last request lands at this fraction of the
/// upfront-vanilla busy time: the system stays loaded, but stragglers and
/// late joiners dominate the tail.
const ARRIVAL_WINDOW_FRAC: f64 = 0.7;

// Long-prompt scenario (chunked prefill): prompts dominate the lifetime of
// a request, which is exactly where TTFT dies under one-token prefill.
const LONG_PROMPT_LEN: usize = 48;
const LONG_N_REQUESTS: usize = 12;
const LONG_MAX_NEW: usize = 8;
/// One chunk per step; gptoss-mini's chunk capacity is its max_batch (16).
const PREFILL_CHUNK: usize = 16;

/// Where `--write-bench <dir>` mirrors every BENCH_*.json artifact — the
/// refresh path for the reference snapshots under `benchmarks/`.
static WRITE_BENCH_DIR: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();

/// Emit one BENCH_*.json artifact: the working directory (CI uploads it
/// from there), the shared report sink, and — when `--write-bench` is set —
/// a copy into the snapshot directory.
fn emit_bench(name: &str, json: &str) {
    std::fs::write(name, json).unwrap_or_else(|e| panic!("writing {name}: {e}"));
    save_report(name, json);
    if let Some(dir) = WRITE_BENCH_DIR.get() {
        std::fs::create_dir_all(dir).expect("creating --write-bench dir");
        std::fs::write(dir.join(name), json)
            .unwrap_or_else(|e| panic!("copying {name} into --write-bench dir: {e}"));
    }
}

fn base_cfg(policy: &str) -> ServeConfig {
    ServeConfig {
        preset: PRESET.into(),
        policy: PolicyKind::parse(policy).expect("policy"),
        batch_size: BATCH_SIZE,
        max_new_tokens: 12,
        ..Default::default()
    }
}

/// Poisson arrival trace with heterogeneous ("staggered") request lengths
/// straight from the domain mix: (arrival sim-seconds, request).
fn arrival_trace(vocab: usize) -> Vec<(f64, Request)> {
    let mut g = TraceGenerator::new(vocab, SEED);
    g.arrival_rate = 1.0; // unit-rate; timestamps are rescaled below
    g.generate(&TraceDomain::standard_suite(), N_REQUESTS)
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(6);
            let mut r = Request::new(t.id, prompt, t.max_new_tokens.clamp(2, 12));
            r.domain = t.domain;
            (t.arrival_s, r)
        })
        .collect()
}

struct ModeResult {
    outputs: BTreeMap<u64, Vec<u32>>,
    tokens: u64,
    makespan_s: f64,
    ttft_mean_s: f64,
    queue_wait_mean_s: f64,
    admitted_in_flight: u64,
    spec_stalled_steps: u64,
    spec_accepted: u64,
    spec_acceptance_rate: f64,
    spec_depth_mean: f64,
    tokens_prompt: u64,
    prompt_tps: f64,
    mean_activated: f64,
    prefill_waves: u64,
    prefill_streams_saved: u64,
    rows_per_wave_mean: f64,
}

impl ModeResult {
    fn otps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.makespan_s
        }
    }
}

/// Stepped continuous serving: requests are submitted the moment the sim
/// clock passes their arrival time; every step admits into free slots.
fn serve_continuous(
    model: &mut MoeModel,
    cfg: &ServeConfig,
    arrivals: &[(f64, Request)],
) -> ModeResult {
    serve_continuous_with(model, cfg, arrivals, |_| {})
}

/// As [`serve_continuous`], with a setup hook on the fresh loop (the spec
/// scenario pins the legacy stall gate on for its baseline arm).
fn serve_continuous_with(
    model: &mut MoeModel,
    cfg: &ServeConfig,
    arrivals: &[(f64, Request)],
    setup: impl FnOnce(&mut ServeLoop),
) -> ModeResult {
    let mut core = ServeLoop::new(model, cfg.clone()).expect("serve loop");
    setup(&mut core);
    let mut idle = 0.0f64; // sim-time spent with no work at all
    let mut idx = 0;
    while idx < arrivals.len() || core.has_work() {
        let now = core.metrics().sim_seconds + idle;
        while idx < arrivals.len() && arrivals[idx].0 <= now + 1e-9 {
            core.submit(arrivals[idx].1.clone()).unwrap();
            idx += 1;
        }
        if core.has_work() {
            core.step().expect("step");
        } else {
            // fast-forward an empty system to the next arrival
            idle += arrivals[idx].0 - now;
        }
    }
    let makespan_s = core.metrics().sim_seconds + idle;
    let report = core.report();
    ModeResult {
        tokens: report.metrics.tokens_out,
        makespan_s,
        ttft_mean_s: report.metrics.ttft.mean(),
        queue_wait_mean_s: report.metrics.queue_wait.mean(),
        admitted_in_flight: report.metrics.admitted_in_flight,
        spec_stalled_steps: report.metrics.spec_stalled_steps,
        spec_accepted: report.metrics.spec_accepted,
        spec_acceptance_rate: report.metrics.acceptance_rate(),
        spec_depth_mean: report.metrics.spec_depth.mean(),
        tokens_prompt: report.metrics.tokens_prompt,
        prompt_tps: report.metrics.prompt_tokens_per_s(),
        mean_activated: report.metrics.mean_activated(),
        prefill_waves: report.metrics.prefill_waves,
        prefill_streams_saved: report.metrics.prefill_streams_saved,
        rows_per_wave_mean: report.metrics.prefill_rows_per_wave.mean(),
        outputs: report.outputs,
    }
}

/// The old worker, emulated on the sim clock: gather everything that has
/// arrived (up to batch_size), run the batch to completion, only then look
/// at the queue again.
fn serve_batched(
    model: &mut MoeModel,
    cfg: &ServeConfig,
    arrivals: &[(f64, Request)],
) -> ModeResult {
    let mut clock = 0.0f64;
    let mut idx = 0;
    let mut queue: VecDeque<(f64, Request)> = VecDeque::new();
    let mut outputs = BTreeMap::new();
    let mut tokens = 0u64;
    let mut ttft_sum = 0.0f64;
    let mut wait_sum = 0.0f64;
    let mut n_served = 0usize;
    while idx < arrivals.len() || !queue.is_empty() {
        while idx < arrivals.len() && arrivals[idx].0 <= clock + 1e-9 {
            queue.push_back(arrivals[idx].clone());
            idx += 1;
        }
        if queue.is_empty() {
            clock = arrivals[idx].0;
            continue;
        }
        let take = queue.len().min(cfg.batch_size);
        let batch: Vec<(f64, Request)> = queue.drain(..take).collect();
        let reqs: Vec<Request> = batch.iter().map(|(_, r)| r.clone()).collect();
        let report = Scheduler::new(model, cfg.clone())
            .expect("scheduler")
            .run(reqs)
            .expect("run");
        // Request-level latency = time queued before the batch started +
        // first-token latency inside the batch run.
        for (arr, _) in &batch {
            wait_sum += clock - arr;
        }
        ttft_sum += report.metrics.ttft.sum + batch.iter().map(|(a, _)| clock - a).sum::<f64>();
        n_served += batch.len();
        tokens += report.metrics.tokens_out;
        clock += report.metrics.sim_seconds;
        outputs.extend(report.outputs);
    }
    ModeResult {
        outputs,
        tokens,
        makespan_s: clock,
        ttft_mean_s: if n_served == 0 { 0.0 } else { ttft_sum / n_served as f64 },
        queue_wait_mean_s: if n_served == 0 { 0.0 } else { wait_sum / n_served as f64 },
        admitted_in_flight: 0,
        spec_stalled_steps: 0,
        spec_accepted: 0,
        spec_acceptance_rate: 0.0,
        spec_depth_mean: 0.0,
        tokens_prompt: 0,
        prompt_tps: 0.0,
        mean_activated: 0.0,
        prefill_waves: 0,
        prefill_streams_saved: 0,
        rows_per_wave_mean: 0.0,
    }
}

/// Poisson arrivals with long uniform prompts (prompt-heavy workload).
fn long_prompt_trace(vocab: usize) -> Vec<(f64, Request)> {
    let mut g = TraceGenerator::new(vocab, SEED + 1);
    g.arrival_rate = 1.0;
    g.generate(&TraceDomain::standard_suite(), LONG_N_REQUESTS)
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            while prompt.len() < LONG_PROMPT_LEN {
                let fill = (prompt.len() as u64 * 7 + t.id * 13) % vocab as u64;
                prompt.push(fill as u32);
            }
            prompt.truncate(LONG_PROMPT_LEN);
            let mut r = Request::new(t.id, prompt, LONG_MAX_NEW);
            r.domain = t.domain;
            (t.arrival_s, r)
        })
        .collect()
}

/// Long-prompt TTFT scenario: the stepped loop with chunked prefill vs the
/// same loop walking prompts one token per step. Same Poisson arrivals,
/// same policies; under vanilla the outputs must additionally be
/// byte-identical (chunking is an execution optimisation only).
fn long_prompt_scenario(model: &mut MoeModel) {
    println!(
        "\n# long-prompt TTFT — chunked (T={PREFILL_CHUNK}) vs one-token prefill \
         ({LONG_N_REQUESTS} reqs × {LONG_PROMPT_LEN}-token prompts, {LONG_MAX_NEW} new)"
    );
    let vocab = model.dims().vocab;
    let mut arrivals = long_prompt_trace(vocab);

    // calibrate the window against the unchunked vanilla busy time
    let mut probe_cfg = base_cfg("vanilla");
    probe_cfg.max_new_tokens = LONG_MAX_NEW;
    let probe_reqs: Vec<Request> = arrivals.iter().map(|(_, r)| r.clone()).collect();
    let probe = Scheduler::new(model, probe_cfg)
        .expect("probe scheduler")
        .run(probe_reqs)
        .expect("probe run");
    let busy = probe.metrics.sim_seconds;
    let t_last = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0).max(1e-12);
    let scale = ARRIVAL_WINDOW_FRAC * busy / t_last;
    for (t, _) in arrivals.iter_mut() {
        *t *= scale;
    }

    let mut table = Table::new(&[
        "policy",
        "prefill",
        "tokens",
        "prompt_toks",
        "makespan_s",
        "ttft_mean_s",
        "ttft_delta",
    ]);
    for policy in ["vanilla", "batch:24:1"] {
        let mut unchunked_cfg = base_cfg(policy);
        unchunked_cfg.max_new_tokens = LONG_MAX_NEW;
        let mut chunked_cfg = unchunked_cfg.clone();
        chunked_cfg.prefill_chunk = PREFILL_CHUNK;

        let un = serve_continuous(model, &unchunked_cfg, &arrivals);
        let ch = serve_continuous(model, &chunked_cfg, &arrivals);

        if policy == "vanilla" {
            assert_eq!(
                un.outputs, ch.outputs,
                "chunked prefill changed generated tokens under vanilla routing"
            );
        }
        assert!(
            ch.ttft_mean_s < un.ttft_mean_s,
            "chunked prefill must cut simulated TTFT ({policy}: {} vs {})",
            ch.ttft_mean_s,
            un.ttft_mean_s
        );

        let rows: [(String, &ModeResult, String); 2] = [
            ("1/step".into(), &un, "-".into()),
            (
                format!("{PREFILL_CHUNK}/step"),
                &ch,
                format!("{:+.1}%", pct(ch.ttft_mean_s, un.ttft_mean_s)),
            ),
        ];
        for (mode, r, delta) in &rows {
            table.row(&[
                policy.to_string(),
                mode.clone(),
                r.tokens.to_string(),
                (LONG_N_REQUESTS * LONG_PROMPT_LEN).to_string(),
                fmt(r.makespan_s, 4),
                fmt(r.ttft_mean_s, 4),
                delta.clone(),
            ]);
        }
        println!(
            "[{policy:<12}] chunked vs one-token: mean TTFT {:+.1}%, makespan {:+.1}%",
            pct(ch.ttft_mean_s, un.ttft_mean_s),
            pct(ch.makespan_s, un.makespan_s),
        );
    }
    table.print("serve_continuous — long-prompt chunked prefill TTFT");
}

/// **Fused prefill wave scenario (PR 8)**: the same prompt-heavy Poisson
/// load as [`long_prompt_scenario`], chunked prefill on — once with the
/// pre-PR8 sequential per-chunk charging (setup hook) and once with the
/// default fused multi-row waves. The charging toggle never changes which
/// forwards run, so outputs must be byte-identical; the fused arm must
/// then win strictly on prompt-tokens/s (one per-forward overhead + one
/// dense-weight stream per wave instead of per chunk) and on mean TTFT.
/// A third arm turns on `--chunk-shared-selection` (lossy: all positions
/// of a chunk share one expert set per layer) and reports its activated-
/// experts reduction *with* its routing-fidelity delta side by side —
/// never silently. Emits `BENCH_prefill_fused.json`.
fn prefill_fused_scenario(model: &mut MoeModel) {
    println!(
        "\n# fused prefill waves — wave-charged vs sequential chunk charging \
         ({LONG_N_REQUESTS} reqs × {LONG_PROMPT_LEN}-token prompts, \
         chunk={PREFILL_CHUNK}, {LONG_MAX_NEW} new)"
    );
    let vocab = model.dims().vocab;
    let mut arrivals = long_prompt_trace(vocab);

    // Calibrate the arrival window against the chunked vanilla busy time so
    // multiple rows genuinely co-prefill (waves with one row fuse nothing).
    let mut probe_cfg = base_cfg("vanilla");
    probe_cfg.max_new_tokens = LONG_MAX_NEW;
    probe_cfg.prefill_chunk = PREFILL_CHUNK;
    let probe_reqs: Vec<Request> = arrivals.iter().map(|(_, r)| r.clone()).collect();
    let probe = Scheduler::new(model, probe_cfg.clone())
        .expect("probe scheduler")
        .run(probe_reqs)
        .expect("probe run");
    let busy = probe.metrics.sim_seconds;
    let t_last = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0).max(1e-12);
    let scale = ARRIVAL_WINDOW_FRAC * busy / t_last;
    for (t, _) in arrivals.iter_mut() {
        *t *= scale;
    }

    let cfg = probe_cfg;
    let seq = serve_continuous_with(model, &cfg, &arrivals, |core| {
        core.set_sequential_prefill_charging(true)
    });
    let fused = serve_continuous(model, &cfg, &arrivals);

    // The toggle is charge-only: identical forwards, identical tokens.
    assert_eq!(
        seq.outputs, fused.outputs,
        "fused wave charging changed generated tokens — it must be cost-only"
    );
    assert_eq!(seq.tokens_prompt, fused.tokens_prompt, "prompt-token accounting diverged");
    assert_eq!(seq.prefill_waves, 0, "sequential charging must record no waves");
    assert!(
        fused.prefill_waves > 0 && fused.prefill_streams_saved > 0,
        "the Poisson long-prompt mix never co-prefilled two rows — scenario \
         is not exercising wave fusion"
    );
    assert!(
        fused.prompt_tps > seq.prompt_tps,
        "ACCEPTANCE: fused waves must yield strictly higher prompt-tokens/s \
         than sequential chunk charging at byte-equal outputs ({} vs {})",
        fused.prompt_tps,
        seq.prompt_tps
    );
    assert!(
        fused.ttft_mean_s < seq.ttft_mean_s,
        "ACCEPTANCE: fused waves must cut mean TTFT ({} vs {})",
        fused.ttft_mean_s,
        seq.ttft_mean_s
    );

    // Opt-in lossy arm: chunk-shared expert selection on top of the waves.
    // Distortion is measured against the exact fused arm and reported as a
    // first-class number next to the activation win.
    let shared_cfg = ServeConfig { chunk_shared_selection: true, ..cfg.clone() };
    let shared = serve_continuous(model, &shared_cfg, &arrivals);
    let fid = xshare::coordinator::compare(&fused.outputs, &shared.outputs);
    assert!(
        fid.token_match.is_finite() && (0.0..=1.0).contains(&fid.token_match),
        "shared-selection fidelity must be a finite fraction, got {}",
        fid.token_match
    );
    assert!(
        shared.mean_activated < fused.mean_activated,
        "ACCEPTANCE: chunk-shared selection must activate strictly fewer \
         experts per forward ({} vs {})",
        shared.mean_activated,
        fused.mean_activated
    );
    let shared_drop_pts = (1.0 - fid.token_match) * 100.0;

    let mut table = Table::new(&[
        "arm",
        "tokens",
        "prompt_toks",
        "prompt_tps",
        "ttft_mean_s",
        "waves",
        "streams_saved",
        "mean_act",
        "token_match",
    ]);
    for (arm, r, tm) in [
        ("sequential", &seq, "-".to_string()),
        ("fused", &fused, "1.0000 (exact)".to_string()),
        ("fused+shared", &shared, format!("{:.4}", fid.token_match)),
    ] {
        table.row(&[
            arm.to_string(),
            r.tokens.to_string(),
            r.tokens_prompt.to_string(),
            fmt(r.prompt_tps, 1),
            fmt(r.ttft_mean_s, 4),
            r.prefill_waves.to_string(),
            r.prefill_streams_saved.to_string(),
            fmt(r.mean_activated, 2),
            tm,
        ]);
    }
    table.print("serve_continuous — fused prefill waves vs sequential charging");
    println!(
        "[prefill_fused] prompt-tokens/s {:+.1}%, mean TTFT {:+.1}%, \
         rows/wave {:.2}; shared selection: activated {:+.1}%, \
         token-match {:.4} ({:.2} pts drop)",
        pct(fused.prompt_tps, seq.prompt_tps),
        pct(fused.ttft_mean_s, seq.ttft_mean_s),
        fused.rows_per_wave_mean,
        pct(shared.mean_activated, fused.mean_activated),
        fid.token_match,
        shared_drop_pts,
    );

    let json = xshare::util::json::Json::obj(vec![
        ("scenario", xshare::util::json::Json::str("prefill_fused")),
        ("preset", xshare::util::json::Json::str(PRESET)),
        ("requests", xshare::util::json::Json::num(LONG_N_REQUESTS as f64)),
        ("prompt_len", xshare::util::json::Json::num(LONG_PROMPT_LEN as f64)),
        ("prefill_chunk", xshare::util::json::Json::num(PREFILL_CHUNK as f64)),
        ("tokens_out", xshare::util::json::Json::num(fused.tokens as f64)),
        (
            "prompt_tokens",
            xshare::util::json::Json::num(fused.tokens_prompt as f64),
        ),
        ("seq_prompt_tps", xshare::util::json::Json::num(seq.prompt_tps)),
        (
            "fused_prompt_tps",
            xshare::util::json::Json::num(fused.prompt_tps),
        ),
        (
            "prompt_tps_gain_pct",
            xshare::util::json::Json::num(pct(fused.prompt_tps, seq.prompt_tps)),
        ),
        ("seq_ttft_mean_s", xshare::util::json::Json::num(seq.ttft_mean_s)),
        (
            "fused_ttft_mean_s",
            xshare::util::json::Json::num(fused.ttft_mean_s),
        ),
        (
            "ttft_gain_pct",
            xshare::util::json::Json::num(pct(fused.ttft_mean_s, seq.ttft_mean_s)),
        ),
        (
            "prefill_waves",
            xshare::util::json::Json::num(fused.prefill_waves as f64),
        ),
        (
            "rows_per_wave_mean",
            xshare::util::json::Json::num(fused.rows_per_wave_mean),
        ),
        (
            "prefill_streams_saved",
            xshare::util::json::Json::num(fused.prefill_streams_saved as f64),
        ),
        (
            "fused_mean_activated",
            xshare::util::json::Json::num(fused.mean_activated),
        ),
        (
            "shared_mean_activated",
            xshare::util::json::Json::num(shared.mean_activated),
        ),
        (
            "shared_activated_delta_pct",
            xshare::util::json::Json::num(pct(shared.mean_activated, fused.mean_activated)),
        ),
        (
            "shared_token_match",
            xshare::util::json::Json::num(fid.token_match),
        ),
        (
            "shared_drop_pts",
            xshare::util::json::Json::num(shared_drop_pts),
        ),
    ])
    .dump();
    emit_bench("BENCH_prefill_fused.json", &json);
    println!("[prefill_fused] wrote BENCH_prefill_fused.json");
}

// Mixed-phase speculation scenario (PR 4): long-prompt Poisson arrivals
// with lookup-draft speculation, per-row phase machines vs the legacy
// stall gate. Runs on the tiny preset: its decode streams enter attractor
// cycles within a couple dozen tokens, which is exactly the regime where
// n-gram lookup drafting genuinely accepts — so speculation is profitable
// and the gate's stalls show up as lost throughput, not noise.
const SPEC_PRESET: &str = "tiny";
const SPEC_N_REQUESTS: usize = 10;
const SPEC_PROMPT_LEN: usize = 9;
const SPEC_MAX_NEW: usize = 24; // 9 + 24 = tiny max_seq + 1 (the KV bound)
const SPEC_LEN: usize = 3;
const SPEC_BATCH: usize = 4;

/// Deterministic spec-scenario prompts (kept in lockstep with the
/// acceptance probes: arithmetic pattern, one seed per request).
fn spec_prompt(seed: u64, vocab: u64) -> Vec<u32> {
    (0..SPEC_PROMPT_LEN as u64)
        .map(|i| ((seed.wrapping_mul(31) + i * 7 + 3) % vocab) as u32)
        .collect()
}

/// **Mixed-phase speculation scenario**: same Poisson arrivals, same
/// requests, vanilla routing, lookup drafts — once with per-row phase
/// machines (speculation runs whenever any row decodes) and once with the
/// pre-PR4 batch-global gate (one prefilling row stalls every verify
/// cycle). Greedy speculation is lossless under vanilla routing, so the
/// outputs must be byte-identical; the phase machines must then win
/// strictly on OTPS over simulated time. Emits `BENCH_spec.json` for the
/// perf trajectory.
fn spec_mixed_phase_scenario() {
    println!(
        "\n# mixed-phase speculation — per-row phase machines vs legacy stall gate \
         ({SPEC_PRESET}, B={SPEC_BATCH}, L_s={SPEC_LEN}, lookup drafts, \
         {SPEC_N_REQUESTS} reqs × {SPEC_PROMPT_LEN}-token prompts, {SPEC_MAX_NEW} new)"
    );
    let mut model = load_model(SPEC_PRESET);
    let vocab = model.dims().vocab;
    let cfg = ServeConfig {
        preset: SPEC_PRESET.into(),
        policy: PolicyKind::Vanilla,
        batch_size: SPEC_BATCH,
        spec_len: SPEC_LEN,
        spec_draft: SpecDraft::Lookup,
        max_new_tokens: SPEC_MAX_NEW,
        ..Default::default()
    };

    // Poisson arrivals, window-calibrated against the gated busy time so
    // prefill phases genuinely overlap other rows' decode (the regime the
    // stall gate hurts).
    let mut g = TraceGenerator::new(vocab, SEED + 2);
    g.arrival_rate = 1.0;
    let mut arrivals: Vec<(f64, Request)> = g
        .generate(&TraceDomain::standard_suite(), SPEC_N_REQUESTS)
        .into_iter()
        .map(|t| {
            let mut r =
                Request::new(t.id, spec_prompt(t.id, vocab as u64), SPEC_MAX_NEW);
            r.domain = t.domain;
            (t.arrival_s, r)
        })
        .collect();
    let upfront: Vec<(f64, Request)> =
        arrivals.iter().map(|(_, r)| (0.0, r.clone())).collect();
    let busy = serve_continuous_with(&mut model, &cfg, &upfront, |core| {
        core.set_legacy_spec_gate(true);
    })
    .makespan_s;
    let t_last = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0).max(1e-12);
    let scale = ARRIVAL_WINDOW_FRAC * busy / t_last;
    for (t, _) in arrivals.iter_mut() {
        *t *= scale;
    }

    let gated = serve_continuous_with(&mut model, &cfg, &arrivals, |core| {
        core.set_legacy_spec_gate(true);
    });
    let mixed = serve_continuous_with(&mut model, &cfg, &arrivals, |_| {});

    let mut table = Table::new(&[
        "spec gating",
        "tokens",
        "makespan_s",
        "otps",
        "ttft_mean_s",
        "stalled_steps",
        "accept_rate",
    ]);
    for (name, r) in [("legacy stall gate", &gated), ("per-row phases", &mixed)] {
        table.row(&[
            name.to_string(),
            r.tokens.to_string(),
            fmt(r.makespan_s, 4),
            fmt(r.otps(), 1),
            fmt(r.ttft_mean_s, 4),
            r.spec_stalled_steps.to_string(),
            fmt(r.spec_acceptance_rate, 3),
        ]);
    }
    table.print("serve_continuous — mixed-phase speculation vs stall gate");
    println!(
        "[spec        ] per-row phases vs stall gate: OTPS {:+.1}%, stalls {} → {}",
        pct(mixed.otps(), gated.otps()),
        gated.spec_stalled_steps,
        mixed.spec_stalled_steps,
    );

    assert_eq!(
        mixed.outputs, gated.outputs,
        "speculation gating is scheduling-only under vanilla routing — outputs \
         must be byte-identical"
    );
    assert!(
        gated.spec_stalled_steps > 0,
        "the Poisson long-prompt mix never tripped the legacy gate — scenario \
         is not exercising the stall"
    );
    assert_eq!(mixed.spec_stalled_steps, 0, "per-row phases must never stall");
    assert!(
        mixed.spec_accepted > 0,
        "lookup drafts never accepted — the speculation win has no substance"
    );
    assert!(
        mixed.otps() > gated.otps(),
        "ACCEPTANCE: mixed-phase speculation must yield strictly higher OTPS \
         than the stall-gated baseline at equal outputs ({} vs {})",
        mixed.otps(),
        gated.otps()
    );

    let json = xshare::util::json::Json::obj(vec![
        ("scenario", xshare::util::json::Json::str("spec_mixed_phase")),
        ("preset", xshare::util::json::Json::str(SPEC_PRESET)),
        ("spec_len", xshare::util::json::Json::num(SPEC_LEN as f64)),
        ("spec_draft", xshare::util::json::Json::str("lookup")),
        ("requests", xshare::util::json::Json::num(SPEC_N_REQUESTS as f64)),
        ("tokens_out", xshare::util::json::Json::num(mixed.tokens as f64)),
        ("mixed_otps", xshare::util::json::Json::num(mixed.otps())),
        ("gated_otps", xshare::util::json::Json::num(gated.otps())),
        (
            "otps_gain_pct",
            xshare::util::json::Json::num(pct(mixed.otps(), gated.otps())),
        ),
        (
            "mixed_ttft_mean_s",
            xshare::util::json::Json::num(mixed.ttft_mean_s),
        ),
        (
            "gated_ttft_mean_s",
            xshare::util::json::Json::num(gated.ttft_mean_s),
        ),
        (
            "gated_stalled_steps",
            xshare::util::json::Json::num(gated.spec_stalled_steps as f64),
        ),
        (
            "acceptance_rate",
            xshare::util::json::Json::num(mixed.spec_acceptance_rate),
        ),
    ])
    .dump();
    // Repo-root copy is the CI artifact (gitignored locally; fail loudly —
    // a silent miss would only surface as an opaque upload-artifact error);
    // target/bench-reports keeps the local trajectory alongside the other
    // bench outputs.
    emit_bench("BENCH_spec.json", &json);
    println!("[spec        ] wrote BENCH_spec.json");
}

/// **Charge-aware speculative depth scenario** (PR 10): the same Poisson
/// long-prompt mix as the spec scenario, adaptive lookup drafting in both
/// arms — once with the fixed usefulness threshold (`a^d` vs a constant)
/// and once with `--spec-charge-aware`, which prices each extra draft
/// level against the cost ledger's marginal verify charge for the CURRENT
/// batch. Decode on the tiny preset is memory-bound, so one more padded
/// verify level costs a few percent of a committed token's value; the
/// marginal test therefore holds depth where the fixed threshold backs
/// off, converting the same acceptance EMA into deeper drafts. Depth
/// choice is scheduling-only (greedy verify under vanilla routing), so
/// the outputs must be byte-identical — and the charge-aware arm must
/// then win strictly on OTPS over simulated time. Emits
/// `BENCH_spec_charge.json`.
fn spec_charge_scenario() {
    println!(
        "\n# charge-aware spec depth — ledger marginal cost vs fixed threshold \
         ({SPEC_PRESET}, B={SPEC_BATCH}, L_s={SPEC_LEN}, adaptive lookup drafts, \
         {SPEC_N_REQUESTS} reqs × {SPEC_PROMPT_LEN}-token prompts, {SPEC_MAX_NEW} new)"
    );
    let mut model = load_model(SPEC_PRESET);
    let vocab = model.dims().vocab;
    let mut cfg = ServeConfig {
        preset: SPEC_PRESET.into(),
        policy: PolicyKind::Vanilla,
        batch_size: SPEC_BATCH,
        spec_len: SPEC_LEN,
        spec_draft: SpecDraft::Lookup,
        spec_adaptive: true,
        max_new_tokens: SPEC_MAX_NEW,
        ..Default::default()
    };

    // Same arrival construction as the spec scenario (window-calibrated
    // against the fixed-threshold upfront busy time).
    let mut g = TraceGenerator::new(vocab, SEED + 2);
    g.arrival_rate = 1.0;
    let mut arrivals: Vec<(f64, Request)> = g
        .generate(&TraceDomain::standard_suite(), SPEC_N_REQUESTS)
        .into_iter()
        .map(|t| {
            let mut r =
                Request::new(t.id, spec_prompt(t.id, vocab as u64), SPEC_MAX_NEW);
            r.domain = t.domain;
            (t.arrival_s, r)
        })
        .collect();
    let upfront: Vec<(f64, Request)> =
        arrivals.iter().map(|(_, r)| (0.0, r.clone())).collect();
    let busy = serve_continuous(&mut model, &cfg, &upfront).makespan_s;
    let t_last = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0).max(1e-12);
    let scale = ARRIVAL_WINDOW_FRAC * busy / t_last;
    for (t, _) in arrivals.iter_mut() {
        *t *= scale;
    }

    let fixed = serve_continuous(&mut model, &cfg, &arrivals);
    cfg.spec_charge_aware = true;
    let charge = serve_continuous(&mut model, &cfg, &arrivals);

    let mut table = Table::new(&[
        "depth control",
        "tokens",
        "makespan_s",
        "otps",
        "depth_mean",
        "accept_rate",
    ]);
    for (name, r) in [("fixed threshold", &fixed), ("charge-aware", &charge)] {
        table.row(&[
            name.to_string(),
            r.tokens.to_string(),
            fmt(r.makespan_s, 4),
            fmt(r.otps(), 1),
            fmt(r.spec_depth_mean, 3),
            fmt(r.spec_acceptance_rate, 3),
        ]);
    }
    table.print("serve_continuous — charge-aware vs fixed-threshold depth");
    println!(
        "[spec_charge ] charge-aware vs fixed threshold: OTPS {:+.1}%, \
         depth {:.3} → {:.3}",
        pct(charge.otps(), fixed.otps()),
        fixed.spec_depth_mean,
        charge.spec_depth_mean,
    );

    assert_eq!(
        charge.outputs, fixed.outputs,
        "depth control is scheduling-only under vanilla routing — outputs \
         must be byte-identical"
    );
    assert!(
        fixed.spec_accepted > 0 && charge.spec_accepted > 0,
        "lookup drafts never accepted — neither arm has substance"
    );
    assert!(
        charge.spec_depth_mean >= fixed.spec_depth_mean,
        "the cheap-marginal regime must never draft shallower than the fixed \
         threshold ({} vs {})",
        charge.spec_depth_mean,
        fixed.spec_depth_mean
    );
    assert!(
        charge.otps() > fixed.otps(),
        "ACCEPTANCE: charge-aware depth must yield strictly higher OTPS than \
         the fixed usefulness threshold at equal outputs ({} vs {})",
        charge.otps(),
        fixed.otps()
    );

    let json = xshare::util::json::Json::obj(vec![
        ("scenario", xshare::util::json::Json::str("spec_charge")),
        ("preset", xshare::util::json::Json::str(SPEC_PRESET)),
        ("spec_len", xshare::util::json::Json::num(SPEC_LEN as f64)),
        ("spec_draft", xshare::util::json::Json::str("lookup")),
        ("requests", xshare::util::json::Json::num(SPEC_N_REQUESTS as f64)),
        ("tokens_out", xshare::util::json::Json::num(charge.tokens as f64)),
        ("charge_otps", xshare::util::json::Json::num(charge.otps())),
        ("fixed_otps", xshare::util::json::Json::num(fixed.otps())),
        (
            "otps_gain_pct",
            xshare::util::json::Json::num(pct(charge.otps(), fixed.otps())),
        ),
        (
            "charge_depth_mean",
            xshare::util::json::Json::num(charge.spec_depth_mean),
        ),
        (
            "fixed_depth_mean",
            xshare::util::json::Json::num(fixed.spec_depth_mean),
        ),
        (
            "acceptance_rate",
            xshare::util::json::Json::num(charge.spec_acceptance_rate),
        ),
    ])
    .dump();
    emit_bench("BENCH_spec_charge.json", &json);
    println!("[spec_charge ] wrote BENCH_spec_charge.json");
}

// Shared-prefix cache scenario (PR 7): two-turn templated traffic on the
// serving preset — turn 2 resubmits each conversation's full turn-1
// history (prompt ++ generated) plus a short follow-up. With
// `--prefix-cache-mb` on, the slot-free hook keeps each finished row's
// prefix KV, so every turn-2 admission restores the cached bytes and
// chunk-prefills only the follow-up suffix.
const PFX_N: usize = 8;
const PFX_BATCH: usize = 4;
const PFX_PROMPT_LEN: usize = 24;
const PFX_MAX_NEW: usize = 8;
const PFX_TURN2_EXTRA: usize = 4;
const PFX_CACHE_MB: usize = 64;
const PFX_MIN_TOKENS: usize = 4;

/// Deterministic per-conversation turn-1 prompts (templated traffic: one
/// arithmetic pattern, one seed per conversation).
fn pfx_prompt(seed: u64, vocab: u64) -> Vec<u32> {
    (0..PFX_PROMPT_LEN as u64)
        .map(|i| ((seed.wrapping_mul(37) + i * 11 + 5) % vocab) as u32)
        .collect()
}

/// One arm's numbers from a two-turn [`pfx_run`]. The turn-2 TTFT mean is
/// the [`xshare::metrics::Summary`] delta between the drains, so both arms
/// are scored on exactly the (potentially) warm-prefix admissions.
struct PfxArm {
    outputs: BTreeMap<u64, Vec<u32>>,
    turn2_ttft_mean_s: f64,
    tokens_prompt: u64,
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_inserts: u64,
    restored_tokens: u64,
}

/// Two-turn run under one config: submit turn 1, drain, snapshot TTFT,
/// submit turn 2, drain, report.
fn pfx_run(
    model: &mut MoeModel,
    cfg: &ServeConfig,
    turn1: &[Request],
    turn2: &[Request],
) -> PfxArm {
    let mut core = ServeLoop::new(model, cfg.clone()).expect("serve loop");
    for r in turn1 {
        core.submit(r.clone()).expect("submit turn 1");
    }
    while core.has_work() {
        core.step().expect("step");
    }
    let (t1_sum, t1_n) = (core.metrics().ttft.sum, core.metrics().ttft.n);
    for r in turn2 {
        core.submit(r.clone()).expect("submit turn 2");
    }
    while core.has_work() {
        core.step().expect("step");
    }
    let report = core.report();
    let m = &report.metrics;
    assert_eq!(m.ttft.n - t1_n, PFX_N as u64, "one TTFT sample per turn-2 request");
    PfxArm {
        outputs: report.outputs,
        turn2_ttft_mean_s: (m.ttft.sum - t1_sum) / (m.ttft.n - t1_n) as f64,
        tokens_prompt: m.tokens_prompt,
        prefix_hits: m.prefix_hits,
        prefix_misses: m.prefix_misses,
        prefix_inserts: m.prefix_inserts,
        restored_tokens: m.prefill_restored_tokens,
    }
}

/// **Shared-prefix cache scenario**: same two-turn conversations, vanilla
/// routing, chunked prefill — once with the cache disabled (every turn-2
/// prompt re-prefills its whole history) and once with
/// `--prefix-cache-mb`/`--prefix-min-tokens` on (turn 2 restores the
/// cached history and prefills only the follow-up). Cache restore is
/// byte-lossless by contract, so outputs must be identical; the warm arm
/// must then win strictly on turn-2 TTFT. Emits `BENCH_prefix.json`.
fn prefix_shared_cache_scenario() {
    println!(
        "\n# shared-prefix KV cache — two-turn templated traffic, cache-off vs \
         --prefix-cache-mb {PFX_CACHE_MB} ({PRESET}, B={PFX_BATCH}, {PFX_N} \
         conversations × {PFX_PROMPT_LEN}-token prompts, {PFX_MAX_NEW} new, \
         +{PFX_TURN2_EXTRA} follow-up)"
    );
    let mut model = load_model(PRESET);
    let vocab = model.dims().vocab as u64;
    let cold_cfg = ServeConfig {
        preset: PRESET.into(),
        policy: PolicyKind::Vanilla,
        batch_size: PFX_BATCH,
        max_new_tokens: PFX_MAX_NEW,
        prefill_chunk: PREFILL_CHUNK,
        ..Default::default()
    };
    let warm_cfg = ServeConfig {
        prefix_cache_mb: PFX_CACHE_MB,
        prefix_min_tokens: PFX_MIN_TOKENS,
        ..cold_cfg.clone()
    };

    let turn1: Vec<Request> = (0..PFX_N as u64)
        .map(|id| Request::new(id, pfx_prompt(id, vocab), PFX_MAX_NEW))
        .collect();

    // Turn-2 prompts extend each conversation's actual turn-1 tokens, so a
    // probe run supplies the histories. Vanilla routing is row-independent,
    // so the probe's outputs are byte-identical to both arms' turn-1
    // outputs (the warm arm's turn-2 hits assert exactly that).
    let probe = Scheduler::new(&mut model, cold_cfg.clone())
        .expect("probe scheduler")
        .run(turn1.clone())
        .expect("probe run");
    let turn2: Vec<Request> = turn1
        .iter()
        .map(|r| {
            let mut prompt = r.prompt.clone();
            prompt.extend_from_slice(&probe.outputs[&r.id]);
            for i in 0..PFX_TURN2_EXTRA as u64 {
                prompt.push(((r.id.wrapping_mul(53) + i * 17 + 29) % vocab) as u32);
            }
            Request::new(100 + r.id, prompt, PFX_MAX_NEW)
        })
        .collect();

    let cold = pfx_run(&mut model, &cold_cfg, &turn1, &turn2);
    let warm = pfx_run(&mut model, &warm_cfg, &turn1, &turn2);

    let mut table = Table::new(&[
        "prefix cache",
        "prompt_toks",
        "restored",
        "hits",
        "turn2_ttft_s",
        "ttft_delta",
    ]);
    let rows: [(&str, &PfxArm, String); 2] = [
        ("off", &cold, "-".into()),
        (
            "on",
            &warm,
            format!("{:+.1}%", pct(warm.turn2_ttft_mean_s, cold.turn2_ttft_mean_s)),
        ),
    ];
    for (name, r, delta) in &rows {
        table.row(&[
            name.to_string(),
            r.tokens_prompt.to_string(),
            r.restored_tokens.to_string(),
            r.prefix_hits.to_string(),
            fmt(r.turn2_ttft_mean_s, 4),
            delta.clone(),
        ]);
    }
    table.print("serve_continuous — shared-prefix cache, two-turn traffic");
    println!(
        "[prefix      ] warm vs cold turn-2 TTFT {:+.1}%, restored {} of {} \
         prompt tokens",
        pct(warm.turn2_ttft_mean_s, cold.turn2_ttft_mean_s),
        warm.restored_tokens,
        cold.tokens_prompt,
    );

    assert_eq!(
        cold.outputs, warm.outputs,
        "cache restore is byte-lossless by contract — enabling it must not \
         change a single generated token"
    );
    assert_eq!(cold.prefix_hits, 0, "cache-off arm must never consult the cache");
    assert_eq!(cold.restored_tokens, 0, "cache-off arm must prefill everything");
    assert_eq!(
        warm.prefix_hits, PFX_N as u64,
        "every turn-2 admission extends a finished turn-1 row — all must hit"
    );
    assert!(
        warm.prefix_inserts >= PFX_N as u64,
        "every finished turn-1 row must offer its prefix KV back"
    );
    assert!(
        warm.restored_tokens > 0 && warm.tokens_prompt < cold.tokens_prompt,
        "restores must replace prefill work ({} restored, {} vs {} prefilled)",
        warm.restored_tokens,
        warm.tokens_prompt,
        cold.tokens_prompt
    );
    assert!(
        warm.turn2_ttft_mean_s < cold.turn2_ttft_mean_s,
        "ACCEPTANCE: warm-prefix turn-2 TTFT must be strictly lower than the \
         cache-disabled baseline at byte-identical outputs ({} vs {})",
        warm.turn2_ttft_mean_s,
        cold.turn2_ttft_mean_s
    );

    let hit_rate =
        warm.prefix_hits as f64 / (warm.prefix_hits + warm.prefix_misses).max(1) as f64;
    let json = xshare::util::json::Json::obj(vec![
        ("scenario", xshare::util::json::Json::str("prefix_shared_cache")),
        ("preset", xshare::util::json::Json::str(PRESET)),
        ("conversations", xshare::util::json::Json::num(PFX_N as f64)),
        ("prompt_len", xshare::util::json::Json::num(PFX_PROMPT_LEN as f64)),
        ("max_new_tokens", xshare::util::json::Json::num(PFX_MAX_NEW as f64)),
        ("turn2_extra", xshare::util::json::Json::num(PFX_TURN2_EXTRA as f64)),
        ("prefix_cache_mb", xshare::util::json::Json::num(PFX_CACHE_MB as f64)),
        ("prefix_min_tokens", xshare::util::json::Json::num(PFX_MIN_TOKENS as f64)),
        ("prefill_chunk", xshare::util::json::Json::num(PREFILL_CHUNK as f64)),
        (
            "cold_turn2_ttft_mean_s",
            xshare::util::json::Json::num(cold.turn2_ttft_mean_s),
        ),
        (
            "warm_turn2_ttft_mean_s",
            xshare::util::json::Json::num(warm.turn2_ttft_mean_s),
        ),
        (
            "ttft_gain_pct",
            xshare::util::json::Json::num(pct(
                warm.turn2_ttft_mean_s,
                cold.turn2_ttft_mean_s,
            )),
        ),
        ("prefix_hits", xshare::util::json::Json::num(warm.prefix_hits as f64)),
        (
            "prefix_inserts",
            xshare::util::json::Json::num(warm.prefix_inserts as f64),
        ),
        ("hit_rate", xshare::util::json::Json::num(hit_rate)),
        (
            "restored_tokens",
            xshare::util::json::Json::num(warm.restored_tokens as f64),
        ),
        (
            "cold_prompt_tokens",
            xshare::util::json::Json::num(cold.tokens_prompt as f64),
        ),
        (
            "warm_prompt_tokens",
            xshare::util::json::Json::num(warm.tokens_prompt as f64),
        ),
    ])
    .dump();
    emit_bench("BENCH_prefix.json", &json);
    println!("[prefix      ] wrote BENCH_prefix.json");
}

// Admission scenario (PR 3): heterogeneous two-dataset mix under queue
// backlog, FIFO vs footprint-aware co-scheduling.
const ADM_N_REQUESTS: usize = 24;
const ADM_BATCH: usize = 4;
const ADM_MAX_NEW: usize = 10;

/// Two templated traffic classes with well-separated vocabulary regions
/// (retries / eval harnesses / templated agent calls: many requests share
/// a prompt template verbatim). Requests alternate A,B,A,B… — the
/// heterogeneous mix FIFO admission preserves and footprint admission
/// unmixes.
fn template_requests() -> Vec<Request> {
    let tpl_a: Vec<u32> = vec![70, 75, 80, 72, 78, 74]; // "aime"-region template
    let tpl_b: Vec<u32> = vec![430, 436, 440, 433, 428, 438]; // "ifeval"-region
    (0..ADM_N_REQUESTS as u64)
        .map(|id| {
            let (prompt, domain) = if id % 2 == 0 {
                (tpl_a.clone(), "tplA")
            } else {
                (tpl_b.clone(), "tplB")
            };
            let mut r = Request::new(id, prompt, ADM_MAX_NEW);
            r.domain = domain.into();
            r
        })
        .collect()
}

/// Serve the template mix to completion under one admission policy (burst
/// backlog: the window→0 limit of the Poisson arrivals above, which is
/// exactly when admission order matters — every slot choice has a full
/// queue to pick from).
fn serve_admission(
    model: &mut MoeModel,
    admission: AdmissionKind,
    mutate: impl Fn(&mut Request),
) -> xshare::coordinator::RunReport {
    let mut cfg = base_cfg("vanilla");
    cfg.batch_size = ADM_BATCH;
    cfg.max_new_tokens = ADM_MAX_NEW;
    cfg.admission = admission;
    let mut core = ServeLoop::new(model, cfg).expect("serve loop");
    for mut r in template_requests() {
        mutate(&mut r);
        core.submit(r).expect("unbounded queue");
    }
    core.drain().expect("drain");
    core.report()
}

/// **Admission scenario** (real model, real serve loop): under a
/// heterogeneous two-template mix with a deep queue, footprint-aware
/// admission must activate strictly fewer experts per decode step than
/// FIFO at equal throughput — co-admitted same-template rows route
/// identically, so the per-layer expert union collapses toward one
/// request's top-k. Priority and EDF runs of the same workload report
/// per-class TTFT and deadline misses.
fn admission_scenario(model: &mut MoeModel) {
    println!(
        "\n# admission — two-template mix ({ADM_N_REQUESTS} reqs, B={ADM_BATCH}, \
         vanilla routing, burst backlog)"
    );
    let fifo = serve_admission(model, AdmissionKind::Fifo, |_| {});
    let fp = serve_admission(model, AdmissionKind::FootprintAware, |_| {});
    // Priority: class A is latency-sensitive (priority 1), B best-effort.
    let prio = serve_admission(model, AdmissionKind::Priority, |r| {
        if r.domain == "tplA" {
            r.priority = 1;
        }
    });
    // EDF: class A carries a 250 ms TTFT SLO, B a slack 10 s one.
    let edf = serve_admission(model, AdmissionKind::SloEdf, |r| {
        r.deadline_ms = Some(if r.domain == "tplA" { 250 } else { 10_000 });
    });

    let mut table = Table::new(&[
        "admission",
        "tokens",
        "activated/layer/step",
        "otps",
        "ttft_mean_s",
        "ttft_p99_s",
        "ttft_by_class_s",
        "deadline_miss",
    ]);
    for (name, r) in
        [("fifo", &fifo), ("footprint", &fp), ("priority", &prio), ("edf", &edf)]
    {
        let m = &r.metrics;
        let classes: Vec<String> = m
            .ttft_by_class
            .iter()
            .map(|(c, s)| format!("{c}:{:.3}", s.mean()))
            .collect();
        table.row(&[
            name.to_string(),
            m.tokens_out.to_string(),
            fmt(m.mean_activated(), 2),
            fmt(m.otps(), 1),
            fmt(m.ttft.mean(), 4),
            fmt(m.ttft_hist.quantile_seconds(0.99), 4),
            classes.join(" "),
            format!("{}/{}", m.deadline_misses, m.deadline_total),
        ]);
    }
    table.print("serve_continuous — admission policies, two-template mix");
    println!(
        "[admission   ] footprint vs fifo: activated/step {:+.1}%, \
         footprint-overlap gauge mean {:.2}",
        pct(fp.metrics.mean_activated(), fifo.metrics.mean_activated()),
        fp.metrics.footprint_overlap.mean(),
    );

    assert_eq!(
        fifo.metrics.tokens_out, fp.metrics.tokens_out,
        "equal throughput: both admissions serve the identical request set"
    );
    assert!(
        fp.metrics.mean_activated() < fifo.metrics.mean_activated(),
        "footprint admission must activate strictly fewer experts per step \
         than FIFO on the heterogeneous template mix ({} vs {})",
        fp.metrics.mean_activated(),
        fifo.metrics.mean_activated()
    );
    assert!(
        fp.metrics.footprint_overlap.n > 0,
        "footprint admissions never scored against a live batch"
    );
    // The latency-sensitive class must come out ahead under priority
    // admission of the same backlog.
    let hi = prio.metrics.ttft_by_class[&1].mean();
    let lo = prio.metrics.ttft_by_class[&0].mean();
    assert!(hi < lo, "priority class TTFT {hi} not ahead of best-effort {lo}");
    assert_eq!(edf.metrics.deadline_total, ADM_N_REQUESTS as u64);
}

// EP serving scenario (PR 5): the same two-template traffic, deployed
// expert-parallel.
const EP_GPUS: usize = 4;
const EP_REBALANCE_EVERY: usize = 2;
const EP_N_REQUESTS: usize = 24;

/// Skewed two-template burst: one minority-class row lands in the first
/// (cold-admitted) batch, then a long majority run, then the minority
/// block. The shape that makes eviction earn its keep: the cold admission
/// strands one "B" row among "A"s with a queue full of better-fitting
/// "A"s, and the B block at the tail gives the preempted row same-class
/// company to resume with.
fn ep_template_requests() -> Vec<Request> {
    let tpl_a: Vec<u32> = vec![70, 75, 80, 72, 78, 74];
    let tpl_b: Vec<u32> = vec![430, 436, 440, 433, 428, 438];
    let mut reqs = Vec::with_capacity(EP_N_REQUESTS);
    let mut push = |id: u64, class_a: bool| {
        let (prompt, domain) =
            if class_a { (tpl_a.clone(), "tplA") } else { (tpl_b.clone(), "tplB") };
        let mut r = Request::new(id, prompt, ADM_MAX_NEW);
        r.domain = domain.into();
        reqs.push(r);
    };
    // first batch: A, A, A, B (the stranded minority row) …
    for id in 0..3 {
        push(id, true);
    }
    push(3, false);
    // … then 10 more A, then the B block
    for id in 4..14 {
        push(id, true);
    }
    for id in 14..EP_N_REQUESTS as u64 {
        push(id, false);
    }
    reqs
}

/// **EP serving scenario**: the live serve loop under a 4-GPU
/// expert-parallel deployment, burst backlog of the skewed template mix,
/// vanilla (placement-blind) routing so token outputs are comparable
/// byte-for-byte. Baseline: static contiguous placement, FIFO admission.
/// Optimized: the gpu-aware scheduling stack — MaxLoad-weighted footprint
/// admission, footprint-driven eviction (`--ep-evict`), dynamic placement
/// (`--ep-rebalance`). ACCEPTANCE: the optimized deployment serves the
/// identical tokens at a strictly lower peak-GPU-load integral
/// (∫ MaxLoad dt). Emits `BENCH_ep_serve.json`.
fn ep_serve_scenario(model: &mut MoeModel) {
    println!(
        "\n# EP serving — gpu-aware stack vs vanilla placement ({EP_N_REQUESTS} reqs, \
         B={ADM_BATCH}, G={EP_GPUS}, vanilla routing, burst backlog)"
    );
    let reqs = ep_template_requests();
    let mut base_cfg = base_cfg("vanilla");
    base_cfg.batch_size = ADM_BATCH;
    base_cfg.max_new_tokens = ADM_MAX_NEW;
    base_cfg.ep = Some(EpConfig { n_gpus: EP_GPUS, placement: PlacementKind::Contiguous });
    let mut opt_cfg = base_cfg.clone();
    opt_cfg.admission = AdmissionKind::FootprintAware;
    opt_cfg.ep_evict = true;
    opt_cfg.ep_rebalance = EP_REBALANCE_EVERY;

    let base = Scheduler::new(model, base_cfg)
        .expect("scheduler")
        .run(reqs.clone())
        .expect("run");
    let opt = Scheduler::new(model, opt_cfg)
        .expect("scheduler")
        .run(reqs)
        .expect("run");

    let mut table = Table::new(&[
        "deployment",
        "tokens",
        "sim_s",
        "max_load_mean",
        "∫maxload_dt",
        "load/gpu",
        "evictions",
        "rebalances",
    ]);
    for (name, r) in [("vanilla placement + fifo", &base), ("gpu-aware stack", &opt)] {
        let m = &r.metrics;
        let per_gpu: Vec<String> =
            m.gpu_loads.iter().map(|s| format!("{:.1}", s.mean())).collect();
        table.row(&[
            name.to_string(),
            m.tokens_out.to_string(),
            fmt(m.sim_seconds, 4),
            fmt(m.max_gpu_load.mean(), 2),
            fmt(m.gpu_load_integral, 5),
            per_gpu.join("/"),
            m.evictions.to_string(),
            m.rebalances.to_string(),
        ]);
    }
    table.print("serve_continuous — expert-parallel serving, skewed template mix");
    println!(
        "[ep          ] gpu-aware stack vs vanilla placement: ∫MaxLoad dt {:+.1}%, \
         sim {:+.1}%, {} evictions, {} rebalances (mean Δ {:.3})",
        pct(opt.metrics.gpu_load_integral, base.metrics.gpu_load_integral),
        pct(opt.metrics.sim_seconds, base.metrics.sim_seconds),
        opt.metrics.evictions,
        opt.metrics.rebalances,
        opt.metrics.rebalance_delta.mean(),
    );

    assert_eq!(
        opt.outputs, base.outputs,
        "scheduling/placement are cost-and-composition levers — under vanilla \
         routing the served tokens must be byte-identical"
    );
    assert!(
        opt.metrics.gpu_load_integral < base.metrics.gpu_load_integral,
        "ACCEPTANCE: gpu-aware admission + eviction + rebalancing must serve the \
         skewed mix at a strictly lower peak-GPU-load integral than vanilla \
         placement ({} vs {})",
        opt.metrics.gpu_load_integral,
        base.metrics.gpu_load_integral
    );
    assert!(
        opt.metrics.evictions > 0,
        "the stranded minority row was never evicted — the scenario is not \
         exercising footprint-driven preemption"
    );
    assert!(
        opt.metrics.rebalances > 0,
        "dynamic placement never adopted a rebalance on the skewed mix"
    );
    assert!(
        opt.metrics.rebalance_delta.min > 0.0,
        "adopted rebalances must strictly improve expected MaxLoad"
    );

    let json = xshare::util::json::Json::obj(vec![
        ("scenario", xshare::util::json::Json::str("ep_serve")),
        ("preset", xshare::util::json::Json::str(PRESET)),
        ("n_gpus", xshare::util::json::Json::num(EP_GPUS as f64)),
        ("requests", xshare::util::json::Json::num(EP_N_REQUESTS as f64)),
        ("tokens_out", xshare::util::json::Json::num(opt.metrics.tokens_out as f64)),
        (
            "base_gpu_load_integral",
            xshare::util::json::Json::num(base.metrics.gpu_load_integral),
        ),
        (
            "opt_gpu_load_integral",
            xshare::util::json::Json::num(opt.metrics.gpu_load_integral),
        ),
        (
            "integral_gain_pct",
            xshare::util::json::Json::num(pct(
                opt.metrics.gpu_load_integral,
                base.metrics.gpu_load_integral,
            )),
        ),
        ("base_sim_s", xshare::util::json::Json::num(base.metrics.sim_seconds)),
        ("opt_sim_s", xshare::util::json::Json::num(opt.metrics.sim_seconds)),
        ("evictions", xshare::util::json::Json::num(opt.metrics.evictions as f64)),
        ("rebalances", xshare::util::json::Json::num(opt.metrics.rebalances as f64)),
        (
            "rebalance_delta_mean",
            xshare::util::json::Json::num(opt.metrics.rebalance_delta.mean()),
        ),
    ])
    .dump();
    emit_bench("BENCH_ep_serve.json", &json);
    println!("[ep          ] wrote BENCH_ep_serve.json");
}

// Replication/migration scenario (PR 6).
const MIG_SLACK: f64 = 2.0;
const MIG_BUDGET: usize = 3;

/// **EP migration scenario**: the same skewed template burst, PR 5's
/// swap-rebalance stack (`--ep-migrate-budget 0`, whole-placement LPT
/// swaps) against PR 6's replicated placement — residency slack
/// [`MIG_SLACK`], incremental plans of at most [`MIG_BUDGET`] ops with the
/// copied weight bytes charged through the interconnect, and footprint
/// prefetch for queued classes. ACCEPTANCE: identical tokens, and the
/// replication arm's ∫ MaxLoad dt strictly below the swap baseline even
/// though it pays for every byte it moves. Emits `BENCH_ep_migrate.json`.
fn ep_migrate_scenario(model: &mut MoeModel) {
    println!(
        "\n# EP migration — replica sets + bounded migration vs swap rebalance \
         ({EP_N_REQUESTS} reqs, B={ADM_BATCH}, G={EP_GPUS}, slack={MIG_SLACK}, \
         budget={MIG_BUDGET}, vanilla routing)"
    );
    let reqs = ep_template_requests();
    let mut swap_cfg = base_cfg("vanilla");
    swap_cfg.batch_size = ADM_BATCH;
    swap_cfg.max_new_tokens = ADM_MAX_NEW;
    swap_cfg.ep = Some(EpConfig { n_gpus: EP_GPUS, placement: PlacementKind::Contiguous });
    swap_cfg.admission = AdmissionKind::FootprintAware;
    swap_cfg.ep_evict = true;
    swap_cfg.ep_rebalance = EP_REBALANCE_EVERY;
    let mut mig_cfg = swap_cfg.clone();
    mig_cfg.ep_replica_slack = MIG_SLACK;
    mig_cfg.ep_migrate_budget = MIG_BUDGET;
    mig_cfg.ep_prefetch = true;

    let swap = Scheduler::new(model, swap_cfg)
        .expect("scheduler")
        .run(reqs.clone())
        .expect("run");
    let mig = Scheduler::new(model, mig_cfg)
        .expect("scheduler")
        .run(reqs)
        .expect("run");

    let mut table = Table::new(&[
        "deployment",
        "tokens",
        "sim_s",
        "max_load_mean",
        "∫maxload_dt",
        "migrations",
        "mig_bytes",
        "mig_charge_s",
        "prefetches",
    ]);
    for (name, r) in [("swap rebalance (PR 5)", &swap), ("replication + migration", &mig)] {
        let m = &r.metrics;
        table.row(&[
            name.to_string(),
            m.tokens_out.to_string(),
            fmt(m.sim_seconds, 4),
            fmt(m.max_gpu_load.mean(), 2),
            fmt(m.gpu_load_integral, 5),
            m.migrations.to_string(),
            fmt(m.migration_bytes, 0),
            fmt(m.migration_seconds, 6),
            m.prefetches.to_string(),
        ]);
    }
    table.print("serve_continuous — replicated placement vs swap rebalance");
    println!(
        "[ep-migrate  ] replication vs swap: ∫MaxLoad dt {:+.1}%, sim {:+.1}%, \
         {} migrations ({} prefetch), max {} ops/plan, {:.0} bytes moved",
        pct(mig.metrics.gpu_load_integral, swap.metrics.gpu_load_integral),
        pct(mig.metrics.sim_seconds, swap.metrics.sim_seconds),
        mig.metrics.migrations,
        mig.metrics.prefetches,
        mig.metrics.migration_ops.max,
        mig.metrics.migration_bytes,
    );

    assert_eq!(
        mig.outputs, swap.outputs,
        "replication/migration are cost-and-composition levers — under vanilla \
         routing the served tokens must be byte-identical to the swap baseline"
    );
    assert!(
        mig.metrics.gpu_load_integral < swap.metrics.gpu_load_integral,
        "ACCEPTANCE: replicated placement + bounded migration must serve the \
         skewed mix at a strictly lower peak-GPU-load integral than the PR 5 \
         swap-rebalance baseline ({} vs {})",
        mig.metrics.gpu_load_integral,
        swap.metrics.gpu_load_integral
    );
    assert!(
        mig.metrics.migrations > 0,
        "the skewed mix never triggered an adopted migration plan"
    );
    assert!(
        mig.metrics.migration_ops.max <= MIG_BUDGET as f64,
        "a migration plan carried {} ops past the budget {MIG_BUDGET}",
        mig.metrics.migration_ops.max
    );
    let cost = xshare::ep::EpCostModel::default();
    assert!(
        mig.metrics.migration_bytes
            <= mig.metrics.migrations as f64 * MIG_BUDGET as f64 * cost.expert_bytes,
        "per-plan migration bytes exceeded budget × expert_bytes"
    );
    assert!(
        mig.metrics.migration_seconds > 0.0,
        "adopted migrations were never charged to the sim clock"
    );
    assert_eq!(mig.metrics.rebalances, 0, "swap path ran in migration mode");
    assert!(
        mig.metrics.rebalance_delta.min > 0.0,
        "adopted migration plans must strictly improve expected MaxLoad"
    );

    let json = xshare::util::json::Json::obj(vec![
        ("scenario", xshare::util::json::Json::str("ep_migrate")),
        ("preset", xshare::util::json::Json::str(PRESET)),
        ("n_gpus", xshare::util::json::Json::num(EP_GPUS as f64)),
        ("requests", xshare::util::json::Json::num(EP_N_REQUESTS as f64)),
        ("replica_slack", xshare::util::json::Json::num(MIG_SLACK)),
        ("migrate_budget", xshare::util::json::Json::num(MIG_BUDGET as f64)),
        ("tokens_out", xshare::util::json::Json::num(mig.metrics.tokens_out as f64)),
        (
            "swap_gpu_load_integral",
            xshare::util::json::Json::num(swap.metrics.gpu_load_integral),
        ),
        (
            "migrate_gpu_load_integral",
            xshare::util::json::Json::num(mig.metrics.gpu_load_integral),
        ),
        (
            "integral_gain_pct",
            xshare::util::json::Json::num(pct(
                mig.metrics.gpu_load_integral,
                swap.metrics.gpu_load_integral,
            )),
        ),
        ("swap_sim_s", xshare::util::json::Json::num(swap.metrics.sim_seconds)),
        ("migrate_sim_s", xshare::util::json::Json::num(mig.metrics.sim_seconds)),
        ("migrations", xshare::util::json::Json::num(mig.metrics.migrations as f64)),
        (
            "migration_ops_max",
            xshare::util::json::Json::num(mig.metrics.migration_ops.max),
        ),
        (
            "migration_bytes",
            xshare::util::json::Json::num(mig.metrics.migration_bytes),
        ),
        (
            "migration_seconds",
            xshare::util::json::Json::num(mig.metrics.migration_seconds),
        ),
        ("prefetches", xshare::util::json::Json::num(mig.metrics.prefetches as f64)),
        (
            "rebalance_delta_mean",
            xshare::util::json::Json::num(mig.metrics.rebalance_delta.mean()),
        ),
    ])
    .dump();
    emit_bench("BENCH_ep_migrate.json", &json);
    println!("[ep-migrate  ] wrote BENCH_ep_migrate.json");
}

// Synthetic-gating admission sim: the general correlated-routing case.
const SIM_N_EXPERTS: usize = 128;
const SIM_TOP_K: usize = 8;
const SIM_N_REQUESTS: usize = 32;
const SIM_SLOTS: usize = 4;
const SIM_STEPS_PER_REQ: usize = 16;
const SIM_SEED: u64 = 2;

struct SimRow {
    stream: RequestGating,
    steps_left: usize,
}

/// Drive the admission components (queue, policy, footprint tracker) over
/// the calibrated synthetic gate-score generator, where same-dataset
/// requests have *correlated* (not identical) routing — the paper's Fig-3
/// structure that the random-weight mini model cannot express. Returns the
/// mean per-step union of top-k experts across the running rows.
fn simulate_admission(kind: AdmissionKind) -> f64 {
    let params = GatingParams::default_for(SIM_N_EXPERTS);
    let dom_a = Domain::new("simA", SIM_N_EXPERTS, 11);
    let dom_b = Domain::new("simB", SIM_N_EXPERTS, 12);
    let mut queue = AdmissionQueue::new(kind, 0);
    let mut tracker = FootprintTracker::new(SIM_N_EXPERTS, SIM_SLOTS);
    // Heterogeneous backlog: requests alternate between the two datasets.
    for id in 0..SIM_N_REQUESTS as u64 {
        let mut r = Request::new(id, vec![1], SIM_STEPS_PER_REQ);
        r.domain = if id % 2 == 0 {
            "simA".into()
        } else {
            "simB".into()
        };
        queue.submit(r, 0.0).expect("unbounded");
    }
    let mut slots: Vec<Option<SimRow>> = (0..SIM_SLOTS).map(|_| None).collect();
    let mut union_sum = 0usize;
    let mut steps = 0usize;
    loop {
        // admission: fill free slots one policy pick at a time
        for slot in 0..SIM_SLOTS {
            if slots[slot].is_some() || queue.is_empty() {
                continue;
            }
            let running: Vec<usize> =
                (0..SIM_SLOTS).filter(|&s| slots[s].is_some()).collect();
            let ctx = AdmissionContext {
                now_sim: steps as f64,
                tracker: (kind == AdmissionKind::FootprintAware).then_some(&tracker),
                running_slots: &running,
                placement: None,
                top_k: SIM_TOP_K,
                spec: None,
                prefix: None,
            };
            let Some(entry) = queue.pop_next(&ctx) else { break };
            tracker.on_admit(slot, &entry.req);
            let dom = if entry.req.domain == "simA" {
                &dom_a
            } else {
                &dom_b
            };
            slots[slot] = Some(SimRow {
                stream: RequestGating::new(params.clone(), dom, SIM_SEED ^ entry.req.id),
                steps_left: SIM_STEPS_PER_REQ,
            });
        }
        if slots.iter().all(|s| s.is_none()) {
            break;
        }
        // one decode step: vanilla top-k per row, union = activated experts
        let mut union = ExpertSet::empty(SIM_N_EXPERTS);
        for slot in 0..SIM_SLOTS {
            let Some(row) = slots[slot].as_mut() else { continue };
            let mut scores = row.stream.next_logits();
            for j in topk_indices(&scores, SIM_TOP_K) {
                union.insert(j);
            }
            softmax_in_place(&mut scores);
            tracker.observe_row(slot, &scores);
            row.steps_left -= 1;
            if row.steps_left == 0 {
                slots[slot] = None;
                tracker.release(slot);
            }
        }
        union_sum += union.len();
        steps += 1;
    }
    union_sum as f64 / steps as f64
}

/// **Correlated-routing admission sim**: same admission machinery, scores
/// from the calibrated generator instead of the mini model.
fn admission_sim_scenario() {
    println!(
        "\n# admission sim — correlated routing (gen::gating, N={SIM_N_EXPERTS}, \
         k={SIM_TOP_K}, {SIM_N_REQUESTS} reqs × {SIM_STEPS_PER_REQ} steps, \
         {SIM_SLOTS} slots)"
    );
    let fifo = simulate_admission(AdmissionKind::Fifo);
    let fp = simulate_admission(AdmissionKind::FootprintAware);
    let mut table = Table::new(&["admission", "mean union top-k / step"]);
    table.row(&["fifo".into(), fmt(fifo, 2)]);
    table.row(&["footprint".into(), fmt(fp, 2)]);
    table.print("serve_continuous — admission under correlated routing (simulated)");
    println!(
        "[admission sim] footprint vs fifo: union/step {:+.1}%",
        pct(fp, fifo)
    );
    assert!(
        fp < fifo,
        "footprint admission must shrink the per-step expert union under \
         domain-correlated routing ({fp} vs {fifo})"
    );
}

// Fleet scenario (PR 9): N replica serve loops, footprint-affine routing
// vs class-blind round-robin on a heterogeneous two-template burst.
const FLEET_REPLICAS: usize = 2;
const FLEET_N_REQUESTS: usize = 24;
const FLEET_MAX_NEW: usize = 10;

/// The admission scenario's two templated classes, but in a PAIRS pattern
/// (ids 0,1 → tplA; 2,3 → tplB; 4,5 → tplA; …). The pairing matters: with
/// a strictly alternating A,B,A,B trace, round-robin at N=2 would unmix
/// the classes *by parity accident* and tie the affinity arm. Pairs make
/// the baseline honest — class-blind rotation splits EVERY class across
/// BOTH replicas, while rendezvous affinity separates them purely.
/// Priorities double as TTFT class labels (tplA=0, tplB=1) so the merged
/// fleet metrics report per-class TTFT directly.
fn fleet_template_requests() -> Vec<Request> {
    let tpl_a: Vec<u32> = vec![70, 75, 80, 72, 78, 74];
    let tpl_b: Vec<u32> = vec![430, 436, 440, 433, 428, 438];
    (0..FLEET_N_REQUESTS as u64)
        .map(|id| {
            let (prompt, domain, priority) = if id % 4 < 2 {
                (tpl_a.clone(), "tplA", 0)
            } else {
                (tpl_b.clone(), "tplB", 1)
            };
            let mut r = Request::new(id, prompt, FLEET_MAX_NEW);
            r.domain = domain.into();
            r.priority = priority;
            r
        })
        .collect()
}

/// Serve the fleet template burst under one routing mode: all requests
/// submitted at sim t=0 (burst backlog — routing decides everything),
/// then drained to completion.
fn serve_fleet(affinity: &str) -> (xshare::fleet::FleetReport, BTreeMap<u64, Vec<u32>>) {
    let mut cfg = base_cfg("vanilla");
    cfg.batch_size = ADM_BATCH;
    cfg.max_new_tokens = FLEET_MAX_NEW;
    cfg.fleet_replicas = FLEET_REPLICAS;
    cfg.fleet_affinity = xshare::fleet::AffinityMode::parse(affinity).expect("affinity");
    let dir = xshare::runtime::artifacts_root().join(PRESET);
    let mut fleet = xshare::fleet::Fleet::from_preset_dir(&dir, &cfg).expect("fleet");
    for r in fleet_template_requests() {
        fleet.submit(r).expect("live fleet").expect("unbounded queue");
    }
    fleet.drain().expect("drain");
    let report = fleet.report().expect("report");
    let outputs = fleet.outputs().clone();
    (report, outputs)
}

/// **Fleet scenario** (real model, N real serve loops on threads): on a
/// heterogeneous two-template burst, footprint-affine routing must beat
/// class-blind round-robin at equal replica count on BOTH aggregate
/// throughput and per-class TTFT — same-class requests share expert
/// footprints, so keeping a class on its home replica keeps each
/// replica's per-step activated-expert union narrow, while round-robin
/// doubles every batch's union by mixing the templates. Vanilla routing
/// is row-independent, so outputs are byte-identical across routing
/// modes (and to a single serve loop) — the win is pure locality.
fn fleet_scenario(model: &mut MoeModel) {
    println!(
        "\n# fleet — footprint-affine routing vs round-robin \
         ({FLEET_REPLICAS} replicas, {FLEET_N_REQUESTS} reqs, B={ADM_BATCH}, \
         vanilla routing, burst backlog)"
    );
    // The two classes must have DISTINCT rendezvous homes at this replica
    // count, or the comparison measures nothing (pinned in fleet::router
    // unit tests too — this guards the bench against key/score drift).
    let home_a = xshare::fleet::FleetRouter::preferred("tplA", FLEET_REPLICAS);
    let home_b = xshare::fleet::FleetRouter::preferred("tplB", FLEET_REPLICAS);
    assert_ne!(home_a, home_b, "bench classes must map to distinct replicas");

    // Single-loop probe: the byte-identity reference.
    let mut cfg = base_cfg("vanilla");
    cfg.batch_size = ADM_BATCH;
    cfg.max_new_tokens = FLEET_MAX_NEW;
    let probe = Scheduler::new(model, cfg)
        .expect("probe scheduler")
        .run(fleet_template_requests())
        .expect("probe run");

    let (aff, aff_out) = serve_fleet("class");
    let (rr, rr_out) = serve_fleet("round-robin");

    assert_eq!(
        aff_out, probe.outputs,
        "fleet (class affinity) outputs diverged from the single serve loop"
    );
    assert_eq!(
        rr_out, probe.outputs,
        "fleet (round-robin) outputs diverged from the single serve loop"
    );

    let ttft_class = |m: &xshare::metrics::ServeMetrics, class: u32| {
        m.ttft_by_class.get(&class).map(|s| s.mean()).unwrap_or(f64::NAN)
    };
    let mut table = Table::new(&[
        "routing",
        "tokens",
        "makespan_s",
        "otps",
        "activated/layer/step",
        "ttft_tplA_s",
        "ttft_tplB_s",
        "spills",
        "failovers",
    ]);
    for (name, r) in [("class-affine", &aff), ("round-robin", &rr)] {
        let m = &r.aggregate;
        table.row(&[
            name.to_string(),
            m.tokens_out.to_string(),
            fmt(m.sim_seconds, 4),
            fmt(m.otps(), 1),
            fmt(m.mean_activated(), 2),
            fmt(ttft_class(m, 0), 4),
            fmt(ttft_class(m, 1), 4),
            r.spills.to_string(),
            r.failovers.to_string(),
        ]);
    }
    table.print("serve_continuous — fleet routing, two-template burst");
    println!(
        "[fleet       ] class-affine vs round-robin: aggregate otps {:+.1}%, \
         ttft tplA {:+.1}%, ttft tplB {:+.1}%",
        pct(aff.aggregate.otps(), rr.aggregate.otps()),
        pct(ttft_class(&aff.aggregate, 0), ttft_class(&rr.aggregate, 0)),
        pct(ttft_class(&aff.aggregate, 1), ttft_class(&rr.aggregate, 1)),
    );

    // The tentpole claims, asserted: strictly higher aggregate throughput
    // AND strictly lower same-class TTFT for both classes, at equal
    // replica count, with byte-identical outputs (checked above).
    assert!(
        aff.aggregate.otps() > rr.aggregate.otps(),
        "class-affine routing must beat round-robin on aggregate OTPS \
         ({} vs {})",
        aff.aggregate.otps(),
        rr.aggregate.otps()
    );
    for class in [0u32, 1] {
        assert!(
            ttft_class(&aff.aggregate, class) < ttft_class(&rr.aggregate, class),
            "class-affine routing must beat round-robin on class-{class} TTFT \
             ({} vs {})",
            ttft_class(&aff.aggregate, class),
            ttft_class(&rr.aggregate, class)
        );
    }

    // Compact per-arm rollup (full per-replica detail stays available via
    // FleetReport::to_json; the snapshot keeps the headline numbers flat
    // and reviewable like the other BENCH_*.json artifacts).
    use xshare::util::json::Json;
    let arm = |r: &xshare::fleet::FleetReport| {
        Json::obj(vec![
            ("tokens", Json::num(r.aggregate.tokens_out as f64)),
            ("makespan_s", Json::num(r.aggregate.sim_seconds)),
            ("otps", Json::num(r.aggregate.otps())),
            ("activated_mean", Json::num(r.aggregate.mean_activated())),
            ("ttft_tplA_s", Json::num(ttft_class(&r.aggregate, 0))),
            ("ttft_tplB_s", Json::num(ttft_class(&r.aggregate, 1))),
            ("spills", Json::num(r.spills as f64)),
            ("failovers", Json::num(r.failovers as f64)),
            (
                "per_replica_requests_done",
                Json::arr(
                    r.replicas.iter().map(|p| Json::num(p.requests_done as f64)),
                ),
            ),
        ])
    };
    let json = Json::obj(vec![
        ("scenario", Json::str("fleet_routing")),
        ("preset", Json::str(PRESET)),
        ("replicas", Json::num(FLEET_REPLICAS as f64)),
        ("requests", Json::num(FLEET_N_REQUESTS as f64)),
        ("batch", Json::num(ADM_BATCH as f64)),
        ("max_new_tokens", Json::num(FLEET_MAX_NEW as f64)),
        ("otps_gain_pct", Json::num(pct(aff.aggregate.otps(), rr.aggregate.otps()))),
        (
            "ttft_tplA_delta_pct",
            Json::num(pct(ttft_class(&aff.aggregate, 0), ttft_class(&rr.aggregate, 0))),
        ),
        (
            "ttft_tplB_delta_pct",
            Json::num(pct(ttft_class(&aff.aggregate, 1), ttft_class(&rr.aggregate, 1))),
        ),
        ("class_affine", arm(&aff)),
        ("round_robin", arm(&rr)),
    ])
    .dump();
    emit_bench("BENCH_fleet.json", &json);
    println!("[fleet       ] wrote BENCH_fleet.json");
}

fn main() {
    // Scenario filter: `cargo bench --bench serve_continuous -- spec`
    // runs only the mixed-phase speculation scenario, `-- spec_charge`
    // the charge-aware depth scenario, `-- ep` the two expert-parallel
    // scenarios, `-- prefix` the shared-prefix cache scenario,
    // `-- prefill_fused` the fused prefill-wave scenario, and `-- fleet`
    // the fleet-routing scenario (CI executes the filters and uploads
    // BENCH_spec.json / BENCH_spec_charge.json / BENCH_ep_serve.json /
    // BENCH_ep_migrate.json / BENCH_prefix.json / BENCH_prefill_fused.json
    // / BENCH_fleet.json); no filter runs everything. `--write-bench <dir>`
    // additionally mirrors every emitted BENCH_*.json into `<dir>` — the
    // recipe for refreshing the reference snapshots under `benchmarks/`.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut only: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        if argv[i] == "--write-bench" {
            let dir = argv.get(i + 1).expect("--write-bench needs a directory");
            WRITE_BENCH_DIR
                .set(std::path::PathBuf::from(dir))
                .expect("--write-bench given twice");
            i += 2;
            continue;
        }
        if !argv[i].starts_with("--") && only.is_none() {
            only = Some(argv[i].clone());
        }
        i += 1;
    }
    if only.as_deref() == Some("spec") {
        spec_mixed_phase_scenario();
        return;
    }
    if only.as_deref() == Some("spec_charge") {
        spec_charge_scenario();
        return;
    }
    if only.as_deref() == Some("ep") {
        let mut model = load_model(PRESET);
        ep_serve_scenario(&mut model);
        ep_migrate_scenario(&mut model);
        return;
    }
    if only.as_deref() == Some("prefix") {
        prefix_shared_cache_scenario();
        return;
    }
    if only.as_deref() == Some("prefill_fused") {
        let mut model = load_model(PRESET);
        prefill_fused_scenario(&mut model);
        return;
    }
    if only.as_deref() == Some("fleet") {
        let mut model = load_model(PRESET);
        fleet_scenario(&mut model);
        return;
    }
    println!(
        "# serve_continuous — Poisson arrivals, staggered lengths \
         ({PRESET}, B={BATCH_SIZE}, {N_REQUESTS} requests)"
    );
    let mut model = load_model(PRESET);
    let vocab = model.dims().vocab;
    let mut arrivals = arrival_trace(vocab);

    // Calibrate the arrival window against the upfront busy time so the
    // arrival process actually overlaps serving (cost-model agnostic).
    let upfront_reqs: Vec<Request> = arrivals.iter().map(|(_, r)| r.clone()).collect();
    let probe = Scheduler::new(&mut model, base_cfg("vanilla"))
        .expect("probe scheduler")
        .run(upfront_reqs.clone())
        .expect("probe run");
    let busy = probe.metrics.sim_seconds;
    let t_last = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0).max(1e-12);
    let scale = ARRIVAL_WINDOW_FRAC * busy / t_last;
    for (t, _) in arrivals.iter_mut() {
        *t *= scale;
    }
    println!(
        "(calibration: upfront busy {busy:.4}s sim → arrival window {:.4}s)",
        ARRIVAL_WINDOW_FRAC * busy
    );

    let mut table = Table::new(&[
        "policy",
        "mode",
        "tokens",
        "makespan_s",
        "otps",
        "ttft_mean_s",
        "queue_wait_s",
        "in_flight_adm",
    ]);
    for policy in ["vanilla", "batch:24:1"] {
        let cfg = base_cfg(policy);
        let cont = serve_continuous(&mut model, &cfg, &arrivals);
        let bat = serve_batched(&mut model, &cfg, &arrivals);

        if policy == "vanilla" {
            // Vanilla rows are independent, so serving mode must not change
            // any request's tokens — the refactor's fidelity guarantee.
            assert_eq!(
                cont.outputs, bat.outputs,
                "continuous vs batch-at-a-time outputs diverged under vanilla"
            );
            assert_eq!(
                probe.outputs, cont.outputs,
                "upfront (seed scheduler) vs continuous outputs diverged under vanilla"
            );
        }

        for (mode, r) in [("continuous", &cont), ("batch-at-a-time", &bat)] {
            table.row(&[
                policy.to_string(),
                mode.to_string(),
                r.tokens.to_string(),
                fmt(r.makespan_s, 4),
                fmt(r.otps(), 1),
                fmt(r.ttft_mean_s, 4),
                fmt(r.queue_wait_mean_s, 4),
                r.admitted_in_flight.to_string(),
            ]);
        }
        println!(
            "[{policy:<12}] continuous vs batch-at-a-time: throughput {:+.1}%, \
             mean TTFT {:+.1}%, mean queue wait {:+.1}%",
            pct(cont.otps(), bat.otps()),
            pct(cont.ttft_mean_s, bat.ttft_mean_s),
            pct(cont.queue_wait_mean_s, bat.queue_wait_mean_s),
        );
        assert!(
            cont.otps() >= bat.otps(),
            "continuous admission should not lose throughput under staggered \
             Poisson arrivals ({policy}: {} vs {})",
            cont.otps(),
            bat.otps()
        );
    }
    table.print("serve_continuous — continuous admission vs gather-batch worker");

    long_prompt_scenario(&mut model);
    prefill_fused_scenario(&mut model);
    admission_scenario(&mut model);
    ep_serve_scenario(&mut model);
    ep_migrate_scenario(&mut model);
    admission_sim_scenario();
    spec_mixed_phase_scenario();
    spec_charge_scenario();
    prefix_shared_cache_scenario();
    fleet_scenario(&mut model);
}
