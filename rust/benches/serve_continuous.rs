//! **Continuous-batching serving bench**: throughput and latency under
//! Poisson arrivals with staggered request lengths — stepped continuous
//! admission (the live TCP worker's path) vs the old gather-window
//! batch-at-a-time worker — across vanilla routing and XShare Algorithm 2.
//!
//! Both modes are driven on the *simulated* clock (memsim H100 cost model),
//! so results are deterministic and hardware-honest: the batch-at-a-time
//! worker idles freed slots on straggler requests and makes late arrivals
//! wait for the whole batch to drain; the stepped core admits them at the
//! next decode step. Same requests, same arrival process, same policies.
//!
//!   make artifacts && cargo bench --bench serve_continuous

#[path = "common/mod.rs"]
mod common;

use std::collections::{BTreeMap, VecDeque};

use common::{fmt, load_model, pct, Table};
use xshare::config::ServeConfig;
use xshare::coordinator::{Request, Scheduler, ServeLoop};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::selection::PolicyKind;

const PRESET: &str = "gptoss-mini";
const N_REQUESTS: usize = 32;
const BATCH_SIZE: usize = 8;
const SEED: u64 = 17;
/// Arrivals are rescaled so the last request lands at this fraction of the
/// upfront-vanilla busy time: the system stays loaded, but stragglers and
/// late joiners dominate the tail.
const ARRIVAL_WINDOW_FRAC: f64 = 0.7;

// Long-prompt scenario (chunked prefill): prompts dominate the lifetime of
// a request, which is exactly where TTFT dies under one-token prefill.
const LONG_PROMPT_LEN: usize = 48;
const LONG_N_REQUESTS: usize = 12;
const LONG_MAX_NEW: usize = 8;
/// One chunk per step; gptoss-mini's chunk capacity is its max_batch (16).
const PREFILL_CHUNK: usize = 16;

fn base_cfg(policy: &str) -> ServeConfig {
    ServeConfig {
        preset: PRESET.into(),
        policy: PolicyKind::parse(policy).expect("policy"),
        batch_size: BATCH_SIZE,
        max_new_tokens: 12,
        ..Default::default()
    }
}

/// Poisson arrival trace with heterogeneous ("staggered") request lengths
/// straight from the domain mix: (arrival sim-seconds, request).
fn arrival_trace(vocab: usize) -> Vec<(f64, Request)> {
    let mut g = TraceGenerator::new(vocab, SEED);
    g.arrival_rate = 1.0; // unit-rate; timestamps are rescaled below
    g.generate(&TraceDomain::standard_suite(), N_REQUESTS)
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(6);
            let mut r = Request::new(t.id, prompt, t.max_new_tokens.clamp(2, 12));
            r.domain = t.domain;
            (t.arrival_s, r)
        })
        .collect()
}

struct ModeResult {
    outputs: BTreeMap<u64, Vec<u32>>,
    tokens: u64,
    makespan_s: f64,
    ttft_mean_s: f64,
    queue_wait_mean_s: f64,
    admitted_in_flight: u64,
}

impl ModeResult {
    fn otps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.makespan_s
        }
    }
}

/// Stepped continuous serving: requests are submitted the moment the sim
/// clock passes their arrival time; every step admits into free slots.
fn serve_continuous(
    model: &mut MoeModel,
    cfg: &ServeConfig,
    arrivals: &[(f64, Request)],
) -> ModeResult {
    let mut core = ServeLoop::new(model, cfg.clone()).expect("serve loop");
    let mut idle = 0.0f64; // sim-time spent with no work at all
    let mut idx = 0;
    while idx < arrivals.len() || core.has_work() {
        let now = core.metrics().sim_seconds + idle;
        while idx < arrivals.len() && arrivals[idx].0 <= now + 1e-9 {
            core.submit(arrivals[idx].1.clone());
            idx += 1;
        }
        if core.has_work() {
            core.step().expect("step");
        } else {
            // fast-forward an empty system to the next arrival
            idle += arrivals[idx].0 - now;
        }
    }
    let makespan_s = core.metrics().sim_seconds + idle;
    let report = core.report();
    ModeResult {
        tokens: report.metrics.tokens_out,
        makespan_s,
        ttft_mean_s: report.metrics.ttft.mean(),
        queue_wait_mean_s: report.metrics.queue_wait.mean(),
        admitted_in_flight: report.metrics.admitted_in_flight,
        outputs: report.outputs,
    }
}

/// The old worker, emulated on the sim clock: gather everything that has
/// arrived (up to batch_size), run the batch to completion, only then look
/// at the queue again.
fn serve_batched(
    model: &mut MoeModel,
    cfg: &ServeConfig,
    arrivals: &[(f64, Request)],
) -> ModeResult {
    let mut clock = 0.0f64;
    let mut idx = 0;
    let mut queue: VecDeque<(f64, Request)> = VecDeque::new();
    let mut outputs = BTreeMap::new();
    let mut tokens = 0u64;
    let mut ttft_sum = 0.0f64;
    let mut wait_sum = 0.0f64;
    let mut n_served = 0usize;
    while idx < arrivals.len() || !queue.is_empty() {
        while idx < arrivals.len() && arrivals[idx].0 <= clock + 1e-9 {
            queue.push_back(arrivals[idx].clone());
            idx += 1;
        }
        if queue.is_empty() {
            clock = arrivals[idx].0;
            continue;
        }
        let take = queue.len().min(cfg.batch_size);
        let batch: Vec<(f64, Request)> = queue.drain(..take).collect();
        let reqs: Vec<Request> = batch.iter().map(|(_, r)| r.clone()).collect();
        let report = Scheduler::new(model, cfg.clone())
            .expect("scheduler")
            .run(reqs)
            .expect("run");
        // Request-level latency = time queued before the batch started +
        // first-token latency inside the batch run.
        for (arr, _) in &batch {
            wait_sum += clock - arr;
        }
        ttft_sum += report.metrics.ttft.sum + batch.iter().map(|(a, _)| clock - a).sum::<f64>();
        n_served += batch.len();
        tokens += report.metrics.tokens_out;
        clock += report.metrics.sim_seconds;
        outputs.extend(report.outputs);
    }
    ModeResult {
        outputs,
        tokens,
        makespan_s: clock,
        ttft_mean_s: if n_served == 0 { 0.0 } else { ttft_sum / n_served as f64 },
        queue_wait_mean_s: if n_served == 0 { 0.0 } else { wait_sum / n_served as f64 },
        admitted_in_flight: 0,
    }
}

/// Poisson arrivals with long uniform prompts (prompt-heavy workload).
fn long_prompt_trace(vocab: usize) -> Vec<(f64, Request)> {
    let mut g = TraceGenerator::new(vocab, SEED + 1);
    g.arrival_rate = 1.0;
    g.generate(&TraceDomain::standard_suite(), LONG_N_REQUESTS)
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            while prompt.len() < LONG_PROMPT_LEN {
                let fill = (prompt.len() as u64 * 7 + t.id * 13) % vocab as u64;
                prompt.push(fill as u32);
            }
            prompt.truncate(LONG_PROMPT_LEN);
            let mut r = Request::new(t.id, prompt, LONG_MAX_NEW);
            r.domain = t.domain;
            (t.arrival_s, r)
        })
        .collect()
}

/// Long-prompt TTFT scenario: the stepped loop with chunked prefill vs the
/// same loop walking prompts one token per step. Same Poisson arrivals,
/// same policies; under vanilla the outputs must additionally be
/// byte-identical (chunking is an execution optimisation only).
fn long_prompt_scenario(model: &mut MoeModel) {
    println!(
        "\n# long-prompt TTFT — chunked (T={PREFILL_CHUNK}) vs one-token prefill \
         ({LONG_N_REQUESTS} reqs × {LONG_PROMPT_LEN}-token prompts, {LONG_MAX_NEW} new)"
    );
    let vocab = model.dims().vocab;
    let mut arrivals = long_prompt_trace(vocab);

    // calibrate the window against the unchunked vanilla busy time
    let mut probe_cfg = base_cfg("vanilla");
    probe_cfg.max_new_tokens = LONG_MAX_NEW;
    let probe_reqs: Vec<Request> = arrivals.iter().map(|(_, r)| r.clone()).collect();
    let probe = Scheduler::new(model, probe_cfg)
        .expect("probe scheduler")
        .run(probe_reqs)
        .expect("probe run");
    let busy = probe.metrics.sim_seconds;
    let t_last = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0).max(1e-12);
    let scale = ARRIVAL_WINDOW_FRAC * busy / t_last;
    for (t, _) in arrivals.iter_mut() {
        *t *= scale;
    }

    let mut table = Table::new(&[
        "policy",
        "prefill",
        "tokens",
        "prompt_toks",
        "makespan_s",
        "ttft_mean_s",
        "ttft_delta",
    ]);
    for policy in ["vanilla", "batch:24:1"] {
        let mut unchunked_cfg = base_cfg(policy);
        unchunked_cfg.max_new_tokens = LONG_MAX_NEW;
        let mut chunked_cfg = unchunked_cfg.clone();
        chunked_cfg.prefill_chunk = PREFILL_CHUNK;

        let un = serve_continuous(model, &unchunked_cfg, &arrivals);
        let ch = serve_continuous(model, &chunked_cfg, &arrivals);

        if policy == "vanilla" {
            assert_eq!(
                un.outputs, ch.outputs,
                "chunked prefill changed generated tokens under vanilla routing"
            );
        }
        assert!(
            ch.ttft_mean_s < un.ttft_mean_s,
            "chunked prefill must cut simulated TTFT ({policy}: {} vs {})",
            ch.ttft_mean_s,
            un.ttft_mean_s
        );

        let rows: [(String, &ModeResult, String); 2] = [
            ("1/step".into(), &un, "-".into()),
            (
                format!("{PREFILL_CHUNK}/step"),
                &ch,
                format!("{:+.1}%", pct(ch.ttft_mean_s, un.ttft_mean_s)),
            ),
        ];
        for (mode, r, delta) in &rows {
            table.row(&[
                policy.to_string(),
                mode.clone(),
                r.tokens.to_string(),
                (LONG_N_REQUESTS * LONG_PROMPT_LEN).to_string(),
                fmt(r.makespan_s, 4),
                fmt(r.ttft_mean_s, 4),
                delta.clone(),
            ]);
        }
        println!(
            "[{policy:<12}] chunked vs one-token: mean TTFT {:+.1}%, makespan {:+.1}%",
            pct(ch.ttft_mean_s, un.ttft_mean_s),
            pct(ch.makespan_s, un.makespan_s),
        );
    }
    table.print("serve_continuous — long-prompt chunked prefill TTFT");
}

fn main() {
    println!(
        "# serve_continuous — Poisson arrivals, staggered lengths \
         ({PRESET}, B={BATCH_SIZE}, {N_REQUESTS} requests)"
    );
    let mut model = load_model(PRESET);
    let vocab = model.dims().vocab;
    let mut arrivals = arrival_trace(vocab);

    // Calibrate the arrival window against the upfront busy time so the
    // arrival process actually overlaps serving (cost-model agnostic).
    let upfront_reqs: Vec<Request> = arrivals.iter().map(|(_, r)| r.clone()).collect();
    let probe = Scheduler::new(&mut model, base_cfg("vanilla"))
        .expect("probe scheduler")
        .run(upfront_reqs.clone())
        .expect("probe run");
    let busy = probe.metrics.sim_seconds;
    let t_last = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0).max(1e-12);
    let scale = ARRIVAL_WINDOW_FRAC * busy / t_last;
    for (t, _) in arrivals.iter_mut() {
        *t *= scale;
    }
    println!(
        "(calibration: upfront busy {busy:.4}s sim → arrival window {:.4}s)",
        ARRIVAL_WINDOW_FRAC * busy
    );

    let mut table = Table::new(&[
        "policy",
        "mode",
        "tokens",
        "makespan_s",
        "otps",
        "ttft_mean_s",
        "queue_wait_s",
        "in_flight_adm",
    ]);
    for policy in ["vanilla", "batch:24:1"] {
        let cfg = base_cfg(policy);
        let cont = serve_continuous(&mut model, &cfg, &arrivals);
        let bat = serve_batched(&mut model, &cfg, &arrivals);

        if policy == "vanilla" {
            // Vanilla rows are independent, so serving mode must not change
            // any request's tokens — the refactor's fidelity guarantee.
            assert_eq!(
                cont.outputs, bat.outputs,
                "continuous vs batch-at-a-time outputs diverged under vanilla"
            );
            assert_eq!(
                probe.outputs, cont.outputs,
                "upfront (seed scheduler) vs continuous outputs diverged under vanilla"
            );
        }

        for (mode, r) in [("continuous", &cont), ("batch-at-a-time", &bat)] {
            table.row(&[
                policy.to_string(),
                mode.to_string(),
                r.tokens.to_string(),
                fmt(r.makespan_s, 4),
                fmt(r.otps(), 1),
                fmt(r.ttft_mean_s, 4),
                fmt(r.queue_wait_mean_s, 4),
                r.admitted_in_flight.to_string(),
            ]);
        }
        println!(
            "[{policy:<12}] continuous vs batch-at-a-time: throughput {:+.1}%, \
             mean TTFT {:+.1}%, mean queue wait {:+.1}%",
            pct(cont.otps(), bat.otps()),
            pct(cont.ttft_mean_s, bat.ttft_mean_s),
            pct(cont.queue_wait_mean_s, bat.queue_wait_mean_s),
        );
        assert!(
            cont.otps() >= bat.otps(),
            "continuous admission should not lose throughput under staggered \
             Poisson arrivals ({policy}: {} vs {})",
            cont.otps(),
            bat.otps()
        );
    }
    table.print("serve_continuous — continuous admission vs gather-batch worker");

    long_prompt_scenario(&mut model);
}
