//! Ablations called out in DESIGN.md §6:
//!   A. warm-up depth k0 sweep at fixed greedy budget (Algorithm 2);
//!   B. EP placement policy (contiguous / round-robin / random) under
//!      Algorithm 6;
//!   C. batch-size sweep under speculation (paper App. B mention);
//!   D. baseline comparison — LYNX-Lat, Dynamic-Skipping, Opportunistic vs
//!      Algorithm 2 at comparable activation levels.

#[path = "common/mod.rs"]
mod common;

use common::{domain_requests, load_model, pct, sweep, Table};
use xshare::config::{EpConfig, ServeConfig};
use xshare::ep::PlacementKind;

fn main() {
    let mut model = load_model("gptoss-mini");
    let vocab = model.dims().vocab;

    // ---- A: warm-up sweep ------------------------------------------------
    {
        let cfg = ServeConfig {
            preset: "gptoss-mini".into(),
            batch_size: 16,
            max_new_tokens: 8,
            ..Default::default()
        };
        let reqs = domain_requests("gpqa", vocab, 16, 10, 8, 11);
        let policies =
            ["vanilla", "batch:12:0", "batch:12:1", "batch:12:2", "batch:12:3"];
        let results = sweep(&mut model, &cfg, &policies, &reqs);
        let mut t = Table::new(&["k0 (m=12)", "OTPS", "activated", "fidelity"]);
        for r in &results {
            let fid = r.fidelity.as_ref().map(|f| f.token_match).unwrap_or(1.0);
            t.row(&[
                r.policy.clone(),
                format!("{:.1}", r.report.metrics.otps()),
                format!("{:.1}", r.report.metrics.mean_activated()),
                format!("{:.1}%", fid * 100.0),
            ]);
        }
        t.print("Ablation A — warm-up depth (fidelity should rise with k0)");
        common::save_report("ablation_warmup.csv", &t.to_csv());
    }

    // ---- D: baselines at comparable activation ---------------------------
    {
        let cfg = ServeConfig {
            preset: "gptoss-mini".into(),
            batch_size: 16,
            max_new_tokens: 8,
            ..Default::default()
        };
        let reqs = domain_requests("mmlu-pro", vocab, 16, 10, 8, 13);
        let policies =
            ["vanilla", "batch:16:1", "lynx:16", "skip:0.3", "opp:2"];
        let results = sweep(&mut model, &cfg, &policies, &reqs);
        let mut t = Table::new(&["method", "OTPS", "ΔOTPS", "activated", "fidelity"]);
        let base = results[0].report.metrics.otps();
        for r in &results {
            let fid = r.fidelity.as_ref().map(|f| f.token_match).unwrap_or(1.0);
            t.row(&[
                r.policy.clone(),
                format!("{:.1}", r.report.metrics.otps()),
                format!("{:+.1}%", pct(r.report.metrics.otps(), base)),
                format!("{:.1}", r.report.metrics.mean_activated()),
                format!("{:.1}%", fid * 100.0),
            ]);
        }
        t.print("Ablation D — baselines (Lynx/Dynamic-Skip/Opportunistic) vs Algorithm 2");
        common::save_report("ablation_baselines.csv", &t.to_csv());
    }

    // ---- C: batch-size sweep under speculation ----------------------------
    {
        let mut t = Table::new(&["BS", "policy", "OTPS", "activated", "fidelity"]);
        for bs in [2usize, 4, 8] {
            let cfg = ServeConfig {
                preset: "gptoss-mini".into(),
                batch_size: bs,
                spec_len: 3,
                max_new_tokens: 6,
                ..Default::default()
            };
            let reqs = domain_requests("aime2025", vocab, bs, 8, 6, 17);
            let results = sweep(&mut model, &cfg, &["vanilla", "spec:1:0:4"], &reqs);
            for r in &results {
                let fid = r.fidelity.as_ref().map(|f| f.token_match).unwrap_or(1.0);
                t.row(&[
                    bs.to_string(),
                    r.policy.clone(),
                    format!("{:.1}", r.report.metrics.otps()),
                    format!("{:.1}", r.report.metrics.mean_activated()),
                    format!("{:.1}%", fid * 100.0),
                ]);
            }
        }
        t.print("Ablation C — batch-size sweep under speculation (App. B)");
        common::save_report("ablation_bs_spec.csv", &t.to_csv());
    }

    // ---- B: EP placement (dsr1-mini) --------------------------------------
    {
        let mut ep_model = load_model("dsr1-mini");
        let vocab = ep_model.dims().vocab;
        let mut t = Table::new(&["placement", "activated", "max/GPU", "sim-otps"]);
        for (name, kind) in [
            ("contiguous", PlacementKind::Contiguous),
            ("round_robin", PlacementKind::RoundRobin),
            ("random:1", PlacementKind::Random(1)),
        ] {
            let cfg = ServeConfig {
                preset: "dsr1-mini".into(),
                batch_size: 8,
                max_new_tokens: 6,
                ep: Some(EpConfig { n_gpus: 8, placement: kind }),
                ..Default::default()
            };
            let reqs = domain_requests("ifeval", vocab, 8, 8, 6, 19);
            let results = sweep(&mut ep_model, &cfg, &["gpu:1:5"], &reqs);
            let m = &results[0].report.metrics;
            t.row(&[
                name.to_string(),
                format!("{:.1}", m.mean_activated()),
                format!("{:.2}", m.max_gpu_load.mean()),
                format!("{:.1}", m.otps()),
            ]);
        }
        t.print("Ablation B — expert placement under Algorithm 6 (G=8)");
        common::save_report("ablation_placement.csv", &t.to_csv());
    }
}
