//! Shared harness for the paper-reproduction benches: model loading, trace
//! construction, policy sweeps and table emission. Each bench binary
//! regenerates one table/figure of the paper (see DESIGN.md §6).

#![allow(dead_code)]
#![allow(unused_imports)]

use std::collections::BTreeMap;

use xshare::config::ServeConfig;
use xshare::coordinator::{compare, Fidelity, Request, RunReport, Scheduler};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

pub use xshare::util::benchkit::{bench, save_report, Table};

pub fn load_model(preset: &str) -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join(preset)).unwrap_or_else(|e| {
        panic!("artifacts for '{preset}' missing ({e:#}) — run `make artifacts`")
    });
    MoeModel::new(Engine::load(manifest).expect("engine load")).expect("model")
}

/// Requests for one domain: `n` requests, prompts truncated to `prompt_len`.
pub fn domain_requests(
    domain: &str,
    vocab: usize,
    n: usize,
    prompt_len: usize,
    max_new: usize,
    seed: u64,
) -> Vec<Request> {
    let d = TraceDomain::by_name(domain).unwrap_or_else(|| panic!("unknown domain {domain}"));
    TraceGenerator::new(vocab, seed)
        .generate(&[d], n)
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(prompt_len.max(1));
            let mut r = Request::new(t.id, prompt, max_new);
            r.domain = t.domain;
            r
        })
        .collect()
}

/// One request from each of the paper's §6.3 mixed datasets.
pub fn mixed_requests(vocab: usize, prompt_len: usize, max_new: usize, seed: u64) -> Vec<Request> {
    TraceGenerator::new(vocab, seed)
        .mixed_batch()
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(prompt_len.max(1));
            let mut r = Request::new(t.id, prompt, max_new);
            r.domain = t.domain;
            r
        })
        .collect()
}

pub struct SweepResult {
    pub policy: String,
    pub report: RunReport,
    pub fidelity: Option<Fidelity>,
}

/// Run `policies` (strings) over the same requests; the first is the
/// baseline all others are compared against.
pub fn sweep(
    model: &mut MoeModel,
    base_cfg: &ServeConfig,
    policies: &[&str],
    requests: &[Request],
) -> Vec<SweepResult> {
    let mut results: Vec<SweepResult> = Vec::new();
    let mut baseline: Option<BTreeMap<u64, Vec<u32>>> = None;
    for &policy in policies {
        let mut cfg = base_cfg.clone();
        cfg.policy = PolicyKind::parse(policy).expect("policy");
        let report = Scheduler::new(model, cfg)
            .expect("scheduler")
            .run(requests.to_vec())
            .expect("run");
        let fidelity = baseline.as_ref().map(|b| compare(b, &report.outputs));
        if baseline.is_none() {
            baseline = Some(report.outputs.clone());
        }
        results.push(SweepResult { policy: policy.into(), report, fidelity });
    }
    results
}

pub fn pct(new: f64, base: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (new / base - 1.0) * 100.0
    }
}

pub fn fmt(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}
