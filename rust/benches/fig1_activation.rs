//! **Figure 1 + §1 claim**: average number of activated experts vs batch
//! size, measured against the analytic expectation
//! E[N_a] = N · (1 − (1 − k/N)^B).
//!
//! Three series: (a) the closed form, (b) the calibrated score simulator
//! (domain-clustered gating), (c) the real gptoss-mini model under vanilla
//! routing. The paper's §1 anchor points — ≈57 experts at B=8 and ≈163 at
//! B=32 for DeepSeek-R1 geometry (N=256, k=8) — are printed explicitly.

#[path = "common/mod.rs"]
mod common;

use common::{load_model, sweep, Table};
use xshare::config::ServeConfig;
use xshare::gen::{batch_scores, Domain, GatingParams};
use xshare::selection::{topk_indices, ExpertSet};

fn analytic(n: usize, k: usize, b: usize) -> f64 {
    n as f64 * (1.0 - (1.0 - k as f64 / n as f64).powi(b as i32))
}

/// Simulated activation: mean |∪ top-k| over trials of B independent tokens.
fn simulated(n: usize, k: usize, b: usize, trials: u64) -> f64 {
    let params = GatingParams::default_for(n);
    let mut total = 0usize;
    for t in 0..trials {
        // B tokens from B different requests over 4 domains (the paper's
        // multi-dataset serving mix).
        let domains: Vec<Domain> =
            (0..4).map(|d| Domain::new(&format!("d{d}"), n, 77 + d as u64)).collect();
        let refs: Vec<&Domain> = (0..b).map(|i| &domains[i % 4]).collect();
        let (_, probs, _) = batch_scores(&params, &refs, 1, 1000 + t);
        let mut union = ExpertSet::empty(n);
        for i in 0..probs.n_tokens() {
            for j in topk_indices(probs.row(i), k) {
                union.insert(j);
            }
        }
        total += union.len();
    }
    total as f64 / trials as f64
}

fn main() {
    println!("# Figure 1 — activated experts vs batch size");

    for (name, n, k) in [("DeepSeek-R1 (N=256,k=8)", 256, 8), ("GPT-OSS (N=128,k=4)", 128, 4)] {
        let mut table = Table::new(&["B", "analytic E[Na]", "simulated", "frac of N"]);
        for b in [1usize, 2, 4, 8, 16, 32, 64] {
            let a = analytic(n, k, b);
            let s = simulated(n, k, b, 30);
            table.row(&[
                b.to_string(),
                format!("{a:.1}"),
                format!("{s:.1}"),
                format!("{:.0}%", 100.0 * a / n as f64),
            ]);
        }
        table.print(name);
        common::save_report(&format!("fig1_{n}_{k}.csv"), &table.to_csv());
    }

    println!("\n§1 anchor points (N=256, k=8):");
    println!("  B=8  → analytic {:.0} (paper: ≈57)", analytic(256, 8, 8));
    println!("  B=32 → analytic {:.0} (paper: ≈163)", analytic(256, 8, 32));
    println!("§3.1 anchor (fraction of N at B=32/64, N=256):");
    println!(
        "  B=32 → {:.0}%  B=64 → {:.0}%  (paper: 62% / 95%)",
        100.0 * analytic(256, 8, 32) / 256.0,
        100.0 * analytic(256, 8, 64) / 256.0
    );

    // Real-model series: gptoss-mini under vanilla routing.
    println!("\nreal gptoss-mini (vanilla routing, measured mean activated/layer):");
    let mut model = load_model("gptoss-mini");
    let vocab = model.dims().vocab;
    let mut table = Table::new(&["B", "measured", "analytic(128,4)"]);
    for b in [2usize, 4, 8, 16] {
        let cfg = ServeConfig {
            preset: "gptoss-mini".into(),
            batch_size: b,
            max_new_tokens: 6,
            ..Default::default()
        };
        let reqs = common::domain_requests("gpqa", vocab, b, 8, 6, 5);
        let res = sweep(&mut model, &cfg, &["vanilla"], &reqs);
        table.row(&[
            b.to_string(),
            format!("{:.1}", res[0].report.metrics.mean_activated()),
            format!("{:.1}", analytic(128, 4, b)),
        ]);
    }
    table.print("gptoss-mini measured vs analytic");
    common::save_report("fig1_real.csv", &table.to_csv());
}
