//! **Figure 7**: OTPS vs number of activated experts (BS=16, speculation
//! off) — the same Algorithm-2 sweep as Figure 4 plotted along the
//! activation axis. Shape target: OTPS decreases monotonically with
//! activated experts (the memory-bound roofline), and all policy points lie
//! up-left of the vanilla baseline.

#[path = "common/mod.rs"]
mod common;

use common::{domain_requests, load_model, sweep, Table};
use xshare::config::ServeConfig;

fn main() {
    println!("# Figure 7 — OTPS vs activated experts (BS=16, no speculation)");
    let mut model = load_model("gptoss-mini");
    let vocab = model.dims().vocab;
    let cfg = ServeConfig {
        preset: "gptoss-mini".into(),
        batch_size: 16,
        max_new_tokens: 10,
        ..Default::default()
    };
    let policies = [
        "vanilla",
        "batch:0:1",
        "batch:12:1",
        "batch:16:1",
        "batch:24:1",
        "batch:32:1",
        "batch:0:2",
        "batch:12:2",
        "batch:24:0",
    ];
    let reqs = domain_requests("mmlu-pro", vocab, 16, 10, 10, 77);
    let results = sweep(&mut model, &cfg, &policies, &reqs);

    let mut series: Vec<(f64, f64, String)> = results
        .iter()
        .map(|r| {
            (r.report.metrics.mean_activated(), r.report.metrics.otps(), r.policy.clone())
        })
        .collect();
    series.sort_by(|a, b| a.0.total_cmp(&b.0));

    let mut table = Table::new(&["activated/layer", "OTPS", "config"]);
    for (act, otps, policy) in &series {
        table.row(&[format!("{act:.1}"), format!("{otps:.1}"), policy.clone()]);
    }
    table.print("sweep sorted by activation (mmlu-pro)");
    common::save_report("fig7.csv", &table.to_csv());

    // Monotonicity check of the roofline: series is sorted by ascending
    // activation, so OTPS should not *rise* with more activated experts
    // (small noise tolerated).
    let violations = series
        .windows(2)
        .filter(|w| w[1].1 > w[0].1 * 1.05)
        .count();
    println!(
        "\nroofline direction: OTPS falls as activation grows ({violations} violations of {})",
        series.len() - 1
    );
}
