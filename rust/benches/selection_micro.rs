//! L3 micro-benchmarks: the per-layer cost of every selection policy on
//! synthetic score matrices, against the memsim layer time it must undercut.
//!
//! The paper claims its selection adds "one additional top-k call,
//! negligible in a memory-bound regime" — this bench quantifies that for
//! our implementation: policy cost per layer vs the ~350 µs the H100 model
//! charges for one gptoss layer at 99 activated experts.

#[path = "common/mod.rs"]
mod common;

use common::{bench, Table};
use xshare::gen::{batch_scores, Domain, GatingParams};
use xshare::memsim::{CostGeometry, DecodeCostModel, HardwareProfile};
use xshare::selection::{PolicyKind, ScoreMatrix, SelectionContext};
use xshare::ep::{Placement, PlacementKind};

fn make_scores(n_experts: usize, requests: usize, toks_per_req: usize) -> (ScoreMatrix, ScoreMatrix, Vec<Vec<usize>>) {
    let params = GatingParams::default_for(n_experts);
    let domains: Vec<Domain> =
        (0..4).map(|d| Domain::new(&format!("d{d}"), n_experts, d as u64)).collect();
    let refs: Vec<&Domain> = (0..requests).map(|i| &domains[i % 4]).collect();
    batch_scores(&params, &refs, toks_per_req, 9)
}

fn main() {
    println!("# selection_micro — per-layer policy cost (L3 hot path)");

    // gptoss geometry, BS=16 no spec (16 rows) and BS=4 Ls=3 (16 rows, 4 groups)
    let (logits, probs, groups) = make_scores(128, 4, 4);
    let rows: Vec<usize> = (0..probs.n_tokens()).collect();
    let placement = Placement::new(128, 8, PlacementKind::Contiguous);

    let policies = [
        "vanilla",
        "batch:24:1",
        "batch:0:1",
        "spec:1:0:4",
        "gpu:1:5",
        "lynx:16",
        "skip:0.3",
        "opp:2",
    ];

    let mut table = Table::new(&["policy", "mean µs/layer", "|S| selected"]);
    for name in policies {
        let policy = PolicyKind::parse(name).unwrap().build();
        let ctx = SelectionContext {
            probs: &probs,
            logits: &logits,
            rows: &rows,
            requests: &groups,
            colsum_hint: None,
            placement: Some(&placement),
            top_k: 4,
        };
        let sel_size = policy.route(&ctx).n_activated();
        let stats = bench(&format!("route/{name}"), 50, 400, || {
            let ctx = SelectionContext {
                probs: &probs,
                logits: &logits,
                rows: &rows,
                requests: &groups,
                colsum_hint: None,
                placement: Some(&placement),
                top_k: 4,
            };
            policy.route(&ctx)
        });
        table.row(&[
            name.to_string(),
            format!("{:.1}", stats.mean_us()),
            sel_size.to_string(),
        ]);
    }
    table.print("per-layer routing cost (T=16, N=128)");
    common::save_report("selection_micro.csv", &table.to_csv());

    // Compare against the memory-bound layer time the policy must undercut.
    let cost = DecodeCostModel::new(
        HardwareProfile::by_name("h100").unwrap(),
        CostGeometry::for_preset("gptoss-mini").unwrap(),
    );
    let step = cost.target_step(&[99; 36], 16);
    let per_layer_us = step.seconds() / 36.0 * 1e6;
    println!(
        "\nmemsim H100 layer time at 99 activated experts: {per_layer_us:.0} µs — \
         selection must stay well below this (paper: 'negligible')."
    );
}
