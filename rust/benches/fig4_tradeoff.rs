//! **Figure 4 + Table 3**: OTPS improvement vs fidelity change for
//! Algorithm 2 configurations (budget m_l, warm-up k_0) on GPT-OSS
//! geometry, BS=16, speculation off, across three datasets.
//!
//! Paper shape targets: (0,1) fastest but big accuracy loss; (24,1) ≈ +7%
//! OTPS within 1% accuracy; (12,2) mild gain ~no loss; pure-greedy (24,0)
//! fast but lossy. "Accuracy" here is behavioural fidelity vs the vanilla
//! baseline (DESIGN.md §4).

#[path = "common/mod.rs"]
mod common;

use common::{domain_requests, load_model, pct, sweep, Table};
use xshare::config::ServeConfig;

fn main() {
    println!("# Figure 4 / Table 3 — Algorithm 2 trade-off (BS=16, no speculation)");
    let mut model = load_model("gptoss-mini");
    let vocab = model.dims().vocab;
    let cfg = ServeConfig {
        preset: "gptoss-mini".into(),
        batch_size: 16,
        max_new_tokens: 10,
        ..Default::default()
    };
    // (m_l, k0) grid of the paper; policy syntax batch:<m>:<k0>
    let policies = [
        "vanilla",
        "batch:0:1",
        "batch:12:1",
        "batch:16:1",
        "batch:24:1",
        "batch:32:1",
        "batch:0:2",
        "batch:12:2",
        "batch:24:0",
    ];

    for domain in ["aime2025", "gpqa", "mmlu-pro"] {
        let reqs = domain_requests(domain, vocab, 16, 10, 10, 21);
        let results = sweep(&mut model, &cfg, &policies, &reqs);
        let base_otps = results[0].report.metrics.otps();
        let mut table = Table::new(&[
            "config (m,k0)",
            "OTPS",
            "ΔOTPS",
            "activated/layer",
            "fidelity",
            "Δfid pts",
        ]);
        for r in &results {
            let m = &r.report.metrics;
            let (fid, drop) = match &r.fidelity {
                None => (1.0, 0.0),
                Some(f) => (f.token_match, f.accuracy_drop_pts()),
            };
            table.row(&[
                r.policy.clone(),
                format!("{:.1}", m.otps()),
                format!("{:+.1}%", pct(m.otps(), base_otps)),
                format!("{:.1}", m.mean_activated()),
                format!("{:.1}%", fid * 100.0),
                format!("{drop:+.1}"),
            ]);
        }
        table.print(&format!("domain {domain}"));
        common::save_report(&format!("fig4_{domain}.csv"), &table.to_csv());
    }
    println!("\npaper shape: (0,1) largest ΔOTPS with worst fidelity; (24,1) ≈ +7-13%");
    println!("with small drop; k0≥1 configs dominate pure-greedy (m,0) on fidelity.");
}
