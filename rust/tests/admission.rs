//! Admission-subsystem tests: the Fifo-equivalence property pinning the
//! refactor to the pre-refactor admission order, policy-ordering behaviour
//! through a live [`ServeLoop`] on the tiny preset, bounded-queue
//! backpressure, and footprint plumbing (observed router scores reaching
//! the tracker without perturbing served outputs).

use std::collections::VecDeque;

use xshare::config::ServeConfig;
use xshare::coordinator::admission::{AdmissionContext, AdmissionKind, AdmissionQueue};
use xshare::coordinator::{Batcher, Request, ServeLoop, SubmitError};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::util::check::forall;
use xshare::util::rng::Rng;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn tiny_cfg() -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        batch_size: 2,
        max_new_tokens: 4,
        ..Default::default()
    }
}

/// The pre-refactor admission semantics, verbatim: one FIFO queue feeding
/// free slots lowest-index-first, up to `max_running`.
struct LegacyBatcher {
    queue: VecDeque<Request>,
    slots: Vec<Option<u64>>,
    max_running: usize,
}

impl LegacyBatcher {
    fn new(n_slots: usize, max_running: usize) -> LegacyBatcher {
        LegacyBatcher {
            queue: VecDeque::new(),
            slots: (0..n_slots).map(|_| None).collect(),
            max_running,
        }
    }

    fn running(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Fill free slots from the queue; (request id, slot) pairs in
    /// admission order — the linear scan the seed implementation used.
    fn admit(&mut self) -> Vec<(u64, usize)> {
        let mut admitted = Vec::new();
        while self.running() < self.max_running && !self.queue.is_empty() {
            let slot = self
                .slots
                .iter()
                .position(|s| s.is_none())
                .expect("running < max_running implies a free slot");
            let req = self.queue.pop_front().unwrap();
            admitted.push((req.id, slot));
            self.slots[slot] = Some(req.id);
        }
        admitted
    }

    fn release(&mut self, slot: usize) {
        assert!(self.slots[slot].take().is_some());
    }
}

/// ACCEPTANCE: under the default Fifo policy, the new admission stack
/// (AdmissionQueue + policy pick + Batcher::place) admits exactly the same
/// requests into exactly the same slots as the pre-refactor hard-coded
/// queue, across arbitrary submit/admit/release interleavings.
#[test]
fn prop_fifo_policy_matches_pre_refactor_admission_order() {
    forall(
        0xAD,
        300,
        |r: &mut Rng| {
            let n_slots = 1 + r.below(6);
            let max_running = 1 + r.below(n_slots);
            // Script of operations: 0 = submit, 1 = admit, 2 = release a
            // random live slot.
            let script: Vec<u8> = (0..r.below(60)).map(|_| r.below(3) as u8).collect();
            let victims: Vec<usize> = (0..script.len()).map(|_| r.below(16)).collect();
            (n_slots, max_running, script, victims)
        },
        |&(n_slots, max_running, ref script, ref victims)| {
            let mut legacy = LegacyBatcher::new(n_slots, max_running);
            let mut queue = AdmissionQueue::new(AdmissionKind::Fifo, 0);
            let mut batcher = Batcher::new(n_slots, max_running);
            let mut next_id = 0u64;
            for (&op, &victim) in script.iter().zip(victims) {
                match op {
                    0 => {
                        legacy.queue.push_back(Request::new(next_id, vec![1], 1));
                        queue
                            .submit(Request::new(next_id, vec![1], 1), 0.0)
                            .map_err(|e| e.to_string())?;
                        next_id += 1;
                    }
                    1 => {
                        let expected = legacy.admit();
                        let mut got = Vec::new();
                        while batcher.has_capacity() && !queue.is_empty() {
                            let live = batcher.live_slots();
                            let ctx = AdmissionContext {
                                now_sim: 0.0,
                                tracker: None,
                                running_slots: &live,
                                placement: None,
                                top_k: 1,
                                spec: None,
                                prefix: None,
                            };
                            let Some(entry) = queue.pop_next(&ctx) else { break };
                            let id = entry.req.id;
                            let slot = batcher.place(entry.req);
                            got.push((id, slot));
                        }
                        if got != expected {
                            return Err(format!(
                                "admission diverged: new {got:?} vs legacy {expected:?}"
                            ));
                        }
                    }
                    _ => {
                        let live = batcher.live_slots();
                        if !live.is_empty() {
                            let slot = live[victim % live.len()];
                            legacy.release(slot);
                            batcher.release(slot);
                        }
                    }
                }
                if batcher.running() != legacy.running() {
                    return Err(format!(
                        "running count diverged: {} vs {}",
                        batcher.running(),
                        legacy.running()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn priority_admission_front_runs_under_backlog() {
    // Two slots, deep backlog: the high-priority stragglers submitted LAST
    // must be admitted before earlier best-effort requests.
    let mut model = tiny_model();
    let cfg = ServeConfig { admission: AdmissionKind::Priority, ..tiny_cfg() };
    let mut core = ServeLoop::new(&mut model, cfg).unwrap();
    for id in 0..4u64 {
        core.submit(Request::new(id, vec![3, 4], 2)).unwrap();
    }
    for id in 4..6u64 {
        let mut r = Request::new(id, vec![3, 4], 2);
        r.priority = 5;
        core.submit(r).unwrap();
    }
    let first = core.step().unwrap();
    assert_eq!(first.admitted, vec![4, 5], "high-priority class admitted first");
    core.drain().unwrap();
    let report = core.report();
    assert_eq!(report.outputs.len(), 6, "backlog fully served");
    // Per-class TTFT: class 5 committed its first tokens strictly earlier.
    let m = &report.metrics;
    assert!(m.ttft_by_class[&5].mean() < m.ttft_by_class[&0].mean());
}

#[test]
fn edf_admission_orders_by_deadline_and_counts_misses() {
    // One slot (batch_size 1), three queued requests. Submitted
    // loose-first, but EDF must admit the tight deadlines first; the
    // second tight request has to wait a full request's service time
    // (≈ 8 sim steps of ~162 µs on the tiny/h100 cost model) before its
    // prefill even starts, so its 1 ms TTFT budget is unmeetable while the
    // first tight request (prefill-only wait, ≈ 0.65 ms) meets its own.
    let mut model = tiny_model();
    let cfg = ServeConfig {
        admission: AdmissionKind::SloEdf,
        batch_size: 1,
        ..tiny_cfg()
    };
    let mut core = ServeLoop::new(&mut model, cfg).unwrap();
    let prompt = vec![3, 4, 5, 6];
    let mut loose = Request::new(1, prompt.clone(), 4);
    loose.deadline_ms = Some(60_000);
    let mut tight = Request::new(2, prompt.clone(), 4);
    tight.deadline_ms = Some(1);
    let mut hopeless = Request::new(3, prompt, 4);
    hopeless.deadline_ms = Some(1);
    core.submit(loose).unwrap();
    core.submit(tight).unwrap();
    core.submit(hopeless).unwrap();
    let first = core.step().unwrap();
    assert_eq!(first.admitted, vec![2], "earliest deadline admitted first");
    let mut admissions = Vec::new();
    while core.has_work() {
        let o = core.step().unwrap();
        admissions.extend(o.admitted);
    }
    assert_eq!(admissions, vec![3, 1], "tight deadlines before the loose one");
    let m = core.metrics().clone();
    assert_eq!(m.deadline_total, 3, "every deadlined request accounted");
    assert_eq!(
        m.deadline_misses, 1,
        "exactly the queued-behind tight request misses"
    );
    assert!((m.deadline_miss_rate() - 1.0 / 3.0).abs() < 1e-12);
}

#[test]
fn bounded_queue_applies_backpressure_and_recovers() {
    let mut model = tiny_model();
    let cfg = ServeConfig { max_queue: 2, batch_size: 1, ..tiny_cfg() };
    let mut core = ServeLoop::new(&mut model, cfg).unwrap();
    core.submit(Request::new(0, vec![3], 4)).unwrap();
    core.step().unwrap(); // request 0 occupies the single slot
    core.submit(Request::new(1, vec![3], 4)).unwrap();
    core.submit(Request::new(2, vec![3], 4)).unwrap();
    let err = core.submit(Request::new(3, vec![3], 4)).unwrap_err();
    assert_eq!(err, SubmitError::QueueFull { id: 3, depth: 2, max_queue: 2 });
    assert_eq!(err.code(), "queue_full");
    assert_eq!(core.metrics().queue_rejected, 1);
    // Serving drains the queue; capacity comes back.
    core.drain().unwrap();
    core.submit(Request::new(3, vec![3], 4)).unwrap();
    core.drain().unwrap();
    let report = core.report();
    assert_eq!(report.outputs.len(), 4);
    assert!(report.metrics.queue_depth.max >= 2.0);
}

#[test]
fn submit_rejects_unservable_requests_typed() {
    let mut model = tiny_model();
    let max_seq = model.dims().max_seq;
    let mut core = ServeLoop::new(&mut model, tiny_cfg()).unwrap();
    let long = Request::new(9, vec![1; max_seq], 4);
    match core.submit(long).unwrap_err() {
        SubmitError::PromptTooLong { id, len, budget, max_seq: ms } => {
            assert_eq!((id, len, budget, ms), (9, max_seq, 4, max_seq));
        }
        other => panic!("expected PromptTooLong, got {other:?}"),
    }
    // A short prompt whose GENERATION budget overruns the KV window is just
    // as unservable: positions ≥ max_seq silently drop their cache writes.
    let greedy = Request::new(12, vec![1, 2], max_seq);
    assert!(matches!(
        core.submit(greedy).unwrap_err(),
        SubmitError::PromptTooLong { id: 12, len: 2, .. }
    ));
    // …while requests that exactly fill the window are fine — including
    // the boundary case where the prompt is the whole window and the one
    // generated token comes off the last prefill forward's logits (the
    // final token is committed without being fed back, so the last KV
    // write is at prompt + budget − 2).
    let exact = Request::new(13, vec![1, 2], max_seq - 2);
    core.submit(exact).unwrap();
    let full_window = Request::new(14, vec![1; max_seq], 1);
    core.submit(full_window).unwrap();
    let empty = Request::new(10, vec![], 4);
    assert_eq!(core.submit(empty).unwrap_err(), SubmitError::EmptyPrompt { id: 10 });
    // The loop is untouched: a normal request still serves (alongside the
    // two exactly-fitting ones admitted above).
    core.submit(Request::new(11, vec![3, 4], 2)).unwrap();
    core.drain().unwrap();
    assert_eq!(core.report().outputs.len(), 3);
}

#[test]
fn footprint_admission_serves_identically_solo_and_learns_profiles() {
    // Plumbing test: footprint admission must not change WHAT is generated
    // (admission order only reorders; routing is untouched), and the
    // tracker must be fed by real observed scores — visible through the
    // footprint_overlap gauge once same-class requests queue up.
    let mut model = tiny_model();
    let fifo = {
        let mut core = ServeLoop::new(&mut model, tiny_cfg()).unwrap();
        for id in 0..6u64 {
            let mut r = Request::new(id, vec![3 + (id % 2) as u32, 4], 3);
            r.domain = if id % 2 == 0 {
                "even".into()
            } else {
                "odd".into()
            };
            core.submit(r).unwrap();
        }
        core.drain().unwrap();
        core.report()
    };
    let cfg = ServeConfig { admission: AdmissionKind::FootprintAware, ..tiny_cfg() };
    let mut core = ServeLoop::new(&mut model, cfg).unwrap();
    for id in 0..6u64 {
        let mut r = Request::new(id, vec![3 + (id % 2) as u32, 4], 3);
        r.domain = if id % 2 == 0 {
            "even".into()
        } else {
            "odd".into()
        };
        core.submit(r).unwrap();
    }
    core.drain().unwrap();
    let fp = core.report();
    // Same request set → same outputs per id under row-independent routing
    // (vanilla default), regardless of admission order.
    assert_eq!(fifo.outputs, fp.outputs);
    // The overlap gauge recorded admissions scored against a live batch.
    assert!(
        fp.metrics.footprint_overlap.n > 0,
        "footprint admissions never saw an informative running union"
    );
}

#[test]
fn footprint_captures_prompt_scores_through_chunked_prefill() {
    // Chunked prefill is the prompt-time score source for footprints
    // (`PrefillInput::collect_probs`): with prefill_chunk > 1 the tracker
    // must still learn profiles and the served outputs must stay
    // byte-identical to one-token prefill under row-independent routing.
    let mut model = tiny_model();
    fn reqs() -> Vec<Request> {
        (0..4u64)
            .map(|id| {
                let mut r = Request::new(id, vec![3, 4, 5, 6, 7], 3);
                r.domain = if id % 2 == 0 {
                    "even".into()
                } else {
                    "odd".into()
                };
                r
            })
            .collect()
    }
    let baseline = {
        let mut core = ServeLoop::new(&mut model, tiny_cfg()).unwrap();
        for r in reqs() {
            core.submit(r).unwrap();
        }
        core.drain().unwrap();
        core.report()
    };
    let cfg = ServeConfig {
        admission: AdmissionKind::FootprintAware,
        prefill_chunk: 3,
        ..tiny_cfg()
    };
    let mut core = ServeLoop::new(&mut model, cfg).unwrap();
    for r in reqs() {
        core.submit(r).unwrap();
    }
    core.drain().unwrap();
    let fp = core.report();
    assert_eq!(baseline.outputs, fp.outputs, "chunked + footprint changed outputs");
    assert!(
        fp.metrics.footprint_overlap.n > 0,
        "chunk-captured scores never informed an admission"
    );
}

#[test]
fn scheduler_propagates_queue_rejections() {
    // Offline submit-all over a bounded queue must fail loudly, not drop
    // requests silently.
    let mut model = tiny_model();
    let cfg = ServeConfig { max_queue: 1, batch_size: 1, ..tiny_cfg() };
    let reqs: Vec<Request> = (0..3).map(|id| Request::new(id, vec![3], 2)).collect();
    let err = xshare::coordinator::Scheduler::new(&mut model, cfg)
        .unwrap()
        .run(reqs)
        .unwrap_err();
    assert!(format!("{err:#}").contains("queue full"), "{err:#}");
}

/// SATELLITE (PR 4): the footprint starvation guard. Under sustained skew
/// — the running batch and a continuous arrival stream all belong to one
/// majority class — any queued request (minority class, or entirely
/// unknown to the tracker) must be admitted within a bounded number of
/// frees: its backlog at submission plus O(STARVATION_HORIZON) aging.
#[test]
fn prop_footprint_admission_is_starvation_free() {
    use xshare::coordinator::admission::{FootprintTracker, STARVATION_HORIZON};
    let n_experts = 8;
    let top_k = 2;
    forall(
        0x5A,
        60,
        |r: &mut Rng| {
            let backlog = r.below(12); // majority entries ahead at submission
            let labeled = r.bool(0.5); // minority carries a domain tag or not
            let refill = 1 + r.below(2); // fresh majority arrivals per free
            (backlog, labeled, refill)
        },
        |&(backlog, labeled, refill)| {
            let mut tracker = FootprintTracker::new(n_experts, 2);
            let mk = |id: u64, domain: &str| {
                let mut rq = Request::new(id, vec![1, 2], 4);
                rq.domain = domain.into();
                rq
            };
            // One majority-class row runs forever, concentrated on {0, 1}.
            let runner = mk(9_000, "hot");
            tracker.on_admit(0, &runner);
            tracker.observe_row(0, &[0.5, 0.4, 0.02, 0.02, 0.02, 0.02, 0.01, 0.01]);
            if labeled {
                // the minority class has been seen before, on {6, 7}
                let probe = mk(9_001, "cold");
                tracker.on_admit(1, &probe);
                tracker.observe_row(1, &[0.01, 0.01, 0.02, 0.02, 0.02, 0.02, 0.4, 0.5]);
                tracker.release(1);
            }

            let mut q = AdmissionQueue::new(AdmissionKind::FootprintAware, 0);
            let mut next_id = 1u64;
            for _ in 0..backlog {
                q.submit(mk(next_id, "hot"), 0.0).map_err(|e| e.to_string())?;
                next_id += 1;
            }
            // the request at risk of starving ("cold" class, or unlabeled
            // and never observed)
            q.submit(mk(0, if labeled { "cold" } else { "" }), 0.0)
                .map_err(|e| e.to_string())?;

            let running = vec![0usize];
            let bound = backlog as u64 + 2 * STARVATION_HORIZON + 2;
            let mut frees = 0u64;
            loop {
                for _ in 0..refill {
                    q.submit(mk(next_id, "hot"), 0.0).map_err(|e| e.to_string())?;
                    next_id += 1;
                }
                let ctx = AdmissionContext {
                    now_sim: frees as f64,
                    tracker: Some(&tracker),
                    running_slots: &running,
                    placement: None,
                    top_k,
                    spec: None,
                    prefix: None,
                };
                let picked = q.pop_next(&ctx).expect("queue never empty");
                frees += 1;
                if picked.req.id == 0 {
                    break;
                }
                if frees > bound {
                    return Err(format!(
                        "minority request still queued after {frees} frees \
                         (backlog {backlog}, labeled {labeled}, refill {refill})"
                    ));
                }
            }
            Ok(())
        },
    );
}
