//! TCP server round-trip tests over the tiny preset: one client, many
//! concurrent clients (dynamic batching), malformed input handling.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use xshare::config::ServeConfig;
use xshare::coordinator::Request;
use xshare::runtime::artifacts_root;
use xshare::server::{Client, Server};

fn start_tiny_server() -> Server {
    let cfg = ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    Server::start_from_dir(artifacts_root().join("tiny"), cfg).unwrap()
}

#[test]
fn single_client_roundtrip() {
    let server = start_tiny_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let resp = client.generate(&Request::new(1, vec![3, 4, 5], 4)).unwrap();
    assert_eq!(resp.id, 1);
    assert_eq!(resp.tokens.len(), 4);
    assert!(resp.tokens.iter().all(|&t| (t as usize) < 64));
    // second request on the same connection
    let resp2 = client.generate(&Request::new(2, vec![3, 4, 5], 4)).unwrap();
    assert_eq!(resp2.tokens, resp.tokens, "same prompt → same greedy tokens");
    server.shutdown();
}

#[test]
fn concurrent_clients_are_batched() {
    let server = start_tiny_server();
    let addr = server.addr;
    let handles: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let prompt = vec![1 + i as u32, 2, 3];
                client.generate(&Request::new(i as u64, prompt, 5)).unwrap()
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.join().unwrap();
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.tokens.len(), 5);
    }
    server.shutdown();
}

#[test]
fn streaming_deltas_arrive_in_order_before_final_reply() {
    // Satellite (PR 4): "stream": true gets one delta frame per step that
    // committed tokens, then the usual final reply whose tokens equal the
    // concatenation of all deltas. Without speculation every step commits
    // exactly one token, so the frame count is pinned too.
    let server = start_tiny_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let mut deltas: Vec<Vec<u32>> = Vec::new();
    let resp = client
        .generate_stream(&Request::new(7, vec![3, 4, 5], 6), |d| deltas.push(d.to_vec()))
        .unwrap();
    assert_eq!(resp.id, 7);
    assert_eq!(resp.tokens.len(), 6);
    let concat: Vec<u32> = deltas.iter().flatten().copied().collect();
    assert_eq!(concat, resp.tokens, "deltas must concatenate to the final reply");
    assert_eq!(deltas.len(), 6, "one frame per committed token without speculation");

    // the same request non-streaming returns the same tokens
    let plain = client.generate(&Request::new(8, vec![3, 4, 5], 6)).unwrap();
    assert_eq!(plain.tokens, resp.tokens);
    server.shutdown();
}

#[test]
fn streaming_spec_commits_batch_several_tokens_per_frame() {
    // Under lookup-draft speculation a verify cycle can commit several
    // tokens at once — they arrive as ONE frame, and the concatenation
    // still equals the final reply.
    let cfg = ServeConfig {
        preset: "tiny".into(),
        batch_size: 2,
        spec_len: 3,
        spec_draft: xshare::config::SpecDraft::Lookup,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    let server = Server::start_from_dir(artifacts_root().join("tiny"), cfg).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let mut deltas: Vec<Vec<u32>> = Vec::new();
    let resp = client
        .generate_stream(&Request::new(3, vec![5, 6], 24), |d| deltas.push(d.to_vec()))
        .unwrap();
    let concat: Vec<u32> = deltas.iter().flatten().copied().collect();
    assert_eq!(concat, resp.tokens);
    assert_eq!(resp.tokens.len(), 24);
    assert!(
        deltas.len() <= resp.tokens.len(),
        "never more frames than tokens"
    );
    server.shutdown();
}

#[test]
fn non_streaming_reply_bytes_unchanged() {
    // Clients that do not opt in must see exactly the pre-streaming wire
    // format: one reply line, bit-identical to encode_response — no delta
    // frames, no extra fields.
    let server = start_tiny_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, r#"{{"id":11,"prompt":[3,4],"max_new_tokens":4}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = xshare::server::decode_response(line.trim()).unwrap();
    assert_eq!(
        line.trim(),
        xshare::server::protocol::encode_response(11, &resp.tokens),
        "non-streaming reply line must be byte-identical to the legacy format"
    );
    server.shutdown();
}

#[test]
fn malformed_request_error_carries_request_id() {
    // A parsable-but-invalid payload (empty prompt) must be answered with
    // an error the client can correlate — not a hardcoded id of 0.
    let server = start_tiny_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, r#"{{"id":9,"prompt":[],"max_new_tokens":3}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    assert!(line.contains("\"id\":9"), "{line}");
    server.shutdown();
}

#[test]
fn queue_full_reaches_client_as_coded_error_with_its_id() {
    // ACCEPTANCE: submit beyond max_queue returns a typed QueueFull that a
    // TCP client observes as a protocol-level error reply carrying its
    // request id. One slot + one queue seat, six rapid submissions: the
    // first occupies the slot for its whole generation, one more waits in
    // the queue, and every other submission must be answered immediately
    // with a "queue_full"-coded error — never silence.
    let cfg = ServeConfig {
        preset: "tiny".into(),
        batch_size: 1,
        max_queue: 1,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    let server = Server::start_from_dir(artifacts_root().join("tiny"), cfg).unwrap();

    let n = 6usize;
    let mut conns: Vec<(std::io::BufReader<TcpStream>, TcpStream)> = (0..n)
        .map(|_| {
            let s = TcpStream::connect(server.addr).unwrap();
            let w = s.try_clone().unwrap();
            (BufReader::new(s), w)
        })
        .collect();
    // Rapid-fire while request 0 is still being served (tiny serves a
    // 3+28-token request over ~31 PJRT steps; these six writes take well
    // under a millisecond).
    for (i, (_, w)) in conns.iter_mut().enumerate() {
        writeln!(
            w,
            r#"{{"id":{i},"prompt":[3,4,5],"max_new_tokens":28}}"#
        )
        .unwrap();
    }
    let mut served = 0usize;
    let mut rejected = 0usize;
    for (i, (r, _)) in conns.iter_mut().enumerate() {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(
            line.contains(&format!("\"id\":{i}")),
            "reply for client {i} lost its id: {line}"
        );
        if line.contains("error") {
            assert!(line.contains("queue_full"), "uncoded rejection: {line}");
            rejected += 1;
        } else {
            assert!(line.contains("tokens"), "{line}");
            served += 1;
        }
    }
    assert_eq!(served + rejected, n, "every request answered exactly once");
    assert!(served >= 1, "the slot-holder must be served");
    // Exact counts depend on how arrivals interleave with slot releases on
    // a loaded machine (each release frees the queue seat for one more
    // absorption), but six near-simultaneous submissions against one slot
    // + one queue seat cannot all be absorbed: rejections MUST occur, and
    // each must have reached its client as a coded reply (asserted above).
    assert!(
        rejected >= 1,
        "backpressure never fired across {n} concurrent requests \
         (served {served}, rejected {rejected})"
    );
    server.shutdown();
}

#[test]
fn over_long_prompt_rejected_with_coded_error_not_batch_poison() {
    // A prompt that cannot fit the compiled KV window must be refused at
    // submit time with a wire reply (id + code), and the worker must keep
    // serving — the pre-refactor behaviour was a mid-step failure that
    // errored every in-flight request.
    let server = start_tiny_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let long: Vec<String> = (0..40).map(|i| (i % 60).to_string()).collect();
    writeln!(
        writer,
        r#"{{"id":21,"prompt":[{}],"max_new_tokens":3}}"#,
        long.join(",")
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    assert!(line.contains("\"id\":21"), "{line}");
    assert!(line.contains("prompt_too_long"), "{line}");
    // connection and server both still healthy
    writeln!(writer, r#"{{"id":22,"prompt":[1,2],"max_new_tokens":3}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"id\":22"), "{line}");
    assert!(line.contains("tokens"), "{line}");
    server.shutdown();
}

#[test]
fn priority_and_deadline_fields_accepted_on_the_wire() {
    let server = start_tiny_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        r#"{{"id":5,"prompt":[3,4],"max_new_tokens":3,"priority":2,"deadline_ms":5000}}"#
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"id\":5"), "{line}");
    assert!(line.contains("tokens"), "{line}");
    server.shutdown();
}

#[test]
fn malformed_line_gets_error_not_hang() {
    let server = start_tiny_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    // connection still usable
    writeln!(writer, r#"{{"id":5,"prompt":[1,2],"max_new_tokens":3}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"id\":5"), "{line}");
    assert!(line.contains("tokens"), "{line}");
    server.shutdown();
}
