//! TCP server round-trip tests over the tiny preset: one client, many
//! concurrent clients (dynamic batching), malformed input handling.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use xshare::config::ServeConfig;
use xshare::coordinator::Request;
use xshare::runtime::artifacts_root;
use xshare::server::{Client, Server};

fn start_tiny_server() -> Server {
    let cfg = ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    Server::start_from_dir(artifacts_root().join("tiny"), cfg).unwrap()
}

#[test]
fn single_client_roundtrip() {
    let server = start_tiny_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let resp = client.generate(&Request::new(1, vec![3, 4, 5], 4)).unwrap();
    assert_eq!(resp.id, 1);
    assert_eq!(resp.tokens.len(), 4);
    assert!(resp.tokens.iter().all(|&t| (t as usize) < 64));
    // second request on the same connection
    let resp2 = client.generate(&Request::new(2, vec![3, 4, 5], 4)).unwrap();
    assert_eq!(resp2.tokens, resp.tokens, "same prompt → same greedy tokens");
    server.shutdown();
}

#[test]
fn concurrent_clients_are_batched() {
    let server = start_tiny_server();
    let addr = server.addr;
    let handles: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let prompt = vec![1 + i as u32, 2, 3];
                client.generate(&Request::new(i as u64, prompt, 5)).unwrap()
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.join().unwrap();
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.tokens.len(), 5);
    }
    server.shutdown();
}

#[test]
fn malformed_request_error_carries_request_id() {
    // A parsable-but-invalid payload (empty prompt) must be answered with
    // an error the client can correlate — not a hardcoded id of 0.
    let server = start_tiny_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, r#"{{"id":9,"prompt":[],"max_new_tokens":3}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    assert!(line.contains("\"id\":9"), "{line}");
    server.shutdown();
}

#[test]
fn malformed_line_gets_error_not_hang() {
    let server = start_tiny_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    // connection still usable
    writeln!(writer, r#"{{"id":5,"prompt":[1,2],"max_new_tokens":3}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"id\":5"), "{line}");
    assert!(line.contains("tokens"), "{line}");
    server.shutdown();
}
