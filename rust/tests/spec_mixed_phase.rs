//! Mixed-phase speculative decoding pins (PR 4).
//!
//! The per-row phase-machine refactor must be a pure *scheduling*
//! generalization — three byte-identity pins nail that down on the tiny
//! preset:
//!
//!  (a) depth-0-everywhere ≡ the non-speculative path (outputs AND final
//!      KV state);
//!  (b) solo-row speculation ≡ the pre-refactor global-gate cycle (the
//!      gate survives as `set_legacy_spec_gate` instrumentation), and a
//!      row speculating solo beside a prefilling neighbour is unperturbed
//!      under vanilla routing;
//!  (c) a staggered-admission property: a prefilling row never flips
//!      speculation off for decoding rows, and — because greedy
//!      speculation is lossless when the verify routes like the target —
//!      every request's tokens stay byte-identical to the non-speculative
//!      run under vanilla routing, at any admission timing.

use std::collections::BTreeMap;

use xshare::config::{ServeConfig, SpecDraft};
use xshare::coordinator::{Phase, Request, Scheduler, ServeLoop};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::util::check::forall;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn cfg(spec_len: usize) -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        spec_len,
        max_new_tokens: 6,
        ..Default::default()
    }
}

fn prompt_of(len: usize, seed: u64, vocab: u64) -> Vec<u32> {
    (0..len as u64).map(|i| ((seed.wrapping_mul(31) + i * 7 + 3) % vocab) as u32).collect()
}

/// Serve requests upfront through a fresh loop, with optional hooks.
fn run_with(
    model: &mut MoeModel,
    c: ServeConfig,
    requests: &[Request],
    setup: impl FnOnce(&mut ServeLoop),
) -> (BTreeMap<u64, Vec<u32>>, u64) {
    let mut core = ServeLoop::new(model, c).expect("serve loop");
    setup(&mut core);
    for r in requests {
        core.submit(r.clone()).unwrap();
    }
    core.drain().unwrap();
    let stalled = core.metrics().spec_stalled_steps;
    (core.report().outputs, stalled)
}

#[test]
fn depth_zero_everywhere_is_byte_identical_to_non_spec() {
    // Pin (a): spec_len > 0 with every row's depth forced to 0 must take
    // the plain path — identical tokens AND identical final KV bytes —
    // while counting the stalled steps.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let requests: Vec<Request> = (0..3)
        .map(|i| Request::new(i, prompt_of(2 + i as usize, 11 + i, vocab), 5))
        .collect();

    let (base, _) = run_with(&mut model, cfg(0), &requests, |_| {});
    let base_kv: Vec<u64> = (0..3).map(|s| model.kv_row_digest(s)).collect();

    let (forced, stalled) = run_with(&mut model, cfg(3), &requests, |core| {
        core.force_spec_depth(Some(0));
    });
    let forced_kv: Vec<u64> = (0..3).map(|s| model.kv_row_digest(s)).collect();

    assert_eq!(base, forced, "depth-0 speculation changed generated tokens");
    assert_eq!(base_kv, forced_kv, "depth-0 speculation changed KV bytes");
    assert!(stalled > 0, "desired-but-depth-0 steps must count as stalled");
}

#[test]
fn solo_row_spec_matches_legacy_global_gate() {
    // Pin (b), first half: for workloads whose phases never mix (solo
    // requests; equal-length prompts submitted upfront), the mixed-phase
    // executor must reproduce the legacy gate cycle byte-for-byte — the
    // ragged machinery at uniform depth IS the old uniform cycle.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;

    for (label, requests) in [
        ("solo", vec![Request::new(1, prompt_of(3, 5, vocab), 7)]),
        (
            "equal-length batch",
            (0..4)
                .map(|i| Request::new(i, prompt_of(4, 20 + i, vocab), 6))
                .collect::<Vec<_>>(),
        ),
    ] {
        let (mixed, _) = run_with(&mut model, cfg(3), &requests, |_| {});
        let (legacy, _) = run_with(&mut model, cfg(3), &requests, |core| {
            core.set_legacy_spec_gate(true);
        });
        assert_eq!(mixed, legacy, "[{label}] mixed-phase diverged from the legacy cycle");
    }
}

#[test]
fn solo_speculator_unperturbed_by_prefilling_neighbour() {
    // Pin (b), second half: under vanilla routing (row-independent), a row
    // speculating as the ONLY decode row — its neighbour mid-prompt, the
    // exact situation the old gate forbade — must produce byte-identical
    // tokens to the same request served completely alone.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let spec_req = Request::new(1, prompt_of(2, 9, vocab), 8);

    let (solo, _) = run_with(&mut model, cfg(2), &[spec_req.clone()], |_| {});

    let mut core = ServeLoop::new(&mut model, cfg(2)).unwrap();
    core.submit(spec_req).unwrap();
    core.step().unwrap(); // prefill token 1
    core.step().unwrap(); // prefill exhausted, first token commits
    // Long-prompt neighbour arrives: request 1 keeps speculating solo.
    core.submit(Request::new(2, prompt_of(9, 3, vocab), 4)).unwrap();
    let mut saw_mixed_spec = false;
    while core.has_work() {
        let o = core.step().unwrap();
        if o.prefill_rows > 0 && o.speculative() {
            saw_mixed_spec = true;
            assert_eq!(
                o.spec_depth_of(0),
                Some(2),
                "request 1 speculates at full depth beside the prefill row"
            );
        }
    }
    assert!(saw_mixed_spec, "phases never mixed — the scenario under test");
    let report = core.report();
    assert_eq!(report.outputs[&1], solo[&1], "neighbour's prefill perturbed the speculator");
    assert_eq!(core.metrics().spec_stalled_steps, 0);
}

#[test]
fn budget_one_prefill_rider_finishes_inside_the_verify_step() {
    // Regression: a rider whose FIRST committed token exhausts its budget
    // (max_new_tokens = 1) must release its slot inside the verify step
    // that committed it — not linger and risk an extra commit on the next
    // plain step.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut core = ServeLoop::new(&mut model, cfg(2)).unwrap();
    core.submit(Request::new(1, prompt_of(2, 7, vocab), 8)).unwrap();
    core.step().unwrap(); // prefill token 1
    core.step().unwrap(); // prompt done, row 1 decodes from here
    core.submit(Request::new(2, prompt_of(1, 8, vocab), 1)).unwrap();
    let o = core.step().unwrap();
    assert!(o.speculative(), "row 1 speculates while row 2 rides at depth 0");
    assert_eq!(o.prefill_rows, 1);
    let finished: Vec<u64> = o.finished.iter().map(|(id, _)| *id).collect();
    assert_eq!(finished, vec![2], "budget-1 rider must finish in-step");
    let two = o.finished.iter().find(|(id, _)| *id == 2).unwrap();
    assert_eq!(two.1.len(), 1, "exactly its one-token budget");
    core.drain().unwrap();
    let report = core.report();
    assert_eq!(report.outputs[&2].len(), 1, "no extra token after release");
    assert_eq!(report.outputs[&1].len(), 8);
}

#[test]
fn prefilling_rows_never_stall_spec_property() {
    // Pin (c): random staggered admissions, prompt lengths and budgets.
    // Whenever a step has ≥1 decoding row, speculation must run (model
    // drafts always fill the full depth), and under vanilla routing every
    // request's tokens must be byte-identical to the non-speculative
    // upfront run — greedy speculation is lossless and scheduling-only.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    forall(
        43,
        6,
        |rng| {
            let n = 2 + rng.below(3); // 2..=4 requests
            let lens: Vec<usize> = (0..n).map(|_| 1 + rng.below(8)).collect();
            let offsets: Vec<usize> = (0..n).map(|_| rng.below(6)).collect();
            let max_new = 2 + rng.below(5);
            let spec_len = 1 + rng.below(3);
            let seed = rng.below(1000) as u64;
            (lens, offsets, max_new, spec_len, seed)
        },
        |&(ref lens, ref offsets, max_new, spec_len, seed)| {
            let requests: Vec<Request> = lens
                .iter()
                .enumerate()
                .map(|(i, &len)| {
                    Request::new(i as u64, prompt_of(len, seed + i as u64, vocab), max_new)
                })
                .collect();

            // reference: non-speculative, submit-all-upfront
            let upfront = Scheduler::new(&mut model, cfg(0))
                .map_err(|e| format!("{e:#}"))?
                .run(requests.clone())
                .map_err(|e| format!("{e:#}"))?;

            // staggered speculative run
            let mut core = ServeLoop::new(&mut model, cfg(spec_len))
                .map_err(|e| format!("{e:#}"))?;
            let mut pending: BTreeMap<usize, Vec<Request>> = BTreeMap::new();
            for (r, &off) in requests.iter().zip(offsets) {
                pending.entry(off).or_default().push(r.clone());
            }
            let mut step_no = 0usize;
            loop {
                if let Some(batch) = pending.remove(&step_no) {
                    for r in batch {
                        core.submit(r).unwrap();
                    }
                }
                if !core.has_work() {
                    if pending.is_empty() {
                        break;
                    }
                    step_no += 1;
                    continue;
                }
                let o = core.step().map_err(|e| format!("{e:#}"))?;
                step_no += 1;
                // THE property: decoding rows speculate regardless of how
                // many rows are mid-prompt.
                if o.decode_rows > 0 && !o.speculative() {
                    return Err(format!(
                        "step with {} decode / {} prefill rows ran without \
                         speculation",
                        o.decode_rows, o.prefill_rows
                    ));
                }
                for &(slot, id, phase) in &o.phases {
                    if matches!(phase, Phase::SpecVerify { depth } if depth > spec_len) {
                        return Err(format!(
                            "slot {slot} (req {id}) exceeded spec_len: {phase:?}"
                        ));
                    }
                }
            }
            let spec = core.report();
            if spec.outputs != upfront.outputs {
                return Err(format!(
                    "speculative outputs diverged: {:?} vs {:?}",
                    spec.outputs, upfront.outputs
                ));
            }
            if core.metrics().spec_stalled_steps != 0 {
                return Err("model-draft speculation reported stalls".into());
            }
            Ok(())
        },
    );
}

#[test]
fn charge_aware_depth_stays_lossless_and_digs_deeper() {
    // `--spec-charge-aware` swaps the fixed usefulness threshold for the
    // ledger's marginal-cost test: accept one more draft level whenever
    // the acceptance-weighted value of the extra committed token beats
    // the marginal verify charge. On the tiny preset's memory-bound
    // decode that marginal is tiny next to a token, so at the same
    // acceptance EMA the charge-aware controller holds depth where the
    // fixed threshold backs off — strictly deeper (never shallower)
    // drafting. Depth choice is scheduling-only: outputs must stay
    // byte-identical to the non-speculative run in BOTH arms.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let requests: Vec<Request> = (0..3)
        .map(|i| Request::new(i, prompt_of(3 + i as usize, 40 + i, vocab), 24))
        .collect();

    let (base, _) = run_with(&mut model, cfg(0), &requests, |_| {});

    let mut arm = |charge_aware: bool| {
        let mut c = cfg(3);
        c.spec_draft = SpecDraft::Lookup;
        c.spec_adaptive = true;
        c.spec_charge_aware = charge_aware;
        let mut core = ServeLoop::new(&mut model, c).unwrap();
        for r in &requests {
            core.submit(r.clone()).unwrap();
        }
        core.drain().unwrap();
        let m = core.metrics().clone();
        let out = core.report().outputs;
        assert_eq!(
            out, base,
            "charge_aware={charge_aware}: depth scheduling changed tokens"
        );
        assert!(m.spec_proposed > 0, "charge_aware={charge_aware}: never proposed");
        assert!(m.spec_depth.n > 0, "charge_aware={charge_aware}: depth gauge empty");
        assert!(m.spec_depth.max <= 3.0, "charge_aware={charge_aware}: exceeded cap");
        m
    };

    let fixed = arm(false);
    let charge = arm(true);
    assert!(
        charge.spec_depth.mean() >= fixed.spec_depth.mean(),
        "charge-aware mean depth {:.3} fell below the fixed threshold's {:.3} — \
         the cheap-marginal regime must never draft shallower",
        charge.spec_depth.mean(),
        fixed.spec_depth.mean()
    );
    // deeper drafts at the same (lossless) outputs can only shed verify
    // steps; allow a little slack for EMA-trajectory divergence between
    // the arms, the strict throughput win is pinned in serve_continuous
    assert!(
        charge.sim_seconds <= fixed.sim_seconds * 1.05,
        "charge-aware sim time {} regressed past fixed-threshold {}",
        charge.sim_seconds,
        fixed.sim_seconds
    );
}

#[test]
fn lookup_draft_and_adaptive_depth_stay_lossless() {
    // The new draft source and the adaptive controller change WHICH cycles
    // run at what depth — never the committed tokens (vanilla routing).
    // Lookup drafting on the tiny preset's cyclic decode also genuinely
    // accepts tokens, which is what the serve_continuous spec scenario's
    // throughput assertion rides on.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    // long budgets reach the decode attractor where lookup drafts hit
    let requests: Vec<Request> = (0..3)
        .map(|i| Request::new(i, prompt_of(3 + i as usize, 40 + i, vocab), 24))
        .collect();

    let (base, _) = run_with(&mut model, cfg(0), &requests, |_| {});

    for adaptive in [false, true] {
        let mut c = cfg(3);
        c.spec_draft = SpecDraft::Lookup;
        c.spec_adaptive = adaptive;
        let mut core = ServeLoop::new(&mut model, c).unwrap();
        for r in &requests {
            core.submit(r.clone()).unwrap();
        }
        core.drain().unwrap();
        let m = core.metrics().clone();
        let out = core.report().outputs;
        assert_eq!(out, base, "lookup/adaptive speculation changed tokens");
        assert!(m.spec_proposed > 0, "lookup drafting never proposed");
        assert!(m.spec_depth.n > 0, "per-row depth gauge empty");
        assert!(
            !m.spec_accept_by_class.is_empty(),
            "per-class acceptance histogram empty"
        );
        assert!(m.spec_depth.max <= 3.0, "per-row depth exceeded spec_len");
        if !adaptive {
            // at full fixed depth over 24-token generations, the tiny
            // preset's cyclic decode must genuinely accept lookup drafts —
            // the effect the serve_continuous spec scenario rides on
            // (adaptive runs may legitimately idle at depth 0 between
            // probes, so only proposals are guaranteed there)
            assert!(
                m.spec_accepted > 0,
                "lookup drafting never accepted on a cyclic decode"
            );
        }
    }
}
