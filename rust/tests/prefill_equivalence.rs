//! Chunked-prefill equivalence suite: the chunk path is an *execution*
//! optimisation, never a model change. For random prompts, policies and
//! chunk sizes, a request served with `prefill_chunk = T` must produce
//! byte-identical generated tokens — and leave byte-identical KV-cache and
//! `pos` state — compared to the one-token-per-step walk (`prefill_chunk =
//! 1`, the pre-PR-2 path). The substrate guarantee (same kernel, same
//! per-position bits) is proven in `python/tests/test_model.py::
//! test_prefill_chunk_matches_one_token_walk_bitwise`; this suite proves it
//! survives the whole serving stack: selection policies, the batcher, cost
//! charging and continuous admission.

use std::collections::BTreeMap;

use xshare::config::ServeConfig;
use xshare::coordinator::{Request, Scheduler, ServeLoop};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;
use xshare::util::check::forall;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    assert!(
        manifest.has_prefill(),
        "tiny artifacts predate the prefill program — re-run `make artifacts`"
    );
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn cfg(policy: &str, chunk: usize, max_new: usize) -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        policy: PolicyKind::parse(policy).expect("policy"),
        batch_size: 4,
        prefill_chunk: chunk,
        max_new_tokens: max_new,
        ..Default::default()
    }
}

fn prompt_of(len: usize, seed: u64, vocab: u64) -> Vec<u32> {
    (0..len as u64).map(|i| ((seed.wrapping_mul(31) + i * 7 + 3) % vocab) as u32).collect()
}

/// Serve one request solo and return (generated tokens, served row's final
/// KV digest).
fn run_solo(model: &mut MoeModel, c: ServeConfig, req: Request) -> (Vec<u32>, u64) {
    let report =
        Scheduler::new(model, c).expect("scheduler").run(vec![req]).expect("run");
    let tokens = report.outputs.into_values().next().expect("one output");
    (tokens, model.kv_row_digest(0))
}

#[test]
fn chunked_prefill_byte_identical_across_policies_and_chunk_sizes() {
    // THE equivalence property. Policies cover every select/route shape in
    // the tree (warm-up+greedy, hierarchical, token-level baselines);
    // chunk sizes cover sub-chunk, capacity-crossing (tiny capacity is 4,
    // so 8 needs two invocations per step) and whole-prompt chunks.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let policies = ["vanilla", "batch:6:1", "spec:1:0:2", "lynx:2", "skip:0.3", "opp:1"];
    forall(
        29,
        8,
        |rng| {
            let policy = policies[rng.below(policies.len())];
            let prompt_len = 2 + rng.below(9); // 2..=10
            let max_new = 2 + rng.below(4); // 2..=5
            let seed = rng.below(1000) as u64;
            (policy, prompt_len, max_new, seed)
        },
        |&(policy, prompt_len, max_new, seed)| {
            let req = || Request::new(1, prompt_of(prompt_len, seed, vocab), max_new);
            let (base_tokens, base_kv) =
                run_solo(&mut model, cfg(policy, 1, max_new), req());
            for chunk in [1usize, 3, 8, prompt_len] {
                let (tokens, kv) =
                    run_solo(&mut model, cfg(policy, chunk, max_new), req());
                if tokens != base_tokens {
                    return Err(format!(
                        "[{policy} chunk={chunk}] tokens diverged: {tokens:?} vs \
                         {base_tokens:?}"
                    ));
                }
                if kv != base_kv {
                    return Err(format!(
                        "[{policy} chunk={chunk}] final KV digest diverged"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn chunked_prefill_pos_state_and_step_count() {
    // The `pos` half of the state equivalence, plus the whole point of the
    // feature: a 7-token prompt takes ceil(7/3)=3 chunked steps to its
    // first committed token instead of 7 — same final pos either way.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let prompt = prompt_of(7, 11, vocab);

    let mut first_commit = BTreeMap::new();
    for chunk in [1usize, 3] {
        let mut core = ServeLoop::new(&mut model, cfg("vanilla", chunk, 4)).unwrap();
        core.submit(Request::new(1, prompt.clone(), 4)).unwrap();
        let mut steps = 0;
        loop {
            let o = core.step().unwrap();
            steps += 1;
            if o.committed > 0 {
                break;
            }
            assert_eq!(o.prefill_tokens, chunk.min(7) as u64);
        }
        assert_eq!(
            core.slot_pos(0),
            Some(prompt.len()),
            "pos after prompt consumption must equal prompt length"
        );
        first_commit.insert(chunk, steps);
    }
    assert_eq!(first_commit[&1], 7, "one-token walk: one step per prompt token");
    assert_eq!(first_commit[&3], 3, "chunk=3 reaches the first token in ceil(7/3)");
}

#[test]
fn staggered_admission_unperturbed_by_chunking() {
    // Continuous-batching order proof: requests joining a chunking loop
    // mid-flight must get exactly the tokens the one-token loop (or a
    // submit-all-upfront run) would give them. Vanilla routing, where rows
    // are independent, is the regime where byte-equality must hold.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    forall(
        31,
        6,
        |rng| {
            let n = 3 + rng.below(3); // 3..=5 requests
            let lens: Vec<usize> = (0..n).map(|_| 2 + rng.below(8)).collect();
            let offsets: Vec<usize> = (0..n).map(|_| rng.below(5)).collect();
            let max_new = 2 + rng.below(3);
            let seed = rng.below(1000) as u64;
            (lens, offsets, max_new, seed)
        },
        |&(ref lens, ref offsets, max_new, seed)| {
            let requests: Vec<Request> = lens
                .iter()
                .enumerate()
                .map(|(i, &len)| {
                    Request::new(i as u64, prompt_of(len, seed + i as u64, vocab), max_new)
                })
                .collect();

            // reference: upfront, one-token prefill
            let upfront = Scheduler::new(&mut model, cfg("vanilla", 1, max_new))
                .map_err(|e| format!("{e:#}"))?
                .run(requests.clone())
                .map_err(|e| format!("{e:#}"))?;

            // staggered submission into a chunking loop
            let mut core = ServeLoop::new(&mut model, cfg("vanilla", 3, max_new))
                .map_err(|e| format!("{e:#}"))?;
            let mut pending: BTreeMap<usize, Vec<Request>> = BTreeMap::new();
            for (r, &off) in requests.iter().zip(offsets) {
                pending.entry(off).or_default().push(r.clone());
            }
            let mut step_no = 0usize;
            loop {
                if let Some(batch) = pending.remove(&step_no) {
                    for r in batch {
                        core.submit(r).unwrap();
                    }
                }
                if !core.has_work() {
                    if pending.is_empty() {
                        break;
                    }
                    step_no += 1;
                    continue;
                }
                core.step().map_err(|e| format!("{e:#}"))?;
                step_no += 1;
            }
            let staggered = core.report();

            if upfront.outputs != staggered.outputs {
                return Err(format!(
                    "chunked staggered outputs diverged: {:?} vs {:?}",
                    staggered.outputs, upfront.outputs
                ));
            }
            if staggered.metrics.ttft.n != lens.len() as u64 {
                return Err(format!(
                    "ttft recorded {} times for {} requests",
                    staggered.metrics.ttft.n,
                    lens.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prompt_and_generated_token_counters_split() {
    // Throughput-inflation regression (PR 2 bugfix): prompt tokens land in
    // tokens_prompt, generated tokens in tokens_out, and OTPS only sees
    // the latter — a 9-token prompt must not look like throughput.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    for chunk in [1usize, 4] {
        let report = Scheduler::new(&mut model, cfg("vanilla", chunk, 3))
            .unwrap()
            .run(vec![Request::new(1, prompt_of(9, 5, vocab), 3)])
            .unwrap();
        assert_eq!(report.metrics.tokens_prompt, 9, "chunk={chunk}");
        assert_eq!(report.metrics.tokens_out, 3, "chunk={chunk}");
        if chunk == 4 {
            // chunks of 4 and 4; the single-token tail rides the shared
            // decode forward instead of paying a dedicated chunk forward
            assert_eq!(report.metrics.prefill_forwards, 2);
            assert!(report.metrics.prefill_tokens_per_step.mean() > 1.0);
        } else {
            assert_eq!(report.metrics.prefill_forwards, 0);
        }
        let j = report.metrics.to_json();
        assert!(j.get("tokens_prompt").is_some());
    }
}

#[test]
fn chunked_step_outcome_reports_prefill_tokens() {
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut core = ServeLoop::new(&mut model, cfg("vanilla", 4, 2)).unwrap();
    core.submit(Request::new(1, prompt_of(6, 2, vocab), 2)).unwrap();
    let o1 = core.step().unwrap();
    assert_eq!((o1.prefill_rows, o1.decode_rows), (1, 0));
    assert_eq!(o1.prefill_tokens, 4, "first chunk consumes 4 prompt tokens");
    assert_eq!(o1.committed, 0, "no generated token mid-prompt");
    let o2 = core.step().unwrap();
    assert_eq!(o2.prefill_tokens, 2, "final partial chunk");
    assert_eq!(o2.committed, 1, "prompt exhaustion commits the first token");
    let o3 = core.step().unwrap();
    assert_eq!(o3.prefill_tokens, 0);
    assert_eq!(o3.committed, 1);
    assert_eq!(o3.finished.len(), 1);
}

/// Run a staggered-admission trace and return (report, per-row KV digests).
/// `sequential_charging` toggles the pre-PR8 per-invocation prefill
/// accounting ([`ServeLoop::set_sequential_prefill_charging`]).
fn run_staggered_trace(
    model: &mut MoeModel,
    c: ServeConfig,
    requests: &[Request],
    offsets: &[usize],
    sequential_charging: bool,
) -> Result<(xshare::coordinator::RunReport, Vec<u64>), String> {
    let b_max = model.max_batch();
    let mut core = ServeLoop::new(model, c).map_err(|e| format!("{e:#}"))?;
    core.set_sequential_prefill_charging(sequential_charging);
    let mut pending: BTreeMap<usize, Vec<Request>> = BTreeMap::new();
    for (r, &off) in requests.iter().zip(offsets) {
        pending.entry(off).or_default().push(r.clone());
    }
    let mut step_no = 0usize;
    loop {
        if let Some(batch) = pending.remove(&step_no) {
            for r in batch {
                core.submit(r).unwrap();
            }
        }
        if !core.has_work() {
            if pending.is_empty() {
                break;
            }
            step_no += 1;
            continue;
        }
        core.step().map_err(|e| format!("{e:#}"))?;
        step_no += 1;
    }
    let report = core.report();
    drop(core);
    let kv = (0..b_max).map(|r| model.kv_row_digest(r)).collect();
    Ok((report, kv))
}

#[test]
fn fused_waves_byte_identical_to_sequential_charging() {
    // THE PR 8 wave pin: across every select/route shape in the tree,
    // chunk sizes, staggered admission and 1–4 co-prefilling rows, fused
    // wave charging (the default) and the pre-PR8 per-invocation charging
    // must produce byte-identical tokens AND byte-identical per-row KV
    // digests — waves fuse the charge, never the computation. The fused
    // run must also expose the amortization in its gauges: waves counted,
    // a weight stream saved whenever ≥2 rows actually co-prefilled, and
    // simulated time never above the sequential charge.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let policies = ["vanilla", "batch:6:1", "spec:1:0:2", "lynx:2", "skip:0.3", "opp:1"];
    forall(
        37,
        6,
        |rng| {
            let policy = policies[rng.below(policies.len())];
            let n = 1 + rng.below(4); // 1..=4 co-prefilling rows
            let chunk = 2 + rng.below(7); // 2..=8
            let lens: Vec<usize> = (0..n).map(|_| 3 + rng.below(8)).collect();
            let offsets: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
            let max_new = 2 + rng.below(3);
            let seed = rng.below(1000) as u64;
            (policy, chunk, lens, offsets, max_new, seed)
        },
        |&(policy, chunk, ref lens, ref offsets, max_new, seed)| {
            let requests: Vec<Request> = lens
                .iter()
                .enumerate()
                .map(|(i, &len)| {
                    Request::new(i as u64, prompt_of(len, seed + i as u64, vocab), max_new)
                })
                .collect();
            let (seq_report, seq_kv) = run_staggered_trace(
                &mut model,
                cfg(policy, chunk, max_new),
                &requests,
                offsets,
                true,
            )?;
            let (fused_report, fused_kv) = run_staggered_trace(
                &mut model,
                cfg(policy, chunk, max_new),
                &requests,
                offsets,
                false,
            )?;
            if fused_report.outputs != seq_report.outputs {
                return Err(format!(
                    "[{policy} chunk={chunk}] fused outputs diverged: {:?} vs {:?}",
                    fused_report.outputs, seq_report.outputs
                ));
            }
            if fused_kv != seq_kv {
                return Err(format!("[{policy} chunk={chunk}] per-row KV digests diverged"));
            }
            let (fm, sm) = (&fused_report.metrics, &seq_report.metrics);
            if fm.tokens_prompt != sm.tokens_prompt || fm.prefill_forwards != sm.prefill_forwards
            {
                return Err("token/forward accounting diverged between charging modes".into());
            }
            if sm.prefill_waves != 0 {
                return Err("sequential charging must record no waves".into());
            }
            if fm.prefill_forwards > 0 && fm.prefill_waves == 0 {
                return Err("fused run with chunk forwards recorded no waves".into());
            }
            if fm.prefill_waves > 0
                && fm.prefill_forwards != fm.prefill_waves + fm.prefill_streams_saved
            {
                return Err(format!(
                    "stream accounting broken: {} forwards, {} waves, {} saved",
                    fm.prefill_forwards, fm.prefill_waves, fm.prefill_streams_saved
                ));
            }
            // The amortized charge never exceeds the per-invocation charge
            // (equal when every wave held a single row), so prompt
            // throughput can only improve.
            if fm.sim_seconds > sm.sim_seconds + 1e-9 {
                return Err(format!(
                    "fused charge {} above sequential {}",
                    fm.sim_seconds, sm.sim_seconds
                ));
            }
            if fm.prefill_streams_saved > 0 && fm.sim_seconds >= sm.sim_seconds {
                return Err("saved streams but no simulated-time saving".into());
            }
            Ok(())
        },
    );
}

#[test]
fn shared_selection_distortion_reported_never_silent() {
    // The lossy mode's accounting contract: a --chunk-shared-selection run
    // reports its routing distortion through the fidelity machinery — a
    // finite token-match in [0, 1] — while a sharing-off run reads as
    // exactly lossless (drop 0.0) without recording anything.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let requests: Vec<Request> =
        (0..3).map(|i| Request::new(i, prompt_of(8, 40 + i, vocab), 4)).collect();
    let offsets = [0usize, 0, 1];

    let (base_report, _) =
        run_staggered_trace(&mut model, cfg("vanilla", 4, 4), &requests, &offsets, false)
            .unwrap();
    // off-mode: delta exactly 0, nothing recorded
    assert_eq!(base_report.metrics.shared_selection_fidelity.n, 0);
    assert_eq!(base_report.metrics.shared_selection_token_match(), 1.0);
    assert_eq!(base_report.metrics.shared_selection_drop_pts(), 0.0);

    let shared_cfg = ServeConfig { chunk_shared_selection: true, ..cfg("vanilla", 4, 4) };
    let mut core = ServeLoop::new(&mut model, shared_cfg).unwrap();
    for r in &requests {
        core.submit(r.clone()).unwrap();
    }
    core.drain().unwrap();
    let shared_outputs = core.report().outputs;
    let f = xshare::coordinator::compare(&base_report.outputs, &shared_outputs);
    assert!(f.token_match.is_finite(), "fidelity must never be NaN");
    assert!((0.0..=1.0).contains(&f.token_match));
    // the harness owns the A/B, so it attaches the measured delta
    core.record_shared_selection_fidelity(f.token_match);
    let shared_metrics = core.report().metrics;

    assert_eq!(shared_metrics.shared_selection_fidelity.n, 1);
    assert!((shared_metrics.shared_selection_token_match() - f.token_match).abs() < 1e-12);
    let drop_pts = shared_metrics.shared_selection_drop_pts();
    assert!(drop_pts.is_finite() && drop_pts >= 0.0);
    let j = shared_metrics.to_json();
    assert!(j.get("shared_selection_fidelity").is_some());
    assert!(j.get("shared_selection_drop_pts").is_some());
}

#[test]
fn serve_loop_rejects_chunks_beyond_compiled_seq_len() {
    let mut model = tiny_model();
    let max_seq = model.dims().max_seq;
    let err = match ServeLoop::new(&mut model, cfg("vanilla", max_seq + 1, 2)) {
        Ok(_) => panic!("chunk beyond max_seq must be rejected"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("sequence length"));
    // at the boundary it is accepted
    assert!(ServeLoop::new(&mut model, cfg("vanilla", max_seq, 2)).is_ok());
}
