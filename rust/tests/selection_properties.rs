//! Cross-algorithm properties on realistic (generator-produced) gate
//! scores — relations between the paper's algorithms that no single-module
//! unit test covers.

use xshare::ep::{Placement, PlacementKind};
use xshare::gen::{batch_scores, Domain, GatingParams};
use xshare::selection::{
    PolicyKind, ScoreMatrix, SelectionContext, SelectionPolicy,
};

fn scores(n_experts: usize, requests: usize, toks: usize, seed: u64)
    -> (ScoreMatrix, ScoreMatrix, Vec<Vec<usize>>)
{
    let params = GatingParams::default_for(n_experts);
    let domains: Vec<Domain> =
        (0..3).map(|d| Domain::new(&format!("d{d}"), n_experts, 40 + d as u64)).collect();
    let refs: Vec<&Domain> = (0..requests).map(|i| &domains[i % 3]).collect();
    batch_scores(&params, &refs, toks, seed)
}

fn ctx<'a>(
    probs: &'a ScoreMatrix,
    logits: &'a ScoreMatrix,
    rows: &'a [usize],
    groups: &'a [Vec<usize>],
    placement: Option<&'a Placement>,
) -> SelectionContext<'a> {
    SelectionContext {
        probs,
        logits,
        rows,
        requests: groups,
        colsum_hint: None,
        placement,
        top_k: 4,
    }
}

#[test]
fn activation_monotone_in_batch_budget() {
    for seed in 0..10 {
        let (logits, probs, groups) = scores(128, 4, 4, seed);
        let rows: Vec<usize> = (0..probs.n_tokens()).collect();
        let mut last = 0usize;
        for m in [0usize, 8, 16, 32, 64] {
            let p = PolicyKind::BatchAware { budget: m, k0: 1 }.build();
            let sel = p.select(&ctx(&probs, &logits, &rows, &groups, None));
            assert!(sel.len() >= last, "budget {m}: |S| shrank");
            last = sel.len();
        }
    }
}

#[test]
fn spec_aware_with_zero_request_budget_contains_warmup_of_batch_aware() {
    // With m_r=0 and m=0, Algorithm 4 degenerates to the union of per-token
    // warm-ups — identical to Algorithm 2's warm-up-only configuration.
    for seed in 10..20 {
        let (logits, probs, groups) = scores(64, 3, 4, seed);
        let rows: Vec<usize> = (0..probs.n_tokens()).collect();
        let spec = PolicyKind::SpecAware { k0: 1, batch_budget: 0, req_budget: 0 }.build();
        let batch = PolicyKind::BatchAware { budget: 0, k0: 1 }.build();
        let s1 = spec.select(&ctx(&probs, &logits, &rows, &groups, None));
        let s2 = batch.select(&ctx(&probs, &logits, &rows, &groups, None));
        assert_eq!(s1.to_vec(), s2.to_vec(), "seed {seed}");
    }
}

#[test]
fn hierarchical_budget_never_exceeds_flat_budget_activation() {
    // Per-request budgets concentrate on shared experts within requests:
    // |S(hier, mr)| ≤ requests × (warm + mr), and on correlated scores the
    // hierarchical set captures more per-request mass than the flat set of
    // the same size (checked as average over seeds).
    let mut hier_mass = 0.0f64;
    let mut flat_mass = 0.0f64;
    for seed in 20..40 {
        let (logits, probs, groups) = scores(128, 4, 4, seed);
        let rows: Vec<usize> = (0..probs.n_tokens()).collect();
        let hier = PolicyKind::SpecAware { k0: 0, batch_budget: 0, req_budget: 4 }.build();
        let s_h = hier.select(&ctx(&probs, &logits, &rows, &groups, None));
        let flat = PolicyKind::BatchAware { budget: s_h.len(), k0: 0 }.build();
        let s_f = flat.select(&ctx(&probs, &logits, &rows, &groups, None));
        assert!(s_f.len() >= s_h.len());
        // per-request captured mass
        let mass = |s: &xshare::selection::ExpertSet| -> f64 {
            groups
                .iter()
                .flat_map(|g| g.iter())
                .map(|&i| s.iter().map(|j| probs.get(i, j) as f64).sum::<f64>())
                .sum()
        };
        hier_mass += mass(&s_h);
        flat_mass += mass(&s_f) * s_h.len() as f64 / s_f.len() as f64;
    }
    // hierarchical should be competitive per selected expert
    assert!(
        hier_mass > 0.8 * flat_mass,
        "hierarchical mass {hier_mass:.2} vs size-normalized flat {flat_mass:.2}"
    );
}

#[test]
fn gpu_aware_never_worse_maxload_than_batch_aware_same_size() {
    for seed in 40..55 {
        let (logits, probs, groups) = scores(128, 4, 4, seed);
        let rows: Vec<usize> = (0..probs.n_tokens()).collect();
        let placement = Placement::new(128, 8, PlacementKind::Contiguous);
        let gpu = PolicyKind::GpuAware { k0: 1, per_gpu_budget: 3 }.build();
        let s_g = gpu.select(&ctx(&probs, &logits, &rows, &groups, Some(&placement)));
        let batch = PolicyKind::BatchAware { budget: s_g.len(), k0: 1 }.build();
        let s_b = batch.select(&ctx(&probs, &logits, &rows, &groups, Some(&placement)));
        assert!(
            placement.max_load(&s_g) <= placement.max_load(&s_b).max(1),
            "seed {seed}: gpu-aware {} > batch-aware {}",
            placement.max_load(&s_g),
            placement.max_load(&s_b)
        );
    }
}

#[test]
fn all_policies_route_within_their_selection_and_deterministically() {
    let (logits, probs, groups) = scores(64, 3, 3, 99);
    let rows: Vec<usize> = (0..probs.n_tokens()).collect();
    let placement = Placement::new(64, 4, PlacementKind::RoundRobin);
    for spec in [
        "vanilla",
        "batch:8:1",
        "spec:1:4:2",
        "gpu:1:3",
        "lynx:4",
        "skip:0.5",
        "opp:2",
    ] {
        let policy = PolicyKind::parse(spec).unwrap().build();
        let c = ctx(&probs, &logits, &rows, &groups, Some(&placement));
        let r1 = policy.route(&c);
        let c2 = ctx(&probs, &logits, &rows, &groups, Some(&placement));
        let r2 = policy.route(&c2);
        assert_eq!(r1.gates.flat(), r2.gates.flat(), "{spec}: nondeterministic");
        for (i, chosen) in r1.chosen.iter().enumerate() {
            assert!(chosen.len() <= 4, "{spec}: token {i} over top-k");
            for &j in chosen {
                assert!(r1.activated.contains(j));
            }
        }
    }
}
