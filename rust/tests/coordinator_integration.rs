//! End-to-end coordinator tests over the tiny artifact preset: continuous
//! batching, policy selection on the request path, and the speculative
//! verify cycle. The strongest check: greedy speculative decoding with the
//! vanilla policy is **lossless**, so its outputs must equal the plain
//! vanilla run token-for-token.

use xshare::config::ServeConfig;
use xshare::coordinator::{compare, Request, Scheduler};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn tiny_cfg() -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        max_new_tokens: 6,
        ..Default::default()
    }
}

fn trace(n: usize, max_new: usize) -> Vec<Request> {
    let g = TraceGenerator::new(64, 7);
    g.generate(&TraceDomain::standard_suite(), n)
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(5);
            let mut r = Request::new(t.id, prompt, max_new);
            r.domain = t.domain;
            r
        })
        .collect()
}

#[test]
fn plain_vanilla_run_completes_and_is_deterministic() {
    let mut model = tiny_model();
    let cfg = tiny_cfg();
    let a = Scheduler::new(&mut model, cfg.clone()).unwrap().run(trace(6, 6)).unwrap();
    assert_eq!(a.outputs.len(), 6);
    for (_, toks) in &a.outputs {
        assert_eq!(toks.len(), 6);
        assert!(toks.iter().all(|&t| (t as usize) < 64));
    }
    assert!(a.metrics.tokens_out >= 36);
    assert!(a.metrics.otps() > 0.0);
    assert!(a.metrics.mean_activated() > 0.0);

    let b = Scheduler::new(&mut model, cfg).unwrap().run(trace(6, 6)).unwrap();
    assert_eq!(a.outputs, b.outputs, "same trace + seed must be bit-identical");
}

#[test]
fn batch_aware_policy_reduces_activation() {
    let mut model = tiny_model();
    let mut cfg = tiny_cfg();
    let base = Scheduler::new(&mut model, cfg.clone()).unwrap().run(trace(8, 6)).unwrap();

    cfg.policy = PolicyKind::parse("batch:2:1").unwrap();
    let tight = Scheduler::new(&mut model, cfg).unwrap().run(trace(8, 6)).unwrap();

    assert!(
        tight.metrics.mean_activated() <= base.metrics.mean_activated(),
        "batch-aware {} vs vanilla {}",
        tight.metrics.mean_activated(),
        base.metrics.mean_activated()
    );
    // restricted routing still produces full-length outputs
    assert_eq!(tight.outputs.len(), 8);
    // and correlates with the baseline behaviour
    let f = compare(&base.outputs, &tight.outputs);
    assert!(f.token_match > 0.2, "fidelity collapsed: {f:?}");
}

#[test]
fn speculative_vanilla_is_lossless() {
    let mut model = tiny_model();
    let mut cfg = tiny_cfg();
    cfg.batch_size = 3;
    let plain = Scheduler::new(&mut model, cfg.clone()).unwrap().run(trace(5, 6)).unwrap();

    cfg.spec_len = 2;
    let spec = Scheduler::new(&mut model, cfg).unwrap().run(trace(5, 6)).unwrap();

    assert_eq!(
        plain.outputs, spec.outputs,
        "greedy speculative decoding with vanilla routing must be lossless"
    );
    assert!(spec.metrics.spec_proposed > 0);
    // acceptance can be low for an untrained draft, but the machinery must
    // at least commit one token per request per cycle
    assert_eq!(spec.metrics.tokens_out, plain.metrics.tokens_out);
}

#[test]
fn speculative_with_spec_aware_policy_completes() {
    let mut model = tiny_model();
    let mut cfg = tiny_cfg();
    cfg.batch_size = 3;
    cfg.spec_len = 2;
    cfg.policy = PolicyKind::parse("spec:1:0:2").unwrap();
    let report = Scheduler::new(&mut model, cfg).unwrap().run(trace(5, 5)).unwrap();
    assert_eq!(report.outputs.len(), 5);
    for (_, toks) in &report.outputs {
        assert_eq!(toks.len(), 5);
    }
    assert!(report.metrics.acceptance_rate() <= 1.0);
}

#[test]
fn ep_run_records_gpu_load() {
    let mut model = tiny_model();
    let mut cfg = tiny_cfg();
    cfg.ep = Some(xshare::config::EpConfig {
        n_gpus: 2,
        placement: xshare::ep::PlacementKind::Contiguous,
    });
    cfg.policy = PolicyKind::parse("gpu:1:2").unwrap();
    let report = Scheduler::new(&mut model, cfg).unwrap().run(trace(4, 4)).unwrap();
    assert_eq!(report.outputs.len(), 4);
    assert!(report.metrics.max_gpu_load.n > 0);
    // per-GPU load can never exceed the experts on one GPU (4 of 8)
    assert!(report.metrics.max_gpu_load.max <= 4.0);
}

#[test]
fn queue_longer_than_slots_drains() {
    let mut model = tiny_model();
    let mut cfg = tiny_cfg();
    cfg.batch_size = 2; // 10 requests through 2 slots
    let report = Scheduler::new(&mut model, cfg).unwrap().run(trace(10, 3)).unwrap();
    assert_eq!(report.outputs.len(), 10);
    assert_eq!(report.metrics.requests_done, 10);
}
