//! Model-walker integration over the tiny preset: step semantics, routing
//! modes, cache behaviour and score plumbing — the contract the scheduler
//! builds on.

use xshare::model::{MoeModel, RoutingMode, StepInput};
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::{baselines::Vanilla, ExpertSet, PolicyKind};

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn step_tokens(model: &MoeModel) -> (Vec<i32>, Vec<i32>, Vec<usize>) {
    let b = model.max_batch();
    let tokens: Vec<i32> = (0..b as i32).map(|i| (i + 3) % 60).collect();
    let pos = vec![0i32; b];
    let rows: Vec<usize> = (0..b).collect();
    (tokens, pos, rows)
}

#[test]
fn step_is_deterministic_after_reset() {
    let mut model = tiny_model();
    let (tokens, pos, rows) = step_tokens(&model);
    let groups: Vec<Vec<usize>> = rows.iter().map(|&r| vec![r]).collect();
    let vanilla = Vanilla;
    let mk_input = || StepInput {
        tokens: &tokens,
        pos: &pos,
        rows: &rows,
        requests: &groups,
        mode: RoutingMode::Policy(&vanilla),
        collect_probs: false,
    };
    let a = model.step(&mk_input()).unwrap();
    model.reset();
    let b = model.step(&mk_input()).unwrap();
    assert_eq!(a.logits.as_f32().unwrap(), b.logits.as_f32().unwrap());
    assert_eq!(a.activated, b.activated);
}

#[test]
fn restricted_to_full_set_equals_vanilla() {
    let mut model = tiny_model();
    let n = model.dims().n_experts;
    let n_layers = model.dims().n_layers;
    let (tokens, pos, rows) = step_tokens(&model);
    let groups: Vec<Vec<usize>> = rows.iter().map(|&r| vec![r]).collect();
    let vanilla = Vanilla;

    let a = model
        .step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Policy(&vanilla),
            collect_probs: false,
        })
        .unwrap();
    model.reset();
    let full: Vec<ExpertSet> = (0..n_layers).map(|_| ExpertSet::full(n)).collect();
    let b = model
        .step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Restricted(&full),
            collect_probs: false,
        })
        .unwrap();
    assert_eq!(a.logits.as_f32().unwrap(), b.logits.as_f32().unwrap());
}

#[test]
fn restriction_changes_output_and_activation() {
    let mut model = tiny_model();
    let n = model.dims().n_experts;
    let n_layers = model.dims().n_layers;
    let (tokens, pos, rows) = step_tokens(&model);
    let groups: Vec<Vec<usize>> = rows.iter().map(|&r| vec![r]).collect();
    let vanilla = Vanilla;
    let a = model
        .step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Policy(&vanilla),
            collect_probs: false,
        })
        .unwrap();
    model.reset();
    // restrict every layer to experts {0, 1}
    let small: Vec<ExpertSet> =
        (0..n_layers).map(|_| ExpertSet::from_indices(n, &[0, 1])).collect();
    let b = model
        .step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Restricted(&small),
            collect_probs: false,
        })
        .unwrap();
    assert!(b.activated.iter().all(|&a| a <= 2));
    assert_ne!(a.logits.as_f32().unwrap(), b.logits.as_f32().unwrap());
}

#[test]
fn policy_mode_respects_batch_aware_budget() {
    let mut model = tiny_model();
    let (tokens, pos, rows) = step_tokens(&model);
    let groups: Vec<Vec<usize>> = rows.iter().map(|&r| vec![r]).collect();
    let policy = PolicyKind::parse("batch:1:1").unwrap().build();
    let out = model
        .step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Policy(policy.as_ref()),
            collect_probs: false,
        })
        .unwrap();
    // |S| ≤ |warm-up (≤ B distinct)| + 1
    let b = model.max_batch();
    for &a in &out.activated {
        assert!(a <= b + 1, "activated {a} exceeds warmup+budget bound");
    }
}

#[test]
fn collect_probs_returns_layer_scores() {
    let mut model = tiny_model();
    let n = model.dims().n_experts;
    let n_layers = model.dims().n_layers;
    let (tokens, pos, rows) = step_tokens(&model);
    let groups: Vec<Vec<usize>> = rows.iter().map(|&r| vec![r]).collect();
    let vanilla = Vanilla;
    let out = model
        .step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Policy(&vanilla),
            collect_probs: true,
        })
        .unwrap();
    let scores = out.scores.expect("scores requested");
    assert_eq!(scores.len(), n_layers);
    for (logits, probs) in &scores {
        assert_eq!(logits.n_experts(), n);
        assert_eq!(probs.n_experts(), n);
        for i in &rows {
            let s: f32 = probs.row(*i).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "probs row sums to {s}");
        }
    }
}

#[test]
fn padding_rows_do_not_affect_live_rows() {
    let mut model = tiny_model();
    let b = model.max_batch();
    let vanilla = Vanilla;
    // run with only row 0 live; padding tokens vary wildly
    let rows = vec![0usize];
    let groups = vec![vec![0usize]];
    let pos = vec![0i32; b];
    let mut t1 = vec![0i32; b];
    t1[0] = 5;
    let a = model
        .step(&StepInput {
            tokens: &t1,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Policy(&vanilla),
            collect_probs: false,
        })
        .unwrap();
    model.reset();
    let mut t2 = vec![42i32; b];
    t2[0] = 5;
    let c = model
        .step(&StepInput {
            tokens: &t2,
            pos: &pos,
            rows: &rows,
            requests: &groups,
            mode: RoutingMode::Policy(&vanilla),
            collect_probs: false,
        })
        .unwrap();
    let v = model.dims().vocab;
    assert_eq!(
        &a.logits.as_f32().unwrap()[0..v],
        &c.logits.as_f32().unwrap()[0..v],
        "padding rows leaked into live row 0"
    );
}

#[test]
fn step_rejects_bad_shapes() {
    let mut model = tiny_model();
    let vanilla = Vanilla;
    let err = model.step(&StepInput {
        tokens: &[0],
        pos: &[0],
        rows: &[0],
        requests: &[],
        mode: RoutingMode::Policy(&vanilla),
        collect_probs: false,
    });
    assert!(err.is_err());
}
