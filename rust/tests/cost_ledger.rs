//! Cost-ledger conservation suite (PR 10).
//!
//! The unified `cost::Ledger` is the single writer to the sim clock, and
//! every posted second carries a `Phase` attribution. This suite pins the
//! accounting identities end to end across the serving feature matrix —
//! selection policies × speculation (off / fixed / adaptive) × EP
//! (including a migration-drain run) × fused-vs-sequential prefill
//! charging × idle advances:
//!
//!  * `clock().to_bits() == attributed().to_bits()` — EXACT: the
//!    attribution shadow is accumulated by the identical chronological
//!    f64 additions as the clock, so no second is ever lost or invented;
//!  * Σ over `Phase::ALL` of `phase_seconds(p)` equals the clock to
//!    within float-regrouping slack (the per-phase array regroups the
//!    same summands);
//!  * `ServeMetrics::sim_seconds` and the five `time_*_s` fields are
//!    bit-equal mirrors of the ledger (assignment, never accumulation);
//!  * sim time stays deterministic: the same config + trace yields the
//!    same clock bits run over run (the bench pins in `serve_continuous`
//!    ride on this).

use xshare::config::{EpConfig, ServeConfig, SpecDraft};
use xshare::coordinator::{AdmissionKind, Request, ServeLoop};
use xshare::cost::Phase;
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn prompt_of(len: usize, seed: u64, vocab: u64) -> Vec<u32> {
    (0..len as u64).map(|i| ((seed.wrapping_mul(31) + i * 7 + 3) % vocab) as u32).collect()
}

fn base_cfg() -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        max_new_tokens: 6,
        ..Default::default()
    }
}

fn trace(vocab: u64, n: u64) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let mut r = Request::new(id, prompt_of(3 + (id % 3) as usize, 40 + id, vocab), 8);
            r.domain = if id % 2 == 0 { "clA".into() } else { "clB".into() };
            r
        })
        .collect()
}

/// The conservation identities every run must satisfy, checked against a
/// live loop (ledger + metrics still attached). Returns the final clock.
fn assert_conserved(core: &ServeLoop, label: &str) -> f64 {
    let l = core.ledger();
    let clock = l.clock();
    assert!(clock > 0.0, "[{label}] run charged no sim time at all");
    // exact: the attribution shadow repeats the clock's chronological adds
    assert_eq!(
        clock.to_bits(),
        l.attributed().to_bits(),
        "[{label}] attributed seconds diverged from the clock: {} vs {clock}",
        l.attributed()
    );
    // regrouped: per-phase totals sum to the clock within float slack
    let phase_sum: f64 = Phase::ALL.iter().map(|&p| l.phase_seconds(p)).sum();
    assert!(
        (phase_sum - clock).abs() <= 1e-12 * clock.max(1.0),
        "[{label}] phase sum {phase_sum} != clock {clock}"
    );
    // metrics are bit-equal mirrors (assigned from the ledger, never
    // accumulated independently)
    let m = core.metrics();
    assert_eq!(m.sim_seconds.to_bits(), clock.to_bits(), "[{label}] sim_seconds mirror");
    assert_eq!(
        m.time_decode_s.to_bits(),
        l.phase_seconds(Phase::Decode).to_bits(),
        "[{label}] time_decode_s mirror"
    );
    let spec = l.phase_seconds(Phase::SpecVerify) + l.phase_seconds(Phase::SpecDraft);
    assert_eq!(m.time_spec_s.to_bits(), spec.to_bits(), "[{label}] time_spec_s mirror");
    assert_eq!(
        m.time_prefill_s.to_bits(),
        l.phase_seconds(Phase::PrefillWave).to_bits(),
        "[{label}] time_prefill_s mirror"
    );
    assert_eq!(
        m.time_migration_s.to_bits(),
        l.phase_seconds(Phase::MigrationDrain).to_bits(),
        "[{label}] time_migration_s mirror"
    );
    assert_eq!(
        m.time_overhead_s.to_bits(),
        l.phase_seconds(Phase::Overhead).to_bits(),
        "[{label}] time_overhead_s mirror"
    );
    // drained migration traffic is double-booked (gauge + phase) from the
    // same per-step summands, so the two agree bit-for-bit as well
    assert_eq!(
        m.migration_seconds.to_bits(),
        l.phase_seconds(Phase::MigrationDrain).to_bits(),
        "[{label}] migration_seconds gauge vs MigrationDrain phase"
    );
    clock
}

/// Serve `requests` upfront through a fresh loop, run the conservation
/// checks, and hand back (clock, per-phase seconds).
fn run_conserved(
    model: &mut MoeModel,
    c: ServeConfig,
    requests: &[Request],
    label: &str,
    setup: impl FnOnce(&mut ServeLoop),
) -> (f64, [f64; Phase::ALL.len()]) {
    let mut core = ServeLoop::new(model, c).expect("serve loop");
    setup(&mut core);
    for r in requests {
        core.submit(r.clone()).unwrap();
    }
    core.drain().unwrap();
    let clock = assert_conserved(&core, label);
    let mut phases = [0.0; Phase::ALL.len()];
    for (i, &p) in Phase::ALL.iter().enumerate() {
        phases[i] = core.ledger().phase_seconds(p);
    }
    (clock, phases)
}

#[test]
fn conservation_holds_across_policies_and_spec_modes() {
    // The full policy × speculation grid: whatever the selection policy
    // charges and whatever depth the controller picks, every second lands
    // in the ledger with a phase tag and nothing else moves the clock.
    // Model drafts (the default source) always fill the configured depth,
    // so the fixed-depth arms are guaranteed to exercise the verify AND
    // draft phases under every policy.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let requests = trace(vocab, 6);

    for policy in ["vanilla", "batch:24:1", "spec:1:0:4"] {
        for (spec_len, adaptive) in [(0usize, false), (3, false), (3, true)] {
            let mut c = base_cfg();
            c.policy = PolicyKind::parse(policy).expect("policy");
            c.spec_len = spec_len;
            c.spec_adaptive = adaptive;
            let label = format!("{policy}/spec={spec_len}/adaptive={adaptive}");
            let (_, phases) = run_conserved(&mut model, c, &requests, &label, |_| {});
            if spec_len == 0 {
                assert_eq!(
                    phases[Phase::SpecVerify.index()],
                    0.0,
                    "[{label}] spec time charged with speculation off"
                );
                assert!(phases[Phase::Decode.index()] > 0.0, "[{label}] no decode time");
            } else if !adaptive {
                assert!(
                    phases[Phase::SpecVerify.index()] > 0.0,
                    "[{label}] fixed-depth speculation charged no verify time"
                );
                assert!(
                    phases[Phase::SpecDraft.index()] > 0.0,
                    "[{label}] model drafting charged no draft time"
                );
            }
            assert!(
                phases[Phase::PrefillWave.index()] > 0.0,
                "[{label}] prompts charged no prefill time"
            );
        }
    }
}

#[test]
fn conservation_holds_under_ep_with_migration_drain() {
    // EP charging path, including the deferred-charge machinery: adopted
    // migration plans post transfer seconds into the ledger's backlog and
    // subsequent steps drain them as MigrationDrain phase time. The
    // skewed two-class trace is the one the migration planner acts on.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let requests: Vec<Request> = (0..8u64)
        .map(|id| {
            let mut r = Request::new(id, prompt_of(3, (id % 2) * 37 + 11, vocab), 5);
            r.domain = if id % 2 == 0 { "mgA".into() } else { "mgB".into() };
            r
        })
        .collect();

    let mut c = base_cfg();
    c.policy = PolicyKind::parse("vanilla").expect("policy");
    c.batch_size = 2;
    c.max_new_tokens = 5;
    c.admission = AdmissionKind::FootprintAware;
    c.ep = Some(EpConfig { n_gpus: 2, placement: xshare::ep::PlacementKind::Contiguous });
    c.ep_rebalance = 1;
    c.ep_migrate_budget = 2;
    c.ep_replica_slack = 2.0;

    let mut core = ServeLoop::new(&mut model, c).expect("serve loop");
    for r in &requests {
        core.submit(r.clone()).unwrap();
    }
    core.drain().unwrap();
    assert_conserved(&core, "ep+migration");
    if core.metrics().migrations > 0 {
        // adopted plans defer their transfer seconds into the ledger
        // backlog; what the steps drained is phase-attributed and the
        // undrained remainder is still held by the ledger — nothing leaks
        let drained = core.ledger().phase_seconds(Phase::MigrationDrain);
        let held = core.ledger().migration_backlog();
        assert!(
            drained + held > 0.0,
            "plans were adopted but no transfer seconds reached the ledger"
        );
    } else {
        assert_eq!(core.ledger().phase_seconds(Phase::MigrationDrain), 0.0);
        assert_eq!(core.ledger().migration_backlog(), 0.0);
    }
    // a plain EP run (no rebalancing) conserves with a silent drain phase
    let mut c2 = base_cfg();
    c2.batch_size = 2;
    c2.ep = Some(EpConfig { n_gpus: 2, placement: xshare::ep::PlacementKind::Contiguous });
    let (_, phases) = run_conserved(&mut model, c2, &requests, "ep-plain", |_| {});
    assert_eq!(phases[Phase::MigrationDrain.index()], 0.0);
}

#[test]
fn conservation_holds_for_fused_and_sequential_prefill_charging() {
    // The PR 8 charging split: chunked prefill billed as fused waves vs
    // the sequential per-row instrumentation path. Both go through the
    // ledger (PrefillWave entries) and both conserve; they price the same
    // work differently, which is exactly why each needs its own run here.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let requests = trace(vocab, 5);

    let mut chunked = base_cfg();
    chunked.prefill_chunk = 4;
    let (fused_clock, fused_phases) =
        run_conserved(&mut model, chunked.clone(), &requests, "fused-waves", |_| {});
    assert!(fused_phases[Phase::PrefillWave.index()] > 0.0);

    let (seq_clock, seq_phases) =
        run_conserved(&mut model, chunked, &requests, "sequential-charging", |core| {
            core.set_sequential_prefill_charging(true);
        });
    assert!(seq_phases[Phase::PrefillWave.index()] > 0.0);
    // fused waves stream each layer's weights once per wave instead of
    // once per row — strictly cheaper on multi-row waves
    assert!(
        fused_clock < seq_clock,
        "fused waves ({fused_clock}s) must undercut sequential charging ({seq_clock}s)"
    );
}

#[test]
fn idle_advance_charges_overhead_and_conserves() {
    // Clock jumps to a later arrival go through Ledger::advance_to and
    // are attributed to Phase::Overhead — visible in the metrics mirror
    // and still covered by the conservation identities.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;

    let mut core = ServeLoop::new(&mut model, base_cfg()).expect("serve loop");
    core.submit(Request::new(0, prompt_of(3, 7, vocab), 4)).unwrap();
    core.drain().unwrap();
    let busy = core.ledger().clock();
    assert_eq!(core.ledger().phase_seconds(Phase::Overhead), 0.0);

    // idle gap to a later arrival, then more work
    core.advance_idle_to(busy + 0.25);
    core.submit(Request::new(1, prompt_of(4, 9, vocab), 4)).unwrap();
    core.drain().unwrap();
    assert_conserved(&core, "idle-advance");
    let overhead = core.ledger().phase_seconds(Phase::Overhead);
    assert!((overhead - 0.25).abs() < 1e-12, "idle gap misattributed: {overhead}");
    assert_eq!(core.metrics().time_overhead_s.to_bits(), overhead.to_bits());
    // a backwards advance is a no-op
    let clock = core.ledger().clock();
    core.advance_idle_to(clock - 1.0);
    assert_eq!(core.ledger().clock().to_bits(), clock.to_bits());
}

#[test]
fn sim_clock_is_bit_deterministic_run_over_run() {
    // The refactor's headline guarantee, in the shape the benchmark
    // scenarios consume it: the same config over the same trace produces
    // the same sim clock BITS every run — per phase, not just in total.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let requests = trace(vocab, 6);

    let mk = || {
        let mut c = base_cfg();
        c.spec_len = 3;
        c.spec_adaptive = true;
        c.spec_draft = SpecDraft::Lookup;
        c.prefill_chunk = 2;
        c
    };
    let (clock_a, phases_a) = run_conserved(&mut model, mk(), &requests, "det-run-a", |_| {});
    let (clock_b, phases_b) = run_conserved(&mut model, mk(), &requests, "det-run-b", |_| {});
    assert_eq!(
        clock_a.to_bits(),
        clock_b.to_bits(),
        "sim clock drifted between identical runs: {clock_a} vs {clock_b}"
    );
    for (i, &p) in Phase::ALL.iter().enumerate() {
        assert_eq!(
            phases_a[i].to_bits(),
            phases_b[i].to_bits(),
            "phase {} seconds drifted between identical runs",
            p.name()
        );
    }
}
