//! Cross-language numerics anchor: replay the selftest vectors that
//! `python/compile/aot.py` computed with JAX through the rust PJRT engine
//! and assert allclose. This is the proof that the AOT bridge (HLO text →
//! xla_extension 0.5.1) preserves the model's semantics end to end.
//!
//! Requires `make artifacts` (tiny preset). Tests panic with a clear
//! message if artifacts are missing.

use xshare::runtime::{artifacts_root, Arg, DType, Engine, HostTensor, Manifest};

fn load_tiny() -> Engine {
    let dir = artifacts_root().join("tiny");
    let manifest = Manifest::load(&dir)
        .expect("tiny artifacts missing — run `make artifacts` before cargo test");
    Engine::load(manifest).expect("engine load")
}

fn assert_allclose(name: &str, got: &[f32], want: &[f32], atol: f32, rtol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length mismatch");
    let mut worst = 0.0f32;
    let mut worst_i = 0;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        let err = (g - w).abs();
        if err > tol && err > worst {
            worst = err;
            worst_i = i;
        }
    }
    assert!(
        worst == 0.0,
        "{name}: worst |err|={worst} at {worst_i}: got {} want {}",
        got[worst_i],
        want[worst_i]
    );
}

fn replay(engine: &Engine, program: &str) {
    let manifest = engine.manifest();
    let meta = manifest.program(program).unwrap().clone();
    let st = manifest.selftests.get(program).expect("selftest entry").clone();
    let dir = manifest.dir.clone();

    let inputs: Vec<HostTensor> = st
        .inputs
        .iter()
        .zip(&meta.params)
        .map(|(f, p)| HostTensor::read_bin(&dir.join(f), p.shape.clone(), p.dtype).unwrap())
        .collect();
    let args: Vec<Arg> = inputs.iter().map(Arg::Host).collect();
    let outputs = engine.execute(program, &args).unwrap();

    assert_eq!(outputs.len(), meta.outputs.len());
    for ((out, f), om) in outputs.iter().zip(&st.outputs).zip(&meta.outputs) {
        let want = HostTensor::read_bin(&dir.join(f), om.shape.clone(), DType::F32).unwrap();
        let got = match out {
            HostTensor::F32 { data, .. } => data.clone(),
            HostTensor::I32 { data, .. } => data.iter().map(|&v| v as f32).collect(),
        };
        assert_allclose(
            &format!("{program}:{}", om.name),
            &got,
            want.as_f32().unwrap(),
            1e-5,
            1e-4,
        );
    }
}

#[test]
fn selftest_embed() {
    replay(&load_tiny(), "embed");
}

#[test]
fn selftest_attn_router() {
    replay(&load_tiny(), "attn_router");
}

#[test]
fn selftest_moe_layer() {
    replay(&load_tiny(), "moe_layer");
}

#[test]
fn selftest_lm_head() {
    replay(&load_tiny(), "lm_head");
}

#[test]
fn selftest_prefill_attn_router() {
    let engine = load_tiny();
    assert!(
        engine.manifest().has_prefill(),
        "tiny artifacts predate the prefill program — re-run `make artifacts`"
    );
    replay(&engine, "prefill_attn_router");
}

#[test]
fn selftest_draft_step() {
    let engine = load_tiny();
    if engine.manifest().has_draft() {
        replay(&engine, "draft_step");
    }
}

#[test]
fn engine_rejects_shape_mismatch() {
    let engine = load_tiny();
    let meta = engine.manifest().program("embed").unwrap().clone();
    // wrong-shaped tokens
    let bad = HostTensor::i32(vec![meta.params[0].shape[0] + 1], vec![0; meta.params[0].shape[0] + 1]);
    let emb_meta = &meta.params[1];
    let emb = HostTensor::zeros_f32(emb_meta.shape.clone());
    let err = engine.execute("embed", &[Arg::Host(&bad), Arg::Host(&emb)]);
    assert!(err.is_err());
    assert!(format!("{:#}", err.unwrap_err()).contains("shape"));
}

#[test]
fn engine_rejects_wrong_arity() {
    let engine = load_tiny();
    let err = engine.execute("embed", &[]);
    assert!(err.is_err());
}

#[test]
fn engine_rejects_unknown_program() {
    let engine = load_tiny();
    assert!(engine.execute("nope", &[]).is_err());
}

#[test]
fn weights_bind_by_name() {
    // embed called with Arg::Weight("emb") must equal the selftest path when
    // given the same tokens as the vector... the selftest used random emb,
    // so here we just check the call succeeds and output shape is right.
    let engine = load_tiny();
    assert!(engine.has_weight("emb"));
    let b = engine.manifest().model.max_batch;
    let toks = HostTensor::i32(vec![b], vec![1; b]);
    let out = engine.execute("embed", &[Arg::Host(&toks), Arg::Weight("emb")]).unwrap();
    assert_eq!(out[0].shape(), &[b, engine.manifest().model.d_model]);
    // rows are identical since all tokens equal
    let d = engine.manifest().model.d_model;
    let data = out[0].as_f32().unwrap();
    assert_eq!(&data[0..d], &data[d..2 * d]);
}

#[test]
fn engine_stats_accumulate() {
    let engine = load_tiny();
    let b = engine.manifest().model.max_batch;
    let toks = HostTensor::i32(vec![b], vec![0; b]);
    let before = engine.stats().calls;
    engine.execute("embed", &[Arg::Host(&toks), Arg::Weight("emb")]).unwrap();
    engine.execute("embed", &[Arg::Host(&toks), Arg::Weight("emb")]).unwrap();
    let st = engine.stats();
    assert_eq!(st.calls, before + 2);
    assert!(st.host_bytes_in > 0 && st.host_bytes_out > 0);
    assert!(st.exec_seconds > 0.0);
}
