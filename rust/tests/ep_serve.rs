//! Expert-parallel serving suite (PR 5).
//!
//! Two contracts pinned here:
//!
//! 1. **EP charging is cost-only.** With `cfg.ep` set (and eviction /
//!    rebalance off), generated tokens and the final KV digest are
//!    byte-identical to the non-EP run under every selection policy that
//!    can run in both modes — staggered admission included. The placement
//!    reaches selection contexts, but only the `gpu` policy reads it (and
//!    that policy cannot run without EP, so it has no non-EP baseline to
//!    compare against); for everyone else EP must only move the sim
//!    clock. This is exactly what keeps the pre-PR EP-off path
//!    byte-identical: the EP arm of `charge_step` is unreachable without
//!    `cfg.ep`.
//!
//! 2. **Eviction/resume is lossless.** A row preempted back to the queue
//!    — mid-decode or mid-prefill — resumes by re-prefilling its
//!    committed history, and under row-independent routing the final
//!    outputs are byte-identical to an uninterrupted run (the
//!    eviction/resume KV contract in `model/moe_model.rs`).
//!
//! 3. **Replication & migration are cost-only (PR 6).** Replica-set
//!    placements, the incremental migration planner, and footprint
//!    prefetch may only move the sim clock: under a placement-blind
//!    policy the tokens (and, when admission order is pinned, the KV
//!    digest) stay byte-identical to the non-EP run, every adopted plan
//!    strictly improves expected MaxLoad, and per-plan copies never
//!    exceed `--ep-migrate-budget`. At `--ep-replica-slack 1.0` the
//!    residency caps are exactly the partition block sizes, so the
//!    planner can never act at all.
//!
//! 4. **Prefill-wave charging is cost-only (PR 8).** Fusing a round of
//!    co-prefilling chunk invocations into ONE EP charge over the unioned
//!    per-layer sets may only move the sim clock: tokens and the KV
//!    digest stay byte-identical to per-invocation charging, and the
//!    wave gauges partition the chunk forwards exactly.

use std::collections::BTreeMap;

use xshare::config::{EpConfig, ServeConfig};
use xshare::coordinator::{AdmissionKind, Request, Scheduler, ServeLoop};
use xshare::ep::PlacementKind;
use xshare::metrics::ServeMetrics;
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn cfg(policy: &str) -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        policy: PolicyKind::parse(policy).expect("policy"),
        batch_size: 2,
        max_new_tokens: 5,
        ..Default::default()
    }
}

fn ep2() -> Option<EpConfig> {
    Some(EpConfig { n_gpus: 2, placement: PlacementKind::Contiguous })
}

fn prompt_of(len: usize, seed: u64, vocab: u64) -> Vec<u32> {
    (0..len as u64).map(|i| ((seed.wrapping_mul(31) + i * 7 + 3) % vocab) as u32).collect()
}

fn trace(vocab: u64) -> Vec<Request> {
    (0..4u64)
        .map(|id| {
            let mut r = Request::new(id, prompt_of(3 + id as usize % 3, id + 5, vocab), 5);
            r.domain = if id % 2 == 0 { "evenA".into() } else { "oddB".into() };
            r
        })
        .collect()
}

/// Staggered admission drive: two requests up front, three steps, the rest
/// mid-flight, then drain. Returns (outputs, final metrics); the caller
/// reads the KV digest off the model afterwards.
fn run_staggered(
    model: &mut MoeModel,
    c: ServeConfig,
    reqs: &[Request],
) -> (BTreeMap<u64, Vec<u32>>, ServeMetrics) {
    run_staggered_with(model, c, reqs, false)
}

/// [`run_staggered`] with the pre-PR8 per-invocation prefill charging
/// toggled on demand (the wave-charging pin's control arm).
fn run_staggered_with(
    model: &mut MoeModel,
    c: ServeConfig,
    reqs: &[Request],
    sequential_prefill_charging: bool,
) -> (BTreeMap<u64, Vec<u32>>, ServeMetrics) {
    let mut core = ServeLoop::new(model, c).expect("serve loop");
    core.set_sequential_prefill_charging(sequential_prefill_charging);
    for r in &reqs[..2] {
        core.submit(r.clone()).unwrap();
    }
    for _ in 0..3 {
        core.step().unwrap();
    }
    for r in &reqs[2..] {
        core.submit(r.clone()).unwrap();
    }
    core.drain().unwrap();
    let report = core.report();
    (report.outputs, report.metrics)
}

#[test]
fn ep_charging_is_cost_only_never_routing_visible() {
    // Every policy shape that runs with and without EP (the `gpu` policy
    // is placement-dependent by design and refuses to run EP-off, so it
    // is the one exclusion). Tokens AND the full KV digest must match;
    // only the sim clock may move.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs = trace(vocab);
    for policy in ["vanilla", "batch:6:1", "spec:1:0:2", "lynx:2", "skip:0.3", "opp:1"] {
        let (base_out, base_metrics) = run_staggered(&mut model, cfg(policy), &reqs);
        let base_kv = model.kv_digest();
        for placement in [PlacementKind::Contiguous, PlacementKind::RoundRobin] {
            let mut c = cfg(policy);
            c.ep = Some(EpConfig { n_gpus: 2, placement });
            let (ep_out, ep_metrics) = run_staggered(&mut model, c, &reqs);
            let ep_kv = model.kv_digest();
            assert_eq!(
                ep_out, base_out,
                "[{policy} {placement:?}] EP charging changed generated tokens"
            );
            assert_eq!(
                ep_kv, base_kv,
                "[{policy} {placement:?}] EP charging changed KV state"
            );
            // …and the cost side is actually live: the straggler model
            // moved the sim clock and populated every EP gauge.
            assert!(
                (ep_metrics.sim_seconds - base_metrics.sim_seconds).abs() > 1e-12,
                "[{policy} {placement:?}] EP run never charged through the comm model"
            );
            assert!(ep_metrics.max_gpu_load.n > 0);
            assert_eq!(ep_metrics.gpu_loads.len(), 2);
            assert!(ep_metrics.gpu_loads.iter().all(|s| s.n > 0));
            assert!(ep_metrics.gpu_load_integral > 0.0);
            assert_eq!(base_metrics.gpu_load_integral, 0.0);
        }
    }
}

#[test]
fn ep_speculative_serving_matches_non_ep_byte_for_byte() {
    // The ragged-verify path under EP: lookup drafts, mixed phases. Cost
    // still must never leak into routing.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs: Vec<Request> = (0..3u64)
        .map(|id| Request::new(id, prompt_of(4, id + 11, vocab), 8))
        .collect();
    let mut base_cfg = cfg("vanilla");
    base_cfg.batch_size = 3;
    base_cfg.spec_len = 2;
    base_cfg.spec_draft = xshare::config::SpecDraft::Lookup;
    base_cfg.max_new_tokens = 8;
    let (base_out, base_metrics) = run_staggered(&mut model, base_cfg.clone(), &reqs);
    let mut ep_cfg = base_cfg;
    ep_cfg.ep = ep2();
    let (ep_out, ep_metrics) = run_staggered(&mut model, ep_cfg, &reqs);
    assert_eq!(ep_out, base_out, "EP verify cycles changed outputs");
    assert!((ep_metrics.sim_seconds - base_metrics.sim_seconds).abs() > 1e-12);
    assert!(ep_metrics.spec_accepted <= base_metrics.spec_proposed);
    assert_eq!(
        ep_metrics.spec_proposed, base_metrics.spec_proposed,
        "speculation planning must not see the cost model"
    );
}

#[test]
fn ep_wave_charging_is_cost_only_and_fuses_rounds() {
    // PR 8 under EP: fused wave charging routes each round's unioned
    // per-layer sets through the EP comm model ONCE instead of once per
    // co-prefilling row. Cost-only — tokens and the KV digest must stay
    // byte-identical to the sequentially-charged EP run — while the sim
    // clock moves and both the EP and the wave gauges stay live.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs: Vec<Request> = (0..4u64)
        .map(|id| Request::new(id, prompt_of(6 + id as usize % 2, id + 61, vocab), 4))
        .collect();
    let mut c = cfg("vanilla");
    c.batch_size = 3;
    c.prefill_chunk = 3;
    c.max_new_tokens = 4;
    c.ep = ep2();
    let (seq_out, seq_metrics) = run_staggered_with(&mut model, c.clone(), &reqs, true);
    let seq_kv = model.kv_digest();
    let (wave_out, wave_metrics) = run_staggered_with(&mut model, c, &reqs, false);
    let wave_kv = model.kv_digest();
    assert_eq!(wave_out, seq_out, "EP wave charging changed generated tokens");
    assert_eq!(wave_kv, seq_kv, "EP wave charging changed KV state");
    assert_eq!(seq_metrics.prefill_waves, 0, "sequential charging recorded waves");
    assert!(wave_metrics.prefill_waves > 0, "no waves under chunked EP prefill");
    assert_eq!(
        wave_metrics.prefill_forwards,
        wave_metrics.prefill_waves + wave_metrics.prefill_streams_saved,
        "wave/stream accounting must partition the chunk forwards"
    );
    assert_eq!(wave_metrics.prefill_forwards, seq_metrics.prefill_forwards);
    assert_eq!(wave_metrics.tokens_prompt, seq_metrics.tokens_prompt);
    // The fused charge is a different EP charge, not a skipped one.
    assert!(wave_metrics.gpu_load_integral > 0.0);
    assert!(wave_metrics.max_gpu_load.n > 0);
    assert!(
        wave_metrics.prefill_streams_saved == 0
            || (wave_metrics.sim_seconds - seq_metrics.sim_seconds).abs() > 1e-12,
        "fused rounds charged exactly like sequential despite saved streams"
    );
}

/// Uninterrupted baseline for the eviction pins: all requests through the
/// plain scheduler.
fn baseline_outputs(
    model: &mut MoeModel,
    c: ServeConfig,
    reqs: &[Request],
) -> BTreeMap<u64, Vec<u32>> {
    Scheduler::new(model, c)
        .expect("scheduler")
        .run(reqs.to_vec())
        .expect("run")
        .outputs
}

#[test]
fn forced_eviction_mid_decode_resumes_losslessly() {
    // Evict a row that has already committed tokens: it must re-enter the
    // queue, rebuild its KV by re-prefilling prompt + generated, and
    // finish with output byte-identical to the uninterrupted run.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs: Vec<Request> = (0..3u64)
        .map(|id| Request::new(id, prompt_of(3 + id as usize, id + 21, vocab), 6))
        .collect();
    let mut c = cfg("vanilla");
    c.max_new_tokens = 6;
    let base = baseline_outputs(&mut model, c.clone(), &reqs);

    let mut core = ServeLoop::new(&mut model, c).expect("serve loop");
    for r in &reqs {
        core.submit(r.clone()).unwrap();
    }
    // step until slot 0's row has committed at least one token (pos
    // reaches its prompt length exactly when the first token commits)
    let victim_prompt = reqs[0].prompt.len();
    let mut evicted_id = None;
    for _ in 0..64 {
        core.step().unwrap();
        if core.slot_pos(0).map(|p| p >= victim_prompt).unwrap_or(false) {
            evicted_id = core.evict_slot(0);
            break;
        }
    }
    let evicted_id = evicted_id.expect("victim row never reached decode");
    assert_eq!(evicted_id, 0, "slot 0 held request 0 (FIFO, lowest slot first)");
    core.drain().unwrap();
    let report = core.report();
    assert_eq!(report.metrics.evictions, 1);
    assert_eq!(
        report.outputs, base,
        "eviction/resume changed outputs under vanilla routing"
    );
    assert_eq!(report.outputs[&0].len(), 6, "resumed row lost part of its budget");
}

#[test]
fn forced_eviction_mid_prefill_resumes_losslessly() {
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs: Vec<Request> = (0..3u64)
        .map(|id| Request::new(id, prompt_of(5, id + 31, vocab), 4))
        .collect();
    let mut c = cfg("vanilla");
    c.max_new_tokens = 4;
    let base = baseline_outputs(&mut model, c.clone(), &reqs);

    let mut core = ServeLoop::new(&mut model, c).expect("serve loop");
    for r in &reqs {
        core.submit(r.clone()).unwrap();
    }
    core.step().unwrap(); // one token of prefill — mid-prompt
    assert!(core.slot_pos(0).unwrap() < 5, "row unexpectedly past prefill");
    assert_eq!(core.evict_slot(0), Some(0));
    core.drain().unwrap();
    let report = core.report();
    assert_eq!(report.metrics.evictions, 1);
    assert_eq!(report.outputs, base);
}

#[test]
fn planned_eviction_under_ep_keeps_vanilla_outputs() {
    // The full planner path (footprint admission + --ep-evict + EP
    // charging): whatever the planner decides, vanilla routing means the
    // served tokens per request cannot change vs plain FIFO serving.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut reqs: Vec<Request> = Vec::new();
    for id in 0..6u64 {
        let mut r = Request::new(id, prompt_of(3, (id % 2) * 17 + 3, vocab), 5);
        r.domain = if id % 2 == 0 { "clsA".into() } else { "clsB".into() };
        reqs.push(r);
    }
    let base = baseline_outputs(&mut model, cfg("vanilla"), &reqs);
    let mut c = cfg("vanilla");
    c.admission = AdmissionKind::FootprintAware;
    c.ep = ep2();
    c.ep_evict = true;
    let report = Scheduler::new(&mut model, c)
        .expect("scheduler")
        .run(reqs)
        .expect("run");
    assert_eq!(
        report.outputs, base,
        "footprint admission + eviction reordered work but must not change tokens"
    );
}

#[test]
fn rebalance_under_vanilla_is_cost_only_and_only_improves() {
    // Dynamic placement with a placement-blind policy: outputs must stay
    // byte-identical to the static-placement run, and every ADOPTED
    // rebalance must have strictly improved expected MaxLoad (the serve
    // loop discards non-improving candidates).
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut reqs: Vec<Request> = Vec::new();
    for id in 0..8u64 {
        let mut r = Request::new(id, prompt_of(3, (id % 2) * 29 + 7, vocab), 5);
        r.domain = if id % 2 == 0 { "rebA".into() } else { "rebB".into() };
        reqs.push(r);
    }
    let mut static_cfg = cfg("vanilla");
    static_cfg.admission = AdmissionKind::FootprintAware;
    static_cfg.ep = ep2();
    let static_out = Scheduler::new(&mut model, static_cfg.clone())
        .expect("scheduler")
        .run(reqs.clone())
        .expect("run")
        .outputs;
    let mut dyn_cfg = static_cfg;
    dyn_cfg.ep_rebalance = 1; // every free
    let report = Scheduler::new(&mut model, dyn_cfg)
        .expect("scheduler")
        .run(reqs)
        .expect("run");
    assert_eq!(
        report.outputs, static_out,
        "placement rebalancing leaked into vanilla routing"
    );
    if report.metrics.rebalances > 0 {
        assert!(
            report.metrics.rebalance_delta.min > 0.0,
            "adopted a rebalance that did not improve expected MaxLoad"
        );
    }
}

#[test]
fn replicated_migration_and_prefetch_keep_vanilla_outputs() {
    // The full PR 6 stack — replica slack, incremental migration, and
    // footprint prefetch — against the plain non-EP FIFO baseline. Under
    // vanilla routing no placement decision may touch tokens; every
    // adopted plan must have strictly improved expected MaxLoad and must
    // fit the per-plan op budget.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut reqs: Vec<Request> = Vec::new();
    for id in 0..8u64 {
        let mut r = Request::new(id, prompt_of(3, (id % 2) * 41 + 13, vocab), 5);
        r.domain = if id % 2 == 0 { "migA".into() } else { "migB".into() };
        reqs.push(r);
    }
    let base = baseline_outputs(&mut model, cfg("vanilla"), &reqs);
    let mut c = cfg("vanilla");
    c.admission = AdmissionKind::FootprintAware;
    c.ep = ep2();
    c.ep_rebalance = 1;
    c.ep_replica_slack = 2.0;
    c.ep_migrate_budget = 2;
    c.ep_prefetch = true;
    let report = Scheduler::new(&mut model, c)
        .expect("scheduler")
        .run(reqs)
        .expect("run");
    assert_eq!(
        report.outputs, base,
        "replication/migration/prefetch leaked into vanilla routing"
    );
    // Budget > 0 routes every rebalance tick through the migration
    // planner; the legacy whole-placement swap must never fire.
    assert_eq!(report.metrics.rebalances, 0, "swap path ran in migration mode");
    assert!(report.metrics.prefetches <= report.metrics.migrations);
    if report.metrics.migrations > 0 {
        assert!(
            report.metrics.migration_ops.max <= 2.0,
            "a plan exceeded --ep-migrate-budget"
        );
        assert!(report.metrics.migration_bytes > 0.0, "copies moved no weight bytes");
        assert!(
            report.metrics.migration_seconds > 0.0,
            "adopted migrations were never charged to the sim clock"
        );
        assert!(
            report.metrics.rebalance_delta.min > 0.0,
            "adopted a plan that did not improve expected MaxLoad"
        );
    }
}

#[test]
fn replication_stack_is_kv_byte_identical_on_uniform_traffic() {
    // Single-class traffic pins the admission order itself: every queued
    // candidate predicts the same footprint, score ties resolve FIFO, so
    // the non-EP and full-replication arms admit identically and even the
    // KV digest must match byte for byte — only the sim clock may move.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs: Vec<Request> = (0..6u64)
        .map(|id| {
            let mut r = Request::new(id, prompt_of(3 + id as usize % 2, id + 51, vocab), 5);
            r.domain = "mono".into();
            r
        })
        .collect();
    let mut base_cfg = cfg("vanilla");
    base_cfg.admission = AdmissionKind::FootprintAware;
    let (base_out, base_metrics) = run_staggered(&mut model, base_cfg.clone(), &reqs);
    let base_kv = model.kv_digest();
    let mut c = base_cfg;
    c.ep = ep2();
    c.ep_rebalance = 1;
    c.ep_replica_slack = 2.0;
    c.ep_migrate_budget = 2;
    c.ep_prefetch = true;
    let (out, metrics) = run_staggered(&mut model, c, &reqs);
    let kv = model.kv_digest();
    assert_eq!(out, base_out, "replication stack changed generated tokens");
    assert_eq!(kv, base_kv, "replication stack changed KV state");
    assert!(
        (metrics.sim_seconds - base_metrics.sim_seconds).abs() > 1e-12,
        "EP arm never charged through the comm model"
    );
}

#[test]
fn slack_one_caps_the_partition_so_nothing_can_migrate() {
    // tiny = 8 experts on 2 GPUs: at slack 1.0 the residency cap is
    // exactly the contiguous block size (4), every GPU is at cap, and the
    // planner has no legal copy — end to end, migrations must be zero and
    // the run must behave like static placement.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut reqs: Vec<Request> = Vec::new();
    for id in 0..6u64 {
        let mut r = Request::new(id, prompt_of(3, (id % 2) * 23 + 9, vocab), 5);
        r.domain = if id % 2 == 0 { "capA".into() } else { "capB".into() };
        reqs.push(r);
    }
    let base = baseline_outputs(&mut model, cfg("vanilla"), &reqs);
    let mut c = cfg("vanilla");
    c.admission = AdmissionKind::FootprintAware;
    c.ep = ep2();
    c.ep_rebalance = 1;
    c.ep_migrate_budget = 2; // planner armed, but the cap starves it
    let report = Scheduler::new(&mut model, c)
        .expect("scheduler")
        .run(reqs)
        .expect("run");
    assert_eq!(report.outputs, base);
    assert_eq!(report.metrics.migrations, 0, "copied a replica past a full cap");
    assert_eq!(report.metrics.migration_bytes, 0.0);
    assert_eq!(report.metrics.migration_seconds, 0.0);
    assert_eq!(report.metrics.prefetches, 0);
}
