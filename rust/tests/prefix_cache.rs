//! Shared-prefix KV cache suite (PR 7). Three pins:
//!
//!  * **warm ≡ cold**: restoring a cached prefix slab and prefilling only
//!    the suffix leaves byte-identical KV (`kv_row_digest`) and logits as
//!    a cold chunk prefill of the whole prompt — across selection policies
//!    and chunk sizes (the cache-restore KV contract in
//!    `model/moe_model.rs`).
//!  * **serving equivalence**: a ServeLoop with the cache on produces
//!    byte-identical outputs to one with it off, while actually hitting
//!    (warm turn-2 traffic) and restoring instead of recomputing on
//!    eviction resume.
//!  * **accounting fixes**: queue-wait is recorded per stint (incremental
//!    on requeue, never double or dropped) and TTFT fires exactly once per
//!    request from its ORIGINAL submission — including across mid-prefill
//!    eviction and slot reuse.

use xshare::config::ServeConfig;
use xshare::coordinator::prefix_cache::PrefixCache;
use xshare::coordinator::{Request, Scheduler, ServeLoop};
use xshare::model::{MoeModel, PrefillInput};
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;
use xshare::util::check::forall;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    assert!(
        manifest.has_prefill(),
        "tiny artifacts predate the prefill program — re-run `make artifacts`"
    );
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn cfg(policy: &str, chunk: usize, max_new: usize) -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        policy: PolicyKind::parse(policy).expect("policy"),
        batch_size: 4,
        prefill_chunk: chunk,
        max_new_tokens: max_new,
        ..Default::default()
    }
}

fn warm_cfg(policy: &str, chunk: usize, max_new: usize) -> ServeConfig {
    ServeConfig {
        prefix_cache_mb: 64,
        prefix_min_tokens: 2,
        ..cfg(policy, chunk, max_new)
    }
}

fn prompt_of(len: usize, seed: u64, vocab: u64) -> Vec<u32> {
    (0..len as u64).map(|i| ((seed.wrapping_mul(31) + i * 7 + 3) % vocab) as u32).collect()
}

/// Feed `tokens` into `row` starting at `start_pos`, `chunk` positions per
/// invocation, returning the final chunk's logits.
fn prefill_all(
    model: &mut MoeModel,
    policy: &dyn xshare::selection::SelectionPolicy,
    row: usize,
    start_pos: usize,
    tokens: &[u32],
    chunk: usize,
) -> Vec<f32> {
    let cap = model.prefill_capacity();
    let mut pos = start_pos;
    let mut last = Vec::new();
    for piece in tokens.chunks(chunk.min(cap)) {
        let out = model
            .prefill_chunk(&PrefillInput {
                row,
                start_pos: pos,
                tokens: piece,
                policy,
                shared_selection: false,
                collect_probs: false,
            })
            .expect("prefill chunk");
        pos += piece.len();
        last = out.last_logits;
    }
    last
}

#[test]
fn warm_restore_byte_identical_across_policies_and_chunk_sizes() {
    // THE cache-restore contract. For random prompts, split points,
    // policies and chunk sizes: extract the first n positions after a cold
    // prefill, reset, restore them, prefill only the suffix — final KV
    // digest and last logits must match the cold arm bit for bit.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let policies = ["vanilla", "batch:6:1", "spec:1:0:2", "lynx:2", "skip:0.3", "opp:1"];
    forall(
        37,
        10,
        |rng| {
            let policy = policies[rng.below(policies.len())];
            let prompt_len = 3 + rng.below(8); // 3..=10
            let split = 1 + rng.below(prompt_len - 1); // 1..=len-1: suffix stays
            let chunk = 1 + rng.below(4); // 1..=4 (tiny capacity is 4)
            let seed = rng.below(1000) as u64;
            (policy, prompt_len, split, chunk, seed)
        },
        |&(policy, prompt_len, split, chunk, seed)| {
            let prompt = prompt_of(prompt_len, seed, vocab);
            let pol = PolicyKind::parse(policy).unwrap().build();

            // cold arm: whole prompt from a fresh cache
            model.reset();
            let cold_logits = prefill_all(&mut model, pol.as_ref(), 0, 0, &prompt, chunk);
            let cold_digest = model.kv_row_digest(0);
            let slab = model.extract_prefix(0, split).map_err(|e| format!("{e:#}"))?;

            // warm arm: restore the n-prefix, prefill only the suffix
            model.reset();
            model.restore_prefix(0, &slab).map_err(|e| format!("{e:#}"))?;
            let warm_logits =
                prefill_all(&mut model, pol.as_ref(), 0, split, &prompt[split..], chunk);
            let warm_digest = model.kv_row_digest(0);

            if warm_digest != cold_digest {
                return Err(format!(
                    "[{policy} chunk={chunk} split={split}/{prompt_len}] KV digest \
                     diverged after restore"
                ));
            }
            if warm_logits != cold_logits {
                return Err(format!(
                    "[{policy} chunk={chunk} split={split}/{prompt_len}] last logits \
                     diverged after restore"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn restore_is_row_portable() {
    // The contract's portability clause: a slab extracted from one row
    // restores into a DIFFERENT row with identical end state — K/V at a
    // position depend only on the token, the weights and the cache prefix,
    // never on the row index.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let prompt = prompt_of(8, 21, vocab);
    let pol = PolicyKind::parse("vanilla").unwrap().build();

    model.reset();
    prefill_all(&mut model, pol.as_ref(), 0, 0, &prompt, 4);
    let cold_digest = model.kv_row_digest(0);
    let slab = model.extract_prefix(0, 5).unwrap();

    model.reset();
    model.restore_prefix(2, &slab).unwrap();
    prefill_all(&mut model, pol.as_ref(), 2, 5, &prompt[5..], 4);
    assert_eq!(
        model.kv_row_digest(2),
        cold_digest,
        "row-2 restore of a row-0 slab must land the same bytes"
    );
}

#[test]
fn lru_eviction_with_real_slabs_and_mid_restore_hit() {
    // Tight-budget LRU over model-extracted slabs, with a hit mid-restore:
    // a clone handed out by lookup() must survive the entry's eviction and
    // still restore byte-identically.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let pol = PolicyKind::parse("vanilla").unwrap().build();
    let prompt_a = prompt_of(8, 1, vocab);
    let prompt_b = prompt_of(8, 2, vocab);

    model.reset();
    prefill_all(&mut model, pol.as_ref(), 0, 0, &prompt_a, 4);
    let cold_digest_a = model.kv_row_digest(0);
    let slab_a = model.extract_prefix(0, 6).unwrap();
    model.reset();
    prefill_all(&mut model, pol.as_ref(), 0, 0, &prompt_b, 4);
    let slab_b = model.extract_prefix(0, 6).unwrap();

    // budget fits exactly one slab
    let mut cache = PrefixCache::new(slab_a.bytes(), 1);
    assert!(cache.insert(&prompt_a[..6], slab_a));
    // the hit is mid-restore: the clone is out, then B's insert evicts A
    let held = cache.lookup(&prompt_a).expect("resident entry hits");
    assert!(cache.insert(&prompt_b[..6], slab_b));
    assert_eq!(cache.stats.evictions, 1, "budget for one slab forces LRU eviction");
    assert_eq!(cache.probe(&prompt_a), 0, "A is gone from the cache");

    // the held clone still restores A byte-identically
    model.reset();
    model.restore_prefix(0, &held).unwrap();
    prefill_all(&mut model, pol.as_ref(), 0, 6, &prompt_a[6..], 4);
    assert_eq!(model.kv_row_digest(0), cold_digest_a);
}

/// Drive `core` until idle, asserting step() never errors.
fn drain(core: &mut ServeLoop) {
    while core.has_work() {
        core.step().expect("step");
    }
}

#[test]
fn warm_serving_byte_identical_with_hits() {
    // Two-turn traffic through a full ServeLoop: turn 2 extends turn 1's
    // prompt+output. With the cache on, outputs must stay byte-identical
    // to the cache-off loop while the turn-2 admissions actually hit and
    // skip prefill forwards for the restored positions.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let t1_prompt = prompt_of(8, 42, vocab);
    let max_new = 3;

    let mut run = |c: ServeConfig, model: &mut MoeModel| {
        let mut core = ServeLoop::new(model, c).unwrap();
        core.submit(Request::new(1, t1_prompt.clone(), max_new)).unwrap();
        let mut t1_out = Vec::new();
        while core.has_work() {
            for (id, toks) in core.step().expect("step").finished {
                if id == 1 {
                    t1_out = toks;
                }
            }
        }
        // turn 2: the full turn-1 conversation plus a fresh user turn
        let mut t2_prompt = t1_prompt.clone();
        t2_prompt.extend_from_slice(&t1_out);
        t2_prompt.extend_from_slice(&prompt_of(3, 43, vocab));
        core.submit(Request::new(2, t2_prompt, max_new)).unwrap();
        drain(&mut core);
        core.report()
    };

    let cold = run(cfg("vanilla", 4, max_new), &mut model);
    let warm = run(warm_cfg("vanilla", 4, max_new), &mut model);

    assert_eq!(warm.outputs, cold.outputs, "cache restore must not change tokens");
    assert!(warm.metrics.prefix_hits > 0, "turn 2 must hit the cache");
    assert!(warm.metrics.prefix_inserts > 0);
    assert!(warm.metrics.prefill_restored_tokens > 0);
    assert!(
        warm.metrics.tokens_prompt < cold.metrics.tokens_prompt,
        "restored positions must not be re-forwarded ({} vs {})",
        warm.metrics.tokens_prompt,
        cold.metrics.tokens_prompt
    );
    assert_eq!(cold.metrics.prefix_hits, 0, "disabled cache never hits");
    assert_eq!(cold.metrics.prefix_inserts, 0);
}

#[test]
fn eviction_resume_restores_from_cache_losslessly() {
    // The resume-accounting tentpole wire: a row evicted mid-generation
    // offers its history to the cache; its re-admission restores the slab
    // instead of re-prefilling — same tokens as a run that was never
    // evicted, with the restore visible in the metrics.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let prompt = prompt_of(6, 7, vocab);
    let max_new = 4;

    // baseline: never evicted
    let base = Scheduler::new(&mut model, warm_cfg("vanilla", 4, max_new))
        .unwrap()
        .run(vec![Request::new(1, prompt.clone(), max_new)])
        .unwrap();

    // evicted after its second commit, resumed via cache restore
    let mut core = ServeLoop::new(&mut model, warm_cfg("vanilla", 4, max_new)).unwrap();
    core.submit(Request::new(1, prompt.clone(), max_new)).unwrap();
    let mut committed = 0;
    while committed < 2 {
        committed += core.step().expect("step").committed;
    }
    let evicted = core.evict_slot(0);
    assert!(evicted.is_some(), "decode row must be evictable");
    drain(&mut core);
    let resumed = core.report();

    assert_eq!(resumed.outputs, base.outputs, "eviction resume must be lossless");
    assert_eq!(resumed.metrics.evictions, 1);
    assert_eq!(
        resumed.metrics.resume_restores, 1,
        "the offered slab must satisfy the resume admission"
    );
    assert_eq!(resumed.metrics.resume_recomputes, 0);
    assert!(resumed.metrics.prefill_restored_tokens > 0);
    assert!(
        resumed.metrics.tokens_prompt < base.metrics.tokens_prompt + prompt.len() as u64,
        "resume must not re-forward the whole history"
    );
}

#[test]
fn queue_wait_records_one_incremental_sample_per_stint() {
    // Satellite-1 regression: eviction resume must record the SECOND
    // stint's incremental wait (here 0: the requeue is re-admitted at the
    // same sim instant), never re-record the first stint's wait (the
    // double-record bug) and never drop the sample (the old guard).
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut core = ServeLoop::new(&mut model, cfg("vanilla", 1, 6)).unwrap();
    core.submit(Request::new(1, prompt_of(4, 3, vocab), 6)).unwrap();
    let mut committed = 0;
    while committed < 2 {
        committed += core.step().expect("step").committed;
    }
    let m = core.metrics();
    assert_eq!(m.queue_wait.n, 1, "first admission records the first stint");
    let first_sum = m.queue_wait.sum;
    assert!(m.sim_seconds > 0.0, "sim must have advanced before the eviction");

    core.evict_slot(0).expect("occupied slot evicts");
    drain(&mut core);
    let m = core.metrics();
    assert_eq!(m.queue_wait.n, 2, "requeue stint records its own sample");
    assert!(
        (m.queue_wait.sum - first_sum).abs() < 1e-12,
        "incremental wait is 0 for an immediate re-admission; {} re-recorded \
         time the row spent being SERVED",
        m.queue_wait.sum - first_sum
    );
}

#[test]
fn mid_prefill_eviction_keeps_exactly_one_ttft_from_original_submit() {
    // Satellite-2 pin (a): a row evicted before its first token still gets
    // exactly one TTFT sample, measured from the ORIGINAL submission — the
    // resume admission must not drop the pending entry or re-anchor it.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut core = ServeLoop::new(&mut model, cfg("vanilla", 2, 3)).unwrap();
    core.submit(Request::new(1, prompt_of(8, 9, vocab), 3)).unwrap();
    let o = core.step().expect("step");
    assert_eq!(o.committed, 0, "one chunk of 2 over an 8-token prompt is mid-prefill");
    assert_eq!(core.metrics().ttft.n, 0);
    let sim_at_evict = core.metrics().sim_seconds;
    assert!(sim_at_evict > 0.0);

    core.evict_slot(0).expect("mid-prefill row evicts");
    drain(&mut core);
    let m = core.metrics();
    assert_eq!(m.ttft.n, 1, "exactly one TTFT sample across the eviction");
    assert!(
        m.ttft.min >= sim_at_evict,
        "TTFT {} anchored at the original submit must cover the pre-eviction \
         steps ({} s)",
        m.ttft.min,
        sim_at_evict
    );
}

#[test]
fn slot_reuse_does_not_inherit_ttft_state() {
    // Satellite-2 pin (b): two requests through the same slot record one
    // TTFT each — the second admission overwrites the slot's pending entry
    // instead of inheriting `recorded` (or the clock) from the first.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let mut core = ServeLoop::new(&mut model, cfg("vanilla", 1, 2)).unwrap();
    core.submit(Request::new(1, prompt_of(3, 1, vocab), 2)).unwrap();
    drain(&mut core);
    assert_eq!(core.metrics().ttft.n, 1);
    let sim_at_resubmit = core.metrics().sim_seconds;
    core.submit(Request::new(2, prompt_of(3, 2, vocab), 2)).unwrap();
    drain(&mut core);
    let m = core.metrics();
    assert_eq!(m.ttft.n, 2, "slot reuse must record the second request's TTFT");
    assert!(
        m.ttft.max < sim_at_resubmit,
        "TTFT {} reaches past the resubmit instant {} — the reused slot \
         anchored the second request on the first one's clock",
        m.ttft.max,
        sim_at_resubmit
    );
}
