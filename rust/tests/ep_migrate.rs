//! Replication & incremental-migration suite (PR 6).
//!
//! Exercises the migration planner through the public `xshare::ep` API and
//! the full serving stack: bounded plans, interconnect charging, budget
//! compliance, and the swap-mode (`--ep-migrate-budget 0`) equivalence.
//! The cost-only token/KV pins live in `rust/tests/ep_serve.rs`; this
//! suite pins the planner's mechanics end to end.

use xshare::config::{EpConfig, ServeConfig};
use xshare::coordinator::{AdmissionKind, Request, Scheduler};
use xshare::ep::{plan_migration, EpCostModel, MigrationOp, Placement, PlacementKind};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn prompt_of(len: usize, seed: u64, vocab: u64) -> Vec<u32> {
    (0..len as u64).map(|i| ((seed.wrapping_mul(31) + i * 7 + 3) % vocab) as u32).collect()
}

/// Skewed two-class trace: the planner only acts when the tracked mix is
/// lopsided enough to beat the interconnect charge.
fn trace(vocab: u64, n: u64) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let mut r = Request::new(id, prompt_of(3, (id % 2) * 37 + 11, vocab), 5);
            r.domain = if id % 2 == 0 { "mgA".into() } else { "mgB".into() };
            r
        })
        .collect()
}

/// Footprint-admission EP config; `budget == 0` is the PR 5 swap mode.
fn ep_cfg(budget: usize, slack: f64, prefetch: bool) -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        policy: PolicyKind::parse("vanilla").expect("policy"),
        batch_size: 2,
        max_new_tokens: 5,
        admission: AdmissionKind::FootprintAware,
        ep: Some(EpConfig { n_gpus: 2, placement: PlacementKind::Contiguous }),
        ep_rebalance: 1,
        ep_migrate_budget: budget,
        ep_replica_slack: slack,
        ep_prefetch: prefetch,
        ..Default::default()
    }
}

#[test]
fn planner_replicates_the_hot_expert_through_the_public_api() {
    // The re-exported surface (`xshare::ep::{plan_migration, ...}`) must
    // carry the whole planner contract: a single copy of the second
    // expert off the hot GPU is the optimal one-op plan here.
    let pl = Placement::new(8, 2, PlacementKind::Contiguous);
    let mut w = vec![0.01f32; 8];
    w[0] = 0.6;
    w[1] = 0.5;
    let cap = Placement::residency_cap(8, 2, 2.0);
    assert_eq!(cap, 8);
    let plan = plan_migration(&pl, &w, 1, cap).expect("an improving plan exists");
    assert_eq!(plan.ops, vec![MigrationOp::Copy { expert: 1, to: 1 }]);
    assert_eq!(plan.copies, 1);
    assert!(plan.expected_after < plan.expected_before);
    assert!(plan.placement.hosts(1, 1), "the adopted placement carries the replica");
    assert!(plan.placement.hosts(0, 1), "copies never drop the original host");

    // Charging is linear in copies through the cost model the serve loop
    // uses: one 44 MB expert over NVLink is O(100 µs), never free.
    let model = EpCostModel::default();
    let one = model.migration_seconds(plan.copies);
    assert!(one > 0.0);
    assert!((model.migration_seconds(3) - 3.0 * one).abs() < 1e-12);
}

#[test]
fn planner_respects_caps_budget_and_balance_through_the_public_api() {
    let pl = Placement::new(8, 2, PlacementKind::Contiguous);
    let mut w = vec![0.01f32; 8];
    w[0] = 0.6;
    w[1] = 0.5;
    // cap == block size: both GPUs full, no legal copy anywhere
    assert!(plan_migration(&pl, &w, 4, Placement::residency_cap(8, 2, 1.0)).is_none());
    // zero budget: planner disabled outright
    assert!(plan_migration(&pl, &w, 0, 8).is_none());
    // balanced mix: nothing improves, no plan
    let flat = vec![0.125f32; 8];
    assert!(plan_migration(&pl, &flat, 4, 8).is_none());
}

#[test]
fn swap_and_migration_modes_serve_identical_tokens() {
    // `--ep-migrate-budget 0` is the PR 5 whole-placement swap; budget > 0
    // switches to incremental plans. Both are cost-only, so under vanilla
    // routing all three arms must emit the same bytes.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs = trace(vocab, 8);
    let base_cfg = ServeConfig {
        preset: "tiny".into(),
        policy: PolicyKind::parse("vanilla").expect("policy"),
        batch_size: 2,
        max_new_tokens: 5,
        ..Default::default()
    };
    let base = Scheduler::new(&mut model, base_cfg)
        .expect("scheduler")
        .run(reqs.clone())
        .expect("run")
        .outputs;

    let swap = Scheduler::new(&mut model, ep_cfg(0, 1.0, false))
        .expect("scheduler")
        .run(reqs.clone())
        .expect("run");
    assert_eq!(swap.outputs, base, "swap mode changed tokens");
    assert_eq!(swap.metrics.migrations, 0, "swap mode ran the migration planner");

    let mig = Scheduler::new(&mut model, ep_cfg(3, 2.0, false))
        .expect("scheduler")
        .run(reqs)
        .expect("run");
    assert_eq!(mig.outputs, base, "migration mode changed tokens");
    assert_eq!(mig.metrics.rebalances, 0, "migration mode fell back to swaps");
}

#[test]
fn migration_charging_stays_within_budget_end_to_end() {
    // Every adopted plan is bounded by the op budget, and the sim clock is
    // charged exactly the bytes the plans moved — never more than
    // copies × expert_bytes / interconnect_bw in total.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs = trace(vocab, 8);
    let budget = 2usize;
    let report = Scheduler::new(&mut model, ep_cfg(budget, 2.0, false))
        .expect("scheduler")
        .run(reqs)
        .expect("run");
    let m = &report.metrics;
    let cost = EpCostModel::default();
    if m.migrations > 0 {
        assert!(
            m.migration_ops.max <= budget as f64,
            "a plan carried {} ops past the budget {budget}",
            m.migration_ops.max
        );
        // bytes are whole expert copies, at most `budget` per adoption
        let max_bytes = m.migrations as f64 * budget as f64 * cost.expert_bytes;
        assert!(m.migration_bytes > 0.0 && m.migration_bytes <= max_bytes);
        let max_charge = m.migration_bytes / cost.interconnect_bw;
        assert!(
            m.migration_seconds > 0.0 && m.migration_seconds <= max_charge + 1e-12,
            "charged {} s for at most {} s of transfer",
            m.migration_seconds,
            max_charge
        );
        assert!(m.rebalance_delta.min > 0.0, "adopted a non-improving plan");
    } else {
        // Nothing adopted — then nothing may have been charged either.
        assert_eq!(m.migration_bytes, 0.0);
        assert_eq!(m.migration_seconds, 0.0);
    }
}

#[test]
fn prefetch_only_adds_cost_never_tokens() {
    // Footprint prefetch replicates ahead of queued classes; it may adopt
    // extra plans (counted in `prefetches`) but tokens stay byte-equal to
    // the no-prefetch arm.
    let mut model = tiny_model();
    let vocab = model.dims().vocab as u64;
    let reqs = trace(vocab, 8);
    let plain = Scheduler::new(&mut model, ep_cfg(2, 2.0, false))
        .expect("scheduler")
        .run(reqs.clone())
        .expect("run");
    assert_eq!(plain.metrics.prefetches, 0, "prefetch fired while disabled");
    let pre = Scheduler::new(&mut model, ep_cfg(2, 2.0, true))
        .expect("scheduler")
        .run(reqs)
        .expect("run");
    assert_eq!(pre.outputs, plain.outputs, "prefetch leaked into routing");
    assert!(pre.metrics.prefetches <= pre.metrics.migrations);
}
