//! Stepped serving-core tests over the tiny artifact preset, ported from
//! the continuous-batch scheduler ordering suite (prefill-before-decode,
//! request-admitted-between-decode-steps) plus the central fidelity
//! property of the refactor: a workload served with staggered mid-flight
//! submission produces identical per-request outputs to submit-all-upfront.

use std::collections::BTreeMap;

use xshare::config::ServeConfig;
use xshare::coordinator::{Request, Scheduler, ServeLoop};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::util::check::forall;

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn tiny_cfg() -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        max_new_tokens: 6,
        ..Default::default()
    }
}

fn trace(n: usize, max_new: usize, seed: u64) -> Vec<Request> {
    let g = TraceGenerator::new(64, seed);
    g.generate(&TraceDomain::standard_suite(), n)
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(5);
            let mut r = Request::new(t.id, prompt, max_new);
            r.domain = t.domain;
            r
        })
        .collect()
}

#[test]
fn prefill_runs_before_decode_for_admitted_request() {
    let mut model = tiny_model();
    let mut core = ServeLoop::new(&mut model, tiny_cfg()).unwrap();
    core.submit(Request::new(1, vec![3, 4, 5], 2)).unwrap();

    // Prompt length 3 → three prefill-phase steps; the third consumes the
    // last prompt token and commits the first generated token.
    let o1 = core.step().unwrap();
    assert_eq!(o1.admitted, vec![1]);
    assert_eq!((o1.prefill_rows, o1.decode_rows), (1, 0));
    assert_eq!(o1.committed, 0);

    let o2 = core.step().unwrap();
    assert_eq!((o2.prefill_rows, o2.decode_rows), (1, 0));

    let o3 = core.step().unwrap();
    assert_eq!((o3.prefill_rows, o3.decode_rows), (1, 0));
    assert_eq!(o3.committed, 1, "prefill completion commits the first token");

    // Only now does the row run in decode phase; max_new=2 finishes here.
    let o4 = core.step().unwrap();
    assert_eq!((o4.prefill_rows, o4.decode_rows), (0, 1));
    assert_eq!(o4.finished.len(), 1);
    assert_eq!(o4.finished[0].0, 1);
    assert_eq!(o4.finished[0].1.len(), 2);
    assert!(!core.has_work());

    // TTFT was recorded exactly once, and covers the three prefill steps.
    assert_eq!(core.metrics().ttft.n, 1);
    assert!(core.metrics().ttft.min > 0.0);
}

#[test]
fn request_admitted_between_decode_steps_joins_next_step() {
    let mut model = tiny_model();
    let mut core = ServeLoop::new(&mut model, tiny_cfg()).unwrap();
    core.submit(Request::new(1, vec![3], 4)).unwrap();

    let o1 = core.step().unwrap(); // single-token prompt: prefill commits #1
    assert_eq!(o1.committed, 1);
    let o2 = core.step().unwrap(); // pure decode
    assert_eq!((o2.prefill_rows, o2.decode_rows), (0, 1));

    // B arrives while A is mid-decode: it must be admitted at the top of
    // the very next step and prefill beside A's decode row.
    core.submit(Request::new(2, vec![4, 5], 3)).unwrap();
    let o3 = core.step().unwrap();
    assert_eq!(o3.admitted, vec![2]);
    assert_eq!((o3.prefill_rows, o3.decode_rows), (1, 1));
    assert_eq!(core.metrics().admitted_in_flight, 1);
    assert!(core.metrics().queue_wait.n >= 2);

    core.drain().unwrap();
    let report = core.report();
    assert_eq!(report.outputs.len(), 2);
    assert_eq!(report.outputs[&1].len(), 4);
    assert_eq!(report.outputs[&2].len(), 3);
}

#[test]
fn finished_requests_release_mid_flight() {
    // A short request co-batched with a long one must finish (and free its
    // slot) while the long one keeps decoding — not when the batch drains.
    let mut model = tiny_model();
    let mut core = ServeLoop::new(&mut model, tiny_cfg()).unwrap();
    core.submit(Request::new(1, vec![3], 2)).unwrap(); // short
    core.submit(Request::new(2, vec![4], 8)).unwrap(); // long

    let mut short_done_at = None;
    let mut steps = 0usize;
    while core.has_work() {
        let o = core.step().unwrap();
        steps += 1;
        if o.finished.iter().any(|(id, _)| *id == 1) {
            short_done_at = Some(steps);
            assert_eq!(o.running, 1, "long request still occupies its slot");
        }
    }
    let report = core.report();
    assert_eq!(report.outputs.len(), 2);
    let short_done_at = short_done_at.expect("short request never finished");
    assert!(short_done_at < steps, "short request only returned at drain");
}

#[test]
fn late_joiner_does_not_perturb_vanilla_outputs() {
    // Under vanilla routing rows are independent, so a request joining
    // mid-flight must not change what an already-running request generates.
    let mut model = tiny_model();
    let solo = Scheduler::new(&mut model, tiny_cfg())
        .unwrap()
        .run(vec![Request::new(1, vec![3, 4], 6)])
        .unwrap();

    let mut core = ServeLoop::new(&mut model, tiny_cfg()).unwrap();
    core.submit(Request::new(1, vec![3, 4], 6)).unwrap();
    core.step().unwrap();
    core.step().unwrap();
    core.submit(Request::new(2, vec![5, 6, 7], 4)).unwrap();
    core.drain().unwrap();
    let mixed = core.report();

    assert_eq!(solo.outputs[&1], mixed.outputs[&1]);
    assert_eq!(mixed.outputs[&2].len(), 4);
}

#[test]
fn spec_cycles_survive_chunk_prefill_rows() {
    // The mixed-phase rule (PR 4): a chunk-prefilling row no longer
    // switches speculation off for the batch. While B walks its prompt in
    // chunks, A keeps running verify cycles — the step reports per-row
    // phases: B in PrefillChunk, A in SpecVerify at full depth.
    use xshare::coordinator::Phase;
    let mut model = tiny_model();
    let cfg = ServeConfig { spec_len: 2, prefill_chunk: 2, ..tiny_cfg() };
    let mut core = ServeLoop::new(&mut model, cfg).unwrap();

    // A: single-token prompt → decodes from step 1 on.
    core.submit(Request::new(1, vec![3], 8)).unwrap();
    let o1 = core.step().unwrap();
    assert!(!o1.speculative(), "a lone prefill row has nothing to speculate");
    assert_eq!(o1.phases, vec![(0, 1, Phase::PrefillChunk)]);
    let o2 = core.step().unwrap();
    assert!(o2.speculative(), "all-decode batch runs the verify cycle");
    assert_eq!(o2.spec_depth_of(0), Some(2));

    // B arrives with a 5-token prompt: three chunked steps (2+2+1); the
    // verify cycle must KEEP RUNNING for A through all of them.
    core.submit(Request::new(2, vec![4, 5, 6, 7, 8], 4)).unwrap();
    for (expect_prefill, expect_tokens) in [(1, 2), (1, 2), (1, 1)] {
        let o = core.step().unwrap();
        assert_eq!(o.prefill_rows, expect_prefill);
        assert_eq!(o.prefill_tokens, expect_tokens, "chunk geometry");
        assert!(
            o.speculative(),
            "a chunk-prefilling row must not stall the decode row's speculation"
        );
        assert_eq!(o.spec_depth_of(0), Some(2), "A speculates at full depth");
        assert!(
            o.phases.iter().any(|&(s, id, p)| (s, id, p) == (1, 2, Phase::PrefillChunk)),
            "B reports its prefill phase: {:?}",
            o.phases
        );
        assert_eq!(core.metrics().spec_stalled_steps, 0, "no stall under mixed phases");
    }
    // B finished its prompt: both rows now speculate.
    let o = core.step().unwrap();
    assert_eq!((o.prefill_rows, o.decode_rows), (0, 2));
    assert!(o.speculative());
    assert_eq!(o.spec_depth_of(1), Some(2));

    core.drain().unwrap();
    let report = core.report();
    assert_eq!(report.outputs[&1].len(), 8);
    assert_eq!(report.outputs[&2].len(), 4);
}

#[test]
fn legacy_gate_restores_batch_global_stall() {
    // The pre-PR4 gate survives as bench/pin instrumentation: with it
    // pinned on, a chunk-prefilling row stalls speculation for everyone
    // and the stall is counted in spec_stalled_steps.
    let mut model = tiny_model();
    let cfg = ServeConfig { spec_len: 2, prefill_chunk: 2, ..tiny_cfg() };
    let mut core = ServeLoop::new(&mut model, cfg).unwrap();
    core.set_legacy_spec_gate(true);

    core.submit(Request::new(1, vec![3], 8)).unwrap();
    core.step().unwrap();
    let o = core.step().unwrap();
    assert!(o.speculative(), "all-decode batch still speculates under the gate");

    core.submit(Request::new(2, vec![4, 5, 6, 7, 8], 4)).unwrap();
    let mut stalled = 0;
    for _ in 0..3 {
        let o = core.step().unwrap();
        assert_eq!(o.prefill_rows, 1);
        assert!(!o.speculative(), "the legacy gate stalls on any prefill row");
        stalled += 1;
    }
    assert_eq!(core.metrics().spec_stalled_steps, stalled);
    let o = core.step().unwrap();
    assert!(o.speculative(), "gate lifts once the batch is all-decode");
}

#[test]
fn prompt_tokens_never_inflate_throughput() {
    // Regression for the committed-vs-prompt counter split on the legacy
    // one-token path: a 12-token prompt and a 2-token prompt with the same
    // generation budget must report the same tokens_out; the prompt walk
    // shows up in tokens_prompt (and in sim time), not in throughput.
    let mut model = tiny_model();
    let mut outs = Vec::new();
    for prompt_len in [2usize, 12] {
        let prompt: Vec<u32> = (0..prompt_len as u32).map(|i| 3 + i % 40).collect();
        let report = Scheduler::new(&mut model, tiny_cfg())
            .unwrap()
            .run(vec![Request::new(1, prompt, 4)])
            .unwrap();
        assert_eq!(report.metrics.tokens_out, 4, "prompt_len={prompt_len}");
        assert_eq!(report.metrics.tokens_prompt, prompt_len as u64);
        assert_eq!(report.metrics.prefill_forwards, 0, "legacy path uses no chunks");
        outs.push((report.metrics.tokens_out, report.metrics.sim_seconds));
    }
    let (tok_short, sim_short) = outs[0];
    let (tok_long, sim_long) = outs[1];
    assert_eq!(tok_short, tok_long);
    assert!(sim_long > sim_short, "longer prompts still cost sim time");
}

#[test]
fn staggered_submission_matches_upfront_property() {
    let mut model = tiny_model();
    let cfg = tiny_cfg();
    forall(
        11,
        6,
        |rng| {
            let n = 3 + rng.below(4); // 3..=6 requests
            let max_new = 2 + rng.below(4); // 2..=5 tokens each
            // Step offset at which each request is submitted (0 = upfront).
            let offsets: Vec<usize> = (0..n).map(|_| rng.below(6)).collect();
            let seed = rng.below(1000) as u64;
            (n, max_new, offsets, seed)
        },
        |&(n, max_new, ref offsets, seed)| {
            let requests = trace(n, max_new, seed);

            let upfront = Scheduler::new(&mut model, cfg.clone())
                .map_err(|e| format!("{e:#}"))?
                .run(requests.clone())
                .map_err(|e| format!("{e:#}"))?;

            let mut core =
                ServeLoop::new(&mut model, cfg.clone()).map_err(|e| format!("{e:#}"))?;
            let mut pending: BTreeMap<usize, Vec<Request>> = BTreeMap::new();
            for (r, &off) in requests.iter().zip(offsets) {
                pending.entry(off).or_default().push(r.clone());
            }
            let mut step_no = 0usize;
            loop {
                if let Some(batch) = pending.remove(&step_no) {
                    for r in batch {
                        core.submit(r).unwrap();
                    }
                }
                if !core.has_work() {
                    if pending.is_empty() {
                        break;
                    }
                    // Idle gap before a later submission: nothing to do
                    // this step.
                    step_no += 1;
                    continue;
                }
                core.step().map_err(|e| format!("{e:#}"))?;
                step_no += 1;
            }
            let staggered = core.report();

            if upfront.outputs != staggered.outputs {
                return Err(format!(
                    "outputs diverged: upfront {:?} vs staggered {:?}",
                    upfront.outputs, staggered.outputs
                ));
            }
            // Every request committed a first token exactly once.
            if staggered.metrics.ttft.n != n as u64 {
                return Err(format!(
                    "ttft recorded {} times for {n} requests",
                    staggered.metrics.ttft.n
                ));
            }
            Ok(())
        },
    );
}
