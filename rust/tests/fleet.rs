//! Fleet-tier integration tests over the tiny artifact preset: class-key
//! parity with footprint admission, byte-identity of fleet serving against
//! the single serve loop (and across routing modes), lossless failover on
//! replica death (mid-decode and mid-prefill), and queue-depth spill.
//!
//! Byte-identity holds because the default policy is vanilla top-k —
//! row-independent selection — so WHERE a row runs (which replica, which
//! batch mix, before or after a failover resume) cannot change WHAT it
//! generates. These are the fleet-level analogues of the eviction/resume
//! pins in `ep_serve.rs`.

use xshare::config::ServeConfig;
use xshare::coordinator::admission::FootprintTracker;
use xshare::coordinator::{Request, Scheduler};
use xshare::fleet::health::RECOVERY_PROBES;
use xshare::fleet::{Fleet, FleetRouter, HealthState, HealthTracker};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};

fn tiny_model() -> MoeModel {
    let manifest = Manifest::load(&artifacts_root().join("tiny"))
        .expect("tiny artifacts missing — run `make artifacts`");
    MoeModel::new(Engine::load(manifest).unwrap()).unwrap()
}

fn fleet_cfg(replicas: usize, affinity: &str) -> ServeConfig {
    ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        max_new_tokens: 8,
        fleet_replicas: replicas,
        fleet_affinity: xshare::fleet::AffinityMode::parse(affinity).unwrap(),
        ..Default::default()
    }
}

fn tiny_fleet(cfg: &ServeConfig) -> Fleet {
    Fleet::from_preset_dir(&artifacts_root().join("tiny"), cfg).unwrap()
}

/// Two well-separated traffic classes whose rendezvous preferences land on
/// DISTINCT replicas at N = 2 (pinned in `fleet::router` unit tests):
/// "tplA" → replica 1, "tplB" → replica 0.
fn two_class_trace() -> Vec<Request> {
    let mut reqs = Vec::new();
    for i in 0..8u64 {
        let (domain, prompt) = if i % 2 == 0 {
            ("tplA", vec![3 + i as u32, 4, 5])
        } else {
            ("tplB", vec![20 + i as u32, 21, 22])
        };
        let mut r = Request::new(i, prompt, 4 + (i % 3) as usize);
        r.domain = domain.into();
        reqs.push(r);
    }
    reqs
}

fn single_loop_outputs(
    requests: Vec<Request>,
) -> std::collections::BTreeMap<u64, Vec<u32>> {
    let mut model = tiny_model();
    let cfg = ServeConfig {
        preset: "tiny".into(),
        batch_size: 4,
        max_new_tokens: 8,
        ..Default::default()
    };
    Scheduler::new(&mut model, cfg).unwrap().run(requests).unwrap().outputs
}

#[test]
fn class_key_parity_between_admission_and_fleet_router() {
    // The fleet routes by Request::class_key; footprint admission
    // aggregates under FootprintTracker::class_key. They must be the SAME
    // derivation — a drift here silently decorrelates routing affinity
    // from the footprint classes it exists to exploit.
    let mut with_domain = Request::new(1, vec![1, 2, 3], 4);
    with_domain.domain = "gpqa".into();
    let anon = Request::new(2, vec![1, 2, 3], 4);
    let mut resumed = Request::new(3, vec![1, 2, 3, 9, 9], 2);
    resumed.resume_prefix = vec![9, 9];
    for req in [&with_domain, &anon, &resumed] {
        assert_eq!(FootprintTracker::class_key(req), req.class_key());
    }
    // And the router consumes exactly this key: same preferred replica for
    // requests of the same class, regardless of which derivation produced
    // the key string.
    let n = 4;
    assert_eq!(
        FleetRouter::preferred(&FootprintTracker::class_key(&with_domain), n),
        FleetRouter::preferred(&with_domain.class_key(), n),
    );
}

#[test]
fn fleet_outputs_match_single_loop_across_routing_modes() {
    let requests = two_class_trace();
    let reference = single_loop_outputs(requests.clone());

    for affinity in ["class", "round-robin"] {
        let cfg = fleet_cfg(2, affinity);
        let mut fleet = tiny_fleet(&cfg);
        for r in requests.clone() {
            fleet.submit(r).unwrap().unwrap();
        }
        fleet.drain().unwrap();
        assert_eq!(
            fleet.outputs(),
            &reference,
            "fleet ({affinity}) must be byte-identical to the single loop"
        );
        let report = fleet.report().unwrap();
        assert_eq!(report.aggregate.requests_done, requests.len() as u64);
        assert_eq!(
            report.aggregate.ttft.n,
            requests.len() as u64,
            "every request records TTFT exactly once fleet-wide"
        );
        assert_eq!(report.failovers, 0);
    }
}

#[test]
fn class_affinity_routes_classes_to_their_rendezvous_replicas() {
    let cfg = fleet_cfg(2, "class");
    let mut fleet = tiny_fleet(&cfg);
    for r in two_class_trace() {
        let id = r.id;
        let expect = FleetRouter::preferred(&r.class_key(), 2);
        let landed = fleet.submit(r).unwrap().unwrap();
        assert_eq!(landed, expect, "request {id} off its affine replica");
        assert_eq!(fleet.replica_of(id), Some(expect));
    }
    assert_eq!(fleet.spills(), 0, "no backpressure configured — pure affinity");
    fleet.drain().unwrap();
}

#[test]
fn replica_death_mid_decode_is_lossless() {
    let requests = two_class_trace();
    let reference = single_loop_outputs(requests.clone());

    let cfg = fleet_cfg(2, "class");
    let mut fleet = tiny_fleet(&cfg);
    for r in requests.clone() {
        fleet.submit(r).unwrap().unwrap();
    }
    // Step the fleet until request 0 ("tplA", on replica 1) has committed
    // generated tokens — then kill its replica MID-DECODE. The fleet's
    // mirror of the committed history is what failover resumes from.
    let victim_replica = fleet.replica_of(0).unwrap();
    assert_eq!(victim_replica, 1, "tplA's pinned rendezvous home at N=2");
    loop {
        let committed = fleet.committed_of(0).map(<[u32]>::to_vec);
        match committed {
            Some(c) if !c.is_empty() => break,
            Some(_) => {
                fleet.pump().unwrap();
            }
            None => panic!("request 0 finished before the kill — shorten the wait"),
        }
    }
    fleet.kill_replica(victim_replica).unwrap();
    assert!(fleet.failovers() >= 1, "stranded rows re-entered the router");
    for r in &requests {
        if let Some(rep) = fleet.replica_of(r.id) {
            assert_ne!(rep, victim_replica, "no in-flight row may stay on the dead replica");
        }
    }
    fleet.drain().unwrap();

    assert_eq!(
        fleet.outputs(),
        &reference,
        "mid-decode failover must be byte-identical to an undisturbed run"
    );
    // TTFT stays exactly-once and origin-anchored: the victim's sample was
    // recorded on the dead replica and survives via its final captured
    // metrics; resumed rows (resume_prefix non-empty) never record again.
    let report = fleet.report().unwrap();
    assert_eq!(report.aggregate.ttft.n, requests.len() as u64);
    assert!(report.replicas[victim_replica].dead);
}

#[test]
fn replica_death_mid_prefill_is_lossless() {
    let requests = two_class_trace();
    let reference = single_loop_outputs(requests.clone());

    let cfg = fleet_cfg(2, "class");
    let mut fleet = tiny_fleet(&cfg);
    for r in requests.clone() {
        fleet.submit(r).unwrap().unwrap();
    }
    // Kill BEFORE any step: every row on replica 1 is still pre-first-token
    // (nothing committed), so the victims resume as plain re-submissions
    // and record their one TTFT sample on the surviving replica.
    assert!(fleet.committed_of(0).unwrap().is_empty());
    fleet.kill_replica(1).unwrap();
    assert!(fleet.failovers() >= 1);
    fleet.drain().unwrap();

    assert_eq!(
        fleet.outputs(),
        &reference,
        "mid-prefill failover must be byte-identical to an undisturbed run"
    );
    let report = fleet.report().unwrap();
    assert_eq!(
        report.aggregate.ttft.n,
        requests.len() as u64,
        "exactly one TTFT sample per request despite the mid-prefill failover"
    );
}

#[test]
fn busy_recovery_hysteresis_does_not_flap_on_oscillating_queue_depth() {
    // A replica whose queue depth oscillates around the high-water mark
    // (the realistic near-saturation pattern: drain one, admit one, …)
    // must NOT flap Healthy↔Busy on every probe — each flap re-routes the
    // replica's whole affine class. With recovery hysteresis the state
    // makes exactly ONE transition (→ Busy) over the whole oscillation,
    // and rejoins only after RECOVERY_PROBES consecutive clean probes.
    let high_water = 4;
    let mut h = HealthTracker::new(2, 1);
    let mut transitions = 0;
    let mut prev = h.state(0);
    for i in 0..64 {
        // 5, 3, 5, 3, … — alternating at/under the mark every probe
        let queued = if i % 2 == 0 { high_water + 1 } else { high_water - 1 };
        h.observe(0, queued, high_water);
        let now = h.state(0);
        if now != prev {
            transitions += 1;
            prev = now;
        }
    }
    assert_eq!(h.state(0), HealthState::Busy);
    assert_eq!(
        transitions, 1,
        "oscillating queue must cost exactly one Healthy→Busy transition"
    );
    // the untouched replica never moved
    assert_eq!(h.state(1), HealthState::Healthy);
    // a real drain recovers after the full streak — and not one probe sooner
    for k in 0..RECOVERY_PROBES {
        assert_eq!(h.state(0), HealthState::Busy, "rejoined after only {k} probes");
        h.observe(0, 0, high_water);
    }
    assert_eq!(h.state(0), HealthState::Healthy);
}

#[test]
fn high_water_backpressure_spills_without_corrupting_outputs() {
    let requests = two_class_trace();
    let reference = single_loop_outputs(requests.clone());

    let cfg = ServeConfig { fleet_high_water: 1, ..fleet_cfg(2, "class") };
    let mut fleet = tiny_fleet(&cfg);
    // Burst-submit with no stepping in between: the affine targets' queues
    // hit the high-water mark immediately and later same-class submits
    // must spill to the other replica.
    for r in requests.clone() {
        fleet.submit(r).unwrap().unwrap();
    }
    assert!(fleet.spills() > 0, "burst past the high-water mark must spill");
    fleet.drain().unwrap();
    assert_eq!(
        fleet.outputs(),
        &reference,
        "spilled requests still generate byte-identical outputs"
    );
    let report = fleet.report().unwrap();
    assert_eq!(report.spills, fleet.spills());
    assert_eq!(report.aggregate.requests_done, requests.len() as u64);
}
