//! Expert → GPU placement for expert-parallel (EP) deployments (§5).
//!
//! The experts of each layer form a partition E = ∪_g E_g across G GPU
//! groups. Serving systems place experts contiguously (DeepSeek-style),
//! round-robin, or randomly (after load-balancing shuffles); the placement
//! policy is an ablation axis in `benches/ablations.rs`.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Experts [0, N/G) on GPU 0, [N/G, 2N/G) on GPU 1, …
    Contiguous,
    /// Expert j on GPU j mod G.
    RoundRobin,
    /// Seeded random permutation, then contiguous blocks.
    Random(u64),
}

/// An expert → GPU-group assignment.
#[derive(Debug, Clone)]
pub struct Placement {
    n_experts: usize,
    n_gpus: usize,
    /// gpu_of[j] = GPU group hosting expert j.
    gpu_of: Vec<usize>,
    /// experts_of[g] = experts hosted on GPU g (ascending).
    experts_of: Vec<Vec<usize>>,
}

impl Placement {
    pub fn new(n_experts: usize, n_gpus: usize, kind: PlacementKind) -> Placement {
        assert!(n_gpus > 0 && n_experts >= n_gpus, "need n_experts >= n_gpus >= 1");
        let order: Vec<usize> = match kind {
            PlacementKind::Contiguous | PlacementKind::RoundRobin => (0..n_experts).collect(),
            PlacementKind::Random(seed) => {
                let mut idx: Vec<usize> = (0..n_experts).collect();
                Rng::new(seed).shuffle(&mut idx);
                idx
            }
        };
        let mut gpu_of = vec![0usize; n_experts];
        match kind {
            PlacementKind::RoundRobin => {
                for (pos, &j) in order.iter().enumerate() {
                    gpu_of[j] = pos % n_gpus;
                }
            }
            _ => {
                // contiguous blocks over `order` (balanced sizes, remainder
                // spread over the first GPUs)
                let base = n_experts / n_gpus;
                let extra = n_experts % n_gpus;
                let mut pos = 0;
                for g in 0..n_gpus {
                    let take = base + usize::from(g < extra);
                    for &j in &order[pos..pos + take] {
                        gpu_of[j] = g;
                    }
                    pos += take;
                }
            }
        }
        let mut experts_of = vec![Vec::new(); n_gpus];
        for (j, &g) in gpu_of.iter().enumerate() {
            experts_of[g].push(j);
        }
        Placement { n_experts, n_gpus, gpu_of, experts_of }
    }

    #[inline]
    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    #[inline]
    pub fn n_gpus(&self) -> usize {
        self.n_gpus
    }

    #[inline]
    pub fn gpu_of(&self, expert: usize) -> usize {
        self.gpu_of[expert]
    }

    pub fn experts_on(&self, gpu: usize) -> &[usize] {
        &self.experts_of[gpu]
    }

    /// Per-GPU load Load_g(S) = |S ∩ E_g| for a selected set.
    pub fn loads(&self, selected: &crate::selection::ExpertSet) -> Vec<usize> {
        let mut loads = vec![0usize; self.n_gpus];
        for j in selected.iter() {
            loads[self.gpu_of[j]] += 1;
        }
        loads
    }

    /// MaxLoad(S) — the synchronization straggler (§5.1).
    pub fn max_load(&self, selected: &crate::selection::ExpertSet) -> usize {
        self.loads(selected).into_iter().max().unwrap_or(0)
    }

    /// Expected per-GPU load under fractional per-expert weights (the
    /// tracked traffic mix): `Σ_{j ∈ E_g} w_j` — the continuous analogue
    /// of [`Placement::loads`] that rebalancing optimizes against.
    pub fn weighted_loads(&self, weights: &[f32]) -> Vec<f64> {
        assert_eq!(weights.len(), self.n_experts, "weights must cover every expert");
        let mut loads = vec![0.0f64; self.n_gpus];
        for (j, &w) in weights.iter().enumerate() {
            loads[self.gpu_of[j]] += w as f64;
        }
        loads
    }

    /// Expected MaxLoad under per-expert weights — what
    /// [`Placement::rebalance_from`] minimizes.
    pub fn expected_max_load(&self, weights: &[f32]) -> f64 {
        self.weighted_loads(weights).into_iter().fold(0.0, f64::max)
    }

    /// Greedy expert → GPU reassignment minimizing expected MaxLoad under
    /// the given per-expert weights (the serve loop feeds the tracked class
    /// mix's footprint weights): experts are placed heaviest-first, each
    /// onto the GPU with the least accumulated weight — LPT scheduling.
    /// Per-GPU expert COUNTS stay balanced within one (same capacity rule
    /// as construction), so memory residency never skews even when the
    /// weight mass does. LPT under the count constraint is a heuristic:
    /// callers that hold an incumbent placement should adopt the result
    /// only when [`Placement::expected_max_load`] strictly improves (the
    /// serve loop's `--ep-rebalance` step does exactly that).
    ///
    /// Deterministic: ties break toward the lower expert index and the
    /// lower GPU index. Weights must be finite and non-negative.
    pub fn rebalance_from(&self, weights: &[f32]) -> Placement {
        assert_eq!(weights.len(), self.n_experts, "weights must cover every expert");
        debug_assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "rebalance weights must be finite and non-negative"
        );
        let base = self.n_experts / self.n_gpus;
        let extra = self.n_experts % self.n_gpus;
        let cap: Vec<usize> =
            (0..self.n_gpus).map(|g| base + usize::from(g < extra)).collect();
        let mut order: Vec<usize> = (0..self.n_experts).collect();
        order.sort_by(|&a, &b| {
            weights[b]
                .partial_cmp(&weights[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut gpu_of = vec![0usize; self.n_experts];
        let mut acc = vec![0.0f64; self.n_gpus];
        let mut counts = vec![0usize; self.n_gpus];
        for &j in &order {
            let g = (0..self.n_gpus)
                .filter(|&g| counts[g] < cap[g])
                .min_by(|&x, &y| {
                    acc[x].partial_cmp(&acc[y]).unwrap_or(std::cmp::Ordering::Equal)
                })
                .expect("capacities sum to n_experts");
            gpu_of[j] = g;
            acc[g] += weights[j] as f64;
            counts[g] += 1;
        }
        let mut experts_of = vec![Vec::new(); self.n_gpus];
        for (j, &g) in gpu_of.iter().enumerate() {
            experts_of[g].push(j);
        }
        Placement {
            n_experts: self.n_experts,
            n_gpus: self.n_gpus,
            gpu_of,
            experts_of,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::ExpertSet;

    #[test]
    fn contiguous_blocks() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        assert_eq!(p.experts_on(0), &[0, 1, 2, 3]);
        assert_eq!(p.experts_on(1), &[4, 5, 6, 7]);
        assert_eq!(p.gpu_of(5), 1);
    }

    #[test]
    fn round_robin() {
        let p = Placement::new(6, 3, PlacementKind::RoundRobin);
        assert_eq!(p.gpu_of(0), 0);
        assert_eq!(p.gpu_of(1), 1);
        assert_eq!(p.gpu_of(5), 2);
        assert_eq!(p.experts_on(1), &[1, 4]);
    }

    #[test]
    fn uneven_split_is_balanced() {
        let p = Placement::new(10, 3, PlacementKind::Contiguous);
        let sizes: Vec<usize> = (0..3).map(|g| p.experts_on(g).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn random_is_seeded_partition() {
        let a = Placement::new(32, 4, PlacementKind::Random(1));
        let b = Placement::new(32, 4, PlacementKind::Random(1));
        let c = Placement::new(32, 4, PlacementKind::Random(2));
        assert_eq!(a.gpu_of, b.gpu_of);
        assert_ne!(a.gpu_of, c.gpu_of);
        // still a partition with balanced sizes
        let mut all: Vec<usize> = (0..4).flat_map(|g| a.experts_on(g).to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn prop_every_placement_is_a_partition() {
        // Invariant for all three kinds at arbitrary (N, G): every expert
        // is placed exactly once, `gpu_of` and `experts_of` agree, and
        // block sizes stay balanced within one expert.
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            0xEF,
            150,
            |r: &mut Rng| {
                let n_gpus = 1 + r.below(8);
                let n_experts = n_gpus + r.below(64);
                let kind = match r.below(3) {
                    0 => PlacementKind::Contiguous,
                    1 => PlacementKind::RoundRobin,
                    _ => PlacementKind::Random(r.next_u64()),
                };
                (n_experts, n_gpus, kind)
            },
            |&(n_experts, n_gpus, kind)| {
                let p = Placement::new(n_experts, n_gpus, kind);
                let mut seen = vec![0usize; n_experts];
                for g in 0..n_gpus {
                    for &j in p.experts_on(g) {
                        if p.gpu_of(j) != g {
                            return Err(format!(
                                "{kind:?}: expert {j} listed on GPU {g} but gpu_of says {}",
                                p.gpu_of(j)
                            ));
                        }
                        seen[j] += 1;
                    }
                }
                if let Some(j) = seen.iter().position(|&c| c != 1) {
                    return Err(format!(
                        "{kind:?} N={n_experts} G={n_gpus}: expert {j} placed {} times",
                        seen[j]
                    ));
                }
                let sizes: Vec<usize> =
                    (0..n_gpus).map(|g| p.experts_on(g).len()).collect();
                let (lo, hi) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                if hi - lo > 1 {
                    return Err(format!(
                        "{kind:?} N={n_experts} G={n_gpus}: unbalanced sizes {sizes:?}"
                    ));
                }
                // loads() of the full set must equal the block sizes.
                let full = crate::selection::ExpertSet::full(n_experts);
                if p.loads(&full) != sizes {
                    return Err("loads(full) disagrees with experts_on sizes".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rebalance_spreads_hot_experts() {
        // Contiguous placement piles the four hot experts onto GPU 0;
        // rebalancing under those weights spreads them one per GPU.
        let p = Placement::new(16, 4, PlacementKind::Contiguous);
        let mut w = vec![0.01f32; 16];
        for j in 0..4 {
            w[j] = 1.0; // all on GPU 0 under the contiguous split
        }
        assert!(p.expected_max_load(&w) > 4.0 - 1e-6);
        let r = p.rebalance_from(&w);
        let loads = r.weighted_loads(&w);
        assert!(
            r.expected_max_load(&w) < 1.2,
            "hot experts not spread: {loads:?}"
        );
        // every hot expert on its own GPU
        let hot_gpus: std::collections::BTreeSet<usize> =
            (0..4).map(|j| r.gpu_of(j)).collect();
        assert_eq!(hot_gpus.len(), 4);
    }

    #[test]
    fn rebalance_is_deterministic_and_count_balanced() {
        let p = Placement::new(10, 3, PlacementKind::RoundRobin);
        let w: Vec<f32> = (0..10).map(|j| (j as f32 * 0.37).sin().abs()).collect();
        let a = p.rebalance_from(&w);
        let b = p.rebalance_from(&w);
        assert_eq!(a.gpu_of, b.gpu_of);
        let sizes: Vec<usize> = (0..3).map(|g| a.experts_on(g).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn prop_rebalance_is_a_balanced_partition() {
        // For arbitrary (N, G, weights): the rebalanced assignment stays a
        // balanced partition (every expert placed exactly once, per-GPU
        // counts within one). LPT is a heuristic, not a guarantee — under
        // the count-balance constraint it CAN land above a lucky static
        // layout (e.g. N=3, G=2, ascending weights), which is why the
        // serve loop adopts a rebalanced placement only when its expected
        // MaxLoad strictly improves on the incumbent's.
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            0xBA1A,
            150,
            |r: &mut Rng| {
                let g = 1 + r.below(8);
                let n = g + r.below(48);
                let seed = r.next_u64();
                (n, g, seed)
            },
            |&(n, g, seed)| {
                let mut r = Rng::new(seed);
                let w: Vec<f32> = (0..n).map(|_| r.f32()).collect();
                let p = Placement::new(n, g, PlacementKind::Contiguous);
                let reb = p.rebalance_from(&w);
                let mut seen = vec![0usize; n];
                for gpu in 0..g {
                    for &j in reb.experts_on(gpu) {
                        if reb.gpu_of(j) != gpu {
                            return Err("gpu_of/experts_of disagree".into());
                        }
                        seen[j] += 1;
                    }
                }
                if seen.iter().any(|&c| c != 1) {
                    return Err("not a partition".into());
                }
                let sizes: Vec<usize> =
                    (0..g).map(|gpu| reb.experts_on(gpu).len()).collect();
                if sizes.iter().max().unwrap() - sizes.iter().min().unwrap() > 1 {
                    return Err(format!("unbalanced counts {sizes:?}"));
                }
                // weighted_loads must agree with the assignment it reports
                let total: f64 = reb.weighted_loads(&w).iter().sum();
                let want: f64 = w.iter().map(|&x| x as f64).sum();
                if (total - want).abs() > 1e-6 {
                    return Err("weighted_loads lost mass".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn weighted_loads_match_integer_loads_on_indicator_weights() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let s = ExpertSet::from_indices(8, &[0, 1, 2, 4]);
        let mut w = vec![0.0f32; 8];
        for j in s.iter() {
            w[j] = 1.0;
        }
        let wl = p.weighted_loads(&w);
        let il = p.loads(&s);
        for (a, b) in wl.iter().zip(&il) {
            assert!((a - *b as f64).abs() < 1e-12);
        }
        assert!((p.expected_max_load(&w) - p.max_load(&s) as f64).abs() < 1e-12);
    }

    #[test]
    fn loads_and_max_load() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let s = ExpertSet::from_indices(8, &[0, 1, 2, 4]);
        assert_eq!(p.loads(&s), vec![3, 1]);
        assert_eq!(p.max_load(&s), 3);
        assert_eq!(p.max_load(&ExpertSet::empty(8)), 0);
    }
}
