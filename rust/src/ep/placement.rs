//! Expert → GPU placement for expert-parallel (EP) deployments (§5).
//!
//! Since PR 6 a placement is a **replica set**, not a strict partition:
//! every expert is resident on at least one GPU, and hot experts may be
//! copied to several (the incremental-migration lever in [`crate::ep::migrate`],
//! following the replication design of arxiv 2605.11537). The load-accounting
//! contract:
//!
//!  * **Routing.** [`Placement::loads`] / [`Placement::weighted_loads`] walk
//!    the experts in ascending index order and send each expert's whole load
//!    to its currently least-loaded replica, tie-breaking toward the lowest
//!    GPU index. The walk is deterministic, and on a partition (every expert
//!    exactly one host) it reduces bit-for-bit to the legacy
//!    `loads[gpu_of[j]] += w` accumulation — pinned by
//!    `prop_slack_one_reproduces_partition_bitwise` below. Online greedy in
//!    index order is a heuristic: a replica only pays off when the expert's
//!    other hosts are busier at its routing turn, which is exactly the
//!    condition the migration planner evaluates before copying.
//!  * **Capacity.** Per-GPU residency is bounded by
//!    [`Placement::residency_cap`]: at replica slack `F ≥ 1`
//!    (`--ep-replica-slack F`) a GPU holds at most `⌈F·N/G⌉` experts, so
//!    replication's memory overhead is explicit and bounded. Slack 1.0
//!    leaves no headroom beyond the balanced partition's largest block.
//!  * **Coverage.** Every expert keeps ≥ 1 replica at all times
//!    ([`Placement::drop_replica`] refuses to orphan an expert).
//!
//! Construction ([`Placement::new`]) still produces the classic partitions —
//! contiguous (DeepSeek-style), round-robin, or seeded-random blocks;
//! replicas appear only through migration or prefetch. The placement policy
//! remains an ablation axis in `benches/ablations.rs`.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Experts [0, N/G) on GPU 0, [N/G, 2N/G) on GPU 1, …
    Contiguous,
    /// Expert j on GPU j mod G.
    RoundRobin,
    /// Seeded random permutation, then contiguous blocks.
    Random(u64),
}

/// An expert → GPU-group replica assignment (see the module docs for the
/// routing / capacity / coverage contract).
#[derive(Debug, Clone)]
pub struct Placement {
    n_experts: usize,
    n_gpus: usize,
    /// replicas_of[j] = GPUs hosting a copy of expert j (ascending, never
    /// empty).
    replicas_of: Vec<Vec<usize>>,
    /// experts_of[g] = experts resident on GPU g (ascending).
    experts_of: Vec<Vec<usize>>,
}

impl Placement {
    pub fn new(n_experts: usize, n_gpus: usize, kind: PlacementKind) -> Placement {
        assert!(n_gpus > 0 && n_experts >= n_gpus, "need n_experts >= n_gpus >= 1");
        let order: Vec<usize> = match kind {
            PlacementKind::Contiguous | PlacementKind::RoundRobin => (0..n_experts).collect(),
            PlacementKind::Random(seed) => {
                let mut idx: Vec<usize> = (0..n_experts).collect();
                Rng::new(seed).shuffle(&mut idx);
                idx
            }
        };
        let mut gpu_of = vec![0usize; n_experts];
        match kind {
            PlacementKind::RoundRobin => {
                for (pos, &j) in order.iter().enumerate() {
                    gpu_of[j] = pos % n_gpus;
                }
            }
            _ => {
                // contiguous blocks over `order` (balanced sizes, remainder
                // spread over the first GPUs)
                let base = n_experts / n_gpus;
                let extra = n_experts % n_gpus;
                let mut pos = 0;
                for g in 0..n_gpus {
                    let take = base + usize::from(g < extra);
                    for &j in &order[pos..pos + take] {
                        gpu_of[j] = g;
                    }
                    pos += take;
                }
            }
        }
        Placement::from_replicas(n_gpus, gpu_of.into_iter().map(|g| vec![g]).collect())
    }

    /// Build a placement from explicit replica sets (`replicas_of[j]` = the
    /// GPUs hosting expert j). Host lists are sorted and deduplicated; every
    /// expert needs at least one in-range host.
    pub fn from_replicas(n_gpus: usize, mut replicas_of: Vec<Vec<usize>>) -> Placement {
        assert!(n_gpus > 0, "need at least one GPU");
        let n_experts = replicas_of.len();
        assert!(n_experts > 0, "need at least one expert");
        let mut experts_of = vec![Vec::new(); n_gpus];
        for (j, hosts) in replicas_of.iter_mut().enumerate() {
            hosts.sort_unstable();
            hosts.dedup();
            assert!(!hosts.is_empty(), "expert {j} has no replica");
            assert!(
                *hosts.last().unwrap() < n_gpus,
                "expert {j} hosted on GPU {} of {n_gpus}",
                hosts.last().unwrap()
            );
            for &g in hosts.iter() {
                experts_of[g].push(j);
            }
        }
        Placement { n_experts, n_gpus, replicas_of, experts_of }
    }

    #[inline]
    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    #[inline]
    pub fn n_gpus(&self) -> usize {
        self.n_gpus
    }

    /// The expert's primary (lowest-indexed) host — under a partition this
    /// is its only host, the legacy `gpu_of[j]`.
    #[inline]
    pub fn gpu_of(&self, expert: usize) -> usize {
        self.replicas_of[expert][0]
    }

    /// All GPUs hosting a copy of the expert (ascending, never empty).
    #[inline]
    pub fn replicas(&self, expert: usize) -> &[usize] {
        &self.replicas_of[expert]
    }

    #[inline]
    pub fn n_replicas(&self, expert: usize) -> usize {
        self.replicas_of[expert].len()
    }

    /// Whether `gpu` holds a copy of `expert`.
    pub fn hosts(&self, gpu: usize, expert: usize) -> bool {
        self.replicas_of[expert].binary_search(&gpu).is_ok()
    }

    /// Experts resident on the GPU (replicas included), ascending.
    pub fn experts_on(&self, gpu: usize) -> &[usize] {
        &self.experts_of[gpu]
    }

    /// Number of expert copies resident on the GPU — what
    /// [`Placement::residency_cap`] bounds.
    pub fn residency(&self, gpu: usize) -> usize {
        self.experts_of[gpu].len()
    }

    /// True iff every expert has exactly one replica (the legacy shape;
    /// every [`Placement::new`] / [`Placement::rebalance_from`] result).
    pub fn is_partition(&self) -> bool {
        self.replicas_of.iter().all(|hosts| hosts.len() == 1)
    }

    /// Per-GPU residency bound at replica slack `F ≥ 1`: `⌈F·N/G⌉` expert
    /// copies (never below the balanced partition's largest block, so a
    /// fresh placement always fits its own cap).
    pub fn residency_cap(n_experts: usize, n_gpus: usize, slack: f64) -> usize {
        assert!(n_gpus > 0, "need at least one GPU");
        assert!(slack.is_finite() && slack >= 1.0, "replica slack {slack} must be ≥ 1");
        let raw = slack * n_experts as f64 / n_gpus as f64;
        // tolerate f64 noise just below an integer boundary
        let cap = (raw - 1e-9).ceil() as usize;
        cap.max(n_experts.div_ceil(n_gpus))
    }

    /// Per-GPU load Load_g(S) for a selected set: each selected expert
    /// counts once, on its least-loaded replica at its (ascending-order)
    /// routing turn; ties go to the lowest GPU index.
    pub fn loads(&self, selected: &crate::selection::ExpertSet) -> Vec<usize> {
        let mut loads = vec![0usize; self.n_gpus];
        for j in selected.iter() {
            let mut best = self.replicas_of[j][0];
            for &g in &self.replicas_of[j][1..] {
                if loads[g] < loads[best] {
                    best = g;
                }
            }
            loads[best] += 1;
        }
        loads
    }

    /// MaxLoad(S) — the synchronization straggler (§5.1), replica-resolved.
    pub fn max_load(&self, selected: &crate::selection::ExpertSet) -> usize {
        self.loads(selected).into_iter().max().unwrap_or(0)
    }

    /// Replica-resolved routing of fractional per-expert weights (the
    /// tracked traffic mix): walks experts in ascending index order, sends
    /// each expert's whole weight to its currently least-loaded replica
    /// (tie: lowest GPU index), and returns the per-GPU loads plus the host
    /// each expert's weight landed on — the migration planner uses the
    /// routing to find replicas that receive no traffic.
    pub fn route_weights(&self, weights: &[f32]) -> (Vec<f64>, Vec<usize>) {
        assert_eq!(weights.len(), self.n_experts, "weights must cover every expert");
        let mut loads = vec![0.0f64; self.n_gpus];
        let mut routed = vec![0usize; self.n_experts];
        for (j, &w) in weights.iter().enumerate() {
            let mut best = self.replicas_of[j][0];
            for &g in &self.replicas_of[j][1..] {
                if loads[g] < loads[best] {
                    best = g;
                }
            }
            routed[j] = best;
            loads[best] += w as f64;
        }
        (loads, routed)
    }

    /// Expected per-GPU load under fractional per-expert weights — the
    /// continuous analogue of [`Placement::loads`] that rebalancing and
    /// migration planning optimize against.
    pub fn weighted_loads(&self, weights: &[f32]) -> Vec<f64> {
        self.route_weights(weights).0
    }

    /// Expected MaxLoad under per-expert weights — what
    /// [`Placement::rebalance_from`] and `ep::migrate::plan_migration`
    /// minimize.
    pub fn expected_max_load(&self, weights: &[f32]) -> f64 {
        self.weighted_loads(weights).into_iter().fold(0.0, f64::max)
    }

    /// Add a replica of `expert` on `gpu`. Returns false (no-op) when the
    /// GPU already hosts it. Callers enforce [`Placement::residency_cap`];
    /// the placement itself only maintains coverage and consistency.
    pub fn add_replica(&mut self, expert: usize, gpu: usize) -> bool {
        assert!(expert < self.n_experts && gpu < self.n_gpus, "replica out of range");
        let hosts = &mut self.replicas_of[expert];
        let Err(pos) = hosts.binary_search(&gpu) else { return false };
        hosts.insert(pos, gpu);
        let row = &mut self.experts_of[gpu];
        let pos = row.binary_search(&expert).unwrap_err();
        row.insert(pos, expert);
        true
    }

    /// Drop the replica of `expert` on `gpu`. Returns false (no-op) when
    /// the GPU does not host it — or when it holds the expert's LAST
    /// replica: coverage is an invariant, an expert can never be orphaned.
    pub fn drop_replica(&mut self, expert: usize, gpu: usize) -> bool {
        assert!(expert < self.n_experts && gpu < self.n_gpus, "replica out of range");
        if self.replicas_of[expert].len() < 2 {
            return false;
        }
        let Ok(pos) = self.replicas_of[expert].binary_search(&gpu) else { return false };
        self.replicas_of[expert].remove(pos);
        let row = &mut self.experts_of[gpu];
        let pos = row.binary_search(&expert).expect("experts_of out of sync");
        row.remove(pos);
        true
    }

    /// Greedy expert → GPU reassignment minimizing expected MaxLoad under
    /// the given per-expert weights (the serve loop feeds the tracked class
    /// mix's footprint weights): experts are placed heaviest-first, each
    /// onto the GPU with the least accumulated weight — LPT scheduling.
    /// Per-GPU expert COUNTS stay balanced within one (same capacity rule
    /// as construction), so memory residency never skews even when the
    /// weight mass does. The result is always a strict partition (one
    /// replica per expert): this is the legacy `--ep-migrate-budget 0`
    /// instantaneous swap; `ep::migrate::plan_migration` is the
    /// replica-aware, bounded alternative. LPT under the count constraint
    /// is a heuristic: callers that hold an incumbent placement should
    /// adopt the result only when [`Placement::expected_max_load`] strictly
    /// improves (the serve loop's `--ep-rebalance` step does exactly that).
    ///
    /// Deterministic: ties break toward the lower expert index and the
    /// lower GPU index. Weights must be finite and non-negative.
    pub fn rebalance_from(&self, weights: &[f32]) -> Placement {
        assert_eq!(weights.len(), self.n_experts, "weights must cover every expert");
        debug_assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "rebalance weights must be finite and non-negative"
        );
        let base = self.n_experts / self.n_gpus;
        let extra = self.n_experts % self.n_gpus;
        let cap: Vec<usize> =
            (0..self.n_gpus).map(|g| base + usize::from(g < extra)).collect();
        let mut order: Vec<usize> = (0..self.n_experts).collect();
        order.sort_by(|&a, &b| {
            weights[b]
                .partial_cmp(&weights[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut gpu_of = vec![0usize; self.n_experts];
        let mut acc = vec![0.0f64; self.n_gpus];
        let mut counts = vec![0usize; self.n_gpus];
        for &j in &order {
            let g = (0..self.n_gpus)
                .filter(|&g| counts[g] < cap[g])
                .min_by(|&x, &y| {
                    acc[x].partial_cmp(&acc[y]).unwrap_or(std::cmp::Ordering::Equal)
                })
                .expect("capacities sum to n_experts");
            gpu_of[j] = g;
            acc[g] += weights[j] as f64;
            counts[g] += 1;
        }
        Placement::from_replicas(self.n_gpus, gpu_of.into_iter().map(|g| vec![g]).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ep::comm::{uniform_tokens, EpCostModel};
    use crate::selection::ExpertSet;

    #[test]
    fn contiguous_blocks() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        assert_eq!(p.experts_on(0), &[0, 1, 2, 3]);
        assert_eq!(p.experts_on(1), &[4, 5, 6, 7]);
        assert_eq!(p.gpu_of(5), 1);
        assert!(p.is_partition());
    }

    #[test]
    fn round_robin() {
        let p = Placement::new(6, 3, PlacementKind::RoundRobin);
        assert_eq!(p.gpu_of(0), 0);
        assert_eq!(p.gpu_of(1), 1);
        assert_eq!(p.gpu_of(5), 2);
        assert_eq!(p.experts_on(1), &[1, 4]);
    }

    #[test]
    fn uneven_split_is_balanced() {
        let p = Placement::new(10, 3, PlacementKind::Contiguous);
        let sizes: Vec<usize> = (0..3).map(|g| p.experts_on(g).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn random_is_seeded_partition() {
        let a = Placement::new(32, 4, PlacementKind::Random(1));
        let b = Placement::new(32, 4, PlacementKind::Random(1));
        let c = Placement::new(32, 4, PlacementKind::Random(2));
        assert_eq!(a.replicas_of, b.replicas_of);
        assert_ne!(a.replicas_of, c.replicas_of);
        // still a partition with balanced sizes
        assert!(a.is_partition());
        let mut all: Vec<usize> = (0..4).flat_map(|g| a.experts_on(g).to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());
    }

    /// Shared consistency check: `replicas_of` and `experts_of` agree, every
    /// expert has ≥ 1 replica, host lists are sorted + deduplicated, and no
    /// GPU holds more than `cap` copies.
    fn check_coverage_and_capacity(p: &Placement, cap: usize) -> Result<(), String> {
        let mut replica_counts = vec![0usize; p.n_experts()];
        for g in 0..p.n_gpus() {
            if p.residency(g) > cap {
                return Err(format!("GPU {g} holds {} > cap {cap}", p.residency(g)));
            }
            for &j in p.experts_on(g) {
                if !p.hosts(g, j) {
                    return Err(format!("expert {j} listed on GPU {g} but hosts() says no"));
                }
                replica_counts[j] += 1;
            }
        }
        for (j, &c) in replica_counts.iter().enumerate() {
            if c == 0 {
                return Err(format!("expert {j} has no replica (coverage broken)"));
            }
            if c != p.n_replicas(j) {
                return Err(format!(
                    "expert {j}: experts_of says {c} replicas, replicas_of says {}",
                    p.n_replicas(j)
                ));
            }
            if !p.hosts(p.gpu_of(j), j) {
                return Err(format!("expert {j}: primary host not in replica set"));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_every_placement_covers_and_fits() {
        // The PR 6 generalization of `prop_every_placement_is_a_partition`:
        // for all three kinds at arbitrary (N, G), construction yields an
        // exact balanced partition (one replica per expert), and after a
        // random sequence of capacity-respecting add_replica / drop_replica
        // mutations the placement still satisfies coverage (every expert
        // ≥ 1 replica) and the per-GPU residency cap.
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            0xEF,
            150,
            |r: &mut Rng| {
                let n_gpus = 1 + r.below(8);
                let n_experts = n_gpus + r.below(64);
                let kind = match r.below(3) {
                    0 => PlacementKind::Contiguous,
                    1 => PlacementKind::RoundRobin,
                    _ => PlacementKind::Random(r.next_u64()),
                };
                (n_experts, n_gpus, kind, r.next_u64())
            },
            |&(n_experts, n_gpus, kind, mut_seed)| {
                let p = Placement::new(n_experts, n_gpus, kind);
                // fresh construction: exactly a partition, balanced within
                // one, within the slack-1.0 cap
                if !p.is_partition() {
                    return Err(format!("{kind:?}: construction is not a partition"));
                }
                let cap1 = Placement::residency_cap(n_experts, n_gpus, 1.0);
                check_coverage_and_capacity(&p, cap1)?;
                let sizes: Vec<usize> =
                    (0..n_gpus).map(|g| p.experts_on(g).len()).collect();
                let (lo, hi) =
                    (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
                if hi - lo > 1 {
                    return Err(format!(
                        "{kind:?} N={n_experts} G={n_gpus}: unbalanced sizes {sizes:?}"
                    ));
                }
                // loads() of the full set must equal the block sizes on a
                // partition (no routing freedom)
                let full = crate::selection::ExpertSet::full(n_experts);
                if p.loads(&full) != sizes {
                    return Err("loads(full) disagrees with experts_on sizes".into());
                }
                // random replica churn under a slack-1.5 cap: the invariant
                // must survive arbitrary capacity-respecting mutations
                let cap = Placement::residency_cap(n_experts, n_gpus, 1.5);
                let mut q = p.clone();
                let mut r = Rng::new(mut_seed);
                for _ in 0..8 {
                    let (j, g) = (r.below(n_experts), r.below(n_gpus));
                    if q.residency(g) < cap {
                        q.add_replica(j, g);
                    }
                    let (j, g) = (r.below(n_experts), r.below(n_gpus));
                    q.drop_replica(j, g); // refuses to orphan internally
                }
                check_coverage_and_capacity(&q, cap)?;
                // routing conserves mass whatever the replica shape
                let total: usize = q.loads(&full).iter().sum();
                if total != n_experts {
                    return Err(format!("routing lost mass: {total} != {n_experts}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_slack_one_reproduces_partition_bitwise() {
        // Backward-compatibility pin (PR 6): a slack-1.0 placement — i.e.
        // any fresh construction, which has one replica per expert — must
        // reproduce the pre-replica partition semantics EXACTLY, for all
        // three kinds: `loads`, `max_load`, `weighted_loads`,
        // `expected_max_load`, and `EpCostModel::layer_latency` bit-equal
        // to the legacy `loads[gpu_of[j]] += w` accumulation.
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            0x51AC,
            150,
            |r: &mut Rng| {
                let n_gpus = 1 + r.below(8);
                let n_experts = n_gpus + r.below(64);
                let kind = match r.below(3) {
                    0 => PlacementKind::Contiguous,
                    1 => PlacementKind::RoundRobin,
                    _ => PlacementKind::Random(r.next_u64()),
                };
                (n_experts, n_gpus, kind, r.next_u64())
            },
            |&(n_experts, n_gpus, kind, seed)| {
                let p = Placement::new(n_experts, n_gpus, kind);
                let mut r = Rng::new(seed);
                let sel_idx: Vec<usize> =
                    (0..n_experts).filter(|_| r.below(2) == 0).collect();
                let sel = ExpertSet::from_indices(n_experts, &sel_idx);
                let weights: Vec<f32> = (0..n_experts).map(|_| r.f32()).collect();

                // integer loads: legacy accumulation over gpu_of
                let mut ref_loads = vec![0usize; n_gpus];
                for j in sel.iter() {
                    ref_loads[p.gpu_of(j)] += 1;
                }
                if p.loads(&sel) != ref_loads {
                    return Err(format!("{kind:?}: loads diverged from partition"));
                }
                if p.max_load(&sel) != ref_loads.iter().copied().max().unwrap_or(0) {
                    return Err("max_load diverged".into());
                }

                // weighted loads: bit-equal f64 accumulation in the same
                // (ascending index) order the legacy code used
                let mut ref_w = vec![0.0f64; n_gpus];
                for (j, &w) in weights.iter().enumerate() {
                    ref_w[p.gpu_of(j)] += w as f64;
                }
                let got_w = p.weighted_loads(&weights);
                for (g, (a, b)) in got_w.iter().zip(&ref_w).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("GPU {g}: weighted load {a} != legacy {b}"));
                    }
                }
                let ref_max = ref_w.into_iter().fold(0.0, f64::max);
                if p.expected_max_load(&weights).to_bits() != ref_max.to_bits() {
                    return Err("expected_max_load diverged".into());
                }

                // layer latency: same ints in, same arithmetic, bit-equal out
                let model = EpCostModel::default();
                let toks = uniform_tokens(1 + r.below(32), n_gpus);
                let straggler = ref_loads
                    .iter()
                    .zip(&toks)
                    .map(|(&l, &t)| {
                        l as f64 * model.expert_load_s
                            + (l * t) as f64 * model.expert_compute_s
                    })
                    .fold(0.0f64, f64::max);
                let total_tokens: usize = toks.iter().sum();
                let a2a = 2.0 * total_tokens as f64 * model.bytes_per_token
                    / model.interconnect_bw;
                let want = straggler + a2a + model.sync_overhead_s;
                let got = model.layer_latency(&p, &sel, &toks);
                if got.to_bits() != want.to_bits() {
                    return Err(format!("layer_latency {got} != legacy {want}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn replica_routing_splits_load_to_least_loaded_host() {
        // Experts 0, 1 on GPU 0; 3 on GPU 1; 2 replicated on both. At
        // expert 2's routing turn GPU 0 already carries {0, 1}, so its load
        // lands on GPU 1 — the partition alternative (2 pinned to GPU 0)
        // would hit MaxLoad 3.
        let p = Placement::from_replicas(2, vec![vec![0], vec![0], vec![0, 1], vec![1]]);
        let sel = ExpertSet::from_indices(4, &[0, 1, 2]);
        assert_eq!(p.loads(&sel), vec![2, 1]);
        assert_eq!(p.max_load(&sel), 2);
        // tie-break: alone, the replicated expert routes to its lowest host
        let lone = ExpertSet::from_indices(4, &[2]);
        assert_eq!(p.loads(&lone), vec![1, 0]);
        // weighted routing follows the same walk and reports the hosts
        let (wl, routed) = p.route_weights(&[1.0, 1.0, 1.0, 0.0]);
        assert_eq!(routed, vec![0, 0, 1, 1]);
        assert!((wl[0] - 2.0).abs() < 1e-12 && (wl[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn add_and_drop_replica_maintain_invariants() {
        let mut p = Placement::new(6, 2, PlacementKind::Contiguous);
        assert!(p.is_partition());
        assert!(p.add_replica(0, 1));
        assert!(!p.add_replica(0, 1), "duplicate replica must be a no-op");
        assert_eq!(p.replicas(0), &[0, 1]);
        assert_eq!(p.n_replicas(0), 2);
        assert_eq!(p.residency(1), 4);
        assert!(p.hosts(1, 0) && p.experts_on(1).contains(&0));
        assert!(!p.is_partition());
        assert!(p.drop_replica(0, 0));
        assert_eq!(p.replicas(0), &[1]);
        assert!(!p.drop_replica(0, 1), "the last replica must never drop");
        assert_eq!(p.gpu_of(0), 1, "primary follows the surviving replica");
        assert!(!p.drop_replica(3, 1), "dropping a non-resident copy is a no-op");
    }

    #[test]
    fn residency_cap_formula() {
        // ⌈F·N/G⌉, never below the balanced partition's largest block
        assert_eq!(Placement::residency_cap(8, 2, 1.0), 4);
        assert_eq!(Placement::residency_cap(8, 2, 1.1), 5);
        assert_eq!(Placement::residency_cap(8, 2, 1.5), 6);
        assert_eq!(Placement::residency_cap(8, 2, 2.0), 8);
        assert_eq!(Placement::residency_cap(10, 3, 1.0), 4);
        assert_eq!(Placement::residency_cap(6, 4, 1.0), 2);
    }

    #[test]
    fn rebalance_spreads_hot_experts() {
        // Contiguous placement piles the four hot experts onto GPU 0;
        // rebalancing under those weights spreads them one per GPU.
        let p = Placement::new(16, 4, PlacementKind::Contiguous);
        let mut w = vec![0.01f32; 16];
        for j in 0..4 {
            w[j] = 1.0; // all on GPU 0 under the contiguous split
        }
        assert!(p.expected_max_load(&w) > 4.0 - 1e-6);
        let r = p.rebalance_from(&w);
        let loads = r.weighted_loads(&w);
        assert!(
            r.expected_max_load(&w) < 1.2,
            "hot experts not spread: {loads:?}"
        );
        // every hot expert on its own GPU
        let hot_gpus: std::collections::BTreeSet<usize> =
            (0..4).map(|j| r.gpu_of(j)).collect();
        assert_eq!(hot_gpus.len(), 4);
    }

    #[test]
    fn rebalance_is_deterministic_and_count_balanced() {
        let p = Placement::new(10, 3, PlacementKind::RoundRobin);
        let w: Vec<f32> = (0..10).map(|j| (j as f32 * 0.37).sin().abs()).collect();
        let a = p.rebalance_from(&w);
        let b = p.rebalance_from(&w);
        assert_eq!(a.replicas_of, b.replicas_of);
        let sizes: Vec<usize> = (0..3).map(|g| a.experts_on(g).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn prop_rebalance_is_a_balanced_partition() {
        // For arbitrary (N, G, weights): the rebalanced assignment stays a
        // balanced partition (every expert placed exactly once, per-GPU
        // counts within one). LPT is a heuristic, not a guarantee — under
        // the count-balance constraint it CAN land above a lucky static
        // layout (e.g. N=3, G=2, ascending weights), which is why the
        // serve loop adopts a rebalanced placement only when its expected
        // MaxLoad strictly improves on the incumbent's.
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            0xBA1A,
            150,
            |r: &mut Rng| {
                let g = 1 + r.below(8);
                let n = g + r.below(48);
                let seed = r.next_u64();
                (n, g, seed)
            },
            |&(n, g, seed)| {
                let mut r = Rng::new(seed);
                let w: Vec<f32> = (0..n).map(|_| r.f32()).collect();
                let p = Placement::new(n, g, PlacementKind::Contiguous);
                let reb = p.rebalance_from(&w);
                if !reb.is_partition() {
                    return Err("rebalance_from must yield a partition".into());
                }
                check_coverage_and_capacity(&reb, Placement::residency_cap(n, g, 1.0))?;
                let sizes: Vec<usize> =
                    (0..g).map(|gpu| reb.experts_on(gpu).len()).collect();
                if sizes.iter().max().unwrap() - sizes.iter().min().unwrap() > 1 {
                    return Err(format!("unbalanced counts {sizes:?}"));
                }
                // weighted_loads must agree with the assignment it reports
                let total: f64 = reb.weighted_loads(&w).iter().sum();
                let want: f64 = w.iter().map(|&x| x as f64).sum();
                if (total - want).abs() > 1e-6 {
                    return Err("weighted_loads lost mass".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn weighted_loads_match_integer_loads_on_indicator_weights() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let s = ExpertSet::from_indices(8, &[0, 1, 2, 4]);
        let mut w = vec![0.0f32; 8];
        for j in s.iter() {
            w[j] = 1.0;
        }
        let wl = p.weighted_loads(&w);
        let il = p.loads(&s);
        for (a, b) in wl.iter().zip(&il) {
            assert!((a - *b as f64).abs() < 1e-12);
        }
        assert!((p.expected_max_load(&w) - p.max_load(&s) as f64).abs() < 1e-12);
    }

    #[test]
    fn loads_and_max_load() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let s = ExpertSet::from_indices(8, &[0, 1, 2, 4]);
        assert_eq!(p.loads(&s), vec![3, 1]);
        assert_eq!(p.max_load(&s), 3);
        assert_eq!(p.max_load(&ExpertSet::empty(8)), 0);
    }
}
