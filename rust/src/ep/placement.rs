//! Expert → GPU placement for expert-parallel (EP) deployments (§5).
//!
//! The experts of each layer form a partition E = ∪_g E_g across G GPU
//! groups. Serving systems place experts contiguously (DeepSeek-style),
//! round-robin, or randomly (after load-balancing shuffles); the placement
//! policy is an ablation axis in `benches/ablations.rs`.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Experts [0, N/G) on GPU 0, [N/G, 2N/G) on GPU 1, …
    Contiguous,
    /// Expert j on GPU j mod G.
    RoundRobin,
    /// Seeded random permutation, then contiguous blocks.
    Random(u64),
}

/// An expert → GPU-group assignment.
#[derive(Debug, Clone)]
pub struct Placement {
    n_experts: usize,
    n_gpus: usize,
    /// gpu_of[j] = GPU group hosting expert j.
    gpu_of: Vec<usize>,
    /// experts_of[g] = experts hosted on GPU g (ascending).
    experts_of: Vec<Vec<usize>>,
}

impl Placement {
    pub fn new(n_experts: usize, n_gpus: usize, kind: PlacementKind) -> Placement {
        assert!(n_gpus > 0 && n_experts >= n_gpus, "need n_experts >= n_gpus >= 1");
        let order: Vec<usize> = match kind {
            PlacementKind::Contiguous | PlacementKind::RoundRobin => (0..n_experts).collect(),
            PlacementKind::Random(seed) => {
                let mut idx: Vec<usize> = (0..n_experts).collect();
                Rng::new(seed).shuffle(&mut idx);
                idx
            }
        };
        let mut gpu_of = vec![0usize; n_experts];
        match kind {
            PlacementKind::RoundRobin => {
                for (pos, &j) in order.iter().enumerate() {
                    gpu_of[j] = pos % n_gpus;
                }
            }
            _ => {
                // contiguous blocks over `order` (balanced sizes, remainder
                // spread over the first GPUs)
                let base = n_experts / n_gpus;
                let extra = n_experts % n_gpus;
                let mut pos = 0;
                for g in 0..n_gpus {
                    let take = base + usize::from(g < extra);
                    for &j in &order[pos..pos + take] {
                        gpu_of[j] = g;
                    }
                    pos += take;
                }
            }
        }
        let mut experts_of = vec![Vec::new(); n_gpus];
        for (j, &g) in gpu_of.iter().enumerate() {
            experts_of[g].push(j);
        }
        Placement { n_experts, n_gpus, gpu_of, experts_of }
    }

    #[inline]
    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    #[inline]
    pub fn n_gpus(&self) -> usize {
        self.n_gpus
    }

    #[inline]
    pub fn gpu_of(&self, expert: usize) -> usize {
        self.gpu_of[expert]
    }

    pub fn experts_on(&self, gpu: usize) -> &[usize] {
        &self.experts_of[gpu]
    }

    /// Per-GPU load Load_g(S) = |S ∩ E_g| for a selected set.
    pub fn loads(&self, selected: &crate::selection::ExpertSet) -> Vec<usize> {
        let mut loads = vec![0usize; self.n_gpus];
        for j in selected.iter() {
            loads[self.gpu_of[j]] += 1;
        }
        loads
    }

    /// MaxLoad(S) — the synchronization straggler (§5.1).
    pub fn max_load(&self, selected: &crate::selection::ExpertSet) -> usize {
        self.loads(selected).into_iter().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::ExpertSet;

    #[test]
    fn contiguous_blocks() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        assert_eq!(p.experts_on(0), &[0, 1, 2, 3]);
        assert_eq!(p.experts_on(1), &[4, 5, 6, 7]);
        assert_eq!(p.gpu_of(5), 1);
    }

    #[test]
    fn round_robin() {
        let p = Placement::new(6, 3, PlacementKind::RoundRobin);
        assert_eq!(p.gpu_of(0), 0);
        assert_eq!(p.gpu_of(1), 1);
        assert_eq!(p.gpu_of(5), 2);
        assert_eq!(p.experts_on(1), &[1, 4]);
    }

    #[test]
    fn uneven_split_is_balanced() {
        let p = Placement::new(10, 3, PlacementKind::Contiguous);
        let sizes: Vec<usize> = (0..3).map(|g| p.experts_on(g).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn random_is_seeded_partition() {
        let a = Placement::new(32, 4, PlacementKind::Random(1));
        let b = Placement::new(32, 4, PlacementKind::Random(1));
        let c = Placement::new(32, 4, PlacementKind::Random(2));
        assert_eq!(a.gpu_of, b.gpu_of);
        assert_ne!(a.gpu_of, c.gpu_of);
        // still a partition with balanced sizes
        let mut all: Vec<usize> = (0..4).flat_map(|g| a.experts_on(g).to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn prop_every_placement_is_a_partition() {
        // Invariant for all three kinds at arbitrary (N, G): every expert
        // is placed exactly once, `gpu_of` and `experts_of` agree, and
        // block sizes stay balanced within one expert.
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            0xEF,
            150,
            |r: &mut Rng| {
                let n_gpus = 1 + r.below(8);
                let n_experts = n_gpus + r.below(64);
                let kind = match r.below(3) {
                    0 => PlacementKind::Contiguous,
                    1 => PlacementKind::RoundRobin,
                    _ => PlacementKind::Random(r.next_u64()),
                };
                (n_experts, n_gpus, kind)
            },
            |&(n_experts, n_gpus, kind)| {
                let p = Placement::new(n_experts, n_gpus, kind);
                let mut seen = vec![0usize; n_experts];
                for g in 0..n_gpus {
                    for &j in p.experts_on(g) {
                        if p.gpu_of(j) != g {
                            return Err(format!(
                                "{kind:?}: expert {j} listed on GPU {g} but gpu_of says {}",
                                p.gpu_of(j)
                            ));
                        }
                        seen[j] += 1;
                    }
                }
                if let Some(j) = seen.iter().position(|&c| c != 1) {
                    return Err(format!(
                        "{kind:?} N={n_experts} G={n_gpus}: expert {j} placed {} times",
                        seen[j]
                    ));
                }
                let sizes: Vec<usize> =
                    (0..n_gpus).map(|g| p.experts_on(g).len()).collect();
                let (lo, hi) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                if hi - lo > 1 {
                    return Err(format!(
                        "{kind:?} N={n_experts} G={n_gpus}: unbalanced sizes {sizes:?}"
                    ));
                }
                // loads() of the full set must equal the block sizes.
                let full = crate::selection::ExpertSet::full(n_experts);
                if p.loads(&full) != sizes {
                    return Err("loads(full) disagrees with experts_on sizes".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn loads_and_max_load() {
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let s = ExpertSet::from_indices(8, &[0, 1, 2, 4]);
        assert_eq!(p.loads(&s), vec![3, 1]);
        assert_eq!(p.max_load(&s), 3);
        assert_eq!(p.max_load(&ExpertSet::empty(8)), 0);
    }
}
