//! Expert-parallelism substrate (§5 of the paper): expert→GPU placement,
//! per-GPU load accounting, and the interconnect/straggler model that turns
//! MaxLoad into layer latency.
//!
//! # The replica / migration / prefetch contract (PR 6)
//!
//! * **Replica routing** ([`placement`]): a [`Placement`] maps each expert
//!   to ≥ 1 hosting GPU. Load accounting walks selected experts in
//!   ascending index order and charges each to its currently least-loaded
//!   replica (tie: lowest GPU). On a one-replica-per-expert partition this
//!   is bit-identical to the legacy `gpu_of` accumulation; replication is
//!   bounded by a per-GPU residency cap of `⌈slack·N/G⌉` expert copies
//!   (`--ep-replica-slack`).
//! * **Migration charging** ([`migrate`] + [`comm`]): placement changes are
//!   physical. A migration step is a bounded op plan (≤ `--ep-migrate-budget`
//!   copies/drops) whose copies cost
//!   [`EpCostModel::migration_seconds`] — `copies × expert_bytes` over the
//!   interconnect — charged into the serve loop's backlog and drained
//!   against subsequent step time (the transfer overlaps decode). Plans are
//!   adopted only when the expected-MaxLoad win, amortized over a horizon
//!   of layer forwards, beats that charge.
//! * **Prefetch** ([`crate::coordinator::serve_loop`]): the same planner run
//!   over the *queued* classes' predicted footprints, so replicas for
//!   traffic about to admit are resident (and paid for) before it lands.
//!
//! Everything above moves only the simulated clock: token streams and KV
//! contents stay byte-identical to non-EP runs (the PR 5 cost-only
//! discipline, pinned by `rust/tests/ep_serve.rs` and
//! `rust/tests/ep_migrate.rs`).

pub mod comm;
pub mod migrate;
pub mod placement;

pub use comm::{uniform_tokens, EpCostModel};
pub use migrate::{plan_migration, MigrationOp, MigrationPlan};
pub use placement::{Placement, PlacementKind};
