//! Expert-parallelism substrate (§5 of the paper): expert→GPU placement,
//! per-GPU load accounting, and the interconnect/straggler model that turns
//! MaxLoad into layer latency.

pub mod comm;
pub mod placement;

pub use comm::EpCostModel;
pub use placement::{Placement, PlacementKind};
