//! EP layer-latency model: straggler synchronization + all-to-all dispatch.
//!
//! Under expert parallelism every GPU must finish its experts before the
//! layer output can be combined, so layer time is governed by the busiest
//! GPU (MaxLoad), plus two all-to-alls (token dispatch + combine) whose cost
//! scales with tokens × hidden size over the interconnect bandwidth.

use super::placement::Placement;
use crate::selection::ExpertSet;

/// Cost parameters for one EP group (defaults ≈ H100 + NVLink4).
#[derive(Debug, Clone)]
pub struct EpCostModel {
    /// Seconds to stream one expert's weights from HBM (per GPU, sequential
    /// in the number of experts resident on that GPU).
    pub expert_load_s: f64,
    /// Seconds of compute per expert per token (tiny during decode).
    pub expert_compute_s: f64,
    /// Interconnect bandwidth for all-to-all, bytes/s.
    pub interconnect_bw: f64,
    /// Bytes per token per direction (hidden state in bf16 + routing meta).
    pub bytes_per_token: f64,
    /// Fixed per-layer synchronization overhead, seconds.
    pub sync_overhead_s: f64,
    /// Bytes of weights one expert replica occupies — what a placement
    /// migration copy moves over the interconnect.
    pub expert_bytes: f64,
}

impl Default for EpCostModel {
    fn default() -> Self {
        // DeepSeek-R1-like expert on H100: ~44 MB of bf16 weights per expert
        // at 3.35 TB/s HBM → ~13 µs; NVLink4 ~450 GB/s effective.
        EpCostModel {
            expert_load_s: 13e-6,
            expert_compute_s: 0.4e-6,
            interconnect_bw: 450e9,
            bytes_per_token: 7168.0 * 2.0,
            sync_overhead_s: 4e-6,
            expert_bytes: 44e6,
        }
    }
}

impl EpCostModel {
    /// Per-layer latency for a selected set under a placement: straggler
    /// GPU time + two all-to-alls.
    pub fn layer_latency(
        &self,
        placement: &Placement,
        selected: &ExpertSet,
        tokens_per_gpu: &[usize],
    ) -> f64 {
        let loads = placement.loads(selected);
        let straggler = loads
            .iter()
            .zip(tokens_per_gpu)
            .map(|(&l, &t)| {
                l as f64 * self.expert_load_s + (l * t) as f64 * self.expert_compute_s
            })
            .fold(0.0f64, f64::max);
        let total_tokens: usize = tokens_per_gpu.iter().sum();
        let a2a = 2.0 * total_tokens as f64 * self.bytes_per_token / self.interconnect_bw;
        straggler + a2a + self.sync_overhead_s
    }

    /// Interconnect time to move `copies` expert replicas between GPUs —
    /// the charge for one adopted [`crate::ep::MigrationPlan`]. The serve
    /// loop accumulates this into a backlog drained against subsequent step
    /// time, so migration overlaps decoding instead of stalling it.
    pub fn migration_seconds(&self, copies: usize) -> f64 {
        copies as f64 * self.expert_bytes / self.interconnect_bw
    }
}

/// Even token spread helper (the decode scheduler dispatches each token's
/// chosen experts; for latency accounting we spread tokens uniformly, the
/// paper does the same for its Max/GPU metric). A free function — it reads
/// no cost-model state.
pub fn uniform_tokens(n_tokens: usize, n_gpus: usize) -> Vec<usize> {
    let base = n_tokens / n_gpus;
    let extra = n_tokens % n_gpus;
    (0..n_gpus).map(|g| base + usize::from(g < extra)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ep::PlacementKind;

    #[test]
    fn latency_tracks_max_load() {
        let model = EpCostModel::default();
        let p = Placement::new(16, 4, PlacementKind::Contiguous);
        let toks = uniform_tokens(8, 4);
        let balanced = ExpertSet::from_indices(16, &[0, 4, 8, 12]);
        let skewed = ExpertSet::from_indices(16, &[0, 1, 2, 3]);
        let t_bal = model.layer_latency(&p, &balanced, &toks);
        let t_skew = model.layer_latency(&p, &skewed, &toks);
        assert!(t_skew > t_bal, "skewed {t_skew} <= balanced {t_bal}");
    }

    #[test]
    fn empty_selection_costs_only_overheads() {
        let model = EpCostModel::default();
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let toks = uniform_tokens(4, 2);
        let t = model.layer_latency(&p, &ExpertSet::empty(8), &toks);
        let a2a = 2.0 * 4.0 * model.bytes_per_token / model.interconnect_bw;
        assert!((t - (a2a + model.sync_overhead_s)).abs() < 1e-12);
    }

    #[test]
    fn uniform_tokens_sums() {
        let v = uniform_tokens(10, 3);
        assert_eq!(v.iter().sum::<usize>(), 10);
        assert_eq!(v, vec![4, 3, 3]);
    }

    #[test]
    fn migration_charge_is_linear_in_copies() {
        let model = EpCostModel::default();
        assert_eq!(model.migration_seconds(0), 0.0);
        let one = model.migration_seconds(1);
        assert!((one - model.expert_bytes / model.interconnect_bw).abs() < 1e-18);
        assert!((model.migration_seconds(3) - 3.0 * one).abs() < 1e-15);
        // ~44 MB over ~450 GB/s ≈ 98 µs — the same order as one EP decode
        // step, which is why the charge drains over several steps.
        assert!(one > 5e-5 && one < 5e-4, "{one}");
    }

    #[test]
    fn latency_is_monotone_in_max_load() {
        // Piling one more expert onto the straggler GPU must never make the
        // layer faster: latency is non-decreasing as MaxLoad grows 1..=N/G,
        // and strictly increasing whenever the straggler gains an expert.
        let model = EpCostModel::default();
        let p = Placement::new(16, 4, PlacementKind::Contiguous);
        let toks = uniform_tokens(8, 4);
        let mut prev = 0.0f64;
        for load in 1..=4usize {
            // GPU 0 hosts experts 0..4 under the contiguous split: select
            // `load` of them so MaxLoad == load exactly.
            let sel = ExpertSet::from_indices(16, &(0..load).collect::<Vec<_>>());
            assert_eq!(p.max_load(&sel), load);
            let t = model.layer_latency(&p, &sel, &toks);
            assert!(
                t > prev,
                "MaxLoad {load}: latency {t} did not grow past {prev}"
            );
            prev = t;
        }
    }

    #[test]
    fn equal_max_load_means_equal_straggler_time() {
        // The straggler term depends only on the busiest GPU (with uniform
        // tokens): 4 experts on one GPU costs the same whether the other
        // GPUs serve 0 or 3 experts each — that is the synchronization
        // pathology the paper's §5 balances against.
        let model = EpCostModel::default();
        let p = Placement::new(16, 4, PlacementKind::Contiguous);
        let toks = uniform_tokens(8, 4);
        let lone = ExpertSet::from_indices(16, &[0, 1, 2, 3]);
        let spread = ExpertSet::from_indices(16, &[0, 1, 2, 3, 4, 5, 6, 8, 9, 10, 12, 13, 14]);
        assert_eq!(p.max_load(&lone), 4);
        assert_eq!(p.max_load(&spread), 4);
        let t_lone = model.layer_latency(&p, &lone, &toks);
        let t_spread = model.layer_latency(&p, &spread, &toks);
        assert!((t_lone - t_spread).abs() < 1e-12, "{t_lone} vs {t_spread}");
    }

    #[test]
    fn all_to_all_scales_linearly_with_tokens() {
        // With an empty selection the straggler term vanishes, so doubling
        // the token count must exactly double the (latency − sync) part —
        // the two all-to-alls are bandwidth-bound in tokens × bytes.
        let model = EpCostModel::default();
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let empty = ExpertSet::empty(8);
        let at = |n: usize| {
            model.layer_latency(&p, &empty, &uniform_tokens(n, 2))
                - model.sync_overhead_s
        };
        let t4 = at(4);
        let t8 = at(8);
        let t16 = at(16);
        assert!((t8 - 2.0 * t4).abs() < 1e-15, "{t8} != 2×{t4}");
        assert!((t16 - 4.0 * t4).abs() < 1e-15, "{t16} != 4×{t4}");
        // and the rate matches the configured interconnect exactly
        let expect = 2.0 * 4.0 * model.bytes_per_token / model.interconnect_bw;
        assert!((t4 - expect).abs() < 1e-18);
    }
}
