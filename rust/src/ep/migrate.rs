//! Incremental, interconnect-charged placement migration (PR 6).
//!
//! The legacy `--ep-rebalance` lever swapped the whole expert → GPU
//! assignment in one free instant ([`Placement::rebalance_from`]). Real
//! deployments move expert weights over the interconnect, a few at a time,
//! while serving continues — the replication design of arxiv 2605.11537.
//! [`plan_migration`] is the bounded analogue: starting from the live
//! placement it greedily picks at most `budget` operations per step —
//! **copies** (add a replica of a hot-adjacent expert on an under-cap GPU)
//! and **free drops** (remove a replica that currently receives no routed
//! traffic from an at-cap GPU, provably load-invariant, to open the slot a
//! copy needs) — each adopted only when the expected MaxLoad under the
//! tracked weights strictly improves.
//!
//! Charging contract (enforced by the serve loop, see
//! [`crate::coordinator::serve_loop`]): a plan's weight movement is
//! `copies × EpCostModel::expert_bytes` over `EpCostModel::interconnect_bw`
//! ([`crate::ep::comm::EpCostModel::migration_seconds`]), accumulated into a
//! backlog that drains against subsequent step time (the transfer overlaps
//! decoding; a step at most doubles). Drops are bookkeeping-only — no bytes
//! move. The plan itself never touches tokens or KV: it is cost-only by the
//! PR 5 discipline.
//!
//! Determinism: candidate scans run in ascending (expert, GPU) order and a
//! later candidate replaces an earlier one only on strict (1e-9) improvement,
//! so equal-quality ties keep the lowest indices and the planner is a pure
//! function of `(placement, weights, budget, cap)`.

use super::placement::Placement;

/// One physical placement-change operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationOp {
    /// Copy the expert's weights onto `to` (charged: one expert of bytes).
    Copy { expert: usize, to: usize },
    /// Drop the replica resident on `from` (free: nothing moves).
    Drop { expert: usize, from: usize },
}

/// A bounded placement-migration step: the op list, the resulting
/// placement, and the expected-MaxLoad movement that justified it.
#[derive(Debug, Clone)]
pub struct MigrationPlan {
    /// Operations in application order, `len() ≤ budget`.
    pub ops: Vec<MigrationOp>,
    /// Number of `Copy` ops — the unit the interconnect charge scales with.
    pub copies: usize,
    /// Expected MaxLoad of the starting placement under the weights.
    pub expected_before: f64,
    /// Expected MaxLoad after applying every op (strictly below
    /// `expected_before`).
    pub expected_after: f64,
    /// The placement with all ops applied.
    pub placement: Placement,
}

/// Best single replica copy strictly improving on `cur`: scans experts with
/// positive weight (ascending) × under-cap non-hosting GPUs (ascending) and
/// keeps the strictly best `(expert, to, expected_after)`.
fn best_copy(
    pl: &Placement,
    weights: &[f32],
    cap: usize,
    cur: f64,
) -> Option<(usize, usize, f64)> {
    let mut best: Option<(usize, usize, f64)> = None;
    for (j, &w) in weights.iter().enumerate() {
        if w <= 0.0 {
            continue; // a zero-traffic replica can never attract load away
        }
        for t in 0..pl.n_gpus() {
            if pl.residency(t) >= cap || pl.hosts(t, j) {
                continue;
            }
            let mut trial = pl.clone();
            trial.add_replica(j, t);
            let after = trial.expected_max_load(weights);
            let bar = best.map_or(cur, |(_, _, b)| b);
            if after < bar - 1e-9 {
                best = Some((j, t, after));
            }
        }
    }
    best
}

/// Plan one bounded migration step from `current` under the tracked
/// per-expert `weights`: at most `budget` ops, every GPU's residency kept
/// ≤ `cap` ([`Placement::residency_cap`]). Returns `None` when no plan
/// strictly improves expected MaxLoad — including `budget == 0`, a cap-full
/// topology with nothing droppable, or weights already balanced. The caller
/// decides adoption by weighing `expected_before − expected_after` against
/// the interconnect charge for `copies`.
pub fn plan_migration(
    current: &Placement,
    weights: &[f32],
    budget: usize,
    cap: usize,
) -> Option<MigrationPlan> {
    assert_eq!(weights.len(), current.n_experts(), "weights must cover every expert");
    if budget == 0 {
        return None;
    }
    let expected_before = current.expected_max_load(weights);
    let mut pl = current.clone();
    let mut cur = expected_before;
    let mut ops: Vec<MigrationOp> = Vec::new();
    let mut copies = 0usize;
    while ops.len() < budget {
        if let Some((j, t, after)) = best_copy(&pl, weights, cap, cur) {
            pl.add_replica(j, t);
            ops.push(MigrationOp::Copy { expert: j, to: t });
            copies += 1;
            cur = after;
            continue;
        }
        // No direct copy improves. If the budget still has room for a
        // drop + the copy it unblocks, try freeing a slot on an at-cap GPU
        // by dropping a replica that receives no routed traffic (removing a
        // never-chosen option leaves the greedy routing walk bit-identical,
        // so the drop itself is load-invariant).
        let mut advanced = false;
        if ops.len() + 2 <= budget {
            let routed = pl.route_weights(weights).1;
            'drops: for g in 0..pl.n_gpus() {
                if pl.residency(g) < cap {
                    continue;
                }
                let droppable: Vec<usize> = pl
                    .experts_on(g)
                    .iter()
                    .copied()
                    .filter(|&j| {
                        pl.n_replicas(j) > 1 && (weights[j] == 0.0 || routed[j] != g)
                    })
                    .collect();
                for j in droppable {
                    let mut trial = pl.clone();
                    trial.drop_replica(j, g);
                    if let Some((cj, ct, after)) = best_copy(&trial, weights, cap, cur)
                    {
                        trial.add_replica(cj, ct);
                        ops.push(MigrationOp::Drop { expert: j, from: g });
                        ops.push(MigrationOp::Copy { expert: cj, to: ct });
                        copies += 1;
                        cur = after;
                        pl = trial;
                        advanced = true;
                        break 'drops;
                    }
                }
            }
        }
        if !advanced {
            break;
        }
    }
    if ops.is_empty() || cur >= expected_before - 1e-9 {
        return None;
    }
    Some(MigrationPlan {
        ops,
        copies,
        expected_before,
        expected_after: cur,
        placement: pl,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ep::placement::PlacementKind;

    #[test]
    fn replicates_second_hottest_expert_off_the_hot_gpu() {
        // Contiguous 8-on-2: GPU0 = {0..3} carries 0.6 + 0.5 + 0.02, GPU1
        // only 0.04. Expert 0 routes first (ascending walk, all loads zero,
        // tie → lowest GPU) so replicating IT can't move anything; the
        // payoff copy is expert 1 → GPU1, which dodges expert 0's 0.6.
        let pl = Placement::new(8, 2, PlacementKind::Contiguous);
        let mut w = vec![0.01f32; 8];
        w[0] = 0.6;
        w[1] = 0.5;
        let plan = plan_migration(&pl, &w, 1, 8).expect("skew must yield a plan");
        assert_eq!(plan.ops, vec![MigrationOp::Copy { expert: 1, to: 1 }]);
        assert_eq!(plan.copies, 1);
        assert!(plan.expected_after < plan.expected_before - 1e-9);
        assert!((plan.expected_before - 1.12).abs() < 1e-6);
        assert!((plan.expected_after - 0.62).abs() < 1e-6);
        assert_eq!(plan.placement.replicas(1), &[0, 1]);
        assert!(!plan.placement.is_partition());
    }

    #[test]
    fn respects_the_op_budget() {
        let pl = Placement::new(16, 4, PlacementKind::Contiguous);
        let mut w = vec![0.05f32; 16];
        for j in 0..4 {
            w[j] = 1.0; // pile the hot experts onto GPU 0
        }
        for budget in 1..=4usize {
            if let Some(plan) = plan_migration(&pl, &w, budget, 16) {
                assert!(plan.ops.len() <= budget, "budget {budget}: {:?}", plan.ops);
                assert!(plan.copies <= budget);
                assert!(plan.expected_after < plan.expected_before - 1e-9);
            }
        }
        // a generous budget does find work on this skew
        let plan = plan_migration(&pl, &w, 3, 16).expect("skew must yield a plan");
        assert!(!plan.ops.is_empty() && plan.ops.len() <= 3);
    }

    #[test]
    fn is_deterministic() {
        let pl = Placement::new(12, 3, PlacementKind::RoundRobin);
        let w: Vec<f32> = (0..12).map(|j| ((j * 7 + 1) % 5) as f32 * 0.2).collect();
        let a = plan_migration(&pl, &w, 2, 8);
        let b = plan_migration(&pl, &w, 2, 8);
        match (a, b) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.ops, y.ops);
                assert_eq!(x.expected_after.to_bits(), y.expected_after.to_bits());
            }
            _ => panic!("planner must be a pure function of its inputs"),
        }
    }

    #[test]
    fn cap_full_partition_yields_no_plan() {
        // Even 8-on-2 split at slack 1.0: both GPUs sit exactly at cap 4 and
        // the partition has no multi-replica expert to drop → nothing fits.
        let pl = Placement::new(8, 2, PlacementKind::Contiguous);
        let mut w = vec![0.01f32; 8];
        w[0] = 1.0;
        let cap = Placement::residency_cap(8, 2, 1.0);
        assert_eq!(cap, 4);
        assert!(plan_migration(&pl, &w, 4, cap).is_none());
    }

    #[test]
    fn balanced_weights_yield_no_plan() {
        let pl = Placement::new(8, 2, PlacementKind::Contiguous);
        assert!(plan_migration(&pl, &[0.25f32; 8], 4, 8).is_none());
        // and a zero budget never plans, whatever the skew
        let mut w = vec![0.01f32; 8];
        w[0] = 1.0;
        assert!(plan_migration(&pl, &w, 0, 8).is_none());
    }

    #[test]
    fn free_drop_unblocks_a_copy_on_an_at_cap_gpu() {
        // GPU0 = {0, 1}, GPU1 = {1, 2, 3}; expert 1 is replicated but gets
        // no traffic. At cap 2 no GPU can take a copy directly, yet
        // dropping expert 1's idle GPU0 replica (routes to GPU1 — removing
        // a never-chosen option is load-invariant) opens the slot for the
        // winning copy: expert 3 → GPU0 (0.7 → 0.6 expected MaxLoad).
        let pl =
            Placement::from_replicas(2, vec![vec![0], vec![0, 1], vec![1], vec![1]]);
        let w = [0.5f32, 0.0, 0.6, 0.1];
        assert!((pl.expected_max_load(&w) - 0.7).abs() < 1e-6);
        let plan = plan_migration(&pl, &w, 2, 2).expect("drop+copy must plan");
        assert_eq!(
            plan.ops,
            vec![
                MigrationOp::Drop { expert: 1, from: 0 },
                MigrationOp::Copy { expert: 3, to: 0 },
            ]
        );
        assert_eq!(plan.copies, 1, "only the copy moves bytes");
        assert!((plan.expected_after - 0.6).abs() < 1e-6);
        assert_eq!(plan.placement.replicas(1), &[1]);
        assert_eq!(plan.placement.replicas(3), &[0, 1]);
        assert!(plan.placement.residency(0) <= 2 && plan.placement.residency(1) <= 2);
        // with budget 1 the pair does not fit → no plan at all
        assert!(plan_migration(&pl, &w, 1, 2).is_none());
    }
}
