//! Request lifecycle types: what enters the queue, how a running sequence
//! tracks its prompt/decode progress inside a batch slot.

use super::speculative::NgramIndex;

/// An inference request as submitted by a client or a trace.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub domain: String,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Admission priority class (higher = admitted sooner under the
    /// `priority` policy; 0 = default best-effort class).
    pub priority: u32,
    /// TTFT deadline in milliseconds from submission, for `edf` admission
    /// and deadline-miss accounting. `None` = no SLO.
    pub deadline_ms: Option<u64>,
    /// Opt-in streaming (wire: `"stream": true`): the server emits a delta
    /// frame per step that commits tokens for this request, then the usual
    /// final reply. Non-streaming requests are byte-unchanged on the wire.
    pub stream: bool,
    /// Tokens this request generated before being preempted by slot
    /// eviction (`coordinator::eviction`). On eviction they are appended
    /// to `prompt` (the KV rebuild re-prefills them) AND recorded here so
    /// the finished output is the request's complete generation. Always
    /// empty for fresh submissions — never a wire field.
    pub resume_prefix: Vec<u32>,
    /// Times this request has been evicted (bounded by
    /// `coordinator::eviction::EVICTION_BUDGET`).
    pub evictions: u32,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            domain: String::new(),
            prompt,
            max_new_tokens,
            priority: 0,
            deadline_ms: None,
            stream: false,
            resume_prefix: Vec::new(),
            evictions: 0,
        }
    }

    /// The prompt as originally submitted, excluding any generated tokens
    /// re-fed after an eviction. Traffic-class keys hash this slice so a
    /// resumed request stays in the class it started in.
    pub fn original_prompt(&self) -> &[u32] {
        &self.prompt[..self.prompt.len() - self.resume_prefix.len()]
    }

    /// The traffic-class key this request aggregates under — THE class
    /// identity shared by footprint admission
    /// (`admission::FootprintTracker`), per-class speculation EMAs, TTFT
    /// breakdowns, and the fleet router's affinity assignment. A labeled
    /// request is its domain tag; an unlabeled one hashes the ORIGINAL
    /// prompt (templated/duplicate traffic shares a class, and an evicted
    /// request re-feeding generated tokens as prompt stays in the class it
    /// started in).
    pub fn class_key(&self) -> String {
        if !self.domain.is_empty() {
            return self.domain.clone();
        }
        let mut h = crate::util::fnv::Fnv::new();
        for &t in self.original_prompt() {
            h.update_u32(t);
        }
        format!("prompt:{:016x}", h.finish())
    }
}

/// Phase of a sequence occupying a slot — the per-row state machine the
/// phase-partitioned executor drives:
///
/// ```text
///   PrefillChunk ──prompt exhausted──▶ Decode ◀──────────────┐
///                                        │ begin_spec(depth) │ end-of-cycle
///                                        ▼                   │
///                                  SpecVerify { depth } ──────┘
/// ```
///
/// `PrefillChunk` covers both the one-token-per-step walk and multi-token
/// chunk advances (the chunk size is an execution detail, not a phase).
/// `SpecVerify` is entered for the duration of one speculative verify
/// cycle at a **per-row** depth — rows at depth 0 ride the verify forward
/// as plain one-token decodes, which is what lets a mixed-phase batch
/// speculate at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Feeding prompt tokens (one token or one chunk per step).
    PrefillChunk,
    /// Generating new tokens, one per step.
    Decode,
    /// Mid speculative verify cycle with `depth` drafted tokens in flight
    /// for this row (0 = riding the verify forward without drafts).
    SpecVerify { depth: usize },
}

impl Phase {
    /// Whether the row is still consuming its prompt.
    pub fn is_prefill(&self) -> bool {
        matches!(self, Phase::PrefillChunk)
    }
}

/// A sequence bound to a batch slot.
#[derive(Debug, Clone)]
pub struct SeqState {
    pub req: Request,
    /// Next KV position to write (= tokens processed so far).
    pub pos: usize,
    /// Next prompt index to feed (prefill).
    pub prompt_idx: usize,
    /// Tokens generated so far.
    pub generated: Vec<u32>,
    /// Token to feed at the next step.
    pub next_token: u32,
    pub phase: Phase,
    /// Rolling n-gram index over the committed history (consumed prompt +
    /// generated), updated on every prefill advance and commit — the
    /// lookup drafter queries it in O(log n) instead of rescanning the
    /// history each verify cycle. The serve loop disables it at admission
    /// unless lookup drafting is configured, so non-drafting deployments
    /// pay nothing on the commit path.
    pub ngram: NgramIndex,
}

impl SeqState {
    pub fn new(req: Request) -> SeqState {
        assert!(!req.prompt.is_empty(), "empty prompt");
        let first = req.prompt[0];
        SeqState {
            req,
            pos: 0,
            prompt_idx: 0,
            generated: Vec::new(),
            next_token: first,
            phase: Phase::PrefillChunk,
            ngram: NgramIndex::default(),
        }
    }

    pub fn is_done(&self) -> bool {
        !self.phase.is_prefill() && self.generated.len() >= self.req.max_new_tokens
    }

    /// The request's complete generation: tokens committed before any
    /// eviction plus the tokens of the current stint. What finished
    /// sequences report.
    pub fn full_output(&self) -> Vec<u32> {
        let mut out = self.req.resume_prefix.clone();
        out.extend_from_slice(&self.generated);
        out
    }

    /// Enter a speculative verify cycle at the given per-row depth. Only a
    /// decoding row can speculate; the executor calls [`SeqState::end_spec`]
    /// after the cycle's commits.
    pub fn begin_spec(&mut self, depth: usize) {
        debug_assert_eq!(self.phase, Phase::Decode, "only decode rows speculate");
        self.phase = Phase::SpecVerify { depth };
    }

    /// Leave the verify cycle (back to plain decode).
    pub fn end_spec(&mut self) {
        debug_assert!(matches!(self.phase, Phase::SpecVerify { .. }));
        self.phase = Phase::Decode;
    }

    /// Depth of the in-flight verify cycle, if the row is in one.
    pub fn spec_depth(&self) -> Option<usize> {
        match self.phase {
            Phase::SpecVerify { depth } => Some(depth),
            _ => None,
        }
    }

    /// Remaining budget of new tokens.
    pub fn remaining(&self) -> usize {
        self.req.max_new_tokens.saturating_sub(self.generated.len())
    }

    /// Commit one generated token (decode or spec-verify phase).
    pub fn commit(&mut self, tok: u32) {
        debug_assert!(!self.phase.is_prefill(), "commit during prefill");
        self.generated.push(tok);
        self.next_token = tok;
        self.pos += 1;
        self.ngram.push(tok);
    }

    /// Advance after a prefill step; returns true if the prompt is finished
    /// and the given first generated token was committed.
    pub fn advance_prefill(&mut self, logits_argmax: u32) -> bool {
        self.advance_prefill_by(1, logits_argmax)
    }

    /// Advance after consuming `n` prompt tokens (a chunk). `logits_argmax`
    /// is the model's prediction at the chunk's last position; it is
    /// committed as the first generated token iff the chunk exhausts the
    /// prompt — identical to `n` one-token advances where only the final
    /// step's logits matter. Returns true when that first token committed.
    pub fn advance_prefill_by(&mut self, n: usize, logits_argmax: u32) -> bool {
        debug_assert_eq!(self.phase, Phase::PrefillChunk);
        assert!(
            n >= 1 && self.prompt_idx + n <= self.req.prompt.len(),
            "chunk of {n} overruns prompt ({} of {} consumed)",
            self.prompt_idx,
            self.req.prompt.len()
        );
        for &t in &self.req.prompt[self.prompt_idx..self.prompt_idx + n] {
            self.ngram.push(t);
        }
        self.pos += n;
        self.prompt_idx += n;
        if self.prompt_idx < self.req.prompt.len() {
            self.next_token = self.req.prompt[self.prompt_idx];
            false
        } else {
            // prompt exhausted: the last position's logits predict the
            // first output
            self.phase = Phase::Decode;
            self.generated.push(logits_argmax);
            self.next_token = logits_argmax;
            self.ngram.push(logits_argmax);
            true
        }
    }

    /// Prompt tokens not yet fed.
    pub fn prompt_remaining(&self) -> usize {
        self.req.prompt.len() - self.prompt_idx
    }

    /// Skip the first `n` prompt positions whose KV was restored from the
    /// prefix cache: identical committed-history state (pos, prompt_idx,
    /// next_token, n-gram index) to feeding them through the model, with
    /// no forwards. Only legal on a freshly placed row, and a suffix must
    /// remain — the first generated token needs real last-position logits
    /// (the cache-restore KV contract in `model/moe_model.rs`).
    pub fn restore_prefix_state(&mut self, n: usize) {
        debug_assert_eq!(self.phase, Phase::PrefillChunk);
        assert!(
            self.pos == 0 && self.prompt_idx == 0,
            "prefix restore into a row that already advanced"
        );
        assert!(
            n >= 1 && n < self.req.prompt.len(),
            "restore of {n} must leave a prompt suffix to feed ({} tokens)",
            self.req.prompt.len()
        );
        for &t in &self.req.prompt[..n] {
            self.ngram.push(t);
        }
        self.pos = n;
        self.prompt_idx = n;
        self.next_token = self.req.prompt[n];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_walks_prompt_then_decodes() {
        let req = Request::new(1, vec![10, 11, 12], 2);
        let mut s = SeqState::new(req);
        assert_eq!(s.phase, Phase::PrefillChunk);
        assert_eq!(s.next_token, 10);
        assert!(!s.advance_prefill(99));
        assert_eq!(s.next_token, 11);
        assert!(!s.advance_prefill(99));
        assert_eq!(s.next_token, 12);
        assert!(s.advance_prefill(42)); // prompt done, first token committed
        assert_eq!(s.phase, Phase::Decode);
        assert_eq!(s.generated, vec![42]);
        assert_eq!(s.pos, 3);
        assert!(!s.is_done());
        s.commit(7);
        assert!(s.is_done());
        assert_eq!(s.generated, vec![42, 7]);
        assert_eq!(s.pos, 4);
    }

    #[test]
    fn chunked_advance_matches_stepwise() {
        // A chunk of n must leave the same state as n one-token advances.
        let req = Request::new(1, vec![10, 11, 12, 13, 14], 2);
        let mut a = SeqState::new(req.clone());
        let mut b = SeqState::new(req);
        assert!(!a.advance_prefill_by(3, 99));
        for _ in 0..3 {
            b.advance_prefill(99);
        }
        assert_eq!((a.pos, a.prompt_idx, a.next_token), (b.pos, b.prompt_idx, b.next_token));
        assert_eq!(a.phase, Phase::PrefillChunk);
        assert_eq!(a.prompt_remaining(), 2);
        // final chunk commits the predicted token
        assert!(a.advance_prefill_by(2, 42));
        assert_eq!(a.phase, Phase::Decode);
        assert_eq!(a.generated, vec![42]);
        assert_eq!(a.pos, 5);
    }

    #[test]
    #[should_panic(expected = "overruns prompt")]
    fn chunked_advance_rejects_overrun() {
        let mut s = SeqState::new(Request::new(1, vec![1, 2], 1));
        s.advance_prefill_by(3, 0);
    }

    #[test]
    fn spec_phase_roundtrip() {
        let mut s = SeqState::new(Request::new(1, vec![1], 3));
        assert!(s.advance_prefill(5));
        assert_eq!(s.spec_depth(), None);
        s.begin_spec(2);
        assert_eq!(s.phase, Phase::SpecVerify { depth: 2 });
        assert_eq!(s.spec_depth(), Some(2));
        assert!(!s.phase.is_prefill());
        // commits are legal mid-verify; budget exhaustion is observable
        // before end_spec (the executor releases the slot from SpecVerify)
        s.commit(7);
        s.commit(8);
        assert!(s.is_done());
        s.end_spec();
        assert_eq!(s.phase, Phase::Decode);
    }

    #[test]
    fn remaining_budget() {
        let mut s = SeqState::new(Request::new(1, vec![1], 3));
        assert!(s.advance_prefill(5));
        assert_eq!(s.remaining(), 2);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn rejects_empty_prompt() {
        SeqState::new(Request::new(1, vec![], 1));
    }

    #[test]
    fn ngram_index_tracks_committed_history() {
        // The index must always cover consumed prompt + generated — the
        // lookup drafter's history — for both chunked and one-token
        // prefill and for decode commits.
        let req = Request::new(1, vec![10, 11, 12, 13], 3);
        let mut s = SeqState::new(req.clone());
        assert!(s.ngram.is_empty());
        s.advance_prefill(0);
        assert_eq!(s.ngram.history(), &[10]);
        s.advance_prefill_by(3, 42); // finishes the prompt, commits 42
        assert_eq!(s.ngram.history(), &[10, 11, 12, 13, 42]);
        s.commit(7);
        assert_eq!(s.ngram.history(), &[10, 11, 12, 13, 42, 7]);
        assert_eq!(*s.ngram.history().last().unwrap(), s.next_token);
        // chunked and stepwise walks build the identical index
        let mut w = SeqState::new(req);
        for _ in 0..3 {
            w.advance_prefill(0);
        }
        w.advance_prefill(42);
        w.commit(7);
        assert_eq!(w.ngram.history(), s.ngram.history());
    }

    #[test]
    fn restore_prefix_state_matches_prefill_walk() {
        // Skipping n restored positions must leave the identical row state
        // (pos, prompt_idx, next_token, n-gram history) as advancing over
        // them through the model.
        let req = Request::new(1, vec![10, 11, 12, 13, 14], 2);
        let mut r = SeqState::new(req.clone());
        r.restore_prefix_state(3);
        let mut w = SeqState::new(req);
        w.advance_prefill_by(3, 99);
        assert_eq!((r.pos, r.prompt_idx, r.next_token), (w.pos, w.prompt_idx, w.next_token));
        assert_eq!(r.ngram.history(), w.ngram.history());
        assert_eq!(r.phase, Phase::PrefillChunk);
        assert_eq!(r.prompt_remaining(), 2);
        // and the suffix prefill continues exactly as the cold walk would
        assert!(r.advance_prefill_by(2, 42));
        assert_eq!(r.generated, vec![42]);
        assert_eq!(r.pos, 5);
    }

    #[test]
    #[should_panic(expected = "leave a prompt suffix")]
    fn restore_prefix_state_rejects_whole_prompt() {
        let mut s = SeqState::new(Request::new(1, vec![1, 2, 3], 1));
        s.restore_prefix_state(3);
    }

    #[test]
    fn class_key_reference_vectors() {
        // Pinned FNV-1a reference vectors (computed independently of
        // `util::fnv`): the fleet router and footprint admission both key
        // on exactly these strings, so the derivation must never drift.
        let tpl_a = Request::new(1, vec![70, 75, 80, 72, 78, 74], 4);
        assert_eq!(tpl_a.class_key(), "prompt:806942a48f164ce4");
        let tpl_b = Request::new(2, vec![430, 436, 440, 433, 428, 438], 4);
        assert_eq!(tpl_b.class_key(), "prompt:b0997d7b9e8edea4");
        let small = Request::new(3, vec![1, 2, 3], 4);
        assert_eq!(small.class_key(), "prompt:fd1f0f4381eb0395");

        // A domain label overrides the prompt hash …
        let mut labeled = Request::new(4, vec![1, 2, 3], 4);
        labeled.domain = "gpqa".into();
        assert_eq!(labeled.class_key(), "gpqa");

        // … and resume re-feeds keep the original class: the key hashes
        // only the original prompt slice.
        let mut resumed = Request::new(5, vec![1, 2, 3], 4);
        resumed.prompt.extend_from_slice(&[9, 8]);
        resumed.resume_prefix = vec![9, 8];
        assert_eq!(resumed.class_key(), "prompt:fd1f0f4381eb0395");
    }

    #[test]
    fn full_output_stitches_resume_prefix() {
        let mut req = Request::new(1, vec![1, 2, 3], 2);
        assert_eq!(req.original_prompt(), &[1, 2, 3]);
        req.prompt.extend_from_slice(&[9, 8]);
        req.resume_prefix = vec![9, 8];
        assert_eq!(req.original_prompt(), &[1, 2, 3]);
        let mut s = SeqState::new(req);
        for _ in 0..4 {
            s.advance_prefill(0);
        }
        s.advance_prefill(5); // prompt done, first post-resume token
        s.commit(6);
        assert_eq!(s.full_output(), vec![9, 8, 5, 6]);
        assert!(s.is_done());
    }
}
