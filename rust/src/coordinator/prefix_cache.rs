//! VRAM-budgeted shared-prefix KV cache (PR 7).
//!
//! Production MoE traffic is dominated by shared system prompts, few-shot
//! templates and multi-turn chat that replays the whole history every
//! turn — redundant prefill work. This cache stores the KV slab of a
//! finished (or preempted) row's processed prefix, keyed by an FNV-1a hash
//! of the token prefix itself; a later request whose prompt extends a
//! cached prefix restores the slab into its slot
//! ([`crate::model::MoeModel::restore_prefix`]) and chunk-prefills only
//! the suffix. Eviction resume rides the same path: the preempted row's
//! committed history is offered here at preemption, so re-admission
//! restores instead of re-prefilling from scratch.
//!
//! Correctness leans entirely on the cache-restore KV contract in
//! `model/moe_model.rs`: KV bytes at a position depend only on the token
//! stream at and below it, so a slab is valid for ANY row whose prompt
//! starts with the entry's exact token sequence. Entries are matched on
//! the full token prefix (the hash is an index, the token comparison is
//! the authority), and a hit always leaves at least one prompt token to
//! feed — the first generated token needs real last-position logits.
//!
//! Budgeting is bytes-denominated LRU: inserts evict least-recently-touched
//! entries until the new slab fits; slabs larger than the whole budget are
//! refused outright. Lookups hand out a **clone** of the slab, so an entry
//! evicted while a hit is mid-restore cannot corrupt the restore (pinned
//! in `rust/tests/prefix_cache.rs`).

use std::collections::HashMap;

use crate::model::KvPrefix;
use crate::util::fnv::Fnv;

/// Order-stable FNV-1a hash of a token prefix (the cache key, and the
/// same `util::fnv` the footprint tracker keys unlabeled classes with).
pub fn prefix_hash(tokens: &[u32]) -> u64 {
    let mut h = Fnv::new();
    for &t in tokens {
        h.update_u32(t);
    }
    h.finish()
}

/// Lifetime counters (mirrored into `ServeMetrics` by the serve loop).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Lookups that matched a cached prefix.
    pub hits: u64,
    /// Lookups that matched nothing.
    pub misses: u64,
    /// Slabs admitted into the cache.
    pub inserts: u64,
    /// Slabs LRU-evicted to make room.
    pub evictions: u64,
}

/// One resident slab: the exact token prefix it covers, its KV bytes, and
/// per-entry accounting.
struct Entry {
    tokens: Vec<u32>,
    kv: KvPrefix,
    bytes: usize,
    hits: u64,
    /// LRU clock value at the last insert/hit touch.
    last_touch: u64,
}

/// The cache. `budget_bytes == 0` disables it entirely (every probe is 0,
/// every lookup a no-stat miss, every insert refused) — the serve loop
/// checks [`PrefixCache::enabled`] once and skips the wiring.
pub struct PrefixCache {
    entries: HashMap<u64, Entry>,
    budget_bytes: usize,
    min_tokens: usize,
    bytes_used: usize,
    cached_tokens: usize,
    clock: u64,
    pub stats: PrefixCacheStats,
}

impl PrefixCache {
    /// `budget_bytes`: total resident-slab budget (0 = disabled).
    /// `min_tokens`: shortest prefix worth caching — tiny slabs churn the
    /// LRU for restores that save almost nothing.
    pub fn new(budget_bytes: usize, min_tokens: usize) -> PrefixCache {
        PrefixCache {
            entries: HashMap::new(),
            budget_bytes,
            min_tokens: min_tokens.max(1),
            bytes_used: 0,
            cached_tokens: 0,
            clock: 0,
            stats: PrefixCacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    pub fn min_tokens(&self) -> usize {
        self.min_tokens
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn bytes_used(&self) -> usize {
        self.bytes_used
    }

    /// Token positions resident across all entries (the metrics gauge).
    pub fn cached_tokens(&self) -> usize {
        self.cached_tokens
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Length of the longest cached prefix of `prompt` that a restore
    /// could use, or 0. Read-only — no stats, no LRU touch — so admission
    /// scoring can probe every queued candidate without skewing hit
    /// accounting. A usable prefix must leave at least one prompt token to
    /// feed (see module docs), hence the strict `< prompt.len()` bound.
    pub fn probe(&self, prompt: &[u32]) -> usize {
        let mut best = 0usize;
        for e in self.entries.values() {
            if e.tokens.len() > best
                && e.tokens.len() < prompt.len()
                && prompt[..e.tokens.len()] == e.tokens[..]
            {
                best = e.tokens.len();
            }
        }
        best
    }

    /// Longest-prefix lookup for an admission: on a hit, bump the entry's
    /// LRU/hit accounting and return a CLONE of its slab (decoupled from
    /// later evictions); on a miss, count the miss. Disabled caches count
    /// nothing.
    pub fn lookup(&mut self, prompt: &[u32]) -> Option<KvPrefix> {
        if !self.enabled() {
            return None;
        }
        let best = self.probe(prompt);
        if best == 0 {
            self.stats.misses += 1;
            return None;
        }
        let hash = prefix_hash(&prompt[..best]);
        let e = self.entries.get_mut(&hash).expect("probe matched a resident entry");
        self.clock += 1;
        e.last_touch = self.clock;
        e.hits += 1;
        self.stats.hits += 1;
        Some(e.kv.clone())
    }

    /// Offer a slab for the exact token prefix `tokens`. Refused (false)
    /// when the cache is disabled, the prefix is below `min_tokens`, the
    /// slab alone exceeds the whole budget, or an entry for these tokens
    /// is already resident (byte-identical by the KV contract — the
    /// resident copy just gets an LRU touch). Otherwise LRU entries are
    /// evicted until the slab fits, and it is inserted (true).
    pub fn insert(&mut self, tokens: &[u32], kv: KvPrefix) -> bool {
        debug_assert_eq!(tokens.len(), kv.len, "slab length mismatch");
        if !self.enabled() || tokens.len() < self.min_tokens {
            return false;
        }
        let bytes = kv.bytes();
        if bytes > self.budget_bytes {
            return false;
        }
        let hash = prefix_hash(tokens);
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&hash) {
            e.last_touch = self.clock;
            return false;
        }
        while self.bytes_used + bytes > self.budget_bytes {
            self.evict_lru();
        }
        self.bytes_used += bytes;
        self.cached_tokens += tokens.len();
        self.stats.inserts += 1;
        self.entries.insert(
            hash,
            Entry { tokens: tokens.to_vec(), kv, bytes, hits: 0, last_touch: self.clock },
        );
        true
    }

    /// Drop the least-recently-touched entry.
    fn evict_lru(&mut self) {
        let victim = self
            .entries
            .iter()
            .min_by_key(|(_, e)| e.last_touch)
            .map(|(&h, _)| h)
            .expect("evict_lru on an empty cache (slab fit was pre-checked)");
        let e = self.entries.remove(&victim).unwrap();
        self.bytes_used -= e.bytes;
        self.cached_tokens -= e.tokens.len();
        self.stats.evictions += 1;
    }

    /// Per-entry hit count for the exact prefix `tokens` (test/debug
    /// introspection).
    pub fn entry_hits(&self, tokens: &[u32]) -> Option<u64> {
        self.entries.get(&prefix_hash(tokens)).map(|e| e.hits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic slab: `layers` layers of `per_layer` f32s each for K
    /// and V, filled with a recognizable value.
    fn slab(len: usize, layers: usize, per_token: usize, fill: f32) -> KvPrefix {
        let layer = vec![fill; len * per_token];
        KvPrefix { len, k: vec![layer.clone(); layers], v: vec![layer; layers] }
    }

    /// Bytes of `slab(len, 2, 4, _)`: 2 layers × (K+V) × len×4 f32s.
    fn slab_bytes(len: usize) -> usize {
        2 * 2 * len * 4 * 4
    }

    #[test]
    fn hash_is_order_and_content_sensitive() {
        assert_eq!(prefix_hash(&[1, 2, 3]), prefix_hash(&[1, 2, 3]));
        assert_ne!(prefix_hash(&[1, 2, 3]), prefix_hash(&[3, 2, 1]));
        assert_ne!(prefix_hash(&[1, 2]), prefix_hash(&[1, 2, 3]));
    }

    #[test]
    fn insert_lookup_roundtrip_longest_match_wins() {
        let mut c = PrefixCache::new(1 << 20, 2);
        assert!(c.insert(&[7, 8], slab(2, 2, 4, 1.0)));
        assert!(c.insert(&[7, 8, 9, 10], slab(4, 2, 4, 2.0)));
        assert_eq!(c.len(), 2);
        assert_eq!(c.cached_tokens(), 6);
        // the longer of the two matching prefixes is chosen
        let hit = c.lookup(&[7, 8, 9, 10, 11, 12]).expect("hit");
        assert_eq!(hit.len, 4);
        assert_eq!(hit.k[0][0], 2.0);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.entry_hits(&[7, 8, 9, 10]), Some(1));
        assert_eq!(c.entry_hits(&[7, 8]), Some(0));
        // an unrelated prompt is a miss
        assert!(c.lookup(&[1, 2, 3]).is_none());
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn match_must_leave_a_suffix_to_feed() {
        // A prompt equal to (or shorter than) a cached prefix cannot use
        // it: the first generated token needs real last-position logits.
        let mut c = PrefixCache::new(1 << 20, 2);
        assert!(c.insert(&[7, 8, 9], slab(3, 2, 4, 1.0)));
        assert_eq!(c.probe(&[7, 8, 9]), 0);
        assert_eq!(c.probe(&[7, 8]), 0);
        assert_eq!(c.probe(&[7, 8, 9, 10]), 3);
        assert!(c.lookup(&[7, 8, 9]).is_none());
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn probe_is_stat_free() {
        let mut c = PrefixCache::new(1 << 20, 2);
        assert!(c.insert(&[7, 8], slab(2, 2, 4, 1.0)));
        for _ in 0..5 {
            assert_eq!(c.probe(&[7, 8, 9]), 2);
            assert_eq!(c.probe(&[1, 2, 3]), 0);
        }
        assert_eq!(c.stats, PrefixCacheStats { inserts: 1, ..Default::default() });
    }

    #[test]
    fn min_tokens_and_oversize_refusals() {
        let mut c = PrefixCache::new(slab_bytes(4), 3);
        assert!(!c.insert(&[1, 2], slab(2, 2, 4, 1.0)), "below min_tokens");
        assert!(!c.insert(&[1, 2, 3, 4, 5], slab(5, 2, 4, 1.0)), "exceeds whole budget");
        assert_eq!(c.len(), 0);
        assert_eq!(c.stats.inserts, 0);
        assert_eq!(c.stats.evictions, 0);
    }

    #[test]
    fn duplicate_insert_keeps_resident_copy() {
        let mut c = PrefixCache::new(1 << 20, 2);
        assert!(c.insert(&[7, 8, 9], slab(3, 2, 4, 1.0)));
        assert!(!c.insert(&[7, 8, 9], slab(3, 2, 4, 9.0)), "already resident");
        assert_eq!(c.stats.inserts, 1);
        assert_eq!(c.cached_tokens(), 3);
        let hit = c.lookup(&[7, 8, 9, 10]).expect("hit");
        assert_eq!(hit.k[0][0], 1.0, "the first copy stays");
    }

    #[test]
    fn lru_eviction_under_tight_budget() {
        // Budget fits exactly two 3-token slabs. Insert A, B; touch A via
        // a lookup; inserting C must evict B (least recently touched).
        let mut c = PrefixCache::new(2 * slab_bytes(3), 3);
        assert!(c.insert(&[1, 1, 1], slab(3, 2, 4, 1.0))); // A
        assert!(c.insert(&[2, 2, 2], slab(3, 2, 4, 2.0))); // B
        assert!(c.lookup(&[1, 1, 1, 9]).is_some()); // touch A
        assert!(c.insert(&[3, 3, 3], slab(3, 2, 4, 3.0))); // C evicts B
        assert_eq!(c.stats.evictions, 1);
        assert_eq!(c.len(), 2);
        assert_eq!(c.probe(&[2, 2, 2, 9]), 0, "B evicted");
        assert_eq!(c.probe(&[1, 1, 1, 9]), 3, "A survived (recently touched)");
        assert_eq!(c.probe(&[3, 3, 3, 9]), 3);
        assert!(c.bytes_used() <= c.budget_bytes());
        assert_eq!(c.cached_tokens(), 6);
    }

    #[test]
    fn hit_clone_survives_concurrent_eviction() {
        // The mid-restore safety property: a slab handed out by `lookup`
        // stays intact even when the entry is evicted before the restore
        // finishes.
        let mut c = PrefixCache::new(slab_bytes(3), 3);
        assert!(c.insert(&[1, 1, 1], slab(3, 2, 4, 7.0)));
        let held = c.lookup(&[1, 1, 1, 9]).expect("hit");
        assert!(c.insert(&[2, 2, 2], slab(3, 2, 4, 8.0)), "evicts the held entry");
        assert_eq!(c.stats.evictions, 1);
        assert_eq!(c.probe(&[1, 1, 1, 9]), 0, "entry gone");
        assert!(held.k.iter().chain(held.v.iter()).all(|l| l.iter().all(|&x| x == 7.0)));
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut c = PrefixCache::new(0, 2);
        assert!(!c.enabled());
        assert!(!c.insert(&[1, 2, 3], slab(3, 2, 4, 1.0)));
        assert!(c.lookup(&[1, 2, 3, 4]).is_none());
        assert_eq!(c.stats, PrefixCacheStats::default(), "disabled caches count nothing");
    }
}
