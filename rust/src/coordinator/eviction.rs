//! Footprint-aware slot eviction (`--ep-evict`): preempt the running row
//! that fits the batch worst when a queued request would fit far better.
//!
//! Admission composes the batch only at slot-free boundaries; under
//! long-running rows a bad mix (cold-start admissions, drifting traffic)
//! can pin the expert union — and under expert parallelism the straggler
//! GPU — for thousands of steps. Eviction is the complementary lever: when
//! the queue holds a request whose predicted expert set overlaps the
//! running union **far** better than the worst-fitting running row does
//! (strictly more than [`EVICTION_MARGIN`], on the same MaxLoad-weighted
//! [`admission_score`] admission uses), that row is preempted back to the
//! queue and the better-fitting request takes its slot at the very next
//! admission. Since PR 6 the MaxLoad term resolves replicas: the
//! leave-one-out unions are scored under replica-aware routing, so an
//! expert with a copy on an idle GPU no longer penalizes the candidate
//! that needs it.
//!
//! ## Preemption is lossless (the recompute/resume contract)
//!
//! KV never migrates between slots. [`requeue_request`] converts the
//! victim's sequence into a resubmittable request: every committed token —
//! consumed prompt and generated alike — becomes the new prompt, the
//! generated tokens are additionally recorded in
//! [`Request::resume_prefix`], and the generation budget shrinks by what
//! was already produced. Re-admission rebuilds the row's cache by
//! prefilling that history into whatever slot it lands in (the chunk
//! `catch_up` idiom at request scope; see the eviction/resume contract in
//! `model/moe_model.rs`), so under row-independent routing the resumed
//! continuation is byte-identical to an uninterrupted run — pinned by
//! `rust/tests/ep_serve.rs`.
//!
//! With the shared-prefix cache on (PR 7), the recompute is usually
//! skipped: the preemption offers the victim's committed-history KV slab
//! to [`super::prefix_cache`], and since the requeued prompt IS that
//! history, the resume admission finds it as an ordinary cache hit and
//! restores the bytes instead of re-prefilling them (the cache-restore KV
//! contract, same file) — same tokens either way, with the
//! restore-vs-recompute split reported in `resume_restores` /
//! `resume_recomputes`. When the slab has been LRU-evicted by then, the
//! full recompute path above still applies unchanged.
//!
//! ## Bounds
//!
//! * At most one eviction per serving step (the serve loop's driver).
//! * At most [`EVICTION_BUDGET`] evictions per request, tracked in
//!   [`Request::evictions`] — a preempted request can never thrash.
//! * A victim must beat the margin: candidates that are merely *slightly*
//!   better never justify throwing away a row's prefill work.
//! * Requeued entries bypass queue backpressure (an accepted request is
//!   never droppable) and keep their submission clock and absolute
//!   deadline, so TTFT/SLO accounting stays origin-anchored.

use super::admission::FootprintTracker;
use super::request::{Request, SeqState};
use crate::ep::Placement;
use crate::selection::{admission_score, ExpertSet};

/// Evictions one request may suffer over its lifetime. One is enough to
/// correct a cold-start mis-admission, and the bound guarantees progress:
/// total evictions per workload ≤ requests submitted.
pub const EVICTION_BUDGET: u32 = 1;

/// How much better (in [`admission_score`] units — experts of overlap,
/// MaxLoad-weighted under EP) the best queued candidate must fit the
/// remaining batch than the victim does. One full expert: eviction
/// recomputes the victim's prefill, so near-ties must never trigger it.
pub const EVICTION_MARGIN: f64 = 1.0;

/// A planned preemption: evict the sequence in `victim_slot`; the best
/// queued candidate out-fits it by `gain` score units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvictionPlan {
    pub victim_slot: usize,
    pub gain: f64,
}

/// Decide whether any running row should be preempted for a queued
/// request. Pure: the serve loop passes read-only views and applies the
/// plan itself.
///
/// For every eligible victim `v` (informative footprint, eviction budget
/// left), the batch it would leave behind is `loo_union(v)` — the union of
/// the OTHER rows' predicted expert sets. The victim's fit and every
/// informative queued candidate's fit are scored against that same union
/// with the MaxLoad-weighted [`admission_score`]; the plan maximizes
/// `best_candidate − victim` and fires only strictly above
/// [`EVICTION_MARGIN`]. Candidate predictions are resolved once up front
/// (only the leave-one-out union varies per victim), so one call costs
/// O(queue + slots²) set operations — the serve loop only calls when the
/// batch is full and the queue is non-empty.
pub fn plan_eviction(
    tracker: &FootprintTracker,
    candidates: &[&Request],
    running: &[(usize, &SeqState)],
    placement: Option<&Placement>,
    top_k: usize,
) -> Option<EvictionPlan> {
    if candidates.is_empty() || running.len() < 2 {
        // A solo row has no "rest of the batch" to fit badly against.
        return None;
    }
    // Hoisted per-candidate predicted expert sets: class-key hashing and
    // top-set extraction are victim-independent.
    let cand_sets: Vec<ExpertSet> = candidates
        .iter()
        .filter_map(|req| tracker.predict(req))
        .map(|fp| fp.top_set(top_k))
        .collect();
    if cand_sets.is_empty() {
        return None; // no informative candidate anywhere in the queue
    }
    let mut best: Option<EvictionPlan> = None;
    for &(victim, seq) in running {
        if seq.req.evictions >= EVICTION_BUDGET {
            continue;
        }
        let Some(victim_fp) = tracker.slot_footprint(victim) else { continue };
        if !victim_fp.is_informative() {
            continue;
        }
        let others: Vec<usize> =
            running.iter().map(|&(s, _)| s).filter(|&s| s != victim).collect();
        let loo_union = tracker.running_union(&others, top_k);
        if loo_union.is_empty() {
            continue; // nothing observed to fit against
        }
        let victim_score =
            admission_score(&victim_fp.top_set(top_k), &loo_union, placement);
        let best_cand = cand_sets
            .iter()
            .map(|set| admission_score(set, &loo_union, placement))
            .fold(f64::NEG_INFINITY, f64::max);
        let gain = best_cand - victim_score;
        if gain > EVICTION_MARGIN && best.map(|b| gain > b.gain).unwrap_or(true) {
            best = Some(EvictionPlan { victim_slot: victim, gain });
        }
    }
    best
}

/// Convert a preempted sequence back into a queue-able request (see the
/// module docs for the resume contract). The prompt/budget invariant
/// `prompt.len() + max_new_tokens` is unchanged, so the KV-window bound
/// checked at submission still holds on resume.
pub fn requeue_request(seq: SeqState) -> Request {
    let mut req = seq.req;
    req.evictions += 1;
    if !seq.generated.is_empty() {
        debug_assert!(seq.generated.len() < req.max_new_tokens, "done rows never evict");
        req.max_new_tokens -= seq.generated.len();
        req.prompt.extend_from_slice(&seq.generated);
        req.resume_prefix.extend_from_slice(&seq.generated);
    }
    req
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Phase;

    fn mk(id: u64, domain: &str) -> Request {
        let mut r = Request::new(id, vec![1, 2, 3], 6);
        r.domain = domain.into();
        r
    }

    /// Tracker with two well-separated classes: "a" on experts {0, 1},
    /// "b" on {6, 7}; slots 0/1 run "a", slot 2 runs "b".
    fn warmed_tracker() -> FootprintTracker {
        let mut tr = FootprintTracker::new(8, 4);
        let row_a = [0.5, 0.4, 0.02, 0.02, 0.02, 0.02, 0.01, 0.01];
        let row_b = [0.01, 0.01, 0.02, 0.02, 0.02, 0.02, 0.4, 0.5];
        for slot in [0usize, 1] {
            tr.on_admit(slot, &mk(slot as u64, "a"));
            tr.observe_row(slot, &row_a);
        }
        tr.on_admit(2, &mk(2, "b"));
        tr.observe_row(2, &row_b);
        tr
    }

    fn seqs() -> Vec<SeqState> {
        vec![
            SeqState::new(mk(0, "a")),
            SeqState::new(mk(1, "a")),
            SeqState::new(mk(2, "b")),
        ]
    }

    #[test]
    fn evicts_the_worst_fitting_row_for_a_better_candidate() {
        let tr = warmed_tracker();
        let seqs = seqs();
        let running: Vec<(usize, &SeqState)> =
            seqs.iter().enumerate().map(|(i, s)| (i, s)).collect();
        let cand = mk(10, "a");
        let plan = plan_eviction(&tr, &[&cand], &running, None, 2).expect("plan");
        // the "b" row overlaps the {a, a} rest not at all; the "a"
        // candidate overlaps it fully → gain 2 > margin 1
        assert_eq!(plan.victim_slot, 2);
        assert!(plan.gain > EVICTION_MARGIN);
        // a same-class candidate must NOT evict anyone out of an all-"a"
        // batch: every victim's leave-one-out fit equals the candidate's
        let all_a: Vec<(usize, &SeqState)> =
            running.iter().take(2).copied().collect();
        assert_eq!(plan_eviction(&tr, &[&cand], &all_a, None, 2), None);
    }

    #[test]
    fn no_eviction_without_informative_candidates_or_mixed_batch() {
        let tr = warmed_tracker();
        let seqs = seqs();
        let running: Vec<(usize, &SeqState)> =
            seqs.iter().enumerate().map(|(i, s)| (i, s)).collect();
        // unknown class → no prediction → no plan
        let unknown = mk(11, "never-seen");
        assert_eq!(plan_eviction(&tr, &[&unknown], &running, None, 2), None);
        // empty queue → no plan
        assert_eq!(plan_eviction(&tr, &[], &running, None, 2), None);
        // a solo row never evicts
        let solo: Vec<(usize, &SeqState)> = vec![(2, &seqs[2])];
        let cand = mk(10, "a");
        assert_eq!(plan_eviction(&tr, &[&cand], &solo, None, 2), None);
    }

    #[test]
    fn eviction_budget_protects_the_victim() {
        let tr = warmed_tracker();
        let mut seqs = seqs();
        seqs[2].req.evictions = EVICTION_BUDGET; // already evicted once
        let running: Vec<(usize, &SeqState)> =
            seqs.iter().enumerate().map(|(i, s)| (i, s)).collect();
        let cand = mk(10, "a");
        assert_eq!(
            plan_eviction(&tr, &[&cand], &running, None, 2),
            None,
            "budget-exhausted rows are immune"
        );
    }

    #[test]
    fn requeue_mid_prefill_keeps_prompt_and_counts_the_eviction() {
        let seq = SeqState::new(mk(7, "a"));
        let req = requeue_request(seq);
        assert_eq!(req.prompt, vec![1, 2, 3]);
        assert_eq!(req.max_new_tokens, 6);
        assert!(req.resume_prefix.is_empty());
        assert_eq!(req.evictions, 1);
    }

    #[test]
    fn requeue_mid_decode_moves_generated_into_prompt() {
        let mut seq = SeqState::new(mk(7, "a"));
        for _ in 0..2 {
            seq.advance_prefill(0);
        }
        seq.advance_prefill(40); // prompt done, first token 40
        seq.commit(41);
        assert_eq!(seq.phase, Phase::Decode);
        let before_sum = seq.req.prompt.len() + seq.req.max_new_tokens;
        let req = requeue_request(seq);
        assert_eq!(req.prompt, vec![1, 2, 3, 40, 41]);
        assert_eq!(req.resume_prefix, vec![40, 41]);
        assert_eq!(req.max_new_tokens, 4);
        assert_eq!(req.prompt.len() + req.max_new_tokens, before_sum);
        assert_eq!(req.evictions, 1);
        // a resumed run that finishes reports the full generation
        let mut resumed = SeqState::new(req);
        for _ in 0..4 {
            resumed.advance_prefill(0);
        }
        resumed.advance_prefill(42);
        assert_eq!(resumed.full_output(), vec![40, 41, 42]);
    }
}
