//! The decode scheduler: continuous batching, the speculative verify cycle,
//! per-step expert selection and cost accounting. This is the L3 "leader"
//! loop — everything on the request path runs here, in rust.
//!
//! ## Speculative verify emulation (DESIGN.md §4)
//!
//! The compiled decode-step artifact advances one token per row, so a verify
//! forward over B×(1+L_s) tokens is emulated in two passes of (1+L_s)
//! sub-steps each:
//!
//!  * **pass 1 (scoring)**: vanilla routing, records every layer's gate
//!    scores for all verify tokens — the effective-batch G^{(l)};
//!  * **selection**: the policy picks S_l once per layer from those scores
//!    (with per-request grouping, exactly Algorithm 4's input);
//!  * **pass 2 (restricted)**: re-runs the sub-steps with every layer
//!    restricted to S_l; its logits drive acceptance and its KV writes are
//!    the ones that persist (positions beyond the accepted prefix are
//!    garbage-but-masked, verified by the kernel tests).
//!
//! The cost model charges one draft step per speculative token plus ONE
//! target forward over the effective batch — the two passes are an artifact
//! of the one-token-per-row compilation, not of the system being modeled.

use std::collections::BTreeMap;

use anyhow::Result;

use super::batcher::Batcher;
use super::request::{Phase, Request};
use super::speculative::{effective_batch_scores, greedy_accept};
use crate::config::ServeConfig;
use crate::ep::{EpCostModel, Placement};
use crate::memsim::{CostGeometry, DecodeCostModel, HardwareProfile};
use crate::metrics::ServeMetrics;
use crate::model::{argmax, MoeModel, RoutingMode, StepInput};
use crate::selection::{baselines::Vanilla, ExpertSet, ScoreMatrix, SelectionPolicy};

/// Result of one serving run.
#[derive(Debug)]
pub struct RunReport {
    pub metrics: ServeMetrics,
    /// request id → generated tokens.
    pub outputs: BTreeMap<u64, Vec<u32>>,
    /// request id → domain (for per-dataset reporting).
    pub domains: BTreeMap<u64, String>,
}

pub struct Scheduler<'m> {
    model: &'m mut MoeModel,
    cfg: ServeConfig,
    policy: Box<dyn SelectionPolicy>,
    cost: DecodeCostModel,
    ep_cost: EpCostModel,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m mut MoeModel, cfg: ServeConfig) -> Result<Scheduler<'m>> {
        let cost = DecodeCostModel::new(
            HardwareProfile::by_name(&cfg.hardware)?,
            CostGeometry::for_preset(&cfg.preset)?,
        );
        let policy = cfg.policy.build();
        if let Some(ep) = &cfg.ep {
            model.placement = Some(Placement::new(
                model.dims().n_experts,
                ep.n_gpus,
                ep.placement,
            ));
        }
        Ok(Scheduler { model, cfg, policy, cost, ep_cost: EpCostModel::default() })
    }

    /// Serve a list of requests to completion; returns metrics + outputs.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<RunReport> {
        let n_layers = self.model.dims().n_layers;
        let b_max = self.model.max_batch();
        let mut batcher = Batcher::new(b_max, self.cfg.batch_size.min(b_max));
        let mut domains = BTreeMap::new();
        for r in &requests {
            domains.insert(r.id, r.domain.clone());
        }
        batcher.submit_all(requests);
        let mut metrics = ServeMetrics::new(n_layers);
        let mut outputs = BTreeMap::new();
        self.model.reset();

        let mut draft = if self.cfg.spec_len > 0 {
            Some(DraftState::new(
                crate::model::DraftModel::new(self.model.engine())?,
                b_max,
            ))
        } else {
            None
        };

        let wall0 = std::time::Instant::now();
        while batcher.has_work() {
            batcher.admit();
            let slots = batcher.live_slots();
            debug_assert!(!slots.is_empty());

            let all_decode =
                slots.iter().all(|&s| batcher.seq(s).phase == Phase::Decode);
            if self.cfg.spec_len > 0 && all_decode {
                self.spec_cycle(&mut batcher, &slots, draft.as_mut().unwrap(), &mut metrics, &mut outputs)?;
            } else {
                self.plain_step(&mut batcher, &slots, draft.as_mut(), &mut metrics, &mut outputs)?;
            }
        }
        metrics.wall_seconds = wall0.elapsed().as_secs_f64();
        metrics.requests_done = outputs.len() as u64;
        Ok(RunReport { metrics, outputs, domains })
    }

    /// One ordinary continuous-batching step (prefill and/or decode rows).
    fn plain_step(
        &mut self,
        batcher: &mut Batcher,
        slots: &[usize],
        draft: Option<&mut DraftState>,
        metrics: &mut ServeMetrics,
        outputs: &mut BTreeMap<u64, Vec<u32>>,
    ) -> Result<()> {
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let mut tokens = vec![0i32; b_max];
        let mut pos = vec![0i32; b_max];
        for &s in slots {
            let seq = batcher.seq(s);
            tokens[s] = seq.next_token as i32;
            pos[s] = seq.pos as i32;
        }
        let groups: Vec<Vec<usize>> = slots.iter().map(|&s| vec![s]).collect();
        let out = self.model.step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: slots,
            requests: &groups,
            mode: RoutingMode::Policy(self.policy.as_ref()),
            collect_probs: false,
        })?;

        // The draft model shadows the token stream so its cache stays warm
        // for upcoming speculative cycles.
        if let Some(d) = draft {
            d.shadow_step(self.model.engine(), &tokens, &pos)?;
        }

        let logits = out.logits.as_f32()?;
        let mut committed = 0u64;
        for &s in slots {
            let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
            let seq = batcher.seq_mut(s);
            match seq.phase {
                Phase::Prefill => {
                    if seq.advance_prefill(am) {
                        committed += 1;
                    }
                }
                Phase::Decode => {
                    seq.commit(am);
                    committed += 1;
                }
            }
            if seq.is_done() {
                let done = batcher.release(s);
                outputs.insert(done.req.id, done.generated);
            }
        }

        let sim_s = self.charge_step(&out.activated, &out.selected, slots.len(), 0, metrics);
        metrics.record_step(&out.activated, sim_s, committed);
        Ok(())
    }

    /// One speculative verify cycle (all rows in decode phase).
    fn spec_cycle(
        &mut self,
        batcher: &mut Batcher,
        slots: &[usize],
        draft: &mut DraftState,
        metrics: &mut ServeMetrics,
        outputs: &mut BTreeMap<u64, Vec<u32>>,
    ) -> Result<()> {
        let ls = self.cfg.spec_len;
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let n_layers = self.model.dims().n_layers;
        let n_experts = self.model.dims().n_experts;

        // ---- draft proposals (plus catch-up for fully-accepted rows) ----
        draft.catch_up(self.model.engine(), batcher, slots)?;
        let mut proposals: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        {
            let mut dtok = vec![0i32; b_max];
            let mut dpos = vec![0i32; b_max];
            for &s in slots {
                let seq = batcher.seq(s);
                dtok[s] = seq.next_token as i32;
                dpos[s] = seq.pos as i32;
                proposals.insert(s, Vec::with_capacity(ls));
            }
            for _ in 0..ls {
                let logits_t = draft.model.step(self.model.engine(), &dtok, &dpos)?;
                let logits = logits_t.as_f32()?;
                for &s in slots {
                    let d = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                    proposals.get_mut(&s).unwrap().push(d);
                    dtok[s] = d as i32;
                    dpos[s] += 1;
                }
            }
            for &s in slots {
                draft.pos[s] = batcher.seq(s).pos + ls; // processed up to pos+ls-1
            }
        }

        // verify inputs per sub-step j: j=0 → next_token, j>=1 → draft j-1
        let verify_tok = |batcher: &Batcher, s: usize, j: usize| -> u32 {
            if j == 0 {
                batcher.seq(s).next_token
            } else {
                proposals[&s][j - 1]
            }
        };

        // ---- pass 1: scoring (vanilla routing, collect per-layer probs) --
        let vanilla = Vanilla;
        let groups_single: Vec<Vec<usize>> = slots.iter().map(|&s| vec![s]).collect();
        let mut pass1_scores: Vec<Vec<(ScoreMatrix, ScoreMatrix)>> = Vec::with_capacity(ls + 1);
        for j in 0..=ls {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            for &s in slots {
                tokens[s] = verify_tok(batcher, s, j) as i32;
                pos[s] = (batcher.seq(s).pos + j) as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: slots,
                requests: &groups_single,
                mode: RoutingMode::Policy(&vanilla),
                collect_probs: true,
            })?;
            pass1_scores.push(out.scores.unwrap());
        }

        // ---- per-layer selection over the effective batch ---------------
        let mut sets: Vec<ExpertSet> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let logits_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].0).collect();
            let probs_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].1).collect();
            let (eff_logits, _) = effective_batch_scores(&logits_steps, slots);
            let (eff_probs, groups) = effective_batch_scores(&probs_steps, slots);
            let rows: Vec<usize> = (0..eff_probs.n_tokens()).collect();
            let ctx = crate::selection::SelectionContext {
                probs: &eff_probs,
                logits: &eff_logits,
                rows: &rows,
                requests: &groups,
                colsum_hint: None,
                placement: self.model.placement.as_ref(),
                top_k: self.model.dims().top_k,
            };
            sets.push(self.policy.select(&ctx));
        }

        // ---- pass 2: restricted run; drives acceptance -------------------
        let mut target_argmax: BTreeMap<usize, Vec<u32>> =
            slots.iter().map(|&s| (s, Vec::with_capacity(ls + 1))).collect();
        let mut union_activated: Vec<ExpertSet> =
            (0..n_layers).map(|_| ExpertSet::empty(n_experts)).collect();
        let mut acts = vec![0usize; n_layers];
        for j in 0..=ls {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            for &s in slots {
                tokens[s] = verify_tok(batcher, s, j) as i32;
                pos[s] = (batcher.seq(s).pos + j) as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: slots,
                requests: &groups_single,
                mode: RoutingMode::Restricted(&sets),
                collect_probs: false,
            })?;
            let logits = out.logits.as_f32()?;
            for &s in slots {
                let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                target_argmax.get_mut(&s).unwrap().push(am);
            }
            for (u, sel) in union_activated.iter_mut().zip(&out.selected) {
                u.union_with(sel);
            }
        }
        for (a, u) in acts.iter_mut().zip(&union_activated) {
            *a = u.len();
        }

        // ---- acceptance & commit -----------------------------------------
        let mut committed_total = 0u64;
        for &s in slots {
            let (n_acc, committed) = greedy_accept(&proposals[&s], &target_argmax[&s]);
            metrics.spec_proposed += ls as u64;
            metrics.spec_accepted += n_acc as u64;
            let seq = batcher.seq_mut(s);
            let take = committed.len().min(seq.remaining());
            for &tok in committed.iter().take(take) {
                seq.commit(tok);
                committed_total += 1;
            }
            // full acceptance leaves the draft cache one input behind
            draft.lag_token[s] = if n_acc == ls && ls > 0 {
                Some(proposals[&s][ls - 1])
            } else {
                None
            };
            if seq.is_done() {
                let done = batcher.release(s);
                outputs.insert(done.req.id, done.generated);
                draft.lag_token[s] = None;
            }
        }

        let sim_s = self.charge_step(
            &acts,
            &union_activated,
            slots.len() * (1 + ls),
            ls, // draft steps
            metrics,
        );
        metrics.record_step(&acts, sim_s, committed_total);
        Ok(())
    }

    /// Simulated cost of one target forward (+ draft steps) and EP load
    /// accounting. Returns simulated seconds.
    fn charge_step(
        &self,
        activated: &[usize],
        selected: &[ExpertSet],
        n_tokens: usize,
        draft_steps: usize,
        metrics: &mut ServeMetrics,
    ) -> f64 {
        let mut sim = draft_steps as f64 * self.cost.draft_step();
        if let Some(pl) = &self.model.placement {
            let sel_refs: Vec<&ExpertSet> = selected.iter().collect();
            sim += self.cost.ep_step(pl, &sel_refs, n_tokens, &self.ep_cost);
            let max_load =
                selected.iter().map(|s| pl.max_load(s)).max().unwrap_or(0);
            metrics.max_gpu_load.add(max_load as f64);
        } else {
            let scaled = self.cost.scale_activations(activated);
            sim += self.cost.target_step(&scaled, n_tokens).total_seconds;
        }
        sim
    }
}

/// Draft-model wrapper tracking per-slot cache positions and catch-up debt.
struct DraftState {
    model: crate::model::DraftModel,
    pos: Vec<usize>,
    lag_token: Vec<Option<u32>>,
}

impl DraftState {
    fn new(model: crate::model::DraftModel, b_max: usize) -> DraftState {
        DraftState { model, pos: vec![0; b_max], lag_token: vec![None; b_max] }
    }

    /// During plain steps the draft ingests the same tokens as the target.
    fn shadow_step(
        &mut self,
        engine: &crate::runtime::Engine,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<()> {
        self.model.step(engine, tokens, pos)?;
        for (p, &np) in self.pos.iter_mut().zip(pos) {
            *p = (*p).max(np as usize + 1);
        }
        Ok(())
    }

    /// Feed the one missing input for rows that fully accepted last cycle.
    fn catch_up(
        &mut self,
        engine: &crate::runtime::Engine,
        batcher: &Batcher,
        slots: &[usize],
    ) -> Result<()> {
        if slots.iter().all(|&s| self.lag_token[s].is_none()) {
            return Ok(());
        }
        let b = self.pos.len();
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for &s in slots {
            let seq = batcher.seq(s);
            match self.lag_token[s] {
                Some(t) => {
                    tokens[s] = t as i32;
                    pos[s] = (seq.pos - 1) as i32;
                }
                None => {
                    // harmless re-write of the upcoming position
                    tokens[s] = seq.next_token as i32;
                    pos[s] = seq.pos as i32;
                }
            }
        }
        self.model.step(engine, &tokens, &pos)?;
        for &s in slots {
            self.lag_token[s] = None;
        }
        Ok(())
    }
}
