//! Batch-at-a-time wrapper over the stepped serving core.
//!
//! `Scheduler::run` is submit-all-upfront + step-until-done on a fresh
//! [`ServeLoop`] — byte-identical to the old monolithic run loop, and what
//! the benches, examples, offline CLI and the fidelity harness drive. Live
//! serving (the TCP worker) talks to [`ServeLoop`] directly so requests can
//! join mid-flight; see [`super::serve_loop`] for the step semantics and
//! the speculative verify emulation notes.

use anyhow::Result;

use super::request::Request;
use super::serve_loop::{RunReport, ServeLoop};
use crate::config::ServeConfig;
use crate::model::MoeModel;

pub struct Scheduler<'m> {
    core: ServeLoop<'m>,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m mut MoeModel, cfg: ServeConfig) -> Result<Scheduler<'m>> {
        Ok(Scheduler { core: ServeLoop::new(model, cfg)? })
    }

    /// Serve a list of requests to completion; returns metrics + outputs.
    ///
    /// Submission is all-upfront, so a bounded queue (`max_queue <
    /// requests.len()`) rejects the overflow here — offline runs should
    /// keep the default unbounded queue.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<RunReport> {
        self.core.reset()?;
        for r in requests {
            self.core.submit(r)?;
        }
        self.core.drain()?;
        Ok(self.core.report())
    }

    /// The underlying stepped core (for callers that want to interleave
    /// submission with stepping themselves).
    pub fn core(&mut self) -> &mut ServeLoop<'m> {
        &mut self.core
    }
}
