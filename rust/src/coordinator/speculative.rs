//! Speculative-decoding primitives: greedy acceptance, per-layer
//! effective-batch score assembly (uniform and **ragged** per-row depth),
//! the adaptive depth controller, and the n-gram lookup drafter. The
//! verify-cycle orchestration lives in [`super::serve_loop`]; the logic
//! here is pure and unit-tested.

use std::collections::BTreeMap;

use crate::selection::ScoreMatrix;

/// Greedy acceptance: compare draft tokens against the target's argmax at
/// each position. Returns the committed tokens: the accepted prefix of the
/// drafts plus one bonus/correction token from the target.
///
/// `target_argmax[j]` = target's argmax after processing verify token j
/// (j=0 is the last committed token, j=1..=L_s are the drafts).
pub fn greedy_accept(drafts: &[u32], target_argmax: &[u32]) -> (usize, Vec<u32>) {
    assert_eq!(target_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut n_acc = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if target_argmax[j] == d {
            committed.push(d);
            n_acc += 1;
        } else {
            break;
        }
    }
    // bonus (all accepted) or correction (first mismatch) token
    committed.push(target_argmax[n_acc]);
    (n_acc, committed)
}

/// Assemble the effective-batch score matrix for one layer from the
/// per-sub-step padded matrices of the scoring pass.
///
/// `per_step[j]` is the padded `[B_max × N]` matrix of verify sub-step j;
/// `slots` are the live row indices. Output rows are ordered
/// (slot-major): request q's tokens occupy rows `q*(1+L_s) .. (q+1)*(1+L_s)`,
/// and the returned groups encode exactly that — the structure Algorithm 4
/// exploits.
pub fn effective_batch_scores(
    per_step: &[&ScoreMatrix],
    slots: &[usize],
) -> (ScoreMatrix, Vec<Vec<usize>>) {
    assert!(!per_step.is_empty());
    let depths = vec![per_step.len() - 1; slots.len()];
    effective_batch_scores_ragged(per_step, slots, &depths, None)
}

/// Ragged generalization of [`effective_batch_scores`]: each slot
/// contributes only its own `1 + depths[q]` verify positions (rows beyond a
/// row's depth are padding the emulation runs but the selection must never
/// see — they would bias the batch utility toward tokens that do not
/// exist).
///
/// With `priors`, position `j` of slot `q` is weighted by
/// `priors[q]^j` — the probability the position is actually *reached*
/// under geometric acceptance, the "acceptance prior" of the paper's
/// hierarchical spec-aware selection. Deep speculative positions of a
/// low-acceptance row then contribute proportionally less gating mass to
/// `SpecAware`'s per-request aggregation and to every batch utility, so
/// the selected set spends its budget on tokens likely to commit. Position
/// 0 (the committed token) always has weight 1; `priors = None` (or all
/// 1.0) reproduces the unweighted matrix bit-for-bit — the uniform-depth
/// byte-identity pin depends on that.
pub fn effective_batch_scores_ragged(
    per_step: &[&ScoreMatrix],
    slots: &[usize],
    depths: &[usize],
    priors: Option<&[f32]>,
) -> (ScoreMatrix, Vec<Vec<usize>>) {
    assert!(!per_step.is_empty());
    assert_eq!(slots.len(), depths.len());
    let n = per_step[0].n_experts();
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut groups = Vec::with_capacity(slots.len());
    for (q, &slot) in slots.iter().enumerate() {
        assert!(
            depths[q] < per_step.len(),
            "slot {slot} depth {} exceeds the {} verify sub-steps",
            depths[q],
            per_step.len()
        );
        let mut group = Vec::with_capacity(1 + depths[q]);
        for (j, m) in per_step.iter().take(1 + depths[q]).enumerate() {
            assert_eq!(m.n_experts(), n);
            group.push(rows.len());
            let mut row = m.row(slot).to_vec();
            if let Some(p) = priors {
                let w = p[q].clamp(0.0, 1.0).powi(j as i32);
                if w != 1.0 {
                    for v in row.iter_mut() {
                        *v *= w;
                    }
                }
            }
            rows.push(row);
        }
        groups.push(group);
    }
    (ScoreMatrix::from_rows(&rows), groups)
}

/// Propose a draft continuation by n-gram lookup over the row's own
/// committed history (prompt + generated) — prompt-lookup / self-lookup
/// decoding (Saxena 2023; vLLM's `prompt_lookup`): find the most recent
/// prior occurrence of the trailing bigram (falling back to the trailing
/// token), and propose the `depth` tokens that followed it. Costs no model
/// forward at all, so its drafts are free on the cost ledger; acceptance is
/// high exactly when generation is locally repetitive.
///
/// `history` must end with the token about to be fed to the verify forward
/// (`SeqState::next_token`). Returns up to `depth` proposals — possibly
/// fewer (ragged by construction) or none when the history has no match.
pub fn lookup_draft(history: &[u32], depth: usize) -> Vec<u32> {
    let n = history.len();
    if depth == 0 || n < 2 {
        return Vec::new();
    }
    // trailing bigram, most recent match first
    if n >= 3 {
        for j in (0..n - 2).rev() {
            if history[j] == history[n - 2] && history[j + 1] == history[n - 1] {
                let end = (j + 2 + depth).min(n);
                return history[j + 2..end].to_vec();
            }
        }
    }
    // trailing unigram fallback
    for j in (0..n - 1).rev() {
        if history[j] == history[n - 1] {
            let end = (j + 1 + depth).min(n);
            return history[j + 1..end].to_vec();
        }
    }
    Vec::new()
}

/// Incremental index behind the lookup drafter: the row's committed
/// history plus, for every trailing n-gram, its two most recent start
/// positions — updated in O(log n) on each commit instead of re-scanning
/// the whole history every verify cycle (the ROADMAP "index the lookup
/// drafter" item). Bigrams key on the exact 64-bit packed token pair
/// (collision-free, so the index is provably equivalent to the scan — the
/// property test below pins proposal-identity against [`lookup_draft`]).
///
/// Two positions per key are required because the *trailing* n-gram is
/// itself the most recent occurrence the moment its last token lands: a
/// draft query must fall back to the previous occurrence, exactly as the
/// linear scan's `(0..n-2).rev()` bound excludes the trailing match.
#[derive(Debug, Clone)]
pub struct NgramIndex {
    history: Vec<u32>,
    /// packed (a, b) → (latest start pos, previous start pos).
    bigram: BTreeMap<u64, (usize, Option<usize>)>,
    /// token → (latest pos, previous pos).
    unigram: BTreeMap<u32, (usize, Option<usize>)>,
    /// Disabled indexes drop every push — deployments that never lookup-
    /// draft (the default `spec_draft = model`, or `spec_len = 0`) must
    /// not pay a per-token history copy plus O(log n) map upserts on the
    /// commit path. The serve loop disables the index at admission unless
    /// lookup drafting is configured.
    enabled: bool,
}

impl Default for NgramIndex {
    fn default() -> NgramIndex {
        NgramIndex {
            history: Vec::new(),
            bigram: BTreeMap::new(),
            unigram: BTreeMap::new(),
            enabled: true,
        }
    }
}

#[inline]
fn bigram_key(a: u32, b: u32) -> u64 {
    ((a as u64) << 32) | b as u64
}

impl NgramIndex {
    /// Stop indexing and free the accumulated state. One-way for the life
    /// of the sequence: `draft()` on a disabled index would see an empty
    /// history, so callers only disable when lookup drafting is off.
    pub fn disable(&mut self) {
        self.enabled = false;
        self.history = Vec::new();
        self.bigram = BTreeMap::new();
        self.unigram = BTreeMap::new();
    }

    /// Append one committed token (prompt or generated), updating the
    /// trailing unigram/bigram occurrence chains. No-op when disabled.
    pub fn push(&mut self, tok: u32) {
        if !self.enabled {
            return;
        }
        let n = self.history.len();
        match self.unigram.get_mut(&tok) {
            Some(e) => *e = (n, Some(e.0)),
            None => {
                self.unigram.insert(tok, (n, None));
            }
        }
        if let Some(&prev) = self.history.last() {
            let key = bigram_key(prev, tok);
            match self.bigram.get_mut(&key) {
                Some(e) => *e = (n - 1, Some(e.0)),
                None => {
                    self.bigram.insert(key, (n - 1, None));
                }
            }
        }
        self.history.push(tok);
    }

    /// The committed history the index covers (prompt + generated; the
    /// last element is the token about to be fed — `SeqState::next_token`
    /// for a decoding row).
    pub fn history(&self) -> &[u32] {
        &self.history
    }

    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }

    /// Propose up to `depth` draft tokens — identical proposals to
    /// [`lookup_draft`] over the same history, in O(log n + depth) instead
    /// of O(n): most recent earlier occurrence of the trailing bigram,
    /// falling back to the trailing unigram; proposals clipped at the
    /// history end.
    pub fn draft(&self, depth: usize) -> Vec<u32> {
        let h = &self.history;
        let n = h.len();
        if depth == 0 || n < 2 {
            return Vec::new();
        }
        if n >= 3 {
            if let Some(&(j1, j2)) = self.bigram.get(&bigram_key(h[n - 2], h[n - 1])) {
                // the trailing bigram itself starts at n-2; a match must
                // start strictly earlier
                let j = if j1 == n - 2 { j2 } else { Some(j1) };
                if let Some(j) = j {
                    let end = (j + 2 + depth).min(n);
                    return h[j + 2..end].to_vec();
                }
            }
        }
        if let Some(&(j1, j2)) = self.unigram.get(&h[n - 1]) {
            let j = if j1 == n - 1 { j2 } else { Some(j1) };
            if let Some(j) = j {
                let end = (j + 1 + depth).min(n);
                return h[j + 1..end].to_vec();
            }
        }
        Vec::new()
    }
}

/// EMA decay for per-class acceptance tracking: ~10-cycle memory, the same
/// horizon the footprint tracker uses for routing scores.
pub const ACCEPT_DECAY: f32 = 0.9;

/// Verify cycles a depth-0 class waits between depth-1 probes. Without
/// probing, a class that ever collapsed to depth 0 would stop producing
/// acceptance observations and stay at 0 forever.
pub const PROBE_INTERVAL: u64 = 8;

/// Verify cycles a row must survive before its own acceptance EMA is
/// trusted: below this, a couple of lucky (or unlucky) cycles would
/// dominate the estimate, so [`SpecDepthController::row_prior`] keeps
/// reporting the class prior until the row has this many observations.
pub const SPEC_ROW_WARMUP: u64 = 4;

/// Per-traffic-class adaptive speculation depth.
///
/// Tracks a decayed EMA of each class's per-token acceptance rate (class
/// keys are [`super::admission::FootprintTracker::class_key`] — domain tag
/// or prompt hash, the same clustering admission uses) and maps it to a
/// draft depth in `[0, max_depth]`: the expected number of tokens a cycle
/// commits beyond position `d` decays like `a^d`, so depth is the largest
/// `d` with `a^d` above a fixed usefulness threshold. Unobserved classes
/// start optimistic (full depth — observations only exist if someone
/// drafts), and collapsed classes probe at depth 1 every
/// [`PROBE_INTERVAL`] cycles so recovery is possible.
///
/// Two refinements layer on the class EMAs:
///
/// * **Per-row acceptance EMAs** ([`Self::observe_row`] /
///   [`Self::row_prior`]): a row that has survived
///   [`SPEC_ROW_WARMUP`] verify cycles has its own acceptance estimate
///   blended 50/50 over the class prior, so one atypical request inside a
///   class (e.g. a highly repetitive row in a low-acceptance domain) gets
///   a prior that reflects *its* behaviour. Row state is keyed by request
///   id — never by slot, which is reused — and is dropped at release via
///   [`Self::forget_row`].
/// * **Charge-aware depth** ([`Self::charge_aware_depth`]): instead of
///   the fixed `DEPTH_USEFULNESS` threshold, compare the
///   acceptance-weighted expected commit value of position `d+1` against
///   the ledger-priced marginal charge of verifying one extra draft row
///   under the *current* batch geometry (see
///   `cost::Ledger::marginal_spec_cost`).
#[derive(Debug, Default)]
pub struct SpecDepthController {
    max_depth: usize,
    ema: BTreeMap<String, ClassAcceptance>,
    /// Per-request acceptance EMAs, keyed by request id (slot indices are
    /// reused across occupancies and would alias unrelated rows).
    rows: BTreeMap<u64, RowAcceptance>,
}

#[derive(Debug, Clone, Copy)]
struct ClassAcceptance {
    rate: f32,
    /// Cycles since the class last drafted (probe scheduling at depth 0).
    idle_cycles: u64,
}

#[derive(Debug, Clone, Copy)]
struct RowAcceptance {
    rate: f32,
    /// Verify cycles observed — the EMA is trusted only after
    /// [`SPEC_ROW_WARMUP`] of them.
    commits: u64,
}

/// Keep drafting position d while the expected marginal commit `a^d`
/// clears this threshold (draft tokens are cheap, verify slots are not).
const DEPTH_USEFULNESS: f32 = 0.25;

impl SpecDepthController {
    pub fn new(max_depth: usize) -> SpecDepthController {
        SpecDepthController {
            max_depth,
            ema: BTreeMap::new(),
            rows: BTreeMap::new(),
        }
    }

    /// Smoothed acceptance rate for a class, if it has ever drafted.
    pub fn acceptance(&self, class: &str) -> Option<f32> {
        self.ema.get(class).map(|c| c.rate)
    }

    /// The acceptance prior used to weight the class's speculative
    /// positions in selection (optimistic 1.0 before any observation).
    pub fn prior(&self, class: &str) -> f32 {
        self.acceptance(class).unwrap_or(1.0)
    }

    /// Draft depth for the next cycle of a row in `class`, advancing the
    /// class's probe clock. Cold classes get full depth.
    pub fn depth_for(&mut self, class: &str) -> usize {
        let Some(c) = self.ema.get_mut(class) else {
            return self.max_depth;
        };
        let mut depth = 0;
        let mut marginal = 1.0f32;
        while depth < self.max_depth {
            marginal *= c.rate;
            if marginal < DEPTH_USEFULNESS {
                break;
            }
            depth += 1;
        }
        if depth == 0 {
            c.idle_cycles += 1;
            if c.idle_cycles >= PROBE_INTERVAL {
                c.idle_cycles = 0;
                return 1; // probe
            }
        }
        depth
    }

    /// Fold one verify cycle's outcome for a row of `class` in:
    /// `accepted` of `proposed` draft tokens matched the target.
    pub fn observe(&mut self, class: &str, proposed: usize, accepted: usize) {
        if proposed == 0 {
            return;
        }
        let rate = accepted as f32 / proposed as f32;
        if let Some(c) = self.ema.get_mut(class) {
            c.rate = ACCEPT_DECAY * c.rate + (1.0 - ACCEPT_DECAY) * rate;
            c.idle_cycles = 0;
            return;
        }
        self.ema.insert(class.to_string(), ClassAcceptance { rate, idle_cycles: 0 });
    }

    /// Fold one verify cycle's outcome into the *row's own* acceptance
    /// EMA (the class EMA is updated separately via [`Self::observe`]).
    pub fn observe_row(&mut self, req_id: u64, proposed: usize, accepted: usize) {
        if proposed == 0 {
            return;
        }
        let rate = accepted as f32 / proposed as f32;
        match self.rows.get_mut(&req_id) {
            Some(r) => {
                r.rate = ACCEPT_DECAY * r.rate + (1.0 - ACCEPT_DECAY) * rate;
                r.commits += 1;
            }
            None => {
                self.rows.insert(req_id, RowAcceptance { rate, commits: 1 });
            }
        }
    }

    /// The acceptance prior for a specific row: the class prior until the
    /// row has survived [`SPEC_ROW_WARMUP`] observed verify cycles, then a
    /// 50/50 blend of the row's own EMA over the class prior. The blend
    /// (rather than a full handoff) keeps the estimate anchored when a
    /// row's local repetitiveness fades back toward class-typical.
    pub fn row_prior(&self, req_id: u64, class: &str) -> f32 {
        let class_prior = self.prior(class);
        match self.rows.get(&req_id) {
            Some(r) if r.commits >= SPEC_ROW_WARMUP => {
                0.5 * r.rate + 0.5 * class_prior
            }
            _ => class_prior,
        }
    }

    /// Drop a row's acceptance state at request release. Request ids are
    /// unique per trace but per-row EMAs only describe one occupancy, and
    /// an unbounded map would leak across a long serve run.
    pub fn forget_row(&mut self, req_id: u64) {
        self.rows.remove(&req_id);
    }

    /// Charge-aware depth choice (ROADMAP open item 2): grow depth while
    /// the acceptance-weighted expected value of the next draft position
    /// beats its ledger-priced marginal charge.
    ///
    /// `token_value` is the sim-seconds one committed token is worth —
    /// the plain (non-speculative) per-token step cost of the current
    /// batch, i.e. what the batch would have to spend to produce that
    /// token without speculation. `marginal(d)` prices verifying draft
    /// position `d+1` given `d` already-drafted positions (plus the draft
    /// side for model drafts). Position `d+1` commits with probability
    /// `a^(d+1)` under geometric acceptance, so we accept the extra depth
    /// while `a^(d+1) · token_value > marginal(d)`.
    ///
    /// In the memory-bound decode regime the marginal verify row is far
    /// cheaper than a full step (weights stream once for the whole
    /// batch), so this typically holds depth deeper than the fixed
    /// [`DEPTH_USEFULNESS`] threshold — the `spec_charge` bench pins the
    /// resulting OTPS win. Cold classes get the full `cap` (observations
    /// only exist if someone drafts) and collapsed classes reuse the
    /// [`PROBE_INTERVAL`] depth-1 probe of [`Self::depth_for`].
    pub fn charge_aware_depth(
        &mut self,
        class: &str,
        cap: usize,
        token_value: f64,
        marginal: impl Fn(usize) -> f64,
    ) -> usize {
        let Some(c) = self.ema.get_mut(class) else {
            return cap;
        };
        let mut depth = 0;
        let mut p = 1.0f32;
        while depth < cap {
            p *= c.rate;
            if (p as f64) * token_value <= marginal(depth) {
                break;
            }
            depth += 1;
        }
        if depth == 0 {
            c.idle_cycles += 1;
            if c.idle_cycles >= PROBE_INTERVAL {
                c.idle_cycles = 0;
                return 1; // probe
            }
        }
        depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_accepted_gets_bonus() {
        let (n, committed) = greedy_accept(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!(n, 3);
        assert_eq!(committed, vec![5, 6, 7, 8]);
    }

    #[test]
    fn first_mismatch_corrects_and_stops() {
        let (n, committed) = greedy_accept(&[5, 6, 7], &[5, 9, 7, 8]);
        assert_eq!(n, 1);
        assert_eq!(committed, vec![5, 9]);
    }

    #[test]
    fn immediate_mismatch_commits_one() {
        let (n, committed) = greedy_accept(&[5], &[4, 0]);
        assert_eq!(n, 0);
        assert_eq!(committed, vec![4]);
    }

    #[test]
    fn empty_drafts_commit_target_token() {
        let (n, committed) = greedy_accept(&[], &[3]);
        assert_eq!(n, 0);
        assert_eq!(committed, vec![3]);
    }

    #[test]
    fn ragged_scores_truncate_to_per_row_depth() {
        let a = ScoreMatrix::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0], vec![3.0, 0.0]]);
        let b = ScoreMatrix::from_rows(&[vec![0.0, 1.0], vec![0.0, 2.0], vec![0.0, 3.0]]);
        let c = ScoreMatrix::from_rows(&[vec![4.0, 4.0], vec![5.0, 5.0], vec![6.0, 6.0]]);
        // slot 0 at depth 2 (all three sub-steps), slot 2 at depth 0
        // (committed token only — its speculative rows must NOT appear)
        let (m, groups) =
            effective_batch_scores_ragged(&[&a, &b, &c], &[0, 2], &[2, 0], None);
        assert_eq!(m.n_tokens(), 4);
        assert_eq!(groups, vec![vec![0, 1, 2], vec![3]]);
        assert_eq!(m.row(0), &[1.0, 0.0]);
        assert_eq!(m.row(1), &[0.0, 1.0]);
        assert_eq!(m.row(2), &[4.0, 4.0]);
        assert_eq!(m.row(3), &[3.0, 0.0]); // slot 2's committed token
    }

    #[test]
    fn ragged_scores_weight_positions_by_acceptance_prior() {
        let a = ScoreMatrix::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        let b = ScoreMatrix::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        let c = ScoreMatrix::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        let (m, _) = effective_batch_scores_ragged(
            &[&a, &b, &c],
            &[0, 1],
            &[2, 2],
            Some(&[0.5, 1.0]),
        );
        // slot 0: positions weighted 1, 0.5, 0.25; slot 1: all 1.0
        assert_eq!(m.row(0), &[1.0, 1.0]);
        assert_eq!(m.row(1), &[0.5, 0.5]);
        assert_eq!(m.row(2), &[0.25, 0.25]);
        assert_eq!(m.row(3), &[1.0, 1.0]);
        assert_eq!(m.row(5), &[1.0, 1.0]);
        // prior 1.0 (or None) must be bit-identical to unweighted — the
        // uniform-depth byte-identity pins ride on this
        let (unweighted, _) =
            effective_batch_scores_ragged(&[&a, &b, &c], &[0, 1], &[2, 2], None);
        let (ones, _) = effective_batch_scores_ragged(
            &[&a, &b, &c],
            &[0, 1],
            &[2, 2],
            Some(&[1.0, 1.0]),
        );
        for i in 0..unweighted.n_tokens() {
            assert_eq!(unweighted.row(i), ones.row(i));
        }
    }

    #[test]
    fn uniform_wrapper_matches_ragged_full_depth() {
        let a = ScoreMatrix::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0]]);
        let b = ScoreMatrix::from_rows(&[vec![0.0, 1.0], vec![0.0, 2.0]]);
        let (m1, g1) = effective_batch_scores(&[&a, &b], &[0, 1]);
        let (m2, g2) =
            effective_batch_scores_ragged(&[&a, &b], &[0, 1], &[1, 1], None);
        assert_eq!(g1, g2);
        for i in 0..m1.n_tokens() {
            assert_eq!(m1.row(i), m2.row(i));
        }
    }

    #[test]
    fn lookup_draft_prefers_bigram_then_unigram() {
        // bigram (2,3) seen earlier → propose what followed it
        assert_eq!(lookup_draft(&[1, 2, 3, 9, 8, 2, 3], 3), vec![9, 8, 2]);
        // most recent bigram match wins
        assert_eq!(lookup_draft(&[2, 3, 7, 2, 3, 5, 2, 3], 2), vec![5, 2]);
        // no bigram match → unigram fallback
        assert_eq!(lookup_draft(&[4, 1, 6, 5, 1], 2), vec![6, 5]);
        // fixed point: the most recent (6,6) match sits one token from the
        // end, so one proposal survives the history clip — enough for the
        // verify to commit 2 tokens per cycle on a repeating tail
        assert_eq!(lookup_draft(&[9, 6, 6, 6], 3), vec![6]);
        // no match at all / short history / zero depth → empty
        assert!(lookup_draft(&[1, 2, 3], 0).is_empty());
        assert!(lookup_draft(&[7], 3).is_empty());
        assert!(lookup_draft(&[1, 2, 3, 4], 2).is_empty());
        // proposals are clipped at the history end (ragged by nature)
        assert_eq!(lookup_draft(&[5, 8, 5], 4), vec![8, 5]);
    }

    #[test]
    fn ngram_index_matches_linear_scan_on_fixtures() {
        // The same fixtures that pin lookup_draft, through the index.
        let cases: [(&[u32], usize); 8] = [
            (&[1, 2, 3, 9, 8, 2, 3], 3),
            (&[2, 3, 7, 2, 3, 5, 2, 3], 2),
            (&[4, 1, 6, 5, 1], 2),
            (&[9, 6, 6, 6], 3),
            (&[1, 2, 3], 0),
            (&[7], 3),
            (&[1, 2, 3, 4], 2),
            (&[5, 8, 5], 4),
        ];
        for (hist, depth) in cases {
            let mut idx = NgramIndex::default();
            for &t in hist {
                idx.push(t);
            }
            assert_eq!(
                idx.draft(depth),
                lookup_draft(hist, depth),
                "divergence on {hist:?} depth {depth}"
            );
        }
    }

    #[test]
    fn disabled_ngram_index_drops_pushes_and_state() {
        // Deployments without lookup drafting disable the index at
        // admission: pushes become no-ops and the accumulated state is
        // freed, so the commit path pays nothing.
        let mut idx = NgramIndex::default();
        idx.push(1);
        idx.push(2);
        assert_eq!(idx.len(), 2);
        idx.disable();
        assert!(idx.is_empty());
        idx.push(3);
        idx.push(3);
        assert!(idx.is_empty());
        assert!(idx.draft(4).is_empty());
    }

    #[test]
    fn prop_ngram_index_equals_linear_scan() {
        // For arbitrary token streams (tiny vocab → dense n-gram
        // collisions) the index proposes IDENTICAL drafts to the linear
        // scan at every prefix and every depth — the losslessness pin the
        // lookup-drafter swap rides on.
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            0x1D11,
            120,
            |r: &mut Rng| {
                let vocab = 2 + r.below(5) as u32;
                let len = r.below(48);
                let seed = r.next_u64();
                (vocab, len, seed)
            },
            |&(vocab, len, seed)| {
                let mut r = Rng::new(seed);
                let mut idx = NgramIndex::default();
                let mut hist: Vec<u32> = Vec::new();
                for _ in 0..len {
                    let tok = r.below(vocab as usize) as u32;
                    idx.push(tok);
                    hist.push(tok);
                    for depth in 0..5 {
                        let want = lookup_draft(&hist, depth);
                        let got = idx.draft(depth);
                        if got != want {
                            return Err(format!(
                                "index {got:?} != scan {want:?} on {hist:?} depth {depth}"
                            ));
                        }
                    }
                }
                if idx.history() != hist.as_slice() || idx.len() != hist.len() {
                    return Err("index history drifted from pushes".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn depth_controller_adapts_and_probes() {
        let mut c = SpecDepthController::new(4);
        // cold class: optimistic full depth, prior 1.0
        assert_eq!(c.depth_for("a"), 4);
        assert_eq!(c.prior("a"), 1.0);
        // strong acceptance keeps full depth
        for _ in 0..5 {
            c.observe("a", 4, 4);
        }
        assert_eq!(c.depth_for("a"), 4);
        assert!(c.prior("a") > 0.9);
        // zero acceptance collapses the class to 0 …
        for _ in 0..40 {
            c.observe("a", 4, 0);
        }
        assert_eq!(c.depth_for("a"), 0);
        // … but a probe at depth 1 fires every PROBE_INTERVAL cycles
        let mut saw_probe = false;
        for _ in 0..PROBE_INTERVAL {
            if c.depth_for("a") == 1 {
                saw_probe = true;
                break;
            }
        }
        assert!(saw_probe, "collapsed class never probed");
        // recovery: sustained acceptance grows depth back
        for _ in 0..60 {
            c.observe("a", 4, 4);
        }
        assert_eq!(c.depth_for("a"), 4);
        // middling acceptance lands between the extremes
        let mut m = SpecDepthController::new(4);
        for _ in 0..30 {
            m.observe("b", 4, 2);
        }
        let d = m.depth_for("b");
        assert!((1..4).contains(&d), "depth {d} for 50% acceptance");
        // classes are independent
        assert_eq!(m.depth_for("never-seen"), 4);
    }

    #[test]
    fn row_prior_blends_over_class_after_warmup() {
        let mut c = SpecDepthController::new(4);
        // strong class prior
        for _ in 0..30 {
            c.observe("a", 4, 4);
        }
        let class_prior = c.prior("a");
        assert!(class_prior > 0.9);
        // unknown row: class prior verbatim
        assert_eq!(c.row_prior(7, "a"), class_prior);
        // a zero-acceptance row stays on the class prior through warmup …
        for i in 0..SPEC_ROW_WARMUP {
            assert_eq!(
                c.row_prior(7, "a"),
                class_prior,
                "row blended before warmup (after {i} commits)"
            );
            c.observe_row(7, 4, 0);
        }
        // … then the 50/50 blend pulls its prior below the class's
        let blended = c.row_prior(7, "a");
        assert!(
            blended < class_prior && blended >= 0.5 * class_prior - 1e-6,
            "expected 50/50 blend, got {blended} vs class {class_prior}"
        );
        // rows are independent: another row of the class is untouched
        assert_eq!(c.row_prior(8, "a"), class_prior);
        // release drops the state; the id falls back to the class prior
        c.forget_row(7);
        assert_eq!(c.row_prior(7, "a"), class_prior);
        // zero-proposal cycles are not observations
        c.observe_row(9, 0, 0);
        assert_eq!(c.row_prior(9, "a"), class_prior);
    }

    #[test]
    fn charge_aware_depth_trades_value_against_marginal_cost() {
        let mut c = SpecDepthController::new(4);
        // cold class: optimistic full cap, regardless of prices
        assert_eq!(c.charge_aware_depth("a", 3, 1.0, |_| f64::MAX), 3);
        for _ in 0..30 {
            c.observe("a", 4, 2); // EMA → 0.5
        }
        let a = c.acceptance("a").unwrap();
        assert!((a - 0.5).abs() < 0.05);
        // memory-bound regime: marginal row nearly free → hold the cap,
        // deeper than the fixed-threshold controller would go
        // (0.5^3 < DEPTH_USEFULNESS=0.25 stops depth_for at 2)
        assert_eq!(c.charge_aware_depth("a", 4, 1.0, |_| 1e-6), 4);
        assert!(c.depth_for("a") < 4);
        // expensive marginal rows collapse the depth to 0 …
        assert_eq!(c.charge_aware_depth("a", 4, 1.0, |_| 10.0), 0);
        // mid prices land in between: a^1=0.5 > 0.2, a^2=0.25 > 0.2,
        // a^3=0.125 <= 0.2 → depth 2
        assert_eq!(c.charge_aware_depth("a", 4, 1.0, |_| 0.2), 2);
        // … and a collapsed class still probes at depth 1 eventually
        let mut saw_probe = false;
        for _ in 0..=PROBE_INTERVAL {
            if c.charge_aware_depth("a", 4, 1.0, |_| 10.0) == 1 {
                saw_probe = true;
                break;
            }
        }
        assert!(saw_probe, "collapsed class never probed under charge-aware depth");
    }

    #[test]
    fn effective_scores_group_per_slot() {
        let a = ScoreMatrix::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0], vec![3.0, 0.0]]);
        let b = ScoreMatrix::from_rows(&[vec![0.0, 1.0], vec![0.0, 2.0], vec![0.0, 3.0]]);
        let (m, groups) = effective_batch_scores(&[&a, &b], &[0, 2]);
        assert_eq!(m.n_tokens(), 4);
        assert_eq!(groups, vec![vec![0, 1], vec![2, 3]]);
        // slot 0: rows from a then b
        assert_eq!(m.row(0), &[1.0, 0.0]);
        assert_eq!(m.row(1), &[0.0, 1.0]);
        // slot 2
        assert_eq!(m.row(2), &[3.0, 0.0]);
        assert_eq!(m.row(3), &[0.0, 3.0]);
    }
}
