//! Speculative-decoding primitives: greedy acceptance and per-layer
//! effective-batch score assembly. The verify-cycle orchestration lives in
//! [`super::scheduler`]; the logic here is pure and unit-tested.

use crate::selection::ScoreMatrix;

/// Greedy acceptance: compare draft tokens against the target's argmax at
/// each position. Returns the committed tokens: the accepted prefix of the
/// drafts plus one bonus/correction token from the target.
///
/// `target_argmax[j]` = target's argmax after processing verify token j
/// (j=0 is the last committed token, j=1..=L_s are the drafts).
pub fn greedy_accept(drafts: &[u32], target_argmax: &[u32]) -> (usize, Vec<u32>) {
    assert_eq!(target_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut n_acc = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if target_argmax[j] == d {
            committed.push(d);
            n_acc += 1;
        } else {
            break;
        }
    }
    // bonus (all accepted) or correction (first mismatch) token
    committed.push(target_argmax[n_acc]);
    (n_acc, committed)
}

/// Assemble the effective-batch score matrix for one layer from the
/// per-sub-step padded matrices of the scoring pass.
///
/// `per_step[j]` is the padded `[B_max × N]` matrix of verify sub-step j;
/// `slots` are the live row indices. Output rows are ordered
/// (slot-major): request q's tokens occupy rows `q*(1+L_s) .. (q+1)*(1+L_s)`,
/// and the returned groups encode exactly that — the structure Algorithm 4
/// exploits.
pub fn effective_batch_scores(
    per_step: &[&ScoreMatrix],
    slots: &[usize],
) -> (ScoreMatrix, Vec<Vec<usize>>) {
    assert!(!per_step.is_empty());
    let n = per_step[0].n_experts();
    let steps = per_step.len();
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(slots.len() * steps);
    let mut groups = Vec::with_capacity(slots.len());
    for &slot in slots {
        let mut group = Vec::with_capacity(steps);
        for m in per_step {
            assert_eq!(m.n_experts(), n);
            group.push(rows.len());
            rows.push(m.row(slot).to_vec());
        }
        groups.push(group);
    }
    (ScoreMatrix::from_rows(&rows), groups)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_accepted_gets_bonus() {
        let (n, committed) = greedy_accept(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!(n, 3);
        assert_eq!(committed, vec![5, 6, 7, 8]);
    }

    #[test]
    fn first_mismatch_corrects_and_stops() {
        let (n, committed) = greedy_accept(&[5, 6, 7], &[5, 9, 7, 8]);
        assert_eq!(n, 1);
        assert_eq!(committed, vec![5, 9]);
    }

    #[test]
    fn immediate_mismatch_commits_one() {
        let (n, committed) = greedy_accept(&[5], &[4, 0]);
        assert_eq!(n, 0);
        assert_eq!(committed, vec![4]);
    }

    #[test]
    fn empty_drafts_commit_target_token() {
        let (n, committed) = greedy_accept(&[], &[3]);
        assert_eq!(n, 0);
        assert_eq!(committed, vec![3]);
    }

    #[test]
    fn effective_scores_group_per_slot() {
        let a = ScoreMatrix::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0], vec![3.0, 0.0]]);
        let b = ScoreMatrix::from_rows(&[vec![0.0, 1.0], vec![0.0, 2.0], vec![0.0, 3.0]]);
        let (m, groups) = effective_batch_scores(&[&a, &b], &[0, 2]);
        assert_eq!(m.n_tokens(), 4);
        assert_eq!(groups, vec![vec![0, 1], vec![2, 3]]);
        // slot 0: rows from a then b
        assert_eq!(m.row(0), &[1.0, 0.0]);
        assert_eq!(m.row(1), &[0.0, 1.0]);
        // slot 2
        assert_eq!(m.row(2), &[3.0, 0.0]);
        assert_eq!(m.row(3), &[0.0, 3.0]);
    }
}
