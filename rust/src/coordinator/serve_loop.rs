//! The incrementally-stepped serving core: continuous batching, per-row
//! phase machines, the ragged speculative verify cycle, per-layer expert
//! selection and cost accounting. This is the L3 "leader" loop —
//! everything on the request path runs here, in rust.
//!
//! Unlike the old monolithic `Scheduler::run`, the loop is **step-scoped**:
//! callers own the cadence. [`ServeLoop::submit`] enqueues a request at any
//! time; every [`ServeLoop::step`] first admits queued requests into free
//! batch slots and then runs one phase-partitioned execution cycle, so work
//! that arrives mid-flight joins the very next step instead of waiting for
//! the whole batch to drain. Finished sequences are surfaced in the
//! returned [`StepOutcome`] the moment their slot releases.
//! [`ServeLoop::drain`] (submit-all + step-until-done) reproduces the old
//! batch-at-a-time behaviour byte-for-byte — the `Scheduler` wrapper in
//! [`super::scheduler`] is exactly that.
//!
//! ## Per-row phase machines (PR 4)
//!
//! Every slot carries an explicit [`Phase`]: `PrefillChunk` (consuming its
//! prompt, one token or one chunk per step), `Decode`, or
//! `SpecVerify { depth }` for the duration of a verify cycle. One step
//! executes all phases side by side:
//!
//!  * chunk-eligible prefill rows advance through the prefill artifact;
//!  * the remaining rows ("riders") share one forward — a plain decode
//!    forward when no row speculates, or a **ragged verify** when any
//!    decoding row has draft depth > 0. Non-speculating riders (one-token
//!    prefill rows, decode rows at depth 0) ride the verify forward parked
//!    on their own (token, position) — the `catch_up` harmless-rewrite
//!    idiom generalized from chunk rows to every short row — and commit
//!    exactly one token from sub-step 0;
//!  * each row commits independently and flips phase on its own schedule.
//!
//! The old batch-global gate (`speculative = spec_len > 0 && prefill_rows
//! == 0`) is gone: a chunk-prefilling row no longer switches speculation
//! off for the whole batch, which under Poisson arrivals with long prompts
//! used to keep speculation off most of the time.
//! [`ServeLoop::set_legacy_spec_gate`] restores the old gate for benches
//! and byte-identity pins.
//!
//! ## Speculative verify emulation (DESIGN.md §4)
//!
//! The compiled decode-step artifact advances one token per row, so a
//! verify forward over B×(1+max_depth) tokens is emulated in two passes of
//! (1+max_depth) sub-steps each:
//!
//!  * **pass 1 (scoring)**: vanilla routing, records every layer's gate
//!    scores for all verify tokens — the effective-batch G^{(l)};
//!  * **selection**: the policy picks S_l once per layer from those scores
//!    with per-request grouping at each row's TRUE depth (rows beyond their
//!    depth contribute nothing to selection), and — under `--spec-adaptive`
//!    — each row's speculative positions weighted by its class's
//!    acceptance prior (Algorithm 4's input, ragged);
//!  * **pass 2 (restricted)**: re-runs the sub-steps with every layer
//!    restricted to S_l; its logits drive acceptance and its KV writes are
//!    the ones that persist. A rider parked beyond its depth re-feeds its
//!    own next (token, position), which rewrites byte-identical KV —
//!    verified by the kernel masking tests plus the depth-0 byte-identity
//!    pin in `rust/tests/spec_mixed_phase.rs`.
//!
//! The cost model charges ONE target forward over the **padded** ragged
//! batch (riders × (1 + max in-use depth) tokens — shrinking one row below
//! the max saves activation, not padding) plus draft cost from the TRUE
//! per-row depths ([`DecodeCostModel::draft_cost`]); the two passes are an
//! artifact of the one-token-per-row compilation, not of the system being
//! modeled.
//!
//! ## Adaptive depth & draft sources
//!
//! With `--spec-adaptive`, a per-traffic-class acceptance EMA
//! ([`SpecDepthController`], class keys shared with [`FootprintTracker`])
//! shrinks or grows each row's draft depth within `[0, spec_len]`, and the
//! class prior weights the row's speculative positions in selection. The
//! draft source is pluggable (`--spec-draft`): the dense draft model
//! (default), or n-gram lookup over the row's own history
//! ([`super::speculative::lookup_draft`]) which drafts for free.
//!
//! ## Chunked prefill (PR 2) and pluggable admission (PR 3)
//!
//! Unchanged in substance: chunk rows advance by up to a whole chunk per
//! step through the `prefill_attn_router` artifact while parked in the
//! shared forward; admission is decided by [`super::admission`], with
//! bounded-queue backpressure and typed [`SubmitError`]s.
//!
//! ## Expert-parallel serving (PR 5)
//!
//! With `cfg.ep` set, expert parallelism is a first-class deployment mode,
//! not a gauge: every forward — decode, ragged verify, chunk prefill —
//! charges through [`EpCostModel::layer_latency`] on the step's true
//! per-layer [`Placement::loads`] ([`ServeLoop::charge_step`]), so
//! sim-time, TTFT and OTPS feel the straggler GPU exactly as §5.1's
//! MaxLoad model says they should (draft forwards stay dense: the draft
//! model is replicated, not expert-sharded). Three schedulers ride that
//! signal:
//!
//!  * **footprint admission** (PR 3) weights overlap by marginal MaxLoad;
//!  * **eviction** (`--ep-evict`, [`super::eviction`]): a running row that
//!    fits the batch far worse than a queued candidate would is preempted
//!    back to the queue (≤ 1/step, ≤ `EVICTION_BUDGET`/request) and
//!    resumed losslessly by re-prefilling its committed history — the
//!    eviction/resume KV contract in `model/moe_model.rs`;
//!  * **rebalancing** (`--ep-rebalance N`): every N slot frees the tracked
//!    class mix's footprint weights drive a greedy LPT
//!    [`Placement::rebalance_from`]; the new placement is adopted only
//!    when its expected MaxLoad strictly improves.
//!
//! Metrics: per-GPU load histograms (`gpu_loads`), the straggler-exposure
//! integral `∫ MaxLoad dt` (`gpu_load_integral`), eviction counts and
//! per-rebalance deltas. `benches/serve_continuous.rs -- ep` asserts the
//! full stack beats static-placement FIFO on the integral at byte-equal
//! outputs.
//!
//! ## Expert replication & incremental migration (PR 6)
//!
//! [`Placement`] is a replica set now (see [`crate::ep`] for the full
//! contract), and placement change is physical. With
//! `--ep-migrate-budget B` the rebalance clock stops swapping the whole
//! assignment for free and instead adopts bounded migration plans
//! ([`crate::ep::plan_migration`]): ≤ B replica copies/drops per step,
//! residency per GPU capped by `--ep-replica-slack`, adopted only when the
//! expected straggler saving over an amortization horizon beats the
//! interconnect charge for the copied weights. That charge lands in a
//! backlog drained against subsequent step time
//! ([`ServeLoop::charge_step`]) — migration overlaps decode, a step at
//! most doubles. `--ep-prefetch` additionally runs the planner over the
//! QUEUED classes' predicted footprints each step, so replicas are
//! resident (and paid for) before the traffic that needs them admits.
//! All of it is cost-only: tokens and KV stay byte-identical to non-EP
//! runs (`rust/tests/ep_migrate.rs`).
//!
//! ## Shared-prefix KV cache (PR 7)
//!
//! With `--prefix-cache-mb` set, releasing rows (finish AND eviction)
//! offer their committed-prefix KV to a VRAM-budgeted LRU cache
//! ([`super::prefix_cache`]); an admission whose prompt extends a cached
//! entry restores the slab into its slot ([`MoeModel::restore_prefix`])
//! and chunk-prefills only the suffix — byte-identical to the cold path
//! by the cache-restore KV contract in `model/moe_model.rs`, pinned by
//! `rust/tests/prefix_cache.rs`. Footprint admission adds a bounded
//! warm-prefix bonus ([`super::admission::PREFIX_HIT_WEIGHT`]), and
//! eviction resume becomes a restore instead of a recompute whenever the
//! victim's offered slab is still resident.
//!
//! ## Fused prefill waves (PR 8)
//!
//! Chunk plans no longer charge per row: each [`ServeLoop::run_chunk_plans`]
//! round issues one prefill invocation per still-advancing plan and
//! charges the whole round ONCE — a single target forward over the
//! per-layer union of the rows' routed experts
//! ([`MoeModel::wave_union`]) and the round's total token count
//! ([`DecodeCostModel::prefill_wave`]; under EP one
//! [`EpCostModel::layer_latency`]-priced step on the unioned
//! [`Placement::loads`]). N co-prefilling rows thus share one amortized
//! per-layer weight stream, exactly the lever continuous batching gives
//! decode. Routing is untouched — tokens and `kv_row_digest` stay
//! byte-identical to the sequential chunk walk
//! ([`ServeLoop::set_sequential_prefill_charging`] restores the old
//! accounting for pins/benches; pinned across policies × chunk sizes ×
//! co-prefilling rows by `rust/tests/prefill_equivalence.rs`). Opt-in
//! `--chunk-shared-selection` additionally pools each chunk's
//! per-position router scores through the paper's modular greedy
//! objective ([`crate::selection::shared_chunk_set`]) so all positions
//! share one expert set per layer — lossy, so it ships with
//! fidelity-delta accounting
//! ([`ServeLoop::record_shared_selection_fidelity`], measured by the
//! harness through [`super::fidelity::compare`]) while the wave metrics
//! (`prefill_waves`, `prefill_streams_saved`, rows-per-wave,
//! prompt-tokens/s) report the amortization first-class.
//!
//! ## Unified cost ledger & charge-aware speculation (PR 10)
//!
//! Every simulated second flows through the [`crate::cost::Ledger`] this
//! loop owns: [`ServeLoop::charge_step`] / [`ServeLoop::charge_wave`]
//! assemble typed entries — decode, spec verify, spec draft, prefill
//! wave, migration drain — and post them. `Ledger::post` and
//! `Ledger::advance_to` are the ONLY writers to the sim clock;
//! `metrics.sim_seconds` and the `time_*_s` phase metrics are read-only
//! mirrors re-assigned after each post, and the migration backlog is
//! ledger state (a deferred charge drained per step as
//! `MigrationDrain` time). The cost models are pure pricers returning
//! [`crate::cost::Charge`] values. On top rides the charge-aware depth
//! controller (`--spec-charge-aware`, requires `--spec-adaptive`):
//! `Ledger::marginal_spec_cost` prices one more verify level under the
//! LAST step's geometry (dense activations or EP selected sets), and
//! `SpecDepthController::charge_aware_depth` keeps deepening while the
//! acceptance-weighted value of the extra committed token beats that
//! marginal charge — replacing the fixed usefulness threshold with the
//! padded-batch economics the roofline model actually exhibits. Depth
//! choice is scheduling-only, so outputs stay byte-identical
//! (`rust/tests/spec_mixed_phase.rs`); exact clock conservation and
//! refactor bit-identity are pinned in `rust/tests/cost_ledger.rs`.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use super::admission::{
    AdmissionContext, AdmissionKind, AdmissionQueue, FootprintTracker, SpecGrouping,
    SubmitError,
};
use super::batcher::Batcher;
use super::eviction;
use super::prefix_cache::PrefixCache;
use super::request::{Phase, Request};
use super::speculative::{effective_batch_scores_ragged, greedy_accept, SpecDepthController};
use crate::config::{ServeConfig, SpecDraft};
use crate::cost::{Entry as CostEntry, Ledger, Phase as CostPhase, SpecGeometry};
use crate::ep::{EpCostModel, Placement};
use crate::memsim::{CostGeometry, DecodeCostModel, HardwareProfile};
use crate::metrics::ServeMetrics;
use crate::model::{
    argmax, DraftRunner, MoeModel, PrefillInput, RoutingMode, StepInput,
};
use crate::selection::{
    admission_score, baselines::Vanilla, ExpertSet, ScoreMatrix, SelectionPolicy,
};

/// Result of one serving run (what `drain` + `report` produce).
#[derive(Debug)]
pub struct RunReport {
    pub metrics: ServeMetrics,
    /// request id → generated tokens.
    pub outputs: BTreeMap<u64, Vec<u32>>,
    /// request id → domain (for per-dataset reporting).
    pub domains: BTreeMap<u64, String>,
}

/// What one `step()` did — the server worker routes responses off this.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Request ids admitted into batch slots at the top of this step.
    pub admitted: Vec<u64>,
    /// Sequences that completed this step: (request id, generated tokens).
    pub finished: Vec<(u64, Vec<u32>)>,
    /// Live rows that were in prefill phase when the step ran.
    pub prefill_rows: usize,
    /// Live rows that were decoding (plain or spec-verify) this step.
    pub decode_rows: usize,
    /// GENERATED tokens committed across all rows this step. Prompt
    /// advances are counted in [`StepOutcome::prefill_tokens`], never here
    /// — the split keeps throughput honest on long prompts.
    pub committed: u64,
    /// Prompt tokens consumed this step (one-token prefill advances and
    /// chunked-prefill tokens alike).
    pub prefill_tokens: u64,
    /// Simulated cost of this step, seconds.
    pub sim_seconds: f64,
    /// Per-row phase report: (slot, request id, phase the row executed
    /// this step). Replaces the old batch-global `speculative` flag —
    /// phases are per row now; [`StepOutcome::speculative`] derives the
    /// old batch-level view.
    pub phases: Vec<(usize, u64, Phase)>,
    /// Generated tokens newly committed this step, per request id (a spec
    /// commit can carry several at once). Streaming responses are cut
    /// from exactly these.
    pub deltas: Vec<(u64, Vec<u32>)>,
    /// Requests still waiting in the admission queue after this step.
    pub queued: usize,
    /// Sequences still occupying batch slots after this step.
    pub running: usize,
    /// Request ids preempted back to the queue by footprint-aware slot
    /// eviction at the top of this step (at most one per step). The
    /// requests are still in flight — they resume from their committed
    /// history at a later admission; no reply is owed for them.
    pub evicted: Vec<u64>,
}

impl StepOutcome {
    /// Whether this step ran a speculative verify cycle (any row was in
    /// `SpecVerify` phase — including depth-0 riders of that cycle).
    pub fn speculative(&self) -> bool {
        self.phases.iter().any(|(_, _, p)| matches!(p, Phase::SpecVerify { .. }))
    }

    /// Per-row verify depth of `slot` this step, if it rode a verify.
    pub fn spec_depth_of(&self, slot: usize) -> Option<usize> {
        self.phases.iter().find_map(|&(s, _, p)| match p {
            Phase::SpecVerify { depth } if s == slot => Some(depth),
            _ => None,
        })
    }
}

/// Per-slot admission metadata, alive for the whole occupancy (not just
/// until the first token): TTFT/per-class-TTFT/deadline-miss accounting
/// fires once (`recorded` flips), but the original submission clock and
/// absolute deadline must survive until release so an eviction at ANY
/// point can requeue the request without resetting its SLO.
#[derive(Debug, Clone, Copy)]
struct PendingTtft {
    submit_sim: f64,
    class: u32,
    deadline_sim: Option<f64>,
    /// First-token latency already recorded (resumed rows start true).
    recorded: bool,
}

/// What the step-body helpers report upward: finished sequences, slots
/// that committed their first generated token, per-request token deltas.
#[derive(Debug, Default)]
struct StepEvents {
    finished: Vec<(u64, Vec<u32>)>,
    first_token_slots: Vec<usize>,
    deltas: Vec<(u64, Vec<u32>)>,
}

impl StepEvents {
    fn absorb(&mut self, other: StepEvents) {
        self.finished.extend(other.finished);
        self.first_token_slots.extend(other.first_token_slots);
        self.deltas.extend(other.deltas);
    }
}

/// One decoding row's speculation plan for the current step.
struct SpecPlan {
    slot: usize,
    /// True draft depth this cycle (≤ spec_len; lookup drafts may come up
    /// short of the controller's depth).
    depth: usize,
    /// Lookup-drafted proposals (model drafts are generated in-cycle).
    proposals: Vec<u32>,
    /// Traffic class (acceptance EMA key).
    class: String,
    /// Acceptance prior weighting this row's speculative positions.
    prior: f32,
}

/// The stepped serving core. Owns the model borrow, selection policy, cost
/// models, admission queue, batcher, draft state and metrics for one
/// serving lifetime.
pub struct ServeLoop<'m> {
    model: &'m mut MoeModel,
    cfg: ServeConfig,
    policy: Box<dyn SelectionPolicy>,
    /// The unified cost ledger — the ONLY writer to the sim clock (the
    /// single-writer contract in `cost/mod.rs`). Owns the pure pricers
    /// ([`DecodeCostModel`], [`EpCostModel`]), the per-phase second
    /// attribution and the deferred migration backlog.
    /// `metrics.sim_seconds` is a read-only mirror of `ledger.clock()`,
    /// re-assigned after every post.
    ledger: Ledger,
    batcher: Batcher,
    /// Bounded admission queue + pluggable policy (see
    /// [`super::admission`]).
    queue: AdmissionQueue,
    /// Observed-router-score footprints (FootprintAware admission only).
    tracker: Option<FootprintTracker>,
    metrics: ServeMetrics,
    outputs: BTreeMap<u64, Vec<u32>>,
    domains: BTreeMap<u64, String>,
    /// Dense draft model state (spec_draft = model only; lookup drafts
    /// need no model, no cache and no shadow steps).
    draft: Option<DraftRunner>,
    /// Per-class acceptance EMAs driving adaptive depth (spec runs only).
    depth_ctl: SpecDepthController,
    /// Restore the pre-PR4 batch-global gate (speculate only when no
    /// prefill row is live, uniform depth). Bench/pin instrumentation.
    legacy_spec_gate: bool,
    /// Pin every row's draft depth (bench/pin instrumentation).
    forced_depth: Option<usize>,
    /// Per-slot TTFT/deadline state, pending until the first token commits.
    ttft_pending: Vec<Option<PendingTtft>>,
    /// Slot releases since the last adopted (or attempted) placement
    /// rebalance — the `--ep-rebalance N` clock.
    frees_since_rebalance: u64,
    /// Geometry of the last charged decode/verify forward — what the
    /// charge-aware depth controller (`--spec-charge-aware`) prices
    /// marginal speculation against. `None` until the first shared
    /// forward charges (cold classes fall back to the fixed threshold).
    last_geometry: Option<SpecGeometry>,
    /// Shared-prefix KV cache (`--prefix-cache-mb`, see
    /// [`super::prefix_cache`]): releasing rows offer their committed
    /// prefix, admissions whose prompt extends a cached entry restore the
    /// slab and chunk-prefill only the suffix. Disabled (zero-budget) by
    /// default.
    prefix_cache: PrefixCache,
    /// Charge chunk-prefill invocations individually instead of fusing
    /// each round of co-prefilling rows into one wave charge (restores
    /// the pre-PR8 cost accounting). Bench/pin instrumentation — tokens
    /// and KV are identical either way; only the charge differs.
    sequential_prefill_charging: bool,
    started: Instant,
}

/// Amortization horizon for adopting a migration plan: an expected-MaxLoad
/// drop of Δ experts saves ~`Δ × expert_load_s` per layer forward, and the
/// plan is adopted only when that saving over this many layer forwards
/// exceeds the plan's interconnect charge. 256 layer forwards ≈ a handful
/// of decode steps on the full-scale geometries — skew shorter-lived than
/// that is not worth moving weights for.
const MIGRATION_HORIZON_LAYER_FORWARDS: f64 = 256.0;

impl<'m> ServeLoop<'m> {
    pub fn new(model: &'m mut MoeModel, cfg: ServeConfig) -> Result<ServeLoop<'m>> {
        if cfg.prefill_chunk > 1 {
            if !model.has_prefill() {
                anyhow::bail!(
                    "prefill_chunk={} needs the chunked-prefill artifact, which preset \
                     '{}' does not ship — rebuild with `make artifacts` or use \
                     prefill_chunk=1",
                    cfg.prefill_chunk,
                    model.dims().name
                );
            }
            if cfg.prefill_chunk > model.dims().max_seq {
                anyhow::bail!(
                    "prefill_chunk={} exceeds the compiled sequence length {}",
                    cfg.prefill_chunk,
                    model.dims().max_seq
                );
            }
        }
        let cost = DecodeCostModel::new(
            HardwareProfile::by_name(&cfg.hardware)?,
            CostGeometry::for_preset(&cfg.preset)?,
        );
        let policy = cfg.policy.build();
        // `model.placement` is (re)established in `reset()` below — which
        // also CLEARS it when this config is not EP, so a loop built over
        // a model that previously served expert-parallel cannot silently
        // keep charging EP costs.
        let mut sl = ServeLoop {
            model,
            cfg,
            policy,
            ledger: Ledger::new(cost, EpCostModel::default()),
            batcher: Batcher::new(1, 1),
            queue: AdmissionQueue::new(AdmissionKind::Fifo, 0),
            tracker: None,
            metrics: ServeMetrics::new(0),
            outputs: BTreeMap::new(),
            domains: BTreeMap::new(),
            draft: None,
            depth_ctl: SpecDepthController::new(0),
            legacy_spec_gate: false,
            forced_depth: None,
            ttft_pending: Vec::new(),
            frees_since_rebalance: 0,
            last_geometry: None,
            prefix_cache: PrefixCache::new(0, 1),
            sequential_prefill_charging: false,
            started: Instant::now(),
        };
        sl.reset()?;
        Ok(sl)
    }

    /// Forget all serving state (queue, batcher, metrics, caches, draft)
    /// and start a fresh run. Queued-but-unserved requests are dropped.
    pub fn reset(&mut self) -> Result<()> {
        let b_max = self.model.max_batch();
        self.batcher = Batcher::new(b_max, self.cfg.batch_size.min(b_max));
        self.queue = AdmissionQueue::new(self.cfg.admission, self.cfg.max_queue);
        self.tracker = (self.cfg.admission == AdmissionKind::FootprintAware).then(|| {
            FootprintTracker::new(self.model.dims().n_experts, b_max)
                .with_decay(self.cfg.footprint_decay)
        });
        self.frees_since_rebalance = 0;
        self.ledger.reset();
        self.last_geometry = None;
        self.prefix_cache = PrefixCache::new(
            self.cfg.prefix_cache_mb * 1024 * 1024,
            self.cfg.prefix_min_tokens,
        );
        self.metrics = ServeMetrics::new(self.model.dims().n_layers);
        self.outputs.clear();
        self.domains.clear();
        self.ttft_pending = vec![None; b_max];
        // Restore the CONFIGURED placement — or clear it. `--ep-rebalance`
        // mutates the placement during serving, so a fresh run must start
        // from the static layout again; and a non-EP config must not
        // inherit a placement left on the model by an earlier EP serving
        // lifetime (which would silently re-enable EP cost charging).
        self.model.placement = self.cfg.ep.as_ref().map(|ep| {
            Placement::new(self.model.dims().n_experts, ep.n_gpus, ep.placement)
        });
        self.model.reset();
        self.draft = if self.cfg.spec_len > 0 && self.cfg.spec_draft == SpecDraft::Model {
            Some(DraftRunner::new(
                crate::model::DraftModel::new(self.model.engine())?,
                b_max,
            ))
        } else {
            None
        };
        self.depth_ctl = SpecDepthController::new(self.cfg.spec_len);
        self.started = Instant::now();
        Ok(())
    }

    /// Restore the pre-PR4 batch-global speculation gate: verify cycles
    /// only when NO prefill row is live. Instrumentation for benches
    /// (quantifying the mixed-phase win) and byte-identity pins; never set
    /// on the serving path.
    pub fn set_legacy_spec_gate(&mut self, on: bool) {
        self.legacy_spec_gate = on;
    }

    /// Restore the pre-PR8 per-invocation prefill charging: every chunk
    /// invocation pays its own full per-layer weight stream instead of
    /// the round's rows sharing one fused wave charge. Instrumentation
    /// for benches and byte-identity pins
    /// (`rust/tests/prefill_equivalence.rs`) — routing, tokens and KV are
    /// unaffected; only cost accounting moves.
    pub fn set_sequential_prefill_charging(&mut self, on: bool) {
        self.sequential_prefill_charging = on;
    }

    /// Attach a measured shared-selection fidelity sample (`token_match`
    /// from [`super::fidelity::compare`] of a `--chunk-shared-selection`
    /// run against its exact-routing baseline). The loop cannot compute
    /// this itself — it would need a second, baseline run of the same
    /// trace — so the harness that ran both (bench scenario, tests, CLI
    /// A/B) reports the delta here and it lands in `to_json` as
    /// `shared_selection_fidelity` / `shared_selection_drop_pts`, never
    /// silently.
    pub fn record_shared_selection_fidelity(&mut self, token_match: f64) {
        self.metrics.record_shared_selection_fidelity(token_match);
    }

    /// Pin every decoding row's draft depth (clamped to `[0, spec_len]`),
    /// overriding the adaptive controller. `None` restores normal depth
    /// assignment. Instrumentation for tests/benches (e.g. the
    /// depth-0-everywhere ≡ non-speculative byte-identity pin).
    ///
    /// Under `spec_draft = lookup` the pin is a CEILING, not a guarantee:
    /// a lookup draft proposes at most what the row's history matches, so
    /// a non-repetitive row may still ride at a lower (even zero) depth.
    pub fn force_spec_depth(&mut self, depth: Option<usize>) {
        self.forced_depth = depth.map(|d| d.min(self.cfg.spec_len));
    }

    /// Enqueue a request. It joins the next `step()` if a slot is free.
    ///
    /// Rejections are typed and immediate: a full bounded queue returns
    /// [`SubmitError::QueueFull`] (backpressure — the TCP worker surfaces
    /// it as a protocol error carrying the request id), and requests that
    /// could never be served (empty prompt, prompt beyond the compiled
    /// sequence length) are refused here instead of poisoning the batch
    /// mid-step.
    pub fn submit(&mut self, req: Request) -> std::result::Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt { id: req.id });
        }
        let max_seq = self.model.dims().max_seq;
        // The full request must fit the KV window: positions ≥ max_seq
        // silently drop their cache writes, so a request whose generation
        // budget overruns the window would decode garbage mid-flight. The
        // last generated token is committed without being fed back, so the
        // highest position a request touches is prompt + budget − 2 —
        // hence the `max_seq + 1` bound.
        if req.prompt.len() + req.max_new_tokens > max_seq + 1 {
            return Err(SubmitError::PromptTooLong {
                id: req.id,
                len: req.prompt.len(),
                budget: req.max_new_tokens,
                max_seq,
            });
        }
        let id = req.id;
        let domain = req.domain.clone();
        match self.queue.submit(req, self.ledger.clock()) {
            Ok(()) => {
                self.domains.insert(id, domain);
                Ok(())
            }
            Err(e) => {
                self.metrics.queue_rejected += 1;
                Err(e)
            }
        }
    }

    /// Re-enter a failed-over request with its ORIGIN submission clock:
    /// the fleet feeds a dead replica's in-flight rows back through here
    /// with their committed history as `resume_prefix` (the eviction
    /// lossless-resume shape) and the submit/deadline anchors of the
    /// original submission, so TTFT and deadline accounting stay pinned to
    /// when the client actually submitted — not to the failover instant.
    /// Same serve-ability validation as [`ServeLoop::submit`]; bypasses
    /// queue backpressure exactly like eviction requeue does (the request
    /// was already admitted once — bouncing it now would drop accepted
    /// work).
    pub fn resubmit(
        &mut self,
        req: Request,
        submit_sim: f64,
        deadline_sim: Option<f64>,
    ) -> std::result::Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt { id: req.id });
        }
        let max_seq = self.model.dims().max_seq;
        if req.prompt.len() + req.max_new_tokens > max_seq + 1 {
            return Err(SubmitError::PromptTooLong {
                id: req.id,
                len: req.prompt.len(),
                budget: req.max_new_tokens,
                max_seq,
            });
        }
        let id = req.id;
        let domain = req.domain.clone();
        self.queue.requeue(req, submit_sim, deadline_sim, self.ledger.clock());
        self.domains.insert(id, domain);
        Ok(())
    }

    /// Advance an IDLE loop's sim clock to fleet time `t` (no-op when work
    /// is live or `t` is in the past). The fleet driver calls this after
    /// every `run_until` wave so an idle replica's clock tracks fleet time
    /// — otherwise a request landing on a long-idle replica would anchor
    /// its TTFT/deadline clocks in that replica's past and report negative
    /// waits relative to the fleet.
    pub fn advance_idle_to(&mut self, t: f64) {
        if !self.has_work() && t > self.ledger.clock() {
            // Idle gaps are ledger time too: attributed to Overhead, the
            // mirror re-assigned like after any other clock write.
            self.ledger.advance_to(t);
            self.mirror_ledger();
        }
    }

    /// Queued or running work remains.
    pub fn has_work(&self) -> bool {
        self.batcher.running() > 0 || !self.queue.is_empty()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.batcher.running()
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// The unified cost ledger (read-only): the authoritative sim clock,
    /// per-phase second attribution and migration backlog.
    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// One serving step: admit newly queued requests into free slots, then
    /// run one phase-partitioned execution cycle over the live rows.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let wall0 = Instant::now();
        let sim_before = self.ledger.clock();
        let was_running = self.batcher.running() > 0;

        // EP serving levers, before admission sees the queue: rebalance
        // the placement on the frees clock, prefetch replicas for the
        // traffic about to admit, then preempt a far-worse-fitting row so
        // this step's admission can hand its slot to the better-fitting
        // queued request.
        self.maybe_rebalance();
        self.maybe_prefetch();
        let evicted = self.maybe_evict(sim_before);

        let admitted = self.admit(sim_before, was_running);
        self.metrics.queue_depth.add(self.queue.len() as f64);

        let slots = self.batcher.live_slots();
        if slots.is_empty() {
            return Ok(StepOutcome {
                admitted,
                evicted,
                queued: self.queue.len(),
                ..StepOutcome::default()
            });
        }

        let prefill_rows =
            slots.iter().filter(|&&s| self.batcher.seq(s).phase.is_prefill()).count();
        let decode_rows = slots.len() - prefill_rows;
        let committed_before = self.metrics.tokens_out;
        let prompt_before = self.metrics.tokens_prompt;

        // ---- speculation planning (per-row phase machines) --------------
        // A verify cycle runs whenever any decoding row has draft depth > 0
        // — prefill rows no longer gate it (unless the legacy gate is
        // pinned on for a baseline run).
        let gate_blocks = self.legacy_spec_gate && prefill_rows > 0;
        let spec_plans = if self.cfg.spec_len > 0 && decode_rows > 0 && !gate_blocks {
            self.plan_spec(&slots)
        } else {
            Vec::new()
        };
        let run_spec = spec_plans.iter().any(|p| p.depth > 0);
        if self.cfg.spec_len > 0 && decode_rows > 0 && !run_spec {
            // Speculation was desired (spec configured, decode rows live)
            // but unavailable this step: the legacy gate stalled it, or
            // every row's depth collapsed to 0.
            self.metrics.spec_stalled_steps += 1;
        }

        // Phase snapshot BEFORE execution mutates row state: chunk/prefill
        // rows report PrefillChunk, verify riders their per-row depth.
        let mut phases = Vec::with_capacity(slots.len());
        for &s in &slots {
            let seq = self.batcher.seq(s);
            let phase = if seq.phase.is_prefill() {
                Phase::PrefillChunk
            } else if run_spec {
                let depth = spec_plans
                    .iter()
                    .find(|p| p.slot == s)
                    .map(|p| p.depth)
                    .unwrap_or(0);
                Phase::SpecVerify { depth }
            } else {
                Phase::Decode
            };
            phases.push((s, seq.req.id, phase));
        }

        let events = if run_spec {
            self.spec_mixed_step(&slots, spec_plans)?
        } else {
            self.serve_step(&slots)?
        };
        let prefill_tokens = self.metrics.tokens_prompt - prompt_before;
        if prefill_tokens > 0 {
            self.metrics.prefill_tokens_per_step.add(prefill_tokens as f64);
        }

        // Sim clock has advanced by this step's cost; TTFT counts it. The
        // slot metadata stays in place after recording — a later eviction
        // still needs the submission clock and deadline.
        let now = self.ledger.clock();
        for s in events.first_token_slots {
            let first = match self.ttft_pending[s].as_mut() {
                Some(p) if !p.recorded => {
                    p.recorded = true;
                    Some((p.submit_sim, p.class, p.deadline_sim))
                }
                _ => None,
            };
            if let Some((submit_sim, class, deadline_sim)) = first {
                let missed = deadline_sim.map(|d| now > d);
                self.metrics.record_ttft(now - submit_sim, class, missed);
            }
        }
        for (id, tokens) in &events.finished {
            self.outputs.insert(*id, tokens.clone());
        }
        self.metrics.requests_done = self.outputs.len() as u64;
        self.metrics.wall_step_latency.record_seconds(wall0.elapsed().as_secs_f64());

        Ok(StepOutcome {
            admitted,
            finished: events.finished,
            prefill_rows,
            decode_rows,
            committed: self.metrics.tokens_out - committed_before,
            prefill_tokens,
            sim_seconds: self.ledger.clock() - sim_before,
            phases,
            deltas: events.deltas,
            queued: self.queue.len(),
            running: self.batcher.running(),
            evicted,
        })
    }

    /// Adopt a placement change when the `--ep-rebalance` frees clock has
    /// fired and the tracked mix says it would strictly lower expected
    /// MaxLoad. The mix weights are the running rows' footprints plus the
    /// class predictions of everything queued — the traffic the placement
    /// is about to serve. With `--ep-migrate-budget 0` (default) this is
    /// the legacy free instantaneous LPT swap
    /// ([`Placement::rebalance_from`]); with a budget it becomes a bounded,
    /// interconnect-charged replica migration
    /// ([`ServeLoop::adopt_migration`]). Candidates that do not improve
    /// are discarded (and not counted): both planners are heuristics, and
    /// a placement change must never make the straggler worse on its own
    /// inputs.
    fn maybe_rebalance(&mut self) {
        let every = self.cfg.ep_rebalance as u64;
        if every == 0 || self.frees_since_rebalance < every {
            return;
        }
        let Some(weights) = self.tracked_mix_weights(true) else {
            return; // keep the clock armed until the tracker warms up
        };
        self.frees_since_rebalance = 0;
        if self.cfg.ep_migrate_budget > 0 {
            // incremental mode: a bounded, interconnect-charged replica
            // plan instead of the free whole-placement swap
            self.adopt_migration(&weights, false);
            return;
        }
        let Some(pl) = self.model.placement.as_ref() else { return };
        let before = pl.expected_max_load(&weights);
        let candidate = pl.rebalance_from(&weights);
        let after = candidate.expected_max_load(&weights);
        if after < before - 1e-9 {
            self.metrics.rebalances += 1;
            self.metrics.rebalance_delta.add(before - after);
            self.model.placement = Some(candidate);
        }
    }

    /// The tracked traffic mix as per-expert weights: the running rows'
    /// informative footprints plus (when `include_running` is false, ONLY)
    /// the class predictions of everything queued. `None` until the tracker
    /// has seen something — or when this loop is not EP / not
    /// footprint-tracked at all.
    fn tracked_mix_weights(&self, include_running: bool) -> Option<Vec<f32>> {
        let tr = self.tracker.as_ref()?;
        let pl = self.model.placement.as_ref()?;
        let mut weights = vec![0.0f32; pl.n_experts()];
        let mut any = false;
        if include_running {
            for s in self.batcher.live_slots() {
                if let Some(fp) = tr.slot_footprint(s) {
                    if fp.is_informative() {
                        for (acc, &w) in weights.iter_mut().zip(fp.weights()) {
                            *acc += w;
                        }
                        any = true;
                    }
                }
            }
        }
        for e in self.queue.entries() {
            if let Some(fp) = tr.predict(&e.req) {
                for (acc, &w) in weights.iter_mut().zip(fp.weights()) {
                    *acc += w;
                }
                any = true;
            }
        }
        any.then_some(weights)
    }

    /// Footprint-driven replica prefetch (`--ep-prefetch`): when requests
    /// are queued and their classes have known footprints, run the
    /// migration planner over the QUEUED mix alone, so replicas for the
    /// experts that traffic is about to hit are resident — and their
    /// interconnect charge underway — before the requests admit. Rides the
    /// same budget/cap/adoption gate as rebalance-driven migration; a
    /// placement already serving the predicted mix well plans nothing and
    /// the call is free.
    fn maybe_prefetch(&mut self) {
        if !self.cfg.ep_prefetch || self.queue.is_empty() {
            return;
        }
        let Some(weights) = self.tracked_mix_weights(false) else { return };
        self.adopt_migration(&weights, true);
    }

    /// Plan a bounded migration toward `weights` and adopt it iff the
    /// expected straggler saving over [`MIGRATION_HORIZON_LAYER_FORWARDS`]
    /// beats the interconnect charge for the copies. Adopted plans update
    /// the live placement immediately (routing may use the new replicas at
    /// once) while their transfer seconds join the ledger's migration
    /// backlog ([`Ledger::defer_migration`]), to be drained against
    /// subsequent step time in [`ServeLoop::charge_step`].
    fn adopt_migration(&mut self, weights: &[f32], prefetch: bool) -> bool {
        let Some(pl) = self.model.placement.as_ref() else { return false };
        let cap = Placement::residency_cap(
            pl.n_experts(),
            pl.n_gpus(),
            self.cfg.ep_replica_slack,
        );
        let Some(plan) =
            crate::ep::plan_migration(pl, weights, self.cfg.ep_migrate_budget, cap)
        else {
            return false;
        };
        let migrate_s = self.ledger.ep_pricer().migration_seconds(plan.copies);
        let benefit_s = (plan.expected_before - plan.expected_after)
            * self.ledger.ep_pricer().expert_load_s
            * MIGRATION_HORIZON_LAYER_FORWARDS;
        if benefit_s <= migrate_s {
            return false; // skew too small / too brief to pay the transfer
        }
        self.metrics.migrations += 1;
        self.metrics.migration_ops.add(plan.ops.len() as f64);
        self.metrics.migration_bytes +=
            plan.copies as f64 * self.ledger.ep_pricer().expert_bytes;
        self.metrics.rebalance_delta.add(plan.expected_before - plan.expected_after);
        if prefetch {
            self.metrics.prefetches += 1;
        }
        self.ledger.defer_migration(migrate_s);
        self.model.placement = Some(plan.placement);
        true
    }

    /// Footprint-aware slot eviction (`--ep-evict`): at most one row per
    /// step, only when the batch is full and the queue non-empty, decided
    /// by [`eviction::plan_eviction`]. The victim is requeued with its
    /// committed history as prompt (lossless resume — see the module docs
    /// and `model/moe_model.rs`), keeping its submission clock and
    /// absolute deadline. Returns the evicted request ids (0 or 1).
    fn maybe_evict(&mut self, now_sim: f64) -> Vec<u64> {
        if !self.cfg.ep_evict || self.queue.is_empty() || self.batcher.has_capacity() {
            return Vec::new();
        }
        let victim = {
            let Some(tr) = &self.tracker else { return Vec::new() };
            let running: Vec<(usize, &super::request::SeqState)> = self
                .batcher
                .live_slots()
                .into_iter()
                .map(|s| (s, self.batcher.seq(s)))
                .collect();
            let candidates: Vec<&Request> =
                self.queue.entries().map(|e| &e.req).collect();
            let Some(plan) = eviction::plan_eviction(
                tr,
                &candidates,
                &running,
                self.model.placement.as_ref(),
                self.model.dims().top_k,
            ) else {
                return Vec::new();
            };
            plan.victim_slot
        };
        vec![self.preempt(victim, now_sim)]
    }

    /// Preempt the sequence in `victim` back to the queue (the eviction
    /// tail shared by the planner path and the test hook). The original
    /// submission clock and absolute deadline survive the preemption —
    /// the slot metadata is kept for a row's whole occupancy, so this
    /// holds whether or not its first token has committed.
    fn preempt(&mut self, victim: usize, now_sim: f64) -> u64 {
        // Offer the victim's committed-history KV to the prefix cache
        // BEFORE the slot releases: its requeued prompt IS that history, so
        // the resume admission can restore the slab instead of recomputing.
        self.offer_to_cache(victim);
        let pending = self.ttft_pending[victim].take();
        let seq = self.release_slot(victim);
        let id = seq.req.id;
        let (submit_sim, deadline_sim) = match pending {
            Some(p) => (p.submit_sim, p.deadline_sim),
            None => (now_sim, None), // unreachable: admission always sets it
        };
        let req = eviction::requeue_request(seq);
        self.queue.requeue(req, submit_sim, deadline_sim, now_sim);
        self.metrics.evictions += 1;
        id
    }

    /// Forcibly preempt the sequence in `slot`, bypassing the footprint
    /// planner (no margin, no budget, no tracker required). Instrumentation
    /// for tests/benches pinning the eviction/resume contract on a chosen
    /// row at a chosen moment; never called on the serving path. Must be
    /// invoked between steps (every row is in a stable Decode/Prefill
    /// phase then). Returns the evicted request id, or `None` if the slot
    /// is empty.
    pub fn evict_slot(&mut self, slot: usize) -> Option<u64> {
        self.batcher.get(slot)?;
        debug_assert!(
            self.batcher.seq(slot).spec_depth().is_none(),
            "evict_slot mid verify cycle"
        );
        let now = self.ledger.clock();
        Some(self.preempt(slot, now))
    }

    /// Per-row draft depth assignment for this step's decoding rows:
    /// forced depth (instrumentation) > adaptive per-class depth >
    /// uniform `spec_len`. Under `--spec-adaptive` the depth is also
    /// capped at `remaining − 1` (drafting past a row's budget is pure
    /// waste); the non-adaptive path keeps the legacy uncapped behaviour
    /// byte-for-byte. Lookup drafts are generated here (they are free and
    /// determine the row's true depth); model drafts run in-cycle.
    fn plan_spec(&mut self, slots: &[usize]) -> Vec<SpecPlan> {
        let mut plans = Vec::new();
        // One controller consultation per CLASS per step: rows of the same
        // class share a depth, and the probe clock ticks per verify cycle,
        // not per live row.
        let mut class_depths: BTreeMap<String, usize> = BTreeMap::new();
        for &s in slots {
            let (class, req_id, remaining) = {
                let seq = self.batcher.seq(s);
                if seq.phase != Phase::Decode {
                    continue;
                }
                (
                    FootprintTracker::class_key(&seq.req),
                    seq.req.id,
                    seq.remaining(),
                )
            };
            let mut depth = match self.forced_depth {
                Some(d) => d,
                None if self.cfg.spec_adaptive => match class_depths.get(&class).copied() {
                    Some(d) => d,
                    None => {
                        let d = self.class_depth(&class);
                        class_depths.insert(class.clone(), d);
                        d
                    }
                },
                None => self.cfg.spec_len,
            };
            depth = depth.min(self.cfg.spec_len);
            if self.forced_depth.is_none() && self.cfg.spec_adaptive {
                depth = depth.min(remaining.saturating_sub(1));
            }
            let proposals = match self.cfg.spec_draft {
                SpecDraft::Model => Vec::new(),
                SpecDraft::Lookup => {
                    // The row's NgramIndex already covers its committed
                    // history (consumed prompt + generated, maintained on
                    // every advance/commit) — an O(log n) query instead of
                    // the old per-cycle linear rescan, proposal-identical
                    // to `lookup_draft` by the equivalence property in
                    // `speculative.rs`.
                    let seq = self.batcher.seq(s);
                    debug_assert_eq!(
                        seq.ngram.len(),
                        seq.prompt_idx + seq.generated.len()
                    );
                    debug_assert_eq!(
                        seq.ngram.history().last().copied(),
                        Some(seq.next_token)
                    );
                    let p = seq.ngram.draft(depth);
                    depth = p.len(); // ragged: the lookup may come up short
                    p
                }
            };
            let prior = if self.cfg.spec_adaptive {
                // Row-blended prior (PR 10 satellite): once this row has
                // survived enough verify cycles, its own acceptance EMA
                // blends over the class prior.
                self.depth_ctl.row_prior(req_id, &class)
            } else {
                1.0
            };
            plans.push(SpecPlan { slot: s, depth, proposals, class, prior });
        }
        plans
    }

    /// Consult the depth controller once for `class`: the fixed
    /// usefulness threshold by default, or — under `--spec-charge-aware`
    /// with a warm step geometry — the largest depth whose
    /// acceptance-weighted expected commit gain beats the ledger's
    /// marginal charge for one more verify level under the CURRENT
    /// batch. A committed token's value is the plain per-token step cost
    /// (what a depth-d acceptance saves versus decoding it in its own
    /// step); cold classes and cold geometry fall back to the
    /// fixed-threshold path.
    fn class_depth(&mut self, class: &str) -> usize {
        if self.cfg.spec_charge_aware {
            if let Some(geo) = self.last_geometry.clone() {
                let placement = self.model.placement.as_ref();
                let plain = self.ledger.plain_step_cost(&geo, placement);
                let token_value = if geo.riders > 0 {
                    plain / geo.riders as f64
                } else {
                    0.0
                };
                let ledger = &self.ledger;
                return self.depth_ctl.charge_aware_depth(
                    class,
                    self.cfg.spec_len,
                    token_value,
                    |d| ledger.marginal_spec_cost(d, &geo, placement),
                );
            }
        }
        self.depth_ctl.depth_for(class)
    }

    /// Fill free batch slots from the admission queue, one policy pick at a
    /// time. Each pick sees the rows admitted before it in the same step
    /// (their footprints are seeded from class profiles at admission), so
    /// FootprintAware co-scheduling can assemble a correlated batch from a
    /// deep queue rather than only reacting to long-running rows.
    fn admit(&mut self, now_sim: f64, was_running: bool) -> Vec<u64> {
        let mut admitted = Vec::new();
        let top_k = self.model.dims().top_k;
        // Spec-grouping refinement (adaptive speculation only): footprint
        // admission sees the running rows' traffic classes and the shared
        // acceptance EMAs, and prefers co-admitting classes with similar
        // priors so ragged verifies stay dense.
        let spec_grouping =
            self.cfg.spec_adaptive && self.cfg.spec_len > 0 && self.tracker.is_some();
        while self.batcher.has_capacity() && !self.queue.is_empty() {
            let running_slots = self.batcher.live_slots();
            let running_classes: Vec<String> = if spec_grouping {
                running_slots
                    .iter()
                    .map(|&s| FootprintTracker::class_key(&self.batcher.seq(s).req))
                    .collect()
            } else {
                Vec::new()
            };
            let ctx = AdmissionContext {
                now_sim,
                tracker: self.tracker.as_ref(),
                running_slots: &running_slots,
                placement: self.model.placement.as_ref(),
                top_k,
                spec: spec_grouping.then(|| SpecGrouping {
                    ctl: &self.depth_ctl,
                    running_classes: &running_classes,
                }),
                prefix: self.prefix_cache.enabled().then_some(&self.prefix_cache),
            };
            let Some(entry) = self.queue.pop_next(&ctx) else { break };
            // Footprint-overlap gauge: what the greedy objective predicted
            // for the admitted candidate against the batch it joins. This
            // re-scores the winner (the policy's internal scores stay
            // internal); the cost is one overlap per ADMISSION — noise next
            // to the model forward each step runs.
            if let Some(tr) = &self.tracker {
                let union = tr.running_union(&running_slots, top_k);
                if !union.is_empty() {
                    if let Some(fp) = tr.predict(&entry.req) {
                        self.metrics.footprint_overlap.add(admission_score(
                            &fp.top_set(top_k),
                            &union,
                            self.model.placement.as_ref(),
                        ));
                    }
                }
            }
            let id = entry.req.id;
            let class = entry.req.priority;
            // Queue-wait accounting is per STINT: a fresh request measures
            // from submission, an eviction-requeued one from its requeue
            // instant (`enqueue_sim`), so time spent being SERVED between
            // stints never counts as queue wait and no stint's wait is
            // dropped. (`submit_sim` still anchors TTFT and deadlines.)
            self.metrics.record_queue_wait(now_sim - entry.enqueue_sim);
            // A row that already committed its first token (non-empty
            // resume prefix) must not re-record TTFT — measured once, from
            // the original submission.
            let ttft_recorded = !entry.req.resume_prefix.is_empty();
            let was_resume = entry.req.evictions > 0;
            if was_running {
                self.metrics.admitted_in_flight += 1;
            }
            let slot = self.batcher.place(entry.req);
            // Only lookup drafting reads the per-row n-gram index; every
            // other deployment must not pay its per-commit upkeep.
            if self.cfg.spec_len == 0 || self.cfg.spec_draft != SpecDraft::Lookup {
                self.batcher.seq_mut(slot).ngram.disable();
            }
            if let Some(tr) = &mut self.tracker {
                tr.on_admit(slot, &self.batcher.seq(slot).req);
            }
            // Prefix-cache restore: if the prompt extends a cached prefix,
            // copy the slab into this row and fast-forward the phase state
            // — the suffix (always ≥ 1 token) chunk-prefills as usual. The
            // cache-restore KV contract (`model/moe_model.rs`) makes this
            // byte-identical to a cold prefill of the whole prompt. An
            // eviction-requeued row's prompt is its committed history, so
            // the slab its preemption offered back is a natural hit here —
            // resume restores instead of recomputing.
            if self.prefix_cache.enabled() {
                match self.prefix_cache.lookup(&self.batcher.seq(slot).req.prompt) {
                    Some(kv) => {
                        let n = kv.len;
                        self.model
                            .restore_prefix(slot, &kv)
                            .expect("cached prefix extracted from this model must fit");
                        self.batcher.seq_mut(slot).restore_prefix_state(n);
                        self.metrics.prefill_restored_tokens += n as u64;
                        if was_resume {
                            self.metrics.resume_restores += 1;
                        }
                    }
                    None => {
                        if was_resume {
                            self.metrics.resume_recomputes += 1;
                        }
                    }
                }
                self.sync_prefix_metrics();
            }
            self.ttft_pending[slot] = Some(PendingTtft {
                submit_sim: entry.submit_sim,
                class,
                deadline_sim: entry.deadline_sim,
                recorded: ttft_recorded,
            });
            admitted.push(id);
        }
        admitted
    }

    /// Release a finished sequence's slot everywhere slot state lives.
    /// (`ttft_pending` is left alone: the first-token commit that finished
    /// this sequence is harvested after the step body returns, and the next
    /// admission into the slot overwrites the entry.)
    fn release_slot(&mut self, slot: usize) -> super::request::SeqState {
        if let Some(tr) = &mut self.tracker {
            tr.release(slot);
        }
        // A pending draft lag dies with the sequence: the next occupant
        // starts at pos 0 and must not inherit a catch-up debt (stale lag
        // would feed `pos − 1` — an underflow — on a fresh prefill rider).
        if let Some(d) = self.draft.as_mut() {
            d.set_lag(slot, None);
        }
        // Every release (finish or eviction) ticks the rebalance clock.
        self.frees_since_rebalance += 1;
        let done = self.batcher.release(slot);
        // Per-row acceptance state lives for ONE slot occupancy: finish
        // and eviction alike drop the row's EMA (a resumed row re-warms
        // from its class prior — its acceptance profile may have changed
        // with its phase).
        self.depth_ctl.forget_row(done.req.id);
        done
    }

    /// Release a FINISHED sequence and report its complete generation
    /// (tokens committed before any eviction stitched in front of this
    /// stint's).
    fn finish_slot(&mut self, slot: usize) -> (u64, Vec<u32>) {
        self.offer_to_cache(slot);
        let done = self.release_slot(slot);
        (done.req.id, done.full_output())
    }

    /// Offer the releasing row's committed-prefix KV to the prefix cache
    /// (no-op when the cache is disabled). The offered token string is
    /// exactly the processed prefix — `(prompt ++ generated)[0..pos]` —
    /// whose KV the row holds; mid-prefill rows offer their consumed
    /// prompt, decoding rows everything committed except the last token
    /// (fed next step, its KV not yet written). Refusals (below
    /// `--prefix-min-tokens`, oversize, duplicate) are free.
    fn offer_to_cache(&mut self, slot: usize) {
        if !self.prefix_cache.enabled() {
            return;
        }
        let Some(seq) = self.batcher.get(slot) else { return };
        let len = seq.pos;
        if len < self.prefix_cache.min_tokens() {
            return;
        }
        let from_prompt = seq.prompt_idx.min(len);
        let mut toks: Vec<u32> = seq.req.prompt[..from_prompt].to_vec();
        toks.extend_from_slice(&seq.generated[..len - from_prompt]);
        if let Ok(kv) = self.model.extract_prefix(slot, len) {
            self.prefix_cache.insert(&toks, kv);
        }
        self.sync_prefix_metrics();
    }

    /// Mirror the prefix cache's counters and resident-tokens gauge into
    /// the run metrics (called after every cache-touching operation).
    fn sync_prefix_metrics(&mut self) {
        let s = self.prefix_cache.stats;
        self.metrics.prefix_hits = s.hits;
        self.metrics.prefix_misses = s.misses;
        self.metrics.prefix_inserts = s.inserts;
        self.metrics.prefix_evictions = s.evictions;
        self.metrics.prefix_cached_tokens = self.prefix_cache.cached_tokens() as u64;
    }

    /// Current KV position of the sequence occupying `slot`, if any
    /// (prefill equivalence tests compare mid-flight positions).
    pub fn slot_pos(&self, slot: usize) -> Option<usize> {
        self.batcher.get(slot).map(|s| s.pos)
    }

    /// Step until all submitted work completes.
    pub fn drain(&mut self) -> Result<()> {
        while self.has_work() {
            self.step()?;
        }
        Ok(())
    }

    /// Drop the per-request run-report bookkeeping (outputs + domains).
    ///
    /// Long-lived callers that consume results from [`StepOutcome::finished`]
    /// (the live TCP worker) must call this periodically: the accumulators
    /// exist only for [`ServeLoop::report`], and on a server that never
    /// reports they would otherwise grow without bound. After discarding,
    /// a later `report()` only covers requests finishing after this call.
    pub fn discard_finished(&mut self) {
        self.outputs.clear();
        // One pass to collect every id still in flight (queued or running),
        // then a set-lookup retain — this runs every server step, so it
        // must stay O(n log n) in the backlog, not O(n²).
        let mut in_flight: std::collections::BTreeSet<u64> = self.queue.ids().collect();
        for s in self.batcher.live_slots() {
            in_flight.insert(self.batcher.seq(s).req.id);
        }
        self.domains.retain(|id, _| in_flight.contains(id));
    }

    /// Close out the run: stamp wall-clock and move the accumulated outputs
    /// into a report. The loop can keep serving afterwards (metrics keep
    /// accumulating; outputs/domains start empty again).
    pub fn report(&mut self) -> RunReport {
        self.metrics.wall_seconds = self.started.elapsed().as_secs_f64();
        self.metrics.requests_done = self.outputs.len() as u64;
        RunReport {
            metrics: self.metrics.clone(),
            outputs: std::mem::take(&mut self.outputs),
            domains: std::mem::take(&mut self.domains),
        }
    }

    /// Rows taking the chunked-prefill path this step. The chunk artifact
    /// slices a fixed `cap`-wide cache window, so rows whose window would
    /// overhang `max_seq` finish their prompt one token per step instead;
    /// single-token advances (one-token tails, 1-token prompts) ride the
    /// shared forward — a dedicated chunk forward for one token would cost
    /// MORE than the legacy path.
    fn chunk_plans(&self, slots: &[usize]) -> Vec<ChunkPlan> {
        if self.cfg.prefill_chunk <= 1 {
            return Vec::new();
        }
        let cap = self.model.prefill_capacity();
        let max_seq = self.model.dims().max_seq;
        slots
            .iter()
            .filter_map(|&s| {
                let seq = self.batcher.seq(s);
                if !seq.phase.is_prefill() || seq.pos + cap > max_seq {
                    return None;
                }
                let n = self.cfg.prefill_chunk.min(seq.prompt_remaining());
                if n < 2 {
                    return None;
                }
                Some(ChunkPlan {
                    slot: s,
                    start: seq.pos,
                    tokens: seq.req.prompt[seq.prompt_idx..seq.prompt_idx + n].to_vec(),
                })
            })
            .collect()
    }

    /// One non-speculative serving step. With `prefill_chunk > 1`, rows in
    /// prefill phase advance by up to a whole chunk through the prefill
    /// artifact while the remaining rows run one ordinary decode step; with
    /// the default chunk of 1 this is byte-identical to the legacy
    /// one-token-per-step path.
    fn serve_step(&mut self, slots: &[usize]) -> Result<StepEvents> {
        let mut plans = self.chunk_plans(slots);
        if plans.is_empty() {
            return self.plain_step(slots, &[]);
        }

        // Slot-membership table instead of a per-slot linear scan of the
        // plans (slots × plans was quadratic in the batch width).
        let mut is_chunk = vec![false; self.model.max_batch()];
        for p in &plans {
            is_chunk[p.slot] = true;
        }
        let rest: Vec<usize> =
            slots.iter().copied().filter(|&s| !is_chunk[s]).collect();

        let mut events = StepEvents::default();
        if !rest.is_empty() {
            // Park each chunk row at (first chunk token, its position): the
            // decode step's cache write there is overwritten by the chunk
            // below, and the draft shadow of the park IS the chunk's first
            // shadow token — the same harmless-rewrite idiom the ragged
            // verify uses for every short row.
            let park: Vec<(usize, u32, usize)> =
                plans.iter().map(|p| (p.slot, p.tokens[0], p.start)).collect();
            events.absorb(self.plain_step(&rest, &park)?);
        }

        events.absorb(self.run_chunk_plans(&mut plans)?);

        // The draft shadows every chunk token so its cache stays aligned
        // for upcoming spec cycles. Token 0 of each chunk was shadowed by
        // the decode sub-step's park when one ran.
        let shadow_from = if rest.is_empty() { 0 } else { 1 };
        self.shadow_chunks(&plans, shadow_from)?;

        Ok(events)
    }

    /// Advance every chunk plan through the prefill artifact in
    /// round-robin **waves**: round r issues one invocation per plan that
    /// still has tokens (and cache window) left, and the whole round is
    /// charged ONCE — a single fused target forward over the per-layer
    /// UNION of the rows' routed experts and the round's total token
    /// count ([`ServeLoop::charge_wave`]). N co-prefilling rows thus
    /// share one amortized per-layer weight stream, exactly the lever
    /// continuous batching gives decode. Routing stays per row per
    /// position — the invocations are byte-identical to the sequential
    /// walk, only the charge fuses (the prefill-wave contract in
    /// `model/moe_model.rs`). Plans are truncated to what the target
    /// actually consumed (max_seq-boundary tails continue one token per
    /// step) so the draft shadow stays aligned.
    fn run_chunk_plans(&mut self, plans: &mut [ChunkPlan]) -> Result<StepEvents> {
        let cap = self.model.prefill_capacity();
        let max_seq = self.model.dims().max_seq;
        let shared = self.cfg.chunk_shared_selection;
        let mut events = StepEvents::default();
        let mut consumed = vec![0usize; plans.len()];
        let mut last_logits: Vec<Option<Vec<f32>>> = vec![None; plans.len()];
        loop {
            // One wave: at most one invocation per still-advancing plan.
            let mut issued = 0usize;
            let mut wave_tokens = 0usize;
            let mut wave_selected: Vec<Vec<ExpertSet>> = Vec::new();
            for (i, plan) in plans.iter().enumerate() {
                if consumed[i] >= plan.tokens.len() {
                    continue;
                }
                let start = plan.start + consumed[i];
                if start + cap > max_seq {
                    continue; // remainder continues one-token-per-step
                }
                let n = (plan.tokens.len() - consumed[i]).min(cap);
                let out = self.model.prefill_chunk(&PrefillInput {
                    row: plan.slot,
                    start_pos: start,
                    tokens: &plan.tokens[consumed[i]..consumed[i] + n],
                    policy: self.policy.as_ref(),
                    shared_selection: shared,
                    collect_probs: self.tracker.is_some(),
                })?;
                issued += 1;
                if self.sequential_prefill_charging {
                    // Pre-PR8 accounting: every invocation pays its own
                    // full per-layer weight stream.
                    self.charge_step(
                        &out.activated,
                        &out.selected,
                        n,
                        0.0,
                        CostPhase::PrefillWave,
                    );
                    self.metrics.record_prefill(&out.activated, n as u64);
                } else {
                    // Activation/token gauges record per invocation; the
                    // round's sim charge lands once below.
                    self.metrics.record_prefill(&out.activated, n as u64);
                    wave_tokens += n;
                    wave_selected.push(out.selected);
                }
                // Prompt-time router scores feed the row's footprint: every
                // chunk position is one observation for the slot's EMA.
                if let (Some(tr), Some(probs)) = (&mut self.tracker, &out.probs) {
                    let layers: Vec<&ScoreMatrix> = probs.iter().collect();
                    for j in 0..n {
                        tr.observe_step(plan.slot, j, &layers);
                    }
                }
                last_logits[i] = Some(out.last_logits);
                consumed[i] += n;
            }
            if issued == 0 {
                break;
            }
            if !self.sequential_prefill_charging {
                // One fused charge for the whole round: the per-layer
                // union is the set one shared weight stream must cover,
                // the wave's token total what it amortizes over.
                let (acts, sets) = MoeModel::wave_union(&wave_selected);
                self.charge_wave(&acts, &sets, wave_tokens);
                self.metrics.record_prefill_wave(issued);
            }
        }
        for (i, plan) in plans.iter_mut().enumerate() {
            // A max_seq-boundary skip leaves a tail for later steps: the
            // draft must only shadow what the target actually consumed.
            plan.tokens.truncate(consumed[i]);
            let am =
                argmax(last_logits[i].as_ref().expect("chunk ran at least once")) as u32;
            let seq = self.batcher.seq_mut(plan.slot);
            let id = seq.req.id;
            if seq.advance_prefill_by(consumed[i], am) {
                // the chunk's last logits committed the first GENERATED
                // token; record_prefill only counted the prompt tokens
                events.first_token_slots.push(plan.slot);
                events.deltas.push((id, vec![am]));
                self.metrics.tokens_out += 1;
            }
            if seq.is_done() {
                let finished = self.finish_slot(plan.slot);
                events.finished.push(finished);
            }
        }
        Ok(events)
    }

    /// One ordinary continuous-batching step over `slots` (prefill and/or
    /// decode rows, one token each). `park` entries pin rows OUTSIDE
    /// `slots` to a (token, position) that a chunk invocation will
    /// overwrite this same step, keeping their target/draft caches clear of
    /// the pos-0 garbage padded rows otherwise receive.
    fn plain_step(
        &mut self,
        slots: &[usize],
        park: &[(usize, u32, usize)],
    ) -> Result<StepEvents> {
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let mut tokens = vec![0i32; b_max];
        let mut pos = vec![0i32; b_max];
        for &s in slots {
            let seq = self.batcher.seq(s);
            tokens[s] = seq.next_token as i32;
            pos[s] = seq.pos as i32;
        }
        for &(s, tok, p) in park {
            debug_assert!(!slots.contains(&s), "parked slot also stepped");
            tokens[s] = tok as i32;
            pos[s] = p as i32;
        }
        let groups: Vec<Vec<usize>> = slots.iter().map(|&s| vec![s]).collect();
        let out = self.model.step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: slots,
            requests: &groups,
            mode: RoutingMode::Policy(self.policy.as_ref()),
            // Footprint admission learns from every forward's router probs.
            collect_probs: self.tracker.is_some(),
        })?;

        // Decayed-EMA footprint update from this step's observed scores.
        if let (Some(tr), Some(scores)) = (&mut self.tracker, &out.scores) {
            let layers: Vec<&ScoreMatrix> = scores.iter().map(|(_, p)| p).collect();
            for &s in slots {
                tr.observe_step(s, s, &layers);
            }
        }

        // The draft model shadows the token stream so its cache stays warm
        // for upcoming speculative cycles.
        if let Some(d) = self.draft.as_mut() {
            d.shadow_step(self.model.engine(), &tokens, &pos)?;
        }

        let logits = out.logits.as_f32()?;
        let mut committed = 0u64;
        let mut prompt_consumed = 0u64;
        let mut events = StepEvents::default();
        for &s in slots {
            let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
            let seq = self.batcher.seq_mut(s);
            let id = seq.req.id;
            let was_unstarted = seq.generated.is_empty();
            match seq.phase {
                Phase::PrefillChunk => {
                    prompt_consumed += 1;
                    if seq.advance_prefill(am) {
                        committed += 1;
                        events.deltas.push((id, vec![am]));
                    }
                }
                Phase::Decode => {
                    seq.commit(am);
                    committed += 1;
                    events.deltas.push((id, vec![am]));
                }
                Phase::SpecVerify { .. } => {
                    unreachable!("verify rows never take the plain path")
                }
            }
            if was_unstarted && !seq.generated.is_empty() {
                events.first_token_slots.push(s);
            }
            if seq.is_done() {
                let finished = self.finish_slot(s);
                events.finished.push(finished);
            }
        }

        let sim_s = self.charge_step(
            &out.activated,
            &out.selected,
            slots.len(),
            0.0,
            CostPhase::Decode,
        );
        self.remember_geometry(slots.len(), &out.activated, &out.selected);
        self.metrics.record_step(&out.activated, sim_s, committed);
        self.metrics.tokens_prompt += prompt_consumed;
        Ok(events)
    }

    /// Feed chunk tokens `shadow_from..` of every plan through the draft
    /// model (one call per chunk offset; rows without a token at that
    /// offset are parked on a position their next real shadow overwrites).
    fn shadow_chunks(&mut self, plans: &[ChunkPlan], shadow_from: usize) -> Result<()> {
        if self.draft.is_none() {
            return Ok(());
        }
        let b_max = self.model.max_batch();
        let longest = plans.iter().map(|p| p.tokens.len()).max().unwrap_or(0);
        for j in shadow_from..longest {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            // harmless parking for every live row not shadowing offset j
            for s in self.batcher.live_slots() {
                let seq = self.batcher.seq(s);
                tokens[s] = seq.next_token as i32;
                pos[s] = seq.pos as i32;
            }
            for p in plans {
                if j < p.tokens.len() {
                    tokens[p.slot] = p.tokens[j] as i32;
                    pos[p.slot] = (p.start + j) as i32;
                }
            }
            let d = self.draft.as_mut().unwrap();
            d.shadow_step(self.model.engine(), &tokens, &pos)?;
        }
        Ok(())
    }

    /// One mixed-phase step with a ragged speculative verify: chunk rows
    /// advance through the prefill artifact (parked in the shared
    /// forward), every other live row rides the verify — decoding rows at
    /// their per-row depth, one-token prefill rows and depth-0 rows parked
    /// at depth 0 committing exactly one token from sub-step 0.
    fn spec_mixed_step(
        &mut self,
        slots: &[usize],
        plans: Vec<SpecPlan>,
    ) -> Result<StepEvents> {
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let n_layers = self.model.dims().n_layers;
        let n_experts = self.model.dims().n_experts;

        let mut chunk_plans = self.chunk_plans(slots);
        // Riders: every live row NOT advancing via the chunk artifact
        // (membership table, not a per-slot scan of the plans).
        let mut is_chunk = vec![false; b_max];
        for p in &chunk_plans {
            is_chunk[p.slot] = true;
        }
        let riders: Vec<usize> =
            slots.iter().copied().filter(|&s| !is_chunk[s]).collect();
        debug_assert!(!riders.is_empty(), "spec step needs at least one decode row");

        // Per-rider depth (0 for prefill riders and unplanned decode rows).
        let mut spec: BTreeMap<usize, SpecPlan> =
            plans.into_iter().map(|p| (p.slot, p)).collect();
        let depth_of = |spec: &BTreeMap<usize, SpecPlan>, s: usize| {
            spec.get(&s).map(|p| p.depth).unwrap_or(0)
        };
        let depths: Vec<usize> = riders.iter().map(|&s| depth_of(&spec, s)).collect();
        let max_d = depths.iter().copied().max().unwrap_or(0);
        debug_assert!(max_d > 0, "spec step without any drafting row");

        // Enter SpecVerify phase for every decoding rider (depth-0 riders
        // included: they are part of this cycle's effective batch).
        for &s in &riders {
            if self.batcher.seq(s).phase == Phase::Decode {
                let d = depth_of(&spec, s);
                self.batcher.seq_mut(s).begin_spec(d);
            }
        }

        // Padded park defaults for every live row: riders on their own
        // next (token, position), chunk rows on their chunk's first token
        // (the chunk invocation below overwrites that write).
        let park_defaults = |batcher: &Batcher, chunk_plans: &[ChunkPlan]| {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            for s in batcher.live_slots() {
                let seq = batcher.seq(s);
                tokens[s] = seq.next_token as i32;
                pos[s] = seq.pos as i32;
            }
            for p in chunk_plans {
                tokens[p.slot] = p.tokens[0] as i32;
                pos[p.slot] = p.start as i32;
            }
            (tokens, pos)
        };

        // ---- draft proposals --------------------------------------------
        // Model drafts run max_d batched sub-steps (rows past their depth —
        // and non-drafting riders — park on harmless rewrites); lookup
        // drafts were generated at planning time for free.
        if self.cfg.spec_draft == SpecDraft::Model {
            let draft = self.draft.as_mut().expect("model-draft spec without runner");
            // Catch-up: rows that fully accepted last cycle owe the draft
            // one input (fed at pos − 1); everyone else harmlessly
            // re-writes their upcoming position.
            if draft.any_lag(&riders) {
                let (mut tokens, mut pos) = park_defaults(&self.batcher, &chunk_plans);
                for &s in &riders {
                    if let Some(t) = draft.lag_token(s) {
                        tokens[s] = t as i32;
                        pos[s] = (self.batcher.seq(s).pos - 1) as i32;
                    }
                }
                draft.step(self.model.engine(), &tokens, &pos)?;
                draft.clear_lag(&riders);
            }
            let (mut dtok, mut dpos) = park_defaults(&self.batcher, &chunk_plans);
            for j in 0..max_d {
                let draft = self.draft.as_mut().unwrap();
                let logits_t = draft.step(self.model.engine(), &dtok, &dpos)?;
                let logits = logits_t.as_f32()?;
                for &s in &riders {
                    let plan_depth = depth_of(&spec, s);
                    if j < plan_depth {
                        let d = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                        spec.get_mut(&s).unwrap().proposals.push(d);
                        dtok[s] = d as i32;
                        dpos[s] += 1;
                    }
                    // rows at/past their depth keep their park: identical
                    // rewrites of a position their next real input covers
                }
            }
        }

        // verify inputs per sub-step j for rider s: j=0 → next_token,
        // 1..=depth → draft j−1, beyond depth → park on (next_token, pos).
        fn verify_tok(
            batcher: &Batcher,
            spec: &BTreeMap<usize, SpecPlan>,
            s: usize,
            j: usize,
        ) -> (u32, usize) {
            let seq = batcher.seq(s);
            if j == 0 {
                return (seq.next_token, seq.pos);
            }
            match spec.get(&s) {
                Some(p) if j <= p.depth => (p.proposals[j - 1], seq.pos + j),
                _ => (seq.next_token, seq.pos),
            }
        }

        // ---- pass 1: scoring (vanilla routing, collect per-layer probs) --
        let vanilla = Vanilla;
        let groups_single: Vec<Vec<usize>> = riders.iter().map(|&s| vec![s]).collect();
        let mut pass1_scores: Vec<Vec<(ScoreMatrix, ScoreMatrix)>> =
            Vec::with_capacity(max_d + 1);
        for j in 0..=max_d {
            let (mut tokens, mut pos) = park_defaults(&self.batcher, &chunk_plans);
            for &s in &riders {
                let (t, p) = verify_tok(&self.batcher, &spec, s, j);
                tokens[s] = t as i32;
                pos[s] = p as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: &riders,
                requests: &groups_single,
                mode: RoutingMode::Policy(&vanilla),
                collect_probs: true,
            })?;
            pass1_scores.push(out.scores.unwrap());
        }

        // Footprints observe the committed-token sub-step (j = 0): the
        // speculative tail is provisional and may be rejected.
        if let Some(tr) = &mut self.tracker {
            let layers: Vec<&ScoreMatrix> =
                pass1_scores[0].iter().map(|(_, p)| p).collect();
            for &s in &riders {
                tr.observe_step(s, s, &layers);
            }
        }

        // ---- per-layer selection over the RAGGED effective batch --------
        // Each rider contributes 1 + its own depth positions; under
        // adaptive depth the speculative positions are weighted by the
        // row's class acceptance prior (deep positions of low-acceptance
        // rows contribute less gating mass).
        let priors: Option<Vec<f32>> = self.cfg.spec_adaptive.then(|| {
            riders
                .iter()
                .map(|&s| spec.get(&s).map(|p| p.prior).unwrap_or(1.0))
                .collect()
        });
        let mut sets: Vec<ExpertSet> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let logits_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].0).collect();
            let probs_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].1).collect();
            let (eff_logits, _) =
                effective_batch_scores_ragged(&logits_steps, &riders, &depths, None);
            let (eff_probs, groups) = effective_batch_scores_ragged(
                &probs_steps,
                &riders,
                &depths,
                priors.as_deref(),
            );
            let rows: Vec<usize> = (0..eff_probs.n_tokens()).collect();
            let ctx = crate::selection::SelectionContext {
                probs: &eff_probs,
                logits: &eff_logits,
                rows: &rows,
                requests: &groups,
                colsum_hint: None,
                placement: self.model.placement.as_ref(),
                top_k: self.model.dims().top_k,
            };
            sets.push(self.policy.select(&ctx));
        }

        // ---- pass 2: restricted run; drives acceptance -------------------
        let mut target_argmax: BTreeMap<usize, Vec<u32>> =
            riders.iter().map(|&s| (s, Vec::with_capacity(max_d + 1))).collect();
        let mut union_activated: Vec<ExpertSet> =
            (0..n_layers).map(|_| ExpertSet::empty(n_experts)).collect();
        let mut acts = vec![0usize; n_layers];
        for j in 0..=max_d {
            let (mut tokens, mut pos) = park_defaults(&self.batcher, &chunk_plans);
            for &s in &riders {
                let (t, p) = verify_tok(&self.batcher, &spec, s, j);
                tokens[s] = t as i32;
                pos[s] = p as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: &riders,
                requests: &groups_single,
                mode: RoutingMode::Restricted(&sets),
                collect_probs: false,
            })?;
            let logits = out.logits.as_f32()?;
            for &s in &riders {
                let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                target_argmax.get_mut(&s).unwrap().push(am);
            }
            for (u, sel) in union_activated.iter_mut().zip(&out.selected) {
                u.union_with(sel);
            }
        }
        for (a, u) in acts.iter_mut().zip(&union_activated) {
            *a = u.len();
        }

        // ---- per-row acceptance & commit ---------------------------------
        let mut committed_total = 0u64;
        let mut prompt_consumed = 0u64;
        let mut events = StepEvents::default();
        for &s in &riders {
            let seq_phase = self.batcher.seq(s).phase;
            match seq_phase {
                Phase::PrefillChunk => {
                    // One-token prompt advance from sub-step 0 of the
                    // shared verify forward.
                    let am = target_argmax[&s][0];
                    let seq = self.batcher.seq_mut(s);
                    let id = seq.req.id;
                    prompt_consumed += 1;
                    if seq.advance_prefill(am) {
                        committed_total += 1;
                        events.first_token_slots.push(s);
                        events.deltas.push((id, vec![am]));
                    }
                    // A budget of 1 finishes on the prefill commit itself.
                    if seq.is_done() {
                        let finished = self.finish_slot(s);
                        events.finished.push(finished);
                    }
                }
                Phase::SpecVerify { depth } => {
                    let plan = &spec[&s];
                    debug_assert_eq!(plan.depth, depth);
                    debug_assert_eq!(plan.proposals.len(), depth);
                    // Acceptance sees only this row's TRUE depth; sub-steps
                    // beyond it were padding (harmless rewrites).
                    let (n_acc, committed) =
                        greedy_accept(&plan.proposals, &target_argmax[&s][..=depth]);
                    self.metrics.spec_proposed += depth as u64;
                    self.metrics.spec_accepted += n_acc as u64;
                    self.metrics.spec_depth.add(depth as f64);
                    if depth > 0 {
                        let rate = n_acc as f64 / depth as f64;
                        self.metrics.record_spec_accept(&plan.class, rate);
                        self.depth_ctl.observe(&plan.class, depth, n_acc);
                        // Per-row EMA rides the same observation; it only
                        // starts speaking after SPEC_ROW_WARMUP cycles.
                        let row_id = self.batcher.seq(s).req.id;
                        self.depth_ctl.observe_row(row_id, depth, n_acc);
                    }
                    let seq = self.batcher.seq_mut(s);
                    let id = seq.req.id;
                    let take = committed.len().min(seq.remaining());
                    let mut delta = Vec::with_capacity(take);
                    for &tok in committed.iter().take(take) {
                        seq.commit(tok);
                        delta.push(tok);
                        committed_total += 1;
                    }
                    if !delta.is_empty() {
                        events.deltas.push((id, delta));
                    }
                    let done = seq.is_done();
                    seq.end_spec();
                    // full acceptance leaves the draft cache one input
                    // behind (model drafts only; lookup drafts have no
                    // cache to lag)
                    if let Some(d) = self.draft.as_mut() {
                        let lag = if n_acc == depth && depth > 0 && !done {
                            Some(plan.proposals[depth - 1])
                        } else {
                            None
                        };
                        d.set_lag(s, lag);
                    }
                    if done {
                        let finished = self.finish_slot(s);
                        events.finished.push(finished);
                    }
                }
                Phase::Decode => unreachable!("decode riders entered SpecVerify"),
            }
        }

        // Cost: ONE target forward over the padded ragged batch (max
        // in-use depth sets the verify geometry) plus the true per-row
        // draft charge. Riders' target_argmax beyond their own depth came
        // from harmless rewrites and cost nothing extra — they are the
        // padding the max-depth charge already covers.
        let draft_seconds = if self.cfg.spec_draft == SpecDraft::Model {
            self.ledger.pricer().draft_cost(&depths).seconds()
        } else {
            0.0 // lookup drafts are a CPU table scan, not a model forward
        };
        let sim_s = self.charge_step(
            &acts,
            &union_activated,
            riders.len() * (1 + max_d),
            draft_seconds,
            CostPhase::SpecVerify,
        );
        self.remember_geometry(riders.len(), &acts, &union_activated);
        self.metrics.record_step(&acts, sim_s, committed_total);
        self.metrics.tokens_prompt += prompt_consumed;

        // ---- chunk rows advance + draft shadow ---------------------------
        if !chunk_plans.is_empty() {
            events.absorb(self.run_chunk_plans(&mut chunk_plans)?);
            // Chunk token 0 was shadowed by the verify/draft parks above
            // (model drafts only; without a draft runner there is nothing
            // to shadow).
            self.shadow_chunks(&chunk_plans, 1)?;
        }

        Ok(events)
    }

    /// Assemble and post one target forward's ledger entry (+ draft
    /// seconds) with EP load accounting. Returns the posted seconds —
    /// the step's sim delta.
    ///
    /// Under EP every target forward — decode, ragged verify, chunk
    /// prefill — charges per layer through
    /// [`EpCostModel::layer_latency`] on the true per-layer
    /// [`Placement::loads`] of the experts it routed, so the sim clock
    /// (and with it TTFT/OTPS and every admission deadline) feels the
    /// straggler GPU. Draft forwards keep their dense charge: the draft
    /// model is replicated per GPU, not expert-sharded, so it adds no
    /// dispatch/straggler term. Load gauges recorded here: per-layer
    /// per-GPU histograms, the per-forward MaxLoad, and the
    /// straggler-exposure integral `∫ MaxLoad dt` (MaxLoad × this
    /// forward's full charge, draft seconds included — the draft runs
    /// inside the same wall interval the straggler bounds).
    ///
    /// `phase` attributes the forward itself (Decode / SpecVerify /
    /// PrefillWave); draft seconds are always [`CostPhase::SpecDraft`]
    /// and the migration drain always [`CostPhase::MigrationDrain`]. The
    /// entry accumulates its parts in the exact chronological order the
    /// pre-ledger code summed them, and [`Ledger::post`] adds ONE total
    /// to the clock — which is what keeps refactored sim time
    /// bit-identical (`tests/cost_ledger.rs`).
    fn charge_step(
        &mut self,
        activated: &[usize],
        selected: &[ExpertSet],
        n_tokens: usize,
        draft_seconds: f64,
        phase: CostPhase,
    ) -> f64 {
        let mut entry = CostEntry::new();
        if draft_seconds > 0.0 {
            entry.add(CostPhase::SpecDraft, draft_seconds);
        }
        if let Some(pl) = &self.model.placement {
            let sel_refs: Vec<&ExpertSet> = selected.iter().collect();
            let ep_charge =
                self.ledger
                    .pricer()
                    .ep_step(pl, &sel_refs, n_tokens, self.ledger.ep_pricer());
            entry.add(phase, ep_charge.seconds());
            // Drain pending migration traffic against this step: the
            // transfer shares the interconnect with serving, so each step
            // absorbs at most its own duration of backlog (a step at most
            // doubles) until the adopted plans are fully paid for.
            let drain = self.ledger.drain_migration(entry.seconds());
            if drain > 0.0 {
                entry.add(CostPhase::MigrationDrain, drain);
                self.metrics.migration_seconds += drain;
            }
            let max_load =
                selected.iter().map(|s| pl.max_load(s)).max().unwrap_or(0);
            self.metrics.max_gpu_load.add(max_load as f64);
            for sel in selected {
                self.metrics.record_gpu_loads(&pl.loads(sel));
            }
            self.metrics.gpu_load_integral += max_load as f64 * entry.seconds();
        } else {
            let scaled = self.ledger.pricer().scale_activations(activated);
            entry.add(
                phase,
                self.ledger.pricer().target_step(&scaled, n_tokens).seconds(),
            );
        }
        let sim = self.ledger.post(entry);
        self.mirror_ledger();
        sim
    }

    /// One fused charge for a prefill wave (the PR 8 charging split):
    /// under EP exactly a [`ServeLoop::charge_step`] on the wave's
    /// unioned per-layer sets — the per-layer [`EpCostModel`] pricing,
    /// straggler gauges and migration drain apply once per wave instead
    /// of once per row; dense, the [`DecodeCostModel::prefill_wave`]
    /// entry point over the unioned activation counts and the wave's
    /// total token count, posted as one [`CostPhase::PrefillWave`]
    /// entry. A one-invocation wave charges exactly what the sequential
    /// path would (union of one = itself).
    fn charge_wave(
        &mut self,
        activated: &[usize],
        selected: &[ExpertSet],
        n_tokens: usize,
    ) -> f64 {
        if self.model.placement.is_some() {
            self.charge_step(activated, selected, n_tokens, 0.0, CostPhase::PrefillWave)
        } else {
            let scaled = self.ledger.pricer().scale_activations(activated);
            let charge = self.ledger.pricer().prefill_wave(&scaled, n_tokens);
            let mut entry = CostEntry::new();
            entry.add(CostPhase::PrefillWave, charge.seconds());
            let sim = self.ledger.post(entry);
            self.mirror_ledger();
            sim
        }
    }

    /// Mirror the ledger's clock and per-phase totals into the run
    /// metrics. The metrics are a READ-ONLY view — every write to
    /// `sim_seconds` and the `time_*_s` fields happens here, by
    /// assignment from the ledger, immediately after a post (the
    /// single-writer contract in `cost/mod.rs`).
    fn mirror_ledger(&mut self) {
        self.metrics.sim_seconds = self.ledger.clock();
        self.metrics.time_decode_s = self.ledger.phase_seconds(CostPhase::Decode);
        self.metrics.time_spec_s = self.ledger.phase_seconds(CostPhase::SpecVerify)
            + self.ledger.phase_seconds(CostPhase::SpecDraft);
        self.metrics.time_prefill_s = self.ledger.phase_seconds(CostPhase::PrefillWave);
        self.metrics.time_migration_s =
            self.ledger.phase_seconds(CostPhase::MigrationDrain);
        self.metrics.time_overhead_s = self.ledger.phase_seconds(CostPhase::Overhead);
    }

    /// Remember the geometry of the shared forward that just charged —
    /// the batch the charge-aware controller prices marginal depth
    /// against next step. Only consulted under `--spec-charge-aware`,
    /// so every other deployment skips the clone.
    fn remember_geometry(
        &mut self,
        riders: usize,
        activated: &[usize],
        selected: &[ExpertSet],
    ) {
        if !self.cfg.spec_charge_aware {
            return;
        }
        let selected = self.model.placement.is_some().then(|| selected.to_vec());
        self.last_geometry = Some(SpecGeometry {
            riders,
            activated: activated.to_vec(),
            selected,
            model_draft: self.cfg.spec_draft == SpecDraft::Model,
        });
    }
}

/// One row's chunk of prompt tokens for this serving step.
struct ChunkPlan {
    slot: usize,
    /// Row position before the chunk.
    start: usize,
    /// Prompt tokens to consume this step (oldest first).
    tokens: Vec<u32>,
}
