//! The incrementally-stepped serving core: continuous batching, the
//! speculative verify cycle, per-layer expert selection and cost accounting.
//! This is the L3 "leader" loop — everything on the request path runs here,
//! in rust.
//!
//! Unlike the old monolithic `Scheduler::run`, the loop is **step-scoped**:
//! callers own the cadence. [`ServeLoop::submit`] enqueues a request at any
//! time; every [`ServeLoop::step`] first admits queued requests into free
//! batch slots and then runs one decode/spec-verify cycle, so work that
//! arrives mid-flight joins the very next step instead of waiting for the
//! whole batch to drain. Finished sequences are surfaced in the returned
//! [`StepOutcome`] the moment their slot releases. [`ServeLoop::drain`]
//! (submit-all + step-until-done) reproduces the old batch-at-a-time
//! behaviour byte-for-byte — the `Scheduler` wrapper in
//! [`super::scheduler`] is exactly that.
//!
//! ## Speculative verify emulation (DESIGN.md §4)
//!
//! The compiled decode-step artifact advances one token per row, so a verify
//! forward over B×(1+L_s) tokens is emulated in two passes of (1+L_s)
//! sub-steps each:
//!
//!  * **pass 1 (scoring)**: vanilla routing, records every layer's gate
//!    scores for all verify tokens — the effective-batch G^{(l)};
//!  * **selection**: the policy picks S_l once per layer from those scores
//!    (with per-request grouping, exactly Algorithm 4's input);
//!  * **pass 2 (restricted)**: re-runs the sub-steps with every layer
//!    restricted to S_l; its logits drive acceptance and its KV writes are
//!    the ones that persist (positions beyond the accepted prefix are
//!    garbage-but-masked, verified by the kernel tests).
//!
//! The cost model charges one draft step per speculative token plus ONE
//! target forward over the effective batch — the two passes are an artifact
//! of the one-token-per-row compilation, not of the system being modeled.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use super::batcher::Batcher;
use super::request::{Phase, Request};
use super::speculative::{effective_batch_scores, greedy_accept};
use crate::config::ServeConfig;
use crate::ep::{EpCostModel, Placement};
use crate::memsim::{CostGeometry, DecodeCostModel, HardwareProfile};
use crate::metrics::ServeMetrics;
use crate::model::{argmax, MoeModel, RoutingMode, StepInput};
use crate::selection::{baselines::Vanilla, ExpertSet, ScoreMatrix, SelectionPolicy};

/// Result of one serving run (what `drain` + `report` produce).
#[derive(Debug)]
pub struct RunReport {
    pub metrics: ServeMetrics,
    /// request id → generated tokens.
    pub outputs: BTreeMap<u64, Vec<u32>>,
    /// request id → domain (for per-dataset reporting).
    pub domains: BTreeMap<u64, String>,
}

/// What one `step()` did — the server worker routes responses off this.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Request ids admitted into batch slots at the top of this step.
    pub admitted: Vec<u64>,
    /// Sequences that completed this step: (request id, generated tokens).
    pub finished: Vec<(u64, Vec<u32>)>,
    /// Live rows that were in prefill phase when the step ran.
    pub prefill_rows: usize,
    /// Live rows that were in decode phase when the step ran.
    pub decode_rows: usize,
    /// Tokens committed across all rows this step.
    pub committed: u64,
    /// Simulated cost of this step, seconds.
    pub sim_seconds: f64,
    /// Whether this step ran a speculative verify cycle.
    pub speculative: bool,
    /// Requests still waiting in the admission queue after this step.
    pub queued: usize,
    /// Sequences still occupying batch slots after this step.
    pub running: usize,
}

/// The stepped serving core. Owns the model borrow, selection policy, cost
/// models, batcher, draft state and metrics for one serving lifetime.
pub struct ServeLoop<'m> {
    model: &'m mut MoeModel,
    cfg: ServeConfig,
    policy: Box<dyn SelectionPolicy>,
    cost: DecodeCostModel,
    ep_cost: EpCostModel,
    batcher: Batcher,
    metrics: ServeMetrics,
    outputs: BTreeMap<u64, Vec<u32>>,
    domains: BTreeMap<u64, String>,
    draft: Option<DraftState>,
    /// request id → sim-clock at submission (queue-wait / TTFT accounting).
    submit_sim: BTreeMap<u64, f64>,
    /// Per-slot submission sim-time, pending until the first token commits.
    ttft_sub: Vec<Option<f64>>,
    started: Instant,
}

impl<'m> ServeLoop<'m> {
    pub fn new(model: &'m mut MoeModel, cfg: ServeConfig) -> Result<ServeLoop<'m>> {
        let cost = DecodeCostModel::new(
            HardwareProfile::by_name(&cfg.hardware)?,
            CostGeometry::for_preset(&cfg.preset)?,
        );
        let policy = cfg.policy.build();
        if let Some(ep) = &cfg.ep {
            model.placement = Some(Placement::new(
                model.dims().n_experts,
                ep.n_gpus,
                ep.placement,
            ));
        }
        let mut sl = ServeLoop {
            model,
            cfg,
            policy,
            cost,
            ep_cost: EpCostModel::default(),
            batcher: Batcher::new(1, 1),
            metrics: ServeMetrics::new(0),
            outputs: BTreeMap::new(),
            domains: BTreeMap::new(),
            draft: None,
            submit_sim: BTreeMap::new(),
            ttft_sub: Vec::new(),
            started: Instant::now(),
        };
        sl.reset()?;
        Ok(sl)
    }

    /// Forget all serving state (batcher, metrics, caches, draft) and start
    /// a fresh run. Queued-but-unserved requests are dropped.
    pub fn reset(&mut self) -> Result<()> {
        let b_max = self.model.max_batch();
        self.batcher = Batcher::new(b_max, self.cfg.batch_size.min(b_max));
        self.metrics = ServeMetrics::new(self.model.dims().n_layers);
        self.outputs.clear();
        self.domains.clear();
        self.submit_sim.clear();
        self.ttft_sub = vec![None; b_max];
        self.model.reset();
        self.draft = if self.cfg.spec_len > 0 {
            Some(DraftState::new(
                crate::model::DraftModel::new(self.model.engine())?,
                b_max,
            ))
        } else {
            None
        };
        self.started = Instant::now();
        Ok(())
    }

    /// Enqueue a request. It joins the next `step()` if a slot is free.
    pub fn submit(&mut self, req: Request) {
        self.domains.insert(req.id, req.domain.clone());
        self.submit_sim.insert(req.id, self.metrics.sim_seconds);
        self.batcher.submit(req);
    }

    /// Queued or running work remains.
    pub fn has_work(&self) -> bool {
        self.batcher.has_work()
    }

    pub fn queued(&self) -> usize {
        self.batcher.queued()
    }

    pub fn running(&self) -> usize {
        self.batcher.running()
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// One serving step: admit newly queued requests into free slots, then
    /// run one decode step (or speculative verify cycle when all live rows
    /// are in decode phase and speculation is on).
    pub fn step(&mut self) -> Result<StepOutcome> {
        let wall0 = Instant::now();
        let sim_before = self.metrics.sim_seconds;
        let was_running = self.batcher.running() > 0;

        let admitted_slots = self.batcher.admit();
        let mut admitted = Vec::with_capacity(admitted_slots.len());
        for &s in &admitted_slots {
            let id = self.batcher.seq(s).req.id;
            let sub = self.submit_sim.remove(&id).unwrap_or(sim_before);
            self.metrics.queue_wait.add(sim_before - sub);
            if was_running {
                self.metrics.admitted_in_flight += 1;
            }
            self.ttft_sub[s] = Some(sub);
            admitted.push(id);
        }

        let slots = self.batcher.live_slots();
        if slots.is_empty() {
            return Ok(StepOutcome {
                admitted,
                queued: self.batcher.queued(),
                ..StepOutcome::default()
            });
        }

        let prefill_rows =
            slots.iter().filter(|&&s| self.batcher.seq(s).phase == Phase::Prefill).count();
        let decode_rows = slots.len() - prefill_rows;
        let speculative = self.cfg.spec_len > 0 && prefill_rows == 0;
        let committed_before = self.metrics.tokens_out;

        let (finished, first_token_slots) = if speculative {
            self.spec_cycle(&slots)?
        } else {
            self.plain_step(&slots)?
        };

        // Sim clock has advanced by this step's cost; TTFT counts it.
        for s in first_token_slots {
            if let Some(sub) = self.ttft_sub[s].take() {
                self.metrics.ttft.add(self.metrics.sim_seconds - sub);
            }
        }
        for (id, tokens) in &finished {
            self.outputs.insert(*id, tokens.clone());
        }
        self.metrics.requests_done = self.outputs.len() as u64;
        self.metrics.wall_step_latency.record_seconds(wall0.elapsed().as_secs_f64());

        Ok(StepOutcome {
            admitted,
            finished,
            prefill_rows,
            decode_rows,
            committed: self.metrics.tokens_out - committed_before,
            sim_seconds: self.metrics.sim_seconds - sim_before,
            speculative,
            queued: self.batcher.queued(),
            running: self.batcher.running(),
        })
    }

    /// Step until all submitted work completes.
    pub fn drain(&mut self) -> Result<()> {
        while self.has_work() {
            self.step()?;
        }
        Ok(())
    }

    /// Drop the per-request run-report bookkeeping (outputs + domains).
    ///
    /// Long-lived callers that consume results from [`StepOutcome::finished`]
    /// (the live TCP worker) must call this periodically: the accumulators
    /// exist only for [`ServeLoop::report`], and on a server that never
    /// reports they would otherwise grow without bound. After discarding,
    /// a later `report()` only covers requests finishing after this call.
    pub fn discard_finished(&mut self) {
        self.outputs.clear();
        let still_queued = &self.submit_sim;
        self.domains.retain(|id, _| still_queued.contains_key(id));
    }

    /// Close out the run: stamp wall-clock and move the accumulated outputs
    /// into a report. The loop can keep serving afterwards (metrics keep
    /// accumulating; outputs/domains start empty again).
    pub fn report(&mut self) -> RunReport {
        self.metrics.wall_seconds = self.started.elapsed().as_secs_f64();
        self.metrics.requests_done = self.outputs.len() as u64;
        RunReport {
            metrics: self.metrics.clone(),
            outputs: std::mem::take(&mut self.outputs),
            domains: std::mem::take(&mut self.domains),
        }
    }

    /// One ordinary continuous-batching step (prefill and/or decode rows).
    /// Returns finished sequences and the slots that committed their first
    /// generated token this step.
    fn plain_step(
        &mut self,
        slots: &[usize],
    ) -> Result<(Vec<(u64, Vec<u32>)>, Vec<usize>)> {
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let mut tokens = vec![0i32; b_max];
        let mut pos = vec![0i32; b_max];
        for &s in slots {
            let seq = self.batcher.seq(s);
            tokens[s] = seq.next_token as i32;
            pos[s] = seq.pos as i32;
        }
        let groups: Vec<Vec<usize>> = slots.iter().map(|&s| vec![s]).collect();
        let out = self.model.step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: slots,
            requests: &groups,
            mode: RoutingMode::Policy(self.policy.as_ref()),
            collect_probs: false,
        })?;

        // The draft model shadows the token stream so its cache stays warm
        // for upcoming speculative cycles.
        if let Some(d) = self.draft.as_mut() {
            d.shadow_step(self.model.engine(), &tokens, &pos)?;
        }

        let logits = out.logits.as_f32()?;
        let mut committed = 0u64;
        let mut finished = Vec::new();
        let mut first_token_slots = Vec::new();
        for &s in slots {
            let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
            let seq = self.batcher.seq_mut(s);
            let was_unstarted = seq.generated.is_empty();
            match seq.phase {
                Phase::Prefill => {
                    if seq.advance_prefill(am) {
                        committed += 1;
                    }
                }
                Phase::Decode => {
                    seq.commit(am);
                    committed += 1;
                }
            }
            if was_unstarted && !seq.generated.is_empty() {
                first_token_slots.push(s);
            }
            if seq.is_done() {
                let done = self.batcher.release(s);
                finished.push((done.req.id, done.generated));
            }
        }

        let sim_s = self.charge_step(&out.activated, &out.selected, slots.len(), 0);
        self.metrics.record_step(&out.activated, sim_s, committed);
        Ok((finished, first_token_slots))
    }

    /// One speculative verify cycle (all rows in decode phase).
    fn spec_cycle(
        &mut self,
        slots: &[usize],
    ) -> Result<(Vec<(u64, Vec<u32>)>, Vec<usize>)> {
        let ls = self.cfg.spec_len;
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let n_layers = self.model.dims().n_layers;
        let n_experts = self.model.dims().n_experts;

        // ---- draft proposals (plus catch-up for fully-accepted rows) ----
        let draft = self.draft.as_mut().expect("spec cycle without draft state");
        draft.catch_up(self.model.engine(), &self.batcher, slots)?;
        let mut proposals: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        {
            let mut dtok = vec![0i32; b_max];
            let mut dpos = vec![0i32; b_max];
            for &s in slots {
                let seq = self.batcher.seq(s);
                dtok[s] = seq.next_token as i32;
                dpos[s] = seq.pos as i32;
                proposals.insert(s, Vec::with_capacity(ls));
            }
            for _ in 0..ls {
                let logits_t = draft.model.step(self.model.engine(), &dtok, &dpos)?;
                let logits = logits_t.as_f32()?;
                for &s in slots {
                    let d = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                    proposals.get_mut(&s).unwrap().push(d);
                    dtok[s] = d as i32;
                    dpos[s] += 1;
                }
            }
            for &s in slots {
                draft.pos[s] = self.batcher.seq(s).pos + ls; // processed up to pos+ls-1
            }
        }

        // verify inputs per sub-step j: j=0 → next_token, j>=1 → draft j-1
        let verify_tok = |batcher: &Batcher, s: usize, j: usize| -> u32 {
            if j == 0 {
                batcher.seq(s).next_token
            } else {
                proposals[&s][j - 1]
            }
        };

        // ---- pass 1: scoring (vanilla routing, collect per-layer probs) --
        let vanilla = Vanilla;
        let groups_single: Vec<Vec<usize>> = slots.iter().map(|&s| vec![s]).collect();
        let mut pass1_scores: Vec<Vec<(ScoreMatrix, ScoreMatrix)>> = Vec::with_capacity(ls + 1);
        for j in 0..=ls {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            for &s in slots {
                tokens[s] = verify_tok(&self.batcher, s, j) as i32;
                pos[s] = (self.batcher.seq(s).pos + j) as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: slots,
                requests: &groups_single,
                mode: RoutingMode::Policy(&vanilla),
                collect_probs: true,
            })?;
            pass1_scores.push(out.scores.unwrap());
        }

        // ---- per-layer selection over the effective batch ---------------
        let mut sets: Vec<ExpertSet> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let logits_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].0).collect();
            let probs_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].1).collect();
            let (eff_logits, _) = effective_batch_scores(&logits_steps, slots);
            let (eff_probs, groups) = effective_batch_scores(&probs_steps, slots);
            let rows: Vec<usize> = (0..eff_probs.n_tokens()).collect();
            let ctx = crate::selection::SelectionContext {
                probs: &eff_probs,
                logits: &eff_logits,
                rows: &rows,
                requests: &groups,
                colsum_hint: None,
                placement: self.model.placement.as_ref(),
                top_k: self.model.dims().top_k,
            };
            sets.push(self.policy.select(&ctx));
        }

        // ---- pass 2: restricted run; drives acceptance -------------------
        let mut target_argmax: BTreeMap<usize, Vec<u32>> =
            slots.iter().map(|&s| (s, Vec::with_capacity(ls + 1))).collect();
        let mut union_activated: Vec<ExpertSet> =
            (0..n_layers).map(|_| ExpertSet::empty(n_experts)).collect();
        let mut acts = vec![0usize; n_layers];
        for j in 0..=ls {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            for &s in slots {
                tokens[s] = verify_tok(&self.batcher, s, j) as i32;
                pos[s] = (self.batcher.seq(s).pos + j) as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: slots,
                requests: &groups_single,
                mode: RoutingMode::Restricted(&sets),
                collect_probs: false,
            })?;
            let logits = out.logits.as_f32()?;
            for &s in slots {
                let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                target_argmax.get_mut(&s).unwrap().push(am);
            }
            for (u, sel) in union_activated.iter_mut().zip(&out.selected) {
                u.union_with(sel);
            }
        }
        for (a, u) in acts.iter_mut().zip(&union_activated) {
            *a = u.len();
        }

        // ---- acceptance & commit -----------------------------------------
        let mut committed_total = 0u64;
        let mut finished = Vec::new();
        let mut first_token_slots = Vec::new();
        for &s in slots {
            let (n_acc, committed) = greedy_accept(&proposals[&s], &target_argmax[&s]);
            self.metrics.spec_proposed += ls as u64;
            self.metrics.spec_accepted += n_acc as u64;
            let seq = self.batcher.seq_mut(s);
            let was_unstarted = seq.generated.is_empty();
            let take = committed.len().min(seq.remaining());
            for &tok in committed.iter().take(take) {
                seq.commit(tok);
                committed_total += 1;
            }
            if was_unstarted && !seq.generated.is_empty() {
                first_token_slots.push(s);
            }
            let done = seq.is_done();
            // full acceptance leaves the draft cache one input behind
            let lag = if n_acc == ls && ls > 0 && !done {
                Some(proposals[&s][ls - 1])
            } else {
                None
            };
            self.draft.as_mut().unwrap().lag_token[s] = lag;
            if done {
                let released = self.batcher.release(s);
                finished.push((released.req.id, released.generated));
            }
        }

        let sim_s = self.charge_step(
            &acts,
            &union_activated,
            slots.len() * (1 + ls),
            ls, // draft steps
        );
        self.metrics.record_step(&acts, sim_s, committed_total);
        Ok((finished, first_token_slots))
    }

    /// Simulated cost of one target forward (+ draft steps) and EP load
    /// accounting. Returns simulated seconds.
    fn charge_step(
        &mut self,
        activated: &[usize],
        selected: &[ExpertSet],
        n_tokens: usize,
        draft_steps: usize,
    ) -> f64 {
        let mut sim = draft_steps as f64 * self.cost.draft_step();
        if let Some(pl) = &self.model.placement {
            let sel_refs: Vec<&ExpertSet> = selected.iter().collect();
            sim += self.cost.ep_step(pl, &sel_refs, n_tokens, &self.ep_cost);
            let max_load =
                selected.iter().map(|s| pl.max_load(s)).max().unwrap_or(0);
            self.metrics.max_gpu_load.add(max_load as f64);
        } else {
            let scaled = self.cost.scale_activations(activated);
            sim += self.cost.target_step(&scaled, n_tokens).total_seconds;
        }
        sim
    }
}

/// Draft-model wrapper tracking per-slot cache positions and catch-up debt.
struct DraftState {
    model: crate::model::DraftModel,
    pos: Vec<usize>,
    lag_token: Vec<Option<u32>>,
}

impl DraftState {
    fn new(model: crate::model::DraftModel, b_max: usize) -> DraftState {
        DraftState { model, pos: vec![0; b_max], lag_token: vec![None; b_max] }
    }

    /// During plain steps the draft ingests the same tokens as the target.
    fn shadow_step(
        &mut self,
        engine: &crate::runtime::Engine,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<()> {
        self.model.step(engine, tokens, pos)?;
        for (p, &np) in self.pos.iter_mut().zip(pos) {
            *p = (*p).max(np as usize + 1);
        }
        Ok(())
    }

    /// Feed the one missing input for rows that fully accepted last cycle.
    fn catch_up(
        &mut self,
        engine: &crate::runtime::Engine,
        batcher: &Batcher,
        slots: &[usize],
    ) -> Result<()> {
        if slots.iter().all(|&s| self.lag_token[s].is_none()) {
            return Ok(());
        }
        let b = self.pos.len();
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for &s in slots {
            let seq = batcher.seq(s);
            match self.lag_token[s] {
                Some(t) => {
                    tokens[s] = t as i32;
                    pos[s] = (seq.pos - 1) as i32;
                }
                None => {
                    // harmless re-write of the upcoming position
                    tokens[s] = seq.next_token as i32;
                    pos[s] = seq.pos as i32;
                }
            }
        }
        self.model.step(engine, &tokens, &pos)?;
        for &s in slots {
            self.lag_token[s] = None;
        }
        Ok(())
    }
}
