//! The incrementally-stepped serving core: continuous batching, the
//! speculative verify cycle, per-layer expert selection and cost accounting.
//! This is the L3 "leader" loop — everything on the request path runs here,
//! in rust.
//!
//! Unlike the old monolithic `Scheduler::run`, the loop is **step-scoped**:
//! callers own the cadence. [`ServeLoop::submit`] enqueues a request at any
//! time; every [`ServeLoop::step`] first admits queued requests into free
//! batch slots and then runs one decode/spec-verify cycle, so work that
//! arrives mid-flight joins the very next step instead of waiting for the
//! whole batch to drain. Finished sequences are surfaced in the returned
//! [`StepOutcome`] the moment their slot releases. [`ServeLoop::drain`]
//! (submit-all + step-until-done) reproduces the old batch-at-a-time
//! behaviour byte-for-byte — the `Scheduler` wrapper in
//! [`super::scheduler`] is exactly that.
//!
//! ## Speculative verify emulation (DESIGN.md §4)
//!
//! The compiled decode-step artifact advances one token per row, so a verify
//! forward over B×(1+L_s) tokens is emulated in two passes of (1+L_s)
//! sub-steps each:
//!
//!  * **pass 1 (scoring)**: vanilla routing, records every layer's gate
//!    scores for all verify tokens — the effective-batch G^{(l)};
//!  * **selection**: the policy picks S_l once per layer from those scores
//!    (with per-request grouping, exactly Algorithm 4's input);
//!  * **pass 2 (restricted)**: re-runs the sub-steps with every layer
//!    restricted to S_l; its logits drive acceptance and its KV writes are
//!    the ones that persist (positions beyond the accepted prefix are
//!    garbage-but-masked, verified by the kernel tests).
//!
//! The cost model charges one draft step per speculative token plus ONE
//! target forward over the effective batch — the two passes are an artifact
//! of the one-token-per-row compilation, not of the system being modeled.
//!
//! ## Chunked prefill (PR 2)
//!
//! With `prefill_chunk > 1`, rows in prefill phase advance by up to a whole
//! chunk of prompt tokens per step through the `prefill_attn_router`
//! artifact ([`MoeModel::prefill_chunk`]) while the remaining rows run one
//! ordinary decode forward; the cost model charges each chunk as one target
//! forward over its true token count, which amortizes the per-layer weight
//! stream and cuts TTFT. Chunk rows are parked on their next (token,
//! position) inside the decode forward — a harmless write the chunk then
//! overwrites — and the draft shadows every chunk token so spec cycles stay
//! aligned. Speculation remains gated on `prefill_rows == 0`, chunked or
//! not. Chunking never changes a request's own prefill routing (the policy
//! runs per chunk position), so a request's outputs are byte-identical to
//! the one-token walk under every policy when served solo, and under
//! row-independent policies in any mix (`rust/tests/prefill_equivalence.rs`).
//! Batch-coupled policies (batch/spec/gpu-aware) still see each step's
//! batch composition, which chunking — exactly like admission timing —
//! alters for concurrently decoding rows.
//!
//! ## Pluggable admission (PR 3)
//!
//! Which queued request takes a freed slot is decided by the
//! [`super::admission`] subsystem: `step()` fills free slots one policy
//! pick at a time (FIFO by default — byte-identical to the legacy
//! hard-coded queue — or priority / EDF / footprint-aware co-scheduling),
//! and [`ServeLoop::submit`] applies bounded-queue backpressure with typed
//! [`SubmitError`]s that the TCP worker converts into protocol error
//! replies. Under footprint admission every forward's router probabilities
//! feed decayed per-slot and per-class footprints ([`FootprintTracker`]),
//! which is what queued requests are scored against.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use super::admission::{
    AdmissionContext, AdmissionKind, AdmissionQueue, FootprintTracker, SubmitError,
};
use super::batcher::Batcher;
use super::request::{Phase, Request};
use super::speculative::{effective_batch_scores, greedy_accept};
use crate::config::ServeConfig;
use crate::ep::{EpCostModel, Placement};
use crate::memsim::{CostGeometry, DecodeCostModel, HardwareProfile};
use crate::metrics::ServeMetrics;
use crate::model::{argmax, MoeModel, PrefillInput, RoutingMode, StepInput};
use crate::selection::{
    admission_score, baselines::Vanilla, ExpertSet, ScoreMatrix, SelectionPolicy,
};

/// Result of one serving run (what `drain` + `report` produce).
#[derive(Debug)]
pub struct RunReport {
    pub metrics: ServeMetrics,
    /// request id → generated tokens.
    pub outputs: BTreeMap<u64, Vec<u32>>,
    /// request id → domain (for per-dataset reporting).
    pub domains: BTreeMap<u64, String>,
}

/// What one `step()` did — the server worker routes responses off this.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Request ids admitted into batch slots at the top of this step.
    pub admitted: Vec<u64>,
    /// Sequences that completed this step: (request id, generated tokens).
    pub finished: Vec<(u64, Vec<u32>)>,
    /// Live rows that were in prefill phase when the step ran.
    pub prefill_rows: usize,
    /// Live rows that were in decode phase when the step ran.
    pub decode_rows: usize,
    /// GENERATED tokens committed across all rows this step. Prompt
    /// advances are counted in [`StepOutcome::prefill_tokens`], never here
    /// — the split keeps throughput honest on long prompts.
    pub committed: u64,
    /// Prompt tokens consumed this step (one-token prefill advances and
    /// chunked-prefill tokens alike).
    pub prefill_tokens: u64,
    /// Simulated cost of this step, seconds.
    pub sim_seconds: f64,
    /// Whether this step ran a speculative verify cycle.
    pub speculative: bool,
    /// Requests still waiting in the admission queue after this step.
    pub queued: usize,
    /// Sequences still occupying batch slots after this step.
    pub running: usize,
}

/// Per-slot accounting carried from admission until the first generated
/// token commits (TTFT, per-class TTFT, deadline-miss accounting).
#[derive(Debug, Clone, Copy)]
struct PendingTtft {
    submit_sim: f64,
    class: u32,
    deadline_sim: Option<f64>,
}

/// The stepped serving core. Owns the model borrow, selection policy, cost
/// models, admission queue, batcher, draft state and metrics for one
/// serving lifetime.
pub struct ServeLoop<'m> {
    model: &'m mut MoeModel,
    cfg: ServeConfig,
    policy: Box<dyn SelectionPolicy>,
    cost: DecodeCostModel,
    ep_cost: EpCostModel,
    batcher: Batcher,
    /// Bounded admission queue + pluggable policy (see
    /// [`super::admission`]).
    queue: AdmissionQueue,
    /// Observed-router-score footprints (FootprintAware admission only).
    tracker: Option<FootprintTracker>,
    metrics: ServeMetrics,
    outputs: BTreeMap<u64, Vec<u32>>,
    domains: BTreeMap<u64, String>,
    draft: Option<DraftState>,
    /// Per-slot TTFT/deadline state, pending until the first token commits.
    ttft_pending: Vec<Option<PendingTtft>>,
    started: Instant,
}

impl<'m> ServeLoop<'m> {
    pub fn new(model: &'m mut MoeModel, cfg: ServeConfig) -> Result<ServeLoop<'m>> {
        if cfg.prefill_chunk > 1 {
            if !model.has_prefill() {
                anyhow::bail!(
                    "prefill_chunk={} needs the chunked-prefill artifact, which preset \
                     '{}' does not ship — rebuild with `make artifacts` or use \
                     prefill_chunk=1",
                    cfg.prefill_chunk,
                    model.dims().name
                );
            }
            if cfg.prefill_chunk > model.dims().max_seq {
                anyhow::bail!(
                    "prefill_chunk={} exceeds the compiled sequence length {}",
                    cfg.prefill_chunk,
                    model.dims().max_seq
                );
            }
        }
        let cost = DecodeCostModel::new(
            HardwareProfile::by_name(&cfg.hardware)?,
            CostGeometry::for_preset(&cfg.preset)?,
        );
        let policy = cfg.policy.build();
        if let Some(ep) = &cfg.ep {
            model.placement = Some(Placement::new(
                model.dims().n_experts,
                ep.n_gpus,
                ep.placement,
            ));
        }
        let mut sl = ServeLoop {
            model,
            cfg,
            policy,
            cost,
            ep_cost: EpCostModel::default(),
            batcher: Batcher::new(1, 1),
            queue: AdmissionQueue::new(AdmissionKind::Fifo, 0),
            tracker: None,
            metrics: ServeMetrics::new(0),
            outputs: BTreeMap::new(),
            domains: BTreeMap::new(),
            draft: None,
            ttft_pending: Vec::new(),
            started: Instant::now(),
        };
        sl.reset()?;
        Ok(sl)
    }

    /// Forget all serving state (queue, batcher, metrics, caches, draft)
    /// and start a fresh run. Queued-but-unserved requests are dropped.
    pub fn reset(&mut self) -> Result<()> {
        let b_max = self.model.max_batch();
        self.batcher = Batcher::new(b_max, self.cfg.batch_size.min(b_max));
        self.queue = AdmissionQueue::new(self.cfg.admission, self.cfg.max_queue);
        self.tracker = (self.cfg.admission == AdmissionKind::FootprintAware)
            .then(|| FootprintTracker::new(self.model.dims().n_experts, b_max));
        self.metrics = ServeMetrics::new(self.model.dims().n_layers);
        self.outputs.clear();
        self.domains.clear();
        self.ttft_pending = vec![None; b_max];
        self.model.reset();
        self.draft = if self.cfg.spec_len > 0 {
            Some(DraftState::new(
                crate::model::DraftModel::new(self.model.engine())?,
                b_max,
            ))
        } else {
            None
        };
        self.started = Instant::now();
        Ok(())
    }

    /// Enqueue a request. It joins the next `step()` if a slot is free.
    ///
    /// Rejections are typed and immediate: a full bounded queue returns
    /// [`SubmitError::QueueFull`] (backpressure — the TCP worker surfaces
    /// it as a protocol error carrying the request id), and requests that
    /// could never be served (empty prompt, prompt beyond the compiled
    /// sequence length) are refused here instead of poisoning the batch
    /// mid-step.
    pub fn submit(&mut self, req: Request) -> std::result::Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt { id: req.id });
        }
        let max_seq = self.model.dims().max_seq;
        // The full request must fit the KV window: positions ≥ max_seq
        // silently drop their cache writes, so a request whose generation
        // budget overruns the window would decode garbage mid-flight. The
        // last generated token is committed without being fed back, so the
        // highest position a request touches is prompt + budget − 2 —
        // hence the `max_seq + 1` bound.
        if req.prompt.len() + req.max_new_tokens > max_seq + 1 {
            return Err(SubmitError::PromptTooLong {
                id: req.id,
                len: req.prompt.len(),
                budget: req.max_new_tokens,
                max_seq,
            });
        }
        let id = req.id;
        let domain = req.domain.clone();
        match self.queue.submit(req, self.metrics.sim_seconds) {
            Ok(()) => {
                self.domains.insert(id, domain);
                Ok(())
            }
            Err(e) => {
                self.metrics.queue_rejected += 1;
                Err(e)
            }
        }
    }

    /// Queued or running work remains.
    pub fn has_work(&self) -> bool {
        self.batcher.running() > 0 || !self.queue.is_empty()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.batcher.running()
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// One serving step: admit newly queued requests into free slots, then
    /// run one decode step (or speculative verify cycle when all live rows
    /// are in decode phase and speculation is on).
    pub fn step(&mut self) -> Result<StepOutcome> {
        let wall0 = Instant::now();
        let sim_before = self.metrics.sim_seconds;
        let was_running = self.batcher.running() > 0;

        let admitted = self.admit(sim_before, was_running);
        self.metrics.queue_depth.add(self.queue.len() as f64);

        let slots = self.batcher.live_slots();
        if slots.is_empty() {
            return Ok(StepOutcome {
                admitted,
                queued: self.queue.len(),
                ..StepOutcome::default()
            });
        }

        let prefill_rows =
            slots.iter().filter(|&&s| self.batcher.seq(s).phase == Phase::Prefill).count();
        let decode_rows = slots.len() - prefill_rows;
        // Spec-verify cycles need an all-decode batch; the gate is on the
        // rows' phase, so a row mid-chunk-prefill keeps speculation off
        // exactly like a one-token prefill row does.
        let speculative = self.cfg.spec_len > 0 && prefill_rows == 0;
        let committed_before = self.metrics.tokens_out;
        let prompt_before = self.metrics.tokens_prompt;

        let (finished, first_token_slots) = if speculative {
            self.spec_cycle(&slots)?
        } else {
            self.serve_step(&slots)?
        };
        let prefill_tokens = self.metrics.tokens_prompt - prompt_before;
        if prefill_tokens > 0 {
            self.metrics.prefill_tokens_per_step.add(prefill_tokens as f64);
        }

        // Sim clock has advanced by this step's cost; TTFT counts it.
        let now = self.metrics.sim_seconds;
        for s in first_token_slots {
            if let Some(p) = self.ttft_pending[s].take() {
                let missed = p.deadline_sim.map(|d| now > d);
                self.metrics.record_ttft(now - p.submit_sim, p.class, missed);
            }
        }
        for (id, tokens) in &finished {
            self.outputs.insert(*id, tokens.clone());
        }
        self.metrics.requests_done = self.outputs.len() as u64;
        self.metrics.wall_step_latency.record_seconds(wall0.elapsed().as_secs_f64());

        Ok(StepOutcome {
            admitted,
            finished,
            prefill_rows,
            decode_rows,
            committed: self.metrics.tokens_out - committed_before,
            prefill_tokens,
            sim_seconds: self.metrics.sim_seconds - sim_before,
            speculative,
            queued: self.queue.len(),
            running: self.batcher.running(),
        })
    }

    /// Fill free batch slots from the admission queue, one policy pick at a
    /// time. Each pick sees the rows admitted before it in the same step
    /// (their footprints are seeded from class profiles at admission), so
    /// FootprintAware co-scheduling can assemble a correlated batch from a
    /// deep queue rather than only reacting to long-running rows.
    fn admit(&mut self, now_sim: f64, was_running: bool) -> Vec<u64> {
        let mut admitted = Vec::new();
        let top_k = self.model.dims().top_k;
        while self.batcher.has_capacity() && !self.queue.is_empty() {
            let running_slots = self.batcher.live_slots();
            let ctx = AdmissionContext {
                now_sim,
                tracker: self.tracker.as_ref(),
                running_slots: &running_slots,
                placement: self.model.placement.as_ref(),
                top_k,
            };
            let Some(entry) = self.queue.pop_next(&ctx) else { break };
            // Footprint-overlap gauge: what the greedy objective predicted
            // for the admitted candidate against the batch it joins. This
            // re-scores the winner (the policy's internal scores stay
            // internal); the cost is one overlap per ADMISSION — noise next
            // to the model forward each step runs.
            if let Some(tr) = &self.tracker {
                let union = tr.running_union(&running_slots, top_k);
                if !union.is_empty() {
                    if let Some(fp) = tr.predict(&entry.req) {
                        self.metrics.footprint_overlap.add(admission_score(
                            &fp.top_set(top_k),
                            &union,
                            self.model.placement.as_ref(),
                        ));
                    }
                }
            }
            let id = entry.req.id;
            let class = entry.req.priority;
            self.metrics.record_queue_wait(now_sim - entry.submit_sim);
            if was_running {
                self.metrics.admitted_in_flight += 1;
            }
            let slot = self.batcher.place(entry.req);
            if let Some(tr) = &mut self.tracker {
                tr.on_admit(slot, &self.batcher.seq(slot).req);
            }
            self.ttft_pending[slot] = Some(PendingTtft {
                submit_sim: entry.submit_sim,
                class,
                deadline_sim: entry.deadline_sim,
            });
            admitted.push(id);
        }
        admitted
    }

    /// Release a finished sequence's slot everywhere slot state lives.
    /// (`ttft_pending` is left alone: the first-token commit that finished
    /// this sequence is harvested after the step body returns, and the next
    /// admission into the slot overwrites the entry.)
    fn release_slot(&mut self, slot: usize) -> super::request::SeqState {
        if let Some(tr) = &mut self.tracker {
            tr.release(slot);
        }
        self.batcher.release(slot)
    }

    /// Current KV position of the sequence occupying `slot`, if any
    /// (prefill equivalence tests compare mid-flight positions).
    pub fn slot_pos(&self, slot: usize) -> Option<usize> {
        self.batcher.get(slot).map(|s| s.pos)
    }

    /// Step until all submitted work completes.
    pub fn drain(&mut self) -> Result<()> {
        while self.has_work() {
            self.step()?;
        }
        Ok(())
    }

    /// Drop the per-request run-report bookkeeping (outputs + domains).
    ///
    /// Long-lived callers that consume results from [`StepOutcome::finished`]
    /// (the live TCP worker) must call this periodically: the accumulators
    /// exist only for [`ServeLoop::report`], and on a server that never
    /// reports they would otherwise grow without bound. After discarding,
    /// a later `report()` only covers requests finishing after this call.
    pub fn discard_finished(&mut self) {
        self.outputs.clear();
        // One pass to collect every id still in flight (queued or running),
        // then a set-lookup retain — this runs every server step, so it
        // must stay O(n log n) in the backlog, not O(n²).
        let mut in_flight: std::collections::BTreeSet<u64> = self.queue.ids().collect();
        for s in self.batcher.live_slots() {
            in_flight.insert(self.batcher.seq(s).req.id);
        }
        self.domains.retain(|id, _| in_flight.contains(id));
    }

    /// Close out the run: stamp wall-clock and move the accumulated outputs
    /// into a report. The loop can keep serving afterwards (metrics keep
    /// accumulating; outputs/domains start empty again).
    pub fn report(&mut self) -> RunReport {
        self.metrics.wall_seconds = self.started.elapsed().as_secs_f64();
        self.metrics.requests_done = self.outputs.len() as u64;
        RunReport {
            metrics: self.metrics.clone(),
            outputs: std::mem::take(&mut self.outputs),
            domains: std::mem::take(&mut self.domains),
        }
    }

    /// One non-speculative serving step. With `prefill_chunk > 1`, rows in
    /// prefill phase advance by up to a whole chunk through the prefill
    /// artifact while the remaining rows run one ordinary decode step; with
    /// the default chunk of 1 this is byte-identical to the legacy
    /// one-token-per-step path.
    fn serve_step(
        &mut self,
        slots: &[usize],
    ) -> Result<(Vec<(u64, Vec<u32>)>, Vec<usize>)> {
        let cap = self.model.prefill_capacity();
        let max_seq = self.model.dims().max_seq;
        // Rows taking the chunked path this step. The chunk artifact slices
        // a fixed `cap`-wide cache window, so rows whose window would
        // overhang `max_seq` finish their prompt one token per step
        // instead; single-token advances (one-token tails, 1-token prompts)
        // ride the shared decode forward below — a dedicated chunk forward
        // for one token would cost MORE than the legacy path.
        let mut plans: Vec<ChunkPlan> = if self.cfg.prefill_chunk > 1 {
            slots
                .iter()
                .filter_map(|&s| {
                    let seq = self.batcher.seq(s);
                    if seq.phase != Phase::Prefill || seq.pos + cap > max_seq {
                        return None;
                    }
                    let n = self.cfg.prefill_chunk.min(seq.prompt_remaining());
                    if n < 2 {
                        return None;
                    }
                    Some(ChunkPlan {
                        slot: s,
                        start: seq.pos,
                        tokens: seq.req.prompt[seq.prompt_idx..seq.prompt_idx + n].to_vec(),
                    })
                })
                .collect()
        } else {
            Vec::new()
        };
        if plans.is_empty() {
            return self.plain_step(slots, &[]);
        }

        let rest: Vec<usize> = slots
            .iter()
            .copied()
            .filter(|s| !plans.iter().any(|p| p.slot == *s))
            .collect();

        let mut finished = Vec::new();
        let mut first_token_slots = Vec::new();
        if !rest.is_empty() {
            // Park each chunk row at (first chunk token, its position): the
            // decode step's cache write there is overwritten by the chunk
            // below, and the draft shadow of the park IS the chunk's first
            // shadow token — the same harmless-rewrite idiom as
            // `DraftState::catch_up`.
            let park: Vec<(usize, u32, usize)> =
                plans.iter().map(|p| (p.slot, p.tokens[0], p.start)).collect();
            let (f, fts) = self.plain_step(&rest, &park)?;
            finished.extend(f);
            first_token_slots.extend(fts);
        }

        for plan in &mut plans {
            let mut consumed = 0usize;
            let mut last_logits: Option<Vec<f32>> = None;
            while consumed < plan.tokens.len() {
                let start = plan.start + consumed;
                if start + cap > max_seq {
                    break; // remainder continues one-token-per-step
                }
                let n = (plan.tokens.len() - consumed).min(cap);
                let out = self.model.prefill_chunk(&PrefillInput {
                    row: plan.slot,
                    start_pos: start,
                    tokens: &plan.tokens[consumed..consumed + n],
                    policy: self.policy.as_ref(),
                    collect_probs: self.tracker.is_some(),
                })?;
                // One target forward over the true chunk geometry: n tokens
                // amortize the per-layer weight stream — the TTFT lever.
                let sim_s = self.charge_step(&out.activated, &out.selected, n, 0);
                self.metrics.record_prefill(&out.activated, sim_s, n as u64);
                // Prompt-time router scores feed the row's footprint: every
                // chunk position is one observation for the slot's EMA.
                if let (Some(tr), Some(probs)) = (&mut self.tracker, &out.probs) {
                    let layers: Vec<&ScoreMatrix> = probs.iter().collect();
                    for i in 0..n {
                        tr.observe_step(plan.slot, i, &layers);
                    }
                }
                last_logits = Some(out.last_logits);
                consumed += n;
            }
            // A max_seq-boundary break leaves a tail for later steps: the
            // draft must only shadow what the target actually consumed.
            plan.tokens.truncate(consumed);
            let am = argmax(&last_logits.expect("chunk ran at least once")) as u32;
            let seq = self.batcher.seq_mut(plan.slot);
            if seq.advance_prefill_by(consumed, am) {
                // the chunk's last logits committed the first GENERATED
                // token; record_prefill only counted the prompt tokens
                first_token_slots.push(plan.slot);
                self.metrics.tokens_out += 1;
            }
            if seq.is_done() {
                let done = self.release_slot(plan.slot);
                finished.push((done.req.id, done.generated));
            }
        }

        // The draft shadows every chunk token so its cache stays aligned
        // for upcoming spec cycles. Token 0 of each chunk was shadowed by
        // the decode sub-step's park when one ran.
        let shadow_from = if rest.is_empty() { 0 } else { 1 };
        self.shadow_chunks(&plans, shadow_from)?;

        Ok((finished, first_token_slots))
    }

    /// One ordinary continuous-batching step over `slots` (prefill and/or
    /// decode rows, one token each). `park` entries pin rows OUTSIDE
    /// `slots` to a (token, position) that a chunk invocation will
    /// overwrite this same step, keeping their target/draft caches clear of
    /// the pos-0 garbage padded rows otherwise receive. Returns finished
    /// sequences and the slots that committed their first generated token.
    fn plain_step(
        &mut self,
        slots: &[usize],
        park: &[(usize, u32, usize)],
    ) -> Result<(Vec<(u64, Vec<u32>)>, Vec<usize>)> {
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let mut tokens = vec![0i32; b_max];
        let mut pos = vec![0i32; b_max];
        for &s in slots {
            let seq = self.batcher.seq(s);
            tokens[s] = seq.next_token as i32;
            pos[s] = seq.pos as i32;
        }
        for &(s, tok, p) in park {
            debug_assert!(!slots.contains(&s), "parked slot also stepped");
            tokens[s] = tok as i32;
            pos[s] = p as i32;
        }
        let groups: Vec<Vec<usize>> = slots.iter().map(|&s| vec![s]).collect();
        let out = self.model.step(&StepInput {
            tokens: &tokens,
            pos: &pos,
            rows: slots,
            requests: &groups,
            mode: RoutingMode::Policy(self.policy.as_ref()),
            // Footprint admission learns from every forward's router probs.
            collect_probs: self.tracker.is_some(),
        })?;

        // Decayed-EMA footprint update from this step's observed scores.
        if let (Some(tr), Some(scores)) = (&mut self.tracker, &out.scores) {
            let layers: Vec<&ScoreMatrix> = scores.iter().map(|(_, p)| p).collect();
            for &s in slots {
                tr.observe_step(s, s, &layers);
            }
        }

        // The draft model shadows the token stream so its cache stays warm
        // for upcoming speculative cycles.
        if let Some(d) = self.draft.as_mut() {
            d.shadow_step(self.model.engine(), &tokens, &pos)?;
        }

        let logits = out.logits.as_f32()?;
        let mut committed = 0u64;
        let mut prompt_consumed = 0u64;
        let mut finished = Vec::new();
        let mut first_token_slots = Vec::new();
        for &s in slots {
            let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
            let seq = self.batcher.seq_mut(s);
            let was_unstarted = seq.generated.is_empty();
            match seq.phase {
                Phase::Prefill => {
                    prompt_consumed += 1;
                    if seq.advance_prefill(am) {
                        committed += 1;
                    }
                }
                Phase::Decode => {
                    seq.commit(am);
                    committed += 1;
                }
            }
            if was_unstarted && !seq.generated.is_empty() {
                first_token_slots.push(s);
            }
            if seq.is_done() {
                let done = self.release_slot(s);
                finished.push((done.req.id, done.generated));
            }
        }

        let sim_s = self.charge_step(&out.activated, &out.selected, slots.len(), 0);
        self.metrics.record_step(&out.activated, sim_s, committed);
        self.metrics.tokens_prompt += prompt_consumed;
        Ok((finished, first_token_slots))
    }

    /// Feed chunk tokens `shadow_from..` of every plan through the draft
    /// model (one call per chunk offset; rows without a token at that
    /// offset are parked on a position their next real shadow overwrites).
    fn shadow_chunks(&mut self, plans: &[ChunkPlan], shadow_from: usize) -> Result<()> {
        if self.draft.is_none() {
            return Ok(());
        }
        let b_max = self.model.max_batch();
        let longest = plans.iter().map(|p| p.tokens.len()).max().unwrap_or(0);
        for j in shadow_from..longest {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            // harmless parking for every live row not shadowing offset j
            for s in self.batcher.live_slots() {
                let seq = self.batcher.seq(s);
                tokens[s] = seq.next_token as i32;
                pos[s] = seq.pos as i32;
            }
            for p in plans {
                if j < p.tokens.len() {
                    tokens[p.slot] = p.tokens[j] as i32;
                    pos[p.slot] = (p.start + j) as i32;
                }
            }
            let d = self.draft.as_mut().unwrap();
            d.shadow_step(self.model.engine(), &tokens, &pos)?;
        }
        Ok(())
    }

    /// One speculative verify cycle (all rows in decode phase).
    fn spec_cycle(
        &mut self,
        slots: &[usize],
    ) -> Result<(Vec<(u64, Vec<u32>)>, Vec<usize>)> {
        let ls = self.cfg.spec_len;
        let b_max = self.model.max_batch();
        let vocab = self.model.dims().vocab;
        let n_layers = self.model.dims().n_layers;
        let n_experts = self.model.dims().n_experts;

        // ---- draft proposals (plus catch-up for fully-accepted rows) ----
        let draft = self.draft.as_mut().expect("spec cycle without draft state");
        draft.catch_up(self.model.engine(), &self.batcher, slots)?;
        let mut proposals: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        {
            let mut dtok = vec![0i32; b_max];
            let mut dpos = vec![0i32; b_max];
            for &s in slots {
                let seq = self.batcher.seq(s);
                dtok[s] = seq.next_token as i32;
                dpos[s] = seq.pos as i32;
                proposals.insert(s, Vec::with_capacity(ls));
            }
            for _ in 0..ls {
                let logits_t = draft.model.step(self.model.engine(), &dtok, &dpos)?;
                let logits = logits_t.as_f32()?;
                for &s in slots {
                    let d = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                    proposals.get_mut(&s).unwrap().push(d);
                    dtok[s] = d as i32;
                    dpos[s] += 1;
                }
            }
            for &s in slots {
                draft.pos[s] = self.batcher.seq(s).pos + ls; // processed up to pos+ls-1
            }
        }

        // verify inputs per sub-step j: j=0 → next_token, j>=1 → draft j-1
        let verify_tok = |batcher: &Batcher, s: usize, j: usize| -> u32 {
            if j == 0 {
                batcher.seq(s).next_token
            } else {
                proposals[&s][j - 1]
            }
        };

        // ---- pass 1: scoring (vanilla routing, collect per-layer probs) --
        let vanilla = Vanilla;
        let groups_single: Vec<Vec<usize>> = slots.iter().map(|&s| vec![s]).collect();
        let mut pass1_scores: Vec<Vec<(ScoreMatrix, ScoreMatrix)>> = Vec::with_capacity(ls + 1);
        for j in 0..=ls {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            for &s in slots {
                tokens[s] = verify_tok(&self.batcher, s, j) as i32;
                pos[s] = (self.batcher.seq(s).pos + j) as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: slots,
                requests: &groups_single,
                mode: RoutingMode::Policy(&vanilla),
                collect_probs: true,
            })?;
            pass1_scores.push(out.scores.unwrap());
        }

        // Footprints observe the committed-token sub-step (j = 0): the
        // speculative tail is provisional and may be rejected.
        if let Some(tr) = &mut self.tracker {
            let layers: Vec<&ScoreMatrix> =
                pass1_scores[0].iter().map(|(_, p)| p).collect();
            for &s in slots {
                tr.observe_step(s, s, &layers);
            }
        }

        // ---- per-layer selection over the effective batch ---------------
        let mut sets: Vec<ExpertSet> = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let logits_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].0).collect();
            let probs_steps: Vec<&ScoreMatrix> =
                pass1_scores.iter().map(|layers| &layers[l].1).collect();
            let (eff_logits, _) = effective_batch_scores(&logits_steps, slots);
            let (eff_probs, groups) = effective_batch_scores(&probs_steps, slots);
            let rows: Vec<usize> = (0..eff_probs.n_tokens()).collect();
            let ctx = crate::selection::SelectionContext {
                probs: &eff_probs,
                logits: &eff_logits,
                rows: &rows,
                requests: &groups,
                colsum_hint: None,
                placement: self.model.placement.as_ref(),
                top_k: self.model.dims().top_k,
            };
            sets.push(self.policy.select(&ctx));
        }

        // ---- pass 2: restricted run; drives acceptance -------------------
        let mut target_argmax: BTreeMap<usize, Vec<u32>> =
            slots.iter().map(|&s| (s, Vec::with_capacity(ls + 1))).collect();
        let mut union_activated: Vec<ExpertSet> =
            (0..n_layers).map(|_| ExpertSet::empty(n_experts)).collect();
        let mut acts = vec![0usize; n_layers];
        for j in 0..=ls {
            let mut tokens = vec![0i32; b_max];
            let mut pos = vec![0i32; b_max];
            for &s in slots {
                tokens[s] = verify_tok(&self.batcher, s, j) as i32;
                pos[s] = (self.batcher.seq(s).pos + j) as i32;
            }
            let out = self.model.step(&StepInput {
                tokens: &tokens,
                pos: &pos,
                rows: slots,
                requests: &groups_single,
                mode: RoutingMode::Restricted(&sets),
                collect_probs: false,
            })?;
            let logits = out.logits.as_f32()?;
            for &s in slots {
                let am = argmax(&logits[s * vocab..(s + 1) * vocab]) as u32;
                target_argmax.get_mut(&s).unwrap().push(am);
            }
            for (u, sel) in union_activated.iter_mut().zip(&out.selected) {
                u.union_with(sel);
            }
        }
        for (a, u) in acts.iter_mut().zip(&union_activated) {
            *a = u.len();
        }

        // ---- acceptance & commit -----------------------------------------
        let mut committed_total = 0u64;
        let mut finished = Vec::new();
        let mut first_token_slots = Vec::new();
        for &s in slots {
            let (n_acc, committed) = greedy_accept(&proposals[&s], &target_argmax[&s]);
            self.metrics.spec_proposed += ls as u64;
            self.metrics.spec_accepted += n_acc as u64;
            let seq = self.batcher.seq_mut(s);
            let was_unstarted = seq.generated.is_empty();
            let take = committed.len().min(seq.remaining());
            for &tok in committed.iter().take(take) {
                seq.commit(tok);
                committed_total += 1;
            }
            if was_unstarted && !seq.generated.is_empty() {
                first_token_slots.push(s);
            }
            let done = seq.is_done();
            // full acceptance leaves the draft cache one input behind
            let lag = if n_acc == ls && ls > 0 && !done {
                Some(proposals[&s][ls - 1])
            } else {
                None
            };
            self.draft.as_mut().unwrap().lag_token[s] = lag;
            if done {
                let released = self.release_slot(s);
                finished.push((released.req.id, released.generated));
            }
        }

        let sim_s = self.charge_step(
            &acts,
            &union_activated,
            slots.len() * (1 + ls),
            ls, // draft steps
        );
        self.metrics.record_step(&acts, sim_s, committed_total);
        Ok((finished, first_token_slots))
    }

    /// Simulated cost of one target forward (+ draft steps) and EP load
    /// accounting. Returns simulated seconds.
    fn charge_step(
        &mut self,
        activated: &[usize],
        selected: &[ExpertSet],
        n_tokens: usize,
        draft_steps: usize,
    ) -> f64 {
        let mut sim = draft_steps as f64 * self.cost.draft_step();
        if let Some(pl) = &self.model.placement {
            let sel_refs: Vec<&ExpertSet> = selected.iter().collect();
            sim += self.cost.ep_step(pl, &sel_refs, n_tokens, &self.ep_cost);
            let max_load =
                selected.iter().map(|s| pl.max_load(s)).max().unwrap_or(0);
            self.metrics.max_gpu_load.add(max_load as f64);
        } else {
            let scaled = self.cost.scale_activations(activated);
            sim += self.cost.target_step(&scaled, n_tokens).total_seconds;
        }
        sim
    }
}

/// One row's chunk of prompt tokens for this serving step.
struct ChunkPlan {
    slot: usize,
    /// Row position before the chunk.
    start: usize,
    /// Prompt tokens to consume this step (oldest first).
    tokens: Vec<u32>,
}

/// Draft-model wrapper tracking per-slot cache positions and catch-up debt.
struct DraftState {
    model: crate::model::DraftModel,
    pos: Vec<usize>,
    lag_token: Vec<Option<u32>>,
}

impl DraftState {
    fn new(model: crate::model::DraftModel, b_max: usize) -> DraftState {
        DraftState { model, pos: vec![0; b_max], lag_token: vec![None; b_max] }
    }

    /// During plain steps the draft ingests the same tokens as the target.
    fn shadow_step(
        &mut self,
        engine: &crate::runtime::Engine,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<()> {
        self.model.step(engine, tokens, pos)?;
        for (p, &np) in self.pos.iter_mut().zip(pos) {
            *p = (*p).max(np as usize + 1);
        }
        Ok(())
    }

    /// Feed the one missing input for rows that fully accepted last cycle.
    fn catch_up(
        &mut self,
        engine: &crate::runtime::Engine,
        batcher: &Batcher,
        slots: &[usize],
    ) -> Result<()> {
        if slots.iter().all(|&s| self.lag_token[s].is_none()) {
            return Ok(());
        }
        let b = self.pos.len();
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for &s in slots {
            let seq = batcher.seq(s);
            match self.lag_token[s] {
                Some(t) => {
                    tokens[s] = t as i32;
                    pos[s] = (seq.pos - 1) as i32;
                }
                None => {
                    // harmless re-write of the upcoming position
                    tokens[s] = seq.next_token as i32;
                    pos[s] = seq.pos as i32;
                }
            }
        }
        self.model.step(engine, &tokens, &pos)?;
        for &s in slots {
            self.lag_token[s] = None;
        }
        Ok(())
    }
}
