//! Pluggable admission: which queued request gets the next free batch slot.
//!
//! XShare's central observation is that *batch composition* determines how
//! much expert sharing is achievable — requests with correlated routing
//! share experts cheaply, heterogeneous ones don't (§6). Admission is the
//! serving system's one lever over composition, so this module pulls it out
//! of the batcher into an [`AdmissionPolicy`] trait with four
//! implementations:
//!
//! * [`AdmissionKind::Fifo`] — submission order, byte-identical to the
//!   pre-refactor hard-coded queue (pinned by the `admission` test suite's
//!   equivalence property).
//! * [`AdmissionKind::Priority`] — strict priority classes
//!   ([`Request::priority`], higher first), FIFO within a class.
//! * [`AdmissionKind::SloEdf`] — earliest-deadline-first on each request's
//!   TTFT deadline ([`Request::deadline_ms`], measured from submission on
//!   the simulated clock); requests without a deadline run after all
//!   deadlined ones, FIFO among themselves. Deadline misses are counted in
//!   [`crate::metrics::ServeMetrics::deadline_misses`].
//! * [`AdmissionKind::FootprintAware`] — the headline: predict each queued
//!   request's expert footprint from router scores observed for its traffic
//!   class ([`FootprintTracker`]), then greedily admit the candidate whose
//!   predicted expert set overlaps most with what the running rows already
//!   activate ([`crate::selection::admission_score`] — the paper's modular
//!   greedy objective applied at admission time). Under expert parallelism
//!   the overlap is MaxLoad-weighted via the placement. Ties and cold
//!   starts (no observed scores yet) fall back to FIFO order, so the
//!   policy degrades to FIFO rather than starving on an uninformative
//!   tracker. A candidate never waits for a "better" batch: every free
//!   slot is filled whenever the queue is non-empty, so footprint
//!   admission reorders the queue but never idles capacity.
//!
//! The queue itself ([`AdmissionQueue`]) is bounded: `max_queue > 0`
//! enables backpressure and [`AdmissionQueue::submit`] returns a typed
//! [`SubmitError::QueueFull`] that the TCP worker surfaces to the client as
//! a protocol-level error reply carrying the request id (no silently
//! dropped jobs).

use std::collections::{BTreeMap, VecDeque};

use crate::coordinator::request::Request;
use crate::coordinator::speculative::SpecDepthController;
use crate::ep::Placement;
use crate::selection::{admission_score, ExpertSet, Footprint, ScoreMatrix};

/// Which admission policy a deployment runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionKind {
    /// Submission order (default; byte-identical to the legacy batcher).
    Fifo,
    /// Higher [`Request::priority`] first, FIFO within a class.
    Priority,
    /// Earliest TTFT deadline first; deadline-less requests go last.
    SloEdf,
    /// Maximal expected expert-set overlap with the running batch.
    FootprintAware,
}

impl AdmissionKind {
    /// Parse the `--admission` / config-file spelling.
    pub fn parse(s: &str) -> Result<AdmissionKind, String> {
        match s {
            "fifo" => Ok(AdmissionKind::Fifo),
            "priority" => Ok(AdmissionKind::Priority),
            "edf" | "slo-edf" => Ok(AdmissionKind::SloEdf),
            "footprint" => Ok(AdmissionKind::FootprintAware),
            other => Err(format!(
                "unknown admission policy '{other}' (fifo | priority | edf | footprint)"
            )),
        }
    }

    /// Instantiate the policy object.
    pub fn build(&self) -> Box<dyn AdmissionPolicy> {
        match self {
            AdmissionKind::Fifo => Box::new(Fifo),
            AdmissionKind::Priority => Box::new(Priority),
            AdmissionKind::SloEdf => Box::new(SloEdf),
            AdmissionKind::FootprintAware => Box::new(FootprintAware),
        }
    }
}

impl std::fmt::Display for AdmissionKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionKind::Fifo => write!(f, "fifo"),
            AdmissionKind::Priority => write!(f, "priority"),
            AdmissionKind::SloEdf => write!(f, "edf"),
            AdmissionKind::FootprintAware => write!(f, "footprint"),
        }
    }
}

/// Typed submit-time rejection. Every variant carries the request id so the
/// wire layer can answer the exact request that was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is at capacity (backpressure).
    QueueFull { id: u64, depth: usize, max_queue: usize },
    /// Prompt plus generation budget cannot fit the compiled KV-cache
    /// window (positions ≥ max_seq would silently drop their cache
    /// writes mid-decode).
    PromptTooLong { id: u64, len: usize, budget: usize, max_seq: usize },
    /// Empty prompts have no first token to feed.
    EmptyPrompt { id: u64 },
}

impl SubmitError {
    /// The rejected request's id.
    pub fn id(&self) -> u64 {
        match *self {
            SubmitError::QueueFull { id, .. }
            | SubmitError::PromptTooLong { id, .. }
            | SubmitError::EmptyPrompt { id } => id,
        }
    }

    /// The same error re-attributed to another request id (the TCP worker
    /// remaps client ids onto worker-unique internal ids before submitting;
    /// the client-facing reply wants the original).
    pub fn with_id(self, id: u64) -> SubmitError {
        match self {
            SubmitError::QueueFull { depth, max_queue, .. } => {
                SubmitError::QueueFull { id, depth, max_queue }
            }
            SubmitError::PromptTooLong { len, budget, max_seq, .. } => {
                SubmitError::PromptTooLong { id, len, budget, max_seq }
            }
            SubmitError::EmptyPrompt { .. } => SubmitError::EmptyPrompt { id },
        }
    }

    /// Stable machine-readable error code for the wire protocol.
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::QueueFull { .. } => "queue_full",
            SubmitError::PromptTooLong { .. } => "prompt_too_long",
            SubmitError::EmptyPrompt { .. } => "empty_prompt",
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { id, depth, max_queue } => write!(
                f,
                "queue full: request {id} rejected at depth {depth} (max_queue {max_queue})"
            ),
            SubmitError::PromptTooLong { id, len, budget, max_seq } => write!(
                f,
                "prompt too long: request {id} needs {len} prompt + {budget} \
                 generated tokens but the compiled sequence length is {max_seq}"
            ),
            SubmitError::EmptyPrompt { id } => {
                write!(f, "empty prompt: request {id} has no tokens to feed")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// A queued request plus the admission metadata policies order by.
#[derive(Debug, Clone)]
pub struct QueuedEntry {
    pub req: Request,
    /// Sim-clock at the ORIGINAL submission (TTFT anchoring, EDF
    /// deadlines). Preserved across eviction requeues.
    pub submit_sim: f64,
    /// Sim-clock at which the entry joined THIS queue stint (fresh
    /// submission or eviction requeue). Queue-wait accounting measures
    /// from here, so a requeued row's time being *served* before its
    /// eviction never counts as queue wait.
    pub enqueue_sim: f64,
    /// Monotone submission counter — the FIFO tiebreak every policy
    /// ultimately falls back to.
    pub seq_no: u64,
    /// Absolute TTFT deadline on the sim clock, from
    /// [`Request::deadline_ms`].
    pub deadline_sim: Option<f64>,
    /// Admissions this entry has been passed over for (its queue-wait
    /// measured in frees, which is scale-free where sim-seconds are not).
    /// Drives the [`aging_bonus`] starvation guard.
    pub skipped: u64,
}

/// Acceptance-prior state for the spec-grouping admission refinement: the
/// ragged verify's padded geometry is densest when co-running rows draft
/// at similar depths, so footprint admission prefers co-admitting classes
/// whose acceptance priors match the running rows' (present only when
/// adaptive speculation is on).
pub struct SpecGrouping<'a> {
    /// The per-class acceptance EMAs (shared with depth control).
    pub ctl: &'a SpecDepthController,
    /// Traffic-class keys of the rows currently running.
    pub running_classes: &'a [String],
}

impl SpecGrouping<'_> {
    /// Mean acceptance prior of the running batch (1.0-optimistic for
    /// unobserved classes, like depth control itself).
    fn running_prior(&self) -> Option<f64> {
        if self.running_classes.is_empty() {
            return None;
        }
        let sum: f64 =
            self.running_classes.iter().map(|c| self.ctl.prior(c) as f64).sum();
        Some(sum / self.running_classes.len() as f64)
    }

    /// Similarity bonus for a candidate class, in `[0, SPEC_GROUP_WEIGHT]`.
    fn bonus(&self, class: &str) -> f64 {
        match self.running_prior() {
            Some(mean) => {
                let cand = self.ctl.prior(class) as f64;
                SPEC_GROUP_WEIGHT * (1.0 - (cand - mean).abs())
            }
            None => 0.0,
        }
    }
}

/// What a policy may look at when choosing the next admission.
pub struct AdmissionContext<'a> {
    /// Current simulated time.
    pub now_sim: f64,
    /// Footprint state (present only under [`AdmissionKind::FootprintAware`]).
    pub tracker: Option<&'a FootprintTracker>,
    /// Slots currently holding sequences (including ones admitted earlier
    /// in the same step — greedy co-scheduling sees its own picks).
    pub running_slots: &'a [usize],
    /// Expert → GPU placement for EP-aware overlap weighting.
    pub placement: Option<&'a Placement>,
    /// The model's native top-k (predicted expert-set size).
    pub top_k: usize,
    /// Spec-grouping refinement state (adaptive speculation only).
    pub spec: Option<SpecGrouping<'a>>,
    /// Shared-prefix KV cache, when serving with one (prefix-aware
    /// admission: a queued request whose prompt extends a cached prefix
    /// skips that much prefill, so it is cheap to admit). Probed
    /// read-only — admission scoring never touches hit/miss stats.
    pub prefix: Option<&'a super::prefix_cache::PrefixCache>,
}

/// Picks which queued entry is admitted into the next free slot.
pub trait AdmissionPolicy: Send {
    fn name(&self) -> &'static str;

    /// Index into `queue` of the entry to admit next, or `None` to admit
    /// nothing. `queue` is always in submission order (ascending `seq_no`).
    fn pick(&self, queue: &VecDeque<QueuedEntry>, ctx: &AdmissionContext) -> Option<usize>;
}

/// Submission order — the pre-refactor behaviour.
pub struct Fifo;

impl AdmissionPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&self, queue: &VecDeque<QueuedEntry>, _ctx: &AdmissionContext) -> Option<usize> {
        if queue.is_empty() { None } else { Some(0) }
    }
}

/// Strict priority classes, FIFO within a class.
pub struct Priority;

impl AdmissionPolicy for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn pick(&self, queue: &VecDeque<QueuedEntry>, _ctx: &AdmissionContext) -> Option<usize> {
        // max priority; ties resolve to the earliest seq_no because the
        // queue is in submission order and the comparison is strict.
        queue
            .iter()
            .enumerate()
            .max_by_key(|&(i, e)| (e.req.priority, std::cmp::Reverse(i)))
            .map(|(i, _)| i)
    }
}

/// Earliest-deadline-first on the absolute TTFT deadline.
pub struct SloEdf;

impl AdmissionPolicy for SloEdf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn pick(&self, queue: &VecDeque<QueuedEntry>, _ctx: &AdmissionContext) -> Option<usize> {
        let mut best: Option<(usize, Option<f64>)> = None;
        for (i, e) in queue.iter().enumerate() {
            let better = match (&best, e.deadline_sim) {
                (None, _) => true,
                // any deadline beats no deadline; earlier beats later;
                // FIFO (first seen) wins ties and the all-None case.
                (Some((_, None)), Some(_)) => true,
                (Some((_, Some(b))), Some(d)) => d < *b,
                _ => false,
            };
            if better {
                best = Some((i, e.deadline_sim));
            }
        }
        best.map(|(i, _)| i)
    }
}

/// Frees after which a passed-over entry's aging bonus fully dominates any
/// possible overlap advantage, forcing its admission. The starvation bound
/// is `queue depth at submission + O(STARVATION_HORIZON)` frees.
pub const STARVATION_HORIZON: u64 = 16;

/// Queue-wait-scaled aging bonus added to [`admission_score`] under
/// footprint admission. The wait is measured in *frees the entry lost*
/// (scale-free, unlike sim-seconds, so the bound holds on any cost model).
/// `admission_score` lives in `[-top_k, top_k]` (overlap minus the EP
/// MaxLoad penalty), so once an entry has been skipped
/// [`STARVATION_HORIZON`] more times than a competitor its bonus exceeds
/// the whole score range and no overlap advantage can outrank it —
/// minority traffic classes cannot starve under sustained skew. Entries
/// that aged together keep their relative base-score order (a burst
/// backlog gets identical bonuses, leaving co-scheduling untouched).
pub fn aging_bonus(skipped: u64, top_k: usize) -> f64 {
    skipped as f64 * (2.0 * top_k as f64 + 1.0) / STARVATION_HORIZON as f64
}

/// Weight of the spec-grouping similarity bonus. Kept at half an expert so
/// the full admission score stays inside `(-top_k, top_k + 1)` and the
/// aging bonus — whose slope is `2·top_k + 1` per [`STARVATION_HORIZON`]
/// skips — still strictly dominates after the horizon: the starvation
/// bound is unchanged by spec grouping.
pub const SPEC_GROUP_WEIGHT: f64 = 0.5;

/// Weight of the warm-prefix admission bonus, scaled by the fraction of
/// the candidate's prompt a cached prefix covers (so the bonus lives in
/// `[0, PREFIX_HIT_WEIGHT]`). Kept at a quarter expert so the full
/// admission score stays inside `(-top_k, top_k + SPEC_GROUP_WEIGHT +
/// PREFIX_HIT_WEIGHT)` and the aging bonus — slope `2·top_k + 1` per
/// [`STARVATION_HORIZON`] skips — still strictly clears the whole widened
/// range: warm prefixes break ties, they never starve cold traffic.
pub const PREFIX_HIT_WEIGHT: f64 = 0.25;

/// Greedy expected-overlap co-scheduling (EP-aware when placed).
pub struct FootprintAware;

impl AdmissionPolicy for FootprintAware {
    fn name(&self) -> &'static str {
        "footprint"
    }

    fn pick(&self, queue: &VecDeque<QueuedEntry>, ctx: &AdmissionContext) -> Option<usize> {
        if queue.is_empty() {
            return None;
        }
        let tracker = match ctx.tracker {
            Some(t) => t,
            None => return Some(0),
        };
        let union = tracker.running_union(ctx.running_slots, ctx.top_k);
        if union.is_empty() {
            // Nothing running (or nothing observed yet): no overlap signal.
            return Some(0);
        }
        let mut best: Option<(usize, f64)> = None;
        let mut any_informative = false;
        for (i, e) in queue.iter().enumerate() {
            // Unknown classes score a neutral 0 base instead of being
            // skipped outright — with the aging bonus they are guaranteed
            // admission too, where the old FIFO fallback could starve
            // them for as long as informative competitors kept arriving.
            let base = match tracker.predict(&e.req) {
                Some(fp) => {
                    any_informative = true;
                    admission_score(&fp.top_set(ctx.top_k), &union, ctx.placement)
                }
                None => 0.0,
            };
            // Spec-grouping refinement: prefer candidates whose class
            // acceptance prior matches the running rows', so ragged
            // verifies stay dense (bounded by SPEC_GROUP_WEIGHT — it
            // breaks overlap ties, never overrides a whole expert).
            let spec_bonus = ctx
                .spec
                .as_ref()
                .map(|sg| sg.bonus(&FootprintTracker::class_key(&e.req)))
                .unwrap_or(0.0);
            // Warm-prefix refinement: a candidate whose prompt extends a
            // cached KV prefix restores instead of prefilling that many
            // positions — prefer it proportionally to the covered prompt
            // fraction (bounded by PREFIX_HIT_WEIGHT — a tiebreak, never
            // worth a whole expert of overlap).
            let prefix_bonus = ctx
                .prefix
                .map(|c| {
                    PREFIX_HIT_WEIGHT * c.probe(&e.req.prompt) as f64
                        / e.req.prompt.len() as f64
                })
                .unwrap_or(0.0);
            let score = base + spec_bonus + prefix_bonus + aging_bonus(e.skipped, ctx.top_k);
            // strictly-greater keeps the earliest seq_no on ties
            if best.map(|(_, s)| score > s).unwrap_or(true) {
                best = Some((i, score));
            }
        }
        // If no queued entry has an informative prediction, stay FIFO.
        if !any_informative {
            return Some(0);
        }
        Some(best.map(|(i, _)| i).unwrap_or(0))
    }
}

/// The bounded admission queue the serve loop owns: submission order plus
/// the policy that reorders admission out of it.
pub struct AdmissionQueue {
    entries: VecDeque<QueuedEntry>,
    policy: Box<dyn AdmissionPolicy>,
    /// 0 = unbounded (the legacy-compatible default).
    max_queue: usize,
    next_seq: u64,
}

impl AdmissionQueue {
    pub fn new(kind: AdmissionKind, max_queue: usize) -> AdmissionQueue {
        AdmissionQueue {
            entries: VecDeque::new(),
            policy: kind.build(),
            max_queue,
            next_seq: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Ids of all queued requests, in submission order.
    pub fn ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.entries.iter().map(|e| e.req.id)
    }

    /// All queued entries, in submission order (eviction planning scans
    /// these read-only).
    pub fn entries(&self) -> impl Iterator<Item = &QueuedEntry> {
        self.entries.iter()
    }

    /// Re-enqueue a preempted (evicted) request. Unlike
    /// [`AdmissionQueue::submit`], this never applies backpressure — a
    /// request the system already accepted must not be droppable — and it
    /// carries the caller-preserved ORIGINAL submission time and absolute
    /// deadline (an eviction must not reset a request's SLO clock), while
    /// `now_sim` stamps this stint's `enqueue_sim` so queue-wait
    /// accounting measures only the incremental requeue wait. The entry
    /// joins the back of submission order.
    pub fn requeue(&mut self, req: Request, submit_sim: f64, deadline_sim: Option<f64>, now_sim: f64) {
        let entry = QueuedEntry {
            req,
            submit_sim,
            enqueue_sim: now_sim,
            seq_no: self.next_seq,
            deadline_sim,
            skipped: 0,
        };
        self.next_seq += 1;
        self.entries.push_back(entry);
    }

    /// Enqueue a request, applying backpressure at `max_queue`.
    pub fn submit(&mut self, req: Request, now_sim: f64) -> Result<(), SubmitError> {
        if self.max_queue > 0 && self.entries.len() >= self.max_queue {
            return Err(SubmitError::QueueFull {
                id: req.id,
                depth: self.entries.len(),
                max_queue: self.max_queue,
            });
        }
        let deadline_sim = req.deadline_ms.map(|ms| now_sim + ms as f64 / 1e3);
        let entry = QueuedEntry {
            req,
            submit_sim: now_sim,
            enqueue_sim: now_sim,
            seq_no: self.next_seq,
            deadline_sim,
            skipped: 0,
        };
        self.next_seq += 1;
        self.entries.push_back(entry);
        Ok(())
    }

    /// Remove and return the entry the policy wants admitted next. Every
    /// entry passed over ages by one free (the starvation-guard clock).
    pub fn pop_next(&mut self, ctx: &AdmissionContext) -> Option<QueuedEntry> {
        let idx = self.policy.pick(&self.entries, ctx)?;
        let popped = self.entries.remove(idx);
        if popped.is_some() {
            for e in self.entries.iter_mut() {
                e.skipped += 1;
            }
        }
        popped
    }
}

/// Observed-router-score state backing [`FootprintAware`] admission.
///
/// Two levels of aggregation, both decayed EMAs over the same full-N
/// probability rows the selection algorithms consume:
///
/// * **per running slot** — seeded from the class prediction at admission,
///   then updated from the row's actual scores (captured during chunked
///   prefill and every decode/verify forward);
/// * **per traffic class** — the prediction source for *queued* requests,
///   which have no scores of their own yet. The class key is the request's
///   `domain` tag when present (tenant / template / dataset id in
///   production terms) and a prompt-content hash otherwise, so duplicate
///   and templated traffic clusters even without labels.
pub struct FootprintTracker {
    n_experts: usize,
    decay: f32,
    slots: Vec<Option<(String, Footprint)>>,
    profiles: BTreeMap<String, Footprint>,
}

/// EMA decay for footprint updates: ~10-step memory, long enough to smooth
/// token noise, short enough to track a request drifting between phases.
pub const FOOTPRINT_DECAY: f32 = 0.9;

impl FootprintTracker {
    pub fn new(n_experts: usize, n_slots: usize) -> FootprintTracker {
        FootprintTracker {
            n_experts,
            decay: FOOTPRINT_DECAY,
            slots: (0..n_slots).map(|_| None).collect(),
            profiles: BTreeMap::new(),
        }
    }

    /// Override the EMA decay (config `footprint_decay`; validated to
    /// `[0, 1]` at config parse time).
    pub fn with_decay(mut self, decay: f32) -> FootprintTracker {
        debug_assert!((0.0..=1.0).contains(&decay), "decay {decay} outside [0, 1]");
        self.decay = decay;
        self
    }

    /// The class key queued and running requests aggregate under — the
    /// shared [`Request::class_key`] derivation, so the fleet router
    /// (`fleet::FleetRouter`) and footprint admission provably agree on
    /// every request's class (reference-vector pins live in
    /// `coordinator::request` and the parity test in `tests/fleet.rs`).
    pub fn class_key(req: &Request) -> String {
        req.class_key()
    }

    /// Predicted footprint for a queued request (its class profile), if its
    /// class has been observed before.
    pub fn predict(&self, req: &Request) -> Option<&Footprint> {
        self.profiles.get(&Self::class_key(req)).filter(|fp| fp.is_informative())
    }

    /// A request took a slot: seed the slot footprint from its class
    /// profile so same-step co-admissions can see it immediately.
    pub fn on_admit(&mut self, slot: usize, req: &Request) {
        let key = Self::class_key(req);
        let fp = self
            .profiles
            .get(&key)
            .cloned()
            .unwrap_or_else(|| Footprint::empty(self.n_experts));
        self.slots[slot] = Some((key, fp));
    }

    /// Fold an observed probability row into the slot's footprint and its
    /// class profile.
    pub fn observe_row(&mut self, slot: usize, probs_row: &[f32]) {
        debug_assert_eq!(probs_row.len(), self.n_experts);
        if let Some((key, fp)) = self.slots[slot].as_mut() {
            fp.observe(probs_row, self.decay);
            self.profiles
                .entry(key.clone())
                .or_insert_with(|| Footprint::empty(probs_row.len()))
                .observe(probs_row, self.decay);
        }
    }

    /// Fold one serving step's per-layer score matrices in for `slot`
    /// (row `row` of each matrix): layers are averaged into a single
    /// observation so the EMA decays once per step, not once per layer.
    pub fn observe_step(&mut self, slot: usize, row: usize, layers: &[&ScoreMatrix]) {
        if layers.is_empty() || self.slots[slot].is_none() {
            return;
        }
        let mut mean = vec![0.0f32; self.n_experts];
        for m in layers {
            for (acc, &p) in mean.iter_mut().zip(m.row(row)) {
                *acc += p;
            }
        }
        let inv = 1.0 / layers.len() as f32;
        for v in mean.iter_mut() {
            *v *= inv;
        }
        self.observe_row(slot, &mean);
    }

    /// The sequence in `slot` finished; its class profile persists.
    pub fn release(&mut self, slot: usize) {
        self.slots[slot] = None;
    }

    /// Union of the predicted expert sets of the running rows.
    pub fn running_union(&self, slots: &[usize], top_k: usize) -> ExpertSet {
        let mut union = ExpertSet::empty(self.n_experts);
        for &s in slots {
            if let Some((_, fp)) = &self.slots[s] {
                if fp.is_informative() {
                    union.union_with(&fp.top_set(top_k));
                }
            }
        }
        union
    }

    /// Slot footprint accessor (diagnostics / tests).
    pub fn slot_footprint(&self, slot: usize) -> Option<&Footprint> {
        self.slots[slot].as_ref().map(|(_, fp)| fp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    fn ctx<'a>() -> AdmissionContext<'a> {
        AdmissionContext {
            now_sim: 0.0,
            tracker: None,
            running_slots: &[],
            placement: None,
            top_k: 2,
            spec: None,
            prefix: None,
        }
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["fifo", "priority", "edf", "footprint"] {
            let k = AdmissionKind::parse(s).unwrap();
            assert_eq!(k.to_string(), s);
        }
        assert_eq!(AdmissionKind::parse("slo-edf").unwrap(), AdmissionKind::SloEdf);
        assert!(AdmissionKind::parse("lifo").is_err());
    }

    #[test]
    fn fifo_pops_in_submission_order() {
        let mut q = AdmissionQueue::new(AdmissionKind::Fifo, 0);
        for id in 0..5 {
            q.submit(req(id), 0.0).unwrap();
        }
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop_next(&ctx()).map(|e| e.req.id)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn queue_full_is_typed_and_carries_id() {
        let mut q = AdmissionQueue::new(AdmissionKind::Fifo, 2);
        q.submit(req(0), 0.0).unwrap();
        q.submit(req(1), 0.0).unwrap();
        let err = q.submit(req(7), 0.0).unwrap_err();
        assert_eq!(
            err,
            SubmitError::QueueFull { id: 7, depth: 2, max_queue: 2 }
        );
        assert_eq!(err.id(), 7);
        assert_eq!(err.code(), "queue_full");
        // a pop frees capacity again
        q.pop_next(&ctx()).unwrap();
        q.submit(req(7), 0.0).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn unbounded_queue_never_rejects() {
        let mut q = AdmissionQueue::new(AdmissionKind::Fifo, 0);
        for id in 0..1000 {
            q.submit(req(id), 0.0).unwrap();
        }
        assert_eq!(q.len(), 1000);
    }

    #[test]
    fn priority_orders_by_class_then_fifo() {
        let mut q = AdmissionQueue::new(AdmissionKind::Priority, 0);
        for (id, prio) in [(0u64, 0u32), (1, 2), (2, 1), (3, 2), (4, 0)] {
            let mut r = req(id);
            r.priority = prio;
            q.submit(r, 0.0).unwrap();
        }
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop_next(&ctx()).map(|e| e.req.id)).collect();
        // class 2 first (FIFO within: 1 then 3), then class 1, then class 0
        assert_eq!(order, vec![1, 3, 2, 0, 4]);
    }

    #[test]
    fn edf_orders_by_deadline_with_deadline_less_last() {
        let mut q = AdmissionQueue::new(AdmissionKind::SloEdf, 0);
        for (id, dl) in [(0u64, None), (1, Some(500u64)), (2, Some(100)), (3, None), (4, Some(300))] {
            let mut r = req(id);
            r.deadline_ms = dl;
            q.submit(r, 0.0).unwrap();
        }
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop_next(&ctx()).map(|e| e.req.id)).collect();
        assert_eq!(order, vec![2, 4, 1, 0, 3]);
    }

    #[test]
    fn edf_deadline_is_relative_to_submission() {
        let mut q = AdmissionQueue::new(AdmissionKind::SloEdf, 0);
        // Same 100 ms budget, but the second request is submitted much
        // later — its absolute deadline is later and FIFO order holds.
        let mut a = req(0);
        a.deadline_ms = Some(100);
        let mut b = req(1);
        b.deadline_ms = Some(100);
        q.submit(a, 0.0).unwrap();
        q.submit(b, 10.0).unwrap();
        assert_eq!(q.pop_next(&ctx()).unwrap().req.id, 0);
        // …and an old slack request loses to a new tight one.
        let mut c = req(2);
        c.deadline_ms = Some(1);
        q.submit(c, 10.0).unwrap();
        assert_eq!(q.pop_next(&ctx()).unwrap().req.id, 2);
    }

    #[test]
    fn footprint_clusters_same_class_and_cold_starts_as_fifo() {
        let n_experts = 8;
        let mut tracker = FootprintTracker::new(n_experts, 4);
        let mut q = AdmissionQueue::new(AdmissionKind::FootprintAware, 0);
        let mk = |id: u64, domain: &str| {
            let mut r = req(id);
            r.domain = domain.into();
            r
        };
        q.submit(mk(0, "a"), 0.0).unwrap();
        q.submit(mk(1, "b"), 0.0).unwrap();
        q.submit(mk(2, "a"), 0.0).unwrap();

        // Cold: no profiles, nothing running → FIFO front.
        let running: Vec<usize> = vec![];
        let c = AdmissionContext {
            now_sim: 0.0,
            tracker: Some(&tracker),
            running_slots: &running,
            placement: None,
            top_k: 2,
            spec: None,
            prefix: None,
        };
        let first = q.pop_next(&c).unwrap();
        assert_eq!(first.req.id, 0);

        // Slot 0 runs a domain-"a" row concentrated on experts {0, 1};
        // domain "b" has been seen on {6, 7}.
        tracker.on_admit(0, &first.req);
        tracker.observe_row(0, &[0.5, 0.4, 0.02, 0.02, 0.02, 0.02, 0.01, 0.01]);
        let b_probe = mk(99, "b");
        tracker.on_admit(1, &b_probe);
        tracker.observe_row(1, &[0.01, 0.01, 0.02, 0.02, 0.02, 0.02, 0.4, 0.5]);
        tracker.release(1);

        // With an "a" row running, the queued "a" request (seq later than
        // the "b" one) must be picked.
        let running = vec![0usize];
        let c = AdmissionContext {
            now_sim: 0.0,
            tracker: Some(&tracker),
            running_slots: &running,
            placement: None,
            top_k: 2,
            spec: None,
            prefix: None,
        };
        let picked = q.pop_next(&c).unwrap();
        assert_eq!(picked.req.id, 2, "same-class request must jump the queue");
        assert_eq!(q.pop_next(&c).unwrap().req.id, 1);
    }

    #[test]
    fn aging_bonus_dominates_overlap_after_horizon() {
        let top_k = 4;
        // within the horizon, a full-overlap fresh entry still outranks an
        // aged zero-overlap one …
        assert!(aging_bonus(1, top_k) < top_k as f64);
        // … but STARVATION_HORIZON extra skips clear the whole score range
        // (overlap ∈ [-k, k]), so -k + bonus > +k for the aged entry.
        let bonus = aging_bonus(STARVATION_HORIZON, top_k);
        assert!(-(top_k as f64) + bonus > top_k as f64);
        // equal ages cancel: a burst backlog keeps its base-score order
        assert_eq!(aging_bonus(7, top_k), aging_bonus(7, top_k));
    }

    #[test]
    fn starving_minority_class_eventually_admitted() {
        // Sustained skew: an "a"-class row runs forever and "a" requests
        // keep arriving, each overlapping the running batch perfectly. A
        // single "b" request must still be admitted within a bounded
        // number of frees (pre-guard behaviour: never).
        let n_experts = 8;
        let mut tracker = FootprintTracker::new(n_experts, 2);
        let mk = |id: u64, domain: &str| {
            let mut r = req(id);
            r.domain = domain.into();
            r
        };
        let runner = mk(1000, "a");
        tracker.on_admit(0, &runner);
        tracker.observe_row(0, &[0.5, 0.4, 0.02, 0.02, 0.02, 0.02, 0.01, 0.01]);
        let b_probe = mk(1001, "b");
        tracker.on_admit(1, &b_probe);
        tracker.observe_row(1, &[0.01, 0.01, 0.02, 0.02, 0.02, 0.02, 0.4, 0.5]);
        tracker.release(1);

        let mut q = AdmissionQueue::new(AdmissionKind::FootprintAware, 0);
        q.submit(mk(0, "b"), 0.0).unwrap(); // the minority request
        let running = vec![0usize];
        let mut next_id = 1u64;
        let mut frees = 0u64;
        loop {
            // adversary: a fresh same-class competitor before every free
            q.submit(mk(next_id, "a"), 0.0).unwrap();
            next_id += 1;
            let ctx = AdmissionContext {
                now_sim: frees as f64,
                tracker: Some(&tracker),
                running_slots: &running,
                placement: None,
                top_k: 2,
                spec: None,
                prefix: None,
            };
            let picked = q.pop_next(&ctx).unwrap();
            frees += 1;
            if picked.req.id == 0 {
                break;
            }
            assert!(
                frees <= 2 * STARVATION_HORIZON + 2,
                "minority request starved for {frees} frees"
            );
        }
        assert!(frees > 1, "guard must not preempt a genuinely better batch at once");
    }

    #[test]
    fn spec_grouping_prefers_similar_acceptance_priors() {
        // Two queued classes with IDENTICAL footprint overlap; the running
        // batch is one high-acceptance class. With adaptive-spec context,
        // admission must pick the class whose acceptance prior matches.
        let n_experts = 8;
        let mut tracker = FootprintTracker::new(n_experts, 2);
        let mk = |id: u64, domain: &str| {
            let mut r = req(id);
            r.domain = domain.into();
            r
        };
        // both queued classes concentrate on the same experts as the
        // running row, so overlap cannot break the tie
        let runner = mk(100, "run");
        tracker.on_admit(0, &runner);
        tracker.observe_row(0, &[0.5, 0.4, 0.02, 0.02, 0.02, 0.02, 0.01, 0.01]);
        for (slot, dom) in [(1usize, "hi"), (1, "lo")] {
            let probe = mk(101, dom);
            tracker.on_admit(slot, &probe);
            tracker.observe_row(slot, &[0.5, 0.4, 0.02, 0.02, 0.02, 0.02, 0.01, 0.01]);
            tracker.release(slot);
        }
        let mut ctl = SpecDepthController::new(4);
        for _ in 0..20 {
            ctl.observe("run", 4, 4); // running class accepts everything
            ctl.observe("hi", 4, 4); // similar prior
            ctl.observe("lo", 4, 0); // collapsed prior
        }
        let mut q = AdmissionQueue::new(AdmissionKind::FootprintAware, 0);
        q.submit(mk(0, "lo"), 0.0).unwrap(); // earlier seq_no
        q.submit(mk(1, "hi"), 0.0).unwrap();
        let running = vec![0usize];
        let classes = vec!["run".to_string()];
        let c = AdmissionContext {
            now_sim: 0.0,
            tracker: Some(&tracker),
            running_slots: &running,
            placement: None,
            top_k: 2,
            spec: Some(SpecGrouping { ctl: &ctl, running_classes: &classes }),
            prefix: None,
        };
        assert_eq!(
            q.pop_next(&c).unwrap().req.id,
            1,
            "similar-prior class must win the overlap tie"
        );
        // without the spec context the earlier submission wins the tie
        let mut q2 = AdmissionQueue::new(AdmissionKind::FootprintAware, 0);
        q2.submit(mk(0, "lo"), 0.0).unwrap();
        q2.submit(mk(1, "hi"), 0.0).unwrap();
        let c2 = AdmissionContext {
            now_sim: 0.0,
            tracker: Some(&tracker),
            running_slots: &running,
            placement: None,
            top_k: 2,
            spec: None,
            prefix: None,
        };
        assert_eq!(q2.pop_next(&c2).unwrap().req.id, 0);
    }

    #[test]
    fn spec_grouping_bonus_is_bounded_below_aging_dominance() {
        // The similarity bonus lives in [0, SPEC_GROUP_WEIGHT]; after
        // STARVATION_HORIZON extra skips the aging bonus still clears the
        // whole widened score range, so the starvation bound is intact.
        let top_k = 4;
        let widened_max = top_k as f64 + SPEC_GROUP_WEIGHT;
        assert!(-(top_k as f64) + aging_bonus(STARVATION_HORIZON, top_k) > widened_max);
        // and the bonus itself is within bounds for extreme priors
        let mut ctl = SpecDepthController::new(4);
        for _ in 0..30 {
            ctl.observe("zero", 4, 0);
        }
        let classes = vec!["zero".to_string()];
        let sg = SpecGrouping { ctl: &ctl, running_classes: &classes };
        let b_same = sg.bonus("zero");
        let b_far = sg.bonus("never-seen"); // optimistic prior 1.0
        assert!(b_same > b_far, "{b_same} vs {b_far}");
        assert!((0.0..=SPEC_GROUP_WEIGHT).contains(&b_same));
        assert!((0.0..=SPEC_GROUP_WEIGHT).contains(&b_far));
    }

    #[test]
    fn requeue_bypasses_backpressure_and_preserves_clock() {
        let mut q = AdmissionQueue::new(AdmissionKind::Fifo, 1);
        q.submit(req(0), 5.0).unwrap();
        assert!(q.submit(req(1), 5.0).is_err(), "bounded queue full");
        // an evicted request re-enters even at capacity, keeping its
        // original submission time and absolute deadline, while the
        // queue-wait anchor re-stamps to the requeue instant
        q.requeue(req(2), 1.25, Some(9.0), 6.0);
        assert_eq!(q.len(), 2);
        let first = q.pop_next(&ctx()).unwrap();
        assert_eq!(first.req.id, 0);
        assert_eq!(first.enqueue_sim, first.submit_sim);
        let re = q.pop_next(&ctx()).unwrap();
        assert_eq!(re.req.id, 2);
        assert_eq!(re.submit_sim, 1.25);
        assert_eq!(re.deadline_sim, Some(9.0));
        assert_eq!(re.enqueue_sim, 6.0, "queue wait must re-anchor at requeue");
    }

    #[test]
    fn prefix_hit_bonus_prefers_warm_candidate() {
        use super::super::prefix_cache::PrefixCache;
        // Two queued requests of the SAME traffic class (identical
        // predicted footprints, so their overlap bases tie exactly); one's
        // prompt extends a cached prefix. With the cache in context, the
        // warm request must jump the FIFO tie.
        let warm_prompt: Vec<u32> = (10..30).collect();
        let cold_prompt: Vec<u32> = (100..120).collect();
        let mut cache = PrefixCache::new(1 << 20, 4);
        let kv = crate::model::KvPrefix {
            len: 16,
            k: vec![vec![0.0f32; 2 * 16 * 4]; 2],
            v: vec![vec![0.0f32; 2 * 16 * 4]; 2],
        };
        assert!(cache.insert(&warm_prompt[..16], kv));

        // A warmed tracker with one informative running row, so the
        // footprint policy actually scores (an empty union or an
        // uninformative queue short-circuits straight to FIFO).
        let mk = |id: u64, prompt: Vec<u32>| {
            let mut r = Request::new(id, prompt, 4);
            r.domain = "t".into();
            r
        };
        let mut tr = FootprintTracker::new(4, 2);
        tr.on_admit(0, &mk(9, vec![1, 2, 3]));
        tr.observe_row(0, &[0.5, 0.4, 0.05, 0.05]);

        let mut q = AdmissionQueue::new(AdmissionKind::FootprintAware, 0);
        q.submit(mk(0, cold_prompt), 0.0).unwrap(); // earlier seq_no
        q.submit(mk(1, warm_prompt), 0.0).unwrap();
        let mut c = ctx();
        c.tracker = Some(&tr);
        c.running_slots = &[0];
        c.prefix = Some(&cache);
        assert_eq!(q.pop_next(&c).unwrap().req.id, 1, "warm prefix must win the tie");
        // same queue without the cache: the earlier submission wins
        let mut q2 = AdmissionQueue::new(AdmissionKind::FootprintAware, 0);
        q2.submit(mk(0, (100..120).collect()), 0.0).unwrap();
        q2.submit(mk(1, (10..30).collect()), 0.0).unwrap();
        let mut c2 = ctx();
        c2.tracker = Some(&tr);
        c2.running_slots = &[0];
        assert_eq!(q2.pop_next(&c2).unwrap().req.id, 0);
    }

    #[test]
    fn prefix_bonus_is_bounded_below_aging_dominance() {
        // The widened score range (overlap + spec bonus + prefix bonus)
        // must still be cleared by the post-horizon aging bonus, or the
        // starvation guarantee silently breaks.
        let top_k = 4;
        let widened_max = top_k as f64 + SPEC_GROUP_WEIGHT + PREFIX_HIT_WEIGHT;
        assert!(-(top_k as f64) + aging_bonus(STARVATION_HORIZON, top_k) > widened_max);
        // and the per-entry bonus itself never exceeds PREFIX_HIT_WEIGHT
        // (probe coverage is < 1 because a suffix must remain to feed)
        assert!(PREFIX_HIT_WEIGHT < SPEC_GROUP_WEIGHT);
    }

    #[test]
    fn tracker_class_key_stable_across_eviction_resume() {
        // An unlabeled request's class key hashes its ORIGINAL prompt: the
        // resume mutation (generated tokens appended to prompt) must not
        // move it to a fresh class and orphan its profile.
        let fresh = Request::new(1, vec![5, 6, 7], 8);
        let mut resumed = Request::new(2, vec![5, 6, 7], 8);
        resumed.prompt.extend_from_slice(&[40, 41]);
        resumed.resume_prefix = vec![40, 41];
        resumed.max_new_tokens = 6;
        resumed.evictions = 1;
        assert_eq!(
            FootprintTracker::class_key(&fresh),
            FootprintTracker::class_key(&resumed)
        );
    }

    #[test]
    fn tracker_class_key_hashes_unlabeled_prompts() {
        let a = Request::new(1, vec![5, 6, 7], 4);
        let b = Request::new(2, vec![5, 6, 7], 4);
        let c = Request::new(3, vec![5, 6, 8], 4);
        assert_eq!(FootprintTracker::class_key(&a), FootprintTracker::class_key(&b));
        assert_ne!(FootprintTracker::class_key(&a), FootprintTracker::class_key(&c));
        let mut lab = Request::new(4, vec![5, 6, 7], 4);
        lab.domain = "gpqa".into();
        assert_eq!(FootprintTracker::class_key(&lab), "gpqa");
    }

    #[test]
    fn tracker_running_union_ignores_uninformative_slots() {
        let mut tracker = FootprintTracker::new(4, 2);
        tracker.on_admit(0, &req(0)); // never observed
        let mut r1 = req(1);
        r1.domain = "d".into();
        tracker.on_admit(1, &r1);
        tracker.observe_row(1, &[0.7, 0.2, 0.05, 0.05]);
        let union = tracker.running_union(&[0, 1], 2);
        assert_eq!(union.to_vec(), vec![0, 1]);
        tracker.release(1);
        assert!(tracker.running_union(&[0, 1], 2).is_empty());
    }

    #[test]
    fn observe_step_averages_layers() {
        let mut tracker = FootprintTracker::new(3, 1);
        let mut r = req(0);
        r.domain = "d".into();
        tracker.on_admit(0, &r);
        let l0 = ScoreMatrix::from_rows(&[vec![1.0, 0.0, 0.0]]);
        let l1 = ScoreMatrix::from_rows(&[vec![0.0, 1.0, 0.0]]);
        tracker.observe_step(0, 0, &[&l0, &l1]);
        let fp = tracker.slot_footprint(0).unwrap();
        assert_eq!(fp.observations(), 1, "one EMA step per serving step");
        assert!((fp.weights()[0] - 0.5).abs() < 1e-6);
        assert!((fp.weights()[1] - 0.5).abs() < 1e-6);
    }
}
