//! The L3 coordinator: request lifecycle, continuous batching, the decode
//! scheduler with XShare selection on the request path, speculative
//! decoding, and the fidelity comparator used as the accuracy substitute.

pub mod batcher;
pub mod fidelity;
pub mod request;
pub mod scheduler;
pub mod speculative;

pub use batcher::Batcher;
pub use fidelity::{compare, Fidelity};
pub use request::{Phase, Request, SeqState};
pub use scheduler::{RunReport, Scheduler};
pub use speculative::{effective_batch_scores, greedy_accept};
