//! The L3 coordinator: request lifecycle, pluggable admission
//! ([`admission`]), continuous batching, the stepped serving core
//! ([`ServeLoop`]) with XShare selection on the request path, speculative
//! decoding, and the fidelity comparator used as the accuracy substitute.
//! [`Scheduler`] is the batch-at-a-time wrapper (submit-all +
//! step-until-done) that offline runs, benches and the fidelity harness use.

pub mod admission;
pub mod batcher;
pub mod eviction;
pub mod fidelity;
pub mod prefix_cache;
pub mod request;
pub mod scheduler;
pub mod serve_loop;
pub mod speculative;

pub use admission::{AdmissionKind, AdmissionPolicy, AdmissionQueue, SubmitError};
pub use batcher::Batcher;
pub use eviction::{EvictionPlan, EVICTION_BUDGET, EVICTION_MARGIN};
pub use fidelity::{compare, Fidelity};
pub use prefix_cache::{PrefixCache, PrefixCacheStats};
pub use request::{Phase, Request, SeqState};
pub use scheduler::Scheduler;
pub use serve_loop::{RunReport, ServeLoop, StepOutcome};
pub use speculative::{
    effective_batch_scores, effective_batch_scores_ragged, greedy_accept, lookup_draft,
    NgramIndex, SpecDepthController,
};
