//! Fidelity: the accuracy substitute (DESIGN.md §4).
//!
//! The paper measures task accuracy (AIME %, GPQA %) of the restricted
//! model vs the vanilla baseline. The mini models are untrained, so task
//! accuracy is undefined; what expert restriction actually causes is
//! *routing distortion*, which we measure directly: run the same trace
//! under the baseline and the policy and compare the generated token
//! streams. `token_match` plays the role of accuracy (1.0 = identical
//! behaviour, i.e. zero accuracy drop); "accuracy drop" in the reproduced
//! tables is `(match - 1) × 100` percentage points of behavioural agreement.

use std::collections::BTreeMap;

/// Agreement between two output maps (request id → tokens).
#[derive(Debug, Clone, Default)]
pub struct Fidelity {
    /// Fraction of positions (across all shared requests) with identical
    /// tokens — positional exact match.
    pub token_match: f64,
    /// Mean normalized longest common prefix.
    pub prefix_match: f64,
    /// Fraction of requests with fully identical outputs.
    pub exact_requests: f64,
    pub n_requests: usize,
}

impl Fidelity {
    /// Paper-style "accuracy drop" in points (0 = none, negative = drop).
    pub fn accuracy_drop_pts(&self) -> f64 {
        (self.token_match - 1.0) * 100.0
    }
}

pub fn compare(
    baseline: &BTreeMap<u64, Vec<u32>>,
    candidate: &BTreeMap<u64, Vec<u32>>,
) -> Fidelity {
    let mut pos_total = 0usize;
    let mut pos_match = 0usize;
    let mut prefix_sum = 0.0f64;
    let mut exact = 0usize;
    let mut n = 0usize;
    for (id, base) in baseline {
        let Some(cand) = candidate.get(id) else { continue };
        n += 1;
        let len = base.len().max(cand.len()).max(1);
        pos_total += len;
        let mut prefix = 0usize;
        let mut still_prefix = true;
        for i in 0..len {
            let same = base.get(i).is_some() && base.get(i) == cand.get(i);
            if same {
                pos_match += 1;
                if still_prefix {
                    prefix += 1;
                }
            } else {
                still_prefix = false;
            }
        }
        prefix_sum += prefix as f64 / len as f64;
        if base == cand {
            exact += 1;
        }
    }
    if n == 0 {
        return Fidelity::default();
    }
    Fidelity {
        token_match: pos_match as f64 / pos_total as f64,
        prefix_match: prefix_sum / n as f64,
        exact_requests: exact as f64 / n as f64,
        n_requests: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(v: Vec<(u64, Vec<u32>)>) -> BTreeMap<u64, Vec<u32>> {
        v.into_iter().collect()
    }

    #[test]
    fn identical_outputs_are_perfect() {
        let a = map(vec![(1, vec![1, 2, 3]), (2, vec![4])]);
        let f = compare(&a, &a.clone());
        assert_eq!(f.token_match, 1.0);
        assert_eq!(f.prefix_match, 1.0);
        assert_eq!(f.exact_requests, 1.0);
        assert_eq!(f.accuracy_drop_pts(), 0.0);
    }

    #[test]
    fn partial_divergence_measured() {
        let a = map(vec![(1, vec![1, 2, 3, 4])]);
        let b = map(vec![(1, vec![1, 2, 9, 4])]);
        let f = compare(&a, &b);
        assert!((f.token_match - 0.75).abs() < 1e-12);
        assert!((f.prefix_match - 0.5).abs() < 1e-12);
        assert_eq!(f.exact_requests, 0.0);
        assert!(f.accuracy_drop_pts() < 0.0);
    }

    #[test]
    fn length_mismatch_penalized() {
        let a = map(vec![(1, vec![1, 2, 3, 4])]);
        let b = map(vec![(1, vec![1, 2])]);
        let f = compare(&a, &b);
        assert!((f.token_match - 0.5).abs() < 1e-12);
    }

    #[test]
    fn missing_requests_skipped() {
        let a = map(vec![(1, vec![1]), (2, vec![2])]);
        let b = map(vec![(1, vec![1])]);
        let f = compare(&a, &b);
        assert_eq!(f.n_requests, 1);
        assert_eq!(f.token_match, 1.0);
    }

    #[test]
    fn empty_maps_yield_default() {
        let f = compare(&BTreeMap::new(), &BTreeMap::new());
        assert_eq!(f.n_requests, 0);
        assert_eq!(f.token_match, 0.0);
    }
}
