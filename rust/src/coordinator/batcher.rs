//! Batch-slot pool for continuous batching: a bounded set of slots with a
//! lowest-index-first free list. Finished sequences free their slot
//! immediately and the serve loop places the next admitted request the same
//! step (vLLM-style continuous batching, constrained to the padded
//! `max_batch` of the compiled artifacts).
//!
//! Which queued request gets a free slot is no longer decided here: the
//! admission queue and its pluggable policy live in
//! [`super::admission`]. The batcher only owns slot assignment, and keeps
//! the legacy guarantee that admission always reuses the lowest free index
//! (slot order determines batch row order).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::request::{Request, SeqState};

pub struct Batcher {
    slots: Vec<Option<SeqState>>,
    /// Free slot indices as a min-heap: placement always reuses the lowest
    /// free index, keeping slot assignment (and thus row order) identical
    /// to the old linear scan while staying O(log slots) per placement.
    free: BinaryHeap<Reverse<usize>>,
    /// Count of occupied slots (kept in sync by place/release).
    n_running: usize,
    /// Cap on concurrently running sequences (≤ slots.len()).
    pub max_running: usize,
}

impl Batcher {
    pub fn new(n_slots: usize, max_running: usize) -> Batcher {
        assert!(max_running >= 1 && max_running <= n_slots);
        Batcher {
            slots: (0..n_slots).map(|_| None).collect(),
            free: (0..n_slots).map(Reverse).collect(),
            n_running: 0,
            max_running,
        }
    }

    pub fn running(&self) -> usize {
        self.n_running
    }

    /// Whether another sequence may be placed right now.
    pub fn has_capacity(&self) -> bool {
        self.n_running < self.max_running
    }

    /// Bind a request to the lowest free slot; returns the slot index.
    pub fn place(&mut self, req: Request) -> usize {
        assert!(self.has_capacity(), "place() beyond max_running");
        let Reverse(slot) = self
            .free
            .pop()
            .expect("running < max_running <= n_slots implies a free slot");
        debug_assert!(self.slots[slot].is_none());
        self.slots[slot] = Some(SeqState::new(req));
        self.n_running += 1;
        slot
    }

    /// Live slot indices, ascending.
    pub fn live_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect()
    }

    pub fn seq(&self, slot: usize) -> &SeqState {
        self.slots[slot].as_ref().expect("slot not occupied")
    }

    /// Sequence in `slot`, if occupied.
    pub fn get(&self, slot: usize) -> Option<&SeqState> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    pub fn seq_mut(&mut self, slot: usize) -> &mut SeqState {
        self.slots[slot].as_mut().expect("slot not occupied")
    }

    /// Free a slot, returning the finished sequence.
    pub fn release(&mut self, slot: usize) -> SeqState {
        let seq = self.slots[slot].take().expect("releasing empty slot");
        self.n_running -= 1;
        self.free.push(Reverse(slot));
        seq
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn placement_fills_up_to_cap() {
        let mut b = Batcher::new(4, 2);
        assert_eq!(b.place(req(0)), 0);
        assert_eq!(b.place(req(1)), 1);
        assert_eq!(b.running(), 2);
        assert!(!b.has_capacity(), "max_running reached");
    }

    #[test]
    fn release_frees_slot_for_next() {
        let mut b = Batcher::new(2, 2);
        b.place(req(0));
        b.place(req(1));
        let done = b.release(0);
        assert_eq!(done.req.id, 0);
        assert_eq!(b.running(), 1);
        assert!(b.has_capacity());
        assert_eq!(b.place(req(2)), 0);
        assert_eq!(b.seq(0).req.id, 2);
    }

    #[test]
    fn live_slots_sorted() {
        let mut b = Batcher::new(4, 4);
        for id in 0..3 {
            b.place(req(id));
        }
        b.release(1);
        assert_eq!(b.live_slots(), vec![0, 2]);
        b.release(0);
        b.release(2);
        assert_eq!(b.running(), 0);
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut b = Batcher::new(2, 2);
        b.place(req(0));
        b.release(0);
        b.release(0);
    }

    #[test]
    #[should_panic(expected = "beyond max_running")]
    fn place_beyond_cap_panics() {
        let mut b = Batcher::new(2, 1);
        b.place(req(0));
        b.place(req(1));
    }

    #[test]
    fn placement_reuses_lowest_free_slot() {
        // The free-list must preserve the linear-scan policy: lowest free
        // index first (slot order determines batch row order).
        let mut b = Batcher::new(4, 4);
        for id in 0..4 {
            b.place(req(id));
        }
        b.release(2);
        b.release(0);
        b.release(3);
        assert_eq!(b.place(req(4)), 0);
        assert_eq!(b.place(req(5)), 2);
        assert_eq!(b.seq(0).req.id, 4);
        assert_eq!(b.seq(2).req.id, 5);
    }

    #[test]
    fn running_count_stays_consistent_under_churn() {
        let mut b = Batcher::new(8, 8);
        let mut next_id = 0u64;
        let mut next_release = 0usize;
        let mut pending = 32u64;
        while pending > 0 || b.running() > 0 {
            while pending > 0 && b.has_capacity() {
                b.place(req(next_id));
                next_id += 1;
                pending -= 1;
            }
            assert_eq!(b.running(), b.live_slots().len(), "counter drifted from slot scan");
            if b.running() > 0 {
                let live = b.live_slots();
                let victim = live[next_release % live.len()];
                next_release += 1;
                b.release(victim);
            }
        }
        assert_eq!(b.running(), 0);
    }
}
