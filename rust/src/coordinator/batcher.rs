//! Continuous batcher: a bounded pool of batch slots fed from a FIFO
//! admission queue. Finished sequences free their slot immediately; the
//! next queued request is admitted the same step (vLLM-style continuous
//! batching, constrained to the padded `max_batch` of the compiled
//! artifacts).

use std::collections::VecDeque;

use super::request::{Request, SeqState};

pub struct Batcher {
    slots: Vec<Option<SeqState>>,
    queue: VecDeque<Request>,
    /// Cap on concurrently running sequences (≤ slots.len()).
    pub max_running: usize,
}

impl Batcher {
    pub fn new(n_slots: usize, max_running: usize) -> Batcher {
        assert!(max_running >= 1 && max_running <= n_slots);
        Batcher { slots: (0..n_slots).map(|_| None).collect(), queue: VecDeque::new(), max_running }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn submit_all<I: IntoIterator<Item = Request>>(&mut self, reqs: I) {
        for r in reqs {
            self.submit(r);
        }
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        self.running() > 0 || !self.queue.is_empty()
    }

    /// Fill free slots from the queue; returns newly admitted slot indices.
    pub fn admit(&mut self) -> Vec<usize> {
        let mut admitted = Vec::new();
        while self.running() < self.max_running && !self.queue.is_empty() {
            let slot = self
                .slots
                .iter()
                .position(Option::is_none)
                .expect("running < max_running <= n_slots implies a free slot");
            let req = self.queue.pop_front().unwrap();
            self.slots[slot] = Some(SeqState::new(req));
            admitted.push(slot);
        }
        admitted
    }

    /// Live slot indices, ascending.
    pub fn live_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect()
    }

    pub fn seq(&self, slot: usize) -> &SeqState {
        self.slots[slot].as_ref().expect("slot not occupied")
    }

    pub fn seq_mut(&mut self, slot: usize) -> &mut SeqState {
        self.slots[slot].as_mut().expect("slot not occupied")
    }

    /// Free a slot, returning the finished sequence.
    pub fn release(&mut self, slot: usize) -> SeqState {
        self.slots[slot].take().expect("releasing empty slot")
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn admission_fills_up_to_cap() {
        let mut b = Batcher::new(4, 2);
        b.submit_all((0..5).map(req));
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.running(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn release_frees_slot_for_next() {
        let mut b = Batcher::new(2, 2);
        b.submit_all((0..3).map(req));
        b.admit();
        assert_eq!(b.running(), 2);
        let done = b.release(0);
        assert_eq!(done.req.id, 0);
        assert_eq!(b.running(), 1);
        let admitted = b.admit();
        assert_eq!(admitted, vec![0]);
        assert_eq!(b.seq(0).req.id, 2);
    }

    #[test]
    fn live_slots_sorted() {
        let mut b = Batcher::new(4, 4);
        b.submit_all((0..3).map(req));
        b.admit();
        b.release(1);
        assert_eq!(b.live_slots(), vec![0, 2]);
        assert!(b.has_work());
        b.release(0);
        b.release(2);
        assert!(!b.has_work());
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut b = Batcher::new(2, 2);
        b.submit(req(0));
        b.admit();
        b.release(0);
        b.release(0);
    }
}
