//! Continuous batcher: a bounded pool of batch slots fed from a FIFO
//! admission queue. Finished sequences free their slot immediately; the
//! next queued request is admitted the same step (vLLM-style continuous
//! batching, constrained to the padded `max_batch` of the compiled
//! artifacts).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use super::request::{Request, SeqState};

pub struct Batcher {
    slots: Vec<Option<SeqState>>,
    queue: VecDeque<Request>,
    /// Free slot indices as a min-heap: admission always reuses the lowest
    /// free index, keeping slot assignment (and thus row order) identical
    /// to the old linear scan while making admission O(log slots) instead
    /// of O(slots) per admitted request.
    free: BinaryHeap<Reverse<usize>>,
    /// Count of occupied slots (kept in sync by admit/release).
    n_running: usize,
    /// Cap on concurrently running sequences (≤ slots.len()).
    pub max_running: usize,
}

impl Batcher {
    pub fn new(n_slots: usize, max_running: usize) -> Batcher {
        assert!(max_running >= 1 && max_running <= n_slots);
        Batcher {
            slots: (0..n_slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            free: (0..n_slots).map(Reverse).collect(),
            n_running: 0,
            max_running,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn submit_all<I: IntoIterator<Item = Request>>(&mut self, reqs: I) {
        for r in reqs {
            self.submit(r);
        }
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.n_running
    }

    pub fn has_work(&self) -> bool {
        self.n_running > 0 || !self.queue.is_empty()
    }

    /// Fill free slots from the queue; returns newly admitted slot indices.
    pub fn admit(&mut self) -> Vec<usize> {
        let mut admitted = Vec::new();
        while self.n_running < self.max_running && !self.queue.is_empty() {
            let Reverse(slot) = self
                .free
                .pop()
                .expect("running < max_running <= n_slots implies a free slot");
            debug_assert!(self.slots[slot].is_none());
            let req = self.queue.pop_front().unwrap();
            self.slots[slot] = Some(SeqState::new(req));
            self.n_running += 1;
            admitted.push(slot);
        }
        admitted
    }

    /// Live slot indices, ascending.
    pub fn live_slots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect()
    }

    pub fn seq(&self, slot: usize) -> &SeqState {
        self.slots[slot].as_ref().expect("slot not occupied")
    }

    /// Sequence in `slot`, if occupied.
    pub fn get(&self, slot: usize) -> Option<&SeqState> {
        self.slots.get(slot).and_then(|s| s.as_ref())
    }

    pub fn seq_mut(&mut self, slot: usize) -> &mut SeqState {
        self.slots[slot].as_mut().expect("slot not occupied")
    }

    /// Free a slot, returning the finished sequence.
    pub fn release(&mut self, slot: usize) -> SeqState {
        let seq = self.slots[slot].take().expect("releasing empty slot");
        self.n_running -= 1;
        self.free.push(Reverse(slot));
        seq
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn admission_fills_up_to_cap() {
        let mut b = Batcher::new(4, 2);
        b.submit_all((0..5).map(req));
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.running(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn release_frees_slot_for_next() {
        let mut b = Batcher::new(2, 2);
        b.submit_all((0..3).map(req));
        b.admit();
        assert_eq!(b.running(), 2);
        let done = b.release(0);
        assert_eq!(done.req.id, 0);
        assert_eq!(b.running(), 1);
        let admitted = b.admit();
        assert_eq!(admitted, vec![0]);
        assert_eq!(b.seq(0).req.id, 2);
    }

    #[test]
    fn live_slots_sorted() {
        let mut b = Batcher::new(4, 4);
        b.submit_all((0..3).map(req));
        b.admit();
        b.release(1);
        assert_eq!(b.live_slots(), vec![0, 2]);
        assert!(b.has_work());
        b.release(0);
        b.release(2);
        assert!(!b.has_work());
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut b = Batcher::new(2, 2);
        b.submit(req(0));
        b.admit();
        b.release(0);
        b.release(0);
    }

    #[test]
    fn admission_reuses_lowest_free_slot() {
        // The free-list must preserve the linear-scan policy: lowest free
        // index first (slot order determines batch row order).
        let mut b = Batcher::new(4, 4);
        b.submit_all((0..4).map(req));
        b.admit();
        b.release(2);
        b.release(0);
        b.release(3);
        b.submit_all((4..6).map(req));
        assert_eq!(b.admit(), vec![0, 2]);
        assert_eq!(b.seq(0).req.id, 4);
        assert_eq!(b.seq(2).req.id, 5);
    }

    #[test]
    fn running_count_stays_consistent_under_churn() {
        let mut b = Batcher::new(8, 8);
        b.submit_all((0..32).map(req));
        let mut next_release = 0usize;
        while b.has_work() {
            b.admit();
            assert_eq!(b.running(), b.live_slots().len(), "counter drifted from slot scan");
            if b.running() > 0 {
                let live = b.live_slots();
                let victim = live[next_release % live.len()];
                next_release += 1;
                b.release(victim);
            }
        }
        assert_eq!(b.running(), 0);
        assert_eq!(b.queued(), 0);
    }
}
