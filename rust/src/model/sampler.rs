//! Token sampling from lm_head logits. Greedy is the default everywhere
//! (deterministic — fidelity experiments compare token streams across
//! policies); temperature sampling is available for the server API.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    Greedy,
    Temperature(f32),
}

/// Argmax with lowest-index tie-break (matches python/jnp argmax).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

pub fn sample(logits: &[f32], mode: Sampling, rng: &mut Rng) -> usize {
    match mode {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature(t) => {
            let t = t.max(1e-4);
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f32> = logits.iter().map(|&v| ((v - m) / t).exp()).collect();
            rng.categorical(&weights)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ties_prefer_lowest() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn greedy_matches_argmax() {
        let mut rng = Rng::new(0);
        assert_eq!(sample(&[0.0, 3.0, 1.0], Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(1);
        let logits = [0.0, 5.0, 1.0];
        for _ in 0..50 {
            assert_eq!(sample(&logits, Sampling::Temperature(0.01), &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut rng = Rng::new(2);
        let logits = [0.0, 1.0, 0.5];
        let mut seen = [false; 3];
        for _ in 0..300 {
            seen[sample(&logits, Sampling::Temperature(5.0), &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
