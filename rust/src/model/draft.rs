//! The dense draft model for speculative decoding (EAGLE-style role: small,
//! fast, same vocabulary). One `draft_step` artifact call advances all rows
//! by one token; caches are stacked per layer and round-trip as two tensors.

use anyhow::{bail, Result};

use crate::runtime::{Arg, Engine, HostTensor};

pub struct DraftModel {
    k_cache: HostTensor,
    v_cache: HostTensor,
}

impl DraftModel {
    /// The engine is passed per call (not stored) so the coordinator can
    /// keep one engine shared between target and draft without lifetime
    /// gymnastics.
    pub fn new(engine: &Engine) -> Result<DraftModel> {
        let m = &engine.manifest().model;
        if !engine.manifest().has_draft() {
            bail!("preset '{}' has no draft model", m.name);
        }
        let shape = vec![m.draft_layers, m.max_batch, m.draft_n_heads, m.max_seq, m.draft_head_dim];
        Ok(DraftModel {
            k_cache: HostTensor::zeros_f32(shape.clone()),
            v_cache: HostTensor::zeros_f32(shape),
        })
    }

    pub fn reset(&mut self) {
        for t in [&mut self.k_cache, &mut self.v_cache] {
            if let HostTensor::F32 { data, .. } = t {
                data.fill(0.0);
            }
        }
    }

    /// Advance every row by one token; returns lm logits `[B × V]`.
    pub fn step(&mut self, engine: &Engine, tokens: &[i32], pos: &[i32]) -> Result<HostTensor> {
        let m = &engine.manifest().model;
        let b = m.max_batch;
        if tokens.len() != b || pos.len() != b {
            bail!("draft step inputs must be padded to max_batch={b}");
        }
        let tokens = HostTensor::i32(vec![b], tokens.to_vec());
        let pos_t = HostTensor::i32(vec![b], pos.to_vec());
        let mut outs = engine.execute(
            "draft_step",
            &[
                Arg::Host(&tokens),
                Arg::Host(&pos_t),
                Arg::Host(&self.k_cache),
                Arg::Host(&self.v_cache),
                Arg::Weight("draft.emb"),
                Arg::Weight("draft.ln1s"),
                Arg::Weight("draft.wqs"),
                Arg::Weight("draft.wks"),
                Arg::Weight("draft.wvs"),
                Arg::Weight("draft.wos"),
                Arg::Weight("draft.ln2s"),
                Arg::Weight("draft.wf1s"),
                Arg::Weight("draft.wf2s"),
                Arg::Weight("draft.lnf"),
                Arg::Weight("draft.unembed"),
            ],
        )?;
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        self.k_cache = k_new;
        self.v_cache = v_new;
        Ok(logits)
    }
}
