//! The dense draft model for speculative decoding (EAGLE-style role: small,
//! fast, same vocabulary). One `draft_step` artifact call advances all rows
//! by one token; caches are stacked per layer and round-trip as two tensors.
//!
//! [`DraftRunner`] wraps the model with the per-row cache bookkeeping the
//! ragged verify needs: per-row positions, per-row lag tokens (a fully
//! accepted row leaves its draft cache one input behind), and the rewind
//! discipline. **Rewind is by overwrite**: after a rejected speculation the
//! cache positions beyond the accepted prefix hold stale draft-token
//! entries, but the next input each row feeds lands at its first stale
//! position (the correction token the target committed), overwriting it,
//! and entries beyond the current query position are masked by the
//! attention kernel — so per-row rewind costs nothing and rows at
//! different depths never interfere.

use anyhow::{bail, Result};

use crate::runtime::{Arg, Engine, HostTensor};

pub struct DraftModel {
    k_cache: HostTensor,
    v_cache: HostTensor,
}

impl DraftModel {
    /// The engine is passed per call (not stored) so the coordinator can
    /// keep one engine shared between target and draft without lifetime
    /// gymnastics.
    pub fn new(engine: &Engine) -> Result<DraftModel> {
        let m = &engine.manifest().model;
        if !engine.manifest().has_draft() {
            bail!("preset '{}' has no draft model", m.name);
        }
        let shape = vec![m.draft_layers, m.max_batch, m.draft_n_heads, m.max_seq, m.draft_head_dim];
        Ok(DraftModel {
            k_cache: HostTensor::zeros_f32(shape.clone()),
            v_cache: HostTensor::zeros_f32(shape),
        })
    }

    pub fn reset(&mut self) {
        for t in [&mut self.k_cache, &mut self.v_cache] {
            if let HostTensor::F32 { data, .. } = t {
                data.fill(0.0);
            }
        }
    }

    /// Advance every row by one token; returns lm logits `[B × V]`.
    pub fn step(&mut self, engine: &Engine, tokens: &[i32], pos: &[i32]) -> Result<HostTensor> {
        let m = &engine.manifest().model;
        let b = m.max_batch;
        if tokens.len() != b || pos.len() != b {
            bail!("draft step inputs must be padded to max_batch={b}");
        }
        let tokens = HostTensor::i32(vec![b], tokens.to_vec());
        let pos_t = HostTensor::i32(vec![b], pos.to_vec());
        let mut outs = engine.execute(
            "draft_step",
            &[
                Arg::Host(&tokens),
                Arg::Host(&pos_t),
                Arg::Host(&self.k_cache),
                Arg::Host(&self.v_cache),
                Arg::Weight("draft.emb"),
                Arg::Weight("draft.ln1s"),
                Arg::Weight("draft.wqs"),
                Arg::Weight("draft.wks"),
                Arg::Weight("draft.wvs"),
                Arg::Weight("draft.wos"),
                Arg::Weight("draft.ln2s"),
                Arg::Weight("draft.wf1s"),
                Arg::Weight("draft.wf2s"),
                Arg::Weight("draft.lnf"),
                Arg::Weight("draft.unembed"),
            ],
        )?;
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        self.k_cache = k_new;
        self.v_cache = v_new;
        Ok(logits)
    }
}

/// [`DraftModel`] plus the per-row serving state the coordinator's verify
/// cycles drive: the per-slot lag tokens. The coordinator prepares the
/// padded (token, position) arrays — it owns the sequences and their
/// positions — and the runner owns the cache plus which rows still owe it
/// an input.
pub struct DraftRunner {
    model: DraftModel,
    /// Fully-accepted rows owe the draft one input (the last drafted token
    /// was committed but never fed); it is fed at the top of the next
    /// cycle, position `seq.pos - 1`.
    lag: Vec<Option<u32>>,
}

impl DraftRunner {
    pub fn new(model: DraftModel, b_max: usize) -> DraftRunner {
        DraftRunner { model, lag: vec![None; b_max] }
    }

    /// Advance the draft one batched step; `tokens`/`pos` are the padded
    /// arrays.
    pub fn step(&mut self, engine: &Engine, tokens: &[i32], pos: &[i32]) -> Result<HostTensor> {
        self.model.step(engine, tokens, pos)
    }

    /// Shadow one target forward (plain steps): same inputs, logits unused.
    pub fn shadow_step(&mut self, engine: &Engine, tokens: &[i32], pos: &[i32]) -> Result<()> {
        self.step(engine, tokens, pos).map(|_| ())
    }

    pub fn lag_token(&self, slot: usize) -> Option<u32> {
        self.lag[slot]
    }

    pub fn set_lag(&mut self, slot: usize, token: Option<u32>) {
        self.lag[slot] = token;
    }

    pub fn any_lag(&self, slots: &[usize]) -> bool {
        slots.iter().any(|&s| self.lag[s].is_some())
    }

    pub fn clear_lag(&mut self, slots: &[usize]) {
        for &s in slots {
            self.lag[s] = None;
        }
    }
}
