//! Model execution layer: the decode-step walker over the AOT artifacts,
//! the dense draft model, and token sampling.

pub mod draft;
pub mod moe_model;
pub mod sampler;

pub use draft::{DraftModel, DraftRunner};
pub use moe_model::{
    KvPrefix, MoeModel, PrefillInput, PrefillOutput, RoutingMode, StepInput, StepOutput,
};
pub use sampler::{argmax, sample, Sampling};
