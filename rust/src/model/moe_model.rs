//! The target-model walker: one token per live row per decode step
//! (embed → L × (attn_router → **expert selection** → moe_layer) → lm_head)
//! plus the chunked-prefill path ([`MoeModel::prefill_chunk`]) that advances
//! ONE row by up to `max_batch` prompt positions per artifact invocation.
//!
//! This is where the three layers meet: the attn_router artifact produces
//! router logits/probs/colsum for the padded batch; the [`crate::selection`]
//! policy (running in rust, on the request path) decides the expert set; the
//! moe_layer artifact consumes the refined gate matrix. KV caches live here
//! as persistent padded host tensors — stale cache slots beyond each row's
//! `pos` are masked inside the attention kernel (verified by the kernel test
//! suite), which is what makes slot reuse and speculative rejection free.
//!
//! ## The ragged-verify park contract
//!
//! The coordinator's mixed-phase verify cycles re-feed a row's own next
//! `(token, position)` on sub-steps beyond that row's depth ("parking").
//! Such a step is a **byte-identical rewrite** as long as the row stays in
//! [`StepInput::rows`] with the same routing mode: the K/V written at a
//! position depend only on the token embedding, the layer weights and the
//! row's cache prefix `< pos` — all unchanged between the first write and
//! the rewrite — and deeper layers see identical hidden streams because
//! the row's routing (policy or set-restricted refine of identical
//! logits) is identical. A parked row EXCLUDED from `rows` gets zero
//! gates, so its layer≥1 K/V writes are garbage — only legal when a chunk
//! invocation overwrites that window the same step (the chunk-park
//! idiom). The depth-0 byte-identity pin in
//! `rust/tests/spec_mixed_phase.rs` and the kernel masking tests hold
//! this contract in place.
//!
//! ## The eviction/resume KV contract
//!
//! Slot eviction (`coordinator::eviction`) NEVER migrates K/V between
//! slots. A preempted row abandons its cache bytes in place and is
//! requeued with every committed token — consumed prompt plus generated —
//! as its new prompt; on re-admission, prefilling that history into
//! whatever slot it lands in rebuilds the cache from scratch (the chunk
//! `catch_up` idiom promoted to request scope). The rebuild is
//! byte-faithful for the same reason parks are: K/V at a position depend
//! only on the token stream and the cache prefix below it, both of which
//! the replay reproduces exactly — so under row-independent routing the
//! resumed continuation is byte-identical to an uninterrupted run
//! (pinned by `rust/tests/ep_serve.rs`). The victim slot's stale bytes
//! beyond a later occupant's `pos` are masked by the attention kernel,
//! exactly as for ordinary slot reuse after a finish.
//!
//! ## The cache-restore KV contract
//!
//! The prefix cache (`coordinator::prefix_cache`) copies KV bytes OUT of a
//! row ([`MoeModel::extract_prefix`]) and back INTO a (possibly different)
//! row later ([`MoeModel::restore_prefix`]), skipping the prefill forwards
//! for the restored positions. This is byte-faithful for the same reason
//! the replays above are: the K/V written at position `p` depend only on
//! the token embedding at `p`, the layer weights and the row's cache
//! prefix `< p`. A stored slab therefore carries everything a prefill of
//! the same token prefix would have produced, bit for bit, regardless of
//! which row it lands in — restore-then-suffix-prefill leaves the identical
//! cache state (and identical `kv_row_digest`) as a cold chunk prefill of
//! the whole prompt. Two provisos, both enforced by the coordinator: the
//! restored tokens must be an exact prefix of the new prompt (the cache is
//! keyed and verified on the token stream), and at least the prompt's last
//! token must still be fed through the model — the first generated token
//! needs real last-position logits, which no slab stores. Pinned across
//! policies × chunk sizes by `rust/tests/prefix_cache.rs`.
//!
//! ## The prefill-wave charging contract
//!
//! The coordinator fuses all chunk invocations of one serving step into
//! **waves** (PR 8): each round issues at most one [`MoeModel::prefill_chunk`]
//! per co-prefilling row, and the round is charged as ONE target forward
//! over [`MoeModel::wave_union`] of the invocations' routed sets and the
//! round's total token count. This is pure cost accounting: the
//! invocations themselves are exactly the sequential walk's (same rows,
//! same cache windows, same per-position routing), so tokens, logits and
//! `kv_row_digest` are byte-identical whether charges fuse or not —
//! pinned across policies × chunk sizes × co-prefilling rows by
//! `rust/tests/prefill_equivalence.rs`. What justifies the fused charge
//! physically: the wave's rows stream each layer's expert weights once,
//! as decode's continuous batching does — the per-layer set one stream
//! must cover is the union, its token count the wave's total.
//!
//! Since PR 10 the wave charge — like every other charge — is *posted*,
//! not accumulated in place: the coordinator prices it through the pure
//! cost models and posts one [`crate::cost::Phase::PrefillWave`]-attributed
//! entry to the [`crate::cost::Ledger`], the single writer to the sim clock (see
//! the single-writer contract in `cost/mod.rs`; conservation pinned in
//! `rust/tests/cost_ledger.rs`).
//!
//! With `--chunk-shared-selection` ([`PrefillInput::shared_selection`])
//! routing itself changes: each layer pools the chunk's per-position
//! router probs through the modular greedy objective
//! ([`crate::selection::shared_chunk_set`] — per-position top-1 warm-up
//! ∪ greedy top-`top_k` by pooled mass) and every position refines within
//! that one set. Lossy by design; the serve loop reports the distortion
//! through `coordinator::fidelity` as `shared_selection_fidelity`, never
//! silently.

use anyhow::{bail, Result};

use crate::runtime::{Arg, Engine, HostTensor};
use crate::selection::{
    refine, shared_chunk_set, ExpertSet, Routing, ScoreMatrix, SelectionContext,
    SelectionPolicy,
};
use crate::ep::Placement;
use crate::util::fnv::Fnv;

/// How a step routes tokens to experts.
pub enum RoutingMode<'a> {
    /// Online per-layer selection by a policy (the serving path).
    Policy(&'a dyn SelectionPolicy),
    /// Restrict every layer to a precomputed set (speculative pass 2:
    /// selection was made on the effective batch's scores).
    Restricted(&'a [ExpertSet]),
}

/// Inputs for one decode step over the padded batch.
pub struct StepInput<'a> {
    /// Token per row (padded rows: 0).
    pub tokens: &'a [i32],
    /// Position per row.
    pub pos: &'a [i32],
    /// Live row indices.
    pub rows: &'a [usize],
    /// Request grouping of rows (speculative selection context).
    pub requests: &'a [Vec<usize>],
    pub mode: RoutingMode<'a>,
    /// Record per-layer probs matrices (speculative pass 1).
    pub collect_probs: bool,
}

/// Inputs for one chunked-prefill invocation: up to
/// [`MoeModel::prefill_capacity`] prompt tokens of ONE row.
pub struct PrefillInput<'a> {
    /// Batch row (slot) the chunk belongs to.
    pub row: usize,
    /// Row position before the chunk (next KV slot to write).
    pub start_pos: usize,
    /// Chunk tokens, oldest first (`1..=prefill_capacity()` of them).
    pub tokens: &'a [u32],
    /// Policy routing each chunk position (applied per position, so
    /// chunking is an execution optimisation, not a routing change — see
    /// `rust/tests/prefill_equivalence.rs`).
    pub policy: &'a dyn SelectionPolicy,
    /// Opt-in lossy chunk-batched selection (`--chunk-shared-selection`):
    /// instead of routing every chunk position independently, pool the
    /// chunk's per-position router probs through the modular greedy
    /// objective ([`crate::selection::shared_chunk_set`]) and refine all
    /// positions within that ONE set per layer. Changes routing — the
    /// serve loop ships it with fidelity-delta accounting, never
    /// silently (see the prefill-wave contract in the module docs).
    pub shared_selection: bool,
    /// Return the per-layer router probability matrices (admission-time
    /// footprint estimation captures prompt-time scores from here).
    pub collect_probs: bool,
}

/// Outputs of one chunked-prefill invocation.
pub struct PrefillOutput {
    /// LM-head logits of the last chunk position `[V]` (predicts the token
    /// after the chunk — the first generated token when the prompt ends).
    pub last_logits: Vec<f32>,
    /// Per-layer |union of experts routed across the chunk positions|.
    pub activated: Vec<usize>,
    /// Per-layer routed unions (EP / cost accounting).
    pub selected: Vec<ExpertSet>,
    /// Per-layer router probability matrices `[max_batch × N]` (rows
    /// `0..tokens.len()` are the chunk positions), if requested.
    pub probs: Option<Vec<ScoreMatrix>>,
}

/// A compact copy of one row's KV prefix — what the prefix cache stores
/// and [`MoeModel::restore_prefix`] writes back. Per layer, the first
/// `len` positions of every head, packed `[n_heads][len][head_dim]` (the
/// row-internal cache layout with the sequence axis truncated to `len`).
/// See "The cache-restore KV contract" in the module docs for why these
/// bytes are position-portable across rows.
#[derive(Debug, Clone)]
pub struct KvPrefix {
    /// Prefix length in token positions.
    pub len: usize,
    /// Per-layer K prefix, `n_heads * len * head_dim` f32s each.
    pub k: Vec<Vec<f32>>,
    /// Per-layer V prefix, same packing as `k`.
    pub v: Vec<Vec<f32>>,
}

impl KvPrefix {
    /// VRAM a resident copy of this slab occupies (the prefix cache's
    /// budget currency): every K and V f32 across layers.
    pub fn bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|l| l.len() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// Outputs of one decode step.
pub struct StepOutput {
    /// LM-head logits `[B × V]`.
    pub logits: HostTensor,
    /// Per-layer number of activated experts (|union of routed|).
    pub activated: Vec<usize>,
    /// Per-layer selected sets (|S_l|; for EP accounting).
    pub selected: Vec<ExpertSet>,
    /// Per-layer (logits, probs) score matrices, if requested.
    pub scores: Option<Vec<(ScoreMatrix, ScoreMatrix)>>,
}

pub struct MoeModel {
    engine: Engine,
    /// Per-layer K/V caches `[B, H, S, hd]`.
    k_cache: Vec<HostTensor>,
    v_cache: Vec<HostTensor>,
    /// Reusable active-mask buffer.
    active: Vec<f32>,
    /// EP placement (only consulted by GPU-aware policies).
    pub placement: Option<Placement>,
}

impl MoeModel {
    pub fn new(engine: Engine) -> Result<MoeModel> {
        engine.manifest().validate_serving()?;
        let m = engine.manifest().model.clone();
        let cache_shape = vec![m.max_batch, m.n_heads, m.max_seq, m.head_dim];
        let k_cache =
            (0..m.n_layers).map(|_| HostTensor::zeros_f32(cache_shape.clone())).collect();
        let v_cache =
            (0..m.n_layers).map(|_| HostTensor::zeros_f32(cache_shape.clone())).collect();
        Ok(MoeModel {
            engine,
            k_cache,
            v_cache,
            active: vec![0.0; m.max_batch],
            placement: None,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn dims(&self) -> &crate::runtime::ModelDims {
        &self.engine.manifest().model
    }

    pub fn max_batch(&self) -> usize {
        self.dims().max_batch
    }

    /// Whether the loaded artifacts ship the chunked-prefill program.
    pub fn has_prefill(&self) -> bool {
        self.engine.manifest().has_prefill()
    }

    /// Chunk positions one `prefill_chunk` invocation advances (compiled
    /// at `max_batch` so the chunk borrows the batch-shaped programs).
    pub fn prefill_capacity(&self) -> usize {
        self.engine.manifest().prefill_chunk_capacity()
    }

    /// Per-layer union of routed expert sets across the invocations of
    /// one prefill wave — what the coordinator charges a fused wave over
    /// (the prefill-wave contract in the module docs). Input: each
    /// invocation's [`PrefillOutput::selected`] (all with the same layer
    /// count); output: per-layer `(|union|, union)` — the activation
    /// counts and sets one amortized weight stream per layer must serve.
    /// Empty input (a wave that issued nothing) yields empty vecs.
    pub fn wave_union(per_invocation: &[Vec<ExpertSet>]) -> (Vec<usize>, Vec<ExpertSet>) {
        let Some(first) = per_invocation.first() else {
            return (Vec::new(), Vec::new());
        };
        let mut sets = first.clone();
        for inv in &per_invocation[1..] {
            debug_assert_eq!(
                inv.len(),
                sets.len(),
                "wave invocations disagree on layer count"
            );
            for (u, s) in sets.iter_mut().zip(inv) {
                u.union_with(s);
            }
        }
        let acts = sets.iter().map(|s| s.len()).collect();
        (acts, sets)
    }

    /// Order-stable FNV-1a digest over every KV-cache byte (all layers,
    /// K then V per layer). The prefill equivalence suite uses this to
    /// assert chunked and one-token prefill leave identical cache state.
    pub fn kv_digest(&self) -> u64 {
        let mut h = Fnv::new();
        for t in self.k_cache.iter().chain(self.v_cache.iter()) {
            if let Ok(data) = t.as_f32() {
                h.update_f32s(data);
            }
        }
        h.finish()
    }

    /// Digest of one row's K/V slabs across layers (ignores the garbage
    /// other slots accumulate from padded-batch steps).
    pub fn kv_row_digest(&self, row: usize) -> u64 {
        let m = self.dims();
        let slab = m.n_heads * m.max_seq * m.head_dim;
        let mut h = Fnv::new();
        for t in self.k_cache.iter().chain(self.v_cache.iter()) {
            if let Ok(data) = t.as_f32() {
                h.update_f32s(&data[row * slab..(row + 1) * slab]);
            }
        }
        h.finish()
    }

    /// Copy the first `len` KV positions of `row` out of every layer's
    /// cache into a compact [`KvPrefix`] slab (the prefix cache's unit of
    /// storage). Pure read — the row's cache is untouched.
    pub fn extract_prefix(&self, row: usize, len: usize) -> Result<KvPrefix> {
        let m = self.dims();
        if row >= m.max_batch {
            bail!("extract_prefix row {row} out of range (max_batch {})", m.max_batch);
        }
        if len == 0 || len > m.max_seq {
            bail!("extract_prefix len {len} outside 1..={}", m.max_seq);
        }
        let slab = m.n_heads * m.max_seq * m.head_dim;
        let head = m.max_seq * m.head_dim;
        let take = len * m.head_dim;
        let copy_rows = |caches: &[HostTensor]| -> Result<Vec<Vec<f32>>> {
            caches
                .iter()
                .map(|t| {
                    let data = t.as_f32()?;
                    let mut out = Vec::with_capacity(m.n_heads * take);
                    for h in 0..m.n_heads {
                        let at = row * slab + h * head;
                        out.extend_from_slice(&data[at..at + take]);
                    }
                    Ok(out)
                })
                .collect()
        };
        let k = copy_rows(&self.k_cache)?;
        let v = copy_rows(&self.v_cache)?;
        Ok(KvPrefix { len, k, v })
    }

    /// Write a [`KvPrefix`] slab into positions `0..prefix.len` of `row`
    /// across every layer — the warm half of the cache-restore KV contract
    /// (module docs): byte-identical to prefilling the slab's tokens into
    /// the row, without the forwards. Positions ≥ `prefix.len` are left
    /// as-is (masked until the row advances past them).
    pub fn restore_prefix(&mut self, row: usize, prefix: &KvPrefix) -> Result<()> {
        let m = self.dims().clone();
        if row >= m.max_batch {
            bail!("restore_prefix row {row} out of range (max_batch {})", m.max_batch);
        }
        if prefix.len == 0 || prefix.len > m.max_seq {
            bail!("restore_prefix len {} outside 1..={}", prefix.len, m.max_seq);
        }
        if prefix.k.len() != m.n_layers || prefix.v.len() != m.n_layers {
            bail!(
                "restore_prefix slab has {}+{} layers, model has {}",
                prefix.k.len(),
                prefix.v.len(),
                m.n_layers
            );
        }
        let slab = m.n_heads * m.max_seq * m.head_dim;
        let head = m.max_seq * m.head_dim;
        let take = prefix.len * m.head_dim;
        let mut write_rows = |caches: &mut [HostTensor], src: &[Vec<f32>]| -> Result<()> {
            for (t, layer) in caches.iter_mut().zip(src) {
                if layer.len() != m.n_heads * take {
                    bail!(
                        "restore_prefix layer slab has {} f32s, geometry needs {}",
                        layer.len(),
                        m.n_heads * take
                    );
                }
                if let HostTensor::F32 { data, .. } = t {
                    for h in 0..m.n_heads {
                        let at = row * slab + h * head;
                        data[at..at + take].copy_from_slice(&layer[h * take..(h + 1) * take]);
                    }
                }
            }
            Ok(())
        };
        write_rows(&mut self.k_cache, &prefix.k)?;
        write_rows(&mut self.v_cache, &prefix.v)?;
        Ok(())
    }

    /// Forget all cache state (fresh serving run).
    pub fn reset(&mut self) {
        // Positions are authoritative; caches need no zeroing (masked), but
        // zero them anyway so resets are bit-deterministic.
        for t in self.k_cache.iter_mut().chain(self.v_cache.iter_mut()) {
            if let HostTensor::F32 { data, .. } = t {
                data.fill(0.0);
            }
        }
    }

    /// One decode step.
    pub fn step(&mut self, input: &StepInput) -> Result<StepOutput> {
        let m = self.dims().clone();
        let b = m.max_batch;
        if input.tokens.len() != b || input.pos.len() != b {
            bail!("step inputs must be padded to max_batch={b}");
        }
        for (&i, name) in input.rows.iter().zip(std::iter::repeat("row")) {
            if i >= b {
                bail!("{name} {i} out of range");
            }
        }
        self.active.fill(0.0);
        for &i in input.rows {
            self.active[i] = 1.0;
        }

        let tokens = HostTensor::i32(vec![b], input.tokens.to_vec());
        let pos = HostTensor::i32(vec![b], input.pos.to_vec());
        let active = HostTensor::f32(vec![b], self.active.clone());

        let mut out = self.engine.execute("embed", &[Arg::Host(&tokens), Arg::Weight("emb")])?;
        let mut hidden = out.remove(0);

        let mut activated = Vec::with_capacity(m.n_layers);
        let mut selected = Vec::with_capacity(m.n_layers);
        let mut scores_acc = if input.collect_probs { Some(Vec::new()) } else { None };
        let shared_flag =
            HostTensor::f32(vec![1], vec![if m.n_shared > 0 { 1.0 } else { 0.0 }]);

        for l in 0..m.n_layers {
            let p = |s: &str| format!("layer{l}.{s}");
            let mut outs = self.engine.execute(
                "attn_router",
                &[
                    Arg::Host(&hidden),
                    Arg::Host(&pos),
                    Arg::Host(&active),
                    Arg::Host(&self.k_cache[l]),
                    Arg::Host(&self.v_cache[l]),
                    Arg::Weight(&p("ln1")),
                    Arg::Weight(&p("wq")),
                    Arg::Weight(&p("wk")),
                    Arg::Weight(&p("wv")),
                    Arg::Weight(&p("wo")),
                    Arg::Weight(&p("ln2")),
                    Arg::Weight(&p("wg")),
                ],
            )?;
            // outputs: hidden2, logits, probs, colsum, k_cache, v_cache
            let v_new = outs.pop().unwrap();
            let k_new = outs.pop().unwrap();
            let colsum_t = outs.pop().unwrap();
            let probs_t = outs.pop().unwrap();
            let logits_t = outs.pop().unwrap();
            let hidden2 = outs.pop().unwrap();
            self.k_cache[l] = k_new;
            self.v_cache[l] = v_new;

            let logits_m =
                ScoreMatrix::from_flat(b, m.n_experts, logits_t.as_f32()?.to_vec());
            let probs_m =
                ScoreMatrix::from_flat(b, m.n_experts, probs_t.as_f32()?.to_vec());
            let colsum = colsum_t.as_f32()?;

            let routing: Routing = match &input.mode {
                RoutingMode::Policy(policy) => {
                    let ctx = SelectionContext {
                        probs: &probs_m,
                        logits: &logits_m,
                        rows: input.rows,
                        requests: input.requests,
                        colsum_hint: Some(colsum),
                        placement: self.placement.as_ref(),
                        top_k: m.top_k,
                    };
                    policy.route(&ctx)
                }
                RoutingMode::Restricted(sets) => {
                    refine(&logits_m, input.rows, &sets[l], m.top_k)
                }
            };
            activated.push(routing.n_activated());
            // Always the *actually routed* union (metrics & EP accounting
            // count experts that serve ≥1 token, as the paper does).
            selected.push(routing.activated.clone());
            if let Some(acc) = scores_acc.as_mut() {
                acc.push((logits_m, probs_m));
            }

            let gates =
                HostTensor::f32(vec![b, m.n_experts], routing.gates.flat().to_vec());
            let mut mo = self.engine.execute(
                "moe_layer",
                &[
                    Arg::Host(&hidden2),
                    Arg::Host(&gates),
                    Arg::Weight(&p("ln2")),
                    Arg::Weight(&p("w1")),
                    Arg::Weight(&p("w2")),
                    Arg::Weight(&p("ws1")),
                    Arg::Weight(&p("ws2")),
                    Arg::Host(&shared_flag),
                ],
            )?;
            hidden = mo.remove(0);
        }

        let mut ho = self.engine.execute(
            "lm_head",
            &[Arg::Host(&hidden), Arg::Weight("lnf"), Arg::Weight("unembed")],
        )?;
        let logits = ho.remove(0);

        Ok(StepOutput { logits, activated, selected, scores: scores_acc })
    }

    /// Advance one row by `tokens.len()` prompt positions in a single
    /// artifact invocation per layer: embed the chunk as batch rows, run the
    /// `prefill_attn_router` artifact (causal attention within the chunk,
    /// K/V written into the row's persistent cache), route every chunk
    /// position through `policy` on the `[T × N]` score matrices, and feed
    /// the refined gates to the shared `moe_layer`/`lm_head` programs.
    ///
    /// Routing is applied **per position** (rows = one chunk position at a
    /// time, batch-utility hint = that position's probs row), so the CHUNK
    /// ROW's prefill routing — and a solo request's full output and cache
    /// state — is byte-identical to the one-token-per-step walk under every
    /// policy; chunking buys TTFT, not different prefill routing. (Rows
    /// decoding concurrently in the same step are routed by the serve loop
    /// without the chunk row in their batch, which batch-coupled policies
    /// observe — as they do any change in batch composition.) Batch-level
    /// sharing across a chunk is the opt-in
    /// [`PrefillInput::shared_selection`] quality/cost trade — lossy, with
    /// fidelity-delta accounting (the prefill-wave contract above).
    pub fn prefill_chunk(&mut self, input: &PrefillInput) -> Result<PrefillOutput> {
        let m = self.dims().clone();
        let b = m.max_batch;
        let t = input.tokens.len();
        if !self.has_prefill() {
            bail!(
                "preset '{}' artifacts lack the prefill program — rebuild with `make artifacts`",
                m.name
            );
        }
        if t == 0 || t > b {
            bail!("chunk length {t} outside 1..={b}");
        }
        if input.row >= b {
            bail!("chunk row {} out of range (max_batch={b})", input.row);
        }
        // The artifact slices a fixed [start, start+capacity) cache window;
        // XLA dynamic_slice would CLAMP an overhanging start and silently
        // shift the write window, so refuse instead (callers fall back to
        // one-token prefill near the end of the cache).
        if input.start_pos + b > m.max_seq {
            bail!(
                "chunk window [{}, {}) exceeds compiled max_seq={}",
                input.start_pos,
                input.start_pos + b,
                m.max_seq
            );
        }

        let mut tok = vec![0i32; b];
        for (dst, &src) in tok.iter_mut().zip(input.tokens) {
            *dst = src as i32;
        }
        let mut valid = vec![0.0f32; b];
        valid[..t].fill(1.0);
        let tokens = HostTensor::i32(vec![b], tok);
        let start = HostTensor::i32(vec![1], vec![input.start_pos as i32]);
        let row_t = HostTensor::i32(vec![1], vec![input.row as i32]);
        let valid_t = HostTensor::f32(vec![b], valid);

        let mut out = self.engine.execute("embed", &[Arg::Host(&tokens), Arg::Weight("emb")])?;
        let mut hidden = out.remove(0);

        let mut activated = Vec::with_capacity(m.n_layers);
        let mut selected = Vec::with_capacity(m.n_layers);
        let mut probs_acc = if input.collect_probs { Some(Vec::new()) } else { None };
        let shared_flag =
            HostTensor::f32(vec![1], vec![if m.n_shared > 0 { 1.0 } else { 0.0 }]);

        for l in 0..m.n_layers {
            let p = |s: &str| format!("layer{l}.{s}");
            let mut outs = self.engine.execute(
                "prefill_attn_router",
                &[
                    Arg::Host(&hidden),
                    Arg::Host(&start),
                    Arg::Host(&valid_t),
                    Arg::Host(&row_t),
                    Arg::Host(&self.k_cache[l]),
                    Arg::Host(&self.v_cache[l]),
                    Arg::Weight(&p("ln1")),
                    Arg::Weight(&p("wq")),
                    Arg::Weight(&p("wk")),
                    Arg::Weight(&p("wv")),
                    Arg::Weight(&p("wo")),
                    Arg::Weight(&p("ln2")),
                    Arg::Weight(&p("wg")),
                ],
            )?;
            // outputs: hidden2, logits, probs, colsum, k_cache, v_cache
            let v_new = outs.pop().unwrap();
            let k_new = outs.pop().unwrap();
            let _colsum = outs.pop().unwrap(); // chunk-wide; per-position hints below
            let probs_t = outs.pop().unwrap();
            let logits_t = outs.pop().unwrap();
            let hidden2 = outs.pop().unwrap();
            self.k_cache[l] = k_new;
            self.v_cache[l] = v_new;

            let logits_m =
                ScoreMatrix::from_flat(b, m.n_experts, logits_t.as_f32()?.to_vec());
            let probs_m =
                ScoreMatrix::from_flat(b, m.n_experts, probs_t.as_f32()?.to_vec());

            let mut gates = vec![0.0f32; b * m.n_experts];
            let mut union = ExpertSet::empty(m.n_experts);
            if input.shared_selection && t > 1 {
                // Chunk-batched selection: ONE set per layer from the
                // pooled per-position probs (per-position top-1 warm-up ∪
                // greedy top-k by pooled mass), every position refined
                // within it. Lossy — see the prefill-wave contract above.
                let rows_t: Vec<usize> = (0..t).collect();
                let set = shared_chunk_set(&probs_m, &rows_t, m.top_k);
                let routing = refine(&logits_m, &rows_t, &set, m.top_k);
                gates.copy_from_slice(routing.gates.flat());
                union.union_with(&routing.activated);
            } else {
                for i in 0..t {
                    let rows_i = [i];
                    let groups_i = [vec![i]];
                    let ctx = SelectionContext {
                        probs: &probs_m,
                        logits: &logits_m,
                        rows: &rows_i,
                        requests: &groups_i,
                        colsum_hint: Some(probs_m.row(i)),
                        placement: self.placement.as_ref(),
                        top_k: m.top_k,
                    };
                    let routing = input.policy.route(&ctx);
                    let lo = i * m.n_experts;
                    gates[lo..lo + m.n_experts]
                        .copy_from_slice(&routing.gates.flat()[lo..lo + m.n_experts]);
                    union.union_with(&routing.activated);
                }
            }
            activated.push(union.len());
            selected.push(union);
            if let Some(acc) = probs_acc.as_mut() {
                acc.push(probs_m);
            }

            let gates_t = HostTensor::f32(vec![b, m.n_experts], gates);
            let mut mo = self.engine.execute(
                "moe_layer",
                &[
                    Arg::Host(&hidden2),
                    Arg::Host(&gates_t),
                    Arg::Weight(&p("ln2")),
                    Arg::Weight(&p("w1")),
                    Arg::Weight(&p("w2")),
                    Arg::Weight(&p("ws1")),
                    Arg::Weight(&p("ws2")),
                    Arg::Host(&shared_flag),
                ],
            )?;
            hidden = mo.remove(0);
        }

        let mut ho = self.engine.execute(
            "lm_head",
            &[Arg::Host(&hidden), Arg::Weight("lnf"), Arg::Weight("unembed")],
        )?;
        let logits = ho.remove(0);
        let lf = logits.as_f32()?;
        let last_logits = lf[(t - 1) * m.vocab..t * m.vocab].to_vec();

        Ok(PrefillOutput { last_logits, activated, selected, probs: probs_acc })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(n: usize, idx: &[usize]) -> ExpertSet {
        ExpertSet::from_indices(n, idx)
    }

    #[test]
    fn wave_union_unions_per_layer() {
        let a = vec![set(8, &[0, 1]), set(8, &[2])];
        let b = vec![set(8, &[1, 3]), set(8, &[2, 4])];
        let (acts, sets) = MoeModel::wave_union(&[a, b]);
        assert_eq!(acts, vec![3, 2]);
        assert_eq!(sets[0].to_vec(), vec![0, 1, 3]);
        assert_eq!(sets[1].to_vec(), vec![2, 4]);
    }

    #[test]
    fn wave_union_of_one_is_identity() {
        let a = vec![set(4, &[0, 2]), set(4, &[1])];
        let (acts, sets) = MoeModel::wave_union(std::slice::from_ref(&a));
        assert_eq!(acts, vec![2, 1]);
        assert_eq!(sets[0].to_vec(), a[0].to_vec());
        assert_eq!(sets[1].to_vec(), a[1].to_vec());
    }

    #[test]
    fn wave_union_empty_input_is_empty() {
        let (acts, sets) = MoeModel::wave_union(&[]);
        assert!(acts.is_empty() && sets.is_empty());
    }
}

