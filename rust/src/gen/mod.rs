//! Synthetic workload substrate.
//!
//! The paper evaluates on proprietary-infrastructure runs over public
//! datasets; this repo substitutes (DESIGN.md §4):
//!
//! * [`gating`] — a controlled-correlation gate-score generator (domain
//!   affinity + request preference + AR(1) token noise) for the activation
//!   and overlap studies (Fig 1, Fig 3) and for large selection sweeps.
//! * [`trace`]  — request traces over five synthetic "datasets" with
//!   distinct vocabulary regions and length profiles, replayed through the
//!   real mini model for the OTPS/fidelity experiments (Fig 4-8, Tables).

pub mod gating;
pub mod trace;

pub use gating::{batch_scores, mean_topk_overlap, Domain, GatingParams, RequestGating};
pub use trace::{TraceDomain, TraceGenerator, TraceRequest};
