//! Synthetic gate-score generator with controlled correlation structure.
//!
//! The paper's phenomena (Fig 1: batch activation growth; Fig 3: speculative
//! tokens overlap 2-3× more than cross-dataset tokens) are functions of the
//! *correlation structure* of router scores, not of any particular trained
//! model. This module generates logits with that structure explicitly:
//!
//!   logits(token t of request r in domain d) =
//!       s_dom · μ_d  +  s_req · μ_r  +  s_tok · z_t
//!
//!   μ_d  — per-domain expert affinity (seeded Gaussian over experts):
//!          tokens from one dataset prefer similar experts;
//!   μ_r  — per-request preference: the context of one generation;
//!   z_t  — AR(1) token noise along the request:
//!          z_t = γ z_{t-1} + √(1-γ²) ε, so *consecutive* (speculative)
//!          tokens are the most correlated pairs of all.
//!
//! Defaults are calibrated (see `benches/fig3_overlap.rs`) so the top-k
//! overlap ratios match the paper's Figure 3.

use crate::selection::ScoreMatrix;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct GatingParams {
    pub n_experts: usize,
    /// Globally-popular-expert strength (trained MoEs share a set of
    /// universally hot experts across datasets; this floor keeps the
    /// cross-dataset overlap non-trivial, as in the paper's Fig 3).
    pub s_glob: f32,
    /// Seed of the global popularity vector (shared by all domains).
    pub glob_seed: u64,
    /// Domain affinity strength.
    pub s_dom: f32,
    /// Request-level strength.
    pub s_req: f32,
    /// Token-noise strength.
    pub s_tok: f32,
    /// AR(1) coefficient between consecutive tokens of one request.
    pub gamma: f32,
}

impl GatingParams {
    pub fn default_for(n_experts: usize) -> GatingParams {
        GatingParams {
            n_experts,
            s_glob: 1.2,
            glob_seed: 0x610B,
            s_dom: 0.7,
            s_req: 0.6,
            s_tok: 1.2,
            gamma: 0.8,
        }
    }

    fn global_mu(&self) -> Vec<f32> {
        let mut rng = Rng::new(self.glob_seed ^ 0x610B_A1);
        (0..self.n_experts).map(|_| rng.normal() as f32).collect()
    }
}

/// One domain's expert-affinity profile.
#[derive(Debug, Clone)]
pub struct Domain {
    pub name: String,
    mu: Vec<f32>,
}

impl Domain {
    /// Seeded affinity: sparse-ish peaks so each domain concentrates on a
    /// subset of experts (what trained routers do across datasets).
    pub fn new(name: &str, n_experts: usize, seed: u64) -> Domain {
        let mut rng = Rng::new(seed ^ 0xD0_0D_F0_0D);
        let mu = (0..n_experts).map(|_| rng.normal() as f32).collect();
        Domain { name: name.into(), mu }
    }
}

/// A request's gating stream: yields one logits row per decode step.
#[derive(Debug, Clone)]
pub struct RequestGating {
    params: GatingParams,
    mu_dr: Vec<f32>, // s_dom·μ_d + s_req·μ_r, precombined
    z: Vec<f32>,     // AR(1) state
    rng: Rng,
    started: bool,
}

impl RequestGating {
    pub fn new(params: GatingParams, domain: &Domain, request_seed: u64) -> RequestGating {
        let mut rng = Rng::new(request_seed ^ 0x5EED_CAFE);
        let mu_g = params.global_mu();
        let mu_dr: Vec<f32> = domain
            .mu
            .iter()
            .zip(&mu_g)
            .map(|(&m, &g)| {
                params.s_glob * g + params.s_dom * m + params.s_req * rng.normal() as f32
            })
            .collect();
        let z = vec![0.0; params.n_experts];
        RequestGating { params, mu_dr, z, rng, started: false }
    }

    /// Next token's router logits.
    pub fn next_logits(&mut self) -> Vec<f32> {
        let g = self.params.gamma;
        let w = (1.0 - g * g).sqrt();
        for zi in self.z.iter_mut() {
            let eps = self.rng.normal() as f32;
            *zi = if self.started { g * *zi + w * eps } else { eps };
        }
        self.started = true;
        self.mu_dr
            .iter()
            .zip(&self.z)
            .map(|(&m, &z)| m + self.params.s_tok * z)
            .collect()
    }
}

/// Build a batch score matrix: one row per token, grouped per request.
/// Returns (logits, probs, request token groups).
pub fn batch_scores(
    params: &GatingParams,
    domains: &[&Domain],
    tokens_per_request: usize,
    seed: u64,
) -> (ScoreMatrix, ScoreMatrix, Vec<Vec<usize>>) {
    let mut rows = Vec::new();
    let mut groups = Vec::new();
    let mut rng = Rng::new(seed);
    for (r, dom) in domains.iter().enumerate() {
        let mut stream = RequestGating::new(params.clone(), dom, rng.fork(r as u64).next_u64());
        let mut group = Vec::new();
        for _ in 0..tokens_per_request {
            group.push(rows.len());
            rows.push(stream.next_logits());
        }
        groups.push(group);
    }
    let logits = ScoreMatrix::from_rows(&rows);
    let probs = ScoreMatrix::softmax(&logits);
    (logits, probs, groups)
}

/// Mean top-k overlap |topk(a) ∩ topk(b)| over row pairs.
pub fn mean_topk_overlap(probs: &ScoreMatrix, pairs: &[(usize, usize)], k: usize) -> f64 {
    use crate::selection::topk_indices;
    if pairs.is_empty() {
        return 0.0;
    }
    let mut total = 0usize;
    for &(a, b) in pairs {
        let ta = topk_indices(probs.row(a), k);
        let tb = topk_indices(probs.row(b), k);
        total += ta.iter().filter(|j| tb.contains(j)).count();
    }
    total as f64 / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize) -> (GatingParams, Vec<Domain>) {
        let params = GatingParams::default_for(n);
        let domains: Vec<Domain> = ["aime", "gpqa", "mmlu", "aalcr"]
            .iter()
            .enumerate()
            .map(|(i, name)| Domain::new(name, n, 1000 + i as u64))
            .collect();
        (params, domains)
    }

    #[test]
    fn deterministic_per_seed() {
        let (params, domains) = setup(64);
        let (a, _, _) = batch_scores(&params, &[&domains[0]], 4, 7);
        let (b, _, _) = batch_scores(&params, &[&domains[0]], 4, 7);
        assert_eq!(a, b);
        let (c, _, _) = batch_scores(&params, &[&domains[0]], 4, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn probs_rows_normalized() {
        let (params, domains) = setup(32);
        let (_, probs, groups) = batch_scores(&params, &[&domains[0], &domains[1]], 3, 1);
        assert_eq!(groups, vec![vec![0, 1, 2], vec![3, 4, 5]]);
        for i in 0..probs.n_tokens() {
            let s: f32 = probs.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    /// The Figure-3 structure: overlap(consecutive same-request) >
    /// overlap(same-domain different-request) > overlap(cross-domain),
    /// with the spec/cross ratio ≈ 2-3×.
    #[test]
    fn overlap_hierarchy_matches_paper() {
        let n = 128;
        let (params, domains) = setup(n);
        let k = 10;
        let mut spec_pairs = Vec::new();
        let mut same_domain_pairs = Vec::new();
        let mut cross_pairs = Vec::new();

        // many batches: 2 requests from domain 0, 1 from domain 1, 4 tokens
        let mut offset = 0;
        let mut all_rows = Vec::new();
        for trial in 0..60 {
            let (_, probs, groups) = batch_scores(
                &params,
                &[&domains[0], &domains[0], &domains[1]],
                4,
                9000 + trial,
            );
            for g in &groups {
                for w in g.windows(2) {
                    spec_pairs.push((offset + w[0], offset + w[1]));
                }
            }
            // same domain, different requests
            same_domain_pairs.push((offset + groups[0][0], offset + groups[1][2]));
            same_domain_pairs.push((offset + groups[0][3], offset + groups[1][1]));
            // cross domain
            cross_pairs.push((offset + groups[0][0], offset + groups[2][2]));
            cross_pairs.push((offset + groups[1][3], offset + groups[2][0]));
            offset += probs.n_tokens();
            all_rows.extend((0..probs.n_tokens()).map(|i| probs.row(i).to_vec()));
        }
        let probs = ScoreMatrix::from_rows(&all_rows);
        let o_spec = mean_topk_overlap(&probs, &spec_pairs, k);
        let o_same = mean_topk_overlap(&probs, &same_domain_pairs, k);
        let o_cross = mean_topk_overlap(&probs, &cross_pairs, k);
        assert!(
            o_spec > o_same && o_same > o_cross,
            "hierarchy violated: spec={o_spec:.2} same={o_same:.2} cross={o_cross:.2}"
        );
        let ratio = o_spec / o_cross.max(1e-9);
        assert!(
            (1.6..5.0).contains(&ratio),
            "spec/cross ratio {ratio:.2} outside the paper's 2-3× band (±)"
        );
    }

    #[test]
    fn gamma_zero_kills_consecutive_advantage() {
        let n = 64;
        let mut params = GatingParams::default_for(n);
        params.gamma = 0.0;
        let dom = Domain::new("d", n, 5);
        let (_, probs, groups) = batch_scores(&params, &[&dom; 8], 6, 3);
        let mut consec = Vec::new();
        let mut far = Vec::new();
        for g in &groups {
            consec.push((g[0], g[1]));
            far.push((g[0], g[5]));
        }
        let oc = mean_topk_overlap(&probs, &consec, 8);
        let of = mean_topk_overlap(&probs, &far, 8);
        // without AR structure, consecutive ≈ distant (same request mean)
        assert!((oc - of).abs() < 1.5, "consec {oc} vs far {of}");
    }
}
