//! Request-trace generator: the workloads the benches and the E2E driver
//! replay against the server.
//!
//! Domains play the role of the paper's evaluation datasets (AIME2025,
//! GPQA, MMLU-Pro, IFEval, AA-LCR): each domain biases its prompts toward a
//! distinct region of the vocabulary, which gives the *real* mini model
//! domain-clustered routing, and carries its own length profile (AA-LCR =
//! long prompts, AIME = long generations, …).

use crate::util::rng::Rng;

/// A synthetic evaluation domain.
#[derive(Debug, Clone)]
pub struct TraceDomain {
    pub name: String,
    /// Center of this domain's token distribution in [0, 1) vocab space.
    pub vocab_center: f64,
    /// Spread of the token distribution.
    pub vocab_spread: f64,
    /// Prompt length range.
    pub prompt_len: (usize, usize),
    /// Generation length range.
    pub gen_len: (usize, usize),
}

impl TraceDomain {
    pub fn standard_suite() -> Vec<TraceDomain> {
        vec![
            TraceDomain {
                name: "aime2025".into(),
                vocab_center: 0.15,
                vocab_spread: 0.08,
                prompt_len: (8, 16),
                gen_len: (24, 48),
            },
            TraceDomain {
                name: "gpqa".into(),
                vocab_center: 0.40,
                vocab_spread: 0.10,
                prompt_len: (12, 24),
                gen_len: (12, 32),
            },
            TraceDomain {
                name: "mmlu-pro".into(),
                vocab_center: 0.65,
                vocab_spread: 0.12,
                prompt_len: (8, 20),
                gen_len: (8, 24),
            },
            TraceDomain {
                name: "ifeval".into(),
                vocab_center: 0.85,
                vocab_spread: 0.08,
                prompt_len: (10, 18),
                gen_len: (16, 32),
            },
            TraceDomain {
                name: "aa-lcr".into(),
                vocab_center: 0.55,
                vocab_spread: 0.25,
                prompt_len: (24, 48),
                gen_len: (16, 40),
            },
        ]
    }

    pub fn by_name(name: &str) -> Option<TraceDomain> {
        Self::standard_suite().into_iter().find(|d| d.name == name)
    }

    /// Sample one prompt token id.
    fn sample_token(&self, vocab: usize, rng: &mut Rng) -> u32 {
        loop {
            let x = self.vocab_center + self.vocab_spread * rng.normal();
            if (0.0..1.0).contains(&x) {
                return (x * vocab as f64) as u32;
            }
        }
    }
}

/// One request of a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub id: u64,
    pub domain: String,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival offset from trace start, seconds (Poisson arrivals).
    pub arrival_s: f64,
}

/// Generate a trace of `n` requests over the given domains.
pub struct TraceGenerator {
    pub vocab: usize,
    pub seed: u64,
    /// Mean request arrival rate (req/s); 0 = all arrive at t=0.
    pub arrival_rate: f64,
}

impl TraceGenerator {
    pub fn new(vocab: usize, seed: u64) -> TraceGenerator {
        TraceGenerator { vocab, seed, arrival_rate: 0.0 }
    }

    /// `mix[i]` = domain of request i (cycled if shorter than `n`).
    pub fn generate(&self, domains: &[TraceDomain], n: usize) -> Vec<TraceRequest> {
        assert!(!domains.is_empty());
        let mut rng = Rng::new(self.seed ^ 0x7ACE);
        let mut t = 0.0f64;
        (0..n)
            .map(|i| {
                let d = &domains[i % domains.len()];
                let plen = d.prompt_len.0 + rng.below(d.prompt_len.1 - d.prompt_len.0 + 1);
                let glen = d.gen_len.0 + rng.below(d.gen_len.1 - d.gen_len.0 + 1);
                let prompt = (0..plen).map(|_| d.sample_token(self.vocab, &mut rng)).collect();
                if self.arrival_rate > 0.0 {
                    t += rng.exp(self.arrival_rate);
                }
                TraceRequest {
                    id: i as u64,
                    domain: d.name.clone(),
                    prompt,
                    max_new_tokens: glen,
                    arrival_s: t,
                }
            })
            .collect()
    }

    /// The paper's §6.3 mixed batch: one request from each of GPQA,
    /// AIME2025, MMLU-Pro, AA-LCR.
    pub fn mixed_batch(&self) -> Vec<TraceRequest> {
        let order = ["gpqa", "aime2025", "mmlu-pro", "aa-lcr"];
        let domains: Vec<TraceDomain> =
            order.iter().map(|n| TraceDomain::by_name(n).unwrap()).collect();
        self.generate(&domains, 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_in_vocab() {
        let g = TraceGenerator::new(512, 42);
        let doms = TraceDomain::standard_suite();
        let a = g.generate(&doms, 20);
        let b = g.generate(&doms, 20);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert!(x.prompt.iter().all(|&t| (t as usize) < 512));
            assert!(x.max_new_tokens > 0);
        }
    }

    #[test]
    fn domains_have_distinct_token_profiles() {
        let g = TraceGenerator::new(512, 7);
        let aime = TraceDomain::by_name("aime2025").unwrap();
        let ifeval = TraceDomain::by_name("ifeval").unwrap();
        let a = g.generate(&[aime], 50);
        let b = g.generate(&[ifeval], 50);
        let mean = |rs: &[TraceRequest]| {
            let (s, n) = rs.iter().flat_map(|r| &r.prompt).fold((0.0, 0usize), |(s, n), &t| {
                (s + t as f64, n + 1)
            });
            s / n as f64
        };
        assert!(mean(&a) + 100.0 < mean(&b), "domains overlap in vocab space");
    }

    #[test]
    fn mixed_batch_covers_four_datasets() {
        let g = TraceGenerator::new(512, 0);
        let batch = g.mixed_batch();
        let names: Vec<&str> = batch.iter().map(|r| r.domain.as_str()).collect();
        assert_eq!(names, vec!["gpqa", "aime2025", "mmlu-pro", "aa-lcr"]);
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let mut g = TraceGenerator::new(512, 3);
        g.arrival_rate = 10.0;
        let trace = g.generate(&TraceDomain::standard_suite(), 30);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(trace.last().unwrap().arrival_s > 0.0);
    }

    #[test]
    fn aalcr_prompts_are_longest() {
        let g = TraceGenerator::new(512, 11);
        let lcr = g.generate(&[TraceDomain::by_name("aa-lcr").unwrap()], 30);
        let aime = g.generate(&[TraceDomain::by_name("aime2025").unwrap()], 30);
        let avg = |rs: &[TraceRequest]| {
            rs.iter().map(|r| r.prompt.len()).sum::<usize>() as f64 / rs.len() as f64
        };
        assert!(avg(&lcr) > avg(&aime));
    }
}
