//! One serve-loop replica on its own thread, driven over a synchronous
//! command channel.
//!
//! PJRT handles are not `Send`, so — exactly like the TCP server's worker
//! — each replica thread constructs its own engine/model and owns the
//! live [`ServeLoop`] for its whole lifetime; the fleet only ever talks
//! to it through [`Cmd`]s. The protocol is strictly request/reply: every
//! command gets exactly one [`ReplicaReply`], and every reply carries a
//! [`ReplicaStatus`] snapshot (queue depth, slot occupancy, sim clock),
//! so the fleet's routing mirror refreshes on every interaction for free.
//!
//! The fleet drives replicas in lockstep sim-time waves: `RunUntil(t)`
//! steps while work remains and the clock is behind `t`, then
//! [`ServeLoop::advance_idle_to`] snaps an idle clock forward so a later
//! submit anchors its TTFT/deadline at fleet time, not in the replica's
//! idle past. Commands are *started* on every replica and *collected*
//! afterwards ([`ReplicaHandle::start_run_until`] /
//! [`ReplicaHandle::collect_pumped`]), so N replica threads step their
//! waves concurrently — the fleet thread never serializes them.
//!
//! A step error inside a wave is answered with [`CmdResult::Dead`] and
//! the thread exits: the serving core's state is suspect at that point,
//! and the fleet's failover path re-enters the dead replica's rows
//! elsewhere. `Kill` is the instrumentation hook for exactly that path —
//! it returns the final metrics snapshot (so TTFT samples already
//! recorded on the dying replica survive into the fleet rollup) and then
//! exits the thread, stranding all in-flight KV like a real crash would.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::ServeConfig;
use crate::coordinator::{Request, ServeLoop, StepOutcome, SubmitError};
use crate::metrics::ServeMetrics;
use crate::model::MoeModel;

/// Point-in-time routing view of a replica.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplicaStatus {
    /// Requests waiting in the replica's admission queue.
    pub queued: usize,
    /// Sequences occupying batch slots.
    pub running: usize,
    /// The replica's sim clock (seconds).
    pub clock: f64,
}

/// What one pump/wave/drain produced, with per-request ids intact.
#[derive(Debug, Default)]
pub struct Pumped {
    /// Finished requests: (id, complete generation including any resumed
    /// prefix) — same shape as [`StepOutcome::finished`].
    pub finished: Vec<(u64, Vec<u32>)>,
    /// Tokens newly committed, per request id (the streaming deltas AND
    /// the fleet's committed-history mirror feed).
    pub deltas: Vec<(u64, Vec<u32>)>,
    /// Serving steps executed.
    pub steps: u64,
}

impl Pumped {
    fn absorb(&mut self, outcome: StepOutcome) {
        self.finished.extend(outcome.finished);
        self.deltas.extend(outcome.deltas);
        self.steps += 1;
    }
}

/// Commands the fleet sends; each yields exactly one [`ReplicaReply`].
enum Cmd {
    /// Submit a fresh request at the replica's current clock.
    Submit(Request),
    /// Re-enter a failed-over request with its origin submit/deadline
    /// anchors (the lossless resume contract).
    Resubmit { req: Request, submit_sim: f64, deadline_sim: Option<f64> },
    /// Step while work remains and the clock is behind `t`, then snap an
    /// idle clock to `t`.
    RunUntil(f64),
    /// At most one step (the server worker's cadence).
    Pump,
    /// Step until no work remains.
    Drain,
    /// Status refresh only (the health probe).
    Probe,
    /// Metrics snapshot (wall clock stamped).
    Metrics,
    /// Instrumented crash: final metrics snapshot, then the thread exits
    /// with all in-flight rows stranded.
    Kill,
    /// Graceful exit (fleet teardown).
    Shutdown,
}

/// Per-command payload; the status snapshot rides alongside in
/// [`ReplicaReply`].
enum CmdResult {
    Submitted(std::result::Result<f64, SubmitError>),
    Pumped(Pumped),
    Metrics(Box<ServeMetrics>),
    Ack,
    /// The replica failed mid-command (step error); the thread is gone.
    Dead(String),
}

struct ReplicaReply {
    result: CmdResult,
    status: ReplicaStatus,
}

/// Fleet-side handle: command sender, reply receiver, last-seen status.
pub struct ReplicaHandle {
    tx: Sender<Cmd>,
    rx: Receiver<ReplicaReply>,
    status: ReplicaStatus,
    thread: Option<std::thread::JoinHandle<()>>,
    dead: bool,
    /// A started-but-uncollected wave command is outstanding.
    pending: bool,
}

impl ReplicaHandle {
    /// Spawn a replica thread: `build` constructs the model INSIDE the
    /// thread (PJRT handles are not `Send`); `spawn` blocks until the
    /// model is loaded and the serving core constructed, or fails.
    pub fn spawn(
        cfg: ServeConfig,
        build: impl FnOnce() -> Result<MoeModel> + Send + 'static,
    ) -> Result<ReplicaHandle> {
        let (cmd_tx, cmd_rx) = channel::<Cmd>();
        let (reply_tx, reply_rx) = channel::<ReplicaReply>();
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let thread = std::thread::spawn(move || {
            let mut model = match build() {
                Ok(m) => m,
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            match ServeLoop::new(&mut model, cfg) {
                Ok(core) => {
                    let _ = ready_tx.send(Ok(()));
                    replica_loop(core, cmd_rx, reply_tx);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                }
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(ReplicaHandle {
                tx: cmd_tx,
                rx: reply_rx,
                status: ReplicaStatus::default(),
                thread: Some(thread),
                dead: false,
                pending: false,
            }),
            Ok(Err(msg)) => {
                let _ = thread.join();
                bail!("fleet replica failed to start: {msg}")
            }
            Err(_) => {
                let _ = thread.join();
                bail!("fleet replica died during startup")
            }
        }
    }

    /// Last status mirror (refreshed by every reply).
    pub fn status(&self) -> ReplicaStatus {
        self.status
    }

    pub fn is_dead(&self) -> bool {
        self.dead
    }

    fn send(&mut self, cmd: Cmd) -> Result<()> {
        debug_assert!(!self.pending, "replica already has an outstanding command");
        if self.dead {
            bail!("replica is dead");
        }
        if self.tx.send(cmd).is_err() {
            self.mark_gone();
            bail!("replica thread gone");
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<CmdResult> {
        match self.rx.recv() {
            Ok(reply) => {
                self.status = reply.status;
                if let CmdResult::Dead(msg) = reply.result {
                    self.mark_gone();
                    bail!("replica died mid-command: {msg}");
                }
                Ok(reply.result)
            }
            Err(_) => {
                self.mark_gone();
                bail!("replica thread gone");
            }
        }
    }

    fn call(&mut self, cmd: Cmd) -> Result<CmdResult> {
        self.send(cmd)?;
        self.recv()
    }

    fn mark_gone(&mut self) {
        self.dead = true;
        self.pending = false;
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    /// Submit a fresh request. Outer `Err` = the replica itself is gone
    /// (route elsewhere); inner `Err` = a typed submit rejection from the
    /// serving core (surface to the client). `Ok(Ok(t))` returns the
    /// replica clock the submission was anchored at.
    pub fn submit(
        &mut self,
        req: Request,
    ) -> Result<std::result::Result<f64, SubmitError>> {
        match self.call(Cmd::Submit(req))? {
            CmdResult::Submitted(r) => Ok(r),
            _ => bail!("replica protocol violation: unexpected reply to Submit"),
        }
    }

    /// Re-enter a failed-over request with origin anchors.
    pub fn resubmit(
        &mut self,
        req: Request,
        submit_sim: f64,
        deadline_sim: Option<f64>,
    ) -> Result<std::result::Result<f64, SubmitError>> {
        match self.call(Cmd::Resubmit { req, submit_sim, deadline_sim })? {
            CmdResult::Submitted(r) => Ok(r),
            _ => bail!("replica protocol violation: unexpected reply to Resubmit"),
        }
    }

    /// Start a sim-time wave (collect with [`ReplicaHandle::collect_pumped`]).
    pub fn start_run_until(&mut self, t: f64) -> Result<()> {
        self.send(Cmd::RunUntil(t))?;
        self.pending = true;
        Ok(())
    }

    /// Start a single-step pump (collect with [`ReplicaHandle::collect_pumped`]).
    pub fn start_pump(&mut self) -> Result<()> {
        self.send(Cmd::Pump)?;
        self.pending = true;
        Ok(())
    }

    /// Start a full drain (collect with [`ReplicaHandle::collect_pumped`]).
    pub fn start_drain(&mut self) -> Result<()> {
        self.send(Cmd::Drain)?;
        self.pending = true;
        Ok(())
    }

    /// Collect the reply of a started wave/pump/drain.
    pub fn collect_pumped(&mut self) -> Result<Pumped> {
        debug_assert!(self.pending, "no outstanding command to collect");
        self.pending = false;
        match self.recv()? {
            CmdResult::Pumped(p) => Ok(p),
            _ => bail!("replica protocol violation: unexpected reply to wave"),
        }
    }

    /// Refresh the status mirror (the health probe).
    pub fn probe(&mut self) -> Result<ReplicaStatus> {
        self.call(Cmd::Probe)?;
        Ok(self.status)
    }

    /// Metrics snapshot (replica keeps serving).
    pub fn metrics(&mut self) -> Result<Box<ServeMetrics>> {
        match self.call(Cmd::Metrics)? {
            CmdResult::Metrics(m) => Ok(m),
            _ => bail!("replica protocol violation: unexpected reply to Metrics"),
        }
    }

    /// Instrumented crash: final metrics back, thread gone, in-flight rows
    /// stranded. The handle is dead afterwards.
    pub fn kill(&mut self) -> Result<Box<ServeMetrics>> {
        let result = self.call(Cmd::Kill)?;
        self.mark_gone();
        match result {
            CmdResult::Metrics(m) => Ok(m),
            _ => bail!("replica protocol violation: unexpected reply to Kill"),
        }
    }

    /// Graceful teardown (drops any idle work; fleet drains first).
    pub fn shutdown(&mut self) {
        if self.dead {
            return;
        }
        if self.tx.send(Cmd::Shutdown).is_ok() {
            let _ = self.rx.recv();
        }
        self.mark_gone();
    }
}

impl Drop for ReplicaHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn status_of(core: &ServeLoop<'_>) -> ReplicaStatus {
    ReplicaStatus {
        queued: core.queued(),
        running: core.running(),
        // the ledger is the sim clock's single writer; metrics only
        // mirror it, so status reads the source of truth directly
        clock: core.ledger().clock(),
    }
}

/// The replica thread body: serve commands until Shutdown/Kill/step error.
fn replica_loop(
    mut core: ServeLoop<'_>,
    cmd_rx: Receiver<Cmd>,
    reply_tx: Sender<ReplicaReply>,
) {
    let started = Instant::now();
    let snapshot = |core: &mut ServeLoop<'_>| {
        let mut m = core.metrics().clone();
        m.wall_seconds = started.elapsed().as_secs_f64();
        Box::new(m)
    };
    for cmd in cmd_rx {
        let mut exit = false;
        let result = match cmd {
            Cmd::Submit(req) => {
                let at = core.ledger().clock();
                CmdResult::Submitted(core.submit(req).map(|()| at))
            }
            Cmd::Resubmit { req, submit_sim, deadline_sim } => CmdResult::Submitted(
                core.resubmit(req, submit_sim, deadline_sim).map(|()| submit_sim),
            ),
            Cmd::RunUntil(t) => {
                let wave = (|| -> Result<Pumped> {
                    let mut p = Pumped::default();
                    while core.has_work() && core.ledger().clock() < t {
                        p.absorb(core.step()?);
                    }
                    core.advance_idle_to(t);
                    core.discard_finished();
                    Ok(p)
                })();
                match wave {
                    Ok(p) => CmdResult::Pumped(p),
                    Err(e) => {
                        exit = true;
                        CmdResult::Dead(format!("{e:#}"))
                    }
                }
            }
            Cmd::Pump => {
                if core.has_work() {
                    match core.step() {
                        Ok(outcome) => {
                            let mut p = Pumped::default();
                            p.absorb(outcome);
                            core.discard_finished();
                            CmdResult::Pumped(p)
                        }
                        Err(e) => {
                            exit = true;
                            CmdResult::Dead(format!("{e:#}"))
                        }
                    }
                } else {
                    CmdResult::Pumped(Pumped::default())
                }
            }
            Cmd::Drain => {
                let drained = (|| -> Result<Pumped> {
                    let mut p = Pumped::default();
                    while core.has_work() {
                        p.absorb(core.step()?);
                    }
                    core.discard_finished();
                    Ok(p)
                })();
                match drained {
                    Ok(p) => CmdResult::Pumped(p),
                    Err(e) => {
                        exit = true;
                        CmdResult::Dead(format!("{e:#}"))
                    }
                }
            }
            Cmd::Probe => CmdResult::Ack,
            Cmd::Metrics => CmdResult::Metrics(snapshot(&mut core)),
            Cmd::Kill => {
                exit = true;
                CmdResult::Metrics(snapshot(&mut core))
            }
            Cmd::Shutdown => {
                exit = true;
                CmdResult::Ack
            }
        };
        let status = status_of(&core);
        if reply_tx.send(ReplicaReply { result, status }).is_err() {
            return; // fleet gone
        }
        if exit {
            return;
        }
    }
}
