//! Footprint-affine request routing over replica serve loops.
//!
//! The affinity map is rendezvous hashing (highest-random-weight): a
//! request's traffic-class key — the SAME [`crate::coordinator::Request::class_key`]
//! footprint admission aggregates under — scores against every replica
//! index with FNV-1a, and the live replica with the highest score is the
//! class's preferred target. Rendezvous gives the two properties a fleet
//! needs and simple modulo hashing lacks: every class has a total
//! preference order over replicas (so a dead replica's classes fall
//! through to their second choice without reshuffling anyone else), and
//! the assignment is stateless — any router instance, including a rebuilt
//! one, computes the same map.
//!
//! Affinity is overridden by two signals, in order:
//!
//! * **health**: `Dead` replicas are never candidates; a `Busy` preferred
//!   target (probe-observed queue at the high-water mark) spills.
//! * **queue-depth backpressure**: when the preferred target's
//!   instantaneous queue has reached the high-water mark, the submit
//!   spills to the least-loaded healthy replica (min queued, then min
//!   running, then lowest index). Spills are counted — a spilling fleet
//!   is measurably trading expert-sharing locality for tail latency.
//!
//! `round-robin` mode is the class-blind baseline (skips dead replicas
//! only) that `benches/serve_continuous.rs -- fleet` compares against.

use crate::util::fnv::Fnv;

use super::health::HealthState;

/// Fleet routing mode (`--fleet-affinity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AffinityMode {
    /// Footprint-class rendezvous affinity (default).
    Class,
    /// Class-blind rotation — the baseline balancer.
    RoundRobin,
}

impl AffinityMode {
    pub fn parse(s: &str) -> Result<AffinityMode, String> {
        match s {
            "class" | "affinity" => Ok(AffinityMode::Class),
            "round-robin" | "round_robin" | "rr" => Ok(AffinityMode::RoundRobin),
            other => {
                Err(format!("unknown fleet affinity '{other}' (class | round-robin)"))
            }
        }
    }
}

impl std::fmt::Display for AffinityMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AffinityMode::Class => write!(f, "class"),
            AffinityMode::RoundRobin => write!(f, "round-robin"),
        }
    }
}

/// What the router sees of one replica at route time: the live queue
/// depth and slot occupancy (from the replica's last status mirror) plus
/// its health state.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSnapshot {
    pub queued: usize,
    pub running: usize,
    pub health: HealthState,
}

/// Rendezvous score of `key` on `replica`: FNV-1a over the key bytes
/// followed by the replica index (LE u64). Public so tests can pin the
/// class→replica map independently of the router.
pub fn rendezvous_score(key: &str, replica: usize) -> u64 {
    let mut h = Fnv::new();
    h.update_bytes(key.as_bytes());
    h.update_bytes(&(replica as u64).to_le_bytes());
    h.finish()
}

/// The routing decision-maker. Holds only routing state (round-robin
/// cursor, spill counter) — replica status lives with the fleet, health
/// with [`super::health::HealthTracker`], and both arrive per route as
/// [`ReplicaSnapshot`]s.
#[derive(Debug)]
pub struct FleetRouter {
    mode: AffinityMode,
    high_water: usize,
    rr_next: usize,
    spills: u64,
}

impl FleetRouter {
    pub fn new(mode: AffinityMode, high_water: usize) -> FleetRouter {
        FleetRouter { mode, high_water, rr_next: 0, spills: 0 }
    }

    pub fn mode(&self) -> AffinityMode {
        self.mode
    }

    /// Submits routed away from their affine target by backpressure.
    pub fn spills(&self) -> u64 {
        self.spills
    }

    /// The class's health-blind rendezvous preference among `n` replicas —
    /// the affinity map itself, introspectable for tests and benches.
    pub fn preferred(key: &str, n: usize) -> usize {
        assert!(n >= 1, "no replicas");
        (0..n).max_by_key(|&i| rendezvous_score(key, i)).unwrap()
    }

    /// Pick the replica for one submit. `None` when every replica is dead.
    pub fn route(&mut self, key: &str, snaps: &[ReplicaSnapshot]) -> Option<usize> {
        match self.mode {
            AffinityMode::RoundRobin => {
                let n = snaps.len();
                for off in 0..n {
                    let i = (self.rr_next + off) % n;
                    if snaps[i].health != HealthState::Dead {
                        self.rr_next = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
            AffinityMode::Class => {
                let target = snaps
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.health != HealthState::Dead)
                    .max_by_key(|&(i, _)| rendezvous_score(key, i))
                    .map(|(i, _)| i)?;
                let over = snaps[target].health == HealthState::Busy
                    || (self.high_water > 0 && snaps[target].queued >= self.high_water);
                if !over {
                    return Some(target);
                }
                // Spill: least-loaded live replica (fewest queued, then
                // fewest running, then lowest index — fully deterministic).
                let spill = snaps
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.health != HealthState::Dead)
                    .min_by_key(|&(i, s)| (s.queued, s.running, i))
                    .map(|(i, _)| i)?;
                if spill != target {
                    self.spills += 1;
                }
                Some(spill)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn healthy(queued: usize) -> ReplicaSnapshot {
        ReplicaSnapshot { queued, running: 0, health: HealthState::Healthy }
    }

    #[test]
    fn affinity_mode_parses_and_displays() {
        assert_eq!(AffinityMode::parse("class").unwrap(), AffinityMode::Class);
        assert_eq!(AffinityMode::parse("affinity").unwrap(), AffinityMode::Class);
        assert_eq!(
            AffinityMode::parse("round-robin").unwrap(),
            AffinityMode::RoundRobin
        );
        assert_eq!(AffinityMode::parse("rr").unwrap(), AffinityMode::RoundRobin);
        assert!(AffinityMode::parse("hash").is_err());
        assert_eq!(AffinityMode::Class.to_string(), "class");
        assert_eq!(AffinityMode::RoundRobin.to_string(), "round-robin");
    }

    #[test]
    fn rendezvous_assignment_is_stable_and_separates_the_bench_templates() {
        // The two-template trace's domain keys land on DISTINCT replicas
        // at N = 2 — the separation the fleet bench's affinity arm relies
        // on. Pinned values: any change to the key bytes or score layout
        // must show up here, not silently reshuffle the fleet.
        assert_eq!(FleetRouter::preferred("tplA", 2), 1);
        assert_eq!(FleetRouter::preferred("tplB", 2), 0);
        // Growing the fleet only ever moves a class to a NEW replica or
        // leaves it alone (rendezvous monotonicity at these pins).
        assert_eq!(FleetRouter::preferred("tplA", 3), 1);
        assert_eq!(FleetRouter::preferred("tplB", 3), 2);
        // Same-class requests agree regardless of router instance.
        let mut a = FleetRouter::new(AffinityMode::Class, 0);
        let mut b = FleetRouter::new(AffinityMode::Class, 0);
        let snaps = [healthy(0), healthy(0)];
        assert_eq!(a.route("tplA", &snaps), b.route("tplA", &snaps));
    }

    #[test]
    fn class_mode_skips_dead_and_falls_through_in_preference_order() {
        let mut r = FleetRouter::new(AffinityMode::Class, 0);
        let mut snaps = [healthy(0), healthy(0)];
        assert_eq!(r.route("tplA", &snaps), Some(1));
        snaps[1].health = HealthState::Dead;
        // tplA falls through to its next-preferred live replica; tplB is
        // undisturbed (no global reshuffle).
        assert_eq!(r.route("tplA", &snaps), Some(0));
        assert_eq!(r.route("tplB", &snaps), Some(0));
        snaps[0].health = HealthState::Dead;
        assert_eq!(r.route("tplA", &snaps), None, "all dead: unroutable");
        assert_eq!(r.spills(), 0, "falling through a dead replica is not a spill");
    }

    #[test]
    fn class_mode_spills_at_high_water_to_least_loaded() {
        let mut r = FleetRouter::new(AffinityMode::Class, 2);
        // tplA prefers replica 1; its queue is at the mark → spill to the
        // least-loaded live replica.
        let snaps = [healthy(1), healthy(2), healthy(0)];
        assert_eq!(r.route("tplA", &snaps), Some(2));
        assert_eq!(r.spills(), 1);
        // Below the mark: pure affinity, no spill.
        let snaps = [healthy(1), healthy(1), healthy(0)];
        assert_eq!(r.route("tplA", &snaps), Some(1));
        assert_eq!(r.spills(), 1);
        // A probe-stale Busy state spills even when the instantaneous
        // queue reads below the mark.
        let mut snaps = [healthy(0), healthy(0), healthy(0)];
        snaps[1].health = HealthState::Busy;
        assert_eq!(r.route("tplA", &snaps), Some(0));
        assert_eq!(r.spills(), 2);
    }

    #[test]
    fn round_robin_rotates_and_skips_dead() {
        let mut r = FleetRouter::new(AffinityMode::RoundRobin, 0);
        let mut snaps = [healthy(0), healthy(0), healthy(0)];
        let picks: Vec<_> = (0..4).map(|_| r.route("anything", &snaps).unwrap()).collect();
        assert_eq!(picks, [0, 1, 2, 0], "class-blind rotation");
        snaps[1].health = HealthState::Dead;
        let picks: Vec<_> = (0..4).map(|_| r.route("anything", &snaps).unwrap()).collect();
        assert_eq!(picks, [2, 0, 2, 0], "dead replica skipped, rotation continues");
    }
}
