//! Fleet tier: N replica serve loops behind a footprint-affine router.
//!
//! One [`crate::coordinator::ServeLoop`] saturates one simulated
//! accelerator; the fleet is the horizontal axis. Each replica owns a full
//! engine + serving core on its own thread ([`replica`]), and the fleet
//! routes every submit by the request's **traffic-class key** — the same
//! [`crate::coordinator::Request::class_key`] footprint admission
//! aggregates under. Class-affine routing is what makes N replicas more
//! than N× a mixed pool: same-class requests share expert footprints, so
//! steering a class to a home replica keeps each replica's in-batch
//! activated-expert union narrow, which is precisely the quantity the
//! memsim cost model charges per step. `benches/serve_continuous.rs --
//! fleet` pins the claim: on a heterogeneous two-template trace, class
//! affinity beats class-blind round-robin on aggregate OTPS *and*
//! same-class TTFT at equal replica count, with byte-identical outputs.
//!
//! Routing ([`router`]) is rendezvous assignment overridden by
//! backpressure and health ([`health`]): a preferred replica whose queue
//! has reached `--fleet-high-water` spills to the least-loaded healthy
//! replica, and `Dead` replicas fall out of every class's preference
//! order without reshuffling the rest.
//!
//! ## Failover is lossless (the resume contract, one level up)
//!
//! The fleet mirrors every in-flight request's committed history from the
//! per-step token deltas. When a replica dies (step error, thread gone, or
//! the [`Fleet::kill_replica`] instrumentation hook), each stranded row is
//! rebuilt exactly like slot eviction rebuilds a preempted row
//! ([`crate::coordinator::eviction`]): committed history becomes the new
//! prompt and [`crate::coordinator::Request::resume_prefix`], the budget
//! shrinks by what was produced, and the request re-enters the router —
//! landing on the class's next-preferred live replica with its **origin**
//! submit clock and absolute deadline ([`crate::coordinator::ServeLoop::resubmit`]).
//! Under row-independent selection the continuation is byte-identical and
//! the TTFT sample stays origin-anchored and exactly-once: if first token
//! was already committed, the sample lives in the dead replica's final
//! metrics snapshot (captured by the kill hook) and survives into the
//! merged rollup; if not, the resubmitted row records it on the new
//! replica — `rust/tests/fleet.rs` pins both paths.
//!
//! Fleet-wide metrics are [`crate::metrics::ServeMetrics::merge`] over
//! replica snapshots: counters sum, histograms merge, clocks take the
//! makespan max — so aggregate OTPS is total tokens over fleet makespan,
//! not a sum of per-replica rates.

pub mod health;
pub mod replica;
pub mod router;

pub use health::{HealthState, HealthTracker};
pub use replica::{Pumped, ReplicaHandle, ReplicaStatus};
pub use router::{rendezvous_score, AffinityMode, FleetRouter, ReplicaSnapshot};

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::{Request, SubmitError};
use crate::metrics::ServeMetrics;
use crate::model::MoeModel;
use crate::runtime::{Engine, Manifest};
use crate::util::json::Json;

/// Fleet-side mirror of one in-flight request — everything failover needs
/// to rebuild it losslessly on another replica.
#[derive(Debug, Clone)]
struct Inflight {
    /// The request as originally submitted (prompt/budget untouched).
    original: Request,
    /// Every token the owning replica has committed so far (accumulated
    /// from step deltas).
    committed: Vec<u32>,
    /// Owning replica index.
    replica: usize,
    /// Origin submission clock (replica sim time at first admission).
    submit_sim: f64,
    /// Origin absolute deadline, if any.
    deadline_sim: Option<f64>,
}

/// N replica serve loops + router + health + the failover mirror.
pub struct Fleet {
    replicas: Vec<ReplicaHandle>,
    router: FleetRouter,
    health: HealthTracker,
    high_water: usize,
    inflight: BTreeMap<u64, Inflight>,
    /// Finished outputs by request id (complete generation incl. any
    /// resumed prefix), for batch-style callers; server-style callers
    /// stream off [`Pumped`] instead.
    outputs: BTreeMap<u64, Vec<u32>>,
    /// Final metrics of dead replicas (captured by the kill hook / last
    /// wave), folded into [`Fleet::report`].
    dead_metrics: BTreeMap<usize, ServeMetrics>,
    /// Requests finished per replica (the replica cores discard finished
    /// rows between waves, so the fleet keeps the tally).
    done_by_replica: Vec<u64>,
    /// Rows re-entered through the router after a replica death.
    failovers: u64,
}

/// One replica's row in [`Fleet::report`].
pub struct ReplicaReport {
    pub metrics: ServeMetrics,
    pub status: ReplicaStatus,
    pub dead: bool,
    pub requests_done: u64,
}

/// Fleet rollup: merged aggregate + per-replica breakdown.
pub struct FleetReport {
    pub aggregate: ServeMetrics,
    pub replicas: Vec<ReplicaReport>,
    pub spills: u64,
    pub failovers: u64,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let rows = self.replicas.iter().map(|r| {
            Json::obj(vec![
                ("queued", Json::num(r.status.queued as f64)),
                ("running", Json::num(r.status.running as f64)),
                ("sim_seconds", Json::num(r.metrics.sim_seconds)),
                ("tokens_out", Json::num(r.metrics.tokens_out as f64)),
                ("otps", Json::num(r.metrics.otps())),
                ("requests_done", Json::num(r.requests_done as f64)),
                ("dead", Json::Bool(r.dead)),
            ])
        });
        Json::obj(vec![
            ("aggregate", self.aggregate.to_json()),
            ("replicas", Json::arr(rows)),
            ("spills", Json::num(self.spills as f64)),
            ("failovers", Json::num(self.failovers as f64)),
        ])
    }
}

impl Fleet {
    /// Spawn one replica per builder. Every replica runs the SAME config
    /// (fleet knobs in `cfg` are read here; the per-replica serving core
    /// ignores them).
    pub fn spawn<F>(cfg: &ServeConfig, builders: Vec<F>) -> Result<Fleet>
    where
        F: FnOnce() -> Result<MoeModel> + Send + 'static,
    {
        let n = builders.len();
        if n == 0 {
            bail!("fleet needs at least one replica");
        }
        let mut replicas = Vec::with_capacity(n);
        for (i, build) in builders.into_iter().enumerate() {
            replicas.push(
                ReplicaHandle::spawn(cfg.clone(), build)
                    .with_context(|| format!("spawning fleet replica {i}"))?,
            );
        }
        Ok(Fleet {
            replicas,
            router: FleetRouter::new(cfg.fleet_affinity, cfg.fleet_high_water),
            health: HealthTracker::new(n, cfg.fleet_probe_every),
            high_water: cfg.fleet_high_water,
            inflight: BTreeMap::new(),
            outputs: BTreeMap::new(),
            dead_metrics: BTreeMap::new(),
            done_by_replica: vec![0; n],
            failovers: 0,
        })
    }

    /// Spawn `cfg.fleet_replicas` replicas of the preset at `dir`, each
    /// loading its own engine in its own thread (PJRT handles are not
    /// `Send`).
    pub fn from_preset_dir(dir: &std::path::Path, cfg: &ServeConfig) -> Result<Fleet> {
        let builders: Vec<_> = (0..cfg.fleet_replicas.max(1))
            .map(|_| {
                let dir = dir.to_path_buf();
                move || Manifest::load(&dir).and_then(Engine::load).and_then(MoeModel::new)
            })
            .collect();
        Fleet::spawn(cfg, builders)
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// True while any request is in flight anywhere in the fleet.
    pub fn has_work(&self) -> bool {
        !self.inflight.is_empty()
    }

    /// Rows re-routed after replica deaths so far.
    pub fn failovers(&self) -> u64 {
        self.failovers
    }

    /// Submits the router sent away from their affine target.
    pub fn spills(&self) -> u64 {
        self.router.spills()
    }

    /// Which replica currently owns in-flight request `id` (tests/benches).
    pub fn replica_of(&self, id: u64) -> Option<usize> {
        self.inflight.get(&id).map(|f| f.replica)
    }

    /// The fleet's committed-history mirror for in-flight request `id`.
    pub fn committed_of(&self, id: u64) -> Option<&[u32]> {
        self.inflight.get(&id).map(|f| f.committed.as_slice())
    }

    /// Finished outputs accumulated so far (complete generations).
    pub fn outputs(&self) -> &BTreeMap<u64, Vec<u32>> {
        &self.outputs
    }

    /// Drop accumulated outputs (long-lived server workers consume results
    /// from [`Pumped`] and must keep this map from growing forever —
    /// the fleet sibling of [`crate::coordinator::ServeLoop::discard_finished`]).
    pub fn discard_outputs(&mut self) {
        self.outputs.clear();
    }

    fn snapshots(&self) -> Vec<ReplicaSnapshot> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, h)| ReplicaSnapshot {
                queued: h.status().queued,
                running: h.status().running,
                health: if h.is_dead() { HealthState::Dead } else { self.health.state(i) },
            })
            .collect()
    }

    /// Probe every live replica and fold fresh queue depths into the
    /// health registry (the probe clock fires this from `submit`).
    fn refresh_health(&mut self) {
        for i in 0..self.replicas.len() {
            if self.replicas[i].is_dead() {
                self.health.mark_dead(i);
                continue;
            }
            match self.replicas[i].probe() {
                Ok(st) => self.health.observe(i, st.queued, self.high_water),
                Err(_) => self.health.mark_dead(i),
            }
        }
    }

    /// Route and submit one request. Outer `Err` = the fleet itself cannot
    /// take work (all replicas dead); inner `Err` = a typed per-request
    /// rejection from the chosen replica's admission. `Ok(Ok(i))` returns
    /// the replica index the request landed on.
    pub fn submit(&mut self, req: Request) -> Result<std::result::Result<usize, SubmitError>> {
        if self.health.tick() {
            self.refresh_health();
        }
        let key = req.class_key();
        loop {
            let snaps = self.snapshots();
            let Some(target) = self.router.route(&key, &snaps) else {
                bail!("fleet has no live replica");
            };
            match self.replicas[target].submit(req.clone()) {
                Ok(Ok(submit_sim)) => {
                    let deadline_sim =
                        req.deadline_ms.map(|ms| submit_sim + ms as f64 / 1e3);
                    self.inflight.insert(
                        req.id,
                        Inflight {
                            original: req,
                            committed: Vec::new(),
                            replica: target,
                            submit_sim,
                            deadline_sim,
                        },
                    );
                    return Ok(Ok(target));
                }
                Ok(Err(e)) => return Ok(Err(e)),
                Err(_) => {
                    // Replica died on contact: fail over its rows and let
                    // the router re-pick for this request.
                    self.on_replica_death(target)?;
                }
            }
        }
    }

    /// Start one wave command on every live replica, collect EVERY reply
    /// (the one-outstanding-command protocol: all replicas must be idle
    /// before any failover resubmits touch them), absorb the products,
    /// and return what was combined plus how many replicas took part.
    /// Replicas that died starting or finishing the wave are failed over
    /// afterwards.
    fn wave(
        &mut self,
        start: impl Fn(&mut ReplicaHandle) -> Result<()>,
    ) -> Result<(Pumped, usize)> {
        let mut started = Vec::new();
        let mut newly_dead = Vec::new();
        for i in 0..self.replicas.len() {
            if self.replicas[i].is_dead() {
                continue; // its rows were failed over when it died
            }
            match start(&mut self.replicas[i]) {
                Ok(()) => started.push(i),
                Err(_) => newly_dead.push(i),
            }
        }
        let participants = started.len();
        let mut combined = Pumped::default();
        for i in started {
            match self.replicas[i].collect_pumped() {
                Ok(p) => {
                    self.absorb(i, &p);
                    combined.finished.extend(p.finished);
                    combined.deltas.extend(p.deltas);
                    combined.steps += p.steps;
                }
                Err(_) => newly_dead.push(i),
            }
        }
        for i in newly_dead {
            self.on_replica_death(i)?;
        }
        Ok((combined, participants))
    }

    /// Advance every live replica's sim clock to `t` (stepping whatever
    /// work each has). Absorbs deltas/finishes; replica deaths mid-wave
    /// fail over.
    pub fn run_until(&mut self, t: f64) -> Result<()> {
        self.wave(|h| h.start_run_until(t))?;
        Ok(())
    }

    /// One serving step on every live replica (the server worker's
    /// cadence). Returns the combined outcome for response dispatch.
    pub fn pump(&mut self) -> Result<Pumped> {
        let (combined, _) = self.wave(ReplicaHandle::start_pump)?;
        Ok(combined)
    }

    /// Run the whole fleet to completion (batch-style callers). Loops
    /// because failover can hand a dying replica's rows to replicas that
    /// already drained.
    pub fn drain(&mut self) -> Result<()> {
        while self.has_work() {
            let (_, participants) = self.wave(ReplicaHandle::start_drain)?;
            if participants == 0 && self.has_work() {
                bail!("fleet has in-flight requests but no live replica");
            }
        }
        Ok(())
    }

    /// Submit a timed trace and run it to completion: for each `(t, req)`
    /// (non-decreasing `t`), advance the fleet to `t`, submit, then drain.
    /// Per-request admission rejections are returned; replica deaths fail
    /// over transparently.
    pub fn run_arrivals(
        &mut self,
        arrivals: Vec<(f64, Request)>,
    ) -> Result<Vec<(u64, SubmitError)>> {
        let mut rejected = Vec::new();
        for (t, req) in arrivals {
            self.run_until(t)?;
            let id = req.id;
            if let Err(e) = self.submit(req)? {
                rejected.push((id, e));
            }
        }
        self.drain()?;
        Ok(rejected)
    }

    /// Fold one replica's wave products into the fleet mirror.
    fn absorb(&mut self, replica: usize, p: &Pumped) {
        for (id, delta) in &p.deltas {
            if let Some(f) = self.inflight.get_mut(id) {
                f.committed.extend_from_slice(delta);
                f.replica = replica;
            }
        }
        for (id, out) in &p.finished {
            self.inflight.remove(id);
            self.outputs.insert(*id, out.clone());
            self.done_by_replica[replica] += 1;
        }
    }

    /// Instrumented replica crash (tests/benches): capture the dying
    /// replica's final metrics (preserving its recorded TTFT samples),
    /// strand its in-flight rows, then fail them over.
    pub fn kill_replica(&mut self, i: usize) -> Result<()> {
        if let Ok(m) = self.replicas[i].kill() {
            self.dead_metrics.insert(i, *m);
        }
        self.on_replica_death(i)
    }

    /// A replica is gone: mark it dead and re-enter every row it owned
    /// through the router as an origin-anchored resume. Worklist, not
    /// recursion — a failover target can itself die on contact.
    fn on_replica_death(&mut self, i: usize) -> Result<()> {
        let mut dead_list = vec![i];
        while let Some(dead) = dead_list.pop() {
            self.health.mark_dead(dead);
            let stranded: Vec<u64> = self
                .inflight
                .iter()
                .filter(|(_, f)| f.replica == dead)
                .map(|(&id, _)| id)
                .collect();
            for id in stranded {
                let f = self.inflight.get(&id).expect("stranded row in mirror").clone();
                // Rebuild exactly like eviction's requeue_request, from the
                // fleet-side mirror (the replica's SeqState is gone).
                let mut req = f.original.clone();
                req.evictions += 1;
                if !f.committed.is_empty() {
                    req.max_new_tokens = req.max_new_tokens.saturating_sub(f.committed.len());
                    req.prompt.extend_from_slice(&f.committed);
                    req.resume_prefix.extend_from_slice(&f.committed);
                }
                let key = req.class_key();
                loop {
                    let snaps = self.snapshots();
                    let Some(target) = self.router.route(&key, &snaps) else {
                        bail!("fleet has no live replica for failover of request {id}");
                    };
                    match self.replicas[target].resubmit(req.clone(), f.submit_sim, f.deadline_sim)
                    {
                        Ok(Ok(_)) => {
                            let row = self.inflight.get_mut(&id).expect("mirror row");
                            row.replica = target;
                            self.failovers += 1;
                            break;
                        }
                        Ok(Err(_)) => {
                            // Resume admission bypasses backpressure; a typed
                            // rejection here means the request itself is
                            // unservable — drop it from the mirror.
                            self.inflight.remove(&id);
                            break;
                        }
                        Err(_) => {
                            self.health.mark_dead(target);
                            dead_list.push(target);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Snapshot fleet-wide metrics: merged aggregate (counters summed,
    /// histograms merged, clocks maxed) + per-replica rows. Dead replicas
    /// contribute their final captured snapshot.
    pub fn report(&mut self) -> Result<FleetReport> {
        let mut rows = Vec::with_capacity(self.replicas.len());
        for i in 0..self.replicas.len() {
            let dead = self.replicas[i].is_dead();
            let mut metrics = if dead {
                self.dead_metrics.get(&i).cloned().unwrap_or_default()
            } else {
                match self.replicas[i].metrics() {
                    Ok(m) => *m,
                    // Died on contact: fall back to its last captured
                    // snapshot, if any.
                    Err(_) => self.dead_metrics.get(&i).cloned().unwrap_or_default(),
                }
            };
            metrics.requests_done = self.done_by_replica[i];
            rows.push(ReplicaReport {
                metrics,
                status: self.replicas[i].status(),
                dead: self.replicas[i].is_dead(),
                requests_done: self.done_by_replica[i],
            });
        }
        let mut aggregate = ServeMetrics::default();
        for r in &rows {
            aggregate.merge(&r.metrics);
        }
        Ok(FleetReport {
            aggregate,
            replicas: rows,
            spills: self.router.spills(),
            failovers: self.failovers,
        })
    }

    /// Graceful teardown (drops queued work; call [`Fleet::drain`] first
    /// if completion matters).
    pub fn shutdown(&mut self) {
        for h in &mut self.replicas {
            h.shutdown();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.shutdown();
    }
}
