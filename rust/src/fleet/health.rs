//! Replica health states and the probe clock.
//!
//! Three states, one-way into `Dead`:
//!
//! * `Healthy` — routable, preferred target eligible.
//! * `Busy` — the replica's admission queue has reached the configured
//!   high-water mark; the router spills its affine traffic to the
//!   least-loaded healthy replica until a later probe sees the queue
//!   drained. Only entered when backpressure is enabled
//!   (`--fleet-high-water` > 0).
//! * `Dead` — the replica thread is gone (channel closed, step error, or
//!   the kill instrumentation hook). Terminal: a dead replica is never
//!   routed to again, and its in-flight rows fail over through the
//!   lossless resume contract ([`super::Fleet`]).
//!
//! The probe clock is submission-driven, not wall-driven: every
//! `probe_every` fleet submits ([`HealthTracker::tick`] fires on the
//! first submit, then every Nth), the fleet re-reads each live replica's
//! queue depth and feeds it to [`HealthTracker::observe`]. Between
//! probes the states are sticky — exactly the staleness a real balancer
//! has between health checks, which is why the router ALSO checks the
//! instantaneous queue depth of its chosen target on every route (the
//! probe protects the fleet from replicas it has not touched lately; the
//! per-route check protects the hot path).

/// Routing-relevant state of one replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    /// Queue at/over the high-water mark as of the last probe.
    Busy,
    /// Replica thread gone. Terminal.
    Dead,
}

/// Consecutive healthy probes a `Busy` replica must accumulate before it
/// rejoins rendezvous preference. One good probe after a bad one is often
/// a queue momentarily dipping under the mark — without hysteresis a
/// replica hovering at the high-water line flaps Healthy↔Busy on every
/// probe, and each flap re-routes its whole affine class (defeating the
/// footprint-sharing the affinity exists for). Recovery therefore takes
/// `RECOVERY_PROBES` clean probes in a row; any over-mark probe resets
/// the streak.
pub const RECOVERY_PROBES: usize = 2;

/// Per-replica health registry + the submission-driven probe clock.
#[derive(Debug)]
pub struct HealthTracker {
    probe_every: usize,
    submits: usize,
    states: Vec<HealthState>,
    /// Consecutive under-mark probes seen by each Busy replica — the
    /// recovery-hysteresis streak ([`RECOVERY_PROBES`]). Always 0 for
    /// Healthy/Dead replicas.
    healthy_streak: Vec<usize>,
}

impl HealthTracker {
    /// `probe_every` is validated ≥ 1 at config parse time.
    pub fn new(n_replicas: usize, probe_every: usize) -> HealthTracker {
        assert!(probe_every >= 1, "probe_every must be ≥ 1");
        HealthTracker {
            probe_every,
            submits: 0,
            states: vec![HealthState::Healthy; n_replicas],
            healthy_streak: vec![0; n_replicas],
        }
    }

    /// Advance the probe clock by one submit; true when this submit should
    /// probe (the very first submit probes, then every `probe_every`th).
    pub fn tick(&mut self) -> bool {
        let fire = self.submits % self.probe_every == 0;
        self.submits += 1;
        fire
    }

    /// Fold one probed queue depth into replica `i`'s state. Dead is
    /// terminal. An at/over-mark probe (backpressure on, `high_water` > 0)
    /// flips to Busy immediately — overload reaction stays one probe fast.
    /// Recovery is hysteretic: a Busy replica needs [`RECOVERY_PROBES`]
    /// consecutive under-mark probes before it reads Healthy again, so a
    /// queue oscillating around the mark cannot flap the routing.
    pub fn observe(&mut self, i: usize, queued: usize, high_water: usize) {
        if self.states[i] == HealthState::Dead {
            return;
        }
        if high_water > 0 && queued >= high_water {
            self.states[i] = HealthState::Busy;
            self.healthy_streak[i] = 0;
            return;
        }
        if self.states[i] == HealthState::Busy {
            self.healthy_streak[i] += 1;
            if self.healthy_streak[i] < RECOVERY_PROBES {
                return; // still Busy: not enough clean probes in a row
            }
        }
        self.states[i] = HealthState::Healthy;
        self.healthy_streak[i] = 0;
    }

    /// Mark replica `i` dead (terminal).
    pub fn mark_dead(&mut self, i: usize) {
        self.states[i] = HealthState::Dead;
    }

    pub fn state(&self, i: usize) -> HealthState {
        self.states[i]
    }

    /// Replicas not marked dead.
    pub fn alive(&self) -> usize {
        self.states.iter().filter(|&&s| s != HealthState::Dead).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_clock_fires_first_then_every_nth() {
        let mut h = HealthTracker::new(2, 3);
        let fires: Vec<bool> = (0..7).map(|_| h.tick()).collect();
        assert_eq!(fires, [true, false, false, true, false, false, true]);
    }

    #[test]
    fn busy_tracks_high_water_and_dead_is_terminal() {
        let mut h = HealthTracker::new(2, 1);
        h.observe(0, 5, 4);
        assert_eq!(h.state(0), HealthState::Busy);
        // recovery is hysteretic: RECOVERY_PROBES consecutive clean probes
        for k in 0..RECOVERY_PROBES {
            assert_eq!(h.state(0), HealthState::Busy, "rejoined after {k} probes");
            h.observe(0, 3, 4);
        }
        assert_eq!(h.state(0), HealthState::Healthy);
        // high_water 0 = backpressure off: never Busy
        h.observe(0, 1000, 0);
        assert_eq!(h.state(0), HealthState::Healthy);
        h.mark_dead(0);
        h.observe(0, 0, 4);
        assert_eq!(h.state(0), HealthState::Dead, "dead is terminal");
        assert_eq!(h.alive(), 1);
    }

    #[test]
    fn busy_recovery_requires_consecutive_clean_probes() {
        // A queue oscillating under/over the mark never rejoins: every
        // over-mark probe resets the streak, so alternating good/bad
        // probes keep the replica Busy indefinitely (no flapping).
        let mut h = HealthTracker::new(1, 1);
        h.observe(0, 6, 4);
        assert_eq!(h.state(0), HealthState::Busy);
        for _ in 0..8 {
            h.observe(0, 2, 4); // one clean probe: streak 1 < RECOVERY_PROBES
            assert_eq!(h.state(0), HealthState::Busy, "flapped on a lone clean probe");
            h.observe(0, 9, 4); // relapse resets the streak
            assert_eq!(h.state(0), HealthState::Busy);
        }
        // a genuinely drained queue recovers after the full streak …
        for _ in 0..RECOVERY_PROBES {
            h.observe(0, 0, 4);
        }
        assert_eq!(h.state(0), HealthState::Healthy);
        // … and overload reaction stays one probe fast after recovery
        h.observe(0, 4, 4);
        assert_eq!(h.state(0), HealthState::Busy);
        // a replica that was never Busy reads Healthy with no warmup
        let mut fresh = HealthTracker::new(1, 1);
        fresh.observe(0, 1, 4);
        assert_eq!(fresh.state(0), HealthState::Healthy);
    }
}
