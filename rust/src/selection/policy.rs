//! The policy abstraction every deployment scenario plugs into.
//!
//! A [`SelectionPolicy`] looks at one layer's router scores for the current
//! batch and decides the expert subset S_l; the shared [`refine`] tail then
//! routes each token to its top-k within S_l. Baselines that are not
//! "select-then-refine" shaped (Dynamic-Skipping, Opportunistic) override
//! [`SelectionPolicy::route`] directly.

use super::expert_set::ExpertSet;
use super::refine::{refine, Routing};
use super::scores::ScoreMatrix;
use crate::ep::Placement;

/// Everything a policy may look at for one layer of one batch.
pub struct SelectionContext<'a> {
    /// Full-N softmax gate scores `[T × N]` (the paper's G^{(l)}).
    pub probs: &'a ScoreMatrix,
    /// Raw router logits `[T × N]` (refinement renormalizes in logit space).
    pub logits: &'a ScoreMatrix,
    /// Live token rows (padding rows excluded).
    pub rows: &'a [usize],
    /// Token rows grouped per request — set by the speculative-decoding
    /// scheduler (each group = 1 bonus token + L_s speculative tokens).
    pub requests: &'a [Vec<usize>],
    /// Batch utility Σ_i probs[i,:] over `rows`, if the accelerator already
    /// reduced it (the Pallas router kernel ships `colsum`).
    pub colsum_hint: Option<&'a [f32]>,
    /// Expert → GPU placement, for EP-aware selection.
    pub placement: Option<&'a Placement>,
    /// The model's native top-k.
    pub top_k: usize,
}

impl<'a> SelectionContext<'a> {
    /// Batch utility over the live rows, using the accelerator-reduced hint
    /// when available.
    pub fn batch_utility(&self) -> Vec<f32> {
        match self.colsum_hint {
            Some(c) => c.to_vec(),
            None => self.probs.col_sums(Some(self.rows)),
        }
    }
}

/// A batch-aware expert selection policy (one of the paper's algorithms or
/// a baseline).
pub trait SelectionPolicy: Send + Sync {
    /// Human-readable name with parameters, e.g. `batch_aware(m=24,k0=1)`.
    fn name(&self) -> String;

    /// Choose the expert subset S_l for this layer.
    fn select(&self, ctx: &SelectionContext) -> ExpertSet;

    /// Full routing decision. Default: select then refine (Algorithm 2/4/6
    /// shape). Token-level baselines override this.
    fn route(&self, ctx: &SelectionContext) -> Routing {
        let selected = self.select(ctx);
        refine(ctx.logits, ctx.rows, &selected, ctx.top_k)
    }
}

/// Parsed policy configuration — what the config file / CLI / benches name.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    /// Unrestricted top-k routing (the paper's baseline).
    Vanilla,
    /// Algorithm 2: warm-up k0 + greedy budget m_l.
    BatchAware { budget: usize, k0: usize },
    /// Algorithm 4: hierarchical — per-request budget m_r, warm-up k0,
    /// then batch-level greedy top-up budget m.
    SpecAware { k0: usize, batch_budget: usize, req_budget: usize },
    /// Algorithm 6: warm-up k0 + GPU-balanced greedy, per-GPU budget m_g.
    GpuAware { k0: usize, per_gpu_budget: usize },
    /// LYNX-Lat (Gupta et al. 2024): drop the `drop` least-frequently
    /// requested experts from the batch union.
    LynxLat { drop: usize },
    /// Dynamic Skipping (Lu et al. 2024): per token, skip expert e_r when
    /// g_r < beta * g_0.
    DynamicSkip { beta: f32 },
    /// Opportunistic (Oncescu et al. 2025): own top-k' + piggyback the
    /// remaining k-k' slots on the batch pool.
    Opportunistic { k_prime: usize },
}

impl PolicyKind {
    /// Parse e.g. `vanilla`, `batch:24:1`, `spec:1:0:4`, `gpu:1:5`,
    /// `lynx:16`, `skip:0.3`, `opp:2`.
    pub fn parse(s: &str) -> Result<PolicyKind, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let usage = "expected vanilla | batch:<m>:<k0> | spec:<k0>:<m>:<mr> | \
                     gpu:<k0>:<mg> | lynx:<drop> | skip:<beta> | opp:<k'>";
        let p = |v: &str| -> Result<usize, String> {
            v.parse().map_err(|_| format!("bad integer '{v}' in '{s}'; {usage}"))
        };
        match parts.as_slice() {
            ["vanilla"] => Ok(PolicyKind::Vanilla),
            ["batch", m, k0] => Ok(PolicyKind::BatchAware { budget: p(m)?, k0: p(k0)? }),
            ["spec", k0, m, mr] => Ok(PolicyKind::SpecAware {
                k0: p(k0)?,
                batch_budget: p(m)?,
                req_budget: p(mr)?,
            }),
            ["gpu", k0, mg] => {
                Ok(PolicyKind::GpuAware { k0: p(k0)?, per_gpu_budget: p(mg)? })
            }
            ["lynx", d] => Ok(PolicyKind::LynxLat { drop: p(d)? }),
            ["skip", b] => Ok(PolicyKind::DynamicSkip {
                beta: b.parse().map_err(|_| format!("bad float '{b}'; {usage}"))?,
            }),
            ["opp", kp] => Ok(PolicyKind::Opportunistic { k_prime: p(kp)? }),
            _ => Err(format!("unknown policy '{s}'; {usage}")),
        }
    }

    /// Instantiate the policy object.
    pub fn build(&self) -> Box<dyn SelectionPolicy> {
        use super::{baselines, batch_aware::BatchAware, gpu_aware::GpuAware,
                    spec_aware::SpecAware};
        match *self {
            PolicyKind::Vanilla => Box::new(baselines::Vanilla),
            PolicyKind::BatchAware { budget, k0 } => Box::new(BatchAware { budget, k0 }),
            PolicyKind::SpecAware { k0, batch_budget, req_budget } => {
                Box::new(SpecAware { k0, batch_budget, req_budget })
            }
            PolicyKind::GpuAware { k0, per_gpu_budget } => {
                Box::new(GpuAware { k0, per_gpu_budget })
            }
            PolicyKind::LynxLat { drop } => Box::new(baselines::LynxLat { drop }),
            PolicyKind::DynamicSkip { beta } => {
                Box::new(baselines::DynamicSkip { beta })
            }
            PolicyKind::Opportunistic { k_prime } => {
                Box::new(baselines::Opportunistic { k_prime })
            }
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicyKind::Vanilla => write!(f, "vanilla"),
            PolicyKind::BatchAware { budget, k0 } => write!(f, "batch:{budget}:{k0}"),
            PolicyKind::SpecAware { k0, batch_budget, req_budget } => {
                write!(f, "spec:{k0}:{batch_budget}:{req_budget}")
            }
            PolicyKind::GpuAware { k0, per_gpu_budget } => {
                write!(f, "gpu:{k0}:{per_gpu_budget}")
            }
            PolicyKind::LynxLat { drop } => write!(f, "lynx:{drop}"),
            PolicyKind::DynamicSkip { beta } => write!(f, "skip:{beta}"),
            PolicyKind::Opportunistic { k_prime } => write!(f, "opp:{k_prime}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["vanilla", "batch:24:1", "spec:1:0:4", "gpu:1:5", "lynx:16", "opp:2"] {
            let k = PolicyKind::parse(s).unwrap();
            assert_eq!(k.to_string(), s);
            assert_eq!(PolicyKind::parse(&k.to_string()).unwrap(), k);
        }
        let k = PolicyKind::parse("skip:0.3").unwrap();
        assert_eq!(k, PolicyKind::DynamicSkip { beta: 0.3 });
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(PolicyKind::parse("").is_err());
        assert!(PolicyKind::parse("batch:x:1").is_err());
        assert!(PolicyKind::parse("spec:1:2").is_err());
        assert!(PolicyKind::parse("nope:1").is_err());
    }

    #[test]
    fn build_produces_named_policies() {
        let p = PolicyKind::parse("batch:12:2").unwrap().build();
        assert!(p.name().contains("12"));
        assert!(p.name().contains('2'));
    }
}
