//! Compact expert subsets (S_l in the paper) as bitsets.
//!
//! N is at most a few hundred (256 for DeepSeek-R1 geometry), so a handful
//! of u64 words keeps membership tests and unions branch-free on the decode
//! hot path.

/// A subset of the N experts of one layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpertSet {
    n_experts: usize,
    words: Vec<u64>,
}

impl ExpertSet {
    pub fn empty(n_experts: usize) -> Self {
        ExpertSet { n_experts, words: vec![0; n_experts.div_ceil(64)] }
    }

    pub fn full(n_experts: usize) -> Self {
        let mut s = Self::empty(n_experts);
        for j in 0..n_experts {
            s.insert(j);
        }
        s
    }

    pub fn from_indices(n_experts: usize, idx: &[usize]) -> Self {
        let mut s = Self::empty(n_experts);
        for &j in idx {
            s.insert(j);
        }
        s
    }

    #[inline]
    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    #[inline]
    pub fn insert(&mut self, j: usize) {
        debug_assert!(j < self.n_experts);
        self.words[j / 64] |= 1u64 << (j % 64);
    }

    #[inline]
    pub fn remove(&mut self, j: usize) {
        self.words[j / 64] &= !(1u64 << (j % 64));
    }

    #[inline]
    pub fn contains(&self, j: usize) -> bool {
        (self.words[j / 64] >> (j % 64)) & 1 == 1
    }

    /// |S| — the paper's "number of activated experts".
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn union_with(&mut self, other: &ExpertSet) {
        debug_assert_eq!(self.n_experts, other.n_experts);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    pub fn intersection_len(&self, other: &ExpertSet) -> usize {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// Ascending member indices.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(w, &bits)| {
            let mut b = bits;
            std::iter::from_fn(move || {
                if b == 0 {
                    None
                } else {
                    let t = b.trailing_zeros() as usize;
                    b &= b - 1;
                    Some(w * 64 + t)
                }
            })
        })
    }

    pub fn to_vec(&self) -> Vec<usize> {
        self.iter().collect()
    }

    /// 0/1 mask (feeds straight into gate-matrix construction).
    pub fn to_mask(&self) -> Vec<f32> {
        (0..self.n_experts).map(|j| if self.contains(j) { 1.0 } else { 0.0 }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut s = ExpertSet::empty(130);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(64);
        s.insert(129);
        assert!(s.contains(0) && s.contains(64) && s.contains(129));
        assert!(!s.contains(1) && !s.contains(128));
        assert_eq!(s.len(), 3);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn union_and_intersection() {
        let a = ExpertSet::from_indices(100, &[1, 2, 3, 70]);
        let b = ExpertSet::from_indices(100, &[3, 70, 99]);
        let mut u = a.clone();
        u.union_with(&b);
        assert_eq!(u.len(), 5);
        assert_eq!(a.intersection_len(&b), 2);
    }

    #[test]
    fn iter_is_sorted_and_complete() {
        let s = ExpertSet::from_indices(200, &[199, 0, 63, 64, 65]);
        assert_eq!(s.to_vec(), vec![0, 63, 64, 65, 199]);
    }

    #[test]
    fn full_set() {
        let s = ExpertSet::full(67);
        assert_eq!(s.len(), 67);
        assert!(s.contains(66));
    }

    #[test]
    fn mask_matches_membership() {
        let s = ExpertSet::from_indices(5, &[1, 4]);
        assert_eq!(s.to_mask(), vec![0.0, 1.0, 0.0, 0.0, 1.0]);
    }
}
