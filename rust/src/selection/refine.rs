//! Refinement step (shared tail of Algorithms 2, 4 and 6): route every token
//! to its top-k experts **within** the selected set S_l, then renormalize the
//! gate weights over the chosen experts (the paper's ḡ restricted to 𝒯).
//!
//! The output gate matrix is dense `[T × N]` with zeros outside each token's
//! chosen experts — exactly the layout the `moe_layer` HLO artifact consumes.

use super::expert_set::ExpertSet;
use super::scores::{topk_indices_where, ScoreMatrix};

/// Final routing decision for one MoE layer.
#[derive(Debug, Clone)]
pub struct Routing {
    /// Renormalized gate weights, zeros outside chosen experts. `[T × N]`.
    pub gates: ScoreMatrix,
    /// Chosen expert indices per token (≤ k each, descending gate order).
    pub chosen: Vec<Vec<usize>>,
    /// Union of experts actually used by ≥1 token — the paper's
    /// "number of activated experts" for this layer.
    pub activated: ExpertSet,
}

impl Routing {
    pub fn n_activated(&self) -> usize {
        self.activated.len()
    }
}

/// Route each token in `rows` to its top-`k` experts within `selected`,
/// renormalizing gates by softmax over the chosen experts' logits.
///
/// `logits` are the raw router outputs (renormalization must happen in logit
/// space to match the paper's gating definition §2.2); ranking within S is
/// identical whether done on logits or their full-N softmax.
///
/// Rows not listed in `rows` (padding) get all-zero gate rows.
pub fn refine(
    logits: &ScoreMatrix,
    rows: &[usize],
    selected: &ExpertSet,
    k: usize,
) -> Routing {
    let n = logits.n_experts();
    let mut gates = ScoreMatrix::zeros(logits.n_tokens(), n);
    let mut chosen = vec![Vec::new(); logits.n_tokens()];
    let mut activated = ExpertSet::empty(n);

    for &i in rows {
        let row = logits.row(i);
        let top = topk_indices_where(row, k, |j| selected.contains(j));
        if top.is_empty() {
            continue;
        }
        // softmax over the chosen logits only
        let m = top.iter().map(|&j| row[j]).fold(f32::NEG_INFINITY, f32::max);
        let mut exps: Vec<f32> = top.iter().map(|&j| (row[j] - m).exp()).collect();
        let sum: f32 = exps.iter().sum();
        for e in &mut exps {
            *e /= sum;
        }
        let out = gates.row_mut(i);
        for (&j, &g) in top.iter().zip(&exps) {
            out[j] = g;
            activated.insert(j);
        }
        chosen[i] = top;
    }
    Routing { gates, chosen, activated }
}

/// Vanilla top-k routing (the serving baseline): refinement against the full
/// expert set.
pub fn vanilla_topk(logits: &ScoreMatrix, rows: &[usize], k: usize) -> Routing {
    refine(logits, rows, &ExpertSet::full(logits.n_experts()), k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn logits_2x4() -> ScoreMatrix {
        ScoreMatrix::from_rows(&[vec![2.0, 1.0, 0.0, -1.0], vec![-1.0, 0.0, 1.0, 2.0]])
    }

    #[test]
    fn vanilla_selects_per_token_topk() {
        let r = vanilla_topk(&logits_2x4(), &[0, 1], 2);
        assert_eq!(r.chosen[0], vec![0, 1]);
        assert_eq!(r.chosen[1], vec![3, 2]);
        assert_eq!(r.n_activated(), 4);
    }

    #[test]
    fn gates_rows_sum_to_one_over_chosen() {
        let r = vanilla_topk(&logits_2x4(), &[0, 1], 2);
        for i in 0..2 {
            let s: f32 = r.gates.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn restriction_forces_tokens_into_selected_set() {
        let sel = ExpertSet::from_indices(4, &[1, 2]);
        let r = refine(&logits_2x4(), &[0, 1], &sel, 2);
        assert_eq!(r.chosen[0], vec![1, 2]);
        assert_eq!(r.chosen[1], vec![2, 1]);
        assert_eq!(r.activated.to_vec(), vec![1, 2]);
        // gate weight zero outside S
        assert_eq!(r.gates.get(0, 0), 0.0);
        assert_eq!(r.gates.get(1, 3), 0.0);
    }

    #[test]
    fn renormalization_matches_restricted_softmax() {
        let sel = ExpertSet::from_indices(4, &[0, 1]);
        let r = refine(&logits_2x4(), &[0], &sel, 2);
        let (a, b) = (2.0f32, 1.0f32);
        let ea = (a - a).exp();
        let eb = (b - a).exp();
        assert!((r.gates.get(0, 0) - ea / (ea + eb)).abs() < 1e-6);
        assert!((r.gates.get(0, 1) - eb / (ea + eb)).abs() < 1e-6);
    }

    #[test]
    fn padding_rows_left_zero() {
        let r = refine(&logits_2x4(), &[0], &ExpertSet::full(4), 2);
        assert!(r.gates.row(1).iter().all(|&v| v == 0.0));
        assert!(r.chosen[1].is_empty());
    }

    #[test]
    fn selected_smaller_than_k_uses_whole_set() {
        let sel = ExpertSet::from_indices(4, &[2]);
        let r = refine(&logits_2x4(), &[0, 1], &sel, 3);
        assert_eq!(r.chosen[0], vec![2]);
        assert!((r.gates.get(0, 2) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn prop_refinement_invariants() {
        forall(
            103,
            200,
            |r: &mut Rng| {
                let t = 1 + r.below(12);
                let n = 2 + r.below(40);
                let k = 1 + r.below(6);
                let rows: Vec<Vec<f32>> = (0..t)
                    .map(|_| (0..n).map(|_| r.normal_f32(0.0, 2.0)).collect())
                    .collect();
                let sel_count = 1 + r.below(n);
                let sel = r.sample_indices(n, sel_count);
                (rows, sel, k)
            },
            |(rows, sel, k)| {
                let logits = ScoreMatrix::from_rows(rows);
                let n = logits.n_experts();
                let all_rows: Vec<usize> = (0..logits.n_tokens()).collect();
                let selected = ExpertSet::from_indices(n, sel);
                let r = refine(&logits, &all_rows, &selected, *k);
                for i in 0..logits.n_tokens() {
                    let chosen = &r.chosen[i];
                    crate::prop_assert!(
                        chosen.len() == (*k).min(selected.len()),
                        "token {i}: {} chosen, want min(k={k}, |S|={})",
                        chosen.len(),
                        selected.len()
                    );
                    for &j in chosen {
                        crate::prop_assert!(selected.contains(j), "chose outside S");
                    }
                    let s: f32 = r.gates.row(i).iter().sum();
                    crate::prop_assert!((s - 1.0).abs() < 1e-5, "row sum {s}");
                    // zero outside chosen
                    for j in 0..n {
                        if !chosen.contains(&j) {
                            crate::prop_assert!(
                                r.gates.get(i, j) == 0.0,
                                "nonzero gate outside chosen"
                            );
                        }
                    }
                }
                // activated == union of chosen
                let mut want = ExpertSet::empty(n);
                for c in &r.chosen {
                    for &j in c {
                        want.insert(j);
                    }
                }
                crate::prop_assert!(r.activated == want, "activated mismatch");
                Ok(())
            },
        );
    }
}
