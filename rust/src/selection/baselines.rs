//! Published baselines the paper compares against (or discusses):
//!
//! * [`Vanilla`] — unrestricted per-token top-k (the serving baseline).
//! * [`LynxLat`] — Lynx's latency policy (Gupta et al. 2024): aggregate
//!   per-token expert *requests* across the batch, drop a fixed number of
//!   the least-used experts. The paper notes Lynx is described only
//!   conceptually; this implementation follows the description literally:
//!   usage = how many tokens put the expert in their top-k, drop the `drop`
//!   lowest-usage experts of the batch union.
//! * [`DynamicSkip`] — Dynamic Skipping (Lu et al. 2024): token-local —
//!   keep the top-1 expert always, keep expert ranked r iff
//!   g_r ≥ β · g_0. No batch awareness.
//! * [`Opportunistic`] — concurrent work (Oncescu et al. 2025): every token
//!   contributes its top-k' (k' < k) to a shared pool, then fills its
//!   remaining k−k' slots with its best experts *from the pool*.

use super::expert_set::ExpertSet;
use super::policy::{SelectionContext, SelectionPolicy};
use super::refine::{refine, vanilla_topk, Routing};
use super::scores::{topk_indices, ScoreMatrix};

// ---------------------------------------------------------------------------
// Vanilla
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
pub struct Vanilla;

impl SelectionPolicy for Vanilla {
    fn name(&self) -> String {
        "vanilla".into()
    }

    fn select(&self, ctx: &SelectionContext) -> ExpertSet {
        // Union of per-token top-k — restriction to it is a no-op.
        let mut s = ExpertSet::empty(ctx.probs.n_experts());
        for &i in ctx.rows {
            for j in topk_indices(ctx.probs.row(i), ctx.top_k) {
                s.insert(j);
            }
        }
        s
    }

    fn route(&self, ctx: &SelectionContext) -> Routing {
        vanilla_topk(ctx.logits, ctx.rows, ctx.top_k)
    }
}

// ---------------------------------------------------------------------------
// LYNX-Lat
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
pub struct LynxLat {
    /// Number of experts to drop from the batch union (tuned offline in the
    /// original; a sweep parameter here).
    pub drop: usize,
}

impl SelectionPolicy for LynxLat {
    fn name(&self) -> String {
        format!("lynx_lat(drop={})", self.drop)
    }

    fn select(&self, ctx: &SelectionContext) -> ExpertSet {
        let n = ctx.probs.n_experts();
        // usage[j] = #tokens with j in their top-k
        let mut usage = vec![0u32; n];
        for &i in ctx.rows {
            for j in topk_indices(ctx.probs.row(i), ctx.top_k) {
                usage[j] += 1;
            }
        }
        let mut used: Vec<usize> = (0..n).filter(|&j| usage[j] > 0).collect();
        // least-used first; ties by higher index dropped first (arbitrary
        // but fixed)
        used.sort_by(|&a, &b| usage[a].cmp(&usage[b]).then(b.cmp(&a)));
        let keep = used.len().saturating_sub(self.drop);
        // keep the most-used `keep` experts
        let mut s = ExpertSet::empty(n);
        for &j in used.iter().rev().take(keep) {
            s.insert(j);
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Dynamic Skipping
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
pub struct DynamicSkip {
    /// β: skip expert ranked r (r ≥ 1) when g_r < β · g_0.
    pub beta: f32,
}

impl SelectionPolicy for DynamicSkip {
    fn name(&self) -> String {
        format!("dynamic_skip(beta={})", self.beta)
    }

    fn select(&self, ctx: &SelectionContext) -> ExpertSet {
        // Union of per-token kept experts (for activation accounting).
        let mut s = ExpertSet::empty(ctx.probs.n_experts());
        for &i in ctx.rows {
            for j in self.kept_for(ctx.probs.row(i), ctx.top_k) {
                s.insert(j);
            }
        }
        s
    }

    fn route(&self, ctx: &SelectionContext) -> Routing {
        // Token-local: each token routes to its own kept set; build the gate
        // matrix directly (renormalized over kept experts).
        let n = ctx.logits.n_experts();
        let mut gates = ScoreMatrix::zeros(ctx.logits.n_tokens(), n);
        let mut chosen = vec![Vec::new(); ctx.logits.n_tokens()];
        let mut activated = ExpertSet::empty(n);
        for &i in ctx.rows {
            let kept = self.kept_for(ctx.probs.row(i), ctx.top_k);
            let row = ctx.logits.row(i);
            let m = kept.iter().map(|&j| row[j]).fold(f32::NEG_INFINITY, f32::max);
            let mut exps: Vec<f32> = kept.iter().map(|&j| (row[j] - m).exp()).collect();
            let sum: f32 = exps.iter().sum();
            for e in &mut exps {
                *e /= sum;
            }
            let out = gates.row_mut(i);
            for (&j, &g) in kept.iter().zip(&exps) {
                out[j] = g;
                activated.insert(j);
            }
            chosen[i] = kept;
        }
        Routing { gates, chosen, activated }
    }
}

impl DynamicSkip {
    fn kept_for(&self, probs_row: &[f32], k: usize) -> Vec<usize> {
        let top = topk_indices(probs_row, k);
        if top.is_empty() {
            return top;
        }
        let g0 = probs_row[top[0]];
        top.into_iter()
            .enumerate()
            .filter(|&(rank, j)| rank == 0 || probs_row[j] >= self.beta * g0)
            .map(|(_, j)| j)
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Opportunistic (Oncescu et al.)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
pub struct Opportunistic {
    /// k': guaranteed own experts per token (k' < k); the pool is the union
    /// of everyone's top-k'.
    pub k_prime: usize,
}

impl SelectionPolicy for Opportunistic {
    fn name(&self) -> String {
        format!("opportunistic(k'={})", self.k_prime)
    }

    fn select(&self, ctx: &SelectionContext) -> ExpertSet {
        let mut pool = ExpertSet::empty(ctx.probs.n_experts());
        for &i in ctx.rows {
            for j in topk_indices(ctx.probs.row(i), self.k_prime) {
                pool.insert(j);
            }
        }
        pool
    }

    fn route(&self, ctx: &SelectionContext) -> Routing {
        // Each token: top-k within the pool. Since its own top-k' is in the
        // pool by construction, this reproduces "own k' + piggyback k−k'".
        let pool = self.select(ctx);
        refine(ctx.logits, ctx.rows, &pool, ctx.top_k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::scores::softmax_in_place;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn ctx<'a>(
        probs: &'a ScoreMatrix,
        rows: &'a [usize],
        top_k: usize,
    ) -> SelectionContext<'a> {
        SelectionContext {
            probs,
            logits: probs,
            rows,
            requests: &[],
            colsum_hint: None,
            placement: None,
            top_k,
        }
    }

    fn demo_probs() -> ScoreMatrix {
        ScoreMatrix::from_rows(&[
            vec![0.50, 0.30, 0.10, 0.05, 0.05],
            vec![0.45, 0.35, 0.10, 0.05, 0.05],
            vec![0.05, 0.05, 0.10, 0.50, 0.30],
        ])
    }

    #[test]
    fn vanilla_activates_union_of_topk() {
        let p = demo_probs();
        let rows = [0, 1, 2];
        let r = Vanilla.route(&ctx(&p, &rows, 2));
        assert_eq!(r.activated.to_vec(), vec![0, 1, 3, 4]);
        assert_eq!(r.chosen[0], vec![0, 1]);
    }

    #[test]
    fn lynx_drops_least_used() {
        let p = demo_probs();
        let rows = [0, 1, 2];
        // usage with k=2: e0:2, e1:2, e3:1, e4:1
        let s = LynxLat { drop: 2 }.select(&ctx(&p, &rows, 2));
        assert_eq!(s.to_vec(), vec![0, 1]);
    }

    #[test]
    fn lynx_drop_zero_equals_vanilla_union() {
        let p = demo_probs();
        let rows = [0, 1, 2];
        let s = LynxLat { drop: 0 }.select(&ctx(&p, &rows, 2));
        assert_eq!(s, Vanilla.select(&ctx(&p, &rows, 2)));
    }

    #[test]
    fn lynx_can_hurt_a_tokens_top_expert() {
        // The failure mode the paper calls out: a dropped expert can be some
        // token's #1. Token 2's top expert (3) has usage 1 and gets dropped.
        let p = demo_probs();
        let rows = [0, 1, 2];
        let s = LynxLat { drop: 2 }.select(&ctx(&p, &rows, 2));
        assert!(!s.contains(3));
        let routed = refine(&p, &rows, &s, 2);
        // token 2 is forced onto experts {0,1} despite preferring {3,4}
        assert_eq!(routed.chosen[2], vec![0, 1]);
        for &j in &routed.chosen[2] {
            assert!(s.contains(j));
        }
    }

    #[test]
    fn dynamic_skip_keeps_top1_always() {
        let p = ScoreMatrix::from_rows(&[vec![0.97, 0.01, 0.01, 0.01]]);
        let r = DynamicSkip { beta: 0.5 }.route(&ctx(&p, &[0], 3));
        assert_eq!(r.chosen[0], vec![0]);
        assert!((r.gates.get(0, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dynamic_skip_beta_zero_equals_vanilla() {
        let p = demo_probs();
        let rows = [0, 1, 2];
        let a = DynamicSkip { beta: 0.0 }.route(&ctx(&p, &rows, 2));
        let b = Vanilla.route(&ctx(&p, &rows, 2));
        for i in 0..3 {
            assert_eq!(a.chosen[i], b.chosen[i]);
        }
    }

    #[test]
    fn dynamic_skip_threshold_drops_weak_experts() {
        let p = ScoreMatrix::from_rows(&[vec![0.5, 0.3, 0.15, 0.05]]);
        let kept = DynamicSkip { beta: 0.5 }.route(&ctx(&p, &[0], 4));
        // keep 0 (top-1), 1 (0.3 ≥ 0.25), drop 2 (0.15 < 0.25), drop 3
        assert_eq!(kept.chosen[0], vec![0, 1]);
    }

    #[test]
    fn opportunistic_pool_is_topkprime_union() {
        let p = demo_probs();
        let rows = [0, 1, 2];
        let pol = Opportunistic { k_prime: 1 };
        let s = pol.select(&ctx(&p, &rows, 2));
        assert_eq!(s.to_vec(), vec![0, 3]);
        let r = pol.route(&ctx(&p, &rows, 2));
        // every token still gets k experts (pool size ≥ k here)
        assert_eq!(r.chosen[0].len(), 2);
        // token 0's second slot piggybacks on 3 (the only other pool member)
        assert_eq!(r.chosen[0], vec![0, 3]);
    }

    #[test]
    fn prop_baseline_routing_stays_inside_selected() {
        forall(
            501,
            100,
            |r: &mut Rng| {
                let t = 1 + r.below(10);
                let n = 6 + r.below(40);
                (t, n, r.next_u64())
            },
            |&(t, n, seed)| {
                let mut r = Rng::new(seed);
                let rows_v: Vec<Vec<f32>> = (0..t)
                    .map(|_| {
                        let mut row: Vec<f32> =
                            (0..n).map(|_| r.normal_f32(0.0, 2.0)).collect();
                        softmax_in_place(&mut row);
                        row
                    })
                    .collect();
                let probs = ScoreMatrix::from_rows(&rows_v);
                let rows: Vec<usize> = (0..t).collect();
                let policies: Vec<Box<dyn SelectionPolicy>> = vec![
                    Box::new(LynxLat { drop: 3 }),
                    Box::new(DynamicSkip { beta: 0.4 }),
                    Box::new(Opportunistic { k_prime: 1 }),
                ];
                for pol in &policies {
                    let c = ctx(&probs, &rows, 3);
                    let routed = pol.route(&c);
                    for (i, ch) in routed.chosen.iter().enumerate() {
                        crate::prop_assert!(
                            ch.len() <= 3,
                            "{}: token {i} got {} experts",
                            pol.name(),
                            ch.len()
                        );
                        let gsum: f32 = routed.gates.row(i).iter().sum();
                        if !ch.is_empty() {
                            crate::prop_assert!(
                                (gsum - 1.0).abs() < 1e-5,
                                "{}: gates sum {gsum}",
                                pol.name()
                            );
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
