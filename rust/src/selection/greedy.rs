//! **Algorithm 1 — Greedy Expert Selection (per layer).**
//!
//! The per-layer proxy f_l(S) = Σ_{j∈S} Σ_i g_{i,j} is *modular*
//! (Proposition 3.2): each expert's marginal gain is its batch utility
//! u_j = Σ_i g_{i,j}, independent of S. Greedy — repeatedly adding the
//! highest-utility expert not yet selected — is therefore **optimal** for
//! the budgeted subproblem (Corollary 3.3), and reduces to sorting experts
//! by u_j.
//!
//! Budget convention (matches the paper's experiment grids, e.g. Fig 4's
//! "(0,1) = warm-up only"): `budget` is the number of experts greedy ADDS on
//! top of the warm-up set S_0, so (m_l=0, k_0=1) selects exactly the
//! warm-up union.

use super::expert_set::ExpertSet;
use super::scores::ScoreMatrix;

/// Greedily add the `budget` highest-utility experts from E \ S_0.
///
/// `utility[j]` is Σ_i g_{i,j} (the modular marginal gain). Returns the
/// final set S ⊇ S_0 with |S| ≤ |S_0| + budget.
pub fn greedy_select(utility: &[f32], budget: usize, warm: &ExpertSet) -> ExpertSet {
    let mut selected = warm.clone();
    if budget == 0 {
        return selected;
    }
    // Modularity ⇒ one sort of the remaining experts is the full greedy run.
    let mut rest: Vec<usize> = (0..utility.len()).filter(|&j| !warm.contains(j)).collect();
    rest.sort_by(|&a, &b| {
        utility[b]
            .partial_cmp(&utility[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    for &j in rest.iter().take(budget) {
        selected.insert(j);
    }
    selected
}

/// Warm-up initialization: S_0 = ∪_i Top-k0(G_i) over `rows` of the score
/// matrix (every token's k0 highest-confidence experts are always kept).
pub fn warmup_set(scores: &ScoreMatrix, rows: &[usize], k0: usize) -> ExpertSet {
    let mut s = ExpertSet::empty(scores.n_experts());
    if k0 == 0 {
        return s;
    }
    for &i in rows {
        for j in super::scores::topk_indices(scores.row(i), k0) {
            s.insert(j);
        }
    }
    s
}

/// Literal step-by-step greedy (argmax loop) — kept as an executable witness
/// of Corollary 3.3: the tests assert it selects exactly the same set as the
/// sort-based fast path for every input.
pub fn greedy_select_naive(utility: &[f32], budget: usize, warm: &ExpertSet) -> ExpertSet {
    let mut selected = warm.clone();
    for _ in 0..budget {
        let mut best: Option<usize> = None;
        for j in 0..utility.len() {
            if selected.contains(j) {
                continue;
            }
            best = match best {
                None => Some(j),
                Some(b) if utility[j] > utility[b] => Some(j),
                keep => keep,
            };
        }
        match best {
            Some(j) => selected.insert(j),
            None => break,
        }
    }
    selected
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn budget_zero_is_warmup_only() {
        let warm = ExpertSet::from_indices(8, &[2, 5]);
        let got = greedy_select(&[9.0; 8], 0, &warm);
        assert_eq!(got, warm);
    }

    #[test]
    fn picks_top_utility_experts() {
        let utility = [0.1, 0.9, 0.3, 0.8, 0.2];
        let got = greedy_select(&utility, 2, &ExpertSet::empty(5));
        assert_eq!(got.to_vec(), vec![1, 3]);
    }

    #[test]
    fn warmup_members_do_not_consume_budget() {
        let utility = [0.9, 0.8, 0.7, 0.1];
        let warm = ExpertSet::from_indices(4, &[0]);
        let got = greedy_select(&utility, 2, &warm);
        assert_eq!(got.to_vec(), vec![0, 1, 2]);
    }

    #[test]
    fn tie_break_prefers_lower_index() {
        let utility = [0.5, 0.5, 0.5];
        let got = greedy_select(&utility, 2, &ExpertSet::empty(3));
        assert_eq!(got.to_vec(), vec![0, 1]);
    }

    #[test]
    fn budget_beyond_n_selects_all() {
        let got = greedy_select(&[1.0, 2.0], 10, &ExpertSet::empty(2));
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn warmup_set_unions_per_token_topk() {
        let m = ScoreMatrix::from_rows(&[
            vec![0.9, 0.05, 0.05, 0.0],
            vec![0.0, 0.1, 0.2, 0.7],
        ]);
        let s = warmup_set(&m, &[0, 1], 1);
        assert_eq!(s.to_vec(), vec![0, 3]);
        let s2 = warmup_set(&m, &[0, 1], 2);
        assert_eq!(s2.to_vec(), vec![0, 1, 2, 3]);
        assert!(warmup_set(&m, &[0, 1], 0).is_empty());
    }

    #[test]
    fn warmup_respects_row_subset() {
        let m = ScoreMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        assert_eq!(warmup_set(&m, &[1], 1).to_vec(), vec![1]);
    }

    /// Corollary 3.3 (modularity ⇒ greedy optimal): the sort-based fast path
    /// must equal the literal argmax loop on random instances.
    #[test]
    fn prop_fast_greedy_equals_naive() {
        forall(
            101,
            200,
            |r: &mut Rng| {
                let n = 1 + r.below(64);
                let utility: Vec<f32> = (0..n).map(|_| r.f32()).collect();
                let warm_n = r.below(n.min(8));
                let warm_idx: Vec<usize> = r.sample_indices(n, warm_n);
                let budget = r.below(n + 2);
                (utility, warm_idx, budget)
            },
            |(utility, warm_idx, budget)| {
                let warm = ExpertSet::from_indices(utility.len(), warm_idx);
                let fast = greedy_select(utility, *budget, &warm);
                let naive = greedy_select_naive(utility, *budget, &warm);
                if fast != naive {
                    return Err(format!(
                        "fast {:?} != naive {:?}",
                        fast.to_vec(),
                        naive.to_vec()
                    ));
                }
                Ok(())
            },
        );
    }

    /// Optimality: no other set of the same size has higher total utility.
    #[test]
    fn prop_greedy_is_optimal_for_modular_proxy() {
        forall(
            102,
            100,
            |r: &mut Rng| {
                let n = 2 + r.below(12); // small n: we brute-force subsets
                let utility: Vec<f32> = (0..n).map(|_| r.f32()).collect();
                let budget = 1 + r.below(n);
                (utility, budget)
            },
            |(utility, budget)| {
                let n = utility.len();
                let sel = greedy_select(utility, *budget, &ExpertSet::empty(n));
                let value: f32 = sel.iter().map(|j| utility[j]).sum();
                // brute force all subsets of size == sel.len()
                let size = sel.len();
                let mut best = f32::NEG_INFINITY;
                for mask in 0u32..(1 << n) {
                    if mask.count_ones() as usize != size {
                        continue;
                    }
                    let v: f32 = (0..n)
                        .filter(|j| (mask >> j) & 1 == 1)
                        .map(|j| utility[j])
                        .sum();
                    best = best.max(v);
                }
                if value < best - 1e-5 {
                    return Err(format!("greedy {value} < optimal {best}"));
                }
                Ok(())
            },
        );
    }
}
