//! The paper's contribution: batch-aware expert selection (Algorithms 1-6)
//! plus the published baselines it is evaluated against.
//!
//! Data flow per MoE layer on the decode path:
//!
//! ```text
//!   attn_router artifact ──► logits [T×N], probs [T×N], colsum [N]
//!                                  │
//!                    SelectionPolicy::route(ctx)          (this module)
//!                                  │
//!             Routing { gates [T×N], chosen, activated }
//!                                  │
//!   moe_layer artifact ◄── gates   └─► memsim (expert-IO accounting)
//! ```
//!
//! * [`greedy`] — Algorithm 1 (optimal by modularity, Corollary 3.3).
//! * [`batch_aware`] — Algorithm 2 (warm-up + greedy + refinement).
//! * [`chunk_shared`] — the modular greedy objective pooled over a prefill
//!   chunk's positions (opt-in `--chunk-shared-selection`, lossy).
//! * [`spec_aware`] — Algorithms 3-4 (hierarchical, speculation-aware).
//! * [`gpu_aware`] — Algorithms 5-6 (EP MaxLoad-balanced).
//! * [`baselines`] — vanilla top-k, LYNX-Lat, Dynamic-Skipping,
//!   Opportunistic.
//! * [`refine`] — the shared refinement tail (top-k within S).
//! * [`footprint`] — decayed expert-footprint estimates consumed by
//!   admission-time co-scheduling ([`crate::coordinator::admission`]).

pub mod baselines;
pub mod batch_aware;
pub mod chunk_shared;
pub mod expert_set;
pub mod footprint;
pub mod gpu_aware;
pub mod greedy;
pub mod policy;
pub mod refine;
pub mod scores;
pub mod spec_aware;

pub use chunk_shared::shared_chunk_set;
pub use expert_set::ExpertSet;
pub use footprint::{admission_score, Footprint};
pub use policy::{PolicyKind, SelectionContext, SelectionPolicy};
pub use refine::{refine, vanilla_topk, Routing};
pub use scores::{softmax_in_place, topk_indices, ScoreMatrix};
