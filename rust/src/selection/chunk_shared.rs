//! **Chunk-shared expert selection** (`--chunk-shared-selection`): the
//! paper's batch-level sharing applied to the prefill axis.
//!
//! Within one chunk invocation every position normally routes
//! independently (lossless — chunking is an execution optimisation, not a
//! routing change). This wrapper instead pools the chunk's per-position
//! router probabilities into one batch utility and picks ONE set per
//! layer with the same modular greedy objective Algorithm 2 applies
//! across a decode batch: a per-position top-1 warm-up (every position
//! keeps its argmax expert — the quality floor) plus `top_k` greedy
//! additions by pooled probability mass. All positions then refine within
//! the shared set ([`crate::selection::refine`]), so a whole chunk — and,
//! through the coordinator's wave union, every co-prefilling row — streams
//! one small expert set per layer instead of up to `T × top_k` distinct
//! experts.
//!
//! Lossy by design: restricted positions whose true top-k falls outside
//! the shared set route differently. The serve loop therefore ships the
//! mode with fidelity-delta accounting (`coordinator::fidelity` →
//! `shared_selection_fidelity`), never silently — see the prefill-wave
//! contract in `model/moe_model.rs`.

use super::expert_set::ExpertSet;
use super::greedy::{greedy_select, warmup_set};
use super::scores::ScoreMatrix;

/// One shared expert set for the chunk positions `rows` of one layer:
/// `greedy_select(pooled colsum, top_k, ∪ per-position top-1)`.
///
/// Size bound `|S| ≤ |rows| + top_k` (warm-up contributes at most one
/// expert per position, usually far fewer — prompt positions overlap
/// heavily on hot experts), versus up to `|rows| × top_k` for
/// per-position routing; every position's top-1 expert is always in `S`.
pub fn shared_chunk_set(probs: &ScoreMatrix, rows: &[usize], top_k: usize) -> ExpertSet {
    let warm = warmup_set(probs, rows, 1);
    let n = probs.n_experts();
    let mut utility = vec![0.0f32; n];
    for &i in rows {
        for (u, &p) in utility.iter_mut().zip(probs.row(i)) {
            *u += p;
        }
    }
    greedy_select(&utility, top_k, &warm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::refine::refine;
    use crate::selection::scores::{softmax_in_place, topk_indices};
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn random_probs(r: &mut Rng, t: usize, n: usize) -> ScoreMatrix {
        let rows: Vec<Vec<f32>> = (0..t)
            .map(|_| {
                let mut row: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 2.0)).collect();
                softmax_in_place(&mut row);
                row
            })
            .collect();
        ScoreMatrix::from_rows(&rows)
    }

    #[test]
    fn overlapping_positions_share_a_small_set() {
        // Three positions all concentrated on experts {0, 1}: the shared
        // set is far below 3 × top_k.
        let probs = ScoreMatrix::from_rows(&[
            vec![0.6, 0.3, 0.05, 0.03, 0.02],
            vec![0.5, 0.4, 0.04, 0.03, 0.03],
            vec![0.55, 0.35, 0.04, 0.03, 0.03],
        ]);
        let s = shared_chunk_set(&probs, &[0, 1, 2], 2);
        // warm-up = {0} (every top-1), greedy adds the 2 best pooled = {1, 2}
        assert!(s.contains(0) && s.contains(1));
        assert!(s.len() <= 3);
    }

    #[test]
    fn prop_top1_kept_and_size_bounded() {
        forall(
            811,
            150,
            |r: &mut Rng| {
                let t = 2 + r.below(14);
                let n = 4 + r.below(60);
                let top_k = 1 + r.below(4);
                (t, n, top_k, r.next_u64())
            },
            |&(t, n, top_k, seed)| {
                let mut r = Rng::new(seed);
                let probs = random_probs(&mut r, t, n);
                let rows: Vec<usize> = (0..t).collect();
                let s = shared_chunk_set(&probs, &rows, top_k);
                crate::prop_assert!(
                    s.len() <= t + top_k,
                    "|S|={} > T+k={}",
                    s.len(),
                    t + top_k
                );
                for &i in &rows {
                    let top1 = topk_indices(probs.row(i), 1)[0];
                    crate::prop_assert!(s.contains(top1), "position {i} lost its top-1");
                }
                // Refinement within S activates at most |S| experts and
                // still routes every position (the fidelity floor: each
                // position has ≥ its top-1 available).
                let routed = refine(&probs, &rows, &s, top_k);
                crate::prop_assert!(routed.n_activated() <= s.len(), "activated beyond S");
                for i in 0..t {
                    crate::prop_assert!(!routed.chosen[i].is_empty(), "position {i} unrouted");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shared_set_is_deterministic() {
        let mut r = Rng::new(99);
        let probs = random_probs(&mut r, 6, 32);
        let rows: Vec<usize> = (0..6).collect();
        let a = shared_chunk_set(&probs, &rows, 2);
        let b = shared_chunk_set(&probs, &rows, 2);
        assert_eq!(a.to_vec(), b.to_vec());
    }
}
