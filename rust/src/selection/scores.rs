//! Gate-score matrices — the single input every XShare algorithm consumes.
//!
//! `ScoreMatrix` is a dense row-major `[T × N]` f32 matrix of router scores
//! (full-N softmax probabilities from the `attn_router` artifact, or
//! synthetic scores from [`crate::gen`]). Rows are tokens, columns experts.

/// Dense `[n_tokens × n_experts]` row-major score matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreMatrix {
    n_tokens: usize,
    n_experts: usize,
    data: Vec<f32>,
}

impl ScoreMatrix {
    pub fn zeros(n_tokens: usize, n_experts: usize) -> Self {
        ScoreMatrix { n_tokens, n_experts, data: vec![0.0; n_tokens * n_experts] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty(), "empty score matrix");
        let n_experts = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * n_experts);
        for r in rows {
            assert_eq!(r.len(), n_experts, "ragged score rows");
            data.extend_from_slice(r);
        }
        ScoreMatrix { n_tokens: rows.len(), n_experts, data }
    }

    /// Wrap an existing flat row-major buffer.
    pub fn from_flat(n_tokens: usize, n_experts: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n_tokens * n_experts);
        ScoreMatrix { n_tokens, n_experts, data }
    }

    #[inline]
    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    #[inline]
    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n_experts..(i + 1) * self.n_experts]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.n_experts..(i + 1) * self.n_experts]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.n_experts + j]
    }

    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Batch utility u_j = Σ_i scores[i, j] over `rows` (Proposition 3.2's
    /// marginal gains). `None` = all rows.
    pub fn col_sums(&self, rows: Option<&[usize]>) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_experts];
        match rows {
            None => {
                for i in 0..self.n_tokens {
                    let r = self.row(i);
                    for (o, v) in out.iter_mut().zip(r) {
                        *o += v;
                    }
                }
            }
            Some(idx) => {
                for &i in idx {
                    let r = self.row(i);
                    for (o, v) in out.iter_mut().zip(r) {
                        *o += v;
                    }
                }
            }
        }
        out
    }

    /// Column sums over a contiguous token range (per-request aggregation
    /// for Algorithm 3). Accumulates into `out` (callers reuse buffers).
    pub fn col_sums_range_into(&self, lo: usize, hi: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.n_experts);
        out.fill(0.0);
        for i in lo..hi {
            let r = self.row(i);
            for (o, v) in out.iter_mut().zip(r) {
                *o += v;
            }
        }
    }

    /// Row-wise softmax of a logits matrix (numerically stable).
    pub fn softmax(logits: &ScoreMatrix) -> ScoreMatrix {
        let mut out = logits.clone();
        for i in 0..out.n_tokens {
            softmax_in_place(out.row_mut(i));
        }
        out
    }
}

/// Stable in-place softmax over one row.
pub fn softmax_in_place(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

#[inline]
fn desc_by_score(row: &[f32]) -> impl Fn(&usize, &usize) -> std::cmp::Ordering + '_ {
    move |&a, &b| {
        row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    }
}

/// Indices of the top-`k` entries of `row`, highest first; ties broken by
/// lower index (matches `ref.topk_mask_ref` on the python side).
///
/// Perf (EXPERIMENTS.md §Perf, L3 iteration 1): this runs per token per
/// layer on the decode hot path. A full sort of all N indices cost
/// O(N log N); `select_nth_unstable` partitions in O(N) and only the k
/// survivors are sorted. The comparator is a total order (score desc,
/// index asc), so the selected set — and therefore every algorithm built
/// on it — is unchanged (property-tested against the sort-based oracle).
pub fn topk_indices(row: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(row.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..row.len()).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, desc_by_score(row));
        idx.truncate(k);
    }
    idx.sort_by(desc_by_score(row));
    idx
}

/// Top-`k` restricted to experts where `allowed(j)` holds. Returns fewer
/// than `k` if the allowed set is smaller.
pub fn topk_indices_where(
    row: &[f32],
    k: usize,
    mut allowed: impl FnMut(usize) -> bool,
) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).filter(|&j| allowed(j)).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, desc_by_score(row));
        idx.truncate(k);
    }
    idx.sort_by(desc_by_score(row));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_access_and_col_sums() {
        let m = ScoreMatrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.col_sums(None), vec![5.0, 7.0, 9.0]);
        assert_eq!(m.col_sums(Some(&[0])), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn col_sums_range() {
        let m = ScoreMatrix::from_rows(&[
            vec![1.0, 0.0],
            vec![0.0, 2.0],
            vec![3.0, 3.0],
        ]);
        let mut out = vec![0.0; 2];
        m.col_sums_range_into(1, 3, &mut out);
        assert_eq!(out, vec![3.0, 5.0]);
    }

    #[test]
    fn softmax_rows_normalized() {
        let logits = ScoreMatrix::from_rows(&[vec![0.0, 1.0, 2.0], vec![-5.0, 5.0, 0.0]]);
        let p = ScoreMatrix::softmax(&logits);
        for i in 0..2 {
            let s: f32 = p.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            // order preserved
            let t = topk_indices(p.row(i), 1);
            assert_eq!(t[0], topk_indices(logits.row(i), 1)[0]);
        }
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut row = vec![1e30f32, -1e30, 0.0];
        softmax_in_place(&mut row);
        assert!(row.iter().all(|v| v.is_finite()));
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn topk_order_and_ties() {
        let row = [0.1f32, 0.5, 0.5, 0.4];
        assert_eq!(topk_indices(&row, 3), vec![1, 2, 3]); // tie 1 before 2
        assert_eq!(topk_indices(&row, 0), Vec::<usize>::new());
        assert_eq!(topk_indices(&row, 99).len(), 4);
    }

    #[test]
    fn prop_partial_select_equals_full_sort() {
        use crate::util::check::forall;
        use crate::util::rng::Rng;
        forall(
            601,
            300,
            |r: &mut Rng| {
                let n = 1 + r.below(300);
                let k = r.below(n + 3);
                // coarse values force ties
                let row: Vec<f32> =
                    (0..n).map(|_| (r.below(16) as f32) / 8.0).collect();
                (row, k)
            },
            |(row, k)| {
                let fast = topk_indices(row, *k);
                let mut idx: Vec<usize> = (0..row.len()).collect();
                idx.sort_by(|&a, &b| {
                    row[b]
                        .partial_cmp(&row[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                idx.truncate((*k).min(row.len()));
                if fast != idx {
                    return Err(format!("fast {fast:?} != oracle {idx:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn topk_where_respects_filter() {
        let row = [0.9f32, 0.8, 0.7, 0.6];
        let got = topk_indices_where(&row, 2, |j| j % 2 == 1);
        assert_eq!(got, vec![1, 3]);
        let small = topk_indices_where(&row, 4, |j| j == 2);
        assert_eq!(small, vec![2]);
    }
}
