//! Expert-footprint estimation for admission-time co-scheduling.
//!
//! A [`Footprint`] is a decayed running average of the full-N router
//! probability rows a request (or a group of requests) has been observed to
//! produce — the same `[T × N]` score matrices every selection algorithm
//! consumes, aggregated over time instead of over a batch. The admission
//! subsystem ([`crate::coordinator::admission`]) maintains one footprint per
//! running batch row (updated from prompt-time scores captured during
//! chunked prefill and from a decayed EMA during decode) and one per traffic
//! class (domain), and scores queued candidates by the expected overlap of
//! their predicted expert set with the experts the running batch already
//! activates — the paper's modular greedy objective (Proposition 3.2)
//! applied at admission time instead of selection time.

use super::expert_set::ExpertSet;
use super::scores::topk_indices;
use crate::ep::Placement;

/// Decayed mean of observed router probability rows for one request or
/// traffic class.
#[derive(Debug, Clone)]
pub struct Footprint {
    weights: Vec<f32>,
    /// Number of `observe` calls folded in (0 = uninformative prior).
    n_obs: u64,
}

impl Footprint {
    /// Uninformative footprint: no observations, zero weights.
    pub fn empty(n_experts: usize) -> Footprint {
        Footprint { weights: vec![0.0; n_experts], n_obs: 0 }
    }

    pub fn n_experts(&self) -> usize {
        self.weights.len()
    }

    /// Whether any router scores have been folded in. Policies treat an
    /// unobserved footprint as "no prediction" and fall back to FIFO order.
    pub fn is_informative(&self) -> bool {
        self.n_obs > 0
    }

    pub fn observations(&self) -> u64 {
        self.n_obs
    }

    /// Fold one observed probability row in: `w ← decay·w + (1−decay)·p`.
    /// The first observation seeds the weights directly so a cold footprint
    /// does not spend its early life biased toward zero.
    ///
    /// `decay` is valid on the whole closed interval `[0, 1]`: `0.0` keeps
    /// no memory (weights = the latest row), `1.0` freezes the weights at
    /// the seed. The old guard rejected exactly one of those endpoints
    /// (`1.0`) while silently accepting the other; range policy now lives
    /// at config parse time (`ServeConfig::validate` on
    /// `footprint_decay`), and this method only debug-checks the closed
    /// interval. A length-mismatched probability row is a caller bug and
    /// panics instead of being silently truncated by `zip`.
    pub fn observe(&mut self, probs_row: &[f32], decay: f32) {
        assert_eq!(
            probs_row.len(),
            self.weights.len(),
            "observed row covers {} experts but the footprint tracks {}",
            probs_row.len(),
            self.weights.len()
        );
        debug_assert!((0.0..=1.0).contains(&decay), "decay {decay} outside [0, 1]");
        if self.n_obs == 0 {
            self.weights.copy_from_slice(probs_row);
        } else {
            for (w, &p) in self.weights.iter_mut().zip(probs_row) {
                *w = decay * *w + (1.0 - decay) * p;
            }
        }
        self.n_obs += 1;
    }

    /// The predicted expert set: the `k` heaviest experts of the footprint.
    pub fn top_set(&self, k: usize) -> ExpertSet {
        ExpertSet::from_indices(self.weights.len(), &topk_indices(&self.weights, k))
    }

    /// Raw affinity weights (diagnostics).
    pub fn weights(&self) -> &[f32] {
        &self.weights
    }
}

/// Admission score of a candidate whose predicted expert set is `cand`
/// against the experts the running batch is predicted to activate
/// (`running_union`): the expected overlap, minus — under expert
/// parallelism — the marginal MaxLoad the candidate's non-overlapping
/// experts would add to the straggler GPU (§5.1's synchronization cost,
/// applied at admission time).
///
/// Higher is better. A candidate that only re-uses already-active experts
/// scores `|cand|`; one that drags in a full set of new experts on the
/// hottest GPU scores lowest.
pub fn admission_score(
    cand: &ExpertSet,
    running_union: &ExpertSet,
    placement: Option<&Placement>,
) -> f64 {
    let overlap = cand.intersection_len(running_union) as f64;
    match placement {
        None => overlap,
        Some(pl) => {
            let before = pl.max_load(running_union) as f64;
            let mut merged = running_union.clone();
            merged.union_with(cand);
            let after = pl.max_load(&merged) as f64;
            overlap - (after - before)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ep::PlacementKind;

    #[test]
    fn empty_is_uninformative() {
        let fp = Footprint::empty(8);
        assert!(!fp.is_informative());
        assert_eq!(fp.top_set(2).len(), 2, "top_set still yields k indices");
    }

    #[test]
    fn first_observation_seeds_weights() {
        let mut fp = Footprint::empty(4);
        fp.observe(&[0.1, 0.5, 0.3, 0.1], 0.9);
        assert!(fp.is_informative());
        assert_eq!(fp.weights(), &[0.1, 0.5, 0.3, 0.1]);
        assert_eq!(fp.top_set(1).to_vec(), vec![1]);
    }

    #[test]
    fn decay_endpoints_are_symmetric() {
        // decay = 1.0 freezes at the seed; decay = 0.0 keeps no memory.
        // Both are legal (the old debug guard rejected only the freeze).
        let mut frozen = Footprint::empty(2);
        frozen.observe(&[0.9, 0.1], 1.0);
        frozen.observe(&[0.0, 1.0], 1.0);
        assert_eq!(frozen.weights(), &[0.9, 0.1]);
        let mut memoryless = Footprint::empty(2);
        memoryless.observe(&[0.9, 0.1], 0.0);
        memoryless.observe(&[0.0, 1.0], 0.0);
        assert_eq!(memoryless.weights(), &[0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "observed row covers")]
    fn mismatched_row_length_panics_instead_of_truncating() {
        let mut fp = Footprint::empty(4);
        fp.observe(&[0.5, 0.5], 0.9);
    }

    #[test]
    fn ema_tracks_recent_observations() {
        let mut fp = Footprint::empty(3);
        fp.observe(&[1.0, 0.0, 0.0], 0.5);
        for _ in 0..10 {
            fp.observe(&[0.0, 1.0, 0.0], 0.5);
        }
        // expert 1 dominates after the distribution shifts
        assert_eq!(fp.top_set(1).to_vec(), vec![1]);
        assert!(fp.weights()[1] > 0.9);
        assert_eq!(fp.observations(), 11);
    }

    #[test]
    fn score_counts_overlap() {
        let running = ExpertSet::from_indices(16, &[0, 1, 2, 3]);
        let hot = ExpertSet::from_indices(16, &[0, 1, 2, 3]);
        let cold = ExpertSet::from_indices(16, &[8, 9, 10, 11]);
        let half = ExpertSet::from_indices(16, &[2, 3, 8, 9]);
        assert_eq!(admission_score(&hot, &running, None), 4.0);
        assert_eq!(admission_score(&cold, &running, None), 0.0);
        assert_eq!(admission_score(&half, &running, None), 2.0);
    }

    #[test]
    fn ep_weighting_penalizes_straggler_growth() {
        // 8 experts on 2 GPUs, contiguous: GPU0 = {0..3}, GPU1 = {4..7}.
        // The batch already loads GPU0 with 3 experts.
        let pl = Placement::new(8, 2, PlacementKind::Contiguous);
        let running = ExpertSet::from_indices(8, &[0, 1, 2]);
        // Equal overlap (one shared expert), but `piles_on` adds 1 expert
        // to the already-hot GPU0 while `spreads` adds 1 to idle GPU1.
        let piles_on = ExpertSet::from_indices(8, &[0, 3]);
        let spreads = ExpertSet::from_indices(8, &[0, 4]);
        let s_pile = admission_score(&piles_on, &running, Some(&pl));
        let s_spread = admission_score(&spreads, &running, Some(&pl));
        assert!(s_spread > s_pile, "spread {s_spread} <= pile {s_pile}");
        // Without the placement both candidates look identical.
        assert_eq!(
            admission_score(&piles_on, &running, None),
            admission_score(&spreads, &running, None)
        );
    }

    #[test]
    fn replica_aware_scoring_forgives_experts_with_an_idle_replica() {
        // PR 6: `max_load` resolves replicas, so the leave-one-out unions
        // the admission/eviction planners score are replica-aware for
        // free. Expert 3 lives on the hot GPU0 in the partition, but with
        // a replica on idle GPU1 the candidate that drags it in no longer
        // grows the straggler — the penalty disappears.
        let partition = Placement::new(8, 2, PlacementKind::Contiguous);
        let replicated = Placement::from_replicas(
            2,
            vec![
                vec![0],
                vec![0],
                vec![0],
                vec![0, 1], // expert 3: replica on the idle GPU
                vec![1],
                vec![1],
                vec![1],
                vec![1],
            ],
        );
        let running = ExpertSet::from_indices(8, &[0, 1, 2]);
        let cand = ExpertSet::from_indices(8, &[3]);
        let s_part = admission_score(&cand, &running, Some(&partition));
        let s_repl = admission_score(&cand, &running, Some(&replicated));
        assert_eq!(s_part, -1.0, "partition: +1 expert on the straggler GPU");
        assert_eq!(s_repl, 0.0, "replica routes to the idle GPU, no penalty");
        assert!(s_repl > s_part);
    }
}
