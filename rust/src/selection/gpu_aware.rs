//! **Algorithms 5 & 6 — Expert-Parallelism-Aware Selection.**
//!
//! Under EP, per-layer latency is set by the GPU with the most activated
//! experts (all groups synchronize after the layer). Standard greedy can
//! pile high-utility experts onto one GPU; the GPU-aware variant selects
//! round-robin **per GPU group**: each round adds the best remaining expert
//! of every GPU, so after any number of rounds no GPU holds more than one
//! expert above any other (of those added by the algorithm), giving
//! MaxLoad(S_added) ≤ ⌈|S_added|/G⌉ and overall
//! MaxLoad(S) ≤ max_g |warm_g| + m_g.
//!
//! Algorithm 6 = warm-up (top-k0 per token) + Algorithm 5 + shared
//! refinement. The paper's Table 2 configuration is (k0=1, m_g=5).
//!
//! **Replica sets (PR 6):** the per-GPU rounds iterate replica groups —
//! [`Placement::experts_on`] lists every expert RESIDENT on the GPU, so a
//! replicated expert appears in several groups. The shared `selected` set
//! dedups it (whichever group reaches it first claims it; later groups'
//! cursors skip it), and the per-round MaxLoad bound still holds because
//! replica-aware routing can only place a selected expert on a less-loaded
//! host than the partition would have.

use super::expert_set::ExpertSet;
use super::greedy::warmup_set;
use super::policy::{SelectionContext, SelectionPolicy};
use crate::ep::Placement;

#[derive(Debug, Clone, Copy)]
pub struct GpuAware {
    /// k_0: per-token warm-up depth.
    pub k0: usize,
    /// m_g: experts Algorithm 5 may add per GPU group.
    pub per_gpu_budget: usize,
}

/// Algorithm 5: GPU-balanced greedy. Adds up to `per_gpu_budget` experts on
/// every GPU group, each round taking the highest-utility unselected expert
/// of each group in turn.
pub fn gpu_aware_greedy(
    utility: &[f32],
    placement: &Placement,
    per_gpu_budget: usize,
    warm: &ExpertSet,
) -> ExpertSet {
    let mut selected = warm.clone();
    // Per-GPU candidate lists sorted descending by utility; a cursor per GPU
    // skips already-selected entries lazily.
    let candidates: Vec<Vec<usize>> = (0..placement.n_gpus())
        .map(|g| {
            let mut v: Vec<usize> = placement.experts_on(g).to_vec();
            v.sort_by(|&a, &b| {
                utility[b]
                    .partial_cmp(&utility[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            v
        })
        .collect();
    let mut cursors = vec![0usize; placement.n_gpus()];

    for _round in 0..per_gpu_budget {
        for g in 0..placement.n_gpus() {
            let list = &candidates[g];
            let cur = &mut cursors[g];
            while *cur < list.len() && selected.contains(list[*cur]) {
                *cur += 1;
            }
            if *cur < list.len() {
                selected.insert(list[*cur]);
                *cur += 1;
            }
        }
    }
    selected
}

impl SelectionPolicy for GpuAware {
    fn name(&self) -> String {
        format!("gpu_aware(k0={},mg={})", self.k0, self.per_gpu_budget)
    }

    fn select(&self, ctx: &SelectionContext) -> ExpertSet {
        let placement = ctx
            .placement
            .expect("GpuAware policy needs a Placement in the SelectionContext");
        let warm = warmup_set(ctx.probs, ctx.rows, self.k0);
        if self.per_gpu_budget == 0 {
            return warm;
        }
        let utility = ctx.batch_utility();
        gpu_aware_greedy(&utility, placement, self.per_gpu_budget, &warm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ep::PlacementKind;
    use crate::selection::scores::{softmax_in_place, ScoreMatrix};
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn each_round_adds_one_per_gpu() {
        // utilities: GPU0 hosts 0..4 (high), GPU1 hosts 4..8 (low)
        let utility = [9.0, 8.0, 7.0, 6.0, 0.4, 0.3, 0.2, 0.1];
        let p = Placement::new(8, 2, PlacementKind::Contiguous);
        let s = gpu_aware_greedy(&utility, &p, 2, &ExpertSet::empty(8));
        // plain greedy would take {0,1,2,3}; gpu-aware takes top-2 per GPU
        assert_eq!(s.to_vec(), vec![0, 1, 4, 5]);
        assert_eq!(p.max_load(&s), 2);
    }

    #[test]
    fn replicated_expert_claimed_once_across_groups() {
        // Expert 1 is replicated on both GPUs, so it shows up in both
        // candidate lists. GPU0's round claims it (highest utility there);
        // GPU1's cursor must skip the duplicate and take its best
        // unclaimed expert instead of re-adding or double-counting it.
        let p = Placement::from_replicas(2, vec![vec![0], vec![0, 1], vec![1], vec![1]]);
        let utility = [0.5, 9.0, 1.0, 0.8];
        let s = gpu_aware_greedy(&utility, &p, 1, &ExpertSet::empty(4));
        assert_eq!(s.to_vec(), vec![1, 2]);
        // routing resolves each selection to one replica: never more than
        // one expert per GPU here
        assert_eq!(p.max_load(&s), 1);
        // a second round picks up the leftovers, still deduplicated
        let s2 = gpu_aware_greedy(&utility, &p, 2, &ExpertSet::empty(4));
        assert_eq!(s2.to_vec(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn warm_members_skipped_not_recounted() {
        let utility = [9.0, 8.0, 1.0, 0.5];
        let p = Placement::new(4, 2, PlacementKind::Contiguous);
        let warm = ExpertSet::from_indices(4, &[0]);
        let s = gpu_aware_greedy(&utility, &p, 1, &warm);
        // GPU0 adds its best non-warm (1); GPU1 adds 2.
        assert_eq!(s.to_vec(), vec![0, 1, 2]);
    }

    #[test]
    fn budget_larger_than_group_takes_whole_group() {
        let utility = [1.0, 2.0, 3.0, 4.0];
        let p = Placement::new(4, 2, PlacementKind::Contiguous);
        let s = gpu_aware_greedy(&utility, &p, 10, &ExpertSet::empty(4));
        assert_eq!(s.len(), 4);
    }

    fn random_ctx_parts(r: &mut Rng, t: usize, n: usize) -> ScoreMatrix {
        let rows: Vec<Vec<f32>> = (0..t)
            .map(|_| {
                let mut row: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 2.0)).collect();
                softmax_in_place(&mut row);
                row
            })
            .collect();
        ScoreMatrix::from_rows(&rows)
    }

    #[test]
    fn prop_max_load_bound() {
        // The paper's §5 design property: the greedy-added portion is
        // balanced, so MaxLoad(S) ≤ max_g Load_g(warm) + m_g.
        forall(
            401,
            150,
            |r: &mut Rng| {
                let g = 1 + r.below(8);
                let n = (g * (1 + r.below(8))).max(g);
                let t = 1 + r.below(10);
                let k0 = r.below(3);
                let mg = r.below(5);
                (g, n, t, k0, mg, r.next_u64())
            },
            |&(g, n, t, k0, mg, seed)| {
                let mut r = Rng::new(seed);
                let probs = random_ctx_parts(&mut r, t, n);
                let rows: Vec<usize> = (0..t).collect();
                let placement = Placement::new(n, g, PlacementKind::RoundRobin);
                let warm = warmup_set(&probs, &rows, k0);
                let utility = probs.col_sums(Some(&rows));
                let s = gpu_aware_greedy(&utility, &placement, mg, &warm);
                let warm_max = placement.max_load(&warm);
                let bound = warm_max + mg;
                crate::prop_assert!(
                    placement.max_load(&s) <= bound,
                    "MaxLoad {} > bound {bound}",
                    placement.max_load(&s)
                );
                // warm-up containment
                for j in warm.iter() {
                    crate::prop_assert!(s.contains(j), "warm expert dropped");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_balances_vs_plain_greedy() {
        // On skewed utilities, GPU-aware MaxLoad ≤ plain greedy MaxLoad for
        // the same number of selected experts.
        forall(
            402,
            100,
            |r: &mut Rng| {
                let g = 2 + r.below(6);
                let per = 2 + r.below(6);
                let n = g * per;
                let hot = r.below(g);
                (g, n, hot, r.next_u64())
            },
            |&(g, n, hot, seed)| {
                let mut r = Rng::new(seed);
                let placement = Placement::new(n, g, PlacementKind::Contiguous);
                // utilities skewed toward GPU `hot`
                let utility: Vec<f32> = (0..n)
                    .map(|j| {
                        let base = r.f32() * 0.1;
                        if placement.gpu_of(j) == hot {
                            base + 1.0
                        } else {
                            base
                        }
                    })
                    .collect();
                let mg = 1 + r.below(3);
                let s_gpu =
                    gpu_aware_greedy(&utility, &placement, mg, &ExpertSet::empty(n));
                let s_plain = crate::selection::greedy::greedy_select(
                    &utility,
                    s_gpu.len(),
                    &ExpertSet::empty(n),
                );
                crate::prop_assert!(
                    placement.max_load(&s_gpu) <= placement.max_load(&s_plain),
                    "gpu-aware {} > plain {}",
                    placement.max_load(&s_gpu),
                    placement.max_load(&s_plain)
                );
                Ok(())
            },
        );
    }
}
