//! **Algorithm 2 — Batch-Aware Expert Selection (per layer).**
//!
//! Warm-up: include every token's top-k0 experts (a per-token quality
//! floor). Greedy: add the `budget` highest batch-utility experts
//! (Algorithm 1, optimal by modularity). Refinement — routing each token to
//! its top-k within S — is the shared default `route` of the policy trait.
//!
//! The paper's Figure 4 / Table 3 configurations are exactly
//! `BatchAware { budget: m_l, k0 }`.

use super::expert_set::ExpertSet;
use super::greedy::{greedy_select, warmup_set};
use super::policy::{SelectionContext, SelectionPolicy};

#[derive(Debug, Clone, Copy)]
pub struct BatchAware {
    /// m_l: experts greedy adds on top of the warm-up set.
    pub budget: usize,
    /// k_0: per-token warm-up depth.
    pub k0: usize,
}

impl SelectionPolicy for BatchAware {
    fn name(&self) -> String {
        format!("batch_aware(m={},k0={})", self.budget, self.k0)
    }

    fn select(&self, ctx: &SelectionContext) -> ExpertSet {
        let warm = warmup_set(ctx.probs, ctx.rows, self.k0);
        if self.budget == 0 {
            return warm;
        }
        let utility = ctx.batch_utility();
        greedy_select(&utility, self.budget, &warm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::scores::{topk_indices, ScoreMatrix};
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn ctx<'a>(
        probs: &'a ScoreMatrix,
        logits: &'a ScoreMatrix,
        rows: &'a [usize],
    ) -> SelectionContext<'a> {
        SelectionContext {
            probs,
            logits,
            rows,
            requests: &[],
            colsum_hint: None,
            placement: None,
            top_k: 2,
        }
    }

    fn random_probs(r: &mut Rng, t: usize, n: usize) -> ScoreMatrix {
        let rows: Vec<Vec<f32>> = (0..t)
            .map(|_| {
                let mut row: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 2.0)).collect();
                crate::selection::scores::softmax_in_place(&mut row);
                row
            })
            .collect();
        ScoreMatrix::from_rows(&rows)
    }

    #[test]
    fn warmup_only_config_matches_paper_zero_one() {
        // (m_l=0, k0=1): S is exactly the union of per-token top-1.
        let probs = ScoreMatrix::from_rows(&[
            vec![0.7, 0.1, 0.1, 0.1],
            vec![0.1, 0.1, 0.7, 0.1],
        ]);
        let rows = [0, 1];
        let p = BatchAware { budget: 0, k0: 1 };
        let s = p.select(&ctx(&probs, &probs, &rows));
        assert_eq!(s.to_vec(), vec![0, 2]);
    }

    #[test]
    fn pure_greedy_config_takes_top_colsum() {
        // (m_l=2, k0=0): top-2 columns by batch utility.
        let probs = ScoreMatrix::from_rows(&[
            vec![0.4, 0.3, 0.2, 0.1],
            vec![0.1, 0.4, 0.3, 0.2],
        ]);
        let p = BatchAware { budget: 2, k0: 0 };
        let s = p.select(&ctx(&probs, &probs, &[0, 1]));
        assert_eq!(s.to_vec(), vec![0, 1]); // colsums 0.5, 0.7, 0.5, 0.3 → ties → 0,1
    }

    #[test]
    fn colsum_hint_is_used_verbatim() {
        let probs = ScoreMatrix::from_rows(&[vec![0.9, 0.05, 0.05]]);
        let hint = [0.0f32, 10.0, 0.0];
        let c = SelectionContext {
            probs: &probs,
            logits: &probs,
            rows: &[0],
            requests: &[],
            colsum_hint: Some(&hint),
            placement: None,
            top_k: 1,
        };
        let p = BatchAware { budget: 1, k0: 0 };
        assert_eq!(p.select(&c).to_vec(), vec![1]);
    }

    #[test]
    fn prop_selected_size_bound_and_warmup_included() {
        forall(
            201,
            150,
            |r: &mut Rng| {
                let t = 1 + r.below(16);
                let n = 4 + r.below(60);
                let k0 = r.below(3);
                let budget = r.below(n);
                (t, n, k0, budget, r.next_u64())
            },
            |&(t, n, k0, budget, seed)| {
                let mut r = Rng::new(seed);
                let probs = random_probs(&mut r, t, n);
                let rows: Vec<usize> = (0..t).collect();
                let p = BatchAware { budget, k0 };
                let s = p.select(&ctx(&probs, &probs, &rows));
                let warm = warmup_set(&probs, &rows, k0);
                crate::prop_assert!(
                    s.len() <= warm.len() + budget,
                    "|S|={} > |S0|+m={}",
                    s.len(),
                    warm.len() + budget
                );
                for j in warm.iter() {
                    crate::prop_assert!(s.contains(j), "warm expert {j} dropped");
                }
                // every token's top-1 within S is its warm-up expert when k0>=1
                if k0 >= 1 {
                    for i in 0..t {
                        let top1 = topk_indices(probs.row(i), 1)[0];
                        crate::prop_assert!(s.contains(top1), "token {i} top-1 missing");
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_activation_never_exceeds_vanilla_when_budget_small() {
        // The headline effect: with budget below the vanilla union size, the
        // batch-aware policy activates fewer (or equal) experts.
        forall(
            202,
            80,
            |r: &mut Rng| (2 + r.below(12), 8 + r.below(56), r.next_u64()),
            |&(t, n, seed)| {
                let mut r = Rng::new(seed);
                let probs = random_probs(&mut r, t, n);
                let rows: Vec<usize> = (0..t).collect();
                let c = ctx(&probs, &probs, &rows);
                let vanilla = crate::selection::refine::vanilla_topk(&probs, &rows, 2);
                let p = BatchAware { budget: 2, k0: 1 };
                let routed = p.route(&c);
                crate::prop_assert!(
                    routed.n_activated() <= vanilla.n_activated(),
                    "batch-aware activated {} > vanilla {}",
                    routed.n_activated(),
                    vanilla.n_activated()
                );
                Ok(())
            },
        );
    }
}
