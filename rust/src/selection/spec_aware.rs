//! **Algorithms 3 & 4 — Speculative-Decoding-Aware Expert Selection.**
//!
//! Speculative tokens of one request are consecutive steps of the same
//! generation, so their expert preferences correlate strongly (the paper's
//! Assumption 4.1 / Figure 3: 2-3× the overlap of independent tokens).
//! The hierarchical proxy exploits that structure:
//!
//!   Algorithm 3 (per request r): warm-up top-k0 per token, then add the
//!   top-m_r experts by the *request's* aggregated scores Σ_{x∈T_r} g_{x,j}.
//!
//!   Algorithm 4 (batch): union the per-request selections, then optionally
//!   top-up with batch-level greedy (budget m), then refine per token.
//!
//! The paper's Pareto-optimal configurations (k0=1, m=0, m_r∈{4,5}) skip the
//! batch top-up entirely — the per-request stage already captures the
//! gating mass.
//!
//! **Ragged depth & acceptance priors (PR 4).** Under per-row speculative
//! depth the coordinator assembles each request's group from only its own
//! `1 + depth_r` verify positions, and — with `--spec-adaptive` — scales
//! position `j`'s probability row by the request's acceptance prior
//! `a_r^j` (`coordinator::speculative::effective_batch_scores_ragged`).
//! The hierarchy below needs no changes to exploit that: the per-request
//! aggregation Σ_{x∈T_r} g_{x,j} then weights every request's positions by
//! how likely they are to commit, so a low-acceptance request's deep
//! speculative tokens stop pulling experts into S_l (verified by
//! `acceptance_prior_weighting_shifts_request_budget` below). Warm-up
//! (top-k0 per position) is scale-invariant per row, so committed tokens
//! keep their guaranteed experts regardless of prior.

use super::expert_set::ExpertSet;
use super::greedy::greedy_select;
use super::policy::{SelectionContext, SelectionPolicy};
use super::scores::{topk_indices, ScoreMatrix};

#[derive(Debug, Clone, Copy)]
pub struct SpecAware {
    /// k_0: per-token warm-up depth.
    pub k0: usize,
    /// m: batch-level greedy top-up budget (0 = per-request union only).
    pub batch_budget: usize,
    /// m_r: per-request budget on top of the warm-up.
    pub req_budget: usize,
}

/// Algorithm 3: expert selection for one request's token group.
pub fn per_request_select(
    probs: &ScoreMatrix,
    token_rows: &[usize],
    req_budget: usize,
    k0: usize,
    scratch: &mut Vec<f32>,
) -> ExpertSet {
    let n = probs.n_experts();
    // Warm-up: top-k0 per token.
    let mut s = ExpertSet::empty(n);
    for &i in token_rows {
        for j in topk_indices(probs.row(i), k0) {
            s.insert(j);
        }
    }
    if req_budget == 0 {
        return s;
    }
    // Aggregate scores across the request (the per-request proxy f_l(S;r)).
    scratch.clear();
    scratch.resize(n, 0.0);
    for &i in token_rows {
        for (acc, v) in scratch.iter_mut().zip(probs.row(i)) {
            *acc += v;
        }
    }
    greedy_select(scratch, req_budget, &s)
}

impl SelectionPolicy for SpecAware {
    fn name(&self) -> String {
        format!(
            "spec_aware(k0={},m={},mr={})",
            self.k0, self.batch_budget, self.req_budget
        )
    }

    fn select(&self, ctx: &SelectionContext) -> ExpertSet {
        let n = ctx.probs.n_experts();
        let mut s_batch = ExpertSet::empty(n);
        let mut scratch = Vec::with_capacity(n);

        if ctx.requests.is_empty() {
            // No request structure supplied (e.g. non-speculative batch):
            // degrade gracefully to treating every token as its own request.
            for &i in ctx.rows {
                let sr = per_request_select(
                    ctx.probs,
                    std::slice::from_ref(&i),
                    self.req_budget,
                    self.k0,
                    &mut scratch,
                );
                s_batch.union_with(&sr);
            }
        } else {
            for group in ctx.requests {
                let sr = per_request_select(
                    ctx.probs,
                    group,
                    self.req_budget,
                    self.k0,
                    &mut scratch,
                );
                s_batch.union_with(&sr);
            }
        }

        if self.batch_budget > 0 {
            let utility = ctx.batch_utility();
            s_batch = greedy_select(&utility, self.batch_budget, &s_batch);
        }
        s_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::scores::softmax_in_place;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn ctx<'a>(
        probs: &'a ScoreMatrix,
        rows: &'a [usize],
        requests: &'a [Vec<usize>],
    ) -> SelectionContext<'a> {
        SelectionContext {
            probs,
            logits: probs,
            rows,
            requests,
            colsum_hint: None,
            placement: None,
            top_k: 2,
        }
    }

    /// Correlated request scores: tokens of one request share a dominant
    /// expert; tokens of different requests don't.
    fn correlated_batch() -> (ScoreMatrix, Vec<Vec<usize>>) {
        let mk = |hot: usize| {
            let mut row = vec![0.01f32; 16];
            row[hot] = 5.0;
            row[(hot + 1) % 16] = 3.0;
            softmax_in_place(&mut row);
            row
        };
        // request 0 → experts {0,1}, request 1 → experts {8,9}
        let rows = vec![mk(0), mk(0), mk(0), mk(8), mk(8), mk(8)];
        (ScoreMatrix::from_rows(&rows), vec![vec![0, 1, 2], vec![3, 4, 5]])
    }

    #[test]
    fn per_request_select_warmup_and_budget() {
        let (probs, reqs) = correlated_batch();
        let mut scratch = Vec::new();
        let s = per_request_select(&probs, &reqs[0], 1, 1, &mut scratch);
        // warm-up top-1 = {0}; budget 1 adds the aggregated runner-up {1}
        assert_eq!(s.to_vec(), vec![0, 1]);
    }

    #[test]
    fn spec_aware_unions_per_request_sets() {
        let (probs, reqs) = correlated_batch();
        let rows: Vec<usize> = (0..6).collect();
        let p = SpecAware { k0: 1, batch_budget: 0, req_budget: 1 };
        let s = p.select(&ctx(&probs, &rows, &reqs));
        assert_eq!(s.to_vec(), vec![0, 1, 8, 9]);
    }

    #[test]
    fn batch_topup_adds_global_experts() {
        let (probs, reqs) = correlated_batch();
        let rows: Vec<usize> = (0..6).collect();
        let none = SpecAware { k0: 1, batch_budget: 0, req_budget: 0 };
        let some = SpecAware { k0: 1, batch_budget: 3, req_budget: 0 };
        let s0 = none.select(&ctx(&probs, &rows, &reqs));
        let s1 = some.select(&ctx(&probs, &rows, &reqs));
        assert_eq!(s1.len(), s0.len() + 3);
        for j in s0.iter() {
            assert!(s1.contains(j));
        }
    }

    #[test]
    fn degrades_to_per_token_without_request_structure() {
        let (probs, _) = correlated_batch();
        let rows: Vec<usize> = (0..6).collect();
        let p = SpecAware { k0: 1, batch_budget: 0, req_budget: 0 };
        let s = p.select(&ctx(&probs, &rows, &[]));
        assert_eq!(s.to_vec(), vec![0, 8]); // top-1 of each token
    }

    #[test]
    fn hierarchical_beats_flat_on_correlated_batches() {
        // The reason Algorithm 4 exists: with per-request correlation, a
        // per-request budget captures more gating mass per activated expert
        // than the same total budget spent batch-wide.
        let (probs, reqs) = correlated_batch();
        let rows: Vec<usize> = (0..6).collect();
        let hier = SpecAware { k0: 0, batch_budget: 0, req_budget: 2 };
        let s_h = hier.select(&ctx(&probs, &rows, &reqs));
        // total activated experts: 2 per request = 4; captures all hot mass
        assert_eq!(s_h.to_vec(), vec![0, 1, 8, 9]);
        let mass = |s: &ExpertSet| -> f32 {
            rows.iter()
                .map(|&i| s.iter().map(|j| probs.get(i, j)).sum::<f32>())
                .sum()
        };
        assert!(mass(&s_h) > 0.9 * 6.0); // ≥90% of total gating mass with 4 experts
    }

    #[test]
    fn acceptance_prior_weighting_shifts_request_budget() {
        // The ragged effective batch scales a request's speculative
        // positions by its acceptance prior. With the prior at 1.0 a hot
        // expert on the deepest position wins the per-request budget; with
        // the prior collapsed (deep rows ≈ 0) the budget must go to the
        // committed position's runner-up instead.
        let mk = |hot: usize, scale: f32| {
            let mut row = vec![0.01f32; 16];
            row[hot] = 5.0;
            row[(hot + 1) % 16] = 3.0;
            softmax_in_place(&mut row);
            for v in row.iter_mut() {
                *v *= scale;
            }
            row
        };
        // request: committed token hot on 0, speculative tokens hot on 8 —
        // position weights emulate priors 1.0 vs 0.05.
        let full = ScoreMatrix::from_rows(&[mk(0, 1.0), mk(8, 1.0), mk(8, 1.0)]);
        let collapsed =
            ScoreMatrix::from_rows(&[mk(0, 1.0), mk(8, 0.05), mk(8, 0.0025)]);
        let rows = vec![0, 1, 2];
        let mut scratch = Vec::new();
        let confident = per_request_select(&full, &rows, 1, 1, &mut scratch);
        let skeptical = per_request_select(&collapsed, &rows, 1, 1, &mut scratch);
        // warm-up top-1 per position is scale-invariant: {0, 8} both ways
        for s in [&confident, &skeptical] {
            assert!(s.contains(0) && s.contains(8), "warm-up lost");
        }
        // the one budget slot goes to the speculative runner-up at full
        // prior …
        assert!(confident.contains(9), "{:?}", confident.to_vec());
        // … and to the committed token's runner-up once the prior collapses
        assert!(skeptical.contains(1), "{:?}", skeptical.to_vec());
        assert!(!skeptical.contains(9), "{:?}", skeptical.to_vec());
    }

    #[test]
    fn prop_spec_aware_invariants() {
        forall(
            301,
            120,
            |r: &mut Rng| {
                let b = 1 + r.below(6); // requests
                let ls = r.below(4); // speculative length
                let n = 8 + r.below(56);
                let k0 = r.below(3);
                let mr = r.below(6);
                let m = r.below(8);
                (b, ls, n, k0, mr, m, r.next_u64())
            },
            |&(b, ls, n, k0, mr, m, seed)| {
                let mut r = Rng::new(seed);
                let t = b * (1 + ls);
                let rows_v: Vec<Vec<f32>> = (0..t)
                    .map(|_| {
                        let mut row: Vec<f32> =
                            (0..n).map(|_| r.normal_f32(0.0, 2.0)).collect();
                        softmax_in_place(&mut row);
                        row
                    })
                    .collect();
                let probs = ScoreMatrix::from_rows(&rows_v);
                let rows: Vec<usize> = (0..t).collect();
                let requests: Vec<Vec<usize>> = (0..b)
                    .map(|q| ((q * (1 + ls))..((q + 1) * (1 + ls))).collect())
                    .collect();
                let p = SpecAware { k0, batch_budget: m, req_budget: mr };
                let c = ctx(&probs, &rows, &requests);
                let s = p.select(&c);
                // size bound: Σ_r (|warm_r| + m_r) + m
                let mut scratch = Vec::new();
                let mut bound = m;
                for g in &requests {
                    let warm =
                        per_request_select(&probs, g, 0, k0, &mut scratch).len();
                    bound += warm + mr;
                }
                crate::prop_assert!(s.len() <= bound, "|S|={} > bound {bound}", s.len());
                // warm-up containment: every token's top-k0 in S
                for &i in &rows {
                    for j in topk_indices(probs.row(i), k0) {
                        crate::prop_assert!(s.contains(j), "warm expert missing");
                    }
                }
                // routing stays inside S
                let routing = p.route(&c);
                for ch in &routing.chosen {
                    for &j in ch {
                        crate::prop_assert!(s.contains(j), "routed outside S");
                    }
                }
                Ok(())
            },
        );
    }
}
