//! # Unified cost ledger — the single writer to the sim clock.
//!
//! Every simulated second this crate charges flows through ONE object:
//! the [`Ledger`] owned by `coordinator::ServeLoop`. The cost models in
//! [`crate::memsim`] and [`crate::ep`] are **pure pricers** — they return
//! [`Charge`] values (a [`StepBreakdown`] tagged with a [`Phase`]) and
//! never touch a clock. The serve loop assembles each serving step's
//! charges into an [`Entry`] (verify seconds, draft seconds, migration
//! drain, …) and posts it; [`Ledger::post`] is the only place sim time
//! advances, and `ledger.clock()` replaces the scattered
//! `sim_seconds += …` sites that PRs 1–9 accreted.
//!
//! ## Single-writer clock contract
//!
//! * `Ledger::post(entry)` and `Ledger::advance_to(t)` are the ONLY
//!   operations that move the clock. `ServeMetrics::sim_seconds` is a
//!   read-only **mirror** assigned from `ledger.clock()` after every
//!   post — nothing else may write it.
//! * Every posted second carries a [`Phase`] attribution, so per-phase
//!   totals (`time_decode_s`, `time_spec_s`, `time_prefill_s`,
//!   `time_migration_s`, `time_overhead_s`) are first-class metrics
//!   that conserve: the ledger keeps an `attributed` shadow accumulated
//!   by the *identical* chronological f64 additions as the clock, so
//!   `clock().to_bits() == attributed().to_bits()` holds **exactly**
//!   (asserted across policies × spec × EP × fused waves in
//!   `tests/cost_ledger.rs`), while the per-phase array — a regrouping
//!   of the same summands — matches to within a few ulps.
//! * Idle gaps (arrival later than the current clock) go through
//!   [`Ledger::advance_to`] and are attributed to [`Phase::Overhead`].
//! * Deferred work is ledger state too: adopted migration plans post
//!   their transfer seconds into a backlog
//!   ([`Ledger::defer_migration`]) that subsequent steps drain
//!   ([`Ledger::drain_migration`]) as [`Phase::MigrationDrain`] time.
//!
//! ## Marginal-cost API (charge-aware speculation)
//!
//! Because the ledger owns both pricers, it can answer "what would one
//! more draft level cost *under the current batch*":
//! [`Ledger::marginal_spec_cost`] prices verify depth `d+1` against `d`
//! on the last observed step geometry (dense activations or EP selected
//! sets), plus the draft-side marginal when the draft source is the
//! dense model. `SpecDepthController::charge_aware_depth` compares that
//! against the acceptance-weighted value of the extra committed token
//! (`--spec-charge-aware`); depth choice is scheduling-only, so outputs
//! stay byte-identical (pinned in `tests/spec_mixed_phase.rs`).

use crate::ep::EpCostModel;
use crate::ep::Placement;
use crate::memsim::{DecodeCostModel, StepBreakdown};
use crate::selection::ExpertSet;

/// Attribution bucket for posted sim time. Every charged second belongs
/// to exactly one phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Plain decode forwards (no drafting row in the step).
    Decode,
    /// Ragged speculative verify forwards.
    SpecVerify,
    /// Draft-model sub-steps feeding a verify.
    SpecDraft,
    /// Fused (or sequential) prefill-chunk forwards.
    PrefillWave,
    /// Migration backlog drained against step time.
    MigrationDrain,
    /// Idle gaps (clock advanced to a later arrival) and anything not
    /// otherwise attributable.
    Overhead,
}

impl Phase {
    pub const ALL: [Phase; 6] = [
        Phase::Decode,
        Phase::SpecVerify,
        Phase::SpecDraft,
        Phase::PrefillWave,
        Phase::MigrationDrain,
        Phase::Overhead,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Decode => "decode",
            Phase::SpecVerify => "spec_verify",
            Phase::SpecDraft => "spec_draft",
            Phase::PrefillWave => "prefill_wave",
            Phase::MigrationDrain => "migration_drain",
            Phase::Overhead => "overhead",
        }
    }
}

/// A priced unit of work: an itemized [`StepBreakdown`] tagged with the
/// pricer's suggested [`Phase`]. Pricers return these; they carry no
/// clock side effects. The serve loop decides the *actual* attribution
/// when it adds the charge to an [`Entry`] (e.g. a decode-priced
/// forward inside a prefill wave is attributed [`Phase::PrefillWave`]).
#[derive(Debug, Clone)]
pub struct Charge {
    breakdown: StepBreakdown,
    phase: Phase,
}

impl Charge {
    pub fn new(breakdown: StepBreakdown, phase: Phase) -> Self {
        Charge { breakdown, phase }
    }

    /// Wrap a bare seconds total (pricers whose models don't itemize,
    /// e.g. the EP straggler path) — the breakdown carries only
    /// `total_seconds`.
    pub fn from_seconds(seconds: f64, phase: Phase) -> Self {
        Charge {
            breakdown: StepBreakdown {
                total_seconds: seconds,
                ..StepBreakdown::default()
            },
            phase,
        }
    }

    /// Total priced seconds (the breakdown's roofline total).
    pub fn seconds(&self) -> f64 {
        self.breakdown.total_seconds
    }

    /// The itemized breakdown (bytes, mem/compute/overhead seconds) —
    /// the one accessor benches report from instead of recomputing
    /// fields ad hoc.
    pub fn breakdown(&self) -> &StepBreakdown {
        &self.breakdown
    }

    /// The pricer's suggested attribution.
    pub fn phase(&self) -> Phase {
        self.phase
    }
}

/// One serving step's worth of charges, accumulated in chronological
/// add-order. `total` is summed by the SAME f64 addition sequence the
/// pre-ledger code used (one local accumulator per step), which is what
/// makes the refactor bit-identical on sim time.
#[derive(Debug, Clone, Default)]
pub struct Entry {
    total: f64,
    parts: Vec<(Phase, f64)>,
}

impl Entry {
    pub fn new() -> Self {
        Entry::default()
    }

    /// Add `seconds` attributed to `phase`. Order matters for f64
    /// bit-identity: add charges in the order the step incurs them.
    pub fn add(&mut self, phase: Phase, seconds: f64) {
        self.total += seconds;
        self.parts.push((phase, seconds));
    }

    /// Total seconds accumulated so far (chronological sum).
    pub fn seconds(&self) -> f64 {
        self.total
    }

    pub fn parts(&self) -> &[(Phase, f64)] {
        &self.parts
    }
}

/// The geometry of the last charged step — what
/// [`Ledger::marginal_spec_cost`] prices hypothetical depths against.
#[derive(Debug, Clone)]
pub struct SpecGeometry {
    /// Rows riding the shared forward (batch width of the verify).
    pub riders: usize,
    /// Per-layer activated-expert counts (dense charging path).
    pub activated: Vec<usize>,
    /// Per-layer selected sets (EP charging path), if `cfg.ep`.
    pub selected: Option<Vec<ExpertSet>>,
    /// Whether drafts come from the dense draft model (true) or free
    /// n-gram lookup (false) — decides the draft-side marginal.
    pub model_draft: bool,
}

/// The single writer to the sim clock. Owns the pure pricers
/// ([`DecodeCostModel`], [`EpCostModel`]), the per-phase second totals,
/// and the deferred migration backlog. See the module docs for the
/// contract.
#[derive(Debug, Clone)]
pub struct Ledger {
    clock: f64,
    /// Shadow of `clock` accumulated by the identical chronological
    /// additions — `clock.to_bits() == attributed.to_bits()` always.
    attributed: f64,
    phase_s: [f64; Phase::ALL.len()],
    pricer: DecodeCostModel,
    ep_pricer: EpCostModel,
    migration_backlog_s: f64,
}

impl Ledger {
    pub fn new(pricer: DecodeCostModel, ep_pricer: EpCostModel) -> Self {
        Ledger {
            clock: 0.0,
            attributed: 0.0,
            phase_s: [0.0; Phase::ALL.len()],
            pricer,
            ep_pricer,
            migration_backlog_s: 0.0,
        }
    }

    /// Post one step's entry: the ONLY place (besides
    /// [`Ledger::advance_to`]) sim time advances. Returns the entry's
    /// total seconds, for callers that report the step delta.
    pub fn post(&mut self, entry: Entry) -> f64 {
        let total = entry.total;
        self.clock += total;
        self.attributed += total;
        for (phase, s) in &entry.parts {
            self.phase_s[phase.index()] += s;
        }
        total
    }

    /// Advance the clock to an absolute time `t` (idle gap to a later
    /// arrival). No-op unless `t > clock()`. The gap is attributed to
    /// [`Phase::Overhead`]; `attributed` is re-synced by assignment so
    /// the bit-identity invariant survives the jump.
    pub fn advance_to(&mut self, t: f64) {
        if t > self.clock {
            let gap = t - self.clock;
            self.clock = t;
            self.phase_s[Phase::Overhead.index()] += gap;
            self.attributed = self.clock;
        }
    }

    /// Zero all accumulators (clock, attribution, backlog); pricers are
    /// configuration and survive.
    pub fn reset(&mut self) {
        self.clock = 0.0;
        self.attributed = 0.0;
        self.phase_s = [0.0; Phase::ALL.len()];
        self.migration_backlog_s = 0.0;
    }

    /// Current sim time.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Chronological shadow of the clock — bit-equal by construction.
    pub fn attributed(&self) -> f64 {
        self.attributed
    }

    /// Seconds attributed to one phase so far.
    pub fn phase_seconds(&self, phase: Phase) -> f64 {
        self.phase_s[phase.index()]
    }

    /// The decode/prefill/draft pricer (pure; no clock side effects).
    pub fn pricer(&self) -> &DecodeCostModel {
        &self.pricer
    }

    /// The EP straggler/interconnect pricer.
    pub fn ep_pricer(&self) -> &EpCostModel {
        &self.ep_pricer
    }

    /// Defer migration transfer seconds into the backlog; subsequent
    /// steps drain it through [`Ledger::drain_migration`].
    pub fn defer_migration(&mut self, seconds: f64) {
        self.migration_backlog_s += seconds;
    }

    /// Outstanding deferred migration seconds.
    pub fn migration_backlog(&self) -> f64 {
        self.migration_backlog_s
    }

    /// Drain up to `upto` seconds of migration backlog (an EP step
    /// overlaps transfers with at most its own duration). Returns the
    /// drained amount — the caller adds it to its entry as
    /// [`Phase::MigrationDrain`].
    pub fn drain_migration(&mut self, upto: f64) -> f64 {
        if self.migration_backlog_s <= 0.0 {
            return 0.0;
        }
        let drain = self.migration_backlog_s.min(upto);
        self.migration_backlog_s -= drain;
        drain
    }

    /// Price a verify forward of `riders × (1 + depth)` tokens on the
    /// given step geometry (dense or EP).
    fn verify_cost(&self, depth: usize, geo: &SpecGeometry, placement: Option<&Placement>) -> f64 {
        let n_tokens = geo.riders * (1 + depth);
        match (placement, &geo.selected) {
            (Some(pl), Some(sets)) => {
                let refs: Vec<&ExpertSet> = sets.iter().collect();
                self.pricer.ep_step(pl, &refs, n_tokens, &self.ep_pricer).seconds()
            }
            _ => {
                if geo.activated.is_empty() {
                    return 0.0;
                }
                let scaled = self.pricer.scale_activations(&geo.activated);
                self.pricer.target_step(&scaled, n_tokens).seconds()
            }
        }
    }

    /// Cost of a PLAIN decode step over the geometry's riders (depth 0)
    /// — the per-step value baseline the charge-aware controller divides
    /// by rider count to price one committed token.
    pub fn plain_step_cost(&self, geo: &SpecGeometry, placement: Option<&Placement>) -> f64 {
        self.verify_cost(0, geo, placement)
    }

    /// Marginal cost of raising every rider's draft depth from `depth`
    /// to `depth + 1` under the current batch: the verify-side delta
    /// (wider padded forward) plus, for model drafts, one more uniform
    /// draft sub-step. In the memory-bound decode regime the weight
    /// stream is depth-invariant, so this is typically tiny next to a
    /// committed token's value — exactly the economics the fixed
    /// usefulness threshold couldn't see.
    pub fn marginal_spec_cost(
        &self,
        depth: usize,
        geo: &SpecGeometry,
        placement: Option<&Placement>,
    ) -> f64 {
        let mut marginal =
            self.verify_cost(depth + 1, geo, placement) - self.verify_cost(depth, geo, placement);
        if geo.model_draft && geo.riders > 0 {
            let shallow = self.pricer.draft_cost(&vec![depth; geo.riders]).seconds();
            let deep = self.pricer.draft_cost(&vec![depth + 1; geo.riders]).seconds();
            marginal += deep - shallow;
        }
        marginal.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{CostGeometry, HardwareProfile};

    fn ledger() -> Ledger {
        Ledger::new(
            DecodeCostModel::new(
                HardwareProfile::by_name("h100").unwrap(),
                CostGeometry::for_preset("gptoss-mini").unwrap(),
            ),
            EpCostModel::default(),
        )
    }

    fn geo(riders: usize) -> SpecGeometry {
        SpecGeometry {
            riders,
            activated: vec![60; 4],
            selected: None,
            model_draft: false,
        }
    }

    #[test]
    fn post_accumulates_clock_and_phases_bit_exactly() {
        let mut l = ledger();
        let mut e = Entry::new();
        e.add(Phase::SpecDraft, 0.1);
        e.add(Phase::SpecVerify, 0.25);
        e.add(Phase::MigrationDrain, 0.05);
        let total = e.seconds();
        assert_eq!(l.post(e), total);
        let mut e2 = Entry::new();
        e2.add(Phase::Decode, 0.5);
        l.post(e2);
        // the conservation invariant: attributed shadows the clock
        // through the identical chronological additions
        assert_eq!(l.clock().to_bits(), l.attributed().to_bits());
        assert_eq!(l.phase_seconds(Phase::Decode), 0.5);
        assert_eq!(l.phase_seconds(Phase::SpecDraft), 0.1);
        assert_eq!(l.phase_seconds(Phase::SpecVerify), 0.25);
        assert_eq!(l.phase_seconds(Phase::MigrationDrain), 0.05);
        assert_eq!(l.phase_seconds(Phase::PrefillWave), 0.0);
        // per-phase totals are a regrouping of the same summands:
        // equal within float regrouping slack
        let phase_sum: f64 = Phase::ALL.iter().map(|&p| l.phase_seconds(p)).sum();
        assert!((phase_sum - l.clock()).abs() <= 1e-12 * l.clock().max(1.0));
    }

    #[test]
    fn advance_to_charges_overhead_and_resyncs() {
        let mut l = ledger();
        let mut e = Entry::new();
        e.add(Phase::Decode, 1.0);
        l.post(e);
        l.advance_to(0.5); // backwards: no-op
        assert_eq!(l.clock(), 1.0);
        assert_eq!(l.phase_seconds(Phase::Overhead), 0.0);
        l.advance_to(1.75);
        assert_eq!(l.clock(), 1.75);
        assert_eq!(l.phase_seconds(Phase::Overhead), 0.75);
        assert_eq!(l.clock().to_bits(), l.attributed().to_bits());
    }

    #[test]
    fn migration_backlog_defers_and_drains_bounded() {
        let mut l = ledger();
        l.defer_migration(0.3);
        l.defer_migration(0.2);
        assert_eq!(l.migration_backlog(), 0.5);
        // drain is bounded by the step's own duration
        assert_eq!(l.drain_migration(0.4), 0.4);
        assert!((l.migration_backlog() - 0.1).abs() < 1e-15);
        // and by the remaining backlog
        let rest = l.drain_migration(10.0);
        assert!((rest - 0.1).abs() < 1e-15);
        assert_eq!(l.drain_migration(1.0), 0.0);
        assert_eq!(l.migration_backlog(), 0.0);
    }

    #[test]
    fn reset_zeroes_accumulators_but_keeps_pricers() {
        let mut l = ledger();
        let mut e = Entry::new();
        e.add(Phase::PrefillWave, 2.0);
        l.post(e);
        l.defer_migration(0.5);
        l.reset();
        assert_eq!(l.clock(), 0.0);
        assert_eq!(l.attributed(), 0.0);
        assert_eq!(l.migration_backlog(), 0.0);
        for p in Phase::ALL {
            assert_eq!(l.phase_seconds(p), 0.0);
        }
        // pricers survive: still able to price a step
        assert!(l.plain_step_cost(&geo(4), None) > 0.0);
    }

    #[test]
    fn charge_accessors_and_from_seconds() {
        let l = ledger();
        let scaled = l.pricer().scale_activations(&[60; 4]);
        let c = l.pricer().target_step(&scaled, 8);
        assert_eq!(c.phase(), Phase::Decode);
        assert_eq!(c.seconds(), c.breakdown().total_seconds);
        assert!(c.breakdown().bytes > 0.0);
        let bare = Charge::from_seconds(0.125, Phase::MigrationDrain);
        assert_eq!(bare.seconds(), 0.125);
        assert_eq!(bare.phase(), Phase::MigrationDrain);
        assert_eq!(bare.breakdown().bytes, 0.0);
    }

    #[test]
    fn marginal_spec_cost_is_small_next_to_a_token_in_mem_bound_decode() {
        // The charge-aware controller's whole premise: in the
        // memory-bound regime the weight stream is depth-invariant, so
        // one more padded verify level costs far less than the plain
        // per-token step cost it can replace.
        let l = ledger();
        let g = geo(4);
        let plain = l.plain_step_cost(&g, None);
        let token_value = plain / g.riders as f64;
        for depth in 0..3 {
            let m = l.marginal_spec_cost(depth, &g, None);
            assert!(m >= 0.0);
            assert!(
                m < token_value,
                "depth {depth}: marginal {m} !< token value {token_value}"
            );
        }
    }

    #[test]
    fn marginal_spec_cost_adds_draft_side_for_model_drafts() {
        let l = ledger();
        let mut g = geo(4);
        let lookup = l.marginal_spec_cost(1, &g, None);
        g.model_draft = true;
        let model = l.marginal_spec_cost(1, &g, None);
        assert!(
            model > lookup,
            "model-draft marginal {model} !> lookup marginal {lookup}"
        );
    }

    #[test]
    fn entry_sums_in_add_order() {
        let mut e = Entry::new();
        assert_eq!(e.seconds(), 0.0);
        e.add(Phase::SpecDraft, 0.1);
        e.add(Phase::SpecVerify, 0.2);
        // exactly the local-accumulator sequence: (0.0 + 0.1) + 0.2
        let expect = 0.1f64 + 0.2;
        assert_eq!(e.seconds().to_bits(), expect.to_bits());
        assert_eq!(e.parts().len(), 2);
    }
}
