//! `xshare` — launcher CLI for the XShare serving stack.
//!
//! Subcommands:
//!   serve   --preset gptoss-mini --policy batch:24:1 [--addr HOST:PORT] …
//!   run     --preset tiny --policy spec:1:0:4 --requests 16 [--spec-len 3] …
//!           offline trace run; prints the metrics JSON
//!   client  --addr HOST:PORT --prompt 1,2,3 --max-new-tokens 8
//!   info    --preset tiny    print the manifest summary
//!
//! Any flag of `ServeConfig` can also come from `--config file.json`
//! (CLI flags win).

use anyhow::{bail, Context, Result};

use xshare::config::ServeConfig;
use xshare::coordinator::{Request, Scheduler};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::server::{Client, Server};
use xshare::util::cli::Args;
use xshare::util::json::Json;

const USAGE: &str = "usage: xshare <serve|run|client|info> [--flags]
  serve  --preset P --policy POL [--batch N] [--spec-len L] [--spec-adaptive]
         [--spec-charge-aware] [--spec-draft model|lookup] [--prefill-chunk T]
         [--admission A]
         [--max-queue Q] [--footprint-decay D] [--ep-gpus G] [--ep-evict]
         [--ep-rebalance N] [--prefix-cache-mb MB] [--prefix-min-tokens N]
         [--chunk-shared-selection] [--fleet-replicas N] [--fleet-affinity M]
         [--fleet-high-water Q] [--fleet-probe-every N] [--addr A] [--config F]
  run    --preset P --policy POL --requests N [--batch N] [--spec-len L]
         [--spec-adaptive] [--spec-charge-aware] [--spec-draft D]
         [--prefill-chunk T]
         [--admission A] [--ep-gpus G] [--ep-evict] [--ep-rebalance N]
         [--prefix-cache-mb MB] [--prefix-min-tokens N]
         [--chunk-shared-selection] [--seed S]
  client --addr A --prompt 1,2,3 [--max-new-tokens N] [--id I]
         [--priority P] [--deadline-ms D] [--stream]
  info   --preset P
policies:  vanilla | batch:<m>:<k0> | spec:<k0>:<m>:<mr> | gpu:<k0>:<mg> |
           lynx:<drop> | skip:<beta> | opp:<k'>
admission: fifo | priority | edf | footprint   (--max-queue 0 = unbounded)
spec:      --spec-adaptive adapts per-row draft depth per traffic class;
           --spec-charge-aware prices depth against the cost ledger's
           marginal verify charge instead of a fixed threshold;
           --spec-draft lookup drafts by n-gram lookup (no draft model);
           --stream makes the client print a delta line per committed chunk
ep:        --ep-gpus G [--ep-placement P] deploys expert-parallel; with
           footprint admission, --ep-evict preempts far-worse-fitting rows
           (lossless resume) and --ep-rebalance N re-places experts by the
           tracked class mix every N slot frees
prefix:    --prefix-cache-mb MB caches released rows' prefix KV under an
           LRU VRAM budget; admissions extending a cached prefix restore
           it and prefill only the suffix (--prefix-min-tokens N gates
           what is worth keeping)
prefill:   co-prefilling rows are charged as fused multi-row waves (one
           weight stream per layer per wave); --chunk-shared-selection
           (needs --prefill-chunk >= 2) additionally shares one expert
           set across each chunk's positions — lossy, with the routing
           fidelity delta reported in metrics, never silently
fleet:     --fleet-replicas N serves N independent replica loops (one
           engine each) behind a footprint-affine router: each request's
           traffic-class key picks a home replica by rendezvous hashing,
           keeping same-class (footprint-sharing) requests together so
           per-replica expert unions stay narrow. --fleet-affinity
           class|round-robin selects the router (round-robin is the
           class-blind baseline); --fleet-high-water Q spills a submit to
           the least-loaded replica when the affine target's queue
           reaches Q (0 = no backpressure); --fleet-probe-every N sets
           the health-probe cadence in submits. A replica that dies has
           its in-flight rows failed over losslessly: committed history
           resumes on the next-preferred replica, byte-identical, with
           origin-anchored TTFT/deadline accounting";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<ServeConfig> {
    let base = match args.get("config") {
        Some(path) => ServeConfig::from_json_file(std::path::Path::new(path))?,
        None => ServeConfig::default(),
    };
    base.apply_args(args)
}

fn real_main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    match cmd {
        "serve" => serve(&args),
        "run" => run_offline(&args),
        "client" => client(&args),
        "info" => info(&args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = artifacts_root().join(&cfg.preset);
    eprintln!("loading preset '{}' from {dir:?} …", cfg.preset);
    let server = Server::start_from_dir(dir, cfg.clone())?;
    println!(
        "xshare serving preset={} policy={} admission={} max_queue={} on {}",
        cfg.preset, cfg.policy, cfg.admission, cfg.max_queue, server.addr
    );
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn run_offline(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n_requests = args.usize_or("requests", 8);
    let dir = artifacts_root().join(&cfg.preset);
    let manifest = Manifest::load(&dir)?;
    let vocab = manifest.model.vocab;
    let mut model = MoeModel::new(Engine::load(manifest)?)?;

    let mut gen = TraceGenerator::new(vocab, cfg.seed);
    gen.arrival_rate = 0.0;
    let trace = gen.generate(&TraceDomain::standard_suite(), n_requests);
    let requests: Vec<Request> = trace
        .into_iter()
        .map(|t| {
            let mut r =
                Request::new(t.id, t.prompt, cfg.max_new_tokens.min(t.max_new_tokens));
            r.domain = t.domain;
            r
        })
        .collect();

    let report = Scheduler::new(&mut model, cfg.clone())?.run(requests)?;
    println!("{}", report.metrics.to_json().dump());
    if args.bool("profile") {
        let st = model.engine().stats();
        for (name, (calls, secs)) in &st.per_program {
            eprintln!(
                "  {name:<12} {calls:>5} calls  {:>8.1} ms total  {:>7.2} ms/call",
                secs * 1e3,
                secs * 1e3 / *calls as f64
            );
        }
    }
    eprintln!(
        "policy={} requests={} otps={:.2} mean_activated={:.1} wall={:.2}s",
        cfg.policy,
        report.outputs.len(),
        report.metrics.otps(),
        report.metrics.mean_activated(),
        report.metrics.wall_seconds
    );
    Ok(())
}

fn client(args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr =
        args.get("addr").context("--addr required")?.parse().context("bad --addr")?;
    let prompt: Vec<u32> = args
        .get("prompt")
        .context("--prompt required (comma-separated token ids)")?
        .split(',')
        .map(|t| t.trim().parse().context("bad token id"))
        .collect::<Result<_>>()?;
    let mut req = Request::new(
        args.usize_or("id", 0) as u64,
        prompt,
        args.usize_or("max-new-tokens", 16),
    );
    req.domain = args.str_or("domain", "");
    req.priority = args.usize_or("priority", 0) as u32;
    let deadline = args.usize_or("deadline-ms", 0);
    if deadline > 0 {
        req.deadline_ms = Some(deadline as u64);
    }
    let mut client = Client::connect(&addr)?;
    let resp = if args.bool("stream") {
        // Delta frames print as they arrive; the final line is the same
        // summary the non-streaming path prints.
        client.generate_stream(&req, |delta| {
            println!(
                "{}",
                Json::obj(vec![(
                    "delta",
                    Json::arr(delta.iter().map(|&t| Json::num(t as f64)))
                )])
                .dump()
            );
        })?
    } else {
        client.generate(&req)?
    };
    println!(
        "{}",
        Json::obj(vec![
            ("id", Json::num(resp.id as f64)),
            ("tokens", Json::arr(resp.tokens.iter().map(|&t| Json::num(t as f64)))),
        ])
        .dump()
    );
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "tiny");
    let manifest = Manifest::load(&artifacts_root().join(&preset))?;
    let m = &manifest.model;
    println!("preset          {}", m.name);
    println!(
        "geometry        d={} heads={} ff={} layers={} vocab={}",
        m.d_model, m.n_heads, m.d_ff, m.n_layers, m.vocab
    );
    println!("moe             N={} top-k={} shared={}", m.n_experts, m.top_k, m.n_shared);
    println!("serving         max_batch={} max_seq={}", m.max_batch, m.max_seq);
    println!("draft           layers={} d={}", m.draft_layers, m.draft_d_model);
    println!(
        "programs        {}",
        manifest.programs.keys().cloned().collect::<Vec<_>>().join(", ")
    );
    println!("weights         {} tensors", manifest.weights.len());
    println!("selftests       {}", manifest.selftests.len());
    Ok(())
}
