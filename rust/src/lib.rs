//! # XShare — collaborative in-batch expert sharing for faster MoE inference
//!
//! Rust/JAX/Pallas reproduction of *XShare: Collaborative in-Batch Expert
//! Sharing for Faster MoE Inference* (Vankov et al., 2026).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack
//! (see `DESIGN.md`): JAX+Pallas author the model at build time and AOT-lower
//! it to HLO text; this crate loads the artifacts via the PJRT C API and owns
//! the entire request path — admission, continuous batching, speculative
//! decoding, KV-cache state, and, at its heart, the paper's contribution:
//! **batch-aware expert selection** ([`selection`]).
//!
//! Module map:
//!
//! * [`selection`] — Algorithms 1–6 from the paper plus published baselines.
//! * [`runtime`]   — PJRT client wrapper: load/compile/execute HLO artifacts.
//! * [`model`]     — decode-step walker: embed → L×(attn+router → select →
//!   MoE) → lm_head, KV caches, sampling, draft model.
//! * [`coordinator`] — request lifecycle: queues, continuous batcher,
//!   decode scheduler, speculative verify loop.
//! * [`server`]    — JSON-lines TCP front-end + client.
//! * [`fleet`]     — N serve-loop replicas behind a footprint-affine
//!   router: rendezvous class assignment, queue-depth backpressure,
//!   health states, lossless failover through the resume contract.
//! * [`cost`]      — the unified cost ledger: single writer to the sim
//!   clock, per-phase second attribution, deferred migration backlog,
//!   and the marginal-cost API behind charge-aware speculation.
//! * [`memsim`]    — H100/TPU memory-hierarchy cost model → OTPS estimates
//!   (pure pricers returning [`cost::Charge`] values).
//! * [`ep`]        — expert-parallel placement and per-GPU load accounting.
//! * [`gen`]       — synthetic workload generator (domain-clustered gate
//!   scores, speculative correlation, request traces).
//! * [`metrics`]   — counters, histograms, OTPS accounting, report dumps.
//! * [`config`]    — presets + file/CLI configuration.
//! * [`util`]      — offline substrates: JSON codec, PRNG, math helpers,
//!   property-test harness (the baked registry carries no serde/rand/etc.,
//!   so these are implemented in-tree; DESIGN.md §Offline-substrates).

pub mod config;
pub mod coordinator;
pub mod cost;
pub mod ep;
pub mod fleet;
pub mod gen;
pub mod memsim;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod selection;
pub mod server;
pub mod util;

pub use selection::{ScoreMatrix, SelectionPolicy};
